"""recurrentgemma-9b [hybrid] — Griffin architecture: RG-LRU + local attn 1:2.

38L d_model=4096 16H (MQA kv=1, head_dim 256) d_ff=12288 vocab=256000
[arXiv:2402.19427; unverified]. Pattern (rglru, rglru, local_attn) with a
2048-token sliding window; 38 = 12×3 + 2 tail (rglru, rglru).

O(window) attention state + O(1) RG-LRU state ⇒ runs long_500k.
Fed layout A.
"""
from repro.configs.base import ArchConfig, FedPlan

CONFIG = ArchConfig(
    name="recurrentgemma-9b",
    family="hybrid",
    num_layers=38,
    d_model=4096,
    num_heads=16,
    num_kv_heads=1,
    head_dim=256,
    d_ff=12288,
    vocab_size=256000,
    block_pattern=("rglru", "rglru", "local_attn"),
    window=2048,
    run_long_context=True,
    microbatch=1,
    fed=FedPlan(layout="stacked", edges_per_pod=4, clients_per_edge=4, kappa1=16, kappa2=4),
    source="arXiv:2402.19427",
)


def smoke() -> ArchConfig:
    return ArchConfig(
        name="recurrentgemma-smoke",
        family="hybrid",
        num_layers=5,  # 1 superblock + 2 tail — exercises the tail path
        d_model=64,
        num_heads=4,
        num_kv_heads=1,
        head_dim=16,
        d_ff=128,
        vocab_size=128,
        block_pattern=("rglru", "rglru", "local_attn"),
        window=8,
        param_dtype="float32",
        compute_dtype="float32",
        remat="none",
        attn_chunk=0,
        fed=FedPlan(layout="stacked", edges_per_pod=2, clients_per_edge=2, kappa1=2, kappa2=2),
    )
