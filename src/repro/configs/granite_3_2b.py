"""granite-3-2b [dense] — IBM Granite 3.0 2B base, GQA.

40L d_model=2048 32H (GQA kv=8) d_ff=8192 vocab=49155
[hf:ibm-granite/granite-3.0-2b-base; hf]. Fed layout A. long_500k skipped.
"""
from repro.configs.base import ArchConfig, FedPlan

CONFIG = ArchConfig(
    name="granite-3-2b",
    family="dense",
    num_layers=40,
    d_model=2048,
    num_heads=32,
    num_kv_heads=8,
    d_ff=8192,
    vocab_size=49155,
    run_long_context=False,
    microbatch=4,
    fed=FedPlan(layout="stacked", edges_per_pod=4, clients_per_edge=4, kappa1=16, kappa2=4),
    source="hf:ibm-granite/granite-3.0-2b-base",
)


def smoke() -> ArchConfig:
    return ArchConfig(
        name="granite-3-2b-smoke",
        family="dense",
        num_layers=2,
        d_model=64,
        num_heads=8,
        num_kv_heads=2,
        d_ff=128,
        vocab_size=99,  # odd vocab like the full config's 49155
        param_dtype="float32",
        compute_dtype="float32",
        remat="none",
        attn_chunk=0,
        fed=FedPlan(layout="stacked", edges_per_pod=2, clients_per_edge=2, kappa1=2, kappa2=2),
    )
