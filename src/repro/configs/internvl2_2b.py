"""internvl2-2b [vlm] — InternVL2 (InternViT-300M + InternLM2-1.8B).

24L d_model=2048 16H (GQA kv=8) d_ff=8192 vocab=92553
[arXiv:2404.16821; hf]. The InternViT vision frontend is a STUB per the
assignment: ``input_specs()`` feeds precomputed patch embeddings
(B, S, d_model) directly into the LM backbone (embed_inputs=False).

Fed layout A (stacked clients), 4 edges/pod × 4 clients/edge.
long_500k skipped (full attention).
"""
from repro.configs.base import ArchConfig, FedPlan

CONFIG = ArchConfig(
    name="internvl2-2b",
    family="vlm",
    num_layers=24,
    d_model=2048,
    num_heads=16,
    num_kv_heads=8,
    d_ff=8192,
    vocab_size=92553,
    embed_inputs=False,  # ViT frontend stubbed: patch embeddings in
    run_long_context=False,
    microbatch=4,
    fed=FedPlan(layout="stacked", edges_per_pod=4, clients_per_edge=4, kappa1=16, kappa2=4),
    source="arXiv:2404.16821",
)


def smoke() -> ArchConfig:
    return ArchConfig(
        name="internvl2-smoke",
        family="vlm",
        num_layers=2,
        d_model=64,
        num_heads=4,
        num_kv_heads=2,
        d_ff=128,
        vocab_size=96,
        embed_inputs=False,
        param_dtype="float32",
        compute_dtype="float32",
        remat="none",
        attn_chunk=0,
        fed=FedPlan(layout="stacked", edges_per_pod=2, clients_per_edge=2, kappa1=2, kappa2=2),
    )
