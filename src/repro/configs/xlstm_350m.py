"""xlstm-350m [ssm] — xLSTM with sLSTM + mLSTM blocks.

24L d_model=1024 4H d_ff=0 vocab=50304 [arXiv:2405.04517; unverified].
Block pattern 3:1 mLSTM:sLSTM (the paper's xLSTM[a:b] notation; 350M uses
a small sLSTM fraction). d_ff=0 per the assignment: the cells carry their
own up/down projections, no separate FFN.

O(1) decode state per token (matrix memory C + normalizer) ⇒ runs the
long_500k cell. Fed layout A.
"""
from repro.configs.base import ArchConfig, FedPlan

CONFIG = ArchConfig(
    name="xlstm-350m",
    family="ssm",
    num_layers=24,
    d_model=1024,
    num_heads=4,
    num_kv_heads=4,
    d_ff=0,
    vocab_size=50304,
    block_pattern=("mlstm", "mlstm", "mlstm", "slstm"),
    run_long_context=True,
    microbatch=4,
    fed=FedPlan(layout="stacked", edges_per_pod=4, clients_per_edge=4, kappa1=16, kappa2=4),
    source="arXiv:2405.04517",
)


def smoke() -> ArchConfig:
    return ArchConfig(
        name="xlstm-smoke",
        family="ssm",
        num_layers=4,
        d_model=64,
        num_heads=4,
        num_kv_heads=4,
        d_ff=0,
        vocab_size=96,
        block_pattern=("mlstm", "mlstm", "mlstm", "slstm"),
        mlstm_chunk=16,
        param_dtype="float32",
        compute_dtype="float32",
        remat="none",
        attn_chunk=0,
        fed=FedPlan(layout="stacked", edges_per_pod=2, clients_per_edge=2, kappa1=2, kappa2=2),
    )
