"""Config schema: architecture, federated topology, sharding and shapes.

One ``ArchConfig`` per assigned architecture lives in ``repro/configs/<id>.py``
with the exact dimensions from the assignment, plus a ``smoke()`` reduction of
the same family for CPU tests. The dry-run enumerates
``ArchConfig.input_shapes`` cells.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Tuple

import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    num_experts: int
    top_k: int
    d_ff_expert: int
    num_shared_experts: int = 0
    dense_residual: bool = False  # arctic: dense FFN in parallel with MoE
    capacity_factor: float = 1.25
    router_aux_weight: float = 0.01


@dataclasses.dataclass(frozen=True)
class MLAConfig:
    """DeepSeek-V3 Multi-head Latent Attention dims."""

    q_lora_rank: int = 1536
    kv_lora_rank: int = 512
    qk_nope_head_dim: int = 128
    qk_rope_head_dim: int = 64
    v_head_dim: int = 128


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    """One input-shape cell: (seq_len, global_batch, kind)."""

    name: str
    seq_len: int
    global_batch: int
    kind: str  # "train" | "prefill" | "decode"


LM_SHAPES: Tuple[ShapeSpec, ...] = (
    ShapeSpec("train_4k", 4096, 256, "train"),
    ShapeSpec("prefill_32k", 32768, 32, "prefill"),
    ShapeSpec("decode_32k", 32768, 128, "decode"),
    ShapeSpec("long_500k", 524288, 1, "decode"),
)


@dataclasses.dataclass(frozen=True)
class FedPlan:
    """How HierFAVG maps onto the mesh for this architecture.

    layout "stacked": params get a leading client axis N = edges*clients_per_edge,
      sharded P(("pod","data")); TP within client over "model".
    layout "sharded": one client per pod (cross-silo); leading axis = num_pods,
      inner dims sharded over ("data","model") (FSDP x TP/EP).

    ``fanouts``/``kappas`` opt into ragged / deeper-than-two trees
    (see ``core.hierarchy``): fanouts is the bottom-up child-count nest of
    ``HierarchySpec.from_fanouts`` and describes the FULL tree across all
    pods (unlike the uniform path, which scales edges_per_pod by the
    mesh's pod count); kappas the matching per-level schedule. When None,
    the uniform two-level (edges_per_pod, clients_per_edge, kappa1,
    kappa2) plan applies unchanged.
    """

    layout: str = "stacked"  # "stacked" | "sharded"
    edges_per_pod: int = 4
    clients_per_edge: int = 4
    kappa1: int = 16
    kappa2: int = 4
    fanouts: Optional[Tuple[Tuple[int, ...], ...]] = None  # ragged tree (None -> uniform)
    kappas: Optional[Tuple[int, ...]] = None  # per-level schedule (None -> (κ₁, κ₂))

    def hierarchy(self, num_pods: int = 1):
        """The aggregation tree this plan describes (lazy import: configs
        stay importable without the core package initialized). ``num_pods``
        scales the uniform path only — explicit ``fanouts`` are the full
        tree already."""
        from repro.core.hierarchy import HierarchySpec

        if self.fanouts is not None:
            return HierarchySpec.from_fanouts([list(l) for l in self.fanouts])
        return HierarchySpec.uniform(num_pods * self.edges_per_pod, self.clients_per_edge)

    def schedule(self):
        from repro.core.hierfavg import HierFAVGConfig

        if self.kappas is not None:
            return HierFAVGConfig.multi_level(self.kappas)
        return HierFAVGConfig(kappa1=self.kappa1, kappa2=self.kappa2)


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str  # dense | moe | vlm | audio | ssm | hybrid
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0  # 0 -> d_model // num_heads
    # block pattern, cycled over layers, e.g. ("rglru","rglru","local_attn")
    block_pattern: Tuple[str, ...] = ("attn",)
    window: int = 0  # local-attention window (0 = full causal)
    moe: Optional[MoEConfig] = None
    mla: Optional[MLAConfig] = None
    # stub frontends ([vlm]/[audio]): inputs are precomputed embeddings
    embed_inputs: bool = True
    tie_embeddings: bool = False
    rope_theta: float = 10000.0
    norm_eps: float = 1e-6
    d_rnn: int = 0  # rglru width (0 -> d_model)
    mlstm_chunk: int = 256
    param_dtype: str = "bfloat16"
    compute_dtype: str = "bfloat16"
    # training-step knobs (overridable per cell by the dry-run/perf loop)
    grad_accum: int = 1
    # per-client microbatch (sequences per grad-accum step); the launcher
    # derives grad_accum = per_client_batch // microbatch for each mesh
    microbatch: int = 1
    remat: str = "full"  # "none" | "full" | "dots"
    scan_layers: bool = True
    # flash-style q/k chunking for full-sequence attention: chunk when
    # S > attn_chunk (bounds activation memory to O(S·chunk) per layer);
    # 0 disables. The Pallas kernel replaces this on real TPU.
    attn_chunk: int = 1024
    # which assigned shapes apply; long_500k only for sub-quadratic archs
    run_long_context: bool = False
    fed: FedPlan = dataclasses.field(default_factory=FedPlan)
    # citation tag from the assignment
    source: str = ""

    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or self.d_model // self.num_heads

    @property
    def d_rnn_resolved(self) -> int:
        return self.d_rnn or self.d_model

    @property
    def pattern_period(self) -> int:
        return len(self.block_pattern)

    @property
    def num_superblocks(self) -> int:
        return self.num_layers // self.pattern_period

    @property
    def tail_layers(self) -> int:
        return self.num_layers % self.pattern_period

    def dtype(self) -> jnp.dtype:
        return jnp.dtype(self.param_dtype)

    @property
    def input_shapes(self) -> Tuple[ShapeSpec, ...]:
        out = []
        for s in LM_SHAPES:
            if s.name == "long_500k" and not self.run_long_context:
                continue
            out.append(s)
        return tuple(out)

    @property
    def skipped_shapes(self) -> Tuple[str, ...]:
        if not self.run_long_context:
            return ("long_500k",)
        return ()


def param_count(cfg: ArchConfig) -> int:
    """Analytic parameter count (exact for our implementation; used for
    MODEL_FLOPS, memory budgeting and config sanity tests)."""
    d, hd = cfg.d_model, cfg.resolved_head_dim
    n = 0
    if cfg.embed_inputs:
        n += cfg.vocab_size * d
    n += cfg.vocab_size * d  # lm head (untied)
    per_layer = {}

    def attn_params(kv_heads):
        a = d * cfg.num_heads * hd  # q
        a += 2 * d * kv_heads * hd  # k, v
        a += cfg.num_heads * hd * d  # o
        return a

    def mla_params():
        m = cfg.mla
        a = d * m.q_lora_rank + m.q_lora_rank  # q down + norm
        a += m.q_lora_rank * cfg.num_heads * (m.qk_nope_head_dim + m.qk_rope_head_dim)
        a += d * (m.kv_lora_rank + m.qk_rope_head_dim) + m.kv_lora_rank  # kv down + norm
        a += m.kv_lora_rank * cfg.num_heads * (m.qk_nope_head_dim + m.v_head_dim)
        a += cfg.num_heads * m.v_head_dim * d
        return a

    def mlp_params(ff):
        return 3 * d * ff  # swiglu: w1, w3, w2

    def moe_params():
        m = cfg.moe
        p = d * m.num_experts  # router
        p += m.num_experts * 3 * d * m.d_ff_expert
        p += m.num_shared_experts * 3 * d * m.d_ff_expert
        if m.dense_residual:
            p += mlp_params(cfg.d_ff)
        return p

    def rglru_params():
        dr = cfg.d_rnn_resolved
        p = 2 * d * dr  # x proj + gate proj
        p += 4 * dr  # conv1d width 4
        p += 2 * dr  # input gate + recurrence gate projections are per-channel diag blocks
        p += dr * d  # out proj
        p += 2 * dr * dr // max(cfg.num_heads, 1) * 0  # (block-diag gates folded above)
        p += dr  # lambda
        return p

    def mlstm_params():
        # qkv + out + gates (i,f per head from x) + skip/up proj 2x
        up = 2 * d
        p = d * up * 2  # up-proj and gate branch
        p += up * 3 * up  # q,k,v over up dim
        p += 2 * up  # i,f per-channel
        p += up * d  # down proj
        return p

    def slstm_params():
        heads = max(cfg.num_heads, 1)
        dh = d // heads
        p = 4 * d * d  # i,f,z,o input projections
        p += 4 * heads * dh * dh  # block-diagonal recurrent mats
        p += 4 * d  # biases
        p += d * d  # out proj
        return p

    for kind in set(cfg.block_pattern):
        if kind == "attn" or kind == "local_attn":
            p = attn_params(cfg.num_kv_heads)
            if cfg.mla is not None:
                p = mla_params()
            if cfg.moe is not None:
                p += moe_params()
            elif cfg.d_ff > 0:
                p += mlp_params(cfg.d_ff)
            p += 2 * d  # 2 rmsnorms
            per_layer[kind] = p
        elif kind == "rglru":
            p = rglru_params()
            if cfg.d_ff > 0:
                p += mlp_params(cfg.d_ff)
            p += 2 * d
            per_layer[kind] = p
        elif kind == "mlstm":
            per_layer[kind] = mlstm_params() + d
        elif kind == "slstm":
            per_layer[kind] = slstm_params() + d
        else:
            raise ValueError(kind)

    for i in range(cfg.num_layers):
        n += per_layer[cfg.block_pattern[i % cfg.pattern_period]]
    n += d  # final norm
    return n


def active_param_count(cfg: ArchConfig) -> int:
    """Active params per token (MoE: top_k + shared of the routed pool)."""
    if cfg.moe is None:
        return param_count(cfg)
    m = cfg.moe
    full = param_count(cfg)
    routed_all = cfg.num_layers * m.num_experts * 3 * cfg.d_model * m.d_ff_expert
    routed_active = cfg.num_layers * m.top_k * 3 * cfg.d_model * m.d_ff_expert
    return full - routed_all + routed_active
