"""arctic-480b [moe] — Snowflake Arctic base.

35L d_model=7168 56H (GQA kv=8) d_ff=4864 vocab=32000, MoE 128 experts
top-2 **plus a dense FFN residual in parallel** (Arctic's dense-MoE hybrid)
[hf:Snowflake/snowflake-arctic-base; hf].

Fed layout B (cross-silo): one client per pod; EP over the model axis
(128 experts / 16 = 8 per chip), FSDP over data. long_500k skipped
(full attention, DESIGN.md §Arch-applicability).
"""
from repro.configs.base import ArchConfig, FedPlan, MoEConfig

CONFIG = ArchConfig(
    name="arctic-480b",
    family="moe",
    num_layers=35,
    d_model=7168,
    num_heads=56,
    num_kv_heads=8,
    d_ff=4864,  # dense residual branch width
    vocab_size=32000,
    moe=MoEConfig(num_experts=128, top_k=2, d_ff_expert=4864, dense_residual=True),
    run_long_context=False,
    microbatch=16,
    fed=FedPlan(layout="sharded", edges_per_pod=1, clients_per_edge=1, kappa1=16, kappa2=4),
    source="hf:Snowflake/snowflake-arctic-base",
)


def smoke() -> ArchConfig:
    """Same family (dense-residual MoE), CPU-sized."""
    return ArchConfig(
        name="arctic-smoke",
        family="moe",
        num_layers=2,
        d_model=64,
        num_heads=4,
        num_kv_heads=2,
        d_ff=128,
        vocab_size=128,
        moe=MoEConfig(num_experts=4, top_k=2, d_ff_expert=128, dense_residual=True),
        param_dtype="float32",
        compute_dtype="float32",
        remat="none",
        attn_chunk=0,
        fed=FedPlan(layout="sharded", edges_per_pod=1, clients_per_edge=1, kappa1=2, kappa2=2),
    )
