"""The paper's own experiment configurations (Section IV-A).

50 clients / 5 edge servers / 1 cloud; mini-batch SGD batch 20;
MNIST: lr 0.01, exp decay 0.995/epoch; CIFAR-10: lr 0.1, decay 0.992/epoch;
no momentum. Offline stand-in datasets come from data.synthetic (same
10-class structure, same partition protocols).

Also defines lm_100m — the ~100M-param LM used by the end-to-end training
example (deliverable (b)): a granite-3-family dense transformer scaled to
~100M params.
"""
import dataclasses

from repro.configs.base import ArchConfig, FedPlan


@dataclasses.dataclass(frozen=True)
class PaperFLConfig:
    name: str
    num_clients: int = 50
    num_edges: int = 5
    batch_size: int = 20
    lr: float = 0.01
    lr_decay: float = 0.995  # per epoch
    kappa1: int = 60
    kappa2: int = 1

    @property
    def clients_per_edge(self) -> int:
        return self.num_clients // self.num_edges

    def hierarchy(self):
        """The paper topology as a (uniform two-level) HierarchySpec."""
        from repro.core.hierarchy import HierarchySpec

        return HierarchySpec.uniform(self.num_edges, self.clients_per_edge)


MNIST = PaperFLConfig(name="paper_mnist", lr=0.01, lr_decay=0.995)
CIFAR10 = PaperFLConfig(name="paper_cifar10", lr=0.1, lr_decay=0.992)

# Table II κ sweeps
MNIST_KAPPAS = ((60, 1), (30, 2), (15, 4), (6, 10))
CIFAR_KAPPAS = ((50, 1), (25, 2), (10, 5), (5, 10))

# Beyond-paper topologies for the ragged-hierarchy engine: the same 50
# clients under (a) uneven edge fan-out (metro edges serve more clients
# than rural ones) and (b) a three-level client/edge/region/cloud tree.
RAGGED_EDGE_FANOUT = ((16, 12, 10, 7, 5), (5,))
THREE_LEVEL_FANOUT = ((16, 12, 10, 7, 5), (2, 3), (2,))
THREE_LEVEL_KAPPAS = (15, 2, 2)  # ≈ the paper's (15, 4) budget, split over 3 hops


LM_100M = ArchConfig(
    name="lm-100m",
    family="dense",
    num_layers=12,
    d_model=768,
    num_heads=12,
    num_kv_heads=4,
    d_ff=2048,
    vocab_size=32768,
    param_dtype="float32",
    compute_dtype="float32",
    remat="none",
    attn_chunk=0,
    microbatch=4,
    fed=FedPlan(layout="stacked", edges_per_pod=4, clients_per_edge=4, kappa1=8, kappa2=4),
    source="framework-native 100M example",
)
