"""yi-9b [dense] — Yi-9B, llama-arch with GQA.

48L d_model=4096 32H (GQA kv=4) d_ff=11008 vocab=64000
[arXiv:2403.04652; hf]. Fed layout A. long_500k skipped.
"""
from repro.configs.base import ArchConfig, FedPlan

CONFIG = ArchConfig(
    name="yi-9b",
    family="dense",
    num_layers=48,
    d_model=4096,
    num_heads=32,
    num_kv_heads=4,
    d_ff=11008,
    vocab_size=64000,
    run_long_context=False,
    microbatch=1,
    fed=FedPlan(layout="stacked", edges_per_pod=4, clients_per_edge=4, kappa1=16, kappa2=4),
    source="arXiv:2403.04652",
)


def smoke() -> ArchConfig:
    return ArchConfig(
        name="yi-9b-smoke",
        family="dense",
        num_layers=2,
        d_model=64,
        num_heads=8,
        num_kv_heads=2,
        d_ff=160,
        vocab_size=128,
        param_dtype="float32",
        compute_dtype="float32",
        remat="none",
        attn_chunk=0,
        fed=FedPlan(layout="stacked", edges_per_pod=2, clients_per_edge=2, kappa1=2, kappa2=2),
    )
