"""Architecture registry: ``--arch <id>`` resolution for every launcher."""
from __future__ import annotations

from typing import Callable, Dict, Tuple

from repro.configs import (
    arctic_480b,
    deepseek_7b,
    deepseek_v3_671b,
    granite_20b,
    granite_3_2b,
    internvl2_2b,
    musicgen_medium,
    recurrentgemma_9b,
    xlstm_350m,
    yi_9b,
)
from repro.configs.base import ArchConfig

_MODULES = {
    "arctic-480b": arctic_480b,
    "deepseek-v3-671b": deepseek_v3_671b,
    "internvl2-2b": internvl2_2b,
    "musicgen-medium": musicgen_medium,
    "xlstm-350m": xlstm_350m,
    "deepseek-7b": deepseek_7b,
    "yi-9b": yi_9b,
    "granite-20b": granite_20b,
    "granite-3-2b": granite_3_2b,
    "recurrentgemma-9b": recurrentgemma_9b,
}

ARCH_IDS: Tuple[str, ...] = tuple(_MODULES)


def get_config(name: str) -> ArchConfig:
    if name == "lm-100m":
        from repro.configs.paper import LM_100M

        return LM_100M
    if name not in _MODULES:
        raise KeyError(f"unknown arch '{name}'; known: {ARCH_IDS + ('lm-100m',)}")
    return _MODULES[name].CONFIG


def get_smoke(name: str) -> ArchConfig:
    if name not in _MODULES:
        raise KeyError(f"unknown arch '{name}'")
    return _MODULES[name].smoke()


def all_configs() -> Dict[str, ArchConfig]:
    return {k: m.CONFIG for k, m in _MODULES.items()}
