"""musicgen-medium [audio] — MusicGen 1.5B decoder over EnCodec tokens.

48L d_model=1536 24H (GQA kv=24 = MHA) d_ff=6144 vocab=2048
[arXiv:2306.05284; hf]. The EnCodec audio frontend is a STUB per the
assignment: ``input_specs()`` feeds precomputed frame embeddings
(B, S, d_model); the head predicts the 2048-way codebook.

Fed layout A. long_500k skipped (full attention).
"""
from repro.configs.base import ArchConfig, FedPlan

CONFIG = ArchConfig(
    name="musicgen-medium",
    family="audio",
    num_layers=48,
    d_model=1536,
    num_heads=24,
    num_kv_heads=24,
    d_ff=6144,
    vocab_size=2048,
    embed_inputs=False,  # EnCodec frontend stubbed: frame embeddings in
    run_long_context=False,
    microbatch=4,
    fed=FedPlan(layout="stacked", edges_per_pod=4, clients_per_edge=4, kappa1=16, kappa2=4),
    source="arXiv:2306.05284",
)


def smoke() -> ArchConfig:
    return ArchConfig(
        name="musicgen-smoke",
        family="audio",
        num_layers=2,
        d_model=64,
        num_heads=4,
        num_kv_heads=4,
        d_ff=128,
        vocab_size=64,
        embed_inputs=False,
        param_dtype="float32",
        compute_dtype="float32",
        remat="none",
        attn_chunk=0,
        fed=FedPlan(layout="stacked", edges_per_pod=2, clients_per_edge=2, kappa1=2, kappa2=2),
    )
