"""deepseek-v3-671b [moe] — DeepSeek-V3.

61L d_model=7168 128H (MLA) d_ff=2048(expert) vocab=129280, MoE: 1 shared +
256 routed experts top-8 [arXiv:2412.19437; hf]. Multi-head Latent
Attention with the standard V3 dims (q_lora 1536, kv_lora 512,
qk_nope/rope 128/64, v 128); the absorbed-matrix decode path caches only
the 512+64 latent per token. The MTP (multi-token-prediction) head is
omitted — it is orthogonal to the aggregation protocol under study
(DESIGN.md §Arch-applicability).

Fed layout B (cross-silo): one client per pod; EP 16-way (256/16 = 16
experts per chip), FSDP over data. long_500k skipped (full attention).
"""
from repro.configs.base import ArchConfig, FedPlan, MLAConfig, MoEConfig

CONFIG = ArchConfig(
    name="deepseek-v3-671b",
    family="moe",
    num_layers=61,
    d_model=7168,
    num_heads=128,
    num_kv_heads=128,
    head_dim=128,
    d_ff=2048,
    vocab_size=129280,
    moe=MoEConfig(num_experts=256, top_k=8, d_ff_expert=2048, num_shared_experts=1),
    mla=MLAConfig(
        q_lora_rank=1536, kv_lora_rank=512,
        qk_nope_head_dim=128, qk_rope_head_dim=64, v_head_dim=128,
    ),
    run_long_context=False,
    microbatch=16,
    fed=FedPlan(layout="sharded", edges_per_pod=1, clients_per_edge=1, kappa1=16, kappa2=4),
    source="arXiv:2412.19437",
)


def smoke() -> ArchConfig:
    """Same family (MLA + shared/routed MoE), CPU-sized."""
    return ArchConfig(
        name="deepseek-v3-smoke",
        family="moe",
        num_layers=2,
        d_model=64,
        num_heads=4,
        num_kv_heads=4,
        head_dim=16,
        d_ff=64,
        vocab_size=160,
        moe=MoEConfig(num_experts=4, top_k=2, d_ff_expert=64, num_shared_experts=1),
        mla=MLAConfig(q_lora_rank=32, kv_lora_rank=16, qk_nope_head_dim=16, qk_rope_head_dim=8, v_head_dim=16),
        param_dtype="float32",
        compute_dtype="float32",
        remat="none",
        attn_chunk=0,
        fed=FedPlan(layout="sharded", edges_per_pod=1, clients_per_edge=1, kappa1=2, kappa2=2),
    )
