"""deepseek-7b [dense] — DeepSeek LLM 7B, llama-arch.

30L d_model=4096 32H (MHA: kv=32) d_ff=11008 vocab=102400
[arXiv:2401.02954; hf]. Fed layout A. long_500k skipped (full attention).
"""
from repro.configs.base import ArchConfig, FedPlan

CONFIG = ArchConfig(
    name="deepseek-7b",
    family="dense",
    num_layers=30,
    d_model=4096,
    num_heads=32,
    num_kv_heads=32,
    d_ff=11008,
    vocab_size=102400,
    run_long_context=False,
    microbatch=1,
    fed=FedPlan(layout="stacked", edges_per_pod=4, clients_per_edge=4, kappa1=16, kappa2=4),
    source="arXiv:2401.02954",
)


def smoke() -> ArchConfig:
    return ArchConfig(
        name="deepseek-7b-smoke",
        family="dense",
        num_layers=2,
        d_model=64,
        num_heads=4,
        num_kv_heads=4,
        d_ff=160,
        vocab_size=128,
        param_dtype="float32",
        compute_dtype="float32",
        remat="none",
        attn_chunk=0,
        fed=FedPlan(layout="stacked", edges_per_pod=2, clients_per_edge=2, kappa1=2, kappa2=2),
    )
