"""granite-20b [dense] — IBM Granite 20B code model, MQA.

52L d_model=6144 48H (MQA: kv=1) d_ff=24576 vocab=49152
[arXiv:2405.04324; hf]. The single KV head cannot shard over the 16-way
model axis — the KV projection stays replicated (the sharding rules drop
non-dividing axes) and the KV cache shards over batch only; this makes
granite-20b the framework's MQA stress test. Fed layout A; serving uses
2D (TP+FSDP) weight sharding. long_500k skipped.
"""
from repro.configs.base import ArchConfig, FedPlan

CONFIG = ArchConfig(
    name="granite-20b",
    family="dense",
    num_layers=52,
    d_model=6144,
    num_heads=48,
    num_kv_heads=1,
    d_ff=24576,
    vocab_size=49152,
    run_long_context=False,
    microbatch=1,
    fed=FedPlan(layout="stacked", edges_per_pod=4, clients_per_edge=4, kappa1=16, kappa2=4),
    source="arXiv:2405.04324",
)


def smoke() -> ArchConfig:
    return ArchConfig(
        name="granite-20b-smoke",
        family="dense",
        num_layers=2,
        d_model=64,
        num_heads=8,
        num_kv_heads=1,
        d_ff=256,
        vocab_size=128,
        param_dtype="float32",
        compute_dtype="float32",
        remat="none",
        attn_chunk=0,
        fed=FedPlan(layout="stacked", edges_per_pod=2, clients_per_edge=2, kappa1=2, kappa2=2),
    )
