from repro.configs.base import ArchConfig, FedPlan, LM_SHAPES, MLAConfig, MoEConfig, ShapeSpec

__all__ = ["ArchConfig", "FedPlan", "LM_SHAPES", "MLAConfig", "MoEConfig", "ShapeSpec"]
