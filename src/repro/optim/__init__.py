from repro.optim.transforms import (
    GradientTransformation,
    adam,
    apply_updates,
    chain,
    clip_by_global_norm,
    identity,
    momentum,
    scale,
    scale_by_adam,
    scale_by_learning_rate,
    sgd,
    trace,
)
from repro.optim.schedule import constant, cosine_decay, exponential_decay, warmup_cosine
from repro.optim import compression

__all__ = [
    "GradientTransformation",
    "adam",
    "apply_updates",
    "chain",
    "clip_by_global_norm",
    "identity",
    "momentum",
    "scale",
    "scale_by_adam",
    "scale_by_learning_rate",
    "sgd",
    "trace",
    "constant",
    "cosine_decay",
    "exponential_decay",
    "warmup_cosine",
    "compression",
]
