"""Learning-rate schedules.

The paper uses an initial rate with exponential decay *per epoch*
(MNIST: 0.01 decayed by 0.995/epoch; CIFAR-10: 0.1 decayed by 0.992/epoch).
Schedules here are functions of the *local update count* k; the caller
supplies steps_per_epoch so the decay clock matches the paper's.
"""
from __future__ import annotations

from typing import Callable

import jax.numpy as jnp

Schedule = Callable[[jnp.ndarray], jnp.ndarray]


def constant(value: float) -> Schedule:
    def schedule(count):
        return jnp.asarray(value, jnp.float32)

    return schedule


def exponential_decay(
    init_value: float,
    decay_rate: float,
    transition_steps: int,
    *,
    staircase: bool = True,
) -> Schedule:
    """lr(k) = init * decay_rate ** (k / transition_steps).

    With staircase=True the exponent is floored — decay happens once per
    `transition_steps` (the paper decays once per epoch).
    """

    def schedule(count):
        exp = count.astype(jnp.float32) / float(transition_steps)
        if staircase:
            exp = jnp.floor(exp)
        return jnp.asarray(init_value, jnp.float32) * jnp.asarray(decay_rate, jnp.float32) ** exp

    return schedule


def cosine_decay(init_value: float, decay_steps: int, alpha: float = 0.0) -> Schedule:
    def schedule(count):
        frac = jnp.clip(count.astype(jnp.float32) / float(decay_steps), 0.0, 1.0)
        cosine = 0.5 * (1.0 + jnp.cos(jnp.pi * frac))
        return jnp.asarray(init_value, jnp.float32) * ((1 - alpha) * cosine + alpha)

    return schedule


def warmup_cosine(init_value: float, warmup_steps: int, decay_steps: int, floor: float = 0.0) -> Schedule:
    cos = cosine_decay(init_value, max(decay_steps - warmup_steps, 1), alpha=floor)

    def schedule(count):
        count = count.astype(jnp.float32)
        warm = init_value * count / max(float(warmup_steps), 1.0)
        return jnp.where(count < warmup_steps, warm, cos(count - warmup_steps))

    return schedule
