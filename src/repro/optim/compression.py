"""Communication-compression operators for the expensive cloud (DCN) hop.

The paper's lever for reducing cloud traffic is aggregation frequency (κ₂).
Production systems compound that with payload compression; we provide the
standard menu as pure pytree transforms. All compressors are *unbiased or
error-bounded* and come with exact decompressors, so they compose with
HierFAVG's weighted averaging (compress deltas w − w_broadcast, aggregate,
decompress).

int8 quantization also has a Pallas kernel (`repro.kernels.quantize`) used
on-device; this module is the numpy/jnp-level API and the reference.
"""
from __future__ import annotations

from typing import Any, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

PyTree = Any


class QuantizedTree(NamedTuple):
    """Per-leaf int8 payload + per-block fp32 scales.

    Self-describing: ``shapes``/``dtypes`` record the original leaves (in
    ``tree_leaves`` order of ``payload``), so ``dequantize_int8`` needs no
    ``like`` tree — the wire format carries everything a receiver needs.
    """

    payload: PyTree  # int8 arrays, (num_blocks, block) per leaf
    scales: PyTree  # fp32 arrays, one scale per block of `block` elements
    block: int
    shapes: Optional[Tuple[Tuple[int, ...], ...]] = None  # original leaf shapes
    dtypes: Optional[Tuple[Any, ...]] = None  # original leaf dtypes


def _quantize_leaf(x: jnp.ndarray, block: int) -> Tuple[jnp.ndarray, jnp.ndarray]:
    flat = x.astype(jnp.float32).reshape(-1)
    pad = (-flat.size) % block
    flat = jnp.pad(flat, (0, pad))
    blocks = flat.reshape(-1, block)
    scale = jnp.max(jnp.abs(blocks), axis=1, keepdims=True) / 127.0
    safe = jnp.where(scale == 0, 1.0, scale)
    q = jnp.clip(jnp.round(blocks / safe), -127, 127).astype(jnp.int8)
    return q, scale[:, 0]


def _dequantize_leaf(q: jnp.ndarray, scale: jnp.ndarray, shape, dtype, block: int) -> jnp.ndarray:
    blocks = q.astype(jnp.float32) * scale[:, None]
    flat = blocks.reshape(-1)
    n = 1
    for s in shape:
        n *= s
    return flat[:n].reshape(shape).astype(dtype)


def quantize_int8(tree: PyTree, block: int = 256) -> QuantizedTree:
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    qs = [_quantize_leaf(x, block) for x in leaves]
    payload = jax.tree_util.tree_unflatten(treedef, [t[0] for t in qs])
    scales = jax.tree_util.tree_unflatten(treedef, [t[1] for t in qs])
    return QuantizedTree(
        payload=payload,
        scales=scales,
        block=block,
        shapes=tuple(tuple(x.shape) for x in leaves),
        dtypes=tuple(jnp.asarray(x).dtype for x in leaves),
    )


def dequantize_int8(q: QuantizedTree, like: Optional[PyTree] = None) -> PyTree:
    """Exact inverse layout of ``quantize_int8``. ``like`` is optional: a
    self-describing tree (the default since shapes/dtypes were added)
    reconstructs from its own metadata; passing ``like`` overrides it (and
    is the only option for trees built before the metadata existed)."""
    ps, treedef = jax.tree_util.tree_flatten(q.payload)
    ss = jax.tree_util.tree_leaves(q.scales)
    if like is not None:
        ls = jax.tree_util.tree_leaves(like)
        shapes = [x.shape for x in ls]
        dtypes = [jnp.asarray(x).dtype for x in ls]
    elif q.shapes is not None and q.dtypes is not None:
        shapes, dtypes = list(q.shapes), list(q.dtypes)
    else:
        raise ValueError(
            "QuantizedTree has no shape/dtype metadata; pass the `like` tree"
        )
    if not len(ps) == len(ss) == len(shapes) == len(dtypes):
        raise ValueError(
            f"inconsistent QuantizedTree: {len(ps)} payload leaves, "
            f"{len(ss)} scale leaves, {len(shapes)} shapes, {len(dtypes)} dtypes"
        )
    out = [
        _dequantize_leaf(p, s, shape, dtype, q.block)
        for p, s, shape, dtype in zip(ps, ss, shapes, dtypes)
    ]
    return jax.tree_util.tree_unflatten(treedef, out)


def compressed_bytes(q: QuantizedTree) -> int:
    """Wire size of the compressed tree (payload + scales)."""
    n = 0
    for leaf in jax.tree_util.tree_leaves(q.payload):
        n += leaf.size  # int8 → 1 byte
    for leaf in jax.tree_util.tree_leaves(q.scales):
        n += leaf.size * 4
    return n


def topk_sparsify(tree: PyTree, frac: float) -> Tuple[PyTree, PyTree]:
    """Keep the top-`frac` fraction (by magnitude) of each leaf; zero the rest.

    Returns (sparse_tree, mask). Standard top-k gradient sparsification;
    callers keep the residual (x - sparse) locally for error feedback.
    """

    def leaf(x):
        flat = x.reshape(-1)
        k = max(int(flat.size * frac), 1)
        thresh = jnp.sort(jnp.abs(flat))[-k]
        mask = (jnp.abs(x) >= thresh).astype(x.dtype)
        return x * mask, mask

    out = jax.tree_util.tree_map(leaf, tree)
    sparse = jax.tree_util.tree_map(lambda t: t[0], out, is_leaf=lambda t: isinstance(t, tuple))
    mask = jax.tree_util.tree_map(lambda t: t[1], out, is_leaf=lambda t: isinstance(t, tuple))
    return sparse, mask


def randk_sparsify(tree: PyTree, frac: float, rng: jax.Array) -> Tuple[PyTree, PyTree]:
    """Unbiased random-k sparsification: keep each coordinate w.p. frac, scale by 1/frac."""
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    keys = jax.random.split(rng, len(leaves))
    sparse, masks = [], []
    for x, key in zip(leaves, keys):
        mask = (jax.random.uniform(key, x.shape) < frac).astype(x.dtype)
        sparse.append(x * mask / frac)
        masks.append(mask)
    return jax.tree_util.tree_unflatten(treedef, sparse), jax.tree_util.tree_unflatten(treedef, masks)
