"""Minimal optax-style gradient-transformation library (pure JAX).

optax is not available offline, so the framework carries its own optimizer
substrate. The interface mirrors optax so downstream code reads familiarly:

    opt = sgd(lr)                    # or momentum(lr, 0.9), adam(lr)
    state = opt.init(params)
    updates, state = opt.update(grads, state, params)
    params = apply_updates(params, updates)

All transforms are pytree-polymorphic and work unchanged on stacked
per-client parameters (leading client axis) — each client simply carries its
own slice of the optimizer state, which is exactly the FedAvg-family
semantics (local optimizer state, reset/kept across aggregations per config).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, NamedTuple, Optional, Union

import jax
import jax.numpy as jnp

PyTree = Any
Schedule = Callable[[jnp.ndarray], jnp.ndarray]
ScalarOrSchedule = Union[float, Schedule]


class GradientTransformation(NamedTuple):
    init: Callable[[PyTree], PyTree]
    update: Callable[..., tuple]  # (grads, state, params=None) -> (updates, state)


class EmptyState(NamedTuple):
    pass


class ScaleByScheduleState(NamedTuple):
    count: jnp.ndarray


class TraceState(NamedTuple):
    trace: PyTree


class ScaleByAdamState(NamedTuple):
    count: jnp.ndarray
    mu: PyTree
    nu: PyTree


def _lr_value(lr: ScalarOrSchedule, count: jnp.ndarray) -> jnp.ndarray:
    if callable(lr):
        return lr(count)
    return jnp.asarray(lr)


def apply_updates(params: PyTree, updates: PyTree) -> PyTree:
    return jax.tree_util.tree_map(
        lambda p, u: (p + u.astype(p.dtype)) if p is not None else None, params, updates
    )


def chain(*transforms: GradientTransformation) -> GradientTransformation:
    def init(params):
        return tuple(t.init(params) for t in transforms)

    def update(grads, state, params=None):
        new_state = []
        for t, s in zip(transforms, state):
            grads, s = t.update(grads, s, params)
            new_state.append(s)
        return grads, tuple(new_state)

    return GradientTransformation(init, update)


def identity() -> GradientTransformation:
    return GradientTransformation(
        lambda params: EmptyState(),
        lambda grads, state, params=None: (grads, state),
    )


def scale(factor: float) -> GradientTransformation:
    def update(grads, state, params=None):
        return jax.tree_util.tree_map(lambda g: g * factor, grads), state

    return GradientTransformation(lambda params: EmptyState(), update)


def scale_by_learning_rate(lr: ScalarOrSchedule, *, flip_sign: bool = True) -> GradientTransformation:
    sign = -1.0 if flip_sign else 1.0

    def init(params):
        return ScaleByScheduleState(count=jnp.zeros([], jnp.int32))

    def update(grads, state, params=None):
        step_lr = _lr_value(lr, state.count) * sign
        updates = jax.tree_util.tree_map(lambda g: g * step_lr.astype(g.dtype), grads)
        return updates, ScaleByScheduleState(count=state.count + 1)

    return GradientTransformation(init, update)


def trace(decay: float, *, nesterov: bool = False) -> GradientTransformation:
    """Momentum accumulator (a la optax.trace)."""

    def init(params):
        return TraceState(trace=jax.tree_util.tree_map(jnp.zeros_like, params))

    def update(grads, state, params=None):
        new_trace = jax.tree_util.tree_map(lambda g, t: g + decay * t, grads, state.trace)
        if nesterov:
            updates = jax.tree_util.tree_map(lambda g, t: g + decay * t, grads, new_trace)
        else:
            updates = new_trace
        return updates, TraceState(trace=new_trace)

    return GradientTransformation(init, update)


def clip_by_global_norm(max_norm: float) -> GradientTransformation:
    def update(grads, state, params=None):
        leaves = jax.tree_util.tree_leaves(grads)
        gnorm = jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32))) for g in leaves))
        factor = jnp.minimum(1.0, max_norm / (gnorm + 1e-12))
        return jax.tree_util.tree_map(lambda g: g * factor.astype(g.dtype), grads), state

    return GradientTransformation(lambda params: EmptyState(), update)


def add_decayed_weights(weight_decay: float) -> GradientTransformation:
    def update(grads, state, params=None):
        if params is None:
            raise ValueError("add_decayed_weights requires params")
        return (
            jax.tree_util.tree_map(lambda g, p: g + weight_decay * p.astype(g.dtype), grads, params),
            state,
        )

    return GradientTransformation(lambda params: EmptyState(), update)


def scale_by_adam(b1: float = 0.9, b2: float = 0.999, eps: float = 1e-8) -> GradientTransformation:
    def init(params):
        return ScaleByAdamState(
            count=jnp.zeros([], jnp.int32),
            mu=jax.tree_util.tree_map(lambda p: jnp.zeros_like(p, dtype=jnp.float32), params),
            nu=jax.tree_util.tree_map(lambda p: jnp.zeros_like(p, dtype=jnp.float32), params),
        )

    def update(grads, state, params=None):
        count = state.count + 1
        mu = jax.tree_util.tree_map(
            lambda g, m: b1 * m + (1 - b1) * g.astype(jnp.float32), grads, state.mu
        )
        nu = jax.tree_util.tree_map(
            lambda g, v: b2 * v + (1 - b2) * jnp.square(g.astype(jnp.float32)), grads, state.nu
        )
        mu_hat_scale = 1.0 / (1 - b1 ** count.astype(jnp.float32))
        nu_hat_scale = 1.0 / (1 - b2 ** count.astype(jnp.float32))
        updates = jax.tree_util.tree_map(
            lambda m, v, g: ((m * mu_hat_scale) / (jnp.sqrt(v * nu_hat_scale) + eps)).astype(g.dtype),
            mu,
            nu,
            grads,
        )
        return updates, ScaleByAdamState(count=count, mu=mu, nu=nu)

    return GradientTransformation(init, update)


# ---------------------------------------------------------------------------
# User-facing optimizers
# ---------------------------------------------------------------------------

def sgd(lr: ScalarOrSchedule) -> GradientTransformation:
    """Plain SGD — what the paper uses ("we do not use momentum")."""
    return scale_by_learning_rate(lr)


def momentum(lr: ScalarOrSchedule, decay: float = 0.9, *, nesterov: bool = False) -> GradientTransformation:
    return chain(trace(decay, nesterov=nesterov), scale_by_learning_rate(lr))


def adam(
    lr: ScalarOrSchedule,
    b1: float = 0.9,
    b2: float = 0.999,
    eps: float = 1e-8,
    weight_decay: float = 0.0,
) -> GradientTransformation:
    parts = [scale_by_adam(b1, b2, eps)]
    if weight_decay:
        parts.append(add_decayed_weights(weight_decay))
    parts.append(scale_by_learning_rate(lr))
    return chain(*parts)
