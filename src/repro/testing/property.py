"""Property-testing front-end: hypothesis when installed, a deterministic
fallback otherwise.

The test suite is property-based where the paper states laws (monotonicity,
composition, limits). CI and dev machines install the real ``hypothesis``
via ``pip install -e .[dev]``; hermetic containers without it still collect
and run every test through this shim, which samples each strategy with a
seeded generator and always includes the boundary points (min/max of every
range), so degenerate cases are never missed even at small example counts.

Usage (drop-in subset of the hypothesis API used by this repo)::

    from repro.testing import given, settings, st

    @given(n=st.integers(1, 64), eta=st.floats(1e-4, 0.5))
    @settings(max_examples=50)
    def test_property(n, eta): ...
"""
from __future__ import annotations

from typing import Any, Callable, Dict, Sequence

try:  # pragma: no cover - exercised only when hypothesis is installed
    from hypothesis import given, settings, strategies as st

    HAVE_HYPOTHESIS = True
except ModuleNotFoundError:
    HAVE_HYPOTHESIS = False

    import numpy as np

    _DEFAULT_MAX_EXAMPLES = 30

    class _Strategy:
        """A sampleable value range with explicit boundary examples."""

        def __init__(self, sample: Callable[[np.random.Generator], Any], boundaries: Sequence[Any] = ()):
            self._sample = sample
            self.boundaries = tuple(boundaries)

        def sample(self, rng: np.random.Generator) -> Any:
            return self._sample(rng)

    class _Strategies:
        @staticmethod
        def integers(min_value: int, max_value: int) -> _Strategy:
            return _Strategy(
                lambda rng: int(rng.integers(min_value, max_value + 1)),
                boundaries=(min_value, max_value),
            )

        @staticmethod
        def floats(min_value: float, max_value: float, **_kw) -> _Strategy:
            return _Strategy(
                lambda rng: float(rng.uniform(min_value, max_value)),
                boundaries=(min_value, max_value),
            )

        @staticmethod
        def booleans() -> _Strategy:
            return _Strategy(lambda rng: bool(rng.integers(0, 2)), boundaries=(False, True))

        @staticmethod
        def sampled_from(options: Sequence[Any]) -> _Strategy:
            opts = list(options)
            return _Strategy(lambda rng: opts[int(rng.integers(len(opts)))], boundaries=opts[:2])

        @staticmethod
        def lists(elements: _Strategy, *, min_size: int = 0, max_size: int = 10) -> _Strategy:
            def sample(rng: np.random.Generator):
                size = int(rng.integers(min_size, max_size + 1))
                return [elements.sample(rng) for _ in range(size)]

            # boundary must be hashable (dedup via dict.fromkeys) -> tuple
            return _Strategy(sample, boundaries=(tuple([elements.boundaries[0]] * max(min_size, 1)),))

    st = _Strategies()

    def settings(max_examples: int = _DEFAULT_MAX_EXAMPLES, **_kw):
        """Accepts (a subset of) hypothesis.settings kwargs; others ignored."""

        def deco(fn):
            fn._prop_max_examples = max_examples
            return fn

        return deco

    import inspect

    def given(**strategies: _Strategy):
        """Run the test on boundary combinations first, then seeded samples."""

        def deco(fn):
            def wrapper(*args, **kwargs):
                max_examples = getattr(fn, "_prop_max_examples", _DEFAULT_MAX_EXAMPLES)
                names = list(strategies)
                # boundary pass: all-min, all-max, plus each argument at its
                # other extreme one at a time — every strategy's min AND max
                # is exercised with O(k) combos, however many arguments
                grids = [strategies[n].boundaries or () for n in names]
                combos = []
                if all(grids):
                    lo = tuple(g[0] for g in grids)
                    hi = tuple(g[-1] for g in grids)
                    combos = [lo, hi]
                    for i in range(len(names)):
                        combos.append(lo[:i] + (hi[i],) + lo[i + 1:])
                        combos.append(hi[:i] + (lo[i],) + hi[i + 1:])
                for combo in dict.fromkeys(combos):
                    fn(*args, **dict(kwargs, **dict(zip(names, combo))))
                rng = np.random.default_rng(0)
                for _ in range(max_examples):
                    drawn: Dict[str, Any] = {n: strategies[n].sample(rng) for n in names}
                    fn(*args, **dict(kwargs, **drawn))

            # expose only the non-strategy params (e.g. pytest fixtures) so
            # the test collector doesn't look for fixtures named after them
            sig = inspect.signature(fn)
            keep = [p for name, p in sig.parameters.items() if name not in strategies]
            wrapper.__signature__ = sig.replace(parameters=keep)
            wrapper.__name__ = fn.__name__
            wrapper.__doc__ = fn.__doc__
            wrapper.__module__ = fn.__module__
            return wrapper

        return deco


__all__ = ["HAVE_HYPOTHESIS", "given", "settings", "st"]
