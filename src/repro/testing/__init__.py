from repro.testing.property import HAVE_HYPOTHESIS, given, settings, st

__all__ = ["HAVE_HYPOTHESIS", "given", "settings", "st"]
