"""Transformer layer zoo (pure JAX, pytree params).

Covers everything the assigned architectures need: RMSNorm, RoPE, GQA/MQA
attention (full-causal and sliding-window, train and cached-decode paths),
DeepSeek-V3 MLA (with the absorbed low-rank decode path), SwiGLU MLP, and a
sort-based fixed-capacity MoE (with optional shared experts and Arctic's
parallel dense residual).

Conventions:
  * init_* take (rng, cfg[, ...]) and return a params dict of jnp arrays.
  * apply functions are pure; attention takes explicit position indices.
  * dtypes: params in cfg.param_dtype; matmuls accumulate in f32 via
    ``preferred_element_type`` where it matters.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig


def _hint(x, *members):
    from repro.dist.sharding import hint  # local import: avoid cycle at package init

    return hint(x, *members)


def _hint_groups() -> int:
    """MoE token groups = data-axis size of the hint mesh (1 off-mesh)."""
    from repro.dist.sharding import hint_data_groups

    return hint_data_groups()


def _ep_mode(num_experts: int) -> str:
    from repro.dist.sharding import moe_ep_mode

    return moe_ep_mode(num_experts)


PyTree = Any


# ---------------------------------------------------------------------------
# Norms / embeddings / RoPE
# ---------------------------------------------------------------------------

def init_rmsnorm(d: int, dtype) -> jnp.ndarray:
    return jnp.ones((d,), dtype)


def rmsnorm(x: jnp.ndarray, scale: jnp.ndarray, eps: float = 1e-6) -> jnp.ndarray:
    xf = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
    out = xf * jax.lax.rsqrt(var + eps) * scale.astype(jnp.float32)
    return out.astype(x.dtype)


def _init_dense(rng, shape, dtype, scale: Optional[float] = None):
    fan_in = shape[0]
    std = scale if scale is not None else fan_in ** -0.5
    return (jax.random.normal(rng, shape, jnp.float32) * std).astype(dtype)


def rope_frequencies(head_dim: int, theta: float) -> jnp.ndarray:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim))


def apply_rope(x: jnp.ndarray, positions: jnp.ndarray, theta: float) -> jnp.ndarray:
    """x: (..., S, H, hd); positions: (..., S)."""
    hd = x.shape[-1]
    freqs = rope_frequencies(hd, theta)  # (hd/2,)
    angles = positions[..., None].astype(jnp.float32) * freqs  # (..., S, hd/2)
    cos = jnp.cos(angles)[..., None, :]  # (..., S, 1, hd/2)
    sin = jnp.sin(angles)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# GQA attention
# ---------------------------------------------------------------------------

def init_attention(rng, cfg: ArchConfig) -> Dict[str, jnp.ndarray]:
    d, hd = cfg.d_model, cfg.resolved_head_dim
    dt = cfg.dtype()
    k = jax.random.split(rng, 4)
    return {
        "wq": _init_dense(k[0], (d, cfg.num_heads * hd), dt),
        "wk": _init_dense(k[1], (d, cfg.num_kv_heads * hd), dt),
        "wv": _init_dense(k[2], (d, cfg.num_kv_heads * hd), dt),
        "wo": _init_dense(k[3], (cfg.num_heads * hd, d), dt),
    }


def _split_heads(x, n_heads, hd):
    return x.reshape(*x.shape[:-1], n_heads, hd)


def _repeat_kv(kv: jnp.ndarray, groups: int) -> jnp.ndarray:
    """(B,S,Hkv,hd) -> (B,S,Hkv*groups,hd)."""
    if groups == 1:
        return kv
    return jnp.repeat(kv, groups, axis=2)


def causal_attention(
    q: jnp.ndarray,
    k: jnp.ndarray,
    v: jnp.ndarray,
    *,
    q_positions: jnp.ndarray,
    kv_positions: jnp.ndarray,
    window: int = 0,
    kv_valid: Optional[jnp.ndarray] = None,
) -> jnp.ndarray:
    """Masked softmax attention, GQA-native. q: (B,Sq,H,hd); k/v:
    (B,Sk,Hkv,hd) with H a multiple of Hkv. The query heads are folded into
    groups and contracted against the UN-replicated K/V — materializing the
    repeated KV (naive `jnp.repeat`) would multiply KV HBM traffic by
    H/Hkv (48× for MQA granite-20b), measured as the dominant memory term
    in the first dry-run probe.

    Mask: kv_pos <= q_pos, and (q_pos - kv_pos) < window when window > 0.
    kv_valid: optional (B, Sk) validity mask for cache slots.
    """
    B, Sq, H, hd = q.shape
    Hkv = k.shape[2]
    g = H // Hkv
    scale = hd ** -0.5
    q5 = q.reshape(B, Sq, Hkv, g, hd)
    logits = jnp.einsum(
        "bqhgd,bkhd->bhgqk", q5.astype(jnp.float32), k.astype(jnp.float32)
    ) * scale  # (B,Hkv,g,Sq,Sk)
    mask = kv_positions[:, None, :] <= q_positions[:, :, None]  # (B,Sq,Sk)
    if window > 0:
        mask &= (q_positions[:, :, None] - kv_positions[:, None, :]) < window
    if kv_valid is not None:
        mask &= kv_valid[:, None, :].astype(bool)
    logits = jnp.where(mask[:, None, None, :, :], logits, -1e30)
    probs = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bhgqk,bkhd->bqhgd", probs.astype(v.dtype), v)
    return out.reshape(B, Sq, H, v.shape[-1])  # MLA: v head dim != qk head dim


def chunked_causal_attention(
    q: jnp.ndarray,
    k: jnp.ndarray,
    v: jnp.ndarray,
    *,
    q_positions: jnp.ndarray,
    kv_positions: jnp.ndarray,
    window: int = 0,
    kv_valid: Optional[jnp.ndarray] = None,
    chunk_q: int = 1024,
    chunk_k: int = 1024,
) -> jnp.ndarray:
    """Flash-style double-scan attention: O(S·chunk) activation memory.

    Numerically identical to ``causal_attention`` (online-softmax, f32
    accumulators); used for long sequences where the naive (B,H,Sq,Sk)
    score tensor would not fit. The scan form also keeps HLO size flat in
    S — essential when lowering 32k/500k cells for 512 devices. On real
    TPU the Pallas kernel (kernels.flash_attention) replaces this with
    block-skipping; at the XLA level all (q,k) chunk pairs are computed
    and masked (documented 2× causal FLOPs overhead, see EXPERIMENTS.md).
    """
    B, Sq, H, hd = q.shape
    Sk, Hkv = k.shape[1], k.shape[2]
    hd_v = v.shape[-1]  # MLA: value head dim (128) differs from qk (192)
    g = H // Hkv
    cq, ck = min(chunk_q, Sq), min(chunk_k, Sk)
    if Sq % cq or Sk % ck:
        raise ValueError(f"seq lens ({Sq},{Sk}) must divide chunks ({cq},{ck})")
    nq, nk = Sq // cq, Sk // ck
    scale = hd ** -0.5
    aligned = Sq == Sk  # self-attention with aligned chunk grids

    kc = jnp.moveaxis(k.reshape(B, nk, ck, Hkv, hd), 1, 0)  # (nk,B,ck,Hkv,hd)
    kp = jnp.moveaxis(kv_positions.reshape(B, nk, ck), 1, 0)
    vc = jnp.moveaxis(v.reshape(B, nk, ck, Hkv, hd_v), 1, 0)
    vd = None if kv_valid is None else jnp.moveaxis(kv_valid.reshape(B, nk, ck), 1, 0)

    def k_step(qb, qpos_b, carry, ki):
        acc, m, l = carry
        if vd is None:
            kb, kpos_b, vb = ki
            valid_b = None
        else:
            kb, kpos_b, vb, valid_b = ki
        s = jnp.einsum(
            "bqhgd,bkhd->bhgqk",
            qb.astype(jnp.float32) * scale,
            kb.astype(jnp.float32),
        )  # (B,Hkv,g,cq,ck)
        mask = kpos_b[:, None, None, None, :] <= qpos_b[:, None, None, :, None]
        if window > 0:
            mask &= (
                qpos_b[:, None, None, :, None] - kpos_b[:, None, None, None, :]
            ) < window
        if valid_b is not None:
            mask &= valid_b[:, None, None, None, :].astype(bool)
        s = jnp.where(mask, s, -1e30)
        m_new = jnp.maximum(m, jnp.max(s, axis=-1))
        # p in the value dtype: halves score-tensor HBM traffic; the pv
        # einsum still accumulates in f32 (MXU-style bf16×bf16→f32)
        p = jnp.exp(s - m_new[..., None]).astype(v.dtype)
        alpha = jnp.exp(m - m_new)
        l_new = l * alpha + jnp.sum(p.astype(jnp.float32), axis=-1)
        pv = jnp.einsum(
            "bhgqk,bkhd->bhgqd", p, vb, preferred_element_type=jnp.float32
        )
        acc_new = acc * alpha[..., None] + pv
        return acc_new, m_new, l_new

    outs = []
    # q chunks as a python loop: per-chunk STATIC k bounds skip the fully
    # masked blocks (strictly-upper causal triangle; beyond-window history)
    # instead of computing and masking them — the structural win the Pallas
    # kernel realizes on TPU, here at the XLA level
    for iq in range(nq):
        q_lo = iq * cq
        q_hi = q_lo + cq - 1
        qb = q[:, q_lo : q_lo + cq].reshape(B, cq, Hkv, g, hd)
        qpos_b = q_positions[:, q_lo : q_lo + cq]
        if aligned:
            k_end = min(iq + 1, nk)  # causal: no keys beyond this q chunk
            k_start = max(0, (q_lo - window + 1) // ck) if window > 0 else 0
        else:
            k_start, k_end = 0, nk

        acc0 = jnp.zeros((B, Hkv, g, cq, hd_v), jnp.float32)
        m0 = jnp.full((B, Hkv, g, cq), -jnp.inf, jnp.float32)
        l0 = jnp.zeros((B, Hkv, g, cq), jnp.float32)
        sl = lambda t: t[k_start:k_end]
        xs = (
            (sl(kc), sl(kp), sl(vc))
            if vd is None
            else (sl(kc), sl(kp), sl(vc), sl(vd))
        )
        # remat the k-step: without it, scan saves every (cq,ck) probability
        # tensor for the backward pass — re-materializing the full S² scores
        # in HBM and defeating the flash structure (measured 20× memory-term
        # inflation on granite-3-2b train_4k; see EXPERIMENTS.md §Perf)
        body = jax.checkpoint(lambda c, ki, _qb=qb, _qp=qpos_b: (k_step(_qb, _qp, c, ki), None))
        (acc, m, l), _ = jax.lax.scan(body, (acc0, m0, l0), xs)
        out = acc / jnp.maximum(l, 1e-30)[..., None]  # (B,Hkv,g,cq,hd)
        outs.append(out)

    out = jnp.stack(outs, axis=1)  # (B,nq,Hkv,g,cq,hd_v)
    out = jnp.transpose(out, (0, 1, 4, 2, 3, 5)).reshape(B, Sq, H, hd_v)
    return out.astype(v.dtype)


def _full_attention(q, k, v, cfg: ArchConfig, positions, *, window: int = 0):
    """Dispatch: naive for short sequences, chunked for long."""
    S = q.shape[1]
    if cfg.attn_chunk and S > cfg.attn_chunk:
        return chunked_causal_attention(
            q, k, v,
            q_positions=positions, kv_positions=positions,
            window=window, chunk_q=cfg.attn_chunk, chunk_k=cfg.attn_chunk,
        )
    return causal_attention(q, k, v, q_positions=positions, kv_positions=positions, window=window)


def attention_apply(
    params: Dict[str, jnp.ndarray],
    x: jnp.ndarray,
    cfg: ArchConfig,
    positions: jnp.ndarray,
    *,
    window: int = 0,
) -> jnp.ndarray:
    """Training / prefill self-attention over the full sequence."""
    B, S, d = x.shape
    hd = cfg.resolved_head_dim
    q = _split_heads(x @ params["wq"], cfg.num_heads, hd)
    k = _split_heads(x @ params["wk"], cfg.num_kv_heads, hd)
    v = _split_heads(x @ params["wv"], cfg.num_kv_heads, hd)
    q = apply_rope(q, positions, cfg.rope_theta)
    k = apply_rope(k, positions, cfg.rope_theta)
    out = _full_attention(q, k, v, cfg, positions, window=window)
    return out.reshape(B, S, -1) @ params["wo"]


def init_kv_cache(cfg: ArchConfig, batch: int, max_len: int, dtype) -> Dict[str, jnp.ndarray]:
    hd = cfg.resolved_head_dim
    cache_len = min(max_len, cfg.window) if cfg.window > 0 else max_len
    return {
        "k": jnp.zeros((batch, cache_len, cfg.num_kv_heads, hd), dtype),
        "v": jnp.zeros((batch, cache_len, cfg.num_kv_heads, hd), dtype),
        # absolute position stored in each slot (-1 = empty), for ring buffers
        "pos": jnp.full((batch, cache_len), -1, jnp.int32),
    }


def attention_decode(
    params: Dict[str, jnp.ndarray],
    x: jnp.ndarray,
    cache: Dict[str, jnp.ndarray],
    cfg: ArchConfig,
    position: jnp.ndarray,
    *,
    window: int = 0,
) -> Tuple[jnp.ndarray, Dict[str, jnp.ndarray]]:
    """Single-token decode with a (ring-buffered, if windowed) KV cache.

    x: (B, 1, d); position: (B,) absolute position of the new token.
    """
    B, _, d = x.shape
    hd = cfg.resolved_head_dim
    cache_len = cache["k"].shape[1]
    q = _split_heads(x @ params["wq"], cfg.num_heads, hd)
    k_new = _split_heads(x @ params["wk"], cfg.num_kv_heads, hd)
    v_new = _split_heads(x @ params["wv"], cfg.num_kv_heads, hd)
    q = apply_rope(q, position[:, None], cfg.rope_theta)
    k_new = apply_rope(k_new, position[:, None], cfg.rope_theta)

    slot = jnp.mod(position, cache_len)  # ring for windowed, linear otherwise
    oh = jax.nn.one_hot(slot, cache_len, dtype=cache["k"].dtype)  # (B, L)
    k = cache["k"] * (1 - oh)[..., None, None] + oh[..., None, None] * k_new
    v = cache["v"] * (1 - oh)[..., None, None] + oh[..., None, None] * v_new
    pos_buf = jnp.where(oh.astype(bool), position[:, None], cache["pos"])

    out = causal_attention(
        q,
        k,
        v,
        q_positions=position[:, None],
        kv_positions=pos_buf,
        window=window,
        kv_valid=pos_buf >= 0,
    )
    y = out.reshape(B, 1, -1) @ params["wo"]
    return y, {"k": k, "v": v, "pos": pos_buf}


# ---------------------------------------------------------------------------
# MLA (DeepSeek-V3 multi-head latent attention)
# ---------------------------------------------------------------------------

def init_mla(rng, cfg: ArchConfig) -> Dict[str, jnp.ndarray]:
    m = cfg.mla
    d, H = cfg.d_model, cfg.num_heads
    dt = cfg.dtype()
    k = jax.random.split(rng, 6)
    qk_head = m.qk_nope_head_dim + m.qk_rope_head_dim
    return {
        "wq_a": _init_dense(k[0], (d, m.q_lora_rank), dt),
        "q_norm": init_rmsnorm(m.q_lora_rank, dt),
        "wq_b": _init_dense(k[1], (m.q_lora_rank, H * qk_head), dt),
        "wkv_a": _init_dense(k[2], (d, m.kv_lora_rank + m.qk_rope_head_dim), dt),
        "kv_norm": init_rmsnorm(m.kv_lora_rank, dt),
        "wkv_b": _init_dense(k[3], (m.kv_lora_rank, H * (m.qk_nope_head_dim + m.v_head_dim)), dt),
        "wo": _init_dense(k[4], (H * m.v_head_dim, d), dt),
    }


def mla_apply(params, x, cfg: ArchConfig, positions) -> jnp.ndarray:
    """Full-sequence MLA (training / prefill)."""
    m = cfg.mla
    B, S, d = x.shape
    H = cfg.num_heads
    dn, dr, dv = m.qk_nope_head_dim, m.qk_rope_head_dim, m.v_head_dim

    q = rmsnorm(x @ params["wq_a"], params["q_norm"], cfg.norm_eps) @ params["wq_b"]
    q = q.reshape(B, S, H, dn + dr)
    q_nope, q_rope = q[..., :dn], q[..., dn:]
    q_rope = apply_rope(q_rope, positions, cfg.rope_theta)

    kv = x @ params["wkv_a"]  # (B,S, kv_lora + dr)
    c_kv, k_rope = kv[..., : m.kv_lora_rank], kv[..., m.kv_lora_rank :]
    c_kv = rmsnorm(c_kv, params["kv_norm"], cfg.norm_eps)
    k_rope = apply_rope(k_rope[..., None, :], positions, cfg.rope_theta)  # (B,S,1,dr)

    kvu = (c_kv @ params["wkv_b"]).reshape(B, S, H, dn + dv)
    k_nope, v = kvu[..., :dn], kvu[..., dn:]
    k = jnp.concatenate([k_nope, jnp.broadcast_to(k_rope, (B, S, H, dr))], axis=-1)
    qf = jnp.concatenate([q_nope, q_rope], axis=-1)
    out = _full_attention(qf, k, v, cfg, positions)
    return out.reshape(B, S, H * dv) @ params["wo"]


def init_mla_cache(cfg: ArchConfig, batch: int, max_len: int, dtype) -> Dict[str, jnp.ndarray]:
    m = cfg.mla
    return {
        "c_kv": jnp.zeros((batch, max_len, m.kv_lora_rank), dtype),
        "k_rope": jnp.zeros((batch, max_len, m.qk_rope_head_dim), dtype),
        "pos": jnp.full((batch, max_len), -1, jnp.int32),
    }


def mla_decode(params, x, cache, cfg: ArchConfig, position) -> Tuple[jnp.ndarray, Dict]:
    """Absorbed-matrix MLA decode: attend in the compressed latent space.

    Scores use q_nope projected through W_ukv^T (absorb), so the cache stores
    only (kv_lora_rank + rope) per token — the paper's KV-compression win.
    """
    m = cfg.mla
    B, _, d = x.shape
    H = cfg.num_heads
    dn, dr, dv = m.qk_nope_head_dim, m.qk_rope_head_dim, m.v_head_dim
    L = m.kv_lora_rank

    q = rmsnorm(x @ params["wq_a"], params["q_norm"], cfg.norm_eps) @ params["wq_b"]
    q = q.reshape(B, 1, H, dn + dr)
    q_nope, q_rope = q[..., :dn], q[..., dn:]
    q_rope = apply_rope(q_rope, position[:, None], cfg.rope_theta)

    kv = x @ params["wkv_a"]
    c_new = rmsnorm(kv[..., :L], params["kv_norm"], cfg.norm_eps)  # (B,1,L)
    kr_new = apply_rope(kv[..., None, L:], position[:, None], cfg.rope_theta)[:, :, 0]  # (B,1,dr)

    max_len = cache["c_kv"].shape[1]
    oh = jax.nn.one_hot(position, max_len, dtype=c_new.dtype)  # (B, S)
    c_kv = cache["c_kv"] * (1 - oh)[..., None] + oh[..., None] * c_new
    k_rope = cache["k_rope"] * (1 - oh)[..., None] + oh[..., None] * kr_new
    pos_buf = jnp.where(oh.astype(bool), position[:, None], cache["pos"])

    # absorb: W_ukv columns for k_nope: (L, H, dn); for v: (L, H, dv)
    wkv_b = params["wkv_b"].reshape(L, H, dn + dv)
    w_uk, w_uv = wkv_b[..., :dn], wkv_b[..., dn:]
    q_lat = jnp.einsum("bqhn,lhn->bqhl", q_nope.astype(jnp.float32), w_uk.astype(jnp.float32))
    scores = jnp.einsum("bqhl,bsl->bhqs", q_lat, c_kv.astype(jnp.float32))
    scores += jnp.einsum("bqhr,bsr->bhqs", q_rope.astype(jnp.float32), k_rope.astype(jnp.float32))
    scores *= (dn + dr) ** -0.5
    valid = (pos_buf >= 0) & (pos_buf <= position[:, None])
    scores = jnp.where(valid[:, None, None, :], scores, -1e30)
    probs = jax.nn.softmax(scores, axis=-1)
    ctx = jnp.einsum("bhqs,bsl->bqhl", probs, c_kv.astype(jnp.float32))  # latent context
    out = jnp.einsum("bqhl,lhv->bqhv", ctx, w_uv.astype(jnp.float32)).astype(x.dtype)
    y = out.reshape(B, 1, H * dv) @ params["wo"]
    return y, {"c_kv": c_kv, "k_rope": k_rope, "pos": pos_buf}


# ---------------------------------------------------------------------------
# MLPs
# ---------------------------------------------------------------------------

def init_mlp(rng, d: int, ff: int, dtype) -> Dict[str, jnp.ndarray]:
    k = jax.random.split(rng, 3)
    return {
        "w1": _init_dense(k[0], (d, ff), dtype),
        "w3": _init_dense(k[1], (d, ff), dtype),
        "w2": _init_dense(k[2], (ff, d), dtype),
    }


def mlp_apply(params, x):
    h = jax.nn.silu(x @ params["w1"]) * (x @ params["w3"])
    return h @ params["w2"]


# ---------------------------------------------------------------------------
# MoE: sort-based fixed-capacity dispatch
# ---------------------------------------------------------------------------

def init_moe(rng, cfg: ArchConfig) -> Dict[str, jnp.ndarray]:
    m = cfg.moe
    d = cfg.d_model
    dt = cfg.dtype()
    k = jax.random.split(rng, 6)
    params = {
        "router": _init_dense(k[0], (d, m.num_experts), jnp.float32, scale=d ** -0.5),
        "w1": _init_dense(k[1], (m.num_experts, d, m.d_ff_expert), dt),
        "w3": _init_dense(k[2], (m.num_experts, d, m.d_ff_expert), dt),
        "w2": _init_dense(k[3], (m.num_experts, m.d_ff_expert, d), dt),
    }
    if m.num_shared_experts:
        params["shared"] = init_mlp(k[4], d, m.num_shared_experts * m.d_ff_expert, dt)
    if m.dense_residual:
        params["dense"] = init_mlp(k[5], d, cfg.d_ff, dt)
    return params


def moe_capacity(num_tokens: int, cfg: ArchConfig) -> int:
    m = cfg.moe
    cap = int(num_tokens * m.top_k * m.capacity_factor / m.num_experts) + 1
    return max(8, -(-cap // 8) * 8)  # round up to multiple of 8, floor 8


def moe_apply(params, x: jnp.ndarray, cfg: ArchConfig) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """x: (T, d) flattened tokens. Returns (y, aux_loss).

    Group-local sort-based dispatch into fixed-capacity buffers (the
    production EP pattern): tokens are split into G groups aligned with the
    data shards, so routing/sort/scatter are *local* per shard (batched over
    the sharded group axis — no cross-device indexing). The only
    communication is the (G,E,Cg,d) → (E,G·Cg,d) layout change into
    expert-major order — exactly one all-to-all each way — after which the
    batched expert SwiGLU is fully local (experts sharded over data×model,
    matching the expert-weight sharding). A global-scatter formulation left
    GSPMD replicating the dispatch (measured 1200→2900s collective on
    deepseek-v3 train_4k; see EXPERIMENTS.md §Perf).

    Capacity is enforced per group (standard: it also statically bounds the
    all-to-all payload). Overflow assignments are dropped.
    """
    m = cfg.moe
    T, d = x.shape
    E, K = m.num_experts, m.top_k
    G = _hint_groups()
    if T % G:
        G = 1
    Tg = T // G
    C = moe_capacity(Tg, cfg)

    xg = _hint(x.reshape(G, Tg, d), "data", None, None)
    logits = jnp.einsum(
        "gtd,de->gte", xg.astype(jnp.float32), params["router"]
    )  # (G,Tg,E)
    probs = jax.nn.softmax(logits, axis=-1)
    gates, ids = jax.lax.top_k(probs, K)  # (G,Tg,K)
    gates = gates / jnp.sum(gates, axis=-1, keepdims=True)

    def dispatch_group(xg_, ids_):
        """Per-group (local) rank + scatter. xg_: (Tg,d); ids_: (Tg,K)."""
        flat_e = ids_.reshape(-1)  # (Tg*K,)
        order = jnp.argsort(flat_e, stable=True)
        sorted_e = flat_e[order]
        counts = jnp.bincount(flat_e, length=E)
        starts = jnp.cumsum(counts) - counts
        ranks_sorted = jnp.arange(Tg * K) - starts[sorted_e]
        ranks = jnp.zeros_like(ranks_sorted).at[order].set(ranks_sorted)
        token_idx = jnp.repeat(jnp.arange(Tg), K)
        buf = jnp.zeros((E, C, d), xg_.dtype)
        buf = buf.at[flat_e, ranks].set(xg_[token_idx], mode="drop")
        return buf, ranks, flat_e

    buf_g, ranks_g, flat_e_g = jax.vmap(dispatch_group)(xg, ids)  # (G,E,C,d)
    mode = _ep_mode(E)

    if mode == "none":
        buf = jnp.moveaxis(buf_g, 0, 1).reshape(E, G * C, d)
    else:
        # explicit shard_map all-to-all: GSPMD cannot reshard the G→E
        # layout change (it replicates — 19.7 GB all-gathers ×915 measured
        # on deepseek-v3 train_4k; EXPERIMENTS.md §Perf)
        from repro.dist.sharding import moe_dispatch_exchange

        buf = moe_dispatch_exchange(buf_g, mode)

    h = jnp.einsum("ecd,edf->ecf", buf, params["w1"], preferred_element_type=jnp.float32)
    g = jnp.einsum("ecd,edf->ecf", buf, params["w3"], preferred_element_type=jnp.float32)
    h = (jax.nn.silu(h) * g).astype(x.dtype)
    # storage-dtype output: when w2's contraction dim is FSDP-sharded the
    # result is psum'ed over the data axis — bf16 halves that payload (the
    # MXU accumulates in f32 regardless on the TPU target)
    out_buf = jnp.einsum("ecf,efd->ecd", h, params["w2"])
    out_buf = out_buf.astype(x.dtype)

    if mode == "none":
        out_g = jnp.moveaxis(out_buf.reshape(E, G, C, d), 1, 0)  # (G,E,C,d)

        def combine_group(out_, flat_e_, ranks_, gates_):
            gathered = out_.at[flat_e_, ranks_].get(mode="fill", fill_value=0.0)
            return jnp.sum(
                gathered.reshape(Tg, K, d).astype(jnp.float32) * gates_[..., None], axis=1
            )

        yg = jax.vmap(combine_group)(out_g, flat_e_g, ranks_g, gates)  # (G,Tg,d)
    else:
        from repro.dist.sharding import moe_combine_exchange

        yg = moe_combine_exchange(out_buf, flat_e_g, ranks_g, gates, mode, C)
    y = yg.reshape(T, d).astype(jnp.float32)

    if "shared" in params:
        y = y + mlp_apply(params["shared"], x).astype(jnp.float32)
    if "dense" in params:
        y = y + mlp_apply(params["dense"], x).astype(jnp.float32)

    # Switch-style load-balance aux loss
    frac_tokens = jnp.mean(jax.nn.one_hot(ids, E, dtype=jnp.float32), axis=(0, 1, 2)) * K
    mean_probs = jnp.mean(probs, axis=(0, 1))
    aux = m.router_aux_weight * E * jnp.sum(frac_tokens * mean_probs)
    return y.astype(x.dtype), aux
