"""Recurrent / SSM blocks: RG-LRU (RecurrentGemma), mLSTM and sLSTM (xLSTM).

All three expose:
    init_<name>(rng, cfg)                  -> params
    <name>_apply(params, x, cfg)           -> y           (full sequence)
    <name>_init_state(cfg, batch, dtype)   -> state       (O(1) decode state)
    <name>_step(params, x_t, state, cfg)   -> (y_t, state)

RG-LRU uses an associative scan (sub-quadratic, O(S) work / O(log S) depth);
mLSTM uses the stabilized *chunkwise* form (exact, scan over chunks with a
matrix-state carry — validated against the naive recurrent oracle in tests);
sLSTM is inherently sequential (hidden-state-dependent gates) and uses
lax.scan over time. These are the blocks that make `long_500k` decoding O(1)
per token for the ssm/hybrid architectures.
"""
from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models.layers import _init_dense

_RGLRU_C = 8.0


# ---------------------------------------------------------------------------
# RG-LRU (Griffin / RecurrentGemma temporal-mixing block)
# ---------------------------------------------------------------------------

def init_rglru(rng, cfg: ArchConfig) -> Dict[str, jnp.ndarray]:
    d, dr = cfg.d_model, cfg.d_rnn_resolved
    dt = cfg.dtype()
    k = jax.random.split(rng, 7)
    # Lambda init so that a = exp(-c*softplus(L)*sigma(..)) sits in (0.9, 0.999)
    lam = jax.random.uniform(k[0], (dr,), jnp.float32, 0.3, 0.8)
    return {
        "w_x": _init_dense(k[1], (d, dr), dt),
        "w_gate": _init_dense(k[2], (d, dr), dt),
        "conv": _init_dense(k[3], (4, dr), dt, scale=0.5),
        "a_r": _init_dense(k[4], (dr,), jnp.float32, scale=1.0),
        "a_i": _init_dense(k[5], (dr,), jnp.float32, scale=1.0),
        "lambda": lam,
        "w_out": _init_dense(k[6], (dr, d), dt),
    }


def _rglru_coeffs(params, v: jnp.ndarray):
    """Per-step recurrence coefficients. v: (..., dr) conv output.

    log a_t = -c * softplus(Lambda) * sigmoid(a_r * v_t)
    b_t     = sqrt(1 - a_t^2) * sigmoid(a_i * v_t) * v_t
    """
    vf = v.astype(jnp.float32)
    r = jax.nn.sigmoid(params["a_r"] * vf)
    i = jax.nn.sigmoid(params["a_i"] * vf)
    log_a = -_RGLRU_C * jax.nn.softplus(params["lambda"]) * r
    a = jnp.exp(log_a)
    b = jnp.sqrt(jnp.clip(1.0 - jnp.exp(2.0 * log_a), 0.0, 1.0)) * i * vf
    return a, b


def _conv1d_causal(x: jnp.ndarray, kernel: jnp.ndarray) -> jnp.ndarray:
    """Depthwise causal conv, width 4. x: (B,S,dr), kernel: (4,dr)."""
    pad = jnp.pad(x, ((0, 0), (3, 0), (0, 0)))
    return sum(pad[:, i : i + x.shape[1]] * kernel[i] for i in range(4))


def rglru_apply(params, x: jnp.ndarray, cfg: ArchConfig) -> jnp.ndarray:
    """Full-sequence RG-LRU mixing block. x: (B,S,d) -> (B,S,d)."""
    gate = jax.nn.gelu((x @ params["w_gate"]).astype(jnp.float32))
    v = _conv1d_causal(x @ params["w_x"], params["conv"])
    a, b = _rglru_coeffs(params, v)

    def combine(l, r):
        a1, b1 = l
        a2, b2 = r
        return a1 * a2, a2 * b1 + b2

    _, h = jax.lax.associative_scan(combine, (a, b), axis=1)
    y = (h * gate).astype(x.dtype) @ params["w_out"]
    return y


def rglru_init_state(cfg: ArchConfig, batch: int, dtype) -> Dict[str, jnp.ndarray]:
    dr = cfg.d_rnn_resolved
    return {
        "h": jnp.zeros((batch, dr), jnp.float32),
        "conv": jnp.zeros((batch, 3, dr), dtype),  # last 3 pre-conv inputs
    }


def rglru_step(params, x_t: jnp.ndarray, state, cfg: ArchConfig):
    """x_t: (B,1,d) -> (y_t, state)."""
    xt = x_t[:, 0]
    gate = jax.nn.gelu((xt @ params["w_gate"]).astype(jnp.float32))
    u = xt @ params["w_x"]  # (B, dr)
    window = jnp.concatenate([state["conv"], u[:, None]], axis=1)  # (B,4,dr)
    v = sum(window[:, i] * params["conv"][i] for i in range(4))
    a, b = _rglru_coeffs(params, v)
    h = a * state["h"] + b
    y = ((h * gate).astype(x_t.dtype) @ params["w_out"])[:, None]
    return y, {"h": h, "conv": window[:, 1:]}


# ---------------------------------------------------------------------------
# mLSTM (xLSTM matrix-memory cell), stabilized chunkwise form
# ---------------------------------------------------------------------------

def init_mlstm(rng, cfg: ArchConfig) -> Dict[str, jnp.ndarray]:
    d = cfg.d_model
    up = 2 * d
    dt = cfg.dtype()
    k = jax.random.split(rng, 8)
    return {
        "w_up": _init_dense(k[0], (d, up), dt),
        "w_gate": _init_dense(k[1], (d, up), dt),
        "wq": _init_dense(k[2], (up, up), dt),
        "wk": _init_dense(k[3], (up, up), dt),
        "wv": _init_dense(k[4], (up, up), dt),
        "a_i": _init_dense(k[5], (up,), jnp.float32, scale=1.0),
        "a_f": _init_dense(k[6], (up,), jnp.float32, scale=1.0) ,
        "w_down": _init_dense(k[7], (up, d), dt),
    }


def _mlstm_qkv_gates(params, x, cfg):
    """x: (B,S,d) -> q,k,v: (B,S,H,dh); i,f gate logits: (B,S,H)."""
    H = max(cfg.num_heads, 1)
    u = x @ params["w_up"]  # (B,S,up)
    B, S, up = u.shape
    dh = up // H

    def heads(t):
        return t.reshape(B, S, H, dh)

    q = heads(u @ params["wq"]) * dh ** -0.5
    k = heads(u @ params["wk"])
    v = heads(u @ params["wv"])
    uf = u.astype(jnp.float32)
    i_logit = (uf * params["a_i"]).reshape(B, S, H, dh).mean(-1)
    f_logit = (uf * params["a_f"]).reshape(B, S, H, dh).mean(-1) + 1.0  # bias toward remembering
    gate = jax.nn.silu((x @ params["w_gate"]).astype(jnp.float32))
    return q, k, v, i_logit, f_logit, gate


def _mlstm_chunk(q, k, v, i_log, f_log, carry):
    """One chunk of the stabilized chunkwise mLSTM.

    q,k,v: (B,H,W,dh); i_log,f_log: (B,H,W); carry: (C,n,m) with
    C: (B,H,dh,dh), n: (B,H,dh), m: (B,H). Exact (tested vs recurrent oracle).
    """
    C, n, m = carry
    logf_cum = jnp.cumsum(jax.nn.log_sigmoid(f_log), axis=-1)  # F_t
    # running max term: m_t = F_t + max(m_carry, cummax_j(i_j - F_j))
    s = i_log - logf_cum
    run = jnp.maximum(jax.lax.cummax(s, axis=s.ndim - 1), m[..., None])
    m_t = logf_cum + run
    # inter-chunk (carry) contribution, decayed by F_t
    w_carry = jnp.exp(m[..., None] + logf_cum - m_t)  # (B,H,W)
    num_inter = jnp.einsum("bhwk,bhkv->bhwv", q, C) * w_carry[..., None]
    den_inter = jnp.einsum("bhwk,bhk->bhw", q, n) * w_carry
    # intra-chunk quadratic term with decay matrix D
    # D[t,j] = exp(F_t - F_j + i_j - m_t), j <= t
    expo = logf_cum[..., :, None] - logf_cum[..., None, :] + i_log[..., None, :] - m_t[..., :, None]
    W = q.shape[-2]
    mask = jnp.tril(jnp.ones((W, W), bool))
    D = jnp.where(mask, jnp.exp(expo), 0.0)  # (B,H,W,W)
    scores = jnp.einsum("bhtk,bhjk->bhtj", q, k) * D
    num_intra = jnp.einsum("bhtj,bhjv->bhtv", scores, v)
    den_intra = jnp.sum(scores, axis=-1)
    num = num_inter + num_intra
    den = den_inter + den_intra
    h = num / jnp.maximum(jnp.abs(den), jnp.exp(-m_t))[..., None]
    # carry update to end of chunk
    F_W = logf_cum[..., -1]
    m_new = F_W + run[..., -1]
    decay_old = jnp.exp(m + F_W - m_new)
    w_new = jnp.exp(F_W[..., None] - logf_cum + i_log - m_new[..., None])  # (B,H,W)
    C_new = C * decay_old[..., None, None] + jnp.einsum("bhwk,bhwv,bhw->bhkv", k, v, w_new)
    n_new = n * decay_old[..., None] + jnp.einsum("bhwk,bhw->bhk", k, w_new)
    return h, (C_new, n_new, m_new)


def mlstm_apply(params, x: jnp.ndarray, cfg: ArchConfig) -> jnp.ndarray:
    """Full-sequence mLSTM block, chunk-scanned. x: (B,S,d)."""
    H = max(cfg.num_heads, 1)
    B, S0, d = x.shape
    q, k, v, i_log, f_log, gate = _mlstm_qkv_gates(params, x, cfg)
    dh = q.shape[-1]
    W = min(cfg.mlstm_chunk, S0)
    pad = (-S0) % W
    if pad:  # causal: end-padding never influences real positions
        pad4 = ((0, 0), (0, pad), (0, 0), (0, 0))
        q, k, v = (jnp.pad(t, pad4) for t in (q, k, v))
        i_log = jnp.pad(i_log, ((0, 0), (0, pad), (0, 0)), constant_values=-1e30)
        f_log = jnp.pad(f_log, ((0, 0), (0, pad), (0, 0)))
    S = S0 + pad
    nchunks = S // W

    def to_chunks(t, has_dh):
        # (B,S,H,*) -> (nchunks, B, H, W, *)
        t = t.reshape(B, nchunks, W, H, -1) if has_dh else t.reshape(B, nchunks, W, H)
        order = (1, 0, 3, 2, 4) if has_dh else (1, 0, 3, 2)
        return jnp.transpose(t, order)

    qc, kc, vc = (to_chunks(t.astype(jnp.float32), True) for t in (q, k, v))
    ic, fc = to_chunks(i_log, False), to_chunks(f_log, False)

    C0 = jnp.zeros((B, H, dh, dh), jnp.float32)
    n0 = jnp.zeros((B, H, dh), jnp.float32)
    m0 = jnp.full((B, H), -1e30, jnp.float32)

    def body(carry, chunk):
        qq, kk, vv, ii, ff = chunk
        h, carry = _mlstm_chunk(qq, kk, vv, ii, ff, carry)
        return carry, h

    _, hs = jax.lax.scan(body, (C0, n0, m0), (qc, kc, vc, ic, fc))
    # (nchunks, B, H, W, dh) -> (B, S, up)
    h = jnp.transpose(hs, (1, 0, 3, 2, 4)).reshape(B, S, H * dh)[:, :S0]
    y = (h * gate).astype(x.dtype) @ params["w_down"]
    return y


def mlstm_init_state(cfg: ArchConfig, batch: int, dtype) -> Dict[str, jnp.ndarray]:
    H = max(cfg.num_heads, 1)
    dh = 2 * cfg.d_model // H
    return {
        "C": jnp.zeros((batch, H, dh, dh), jnp.float32),
        "n": jnp.zeros((batch, H, dh), jnp.float32),
        "m": jnp.full((batch, H), -1e30, jnp.float32),
    }


def mlstm_step(params, x_t: jnp.ndarray, state, cfg: ArchConfig):
    """Recurrent single-token step. x_t: (B,1,d)."""
    q, k, v, i_log, f_log, gate = _mlstm_qkv_gates(params, x_t, cfg)
    q, k, v = (t[:, 0].astype(jnp.float32) for t in (q, k, v))  # (B,H,dh)
    i_log, f_log, gate = i_log[:, 0], f_log[:, 0], gate[:, 0]
    logf = jax.nn.log_sigmoid(f_log)
    m_new = jnp.maximum(logf + state["m"], i_log)
    f_p = jnp.exp(logf + state["m"] - m_new)
    i_p = jnp.exp(i_log - m_new)
    C = state["C"] * f_p[..., None, None] + i_p[..., None, None] * k[..., :, None] * v[..., None, :]
    n = state["n"] * f_p[..., None] + i_p[..., None] * k
    num = jnp.einsum("bhk,bhkv->bhv", q, C)
    den = jnp.einsum("bhk,bhk->bh", q, n)
    h = num / jnp.maximum(jnp.abs(den), jnp.exp(-m_new))[..., None]
    B = x_t.shape[0]
    y = ((h.reshape(B, -1) * gate).astype(x_t.dtype) @ params["w_down"])[:, None]
    return y, {"C": C, "n": n, "m": m_new}


# ---------------------------------------------------------------------------
# sLSTM (xLSTM scalar-memory cell with hidden-dependent gates)
# ---------------------------------------------------------------------------

def init_slstm(rng, cfg: ArchConfig) -> Dict[str, jnp.ndarray]:
    d = cfg.d_model
    H = max(cfg.num_heads, 1)
    dh = d // H
    dt = cfg.dtype()
    k = jax.random.split(rng, 10)
    return {
        "w_i": _init_dense(k[0], (d, d), dt),
        "w_f": _init_dense(k[1], (d, d), dt),
        "w_z": _init_dense(k[2], (d, d), dt),
        "w_o": _init_dense(k[3], (d, d), dt),
        "r_i": _init_dense(k[4], (H, dh, dh), jnp.float32),
        "r_f": _init_dense(k[5], (H, dh, dh), jnp.float32),
        "r_z": _init_dense(k[6], (H, dh, dh), jnp.float32),
        "r_o": _init_dense(k[7], (H, dh, dh), jnp.float32),
        "b": jnp.concatenate(
            [jnp.zeros((d,)), jnp.ones((d,)), jnp.zeros((2 * d,))]
        ).astype(jnp.float32),  # i, f(+1), z, o biases
        "w_out": _init_dense(k[8], (d, d), dt),
    }


def slstm_init_state(cfg: ArchConfig, batch: int, dtype) -> Dict[str, jnp.ndarray]:
    d = cfg.d_model
    H = max(cfg.num_heads, 1)
    dh = d // H
    z = lambda: jnp.zeros((batch, H, dh), jnp.float32)
    return {"h": z(), "c": z(), "n": z() + 1e-6, "m": z()}


def _slstm_cell(params, pre, state, H, dh):
    """pre: (B, 4d) input projections [i,f,z,o]; state: dict of (B,H,dh)."""
    B = pre.shape[0]
    h_prev = state["h"]
    rec = lambda r: jnp.einsum("bhk,hkj->bhj", h_prev, r)
    pre = pre.reshape(B, 4, H, dh)
    i_t = pre[:, 0] + rec(params["r_i"])
    f_t = pre[:, 1] + rec(params["r_f"])
    z_t = jnp.tanh(pre[:, 2] + rec(params["r_z"]))
    o_t = jax.nn.sigmoid(pre[:, 3] + rec(params["r_o"]))
    m_new = jnp.maximum(f_t + state["m"], i_t)
    i_p = jnp.exp(i_t - m_new)
    f_p = jnp.exp(f_t + state["m"] - m_new)
    c = f_p * state["c"] + i_p * z_t
    n = f_p * state["n"] + i_p
    h = o_t * c / jnp.maximum(n, 1e-6)
    return h, {"h": h, "c": c, "n": n, "m": m_new}


def slstm_apply(params, x: jnp.ndarray, cfg: ArchConfig) -> jnp.ndarray:
    """Sequential scan over time (no parallel form exists — gates depend on h)."""
    B, S, d = x.shape
    H = max(cfg.num_heads, 1)
    dh = d // H
    w = jnp.concatenate([params["w_i"], params["w_f"], params["w_z"], params["w_o"]], axis=1)
    pre_all = (x @ w).astype(jnp.float32) + params["b"]  # (B,S,4d)
    state = slstm_init_state(cfg, B, x.dtype)

    def body(st, pre_t):
        h, st = _slstm_cell(params, pre_t, st, H, dh)
        return st, h

    _, hs = jax.lax.scan(body, state, jnp.swapaxes(pre_all, 0, 1))
    h = jnp.swapaxes(hs, 0, 1).reshape(B, S, d)
    return h.astype(x.dtype) @ params["w_out"]


def slstm_step(params, x_t: jnp.ndarray, state, cfg: ArchConfig):
    B, _, d = x_t.shape
    H = max(cfg.num_heads, 1)
    dh = d // H
    w = jnp.concatenate([params["w_i"], params["w_f"], params["w_z"], params["w_o"]], axis=1)
    pre = (x_t[:, 0] @ w).astype(jnp.float32) + params["b"]
    h, state = _slstm_cell(params, pre, state, H, dh)
    y = (h.reshape(B, d).astype(x_t.dtype) @ params["w_out"])[:, None]
    return y, state
