from repro.models import cnn, layers, recurrent, transformer

__all__ = ["cnn", "layers", "recurrent", "transformer"]
