"""Decoder stack: pattern-cycled blocks, scanned superblocks, remat,
full train/prefill/decode paths, and the LM loss.

The stack is organized as ``num_superblocks`` repetitions of
``cfg.block_pattern`` (plus an unscanned tail for remainders, e.g.
recurrentgemma's 38 = 12x(rec,rec,attn) + 2). Superblock parameters are
stacked on a leading axis and the stack runs under ``jax.lax.scan`` —
compile-time and HLO size stay flat in depth, which matters when lowering
61-layer models for 512 devices. ``cfg.remat`` wraps the superblock in
``jax.checkpoint`` for activation recomputation.

Cross-entropy is computed as logsumexp - target_logit on sharded logits
(vocab sharded over the `model` axis), never materializing a one-hot.
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models import layers, recurrent

PyTree = Any

ATTN_KINDS = ("attn", "local_attn")


# ---------------------------------------------------------------------------
# Single block
# ---------------------------------------------------------------------------

def init_block(rng, cfg: ArchConfig, kind: str) -> Dict[str, PyTree]:
    d, dt = cfg.d_model, cfg.dtype()
    k = jax.random.split(rng, 4)
    p: Dict[str, PyTree] = {"norm1": layers.init_rmsnorm(d, dt)}
    if kind in ATTN_KINDS:
        p["attn"] = layers.init_mla(k[0], cfg) if cfg.mla else layers.init_attention(k[0], cfg)
        if cfg.moe is not None:
            p["norm2"] = layers.init_rmsnorm(d, dt)
            p["moe"] = layers.init_moe(k[1], cfg)
        elif cfg.d_ff > 0:
            p["norm2"] = layers.init_rmsnorm(d, dt)
            p["mlp"] = layers.init_mlp(k[1], d, cfg.d_ff, dt)
    elif kind == "rglru":
        p["rnn"] = recurrent.init_rglru(k[0], cfg)
        if cfg.d_ff > 0:
            p["norm2"] = layers.init_rmsnorm(d, dt)
            p["mlp"] = layers.init_mlp(k[1], d, cfg.d_ff, dt)
    elif kind == "mlstm":
        p["cell"] = recurrent.init_mlstm(k[0], cfg)
    elif kind == "slstm":
        p["cell"] = recurrent.init_slstm(k[0], cfg)
    else:
        raise ValueError(kind)
    return p


def block_apply(params, x, cfg: ArchConfig, kind: str, positions) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Full-sequence block. Returns (x, moe_aux_loss)."""
    aux = jnp.zeros((), jnp.float32)
    h = layers.rmsnorm(x, params["norm1"], cfg.norm_eps)
    if kind in ATTN_KINDS:
        window = cfg.window if kind == "local_attn" else 0
        if cfg.mla:
            y = layers.mla_apply(params["attn"], h, cfg, positions)
        else:
            y = layers.attention_apply(params["attn"], h, cfg, positions, window=window)
        x = x + y
        if "moe" in params:
            h2 = layers.rmsnorm(x, params["norm2"], cfg.norm_eps)
            B, S, d = h2.shape
            y2, aux = layers.moe_apply(params["moe"], h2.reshape(B * S, d), cfg)
            x = x + y2.reshape(B, S, d)
        elif "mlp" in params:
            x = x + layers.mlp_apply(params["mlp"], layers.rmsnorm(x, params["norm2"], cfg.norm_eps))
    elif kind == "rglru":
        x = x + recurrent.rglru_apply(params["rnn"], h, cfg)
        if "mlp" in params:
            x = x + layers.mlp_apply(params["mlp"], layers.rmsnorm(x, params["norm2"], cfg.norm_eps))
    elif kind == "mlstm":
        x = x + recurrent.mlstm_apply(params["cell"], h, cfg)
    elif kind == "slstm":
        x = x + recurrent.slstm_apply(params["cell"], h, cfg)
    return x, aux


def block_init_cache(cfg: ArchConfig, kind: str, batch: int, max_len: int, dtype) -> PyTree:
    if kind in ATTN_KINDS:
        if cfg.mla:
            return layers.init_mla_cache(cfg, batch, max_len, dtype)
        window = cfg.window if kind == "local_attn" else 0
        eff = min(max_len, window) if window else max_len
        return layers.init_kv_cache(cfg, batch, eff if window else max_len, dtype)
    if kind == "rglru":
        return recurrent.rglru_init_state(cfg, batch, dtype)
    if kind == "mlstm":
        return recurrent.mlstm_init_state(cfg, batch, dtype)
    if kind == "slstm":
        return recurrent.slstm_init_state(cfg, batch, dtype)
    raise ValueError(kind)


def block_decode(params, x, cache, cfg: ArchConfig, kind: str, position) -> Tuple[jnp.ndarray, PyTree]:
    h = layers.rmsnorm(x, params["norm1"], cfg.norm_eps)
    if kind in ATTN_KINDS:
        window = cfg.window if kind == "local_attn" else 0
        if cfg.mla:
            y, cache = layers.mla_decode(params["attn"], h, cache, cfg, position)
        else:
            y, cache = layers.attention_decode(params["attn"], h, cache, cfg, position, window=window)
        x = x + y
        if "moe" in params:
            h2 = layers.rmsnorm(x, params["norm2"], cfg.norm_eps)
            B, S, d = h2.shape
            y2, _ = layers.moe_apply(params["moe"], h2.reshape(B * S, d), cfg)
            x = x + y2.reshape(B, S, d)
        elif "mlp" in params:
            x = x + layers.mlp_apply(params["mlp"], layers.rmsnorm(x, params["norm2"], cfg.norm_eps))
    elif kind == "rglru":
        y, cache = recurrent.rglru_step(params["rnn"], h, cache, cfg)
        x = x + y
        if "mlp" in params:
            x = x + layers.mlp_apply(params["mlp"], layers.rmsnorm(x, params["norm2"], cfg.norm_eps))
    elif kind == "mlstm":
        y, cache = recurrent.mlstm_step(params["cell"], h, cache, cfg)
        x = x + y
    elif kind == "slstm":
        y, cache = recurrent.slstm_step(params["cell"], h, cache, cfg)
        x = x + y
    return x, cache


# ---------------------------------------------------------------------------
# Full model
# ---------------------------------------------------------------------------

def init_superblock(rng, cfg: ArchConfig) -> Dict[str, PyTree]:
    ks = jax.random.split(rng, cfg.pattern_period)
    return {f"b{i}": init_block(ks[i], cfg, kind) for i, kind in enumerate(cfg.block_pattern)}


def init_params(rng, cfg: ArchConfig) -> Dict[str, PyTree]:
    dt = cfg.dtype()
    k = jax.random.split(rng, 4 + cfg.tail_layers)
    params: Dict[str, PyTree] = {}
    if cfg.embed_inputs:
        params["embed"] = layers._init_dense(k[0], (cfg.vocab_size, cfg.d_model), dt, scale=1.0)
    if cfg.scan_layers and cfg.num_superblocks > 0:
        sb_keys = jax.random.split(k[1], cfg.num_superblocks)
        params["blocks"] = jax.vmap(lambda kk: init_superblock(kk, cfg))(sb_keys)
    else:
        sb_keys = jax.random.split(k[1], cfg.num_layers)
        params["blocks_unrolled"] = [
            init_block(sb_keys[i], cfg, cfg.block_pattern[i % cfg.pattern_period])
            for i in range(cfg.num_layers - cfg.tail_layers)
        ]
    for t in range(cfg.tail_layers):
        params[f"tail{t}"] = init_block(k[3 + t], cfg, cfg.block_pattern[t])
    params["final_norm"] = layers.init_rmsnorm(cfg.d_model, dt)
    params["lm_head"] = layers._init_dense(k[2], (cfg.d_model, cfg.vocab_size), dt)
    return params


def _superblock_apply(sb_params, x, cfg, positions):
    aux = jnp.zeros((), jnp.float32)
    for i, kind in enumerate(cfg.block_pattern):
        x, a = block_apply(sb_params[f"b{i}"], x, cfg, kind, positions)
        aux = aux + a
    return x, aux


def _remat(fn, cfg: ArchConfig):
    if cfg.remat == "none":
        return fn
    policy = None
    if cfg.remat == "dots":
        policy = jax.checkpoint_policies.checkpoint_dots
    return jax.checkpoint(fn, policy=policy)


def backbone(params, cfg: ArchConfig, x: jnp.ndarray, positions: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """(B,S,d) -> (B,S,d) hidden states + accumulated moe aux loss."""
    total_aux = jnp.zeros((), jnp.float32)
    if cfg.scan_layers and cfg.num_superblocks > 0 and "blocks" in params:
        sb_fn = _remat(lambda p, h: _superblock_apply(p, h, cfg, positions), cfg)

        def body(carry, sb_params):
            h, aux = carry
            h, a = sb_fn(sb_params, h)
            return (h, aux + a), ()

        (x, total_aux), _ = jax.lax.scan(body, (x, total_aux), params["blocks"])
    elif "blocks_unrolled" in params:
        for i, bp in enumerate(params["blocks_unrolled"]):
            kind = cfg.block_pattern[i % cfg.pattern_period]
            x, a = block_apply(bp, x, cfg, kind, positions)
            total_aux = total_aux + a
    for t in range(cfg.tail_layers):
        x, a = block_apply(params[f"tail{t}"], x, cfg, cfg.block_pattern[t], positions)
        total_aux = total_aux + a
    return x, total_aux


def forward(params, cfg: ArchConfig, inputs: jnp.ndarray, positions: Optional[jnp.ndarray] = None):
    """inputs: int tokens (B,S) if cfg.embed_inputs else embeddings (B,S,d).

    Returns (logits (B,S,V), aux_loss).
    """
    if cfg.embed_inputs:
        x = params["embed"][inputs]
    else:
        x = inputs.astype(cfg.dtype())
    B, S = x.shape[0], x.shape[1]
    if positions is None:
        positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))
    x, aux = backbone(params, cfg, x, positions)
    x = layers.rmsnorm(x, params["final_norm"], cfg.norm_eps)
    logits = x @ params["lm_head"]
    return logits, aux


def cross_entropy(logits: jnp.ndarray, targets: jnp.ndarray, mask: Optional[jnp.ndarray] = None):
    """Sharded-vocab-safe CE: logsumexp - target logit. targets: (B,S) int."""
    lf = logits.astype(jnp.float32)
    lse = jax.nn.logsumexp(lf, axis=-1)
    tgt = jnp.take_along_axis(lf, targets[..., None], axis=-1)[..., 0]
    nll = lse - tgt
    if mask is not None:
        return jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1.0)
    return jnp.mean(nll)


def make_loss_fn(cfg: ArchConfig):
    """Per-client loss for HierFAVG: loss_fn(params, batch, rng) -> scalar.

    batch: {"inputs": tokens (b,S) or embeds (b,S,d), "targets": (b,S) int32}.
    """

    def loss_fn(params, batch, rng):
        logits, aux = forward(params, cfg, batch["inputs"])
        return cross_entropy(logits, batch["targets"], batch.get("mask")) + aux

    return loss_fn


# ---------------------------------------------------------------------------
# Serving: prefill + single-token decode with stacked caches
# ---------------------------------------------------------------------------

def _block_prefill(params, x, cfg, kind, positions, max_len):
    """Run the block over the prompt AND build its decode cache."""
    y, _ = block_apply(params, x, cfg, kind, positions)
    B, S, _ = x.shape
    dtype = cfg.dtype()
    cache = block_init_cache(cfg, kind, B, max_len, dtype)
    if kind in ATTN_KINDS and not cfg.mla:
        h = layers.rmsnorm(x, params["norm1"], cfg.norm_eps)
        hd = cfg.resolved_head_dim
        k = layers._split_heads(h @ params["attn"]["wk"], cfg.num_kv_heads, hd)
        k = layers.apply_rope(k, positions, cfg.rope_theta)
        v = layers._split_heads(h @ params["attn"]["wv"], cfg.num_kv_heads, hd)
        L = cache["k"].shape[1]
        take = min(S, L)
        slots = jnp.mod(positions[:, -take:], L)
        bidx = jnp.arange(B)[:, None]
        cache["k"] = cache["k"].at[bidx, slots].set(k[:, -take:].astype(dtype))
        cache["v"] = cache["v"].at[bidx, slots].set(v[:, -take:].astype(dtype))
        cache["pos"] = cache["pos"].at[bidx, slots].set(positions[:, -take:])
    elif kind in ATTN_KINDS and cfg.mla:
        h = layers.rmsnorm(x, params["norm1"], cfg.norm_eps)
        m = cfg.mla
        kv = h @ params["attn"]["wkv_a"]
        c_kv = layers.rmsnorm(kv[..., : m.kv_lora_rank], params["attn"]["kv_norm"], cfg.norm_eps)
        k_rope = layers.apply_rope(kv[..., None, m.kv_lora_rank :], positions, cfg.rope_theta)[:, :, 0]
        cache["c_kv"] = cache["c_kv"].at[:, :S].set(c_kv.astype(dtype))
        cache["k_rope"] = cache["k_rope"].at[:, :S].set(k_rope.astype(dtype))
        cache["pos"] = cache["pos"].at[:, :S].set(positions)
    elif kind == "rglru":
        h = layers.rmsnorm(x, params["norm1"], cfg.norm_eps)
        u = h @ params["rnn"]["w_x"]
        v = recurrent._conv1d_causal(u, params["rnn"]["conv"])
        a, b = recurrent._rglru_coeffs(params["rnn"], v)

        def combine(l, r):
            return l[0] * r[0], r[0] * l[1] + r[1]

        af, bf = jax.lax.associative_scan(combine, (a, b), axis=1)
        cache["h"] = bf[:, -1]  # h_S with h_0 = 0
        cache["conv"] = u[:, -3:].astype(dtype)
    elif kind in ("mlstm", "slstm"):
        # replay the sequence through the recurrent cell to get the state
        cell = params["cell"]
        if kind == "mlstm":
            q, k, v, i_log, f_log, _ = recurrent._mlstm_qkv_gates(cell, x_normed_in(params, x, cfg), cfg)
            B_, S_, H, dh = q.shape
            carry = (
                jnp.zeros((B_, H, dh, dh), jnp.float32),
                jnp.zeros((B_, H, dh), jnp.float32),
                jnp.full((B_, H), -1e30, jnp.float32),
            )
            W = min(cfg.mlstm_chunk, S_)
            n_chunks = S_ // W

            def to_chunks(t, has_dh=True):
                tt = t.reshape(B_, n_chunks, W, H, -1) if has_dh else t.reshape(B_, n_chunks, W, H)
                return jnp.transpose(tt, (1, 0, 3, 2, 4) if has_dh else (1, 0, 3, 2))

            def body(c, ch):
                _, c2 = recurrent._mlstm_chunk(*ch, c)
                return c2, ()

            carry, _ = jax.lax.scan(
                body,
                carry,
                (
                    to_chunks(q.astype(jnp.float32)),
                    to_chunks(k.astype(jnp.float32)),
                    to_chunks(v.astype(jnp.float32)),
                    to_chunks(i_log, False),
                    to_chunks(f_log, False),
                ),
            )
            cache = {"C": carry[0], "n": carry[1], "m": carry[2]}
        else:
            h = x_normed_in(params, x, cfg)
            w = jnp.concatenate([cell["w_i"], cell["w_f"], cell["w_z"], cell["w_o"]], axis=1)
            pre_all = (h @ w).astype(jnp.float32) + cell["b"]
            H = max(cfg.num_heads, 1)
            dh = cfg.d_model // H
            st = recurrent.slstm_init_state(cfg, x.shape[0], cfg.dtype())

            def body(s, p):
                _, s2 = recurrent._slstm_cell(cell, p, s, H, dh)
                return s2, ()

            cache, _ = jax.lax.scan(body, st, jnp.swapaxes(pre_all, 0, 1))
    return y, cache


def x_normed_in(params, x, cfg):
    return layers.rmsnorm(x, params["norm1"], cfg.norm_eps)


def prefill(params, cfg: ArchConfig, inputs: jnp.ndarray, max_len: int):
    """Full-prompt forward building every layer's decode cache.

    Returns (last-position logits (B,V), caches pytree).
    """
    if cfg.embed_inputs:
        x = params["embed"][inputs]
    else:
        x = inputs.astype(cfg.dtype())
    B, S = x.shape[0], x.shape[1]
    positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))
    caches = {}
    if cfg.scan_layers and cfg.num_superblocks > 0 and "blocks" in params:
        def body(h, sb_params):
            cs = {}
            for i, kind in enumerate(cfg.block_pattern):
                h, c = _block_prefill(sb_params[f"b{i}"], h, cfg, kind, positions, max_len)
                cs[f"b{i}"] = c
            return h, cs

        x, caches["blocks"] = jax.lax.scan(body, x, params["blocks"])
    for t in range(cfg.tail_layers):
        x, c = _block_prefill(params[f"tail{t}"], x, cfg, cfg.block_pattern[t], positions, max_len)
        caches[f"tail{t}"] = c
    x = layers.rmsnorm(x, params["final_norm"], cfg.norm_eps)
    logits = x[:, -1] @ params["lm_head"]
    return logits, caches


def init_decode_caches(params, cfg: ArchConfig, batch: int, max_len: int):
    """Fresh (empty) caches matching the model structure."""
    dtype = cfg.dtype()
    caches = {}
    if cfg.scan_layers and cfg.num_superblocks > 0 and "blocks" in params:
        def one(_):
            return {
                f"b{i}": block_init_cache(cfg, kind, batch, max_len, dtype)
                for i, kind in enumerate(cfg.block_pattern)
            }

        caches["blocks"] = jax.vmap(one)(jnp.arange(cfg.num_superblocks))
    for t in range(cfg.tail_layers):
        caches[f"tail{t}"] = block_init_cache(cfg, cfg.block_pattern[t], batch, max_len, dtype)
    return caches


def decode_step(params, cfg: ArchConfig, caches, tokens: jnp.ndarray, position: jnp.ndarray):
    """One decode step for all requests.

    tokens: (B,) int32 (or (B,d) embeddings for stub-frontend archs);
    position: (B,) absolute positions. Returns (logits (B,V), caches).
    """
    if cfg.embed_inputs:
        x = params["embed"][tokens][:, None]  # (B,1,d)
    else:
        x = tokens.astype(cfg.dtype())[:, None]
    new_caches = {}
    if cfg.scan_layers and cfg.num_superblocks > 0 and "blocks" in params:
        def body(h, xs):
            sb_params, sb_cache = xs
            cs = {}
            for i, kind in enumerate(cfg.block_pattern):
                h, c = block_decode(sb_params[f"b{i}"], h, sb_cache[f"b{i}"], cfg, kind, position)
                cs[f"b{i}"] = c
            return h, cs

        x, new_caches["blocks"] = jax.lax.scan(body, x, (params["blocks"], caches["blocks"]))
    for t in range(cfg.tail_layers):
        x, c = block_decode(
            params[f"tail{t}"], x, caches[f"tail{t}"], cfg, cfg.block_pattern[t], position
        )
        new_caches[f"tail{t}"] = c
    x = layers.rmsnorm(x, params["final_norm"], cfg.norm_eps)
    logits = x[:, 0] @ params["lm_head"]
    return logits, new_caches
