"""The paper's experiment models (Section IV-A).

MNIST CNN: "the CNN with 21,840 trainable parameters as in [2]" — the
classic conv(1->10,5x5) -> pool -> conv(10->20,5x5) -> pool -> fc(320->50)
-> fc(50->10) network: 260 + 5,020 + 16,050 + 510 = 21,840. Exact.

CIFAR CNN: "a CNN with 3 convolutional blocks, 5,852,170 parameters". The
paper doesn't print the layer list; we use a standard 3-block VGG-style net
(32,32 / 64,64 / 128,128 + 2 FC) and document the parameter count — the cost
model uses the paper's 5,852,170 constant independently (cost_model.py), so
Table I/II reproduction does not depend on matching the count exactly.
"""
from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

PyTree = Any


def _conv_init(rng, shape, dtype=jnp.float32):
    fan_in = shape[0] * shape[1] * shape[2]
    return jax.random.normal(rng, shape, dtype) * (2.0 / fan_in) ** 0.5


def _fc_init(rng, shape, dtype=jnp.float32):
    return jax.random.normal(rng, shape, dtype) * (2.0 / shape[0]) ** 0.5


def conv2d(x, w, b):
    """x: (B,H,W,C), w: (kh,kw,Cin,Cout). SAME-valid per layer spec below."""
    y = jax.lax.conv_general_dilated(
        x, w, window_strides=(1, 1), padding="VALID", dimension_numbers=("NHWC", "HWIO", "NHWC")
    )
    return y + b


def conv2d_same(x, w, b):
    y = jax.lax.conv_general_dilated(
        x, w, window_strides=(1, 1), padding="SAME", dimension_numbers=("NHWC", "HWIO", "NHWC")
    )
    return y + b


def maxpool2(x):
    return jax.lax.reduce_window(x, -jnp.inf, jax.lax.max, (1, 2, 2, 1), (1, 2, 2, 1), "VALID")


# ---------------------------------------------------------------------------
# MNIST CNN — exactly 21,840 params
# ---------------------------------------------------------------------------

def init_mnist_cnn(rng) -> PyTree:
    k = jax.random.split(rng, 4)
    return {
        "c1w": _conv_init(k[0], (5, 5, 1, 10)),
        "c1b": jnp.zeros((10,)),
        "c2w": _conv_init(k[1], (5, 5, 10, 20)),
        "c2b": jnp.zeros((20,)),
        "f1w": _fc_init(k[2], (320, 50)),
        "f1b": jnp.zeros((50,)),
        "f2w": _fc_init(k[3], (50, 10)),
        "f2b": jnp.zeros((10,)),
    }


def mnist_cnn_apply(params: PyTree, x: jnp.ndarray) -> jnp.ndarray:
    """x: (B, 28, 28, 1) -> logits (B, 10)."""
    x = jax.nn.relu(maxpool2(conv2d(x, params["c1w"], params["c1b"])))  # 24->12
    x = jax.nn.relu(maxpool2(conv2d(x, params["c2w"], params["c2b"])))  # 8->4
    x = x.reshape(x.shape[0], -1)  # 4*4*20 = 320
    x = jax.nn.relu(x @ params["f1w"] + params["f1b"])
    return x @ params["f2w"] + params["f2b"]


# ---------------------------------------------------------------------------
# CIFAR CNN — 3 conv blocks, ~5.85M params
# ---------------------------------------------------------------------------

def init_cifar_cnn(rng) -> PyTree:
    k = jax.random.split(rng, 9)
    return {
        "c1aw": _conv_init(k[0], (3, 3, 3, 32)), "c1ab": jnp.zeros((32,)),
        "c1bw": _conv_init(k[1], (3, 3, 32, 32)), "c1bb": jnp.zeros((32,)),
        "c2aw": _conv_init(k[2], (3, 3, 32, 64)), "c2ab": jnp.zeros((64,)),
        "c2bw": _conv_init(k[3], (3, 3, 64, 64)), "c2bb": jnp.zeros((64,)),
        "c3aw": _conv_init(k[4], (3, 3, 64, 128)), "c3ab": jnp.zeros((128,)),
        "c3bw": _conv_init(k[5], (3, 3, 128, 128)), "c3bb": jnp.zeros((128,)),
        "f1w": _fc_init(k[6], (2048, 2048)), "f1b": jnp.zeros((2048,)),
        "f2w": _fc_init(k[7], (2048, 512)), "f2b": jnp.zeros((512,)),
        "f3w": _fc_init(k[8], (512, 10)), "f3b": jnp.zeros((10,)),
    }


def cifar_cnn_apply(params: PyTree, x: jnp.ndarray) -> jnp.ndarray:
    """x: (B, 32, 32, 3) -> logits (B, 10)."""
    x = jax.nn.relu(conv2d_same(x, params["c1aw"], params["c1ab"]))
    x = maxpool2(jax.nn.relu(conv2d_same(x, params["c1bw"], params["c1bb"])))  # 16
    x = jax.nn.relu(conv2d_same(x, params["c2aw"], params["c2ab"]))
    x = maxpool2(jax.nn.relu(conv2d_same(x, params["c2bw"], params["c2bb"])))  # 8
    x = jax.nn.relu(conv2d_same(x, params["c3aw"], params["c3ab"]))
    x = maxpool2(jax.nn.relu(conv2d_same(x, params["c3bw"], params["c3bb"])))  # 4
    x = x.reshape(x.shape[0], -1)  # 128*4*4 = 2048
    x = jax.nn.relu(x @ params["f1w"] + params["f1b"])
    x = jax.nn.relu(x @ params["f2w"] + params["f2b"])
    return x @ params["f3w"] + params["f3b"]


def classification_loss(logits: jnp.ndarray, labels: jnp.ndarray) -> jnp.ndarray:
    lse = jax.nn.logsumexp(logits.astype(jnp.float32), axis=-1)
    tgt = jnp.take_along_axis(logits.astype(jnp.float32), labels[:, None], axis=-1)[:, 0]
    return jnp.mean(lse - tgt)


def accuracy(logits: jnp.ndarray, labels: jnp.ndarray) -> jnp.ndarray:
    return jnp.mean((jnp.argmax(logits, axis=-1) == labels).astype(jnp.float32))


def make_cnn_loss_fn(apply_fn):
    """HierFAVG-compatible loss: batch = {"inputs": images, "targets": labels}."""

    def loss_fn(params, batch, rng):
        return classification_loss(apply_fn(params, batch["inputs"]), batch["targets"])

    return loss_fn


def count_params(tree: PyTree) -> int:
    return sum(x.size for x in jax.tree_util.tree_leaves(tree))
