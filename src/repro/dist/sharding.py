"""Mesh sharding rules + in-model sharding hints.

Two jobs:

* **Hints** (``hint`` / ``set_hint_mesh``): models annotate activations with
  the mesh axes they should be partitioned over. Off-mesh (CPU tests, no
  hint mesh installed) every hint is the identity, so model code never
  branches on the execution environment. The dry-run installs its
  placeholder mesh around tracing.

* **Rules** (``ShardingRules`` via ``fed_rules`` / ``serve_rules``): map
  parameter / batch / cache pytrees to PartitionSpecs for the production
  meshes of ``launch.mesh``. Federated training shards the leading stacked
  client axis over the federated axes ("pod","data"); tensor-parallel
  shards the last dim of matrices over "model" where it divides. Serving
  drops the client axis and shards requests over "data".

Rules degrade to replication when an axis is absent or a non-client dim
does not divide — specs stay valid on any mesh, which is what lets one
codepath serve the single-pod, multi-pod, and interpret/CPU environments.
The one exception is the leading stacked *client* axis: a client count
that does not divide the mesh's client axes raises instead of silently
replicating N model copies onto every device.

Also home to the client-sharded superround placement helpers
(``client_mesh`` / ``fed_state_shardings`` / ``batch_block_sharding`` /
``mask_stack_sharding``) consumed by ``fed.engine``'s mesh execution path.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Tuple

import jax
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.base import ArchConfig

PyTree = Any

_HINT_MESH = None


def set_hint_mesh(mesh) -> None:
    """Install (or clear, with None) the mesh that ``hint`` constrains to."""
    global _HINT_MESH
    _HINT_MESH = mesh


def hint_mesh():
    return _HINT_MESH


def _valid_member(mesh, member, dim_size: int):
    if member is None:
        return None
    names = member if isinstance(member, tuple) else (member,)
    if any(n not in mesh.axis_names for n in names):
        return None
    total = 1
    for n in names:
        total *= mesh.shape[n]
    return member if dim_size % total == 0 else None


def hint(x, *members):
    """with_sharding_constraint(x, P(*members)) under the hint mesh; identity
    off-mesh. Axes that are absent or don't divide degrade to replication."""
    mesh = _HINT_MESH
    if mesh is None:
        return x
    spec = P(*(_valid_member(mesh, m, d) for m, d in zip(members, x.shape)))
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))


def hint_data_groups() -> int:
    """MoE token groups = data-axis size of the hint mesh (1 off-mesh)."""
    mesh = _HINT_MESH
    if mesh is None or "data" not in mesh.axis_names:
        return 1
    return int(mesh.shape["data"])


def moe_ep_mode(num_experts: int) -> str:
    """Expert-parallel exchange mode for the MoE block.

    "none" keeps the per-group dispatch local (GSPMD handles any resharding;
    correct everywhere, and the only mode off-mesh). The explicit shard_map
    all-to-all path activates only on a real multi-device mesh whose data
    axis divides the expert count.
    """
    mesh = _HINT_MESH
    if mesh is None or "data" not in mesh.axis_names:
        return "none"
    ndev = int(mesh.shape["data"])
    if ndev <= 1 or num_experts % ndev:
        return "none"
    return "ep_data"


def moe_dispatch_exchange(buf_g, mode: str):
    """(G, E, C, d) group-major dispatch buffers -> (E, G*C, d) expert-major.

    The explicit all-to-all over the data axis (avoids GSPMD replicating the
    full buffer). Only reachable with a hint mesh installed.
    """
    if mode != "ep_data":
        raise ValueError(f"unknown ep mode: {mode}")
    mesh = _HINT_MESH
    if mesh is None:
        raise RuntimeError("moe_dispatch_exchange needs a hint mesh")
    from jax.experimental.shard_map import shard_map

    g, e, c, d = buf_g.shape

    def body(buf):
        # buf: (G/P, E, C, d) per shard; exchange expert blocks across the
        # data axis: split E, concat G
        out = jax.lax.all_to_all(buf, "data", split_axis=1, concat_axis=0, tiled=True)
        ge, ee = out.shape[0], out.shape[1]
        return jax.numpy.moveaxis(out, 0, 1).reshape(ee, ge * c, d)

    return shard_map(
        body,
        mesh=mesh,
        in_specs=P("data", None, None, None),
        out_specs=P("data", None, None),
    )(buf_g)


def moe_combine_exchange(out_buf, flat_e_g, ranks_g, gates, mode: str, capacity: int):
    """Inverse of ``moe_dispatch_exchange`` + weighted combine."""
    if mode != "ep_data":
        raise ValueError(f"unknown ep mode: {mode}")
    mesh = _HINT_MESH
    if mesh is None:
        raise RuntimeError("moe_combine_exchange needs a hint mesh")
    import jax.numpy as jnp
    from jax.experimental.shard_map import shard_map

    e, gc, d = out_buf.shape
    g = gc // capacity

    def body(buf):
        ee = buf.shape[0]
        back = jnp.moveaxis(buf.reshape(ee, g, capacity, d), 1, 0)  # (G, E/P, C, d)
        return jax.lax.all_to_all(back, "data", split_axis=0, concat_axis=1, tiled=True)

    out_g = shard_map(
        body,
        mesh=mesh,
        in_specs=P("data", None, None),
        out_specs=P("data", None, None, None),
    )(out_buf)  # (G, E, C, d)

    tg, k = gates.shape[1], gates.shape[2]

    def combine_group(out_, flat_e_, ranks_, gates_):
        gathered = out_.at[flat_e_, ranks_].get(mode="fill", fill_value=0.0)
        return jnp.sum(
            gathered.reshape(tg, k, d).astype(jnp.float32) * gates_[..., None], axis=1
        )

    return jax.vmap(combine_group)(out_g, flat_e_g, ranks_g, gates)


# ---------------------------------------------------------------------------
# Pytree sharding rules
# ---------------------------------------------------------------------------

def _last_dim_member(mesh, shape, axis: str):
    if len(shape) < 2 or axis not in mesh.axis_names:
        return None
    return axis if shape[-1] % mesh.shape[axis] == 0 else None


@dataclasses.dataclass(frozen=True)
class ShardingRules:
    """PartitionSpec factory for one (config, mesh) pair.

    client_axes: mesh axes the leading stacked client dim is sharded over
    (empty for serving — params then carry no client axis).
    """

    mesh: Any
    client_axes: Tuple[str, ...] = ()
    model_axis: str = "model"
    data_axis: str = "data"

    def _client_member(self, dim_size: int):
        axes = tuple(a for a in self.client_axes if a in self.mesh.axis_names)
        if not axes:
            return None
        total = 1
        for a in axes:
            total *= self.mesh.shape[a]
        if dim_size % total:
            # a silent fall-back to replication here used to hide an N-fold
            # memory and compute blow-up behind an innocuous-looking config;
            # an indivisible client count is a topology mistake, not a hint
            raise ValueError(
                f"stacked client axis of size {dim_size} is not divisible by the "
                f"mesh's client axes {axes} ({total} ways); choose a client count "
                f"that divides the mesh (or drop the client axes from the "
                f"sharding rules) instead of silently replicating the state"
            )
        return axes if len(axes) > 1 else axes[0]

    def batch_spec(self, shape, *, has_accum: bool = False) -> P:
        """Training batch (accum?, N, micro/b, ...): client dim over the
        federated axes, everything else replicated."""
        members = [None] * len(shape)
        client_dim = 1 if has_accum else 0
        members[client_dim] = self._client_member(shape[client_dim])
        return P(*members)

    def request_spec(self, shape) -> P:
        """Serving request (B, ...): batch dim over "data" when it divides."""
        members = [None] * len(shape)
        if shape and self.data_axis in self.mesh.axis_names and shape[0] % self.mesh.shape[self.data_axis] == 0:
            members[0] = self.data_axis
        return P(*members)

    def _param_spec(self, shape) -> P:
        members = [None] * len(shape)
        if self.client_axes and shape:
            members[0] = self._client_member(shape[0])
        tp = _last_dim_member(self.mesh, shape, self.model_axis)
        if tp is not None and (not members or members[-1] is None) and len(shape) >= 2:
            members[-1] = tp
        return P(*members)

    def params_shardings(self, params: PyTree, *, scanned: bool = True) -> PyTree:
        del scanned  # specs are rank-generic; scan only adds a replicated dim
        return jax.tree_util.tree_map(
            lambda leaf: NamedSharding(self.mesh, self._param_spec(leaf.shape)), params
        )

    def caches_shardings(self, caches: PyTree, *, scanned: bool = True) -> PyTree:
        del scanned

        def spec(leaf):
            members = [None] * len(leaf.shape)
            if leaf.shape and self.data_axis in self.mesh.axis_names and leaf.shape[0] % self.mesh.shape[self.data_axis] == 0:
                members[0] = self.data_axis
            return NamedSharding(self.mesh, P(*members))

        return jax.tree_util.tree_map(spec, caches)


# ---------------------------------------------------------------------------
# Client-sharded superround placement (fed.engine's mesh execution path)
# ---------------------------------------------------------------------------


def client_axis_of(mesh) -> str:
    """The mesh axis the stacked client dim shards over: ``"clients"`` when
    present, else the mesh's first axis."""
    names = tuple(mesh.axis_names)
    return "clients" if "clients" in names else names[0]


def client_mesh(num_devices: int = 0, axis: str = "clients"):
    """A 1-D ``Mesh`` over the first ``num_devices`` local devices (0/None =
    all). The canonical mesh for the client-sharded superround engine."""
    import numpy as np
    from jax.sharding import Mesh

    devs = jax.devices()
    k = len(devs) if not num_devices else int(num_devices)
    if k < 1:
        raise ValueError(f"client mesh needs a positive device count, got {k}")
    if k > len(devs):
        raise ValueError(
            f"requested a {k}-device client mesh but only {len(devs)} device(s) "
            f"are visible; on CPU set XLA_FLAGS=--xla_force_host_platform_"
            f"device_count={k} before importing jax"
        )
    return Mesh(np.asarray(devs[:k]), (axis,))


def batch_block_sharding(mesh, axis: str) -> NamedSharding:
    """Superround batch blocks (κ₂, κ₁, N, b, ...): client dim over ``axis``."""
    return NamedSharding(mesh, P(None, None, axis))


def mask_stack_sharding(mesh, axis: str) -> NamedSharding:
    """Survival mask stacks (κ₂, N): client dim over ``axis``."""
    return NamedSharding(mesh, P(None, axis))


def fed_state_shardings(mesh, axis: str, state, stacked_dim: int):
    """NamedShardings for a placement-ordered stacked ``FedState``: leaves
    with the leading ``stacked_dim`` client axis shard over ``axis``, all
    else (step, rng, scalar opt leaves) replicates."""
    from repro.core.hierfavg import fed_state_partition_specs

    specs = fed_state_partition_specs(state, axis, stacked_dim)
    return jax.tree_util.tree_map(
        lambda spec: NamedSharding(mesh, spec), specs, is_leaf=lambda x: isinstance(x, P)
    )


def fed_rules(cfg: ArchConfig, mesh) -> ShardingRules:
    """Federated training: stacked client axis over ("pod","data")."""
    del cfg
    axes = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    return ShardingRules(mesh=mesh, client_axes=axes)


def serve_rules(cfg: ArchConfig, mesh) -> ShardingRules:
    """Serving: no client axis; TP over "model", requests over "data"."""
    del cfg
    return ShardingRules(mesh=mesh, client_axes=())


def topology_for(cfg: ArchConfig, mesh):
    """The federated tree this config trains on this mesh: the uniform
    two-level FedTopology, or the FedPlan's ragged HierarchySpec when set."""
    num_pods = mesh.shape["pod"] if "pod" in mesh.axis_names else 1
    if cfg.fed.fanouts is not None:
        return cfg.fed.hierarchy(num_pods)
    from repro.core.hierfavg import FedTopology

    return FedTopology(
        num_edges=num_pods * cfg.fed.edges_per_pod,
        clients_per_edge=cfg.fed.clients_per_edge,
    )
