"""Analytic per-link traffic of the HierFAVG collective schedule.

Ring model: an all-reduce of S bytes over n participants moves
2·S·(n−1)/n per participant. Edge aggregation is a grouped all-reduce over
each edge's clients every κ₁ steps (ICI); cloud aggregation is an
all-reduce over edges every κ₁κ₂ steps (DCN) — amortizing both by their
interval gives steady-state bytes *per local step*, the paper's
communication-frequency knob in bytes.

``hierarchy_traffic_per_step`` generalizes to any (possibly ragged)
``HierarchySpec``: level ℓ's hop is a grouped all-reduce over each tier-ℓ
node's children every prod(κ[:ℓ]) steps. Ragged fan-out uses each group's
own size; the returned per-level figure is the *maximum* over groups (the
bottleneck link that sets the wall-clock of the hop).
"""
from __future__ import annotations

from math import prod
from typing import List, Sequence, Tuple

import numpy as np


def ring_allreduce_bytes(payload_bytes: float, participants: int) -> float:
    """Per-participant wire bytes of a ring all-reduce."""
    n = max(int(participants), 1)
    return 2.0 * payload_bytes * (n - 1) / n


def hierfavg_traffic_per_step(
    per_dev_bytes: float,
    clients_per_edge: int,
    num_edges: int,
    kappa1: int,
    kappa2: int,
) -> Tuple[float, float]:
    """(edge_bytes_per_step, cloud_bytes_per_step) for the two-level tree."""
    edge = ring_allreduce_bytes(per_dev_bytes, clients_per_edge) / kappa1
    cloud = ring_allreduce_bytes(per_dev_bytes, num_edges) / (kappa1 * kappa2)
    return edge, cloud


def hierarchy_traffic_per_step(
    per_dev_bytes: float,
    spec,  # core.hierarchy.HierarchySpec
    kappas: Sequence[int],
) -> List[float]:
    """Per-level bottleneck bytes per local step, bottom-up (level 1 = edge
    hop ... level depth = cloud hop)."""
    kv = tuple(int(k) for k in kappas)
    if len(kv) != spec.depth:
        raise ValueError(f"kappas {kv} vs hierarchy depth {spec.depth}")
    out = []
    for level in range(1, spec.depth + 1):
        # participants of a tier-level node = its tier-(level-1) children
        parents = np.asarray(spec.parents[level - 1])
        sizes = np.bincount(parents, minlength=spec.num_nodes(level))
        interval = prod(kv[:level])
        out.append(ring_allreduce_bytes(per_dev_bytes, int(sizes.max())) / interval)
    return out
