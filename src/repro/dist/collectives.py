"""Analytic per-link traffic of the HierFAVG collective schedule.

Ring model: an all-reduce of S bytes over n participants moves
2·S·(n−1)/n per participant. Edge aggregation is a grouped all-reduce over
each edge's clients every κ₁ steps (ICI); cloud aggregation is an
all-reduce over edges every κ₁κ₂ steps (DCN) — amortizing both by their
interval gives steady-state bytes *per local step*, the paper's
communication-frequency knob in bytes.

``hierarchy_traffic_per_step`` generalizes to any (possibly ragged)
``HierarchySpec``: level ℓ's hop is a grouped all-reduce over each tier-ℓ
node's children every prod(κ[:ℓ]) steps. Ragged fan-out uses each group's
own size; the returned per-level figure is the *maximum* over groups (the
bottleneck link that sets the wall-clock of the hop).
"""
from __future__ import annotations

from math import prod
from typing import List, Optional, Sequence, Tuple

import numpy as np


def ring_allreduce_bytes(payload_bytes: float, participants: int) -> float:
    """Per-participant wire bytes of a ring all-reduce."""
    n = max(int(participants), 1)
    return 2.0 * payload_bytes * (n - 1) / n


def hierfavg_traffic_per_step(
    per_dev_bytes: float,
    clients_per_edge: int,
    num_edges: int,
    kappa1: int,
    kappa2: int,
    *,
    edge_bits_per_param: float = 32.0,
    cloud_bits_per_param: float = 32.0,
) -> Tuple[float, float]:
    """(edge_bytes_per_step, cloud_bytes_per_step) for the two-level tree.

    ``per_dev_bytes`` is the uncompressed fp32 payload; the per-hop
    bits-per-parameter (``fed.transport.TransportSpec.bits_per_param``)
    scale it to the compressed wire size.
    """
    edge_payload = per_dev_bytes * edge_bits_per_param / 32.0
    cloud_payload = per_dev_bytes * cloud_bits_per_param / 32.0
    edge = ring_allreduce_bytes(edge_payload, clients_per_edge) / kappa1
    cloud = ring_allreduce_bytes(cloud_payload, num_edges) / (kappa1 * kappa2)
    return edge, cloud


def hierarchy_traffic_per_step(
    per_dev_bytes: float,
    spec,  # core.hierarchy.HierarchySpec
    kappas: Sequence[int],
    *,
    bits_per_param: Optional[Sequence[float]] = None,
) -> List[float]:
    """Per-level bottleneck bytes per local step, bottom-up (level 1 = edge
    hop ... level depth = cloud hop).

    ``per_dev_bytes`` is the uncompressed fp32 payload. ``bits_per_param``
    (one entry per level, bottom-up — ``TransportSpec.bits_vector()``)
    rescales each hop to its codec's wire size; None means fp32 (32 bits)
    everywhere.
    """
    kv = tuple(int(k) for k in kappas)
    if len(kv) != spec.depth:
        raise ValueError(f"kappas {kv} vs hierarchy depth {spec.depth}")
    if bits_per_param is None:
        bits = (32.0,) * spec.depth
    else:
        bits = tuple(float(b) for b in bits_per_param)
        if len(bits) != spec.depth:
            raise ValueError(f"bits_per_param {bits} vs hierarchy depth {spec.depth}")
        if any(b <= 0 for b in bits):
            raise ValueError(f"bits per parameter must be positive, got {bits}")
    out = []
    for level in range(1, spec.depth + 1):
        # participants of a tier-level node = its tier-(level-1) children
        parents = np.asarray(spec.parents[level - 1])
        sizes = np.bincount(parents, minlength=spec.num_nodes(level))
        interval = prod(kv[:level])
        payload = per_dev_bytes * bits[level - 1] / 32.0
        out.append(ring_allreduce_bytes(payload, int(sizes.max())) / interval)
    return out
