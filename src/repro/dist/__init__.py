from repro.dist import collectives, sharding

__all__ = ["collectives", "sharding"]
