"""Federated training driver.

    PYTHONPATH=src python -m repro.launch.train --arch granite-3-2b --smoke \
        --rounds 8 --ckpt-dir /tmp/fed_ckpt [--resume] [--inject-failures]

Uses the SAME cell builders as the dry-run: on a real TPU cluster this
binary runs the lowered train step per local update with the host loop at
aggregation boundaries; on CPU, --smoke selects the reduced config.
"""
from __future__ import annotations

import argparse

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import CheckpointManager
from repro.configs.registry import ARCH_IDS, get_config, get_smoke
from repro.core import FedTopology, HierFAVGConfig
from repro.data import FederatedBatcher, make_partition, token_corpus, synthetic
from repro.fed import FailureSimulator, FederatedRunner, RunnerConfig, StragglerModel
from repro.models import transformer
from repro.optim import sgd, exponential_decay


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True, choices=list(ARCH_IDS) + ["lm-100m"])
    ap.add_argument("--smoke", action="store_true", help="reduced CPU config")
    ap.add_argument("--rounds", type=int, default=8)
    ap.add_argument("--seq-len", type=int, default=32)
    ap.add_argument("--batch", type=int, default=4, help="per-client batch")
    ap.add_argument("--lr", type=float, default=1e-2)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--inject-failures", action="store_true")
    ap.add_argument("--stragglers", action="store_true")
    args = ap.parse_args()

    cfg = get_smoke(args.arch) if args.smoke and args.arch in ARCH_IDS else get_config(args.arch)
    topo = FedTopology(num_edges=cfg.fed.edges_per_pod, clients_per_edge=cfg.fed.clients_per_edge)
    hier = HierFAVGConfig(kappa1=min(cfg.fed.kappa1, 4), kappa2=min(cfg.fed.kappa2, 2))
    n = topo.num_clients
    rng = np.random.default_rng(0)

    if cfg.embed_inputs:
        corp = token_corpus(rng, num_sequences=max(128, n * 16), seq_len=args.seq_len,
                            vocab=cfg.vocab_size, num_classes=8)
        parts = make_partition("simple_niid", corp.labels, topo.num_edges,
                               topo.clients_per_edge, rng)
        batcher = FederatedBatcher(
            {"tokens": corp.tokens}, parts, batch_size=args.batch, seed=0,
            batch_fn=lambda d: {"inputs": d["tokens"][..., :-1], "targets": d["tokens"][..., 1:]},
        )
    else:  # stub-frontend archs: precomputed embeddings
        emb, tgt, labels = synthetic.embedding_corpus(
            rng, num_sequences=max(128, n * 16), seq_len=args.seq_len,
            d_model=cfg.d_model, num_classes=8,
        )
        tgt = np.clip(tgt, 0, cfg.vocab_size - 1)
        parts = make_partition("simple_niid", labels, topo.num_edges, topo.clients_per_edge, rng)
        batcher = FederatedBatcher(
            {"inputs": emb, "targets": tgt}, parts, batch_size=args.batch, seed=0
        )

    runner = FederatedRunner(
        loss_fn=transformer.make_loss_fn(cfg),
        optimizer=sgd(exponential_decay(args.lr, 0.995, 50)),
        topology=topo,
        hier_config=hier,
        data_sizes=batcher.data_sizes,
        batcher=batcher,
        runner_config=RunnerConfig(num_rounds=args.rounds,
                                   checkpoint_every=4 if args.ckpt_dir else 0),
        checkpointer=CheckpointManager(args.ckpt_dir, keep=2) if args.ckpt_dir else None,
        failures=FailureSimulator(n, p_fail=0.1, seed=1) if args.inject_failures else None,
        stragglers=StragglerModel(n, seed=2) if args.stragglers else None,
    )
    params = transformer.init_params(jax.random.PRNGKey(0), cfg)
    if args.resume and args.ckpt_dir:
        state, start = runner.restore_or_init(jax.random.PRNGKey(1), params)
        print(f"resumed at round {start}")
    else:
        state, start = runner.init(jax.random.PRNGKey(1), params), 0
    state = runner.run(state, start_round=start)
    for h in runner.history:
        print(f"round {h.round:3d} step {h.step:4d} loss {h.loss:.4f} alive {h.mask_alive}")


if __name__ == "__main__":
    main()
