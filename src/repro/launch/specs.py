"""ShapeDtypeStruct stand-ins for every model input — no device allocation.

``input_specs(cfg, shape, mesh)`` returns the argument structs the step
function is lowered with; shardings are attached NamedShardings. Stub
frontends ([vlm]/[audio]) get float embedding inputs in place of tokens,
per the assignment.
"""
from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.base import ArchConfig, ShapeSpec
from repro.dist.sharding import ShardingRules, fed_rules, serve_rules, topology_for

PyTree = Any


def _sds(shape, dtype, sharding=None):
    return jax.ShapeDtypeStruct(tuple(shape), dtype, sharding=sharding)


def train_batch_specs(cfg: ArchConfig, shape: ShapeSpec, mesh) -> Tuple[PyTree, PyTree, int]:
    """Returns (batch_structs, batch_shardings, grad_accum).

    Batch leaves: (accum, N, micro, S[, d]) when accum > 1, else (N, b, S[, d]).
    """
    rules = fed_rules(cfg, mesh)
    topo = topology_for(cfg, mesh)
    n = topo.num_clients
    if shape.global_batch % n:
        raise ValueError(f"global_batch {shape.global_batch} % N={n} != 0")
    b = shape.global_batch // n
    micro = min(cfg.microbatch, b)
    accum = b // micro
    has_accum = accum > 1
    lead = (accum, n, micro) if has_accum else (n, b)

    if cfg.embed_inputs:
        in_shape = lead + (shape.seq_len,)
        in_dtype = jnp.int32
    else:
        in_shape = lead + (shape.seq_len, cfg.d_model)
        in_dtype = jnp.bfloat16 if cfg.param_dtype == "bfloat16" else jnp.float32
    tgt_shape = lead + (shape.seq_len,)

    in_spec = rules.batch_spec(in_shape, has_accum=has_accum)
    tgt_spec = rules.batch_spec(tgt_shape, has_accum=has_accum)
    batch = {
        "inputs": _sds(in_shape, in_dtype, NamedSharding(mesh, in_spec)),
        "targets": _sds(tgt_shape, jnp.int32, NamedSharding(mesh, tgt_spec)),
    }
    shardings = {
        "inputs": NamedSharding(mesh, in_spec),
        "targets": NamedSharding(mesh, tgt_spec),
    }
    return batch, shardings, accum


def prefill_request_specs(cfg: ArchConfig, shape: ShapeSpec, mesh) -> Tuple[PyTree, PyTree]:
    rules = serve_rules(cfg, mesh)
    if cfg.embed_inputs:
        s = (shape.global_batch, shape.seq_len)
        dt = jnp.int32
    else:
        s = (shape.global_batch, shape.seq_len, cfg.d_model)
        dt = jnp.bfloat16 if cfg.param_dtype == "bfloat16" else jnp.float32
    spec = rules.request_spec(s)
    sh = NamedSharding(mesh, spec)
    return _sds(s, dt, sh), sh


def decode_request_specs(cfg: ArchConfig, shape: ShapeSpec, mesh) -> Tuple[PyTree, PyTree]:
    """(tokens, position) structs for one decode step."""
    rules = serve_rules(cfg, mesh)
    B = shape.global_batch
    if cfg.embed_inputs:
        tok_shape: Tuple[int, ...] = (B,)
        dt = jnp.int32
    else:
        tok_shape = (B, cfg.d_model)
        dt = jnp.bfloat16 if cfg.param_dtype == "bfloat16" else jnp.float32
    tok_spec = rules.request_spec(tok_shape)
    pos_spec = rules.request_spec((B,))
    structs = {
        "tokens": _sds(tok_shape, dt, NamedSharding(mesh, tok_spec)),
        "position": _sds((B,), jnp.int32, NamedSharding(mesh, pos_spec)),
    }
    shardings = {
        "tokens": NamedSharding(mesh, tok_spec),
        "position": NamedSharding(mesh, pos_spec),
    }
    return structs, shardings


def input_specs(cfg: ArchConfig, shape: ShapeSpec, mesh) -> PyTree:
    """The assignment-mandated entry point: structs for every model input
    of this cell (training batch or serving request)."""
    if shape.kind == "train":
        batch, _, _ = train_batch_specs(cfg, shape, mesh)
        return batch
    if shape.kind == "prefill":
        req, _ = prefill_request_specs(cfg, shape, mesh)
        return {"inputs": req}
    if shape.kind == "decode":
        structs, _ = decode_request_specs(cfg, shape, mesh)
        return structs
    raise ValueError(shape.kind)
