"""Serving driver: prefill a request batch, decode with KV caches.

    PYTHONPATH=src python -m repro.launch.serve --arch yi-9b --smoke \
        --batch 4 --prompt-len 32 --gen 16
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.registry import ARCH_IDS, get_config, get_smoke
from repro.models import transformer


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True, choices=list(ARCH_IDS))
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    args = ap.parse_args()

    cfg = get_smoke(args.arch) if args.smoke else get_config(args.arch)
    params = transformer.init_params(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(0)
    B, P = args.batch, args.prompt_len
    max_len = P + args.gen
    if cfg.embed_inputs:
        prompts = jnp.asarray(rng.integers(0, cfg.vocab_size, size=(B, P)), jnp.int32)
    else:
        prompts = jnp.asarray(rng.normal(size=(B, P, cfg.d_model)), jnp.float32)

    prefill = jax.jit(lambda p, x: transformer.prefill(p, cfg, x, max_len))
    decode = jax.jit(lambda p, c, t, pos: transformer.decode_step(p, cfg, c, t, pos))

    t0 = time.time()
    logits, caches = prefill(params, prompts)
    jax.block_until_ready(logits)
    print(f"prefill {B}×{P}: {time.time()-t0:.2f}s")

    tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
    t0 = time.time()
    for t in range(args.gen - 1):
        pos = jnp.full((B,), P + t, jnp.int32)
        if cfg.embed_inputs:
            nxt = tok
        else:  # stub frontend: feed the embedding of the argmax id (demo)
            nxt = jnp.zeros((B, cfg.d_model), jnp.float32)
        logits, caches = decode(params, caches, nxt, pos)
        tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
    jax.block_until_ready(tok)
    dt = time.time() - t0
    print(f"decode {B}×{args.gen-1}: {dt:.2f}s ({B*(args.gen-1)/max(dt,1e-9):.1f} tok/s)")
    print("sample ids:", np.asarray(tok)[:4])


if __name__ == "__main__":
    main()
