from repro.launch import mesh, specs, steps
from repro.launch.mesh import make_host_mesh, make_production_mesh
from repro.launch.specs import input_specs

__all__ = ["mesh", "specs", "steps", "make_host_mesh", "make_production_mesh", "input_specs"]
