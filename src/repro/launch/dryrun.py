import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch × shape) on the production
meshes, record memory/cost/roofline artifacts.

    PYTHONPATH=src python -m repro.launch.dryrun --arch all --mesh both
    PYTHONPATH=src python -m repro.launch.dryrun --arch yi-9b --shape train_4k --mesh single

Artifacts: one JSON per cell under --out (default artifacts/dryrun/),
consumed by benchmarks/roofline_report.py and EXPERIMENTS.md. Cells with an
existing artifact are skipped unless --force. The 512 placeholder-device
XLA flag above MUST precede every other import (jax locks device count on
first init) — do not move it.
"""
import argparse
import json
import time
import traceback
from typing import Optional

import jax

from repro.analysis import hlo as hlo_mod
from repro.analysis import roofline as rl
from repro.configs.base import ShapeSpec
from repro.configs.registry import ARCH_IDS, get_config
from repro.dist.sharding import set_hint_mesh, topology_for
from repro.launch.mesh import make_production_mesh
from repro.launch.steps import build_aggregation_cells, build_cell


def mem_stats_dict(ma) -> dict:
    fields = (
        "argument_size_in_bytes", "output_size_in_bytes",
        "temp_size_in_bytes", "alias_size_in_bytes", "generated_code_size_in_bytes",
    )
    return {f: getattr(ma, f, None) for f in fields}


def run_cell(cfg, shape: ShapeSpec, mesh, mesh_name: str, *, phases: bool) -> dict:
    chips = mesh.devices.size
    t0 = time.time()
    set_hint_mesh(mesh)
    try:
        cell = build_cell(cfg, shape, mesh)
        lowered = cell.fn.lower(*cell.arg_structs)
        compiled = lowered.compile()
    finally:
        set_hint_mesh(None)
    compile_s = time.time() - t0

    ma = compiled.memory_analysis()
    ca = compiled.cost_analysis() or {}
    txt = compiled.as_text()
    summary = hlo_mod.analyze(txt, mesh, conditional_weight=0.0)
    summary_full = hlo_mod.analyze(txt, mesh, conditional_weight=1.0)

    mf = rl.model_flops(cfg, shape)
    local_terms = rl.from_summary(
        f"{cfg.name}/{shape.name}/{mesh_name}", summary, chips, model_flops_global=mf
    )

    out = {
        "arch": cfg.name,
        "shape": shape.name,
        "kind": shape.kind,
        "mesh": mesh_name,
        "chips": chips,
        "compile_s": compile_s,
        "memory": mem_stats_dict(ma),
        "cost_analysis": {
            "flops": float(ca.get("flops", 0.0)),
            "bytes accessed": float(ca.get("bytes accessed", 0.0)),
        },
        "hlo": {
            "flops_per_device": summary.flops,
            "hbm_bytes_per_device": summary.hbm_bytes,
            "coll_bytes_per_device": summary.collective_bytes_per_device(),
            "coll_breakdown": summary.collective_breakdown(),
            "coll_breakdown_with_agg": summary_full.collective_breakdown(),
            "unresolved_whiles": summary.unresolved_whiles,
        },
        "meta": cell.meta,
        "roofline": local_terms.to_dict(),
    }

    if phases and shape.kind == "train":
        set_hint_mesh(mesh)
        try:
            edge_cell, cloud_cell = build_aggregation_cells(cfg, mesh)
            e_txt = edge_cell.fn.lower(*edge_cell.arg_structs).compile().as_text()
            c_txt = cloud_cell.fn.lower(*cloud_cell.arg_structs).compile().as_text()
        finally:
            set_hint_mesh(None)
        e_sum = hlo_mod.analyze(e_txt, mesh)
        c_sum = hlo_mod.analyze(c_txt, mesh)
        e_terms = rl.from_summary("edge", e_sum, chips)
        c_terms = rl.from_summary("cloud", c_sum, chips)
        k1, k2 = cfg.fed.kappa1, cfg.fed.kappa2
        amort = rl.hierfavg_step_terms(
            f"{cfg.name}/{shape.name}/{mesh_name}/amortized",
            local_terms, e_terms, c_terms, k1, k2,
        )
        out["phases"] = {
            "edge": e_terms.to_dict(),
            "cloud": c_terms.to_dict(),
            "amortized_step": amort.to_dict(),
            "kappa1": k1,
            "kappa2": k2,
        }
    return out


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="all")
    ap.add_argument("--shape", default="all")
    ap.add_argument("--mesh", default="both", choices=["single", "multi", "both"])
    ap.add_argument("--out", default="artifacts/dryrun")
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--no-phases", action="store_true")
    args = ap.parse_args()

    os.makedirs(args.out, exist_ok=True)
    archs = list(ARCH_IDS) if args.arch == "all" else args.arch.split(",")
    meshes = {"single": [False], "multi": [True], "both": [False, True]}[args.mesh]

    failures = []
    for arch in archs:
        cfg = get_config(arch)
        shapes = [s for s in cfg.input_shapes if args.shape in ("all", s.name)]
        for skipped in cfg.skipped_shapes:
            if args.shape in ("all", skipped):
                print(f"[skip] {arch} × {skipped}: full attention — noted in DESIGN.md")
        for multi in meshes:
            mesh_name = "multi_pod_2x16x16" if multi else "single_pod_16x16"
            mesh = make_production_mesh(multi_pod=multi)
            for shape in shapes:
                tag = f"{arch}__{shape.name}__{mesh_name}"
                path = os.path.join(args.out, tag + ".json")
                if os.path.exists(path) and not args.force:
                    print(f"[cached] {tag}")
                    continue
                print(f"[lower+compile] {tag} ...", flush=True)
                try:
                    rec = run_cell(cfg, shape, mesh, mesh_name,
                                   phases=(not args.no_phases) and not multi)
                    with open(path, "w") as f:
                        json.dump(rec, f, indent=1)
                    r = rec["roofline"]
                    print(
                        f"  OK {rec['compile_s']:.1f}s compile | "
                        f"mem/dev: arg {rec['memory']['argument_size_in_bytes']/1e9:.2f}GB "
                        f"temp {rec['memory']['temp_size_in_bytes']/1e9:.2f}GB | "
                        f"compute {r['compute_s']*1e3:.2f}ms memory {r['memory_s']*1e3:.2f}ms "
                        f"collective {r['collective_s']*1e3:.2f}ms -> {r['dominant']}",
                        flush=True,
                    )
                except Exception as e:
                    failures.append((tag, repr(e)))
                    print(f"  FAIL: {e}\n{traceback.format_exc()}", flush=True)

    print(f"\n{'='*60}\ndry-run complete; {len(failures)} failures")
    for tag, err in failures:
        print(f"  FAIL {tag}: {err[:200]}")
    if failures:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
