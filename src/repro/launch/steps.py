"""Cell builders: (arch × shape × mesh) -> jitted step + argument structs.

Every cell the dry-run lowers comes from here, and the real drivers
(train.py / serve.py) use the same builders with concrete arrays — the
dry-run proves exactly what production would run.

train cell  : HierFAVG train_step (local update + conditional two-level
              aggregation) over stacked client params.
prefill cell: full-prompt forward building decode caches (serving).
decode cell : one-token serve_step against a seq_len-deep KV cache.
"""
from __future__ import annotations

import functools
from typing import Any, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.base import ArchConfig, ShapeSpec
from repro.core.hierfavg import build_train_step, init_state
from repro.dist.sharding import fed_rules, serve_rules, topology_for
from repro.launch import specs as specs_mod
from repro.models import transformer
from repro.optim import sgd

PyTree = Any


class Cell(NamedTuple):
    fn: Any  # jitted callable, ready to .lower(*arg_structs)
    arg_structs: Tuple
    arg_shardings: Tuple
    meta: dict


def _replicated(mesh):
    return NamedSharding(mesh, P())


def _state_shardings(state_struct, params_shardings, mesh):
    """FedState shardings: params by rules; opt-state subtrees that mirror
    the params tree inherit its shardings; everything else replicated."""
    rep = _replicated(mesh)
    params_def = jax.tree_util.tree_structure(params_shardings)

    def map_like(node):
        try:
            if jax.tree_util.tree_structure(node) == params_def:
                return params_shardings
        except Exception:
            pass
        if isinstance(node, (tuple, list)) and not hasattr(node, "shape"):
            mapped = [map_like(c) for c in node]
            return type(node)(*mapped) if hasattr(node, "_fields") else type(node)(mapped)
        if isinstance(node, dict):
            return {k: map_like(v) for k, v in node.items()}
        return jax.tree_util.tree_map(lambda _: rep, node)

    opt_sh = map_like(state_struct.opt_state)
    anchor_sh = None if state_struct.anchor is None else params_shardings
    return type(state_struct)(
        step=rep, params=params_shardings, opt_state=opt_sh, rng=rep, anchor=anchor_sh
    )


def _attach(structs: PyTree, shardings: PyTree) -> PyTree:
    return jax.tree_util.tree_map(
        lambda s, sh: jax.ShapeDtypeStruct(s.shape, s.dtype, sharding=sh), structs, shardings
    )


# ---------------------------------------------------------------------------
# Train cell
# ---------------------------------------------------------------------------

def build_train_cell(
    cfg: ArchConfig,
    shape: ShapeSpec,
    mesh,
    *,
    lr: float = 1e-3,
    donate: bool = True,
) -> Cell:
    rules = fed_rules(cfg, mesh)
    topo = topology_for(cfg, mesh)
    n = topo.num_clients
    hier = cfg.fed.schedule()
    weights = jnp.ones((n,), jnp.float32)
    loss_fn = transformer.make_loss_fn(cfg)
    opt = sgd(lr)

    batch_structs, batch_shardings, accum = specs_mod.train_batch_specs(cfg, shape, mesh)
    step_fn = build_train_step(loss_fn, opt, topo, hier, weights, grad_accum=accum)

    def init_fn():
        params = transformer.init_params(jax.random.PRNGKey(0), cfg)
        return init_state(jax.random.PRNGKey(1), params, opt, topo, hier)

    state_struct = jax.eval_shape(init_fn)
    params_sh = rules.params_shardings(state_struct.params, scanned=cfg.scan_layers)
    state_sh = _state_shardings(state_struct, params_sh, mesh)
    state_struct = _attach(state_struct, state_sh)

    fn = jax.jit(
        lambda state, batch: step_fn(state, batch),
        donate_argnums=(0,) if donate else (),
    )
    return Cell(
        fn=fn,
        arg_structs=(state_struct, batch_structs),
        arg_shardings=(state_sh, batch_shardings),
        meta={
            "kind": "train",
            "num_clients": n,
            "grad_accum": accum,
            "kappa1": hier.kappa1,
            "kappa2": hier.kappa2,
            "layout": cfg.fed.layout,
        },
    )


# ---------------------------------------------------------------------------
# Serving cells
# ---------------------------------------------------------------------------

def _serve_params(cfg: ArchConfig, mesh) -> Tuple[PyTree, PyTree]:
    rules = serve_rules(cfg, mesh)
    p_struct = jax.eval_shape(lambda: transformer.init_params(jax.random.PRNGKey(0), cfg))
    p_sh = rules.params_shardings(p_struct, scanned=cfg.scan_layers)
    return _attach(p_struct, p_sh), p_sh


def build_prefill_cell(cfg: ArchConfig, shape: ShapeSpec, mesh) -> Cell:
    params_struct, params_sh = _serve_params(cfg, mesh)
    req_struct, req_sh = specs_mod.prefill_request_specs(cfg, shape, mesh)
    max_len = shape.seq_len

    fn = jax.jit(lambda params, inputs: transformer.prefill(params, cfg, inputs, max_len))
    return Cell(
        fn=fn,
        arg_structs=(params_struct, req_struct),
        arg_shardings=(params_sh, req_sh),
        meta={"kind": "prefill", "max_len": max_len},
    )


def build_decode_cell(cfg: ArchConfig, shape: ShapeSpec, mesh, *, donate: bool = True) -> Cell:
    rules = serve_rules(cfg, mesh)
    params_struct, params_sh = _serve_params(cfg, mesh)
    req_structs, req_sh = specs_mod.decode_request_specs(cfg, shape, mesh)
    B, L = shape.global_batch, shape.seq_len

    cache_struct = jax.eval_shape(
        lambda p: transformer.init_decode_caches(p, cfg, B, L), params_struct
    )
    cache_sh = rules.caches_shardings(cache_struct, scanned=cfg.scan_layers)
    cache_struct = _attach(cache_struct, cache_sh)

    def serve_step(params, caches, tokens, position):
        return transformer.decode_step(params, cfg, caches, tokens, position)

    fn = jax.jit(serve_step, donate_argnums=(1,) if donate else ())
    return Cell(
        fn=fn,
        arg_structs=(params_struct, cache_struct, req_structs["tokens"], req_structs["position"]),
        arg_shardings=(params_sh, cache_sh, req_sh["tokens"], req_sh["position"]),
        meta={"kind": "decode", "cache_len": L},
    )


def build_cell(cfg: ArchConfig, shape: ShapeSpec, mesh) -> Cell:
    if shape.kind == "train":
        return build_train_cell(cfg, shape, mesh)
    if shape.kind == "prefill":
        return build_prefill_cell(cfg, shape, mesh)
    if shape.kind == "decode":
        return build_decode_cell(cfg, shape, mesh)
    raise ValueError(shape.kind)


# ---------------------------------------------------------------------------
# Aggregation-phase cells (for per-phase roofline attribution)
# ---------------------------------------------------------------------------

def build_aggregation_cells(cfg: ArchConfig, mesh) -> Tuple[Cell, Cell]:
    """(edge_sync, cloud_sync) as standalone jittables over the fed state's
    stacked params — lowered separately so the roofline can attribute
    collective bytes to the two HierFAVG hops exactly."""
    from repro.core.hierfavg import build_cloud_sync, build_edge_sync

    rules = fed_rules(cfg, mesh)
    topo = topology_for(cfg, mesh)
    n = topo.num_clients
    hier = cfg.fed.schedule()
    weights = jnp.ones((n,), jnp.float32)

    def init_fn():
        params = transformer.init_params(jax.random.PRNGKey(0), cfg)
        return init_state(jax.random.PRNGKey(1), params, sgd(1e-3), topo, hier)

    state_struct = jax.eval_shape(init_fn)
    params_sh = rules.params_shardings(state_struct.params, scanned=cfg.scan_layers)
    state_sh = _state_shardings(state_struct, params_sh, mesh)
    state_struct = _attach(state_struct, state_sh)

    edge = build_edge_sync(topo, hier, weights)
    cloud = build_cloud_sync(topo, hier, weights)
    edge_cell = Cell(
        fn=jax.jit(lambda s: edge(s)),
        arg_structs=(state_struct,),
        arg_shardings=(state_sh,),
        meta={"kind": "edge_sync", "num_clients": n},
    )
    cloud_cell = Cell(
        fn=jax.jit(lambda s: cloud(s)),
        arg_structs=(state_struct,),
        arg_shardings=(state_sh,),
        meta={"kind": "cloud_sync", "num_clients": n},
    )
    return edge_cell, cloud_cell
