"""Production meshes.

Functions (not module constants) so importing never touches jax device
state. Single-pod: (16,16) ("data","model") = 256 chips. Multi-pod:
(2,16,16) ("pod","data","model") = 512 chips; the "pod" axis is the
cross-DCN dimension HierFAVG's cloud hop amortizes.
"""
from __future__ import annotations

from typing import Optional, Sequence

import jax
import numpy as np


def make_production_mesh(*, multi_pod: bool = False, devices: Optional[Sequence] = None):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    n = int(np.prod(shape))
    if devices is None:
        devices = jax.devices()[:n]
    if len(devices) != n:
        raise ValueError(f"need {n} devices for mesh {shape}, got {len(devices)}")
    return jax.make_mesh(
        shape, axes, devices=devices,
        axis_types=(jax.sharding.AxisType.Auto,) * len(axes),
    )


def make_host_mesh(shape: Sequence[int], axes: Sequence[str]):
    """Small mesh over host devices (tests / probes)."""
    n = int(np.prod(shape))
    devs = jax.devices()[:n]
    if len(devs) != n:
        raise ValueError(f"need {n} devices, have {len(jax.devices())}")
    return jax.make_mesh(
        tuple(shape), tuple(axes), devices=devs,
        axis_types=(jax.sharding.AxisType.Auto,) * len(axes),
    )
