"""Zero-copy superround execution engine.

The per-round ``FederatedRunner`` loop pays, every edge interval: a Python
dispatch, a full un-donated copy of the stacked (N, ...) ``FedState``
(params + opt_state + anchor + EF residual ≈ 4 model copies per client), a
blocking host sync for ``step``/``loss``, and a synchronous batch upload.
The paper's protocol only *needs* the host at cloud boundaries — failure
masks, eval, checkpointing, and early stopping are all cloud-interval
decisions — so this engine drives one full cloud interval per dispatch and
removes every per-round host cost:

* **Donated state** — ``core.hierfavg.build_super_round`` is jitted with
  ``donate_argnums=(0,)``: XLA reuses the FedState's buffers for the
  output, so the multi-copy stacked state is updated in place instead of
  round-tripped through fresh HBM allocations each interval.
* **Cloud-interval scan fusion** — κ₂ edge intervals (κ₁ local steps +
  the due per-level aggregation each) run as one ``lax.scan`` with the
  level switch folded in: one dispatch and one executable per cloud
  interval instead of κ₂ of each.
* **Async metrics** — per-round loss / grad-norm / step accumulate on
  device inside the scan and come back stacked; the engine stores the
  device arrays and defers the host fetch to eval/checkpoint boundaries
  (or the end of the run), reconstructing the per-round ``RoundRecord``
  history host-side. No per-round blocking transfer.
* **Device-side batch prefetch** — a ``data.pipeline.SuperBatchPrefetcher``
  worker assembles and ``jax.device_put``s interval r+1's
  (κ₂, κ₁, N, b, ...) block while interval r computes.

**Mesh execution** — when the runner carries a device mesh, the engine
swaps in ``core.hierfavg.build_sharded_super_round``: the stacked client
axis is permuted into the edge-aligned ``core.hierarchy.ShardPlacement``
order (each edge subtree wholly on one shard, phantom-padded when the
packing is ragged) and ``shard_map``-sharded over the mesh's ``"clients"``
axis. Edge syncs become device-local segment reductions; each cloud
boundary issues exactly one grouped psum; the prefetcher ``device_put``s
batch blocks with the matching ``NamedSharding`` so every device receives
only its shard's slice; metrics stay per-client on device and are reduced
host-side at flush time. The engine owns the layout conversion: callers
hand in and get back canonical client order.

Protocol state is bit-exact versus the per-round driver (tests enforce
it; see docs/performance.md for the two 1-ULP XLA:CPU codegen caveats and
the cloud-psum reassociation tolerance of the mesh path); the runner
transparently falls back to the per-round path when ``eval_every``/
``checkpoint_every`` demand sub-cloud-interval granularity.
"""
from __future__ import annotations

from typing import Any, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import aggregation
from repro.core.hierarchy import as_hierarchy, plan_shard_placement
from repro.core.hierfavg import (
    FedState,
    build_cohort_super_round,
    build_megakernel_super_round,
    build_sharded_super_round,
    build_super_round,
    map_stacked_fed_state,
)
from repro.data.pipeline import CohortPrefetcher, SuperBatchPrefetcher
from repro.fed.client_store import replace_sticky_rows, sticky_rows

PyTree = Any


def _map_stacked(state: FedState, fn, lead: int) -> FedState:
    """Apply ``fn`` to every state leaf carrying the stacked client dim of
    size ``lead`` (params/opt/anchor/residual rows), pass everything else
    through — the permute/pad twin of ``fed_state_partition_specs``."""
    return map_stacked_fed_state(state, fn, lambda x: x, lead)


class SuperRoundEngine:
    """Drives a ``FederatedRunner``'s training loop one cloud interval per
    donated dispatch — client-sharded over the runner's mesh when one is
    configured. Constructed (and cached) by the runner; appends the same
    per-round ``RoundRecord`` history the per-round path would."""

    def __init__(self, runner, *, donate: bool = True, prefetch: bool = True):
        self.runner = runner
        hier = runner.hier_config
        self.k1 = hier.kappa1
        self.k2 = hier.kappa2_effective
        self.prefetch = prefetch
        self.mesh = runner.mesh
        self.placement = None
        # engine="megakernel" is an opt-in fast path: whole cloud intervals
        # through the client-blocked lowering when the schedule is block-
        # separable, otherwise the scan-fused superround with a named reason
        # (queryable here and on runner._megakernel_reason — the same
        # report-don't-raise idiom as the mesh's sharding_incompatibility)
        self.uses_megakernel = False
        self.megakernel_reason: Optional[str] = None
        if getattr(runner.cfg, "engine", "") == "megakernel":
            self.megakernel_reason = runner._check_megakernel()
        if self.mesh is not None:
            from repro.dist import sharding as dist_sharding

            self.axis = dist_sharding.client_axis_of(self.mesh)
            num_shards = int(self.mesh.shape[self.axis])
            # the runner plans (and caches) the placement during eligibility;
            # replan only for directly constructed engines
            self.placement = getattr(runner, "_placement", None)
            if self.placement is None or self.placement.num_shards != num_shards:
                self.placement = plan_shard_placement(as_hierarchy(runner.topology), num_shards)
            fn = build_sharded_super_round(
                runner.loss_fn,
                runner.optimizer,
                runner.topology,
                hier,
                runner.weights,
                mesh=self.mesh,
                axis=self.axis,
                placement=self.placement,
                grad_accum=runner.grad_accum,
            )
            self._gather = self.placement.gather_index()
            self._positions = self.placement.positions()
            self._valid = self.placement.valid()
            self._block_sharding = dist_sharding.batch_block_sharding(self.mesh, self.axis)
            self._mask_sharding = dist_sharding.mask_stack_sharding(self.mesh, self.axis)
        elif getattr(runner.cfg, "engine", "") == "megakernel" and self.megakernel_reason is None:
            fn = build_megakernel_super_round(
                runner.loss_fn,
                runner.optimizer,
                runner.topology,
                hier,
                runner.weights,
                grad_accum=runner.grad_accum,
            )
            self.uses_megakernel = True
        else:
            fn = build_super_round(
                runner.loss_fn,
                runner.optimizer,
                runner.topology,
                hier,
                runner.weights,
                grad_accum=runner.grad_accum,
            )
        self._super = jax.jit(fn, donate_argnums=(0,) if donate else ())
        # [(round_base, [alive...], device metrics)] — single-device metrics
        # are {"loss","grad_norm","step"} (κ₂,) scalars; mesh metrics are
        # per-client {"loss","gsq"} (κ₂, κ₁, padded_N) + "step" (κ₂,)
        self._pending: List[Tuple[int, List[int], dict]] = []

    # -- placement-order layout conversion (mesh path) ----------------------
    def _shard_state(self, state: FedState) -> FedState:
        """Canonical (N, ...) state -> placement-ordered padded state laid
        out with the engine's NamedShardings (one upload per device)."""
        from repro.dist.sharding import fed_state_shardings

        gather = jnp.asarray(self._gather)
        padded = _map_stacked(
            state, lambda x: jnp.take(x, gather, axis=0), self.runner.topology.num_clients
        )
        shardings = fed_state_shardings(
            self.mesh, self.axis, padded, self.placement.padded_clients
        )
        return jax.device_put(padded, shardings)

    def _unshard_state(self, state: FedState) -> FedState:
        """Placement-ordered padded state -> canonical client order on the
        default device (phantom rows dropped by the inverse gather)."""
        pos = jnp.asarray(self._positions)
        out = _map_stacked(
            state, lambda x: jnp.take(x, pos, axis=0), self.placement.padded_clients
        )
        return jax.device_put(out, jax.devices()[0])

    def _canonical_params(self, state: FedState) -> PyTree:
        if self.mesh is None:
            return state.params
        pos = jnp.asarray(self._positions)
        return jax.tree_util.tree_map(lambda x: jnp.take(x, pos, axis=0), state.params)

    def _mask_to_device(self, stack: np.ndarray):
        if self.mesh is None:
            return jnp.asarray(stack)
        padded = stack[:, self._gather] * self._valid[None, :].astype(stack.dtype)
        return jax.device_put(jnp.asarray(padded), self._mask_sharding)

    def _block_transform(self):
        if self.mesh is None:
            return None
        gather = self._gather
        return lambda block: jax.tree_util.tree_map(lambda x: x[:, :, gather], block)

    # ------------------------------------------------------------------
    def _masks_for_interval(self) -> Tuple[Optional[np.ndarray], List[int], Optional[np.ndarray]]:
        """κ₂ host-side survival masks, stacked to a (κ₂, N) numpy block for
        the scan (canonical client order — the engine permutes for the mesh
        at upload time).

        Returns (mask_stack | None, per-round alive counts, last round's
        mask for the boundary eval). Calls the failure detector once per
        round — the same host sequence as the per-round driver.
        """
        r = self.runner
        n = r.topology.num_clients
        masks = [r._mask_for_round() for _ in range(self.k2)]
        if all(m is None for m in masks):
            return None, [n] * self.k2, None
        stack = np.stack(
            [m if m is not None else np.ones(n, np.float32) for m in masks]
        )
        alive = [int(row.sum()) for row in stack]
        return stack, alive, stack[-1]

    def _flush(self, wire_per_step: float) -> None:
        """Materialize pending device metrics into RoundRecords (one
        ``device_get`` per outstanding cloud interval) through the runner's
        shared record-assembly helper — both drivers' histories are built
        by the same code. Mesh metrics arrive per-client (no collective was
        spent on diagnostics): the loss mean and grad-norm reduce here,
        over real clients only (phantom pad columns dropped)."""
        r = self.runner
        for round_base, alive, metrics in self._pending:
            vals = jax.device_get(metrics)
            for j in range(self.k2):
                step = int(vals["step"][j])
                if self.mesh is None:
                    loss = float(vals["loss"][j])
                    gnorm = float(vals["grad_norm"][j])
                else:
                    loss = float(np.mean(vals["loss"][j][:, self._valid]))
                    gsq = vals["gsq"][j][:, self._valid]  # (κ₁, N_real)
                    gnorm = float(np.mean(np.sqrt(np.sum(gsq, axis=1))))
                r._record_round(
                    round_base + j, step, loss, gnorm, alive[j], wire_per_step,
                    wall_clock_s=self._wall_clock_for(round_base + j),
                )
        self._pending.clear()

    # -- engine-variant hooks (overridden by DeadlineEngine) ----------------
    def _dispatch_interval(
        self, state: FedState, block: PyTree, mask_stack: Optional[np.ndarray], round_base: int
    ) -> Tuple[FedState, dict]:
        """Run one cloud interval on device. The stock engine is purely
        synchronous: upload the mask stack (mesh-permuted when sharded) and
        dispatch the fused superround executable."""
        mask_dev = None if mask_stack is None else self._mask_to_device(mask_stack)
        return self._super(state, block, mask_dev)

    def _eval_mask(self, last_mask: Optional[np.ndarray]) -> Optional[np.ndarray]:
        """Mask defining the published cloud model for the boundary eval."""
        return last_mask

    def _wall_clock_for(self, round_index: int) -> float:
        """Simulated wall-clock seconds at a round's close (0.0 for the
        synchronous engine, which has no event clock)."""
        return 0.0

    def _checkpoint_meta(self, end_round: int, batcher_snapshot: dict) -> dict:
        r = self.runner
        meta = {"round": end_round, "batcher": batcher_snapshot}
        if r.failures is not None:
            meta["failures"] = r.failures.state_dict()
        if r.stragglers is not None:
            meta["stragglers"] = r.stragglers.state_dict()
        return meta

    # ------------------------------------------------------------------
    def run_intervals(
        self, state: FedState, *, start_round: int, num_intervals: int
    ) -> Tuple[FedState, bool]:
        """Run ``num_intervals`` cloud intervals from a cloud-aligned
        ``start_round``. Takes and returns canonical client order (the mesh
        path converts to placement order internally). Returns
        (state, stopped_early)."""
        r = self.runner
        if start_round % self.k2:
            raise ValueError(
                f"superround engine must start at a cloud boundary: "
                f"start_round={start_round} is not a multiple of {self.k2}"
            )
        wire_per_step = r._wire_bytes_per_step(state)
        if self.mesh is not None:
            state = self._shard_state(state)
        stopped = False
        # no failure model -> the all-alive mask triple is identical every
        # interval: build it once instead of κ₂ detector calls per interval.
        # An overridden/monkeypatched _mask_for_round is a live seam (the
        # per-round driver polls it unconditionally), so only the stock
        # implementation is hoisted.
        from repro.fed.runner import FederatedRunner

        no_failures = (
            r.failures is None
            and r.stragglers is None
            and getattr(r._mask_for_round, "__func__", None)
            is FederatedRunner._mask_for_round
        )
        static_masks = (None, [r.topology.num_clients] * self.k2, None)
        prefetcher = SuperBatchPrefetcher(
            r.batcher,
            rounds_per_block=self.k2,
            steps_per_round=self.k1,
            num_blocks=num_intervals,
            device=self._block_sharding if self.mesh is not None else None,
            use_thread=self.prefetch,
            transform=self._block_transform(),
        )
        try:
            for q in range(num_intervals):
                round_base = start_round + q * self.k2
                block, batcher_snapshot = prefetcher.get()
                mask_stack, alive, last_mask = (
                    static_masks if no_failures else self._masks_for_interval()
                )
                state, metrics = self._dispatch_interval(state, block, mask_stack, round_base)
                self._pending.append((round_base, alive, metrics))

                end_round = round_base + self.k2  # rounds completed so far
                do_eval = (
                    r.eval_fn is not None
                    and r.cfg.eval_every
                    and end_round % r.cfg.eval_every == 0
                )
                do_ckpt = (
                    r.checkpointer is not None
                    and r.cfg.checkpoint_every
                    and end_round % r.cfg.checkpoint_every == 0
                )
                if do_eval or do_ckpt:
                    self._flush(wire_per_step)
                acc = None
                if do_eval:
                    mask_eval = self._eval_mask(last_mask)
                    mask_last = None if mask_eval is None else jnp.asarray(mask_eval)
                    cloud0 = r.eval_model(self._canonical_params(state), mask_last)
                    acc = float(r.eval_fn(cloud0))
                    r.history[-1].accuracy = acc
                if do_ckpt:
                    # the live batcher has prefetched ahead; the snapshot is
                    # the cursor state as of THIS block's cloud boundary
                    meta = self._checkpoint_meta(end_round, batcher_snapshot)
                    save_state = state if self.mesh is None else self._unshard_state(state)
                    r.checkpointer.save(r.history[-1].step, save_state, meta)
                if acc is not None and r.cfg.target_accuracy and acc >= r.cfg.target_accuracy:
                    stopped = True
                    break
            self._flush(wire_per_step)
        finally:
            prefetcher.stop()
        if self.mesh is not None:
            state = self._unshard_state(state)
        return state, stopped


class DeadlineEngine(SuperRoundEngine):
    """Semi-synchronous cloud rounds: the superround engine driven by a
    ``fed.deadline.SemiSyncScheduler`` event queue.

    Per cloud interval the scheduler advances every edge's upload clock and
    closes the round at the configured deadline/quorum, returning a
    ``RoundPlan``. A *trivial* plan (every edge folded on time at weight 1
    — always the case under uniform cadences with the full-quorum barrier)
    dispatches the stock ``build_super_round`` executable, so the parity
    contract with the synchronous engine is bit-exact *by construction*:
    same jitted function, same inputs. Non-trivial plans dispatch the gated
    ``build_deadline_super_round`` executable: folded edges contribute at
    staleness-decayed weight and receive the broadcast; late edges keep
    their edge-synced model and carry the upload into the next round.

    The ``dead`` channel of the runner's mask composition (outages — see
    ``fed.failures.compose_masks``) feeds the scheduler so a dead edge is
    skip-and-reweighted instead of force-waited: only *late* edges, whose
    upload is actually coming, can hold the cloud past its deadline.

    Wall-clock accounting: each round record gets ``wall_clock_s`` from the
    event clock (rounds inside an interval interpolate linearly to the
    interval's close — the cloud only observes time at its own boundaries).
    Boundary evals aggregate over folded edges only: that is the model the
    cloud actually published. Checkpoints add the scheduler's full event
    state (clock, per-edge finish times, staleness, retry credits, RNG)
    under ``meta["deadline"]`` so interrupted semi-synchronous runs resume
    on the identical event sequence.

    Single-device only for now: the gated top sync wants the whole client
    axis for its per-edge select (the runner's eligibility check reports
    this, mirroring the mesh/cohort predicates).
    """

    def __init__(self, runner, *, donate: bool = True, prefetch: bool = True):
        if runner.mesh is not None:
            raise ValueError(
                "the deadline engine is single-device (the gated cloud sync "
                "selects per-edge over the whole client axis); drop the mesh"
            )
        if getattr(runner.cfg, "engine", "") == "megakernel":
            raise ValueError("the deadline engine and the megakernel lowering do not compose")
        super().__init__(runner, donate=donate, prefetch=prefetch)
        from repro.core.hierfavg import build_deadline_super_round

        self.scheduler = runner.deadline
        if self.scheduler is None:
            raise ValueError("DeadlineEngine needs runner.deadline (a SemiSyncScheduler)")
        spec = as_hierarchy(runner.topology)
        # the unit that talks to the cloud: the top-minus-one tier (edges on
        # two-level trees, regions on deeper ones; the whole client set when
        # clients report straight to the cloud)
        if spec.depth >= 2:
            self._gate_segments = np.asarray(spec.segments(spec.depth - 1))
            num_units = spec.num_nodes(spec.depth - 1)
        else:
            self._gate_segments = np.zeros(spec.num_clients, np.int64)
            num_units = 1
        if self.scheduler.num_edges != num_units:
            raise ValueError(
                f"scheduler models {self.scheduler.num_edges} edge(s) but the "
                f"tree has {num_units} cloud-facing unit(s)"
            )
        fn = build_deadline_super_round(
            runner.loss_fn,
            runner.optimizer,
            runner.topology,
            runner.hier_config,
            runner.weights,
            grad_accum=runner.grad_accum,
        )
        self._gated = jax.jit(fn, donate_argnums=(0,) if donate else ())
        self._wall: dict = {}  # round index -> event-clock seconds at close
        self._last_plan = None

    # ------------------------------------------------------------------
    def _dead_units(self, mask_stack: Optional[np.ndarray]) -> Optional[np.ndarray]:
        """(E,) bool: units with zero surviving clients at the interval's
        cloud boundary, from the outage channel when the runner tracked one
        (late stragglers must NOT count — their upload is still coming)."""
        r = self.runner
        parts = getattr(r, "_last_mask_parts", None)
        dead_clients = None
        if parts is not None and parts.dead is not None:
            dead_clients = parts.dead  # 1 = outage, straggler channel excluded
        elif mask_stack is not None and r.stragglers is None:
            dead_clients = (mask_stack[-1] == 0).astype(np.float32)
        if dead_clients is None:
            return None
        e = self.scheduler.num_edges
        alive_per_unit = np.zeros(e, np.float64)
        np.add.at(alive_per_unit, self._gate_segments, 1.0 - dead_clients)
        return alive_per_unit == 0

    def _dispatch_interval(self, state, block, mask_stack, round_base):
        plan = self.scheduler.next_round(dead=self._dead_units(mask_stack))
        self._last_plan = plan
        start, close = plan.start, plan.close
        for j in range(self.k2):
            # the cloud observes time at its boundaries; interior edge
            # intervals interpolate linearly for plotting/bench purposes
            self._wall[round_base + j] = start + (close - start) * (j + 1) / self.k2
        if plan.is_trivial:
            # stock executable, stock inputs: bit-exact vs SuperRoundEngine
            return super()._dispatch_interval(state, block, mask_stack, round_base)
        gate = jnp.asarray(plan.client_gate(self._gate_segments))
        mask_dev = None if mask_stack is None else jnp.asarray(mask_stack)
        return self._gated(state, block, gate, mask_dev)

    def _eval_mask(self, last_mask):
        plan = self._last_plan
        if plan is None or plan.is_trivial:
            return last_mask
        folded = plan.folded[self._gate_segments].astype(np.float32)
        return folded if last_mask is None else last_mask * folded

    def _wall_clock_for(self, round_index: int) -> float:
        return float(self._wall.get(round_index, 0.0))

    def _checkpoint_meta(self, end_round: int, batcher_snapshot: dict) -> dict:
        meta = super()._checkpoint_meta(end_round, batcher_snapshot)
        meta["deadline"] = self.scheduler.state_dict()
        return meta


class CohortEngine:
    """Superround engine for sampled participation: only the cohort is
    device-resident.

    Per cloud interval the loop is: take the prefetched ``(ids, cohort,
    block)`` triple (cohort arrays + batch block already uploading in the
    worker — see ``CohortPrefetcher``), swap the cohort's sticky rows
    (stacked opt_state leaves + EF residual) in from the host
    ``ClientStateStore``, dispatch the donated cohort superround, and write
    the rows back by original client id. Model params and anchors never
    touch the store: control returns only at cloud boundaries, where every
    stacked row equals the fresh broadcast.

    Device footprint is ∝ cohort size C; the (N, …) population exists only
    as host arrays (store + sampler + batcher cursors). With the identity
    cohort (C == N) the trajectory reproduces ``SuperRoundEngine``'s —
    that's the parity anchor the tests pin.

    **Mesh execution** — with a runner mesh the engine swaps in
    ``core.hierfavg.build_sharded_cohort_super_round``: stratified quotas
    make the cohort's slot→edge layout a pure function of (topology,
    cohort_size), so the slot ``ShardPlacement`` is planned once and every
    sampled cohort reuses one executable and one layout. The prefetcher
    permutes/pads blocks into slot order and ``device_put``s per-device
    slices; store rows ride ``gather_placed``/``scatter_placed``; per-shard
    memory is ∝ C / num_shards. Survival masks compose with sampling on
    both paths by masking the cohort's weight columns.

    History/eval/checkpoint cadences are cloud-interval-granular like the
    superround engine; the per-round fallback does not exist here (the
    runner validates cadences up front). Checkpoints save the composite
    ``{"fed": state, "store": store.state()}`` pytree plus the prefetcher's
    paired batcher+sampler snapshots, so a resumed run replays the exact
    same cohorts and batches.
    """

    def __init__(self, runner, *, donate: bool = True, prefetch: bool = True):
        self.runner = runner
        hier = runner.hier_config
        self.k1 = hier.kappa1
        self.k2 = hier.kappa2_effective
        self.prefetch = prefetch
        self.cohort_size = int(hier.participation.cohort_size)
        self.spec = as_hierarchy(runner.topology)
        self.mesh = runner.mesh
        self.placement = None
        self._weights_np = np.asarray(runner.weights, np.float32)
        if self.mesh is not None:
            from repro.core.hierfavg import (
                _cohort_quotas,
                build_sharded_cohort_super_round,
            )
            from repro.dist import sharding as dist_sharding

            self.axis = dist_sharding.client_axis_of(self.mesh)
            num_shards = int(self.mesh.shape[self.axis])
            # the runner plans (and caches) the cohort slot placement during
            # eligibility; replan only for directly constructed engines
            self.placement = getattr(runner, "_cohort_placement", None)
            if self.placement is None or self.placement.num_shards != num_shards:
                from repro.core.hierarchy import plan_cohort_placement

                self.placement = plan_cohort_placement(
                    self.spec, _cohort_quotas(self.spec, self.cohort_size), num_shards
                )
            fn = build_sharded_cohort_super_round(
                runner.loss_fn,
                runner.optimizer,
                runner.topology,
                hier,
                cohort_size=self.cohort_size,
                mesh=self.mesh,
                axis=self.axis,
                placement=self.placement,
                grad_accum=runner.grad_accum,
            )
            self._gather = self.placement.gather_index()
            self._positions = self.placement.positions()
            self._valid = self.placement.valid()
            self._block_sharding = dist_sharding.batch_block_sharding(self.mesh, self.axis)
            self._mask_sharding = dist_sharding.mask_stack_sharding(self.mesh, self.axis)
            from jax.sharding import NamedSharding, PartitionSpec

            self._row_sharding = NamedSharding(self.mesh, PartitionSpec(self.axis))
        else:
            fn = build_cohort_super_round(
                runner.loss_fn,
                runner.optimizer,
                runner.topology,
                hier,
                cohort_size=self.cohort_size,
                grad_accum=runner.grad_accum,
            )
        self._super = jax.jit(fn, donate_argnums=(0,) if donate else ())
        # [(round_base, [alive...], device metrics)] — single-device metrics
        # are {"loss","grad_norm","step"} (κ₂,) scalars; mesh metrics are
        # per-client {"loss","gsq"} (κ₂, κ₁, padded_C) + "step" (κ₂,)
        self._pending: List[Tuple[int, List[int], dict]] = []

    # -- slot-placement layout conversion (mesh path) -----------------------
    @property
    def _state_rows(self) -> int:
        """Leading stacked dim of the live state: C single-device,
        padded_C on the mesh path."""
        return self.cohort_size if self.mesh is None else self.placement.padded_clients

    def _shard_state(self, state: FedState) -> FedState:
        """Canonical (C, ...) cohort state -> slot-placement-ordered padded
        state laid out with the engine's NamedShardings."""
        from repro.dist.sharding import fed_state_shardings

        gather = jnp.asarray(self._gather)
        padded = _map_stacked(state, lambda x: jnp.take(x, gather, axis=0), self.cohort_size)
        shardings = fed_state_shardings(
            self.mesh, self.axis, padded, self.placement.padded_clients
        )
        return jax.device_put(padded, shardings)

    def _unshard_state(self, state: FedState) -> FedState:
        """Slot-placement-ordered padded state -> canonical cohort order on
        the default device (phantom rows dropped)."""
        pos = jnp.asarray(self._positions)
        out = _map_stacked(
            state, lambda x: jnp.take(x, pos, axis=0), self.placement.padded_clients
        )
        return jax.device_put(out, jax.devices()[0])

    def _canonical_params(self, state: FedState) -> PyTree:
        if self.mesh is None:
            return state.params
        pos = jnp.asarray(self._positions)
        return jax.tree_util.tree_map(lambda x: jnp.take(x, pos, axis=0), state.params)

    # ------------------------------------------------------------------
    def _segments_table(self) -> np.ndarray:
        """(depth-1, N) host table of per-client sub-top ancestor ids; the
        prefetcher columns it per cohort."""
        depth = self.spec.depth
        if depth == 1:
            return np.zeros((0, self.spec.num_clients), np.int32)
        return np.stack([np.asarray(self.spec.segments(l), np.int32) for l in range(1, depth)])

    def _masks_for_interval(self, ids: np.ndarray):
        """κ₂ survival draws over the population, columned at the sampled
        ids: participation and failure compose by masking the cohort's
        weight columns. Returns (device mask stack | None, per-round alive
        counts, last round's cohort columns for the boundary eval)."""
        r = self.runner
        masks = [r._mask_for_round() for _ in range(self.k2)]
        if all(m is None for m in masks):
            return None, [self.cohort_size] * self.k2, None
        n = r.topology.num_clients
        stack = np.stack([m if m is not None else np.ones(n, np.float32) for m in masks])
        cols = stack[:, ids]  # (κ₂, C) — the sampled cohort's survival bits
        alive = [int(row.sum()) for row in cols]
        if self.mesh is None:
            return jnp.asarray(cols), alive, cols[-1]
        padded = cols[:, self._gather] * self._valid[None, :].astype(cols.dtype)
        return jax.device_put(jnp.asarray(padded), self._mask_sharding), alive, cols[-1]

    def _load_cohort(self, state: FedState, ids: np.ndarray) -> FedState:
        """Swap the sampled clients' sticky rows in from the host store."""
        store = self.runner.client_store
        if store.is_empty:
            return state
        if self.mesh is None:
            rows = jax.device_put(store.gather(ids))
        else:
            rows = jax.device_put(
                store.gather_placed(ids, self.placement), self._row_sharding
            )
        return replace_sticky_rows(state, rows, self._state_rows)

    def _writeback(self, state: FedState, ids: np.ndarray) -> None:
        """Persist the cohort's post-interval sticky rows by original id.
        The ``device_get`` doubles as this interval's sync point, so the
        store is consistent with ``state`` at every checkpoint boundary."""
        store = self.runner.client_store
        if store.is_empty:
            return
        rows = jax.device_get(sticky_rows(state, self._state_rows))
        if self.mesh is None:
            store.scatter(ids, rows)
        else:
            store.scatter_placed(ids, self.placement, rows)

    def _flush(self, wire_per_step: float) -> None:
        r = self.runner
        for round_base, alive, metrics in self._pending:
            vals = jax.device_get(metrics)
            for j in range(self.k2):
                if self.mesh is None:
                    loss = float(vals["loss"][j])
                    gnorm = float(vals["grad_norm"][j])
                else:
                    loss = float(np.mean(vals["loss"][j][:, self._valid]))
                    gsq = vals["gsq"][j][:, self._valid]  # (κ₁, C)
                    gnorm = float(np.mean(np.sqrt(np.sum(gsq, axis=1))))
                r._record_round(
                    round_base + j,
                    int(vals["step"][j]),
                    loss,
                    gnorm,
                    alive[j],
                    wire_per_step,
                )
        self._pending.clear()

    # ------------------------------------------------------------------
    def run_intervals(
        self, state: FedState, *, start_round: int, num_intervals: int
    ) -> Tuple[FedState, bool]:
        """Run ``num_intervals`` cloud intervals from a cloud-aligned
        ``start_round``. Returns (state, stopped_early)."""
        r = self.runner
        if start_round % self.k2:
            raise ValueError(
                f"cohort engine must start at a cloud boundary: "
                f"start_round={start_round} is not a multiple of {self.k2}"
            )
        r._ensure_client_store(state)
        wire_per_step = r._wire_bytes_per_step(state)
        if self.mesh is not None:
            state = self._shard_state(state)
        stopped = False
        # no failure model -> skip the κ₂ detector calls per interval; an
        # overridden/monkeypatched _mask_for_round is a live seam, so only
        # the stock implementation is hoisted (same idiom as the superround
        # engine above)
        from repro.fed.runner import FederatedRunner

        no_failures = (
            r.failures is None
            and r.stragglers is None
            and getattr(r._mask_for_round, "__func__", None)
            is FederatedRunner._mask_for_round
        )
        static_masks = (None, [self.cohort_size] * self.k2, None)
        prefetcher = CohortPrefetcher(
            r.batcher,
            r._cohort_sampler(),
            segments=self._segments_table(),
            weights=self._weights_np,
            rounds_per_block=self.k2,
            steps_per_round=self.k1,
            num_blocks=num_intervals,
            device=self._block_sharding if self.mesh is not None else None,
            use_thread=self.prefetch,
            placement=self.placement,
            weights_device=self._row_sharding if self.mesh is not None else None,
        )
        try:
            for q in range(num_intervals):
                round_base = start_round + q * self.k2
                (ids, cohort, block), snapshot = prefetcher.get()
                mask_dev, alive, last_mask = (
                    static_masks if no_failures else self._masks_for_interval(ids)
                )
                state = self._load_cohort(state, ids)
                if self.mesh is None:
                    state, metrics = self._super(state, block, cohort, mask_dev)
                else:
                    state, metrics = self._super(state, block, cohort["weights"], mask_dev)
                self._writeback(state, ids)
                self._pending.append((round_base, alive, metrics))

                end_round = round_base + self.k2
                do_eval = (
                    r.eval_fn is not None
                    and r.cfg.eval_every
                    and end_round % r.cfg.eval_every == 0
                )
                do_ckpt = (
                    r.checkpointer is not None
                    and r.cfg.checkpoint_every
                    and end_round % r.cfg.checkpoint_every == 0
                )
                if do_eval or do_ckpt:
                    self._flush(wire_per_step)
                acc = None
                if do_eval:
                    # cohort-weighted cloud model; with C == N this is
                    # bit-identical to the runner's full-population eval
                    mask_last = None if last_mask is None else jnp.asarray(last_mask)
                    cloud0 = aggregation.cloud_model(
                        self._canonical_params(state),
                        jnp.asarray(self._weights_np[ids]),
                        mask_last,
                    )
                    acc = float(r.eval_fn(cloud0))
                    r.history[-1].accuracy = acc
                if do_ckpt:
                    meta = {
                        "round": end_round,
                        "batcher": snapshot["batcher"],
                        "sampler": snapshot["sampler"],
                    }
                    if r.failures is not None:
                        # mask draws for this interval already happened, so
                        # the simulator state resumes at exactly end_round
                        meta["failures"] = r.failures.state_dict()
                    if r.stragglers is not None:
                        meta["stragglers"] = r.stragglers.state_dict()
                    fed = state if self.mesh is None else self._unshard_state(state)
                    save_state = {"fed": fed, "store": r.client_store.state()}
                    r.checkpointer.save(r.history[-1].step, save_state, meta)
                if acc is not None and r.cfg.target_accuracy and acc >= r.cfg.target_accuracy:
                    stopped = True
                    break
            self._flush(wire_per_step)
        finally:
            prefetcher.stop()
        if self.mesh is not None:
            state = self._unshard_state(state)
        return state, stopped
