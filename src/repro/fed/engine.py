"""Zero-copy superround execution engine.

The per-round ``FederatedRunner`` loop pays, every edge interval: a Python
dispatch, a full un-donated copy of the stacked (N, ...) ``FedState``
(params + opt_state + anchor + EF residual ≈ 4 model copies per client), a
blocking host sync for ``step``/``loss``, and a synchronous batch upload.
The paper's protocol only *needs* the host at cloud boundaries — failure
masks, eval, checkpointing, and early stopping are all cloud-interval
decisions — so this engine drives one full cloud interval per dispatch and
removes every per-round host cost:

* **Donated state** — ``core.hierfavg.build_super_round`` is jitted with
  ``donate_argnums=(0,)``: XLA reuses the FedState's buffers for the
  output, so the multi-copy stacked state is updated in place instead of
  round-tripped through fresh HBM allocations each interval.
* **Cloud-interval scan fusion** — κ₂ edge intervals (κ₁ local steps +
  the due per-level aggregation each) run as one ``lax.scan`` with the
  level switch folded in: one dispatch and one executable per cloud
  interval instead of κ₂ of each.
* **Async metrics** — per-round loss / grad-norm / step accumulate on
  device inside the scan and come back stacked; the engine stores the
  device arrays and defers the host fetch to eval/checkpoint boundaries
  (or the end of the run), reconstructing the per-round ``RoundRecord``
  history host-side. No per-round blocking transfer.
* **Device-side batch prefetch** — a ``data.pipeline.SuperBatchPrefetcher``
  worker assembles and ``jax.device_put``s interval r+1's
  (κ₂, κ₁, N, b, ...) block while interval r computes.

Protocol state is bit-exact versus the per-round driver (tests enforce
it; see docs/performance.md for the two 1-ULP XLA:CPU codegen caveats); the
runner transparently falls back to the per-round path when ``eval_every``/
``checkpoint_every`` demand sub-cloud-interval granularity or a mesh
sharding is in play.
"""
from __future__ import annotations

from typing import Any, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.hierfavg import FedState, build_super_round
from repro.data.pipeline import SuperBatchPrefetcher

PyTree = Any


class SuperRoundEngine:
    """Drives a ``FederatedRunner``'s training loop one cloud interval per
    donated dispatch. Constructed (and cached) by the runner; appends the
    same per-round ``RoundRecord`` history the per-round path would."""

    def __init__(self, runner, *, donate: bool = True, prefetch: bool = True):
        self.runner = runner
        hier = runner.hier_config
        self.k1 = hier.kappa1
        self.k2 = hier.kappa2_effective
        self.prefetch = prefetch
        fn = build_super_round(
            runner.loss_fn,
            runner.optimizer,
            runner.topology,
            hier,
            runner.weights,
            grad_accum=runner.grad_accum,
        )
        self._super = jax.jit(fn, donate_argnums=(0,) if donate else ())
        # [(round_base, [alive...], device metrics {"loss","grad_norm","step"})]
        self._pending: List[Tuple[int, List[int], dict]] = []

    # ------------------------------------------------------------------
    def _masks_for_interval(self) -> Tuple[Optional[jnp.ndarray], List[int], Optional[jnp.ndarray]]:
        """κ₂ host-side survival masks, stacked to (κ₂, N) for the scan.

        Returns (mask_stack | None, per-round alive counts, last round's
        mask for the boundary eval). Calls the failure detector once per
        round — the same host sequence as the per-round driver.
        """
        r = self.runner
        n = r.topology.num_clients
        masks = [r._mask_for_round() for _ in range(self.k2)]
        if all(m is None for m in masks):
            return None, [n] * self.k2, None
        stack = np.stack(
            [m if m is not None else np.ones(n, np.float32) for m in masks]
        )
        alive = [int(row.sum()) for row in stack]
        stack_dev = jnp.asarray(stack)
        return stack_dev, alive, stack_dev[-1]

    def _flush(self, wire_per_step: float) -> None:
        """Materialize pending device metrics into RoundRecords (one
        ``device_get`` per outstanding cloud interval) through the runner's
        shared record-assembly helper — both drivers' histories are built
        by the same code."""
        r = self.runner
        for round_base, alive, metrics in self._pending:
            vals = jax.device_get(metrics)
            for j in range(self.k2):
                step = int(vals["step"][j])
                r._record_round(
                    round_base + j, step, float(vals["loss"][j]),
                    float(vals["grad_norm"][j]), alive[j], wire_per_step,
                )
        self._pending.clear()

    # ------------------------------------------------------------------
    def run_intervals(
        self, state: FedState, *, start_round: int, num_intervals: int
    ) -> Tuple[FedState, bool]:
        """Run ``num_intervals`` cloud intervals from a cloud-aligned
        ``start_round``. Returns (state, stopped_early)."""
        r = self.runner
        if start_round % self.k2:
            raise ValueError(
                f"superround engine must start at a cloud boundary: "
                f"start_round={start_round} is not a multiple of {self.k2}"
            )
        wire_per_step = r._wire_bytes_per_step(state)
        stopped = False
        prefetcher = SuperBatchPrefetcher(
            r.batcher,
            rounds_per_block=self.k2,
            steps_per_round=self.k1,
            num_blocks=num_intervals,
            use_thread=self.prefetch,
        )
        try:
            for q in range(num_intervals):
                round_base = start_round + q * self.k2
                block, batcher_snapshot = prefetcher.get()
                mask_stack, alive, last_mask = self._masks_for_interval()
                state, metrics = self._super(state, block, mask_stack)
                self._pending.append((round_base, alive, metrics))

                end_round = round_base + self.k2  # rounds completed so far
                do_eval = (
                    r.eval_fn is not None
                    and r.cfg.eval_every
                    and end_round % r.cfg.eval_every == 0
                )
                do_ckpt = (
                    r.checkpointer is not None
                    and r.cfg.checkpoint_every
                    and end_round % r.cfg.checkpoint_every == 0
                )
                if do_eval or do_ckpt:
                    self._flush(wire_per_step)
                acc = None
                if do_eval:
                    cloud0 = r.eval_model(state.params, last_mask)
                    acc = float(r.eval_fn(cloud0))
                    r.history[-1].accuracy = acc
                if do_ckpt:
                    # the live batcher has prefetched ahead; the snapshot is
                    # the cursor state as of THIS block's cloud boundary
                    meta = {"round": end_round, "batcher": batcher_snapshot}
                    if r.failures is not None:
                        meta["failures"] = r.failures.state_dict()
                    r.checkpointer.save(r.history[-1].step, state, meta)
                if acc is not None and r.cfg.target_accuracy and acc >= r.cfg.target_accuracy:
                    stopped = True
                    break
            self._flush(wire_per_step)
        finally:
            prefetcher.stop()
        return state, stopped
