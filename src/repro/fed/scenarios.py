"""Named scenario registry: the paper's table/figure configurations (and
the beyond-paper robustness/compression ones) as one-line lookups.

    from repro.fed import scenarios
    runner, state = scenarios.get("hierfavg_edge_niid").run_experiment()

Every entry is a factory returning a fresh ``ExperimentSpec`` — tweak any
point of the design space with dotted-path overrides before building:

    spec = scenarios.get("int8_cloud", overrides=["schedule.kappas=30,2"])

``register`` adds project-local scenarios; names must be unique.
"""
from __future__ import annotations

from typing import Callable, Dict, List, Sequence, Tuple

from repro.fed.api import (
    AggregatorSpec,
    CostSpec,
    DataSpec,
    DeadlineSpec,
    ExperimentSpec,
    FailureSpec,
    ModelSpec,
    NetworkSpec,
    ParticipationSpec,
    RunSpec,
    ScheduleSpec,
    TopologySpec,
    TransportSpec,
)

_REGISTRY: Dict[str, Tuple[Callable[[], ExperimentSpec], str]] = {}


def register(name: str, description: str = ""):
    """Decorator: ``@register("my_scenario", "what it shows")`` on a
    zero-arg factory returning an ``ExperimentSpec``."""

    def wrap(fn: Callable[[], ExperimentSpec]):
        if name in _REGISTRY:
            raise ValueError(f"scenario {name!r} is already registered")
        _REGISTRY[name] = (fn, description or (fn.__doc__ or "").strip())
        return fn

    return wrap


def names() -> List[str]:
    return sorted(_REGISTRY)


def get(name: str, overrides: Sequence[str] = ()) -> ExperimentSpec:
    """A fresh spec for a registered scenario, with optional dotted-path
    overrides applied (``overrides=["run.num_rounds=8"]``)."""
    if name not in _REGISTRY:
        raise ValueError(f"unknown scenario {name!r}; choose from {names()}")
    spec = _REGISTRY[name][0]()
    return spec.with_overrides(overrides) if overrides else spec


def describe_all() -> List[Tuple[str, str]]:
    """(name, description) rows for the scenario table."""
    return [(n, _REGISTRY[n][1]) for n in names()]


# ---------------------------------------------------------------------------
# Paper configurations (Section IV / Tables I-II / Figs. 2-4)
# ---------------------------------------------------------------------------

# The benchmark stand-in problem: 50 clients / 5 edges on the synthetic
# 10-class dataset with the paper's MNIST cost constants; lr schedule
# matches benchmarks.common (exponential 0.995/50).
_BENCH_MODEL = ModelSpec(lr=0.15, lr_schedule="exponential")


def _bench(name, *, kappas, partition, rounds, **kw) -> ExperimentSpec:
    return ExperimentSpec(
        name=name,
        topology=TopologySpec(num_edges=5, clients_per_edge=10),
        schedule=ScheduleSpec(kappas=kappas),
        data=DataSpec(partition=partition),
        model=_BENCH_MODEL,
        run=RunSpec(num_rounds=rounds),
        **kw,
    )


@register("quickstart", "20 clients / 4 edges, edge-NIID, kappas=(4,2) — the README example")
def _quickstart() -> ExperimentSpec:
    return ExperimentSpec(
        name="quickstart",
        topology=TopologySpec(num_edges=4, clients_per_edge=5),
        schedule=ScheduleSpec(kappas=(4, 2)),
        data=DataSpec(partition="edge_niid", num_samples=2000),
        model=ModelSpec(lr=0.15),
        run=RunSpec(num_rounds=24, eval_every=4),
    )


@register("favg", "cloud-based FAVG baseline: kappa=(60,1), simple-NIID (paper Fig. 2)")
def _favg() -> ExperimentSpec:
    return _bench("favg", kappas=(60, 1), partition="simple_niid", rounds=10)


@register("hierfavg_iid", "HierFAVG kappas=(6,10) on IID client data (paper Fig. 4 anchor)")
def _hierfavg_iid() -> ExperimentSpec:
    return _bench("hierfavg_iid", kappas=(6, 10), partition="iid", rounds=40)


@register("hierfavg_edge_iid", "HierFAVG kappas=(6,10), edge-IID partition (paper Fig. 4a)")
def _hierfavg_edge_iid() -> ExperimentSpec:
    return _bench("hierfavg_edge_iid", kappas=(6, 10), partition="edge_iid", rounds=40)


@register("hierfavg_edge_niid", "HierFAVG kappas=(6,10), edge-NIID partition (paper Fig. 4b)")
def _hierfavg_edge_niid() -> ExperimentSpec:
    return _bench("hierfavg_edge_niid", kappas=(6, 10), partition="edge_niid", rounds=40)


@register("kappa_sweep_fast", "frequent cloud sync: kappas=(30,2) (paper Table II row)")
def _kappa_sweep_fast() -> ExperimentSpec:
    return _bench("kappa_sweep_fast", kappas=(30, 2), partition="edge_iid", rounds=12)


@register("edge_only", "one edge's 10 clients, no cloud hop — limited data access (paper Fig. 2)")
def _edge_only() -> ExperimentSpec:
    return ExperimentSpec(
        name="edge_only",
        topology=TopologySpec(num_edges=1, clients_per_edge=10),
        schedule=ScheduleSpec(kappas=(6, 1)),
        data=DataSpec(
            partition="simple_niid", class_sep=2.0,
            partition_topology="10,10,10,10,10/5",  # shard for 50, train the first 10
        ),
        model=_BENCH_MODEL,
        cost=CostSpec(workload="mnist", cloud_latency_mult=1.0),
        run=RunSpec(num_rounds=60),
    )


@register("int8_cloud", "int8 cloud hop (blockwise-absmax, Table IIc compressed-wire rows)")
def _int8_cloud() -> ExperimentSpec:
    return _bench(
        "int8_cloud", kappas=(6, 10), partition="edge_iid", rounds=40,
        transport=TransportSpec(levels="identity/int8:256"),
    )


@register("int8_ef_both", "error-feedback int8 on both hops (arXiv:2103.14272 compounding)")
def _int8_ef_both() -> ExperimentSpec:
    return _bench(
        "int8_ef_both", kappas=(6, 10), partition="edge_iid", rounds=40,
        transport=TransportSpec(levels="int8_ef:128/int8_ef:128"),
    )


@register("trimmed_edge", "robust edge sync: 10%-trimmed mean under client failures")
def _trimmed_edge() -> ExperimentSpec:
    return ExperimentSpec(
        name="trimmed_edge",
        topology=TopologySpec(num_edges=4, clients_per_edge=5),
        schedule=ScheduleSpec(kappas=(4, 2)),
        data=DataSpec(partition="edge_niid", num_samples=2000),
        model=ModelSpec(lr=0.15),
        aggregators=AggregatorSpec(levels="trimmed_mean:0.1/weighted_mean"),
        failures=FailureSpec(p_fail=0.05, p_recover=0.5),
        run=RunSpec(num_rounds=16, eval_every=4),
    )


@register("median_cloud", "coordinate-median cloud sync (Byzantine-robust top hop)")
def _median_cloud() -> ExperimentSpec:
    return _bench(
        "median_cloud", kappas=(6, 10), partition="edge_iid", rounds=40,
        aggregators=AggregatorSpec(levels="weighted_mean/coordinate_median"),
    )


@register("trimmed_int8", "robustness x compression: trimmed edge sync over an int8 cloud hop")
def _trimmed_int8() -> ExperimentSpec:
    return _bench(
        "trimmed_int8", kappas=(6, 10), partition="edge_iid", rounds=40,
        aggregators=AggregatorSpec(levels="trimmed_mean:0.1/weighted_mean"),
        transport=TransportSpec(levels="identity/int8:256"),
    )


@register("ragged_edges", "ragged 16/12/10/7/5-client edges, kappas=(6,10) (docs/hierarchy.md)")
def _ragged_edges() -> ExperimentSpec:
    return ExperimentSpec(
        name="ragged_edges",
        topology=TopologySpec(fanouts="16,12,10,7,5/5"),
        schedule=ScheduleSpec(kappas=(6, 10)),
        # simple_niid: edge_iid needs <= num_classes clients per edge (16 > 10)
        data=DataSpec(partition="simple_niid"),
        model=_BENCH_MODEL,
        run=RunSpec(num_rounds=40),
    )


@register("three_level", "client-edge-region-cloud tree, kappas=(6,5,2)")
def _three_level() -> ExperimentSpec:
    return ExperimentSpec(
        name="three_level",
        topology=TopologySpec(fanouts="10,10,10,10,10/3,2/2"),
        schedule=ScheduleSpec(kappas=(6, 5, 2)),
        data=DataSpec(partition="edge_iid"),
        model=_BENCH_MODEL,
        run=RunSpec(num_rounds=40),
    )


@register("lm_edge_niid", "decoder-only 10M LM, 8 clients / 2 edges, label-skewed corpus")
def _lm_edge_niid() -> ExperimentSpec:
    return ExperimentSpec(
        name="lm_edge_niid",
        topology=TopologySpec(num_edges=2, clients_per_edge=4),
        schedule=ScheduleSpec(kappas=(4, 2)),
        data=DataSpec(
            dataset="tokens", partition="edge_niid", num_samples=512,
            num_classes=8, classes_per_edge=4, seq_len=64, vocab=512,
        ),
        model=ModelSpec(
            arch="lm-10m", optimizer="adam", lr=3e-4,
            lr_schedule="warmup_cosine", warmup_steps=20,
        ),
        cost=CostSpec(workload="none"),
        run=RunSpec(num_rounds=24, eval_every=0),
    )


@register(
    "n1m_cohort4096",
    "1M virtual clients / 1000 edges, stratified 4096-client cohorts — "
    "population-scale streaming participation (device state ∝ cohort)",
)
def _n1m_cohort4096() -> ExperimentSpec:
    return ExperimentSpec(
        name="n1m_cohort4096",
        topology=TopologySpec(num_edges=1000, clients_per_edge=1000),
        schedule=ScheduleSpec(kappas=(4, 2)),
        data=DataSpec(
            partition="iid", num_samples=20000, batch_size=8,
            virtual_clients=1_000_000, samples_per_client=64,
        ),
        model=ModelSpec(lr=0.1),
        participation=ParticipationSpec(cohort_size=4096, sampler="stratified"),
        cost=CostSpec(workload="none"),
        run=RunSpec(num_rounds=8, eval_every=0),
    )


# ---------------------------------------------------------------------------
# Simulation scenarios (repro.sim; benchmarks/round_time_sim.py)
# ---------------------------------------------------------------------------

@register(
    "congested_backhaul",
    "sim: 10% of edges on an 8x-slower backhaul + lognormal link jitter — "
    "p99 round time vs the analytic point estimate",
)
def _congested_backhaul() -> ExperimentSpec:
    return _bench(
        "congested_backhaul", kappas=(6, 10), partition="edge_iid", rounds=40,
        network=NetworkSpec(
            edge_backhaul="mixture:0.9@1,0.1@8",
            backhaul_jitter="lognormal:0.25",
            link_jitter="lognormal:0.15",
            seed=11,
        ),
    )


@register(
    "hetero_clients_assoc",
    "sim: heterogeneous client speeds + a congested uplink band with "
    "contention — the association-optimizer target (HFEL)",
)
def _hetero_clients_assoc() -> ExperimentSpec:
    return _bench(
        "hetero_clients_assoc", kappas=(6, 10), partition="edge_iid", rounds=40,
        network=NetworkSpec(
            client_speed="lognormal:0.35",
            edge_uplink="mixture:0.6@1,0.4@4",
            link_jitter="lognormal:0.2",
            contention=True,
            seed=3,
        ),
    )


@register(
    "straggler_tail",
    "sim: deadline-based straggler exclusion priced by the replay from the "
    "same StragglerModel distribution the runner masks with",
)
def _straggler_tail() -> ExperimentSpec:
    return _bench(
        "straggler_tail", kappas=(6, 10), partition="edge_iid", rounds=40,
        failures=FailureSpec(straggler_sigma=0.4, straggler_mean_s=1.0, seed=5),
        network=NetworkSpec(
            compute_jitter="lognormal:0.4", jitter_granularity="interval", seed=5
        ),
    )


# ---------------------------------------------------------------------------
# Semi-synchronous deadline scenarios (fed.deadline; docs/robustness.md)
# ---------------------------------------------------------------------------

@register(
    "deadline_straggler",
    "semi-sync: 60% quorum over the straggler tail's edge cadences with "
    "mid-round edge dropout — late edges carry, dead edges are reweighted",
)
def _deadline_straggler() -> ExperimentSpec:
    return _bench(
        "deadline_straggler", kappas=(6, 10), partition="edge_iid", rounds=40,
        failures=FailureSpec(straggler_sigma=0.4, straggler_mean_s=1.0, seed=5),
        deadline=DeadlineSpec(
            enabled=True, quorum=0.6, max_staleness=3,
            staleness="poly:0.5", edge_drop_rate=0.05, retry_limit=1, seed=5,
        ),
    )


@register(
    "fedbuff_k4",
    "semi-sync: FedBuff-style buffered aggregation — the cloud folds the "
    "first K=4 edge arrivals per round under heterogeneous edge speeds",
)
def _fedbuff_k4() -> ExperimentSpec:
    return _bench(
        "fedbuff_k4", kappas=(6, 10), partition="edge_iid", rounds=40,
        deadline=DeadlineSpec(
            enabled=True, buffer_size=4, max_staleness=3,
            staleness="poly:0.5", edge_speed="lognormal:0.5", seed=7,
        ),
    )


@register(
    "stale_decay",
    "semi-sync: 80% quorum with exponential staleness decay exp:0.7 — "
    "stragglers' carried updates fold at geometrically shrinking weight",
)
def _stale_decay() -> ExperimentSpec:
    return _bench(
        "stale_decay", kappas=(6, 10), partition="edge_iid", rounds=40,
        deadline=DeadlineSpec(
            enabled=True, quorum=0.8, max_staleness=4,
            staleness="exp:0.7", edge_speed="lognormal:0.4", seed=9,
        ),
    )


__all__ = ["register", "get", "names", "describe_all"]
