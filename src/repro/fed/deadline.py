"""Semi-synchronous cloud rounds: deadlines, quorums, and staleness.

The synchronous HierFAVG barrier stalls every cloud round on the slowest
edge — one straggling subtree degrades the whole system, the exact
heterogeneous-resource regime HFEL (arXiv 2002.11343) targets. This module
owns the host-side half of the semi-synchronous engine:

* ``StalenessPolicy`` — how much a late edge's update counts once it lands
  (``constant`` | ``poly:a`` → (1+s)^-a | ``exp:a`` → e^{-a·s}); a new
  config axis alongside ``AggregatorSpec``.
* ``EdgeCadenceModel`` — per-edge cloud-interval durations: a persistent
  speed factor per edge (drawn from a ``sim.distributions`` grammar string,
  or reduced from a ``StragglerModel``'s slowness array) times per-round
  jitter.
* ``SemiSyncScheduler`` — the event queue. Each cloud round it advances
  every edge's upload-finish time, closes the round when a quorum / FedBuff
  buffer fills or a timeout fires (never before the first arrival, never
  past the ``max_staleness`` force-wait bound), injects mid-round upload
  drops with bounded retry, and returns a :class:`RoundPlan` telling the
  engine which edges fold into the cloud aggregate and at what weight.

Everything here is pure host numpy with JSON-safe ``state_dict`` /
``load_state_dict`` (PCG64 state, same contract as the cohort samplers and
``sim.distributions``), so an interrupted semi-synchronous run resumes on
the exact same event sequence.

Semantics of a :class:`RoundPlan` (consumed by ``fed.engine.DeadlineEngine``
via ``core.hierfavg.build_deadline_super_round``):

* ``folded`` edges contribute their upload to the cloud aggregate at weight
  ``weights`` (arrival × staleness decay) and receive the new cloud model;
  their next interval starts at the round's close.
* late edges (in flight past the close) keep computing; their upload is
  *carried into the next round* rather than dropped, and they miss the
  broadcast — their clients keep the edge-synced model (staleness + 1).
* dropped uploads (fault injection) retry at the next round start up to
  ``retry_limit`` times, then the edge abandons the stale upload and
  recomputes — the aggregation renormalizes over whoever folded
  (skip-and-reweight; the masked weighted mean does this for free).

Compute-lockstep approximation: every edge executes the same κ₂·κ₁ device
steps per dispatched interval; heterogeneity enters through *when* the
cloud folds an edge in (arrival times, staleness decay, frozen late
subtrees), not through differing step counts. ``docs/robustness.md``
spells out what this does and does not model.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, Dict, NamedTuple, Optional

import numpy as np

from repro.sim.distributions import Distribution, parse_distribution

__all__ = [
    "StalenessPolicy",
    "parse_staleness",
    "EdgeCadenceModel",
    "RoundPlan",
    "SemiSyncScheduler",
]


# ---------------------------------------------------------------------------
# Staleness policies
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class StalenessPolicy:
    """Weight multiplier for an update that is ``s`` cloud rounds stale.

    All policies are exactly 1.0 at s=0 (an on-time update is never
    down-weighted — the parity contract with the synchronous engine depends
    on this being exact, and it is: ``(1+0)**-a == exp(-a*0) == 1.0``).
    """

    kind: str = "constant"
    rate: float = 0.0

    def weights(self, staleness: np.ndarray) -> np.ndarray:
        s = np.asarray(staleness, np.float64)
        if self.kind == "constant":
            return np.ones_like(s)
        if self.kind == "poly":
            return (1.0 + s) ** (-self.rate)
        return np.exp(-self.rate * s)

    @property
    def is_trivial(self) -> bool:
        return self.kind == "constant" or self.rate == 0.0

    def describe(self) -> str:
        return self.kind if self.kind == "constant" else f"{self.kind}:{self.rate:g}"


def parse_staleness(text: str) -> StalenessPolicy:
    """Parse the staleness grammar: ``constant`` | ``poly:A`` | ``exp:A``."""
    name, _, args = text.strip().partition(":")
    if name == "constant":
        if args:
            raise ValueError(f"bad staleness {text!r}: constant takes no rate")
        return StalenessPolicy("constant", 0.0)
    if name in ("poly", "exp"):
        try:
            rate = float(args)
        except ValueError:
            raise ValueError(f"bad staleness {text!r}: {name} needs a numeric rate") from None
        if rate < 0:
            raise ValueError(f"bad staleness {text!r}: rate must be >= 0")
        return StalenessPolicy(name, rate)
    raise ValueError(
        f"unknown staleness policy {text!r}; grammar: constant | poly:A | exp:A"
    )


# ---------------------------------------------------------------------------
# Edge cadence
# ---------------------------------------------------------------------------


class EdgeCadenceModel:
    """Per-edge cloud-interval durations (simulated seconds).

    ``base_interval_s`` is the nominal duration of ONE edge interval (κ₁
    local steps + the client↔edge exchange); each edge multiplies it by a
    persistent ``slowness`` factor (heterogeneous provisioning) and a fresh
    jitter draw per call. The speed distribution is consumed once at
    construction; only the jitter stream stays live (and is checkpointed).
    """

    def __init__(
        self,
        num_edges: int,
        base_interval_s: float = 1.0,
        *,
        speed: str = "det",
        jitter: str = "det",
        seed: int = 0,
        slowness: Optional[np.ndarray] = None,
    ):
        if num_edges < 1:
            raise ValueError(f"num_edges must be >= 1, got {num_edges}")
        if base_interval_s <= 0:
            raise ValueError(f"base_interval_s must be positive, got {base_interval_s}")
        self.num_edges = int(num_edges)
        self.base_interval_s = float(base_interval_s)
        if slowness is not None:
            self.slowness = np.asarray(slowness, np.float64).copy()
            if self.slowness.shape != (self.num_edges,):
                raise ValueError(
                    f"slowness shape {self.slowness.shape} != ({self.num_edges},)"
                )
        else:
            self.slowness = parse_distribution(speed, seed=(seed, 1)).sample(self.num_edges)
        self._jitter: Distribution = parse_distribution(jitter, seed=(seed, 2))

    @classmethod
    def from_stragglers(
        cls,
        model,
        segments: np.ndarray,
        num_edges: int,
        kappa1: int,
        *,
        jitter: str = "det",
        seed: int = 0,
    ) -> "EdgeCadenceModel":
        """Derive edge cadences from a ``StragglerModel``: an edge's interval
        completes when its slowest client does, so the edge slowness is the
        per-edge max of the model's persistent per-client slowness. Reads
        the ``slowness`` array only — never the model's RNG stream, which
        drives the survival-mask draws and must not shift.
        """
        seg = np.asarray(segments)
        slow = np.zeros(num_edges, np.float64)
        np.maximum.at(slow, seg, np.asarray(model.slowness, np.float64))
        slow[slow == 0.0] = 1.0  # edge with no clients: nominal speed
        return cls(
            num_edges,
            kappa1 * model.mean_step_s,
            jitter=jitter,
            seed=seed,
            slowness=slow,
        )

    def interval_durations(self) -> np.ndarray:
        """(E,) simulated seconds for each edge's next edge interval.
        Consumes one jitter draw per edge."""
        return self.base_interval_s * self.slowness * self._jitter.sample(self.num_edges)

    def state_dict(self) -> Dict[str, Any]:
        return {"slowness": self.slowness.copy(), "jitter": self._jitter.state_dict()}

    def load_state_dict(self, state: Dict[str, Any]) -> None:
        self.slowness = np.asarray(state["slowness"], np.float64).copy()
        self._jitter.load_state_dict(state["jitter"])


# ---------------------------------------------------------------------------
# The scheduler
# ---------------------------------------------------------------------------


class RoundPlan(NamedTuple):
    """What the cloud does at one semi-synchronous round close."""

    start: float  # simulated clock when the round opened
    close: float  # simulated clock when the cloud closed the round
    arrivals: np.ndarray  # (E,) upload-ready times (in-flight finish times)
    folded: np.ndarray  # (E,) bool: upload aggregated into this cloud model
    staleness: np.ndarray  # (E,) int: rounds since each edge last folded
    weights: np.ndarray  # (E,) float: arrival x staleness multiplier (0 if not folded)
    dropped: np.ndarray  # (E,) bool: upload arrived but was lost (fault injection)
    dead: np.ndarray  # (E,) bool: edge had no live clients this round (outage)

    @property
    def is_trivial(self) -> bool:
        """True when this round is indistinguishable from the synchronous
        barrier: every edge folded, nothing dropped, all weights exactly 1."""
        return bool(
            self.folded.all() and not self.dropped.any() and np.all(self.weights == 1.0)
        )

    def client_gate(self, segments: np.ndarray) -> np.ndarray:
        """(N,) float32 per-client cloud-aggregation gate: the edge weight
        broadcast to each client (0 for late/dropped/dead edges)."""
        return self.weights[np.asarray(segments)].astype(np.float32)


class SemiSyncScheduler:
    """Event-driven cloud-round bookkeeping over per-edge upload times.

    Round close rule, per :meth:`next_round` call:

    1. every idle edge (just folded, has the current cloud model) starts a
       fresh interval of ``intervals_per_round`` edge intervals at the
       current clock; in-flight edges keep their finish times;
    2. the K-th live arrival closes the round, where K is ``buffer_size``
       (FedBuff) if set, else ``ceil(quorum * live_edges)``;
    3. a positive ``timeout_s`` caps the close at ``start + timeout_s`` but
       never before the first live arrival (the cloud always folds at
       least one upload);
    4. any live edge at ``staleness >= max_staleness`` is force-waited —
       bounded staleness is a hard guarantee, not a preference;
    5. each arrived upload is lost with probability ``edge_drop_rate``;
       lost uploads retry at the next round start up to ``retry_limit``
       times, then the edge abandons the upload and recomputes.

    ``dead`` edges (outage: no live clients, see
    ``fed.failures.compose_masks``) are excluded from the quorum
    denominator and from the force-wait bound — a dead edge cannot stall
    the cloud, unlike a merely *late* one whose upload is still coming.
    """

    def __init__(
        self,
        cadence: EdgeCadenceModel,
        *,
        intervals_per_round: int = 1,
        quorum: float = 1.0,
        timeout_s: float = 0.0,
        buffer_size: int = 0,
        max_staleness: int = 2,
        staleness: str = "constant",
        edge_drop_rate: float = 0.0,
        retry_limit: int = 1,
        seed: int = 0,
    ):
        if not 0.0 < quorum <= 1.0:
            raise ValueError(f"quorum must be in (0, 1], got {quorum}")
        if timeout_s < 0:
            raise ValueError(f"timeout_s must be >= 0, got {timeout_s}")
        if buffer_size < 0 or buffer_size > cadence.num_edges:
            raise ValueError(
                f"buffer_size must be in 0..{cadence.num_edges}, got {buffer_size}"
            )
        if max_staleness < 0:
            raise ValueError(f"max_staleness must be >= 0, got {max_staleness}")
        if not 0.0 <= edge_drop_rate < 1.0:
            raise ValueError(f"edge_drop_rate must be in [0, 1), got {edge_drop_rate}")
        if retry_limit < 0:
            raise ValueError(f"retry_limit must be >= 0, got {retry_limit}")
        if intervals_per_round < 1:
            raise ValueError(f"intervals_per_round must be >= 1, got {intervals_per_round}")
        self.cadence = cadence
        self.intervals_per_round = int(intervals_per_round)
        self.quorum = float(quorum)
        self.timeout_s = float(timeout_s)
        self.buffer_size = int(buffer_size)
        self.max_staleness = int(max_staleness)
        self.policy = parse_staleness(staleness)
        self.edge_drop_rate = float(edge_drop_rate)
        self.retry_limit = int(retry_limit)
        self.seed = seed
        e = cadence.num_edges
        self.clock = 0.0
        self.rounds_closed = 0
        self.finish = np.zeros(e, np.float64)
        self.in_flight = np.zeros(e, bool)
        self.staleness = np.zeros(e, np.int64)
        self.retry = np.zeros(e, np.int64)
        self._rng = np.random.default_rng(seed)

    @property
    def num_edges(self) -> int:
        return self.cadence.num_edges

    @property
    def is_barrier(self) -> bool:
        """True when the configuration can never leave an edge behind:
        full quorum, no timeout, no buffer, no fault injection."""
        return (
            self.quorum == 1.0
            and self.timeout_s == 0.0
            and self.buffer_size == 0
            and self.edge_drop_rate == 0.0
        )

    # ------------------------------------------------------------------
    def next_round(self, dead: Optional[np.ndarray] = None) -> RoundPlan:
        """Advance the event queue by one cloud round and return its plan.
        ``dead``: optional (E,) truthy marks for edges with no live clients
        this boundary (from the outage channel of ``compose_masks``)."""
        e = self.num_edges
        start = self.clock
        # one duration draw per edge per round (jitter at round granularity)
        dur = self.intervals_per_round * self.cadence.interval_durations()
        starting = ~self.in_flight
        self.finish = np.where(starting, start + dur, self.finish)
        self.in_flight = np.ones(e, bool)
        arrivals = self.finish.copy()

        dead_e = np.zeros(e, bool) if dead is None else np.asarray(dead).astype(bool)
        live = ~dead_e
        if not live.any():
            # total outage: nothing to wait for, nothing folds
            close = start
            arrived = np.zeros(e, bool)
        else:
            order = np.sort(arrivals[live])
            k = self.buffer_size if self.buffer_size > 0 else math.ceil(self.quorum * int(live.sum()))
            k = min(max(k, 1), int(live.sum()))
            close = float(order[k - 1])
            if self.timeout_s > 0.0:
                close = max(min(close, start + self.timeout_s), float(order[0]))
            must = live & (self.staleness >= self.max_staleness)
            if must.any():
                close = max(close, float(arrivals[must].max()))
            arrived = live & (arrivals <= close)

        # fault injection: each arrived upload is lost independently
        drop_u = self._rng.random(e)
        dropped = arrived & (drop_u < self.edge_drop_rate)
        folded = arrived & ~dropped

        stale_used = self.staleness.copy()
        weights = np.where(folded, self.policy.weights(stale_used), 0.0)

        # post-round state: folded edges received the broadcast and restart
        # at the close; retryable drops re-send the buffered upload at the
        # next round start; exhausted drops abandon it and recompute.
        self.in_flight = self.in_flight & ~folded
        retryable = dropped & (self.retry < self.retry_limit)
        exhausted = dropped & ~retryable
        self.finish = np.where(retryable, close, self.finish)
        self.retry = np.where(retryable, self.retry + 1, self.retry)
        self.retry[folded | exhausted] = 0
        self.in_flight = self.in_flight & ~exhausted
        self.staleness = np.where(folded, 0, self.staleness + 1)
        self.clock = close
        self.rounds_closed += 1
        return RoundPlan(
            start=start,
            close=close,
            arrivals=arrivals,
            folded=folded,
            staleness=stale_used,
            weights=weights,
            dropped=dropped,
            dead=dead_e,
        )

    # ------------------------------------------------------------------
    def state_dict(self) -> Dict[str, Any]:
        return {
            "clock": self.clock,
            "rounds_closed": self.rounds_closed,
            "finish": self.finish.copy(),
            "in_flight": self.in_flight.copy(),
            "staleness": self.staleness.copy(),
            "retry": self.retry.copy(),
            "rng": self._rng.bit_generator.state,
            "cadence": self.cadence.state_dict(),
        }

    def load_state_dict(self, state: Dict[str, Any]) -> None:
        self.clock = float(state["clock"])
        self.rounds_closed = int(state["rounds_closed"])
        self.finish = np.asarray(state["finish"], np.float64).copy()
        self.in_flight = np.asarray(state["in_flight"]).astype(bool).copy()
        self.staleness = np.asarray(state["staleness"], np.int64).copy()
        self.retry = np.asarray(state["retry"], np.int64).copy()
        self._rng.bit_generator.state = state["rng"]
        self.cadence.load_state_dict(state["cadence"])
