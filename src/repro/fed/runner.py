"""Federated round runner: the host-side training orchestrator.

Drives ``core.hierfavg`` edge-interval by edge-interval:

    for round r:                       # r-th edge interval (κ₁ local steps)
        mask  = failure detector + straggler deadline      (host)
        state = hier_round(state, batches_r, r, mask)      (device, jitted)
        if r % kappa2 == kappa2-1: cloud boundary          (inside hier_round)
        eval / checkpoint / cost accounting                (host)

This is the deployable loop: one executable for the whole run, host logic
only at aggregation boundaries (the natural synchronization points of the
paper's protocol). Metrics include the paper's T/E accounting (cost_model)
so experiments read time-to-accuracy directly off the run log.

By default (``RunnerConfig.engine="auto"``) every whole cloud interval is
delegated to the zero-copy superround engine (``fed.engine``): one donated
dispatch per κ₂ edge intervals, device-side batch prefetch, and async
metrics — bit-exact versus this per-round loop, which remains the fallback
whenever ``eval_every``/``checkpoint_every`` demand finer granularity than
a cloud interval. With a device mesh (``mesh=`` or ``RunnerConfig.mesh``)
the engine runs client-sharded over the mesh's ``"clients"`` axis — edge
syncs device-local, one grouped psum per cloud interval — rather than
falling back to the per-round loop; only a schedule the sharded lowering
cannot express (``core.hierfavg.sharding_incompatibility``) or an explicit
``state_shardings`` pytree keeps whole cloud intervals on the per-round
path.

When ``hier_config.transport`` declares per-level link codecs, the cost
accounting automatically switches to the compressed wire: T/E use
``WorkloadCosts.with_bits`` and each round records the cumulative uplink
bytes per client (``wire_mb``) from the ``dist.collectives`` traffic model
at the transport's per-level bits-per-parameter.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import cost_model as cm
from repro.core.hierarchy import as_hierarchy
from repro.core.hierfavg import (
    FedState,
    HierFAVGConfig,
    Topology,
    build_hier_round,
    init_state,
)
from repro.dist import collectives
from repro.fed.failures import FailureSimulator, StragglerModel, compose_masks

PyTree = Any


@dataclasses.dataclass
class RunnerConfig:
    num_rounds: int  # edge intervals to run (= K / kappa1)
    eval_every: int = 0  # rounds between evals (0 = never)
    checkpoint_every: int = 0  # rounds between checkpoints (0 = never)
    target_accuracy: float = 0.0  # stop early when reached (0 = never)
    straggler_deadline_pct: float = 95.0
    # "auto": superround engine (fed.engine) for every whole cloud interval
    # whose boundaries satisfy eval/checkpoint granularity, per-round
    # otherwise; "superround" forces the engine (raises if ineligible);
    # "megakernel" is the opt-in client-blocked fast path (falls back to the
    # scan-fused superround with a named reason when the schedule is not
    # block-separable; see core.hierfavg.megakernel_incompatibility);
    # "per_round" forces the legacy one-dispatch-per-edge-interval loop.
    engine: str = "auto"
    # device mesh for client-sharded execution (jax.sharding.Mesh with a
    # "clients" axis; see dist.sharding.client_mesh). The FederatedRunner
    # constructor's mesh= argument wins when both are given.
    mesh: Any = None

    def __post_init__(self):
        # fail at construction, not on the first run() call
        if self.engine not in ("auto", "superround", "megakernel", "per_round"):
            raise ValueError(
                f"RunnerConfig.engine must be auto|superround|megakernel|per_round, "
                f"got {self.engine!r}"
            )


@dataclasses.dataclass
class RoundRecord:
    round: int
    step: int
    loss: float
    mask_alive: int
    sim_time_s: float
    sim_energy_j: float
    accuracy: Optional[float] = None
    wire_mb: float = 0.0  # cumulative uplink MB/client on the compressed wire
    grad_norm: Optional[float] = None  # mean stacked-gradient norm over the round
    # event-clock seconds at the round's close under the deadline engine
    # (0.0 for the synchronous drivers, which have no event clock)
    wall_clock_s: float = 0.0


class FederatedRunner:
    def __init__(
        self,
        *,
        loss_fn,
        optimizer,
        topology: Topology,  # FedTopology or a ragged HierarchySpec
        hier_config: HierFAVGConfig,
        data_sizes: np.ndarray,
        batcher,  # FederatedBatcher
        runner_config: RunnerConfig,
        eval_fn: Optional[Callable[[PyTree], float]] = None,
        costs: Optional[cm.WorkloadCosts] = None,
        failures: Optional[FailureSimulator] = None,
        stragglers: Optional[StragglerModel] = None,
        deadline=None,  # fed.deadline.SemiSyncScheduler (semi-synchronous cloud)
        checkpointer=None,  # checkpoint.manager.CheckpointManager
        grad_accum: int = 1,
        mesh=None,
        state_shardings=None,
    ):
        self.loss_fn = loss_fn
        self.optimizer = optimizer
        self.topology = topology
        self.hier_config = hier_config
        self.weights = jnp.asarray(data_sizes, jnp.float32)
        self.batcher = batcher
        self.cfg = runner_config
        self.eval_fn = eval_fn
        self.costs = costs
        self.transport = getattr(hier_config, "transport", None)
        if self.costs is not None and self.transport is not None:
            # T/E accounting on the compressed wire: edge hop = level 1,
            # cloud hop = top level (matches kappa2_effective's 2-level view)
            self.costs = self.costs.with_bits(
                self.transport.bits_per_param(1),
                self.transport.bits_per_param(self.transport.depth),
            )
        self.failures = failures
        self.stragglers = stragglers
        self.deadline = deadline
        # the most recent mask composition's channels (dead vs late) — the
        # deadline engine reads the dead channel to skip-and-reweight outaged
        # edges without force-waiting on them
        self._last_mask_parts = None
        self.checkpointer = checkpointer
        self.grad_accum = grad_accum
        self.mesh = mesh if mesh is not None else runner_config.mesh
        self._state_shardings = state_shardings
        self._mesh_reason: Optional[str] = None
        self._megakernel_reason: Optional[str] = None
        # the edge-aligned placement is a pure function of (topology, mesh):
        # plan it once and share it between eligibility checks and the engine
        self._placement = None
        self._placement_error: Optional[str] = None
        # the cohort *slot* placement (sampled participation + mesh) is
        # likewise pure in (topology, mesh, cohort_size): planned once
        self._cohort_placement = None
        self._cohort_placement_error: Optional[str] = None
        self._engine = None  # lazily built (and cached) SuperRoundEngine / CohortEngine
        # sampled participation: the active ParticipationSpec (or None), the
        # host-side ClientStateStore (built lazily from the first state seen,
        # which fixes the sticky-row template), and the cached cohort sampler
        self.participation = (
            hier_config.participation if getattr(hier_config, "participation_active", False) else None
        )
        self.client_store = None
        self._sampler = None

        if self.participation is not None:
            # the per-round lowering is never driven under sampled
            # participation (no full-population state exists to feed it)
            self._round = None
        else:
            round_fn = build_hier_round(
                loss_fn, optimizer, topology, hier_config, self.weights, grad_accum=grad_accum
            )
            if self.mesh is not None and state_shardings is not None:
                self._round = jax.jit(round_fn, in_shardings=(state_shardings, None, None, None),
                                      out_shardings=(state_shardings, None))
            else:
                self._round = jax.jit(round_fn)
        self.history: List[RoundRecord] = []

    # ------------------------------------------------------------------
    def init(self, rng: jax.Array, params: PyTree) -> FedState:
        if self.participation is not None:
            from repro.core.hierfavg import init_cohort_state

            return init_cohort_state(
                rng, params, self.optimizer, self.hier_config, self.participation.cohort_size
            )
        return init_state(rng, params, self.optimizer, self.topology, self.hier_config)

    def restore_or_init(self, rng: jax.Array, params: PyTree) -> tuple:
        """(state, start_round). Resumes from the latest checkpoint if any."""
        state = self.init(rng, params)
        if self.checkpointer is None:
            return state, 0
        if self.participation is not None:
            # cohort checkpoints are the composite {"fed", "store"} pytree,
            # with batcher + cohort-sampler snapshots in the metadata
            store = self._ensure_client_store(state)
            restored = self.checkpointer.restore_latest({"fed": state, "store": store.state()})
            if restored is not None:
                payload, meta = restored
                store.load(payload["store"])
                if "batcher" in meta:
                    self.batcher.load_state_dict(meta["batcher"])
                if "sampler" in meta:
                    self._cohort_sampler().load_state_dict(meta["sampler"])
                if self.failures is not None and "failures" in meta:
                    self.failures.load_state_dict(meta["failures"])
                if self.stragglers is not None and "stragglers" in meta:
                    self.stragglers.load_state_dict(meta["stragglers"])
                return payload["fed"], int(meta.get("round", 0))
            return state, 0
        restored = self.checkpointer.restore_latest(state)
        if restored is not None:
            state, meta = restored
            if "batcher" in meta:
                self.batcher.load_state_dict(meta["batcher"])
            if self.failures is not None and "failures" in meta:
                self.failures.load_state_dict(meta["failures"])
            if self.stragglers is not None and "stragglers" in meta:
                self.stragglers.load_state_dict(meta["stragglers"])
            if self.deadline is not None and "deadline" in meta:
                # the scheduler's event queue + staleness state resume the
                # identical event sequence an uninterrupted run would produce
                self.deadline.load_state_dict(meta["deadline"])
            return state, int(meta.get("round", 0))
        return state, 0

    # -- sampled-participation runtime (shared by engine and resume path) ----
    def _cohort_sampler(self):
        """The run's single cohort sampler (cached: its RNG stream IS the
        cohort sequence, so everyone must share one instance)."""
        if self._sampler is None:
            self._sampler = self.participation.build_sampler(as_hierarchy(self.topology))
        return self._sampler

    def _ensure_client_store(self, state: FedState):
        """Build (once) the host store from the cohort state's sticky-row
        template — stacked opt_state leaves + EF residual rows."""
        if self.client_store is None:
            from repro.fed.client_store import ClientStateStore, sticky_rows

            rows = sticky_rows(state, int(self.participation.cohort_size))
            self.client_store = ClientStateStore.from_rows(
                self.topology.num_clients, jax.device_get(rows)
            )
        return self.client_store

    # ------------------------------------------------------------------
    def _mask_for_round(self) -> Optional[np.ndarray]:
        """Per-round survival mask; the combined mask is bit-identical to the
        historical ``combine_masks`` of every model, but the composition keeps
        the *dead* (outage: no contribution) and *late* (deadline miss: the
        compute happened, the upload is deferred) channels apart on
        ``_last_mask_parts`` for the deadline engine."""
        dead = []
        late = []
        if self.failures is not None:
            dead.append(self.failures.step())
        if self.stragglers is not None:
            m, _ = self.stragglers.survivors(
                self.hier_config.kappa1, None
            )
            late.append(m)
        parts = compose_masks(dead=dead, late=late)
        self._last_mask_parts = parts
        return parts.effective

    def eval_model(self, params: PyTree, mask: Optional[jnp.ndarray]) -> PyTree:
        """The single cloud model the eval/serving path should score: the
        weighted mean of client models — or, when the schedule configures a
        non-default top-level aggregator (``AggregatorSpec``), that robust
        statistic, so robust experiments are judged by the model the cloud
        would actually publish."""
        cfg = self.hier_config
        if getattr(cfg, "aggregators_active", False):
            top = cfg.aggregators.aggregator(cfg.num_levels)
            if not top.is_default:
                spec = as_hierarchy(self.topology)
                agg = top(params, self.weights, spec, spec.depth, mask)
                return jax.tree_util.tree_map(lambda x: x[0], agg)
        from repro.core import aggregation

        # single-model reduction: no (N, ...) broadcast allocation
        return aggregation.cloud_model(params, self.weights, mask)

    def _wire_bytes_per_step(self, state: FedState) -> float:
        """Summed per-level uplink bytes per local step for one client
        (bottleneck link, amortized by each level's interval), at the
        transport's per-level bits-per-parameter."""
        spec = as_hierarchy(self.topology)
        per_client_bytes = sum(
            leaf.size // leaf.shape[0] * leaf.dtype.itemsize
            for leaf in jax.tree_util.tree_leaves(state.params)
        )
        bits = self.transport.bits_vector() if self.transport is not None else None
        traffic = collectives.hierarchy_traffic_per_step(
            float(per_client_bytes), spec, self.hier_config.kappa_vector,
            bits_per_param=bits,
        )
        return float(sum(traffic))

    def _record_round(
        self,
        round_index: int,
        step: int,
        loss: float,
        grad_norm: float,
        mask_alive: int,
        wire_per_step: float,
        accuracy: Optional[float] = None,
        wall_clock_s: float = 0.0,
    ) -> RoundRecord:
        """Assemble and append one round's ``RoundRecord`` — the single
        site both drivers (per-round loop and superround engine) share, so
        cost-model T/E, wire accounting, and any future fields stay
        field-for-field identical between the two histories."""
        sim_t = sim_e = 0.0
        if self.costs is not None:
            k1 = self.hier_config.kappa1
            k2 = self.hier_config.kappa2_effective
            sim_t = cm.time_at_step(self.costs, k1, k2, step)
            sim_e = cm.energy_at_step(self.costs, k1, k2, step)
        record = RoundRecord(
            round=round_index,
            step=step,
            loss=loss,
            mask_alive=mask_alive,
            sim_time_s=sim_t,
            sim_energy_j=sim_e,
            accuracy=accuracy,
            wire_mb=step * wire_per_step / 1e6,
            grad_norm=grad_norm,
            wall_clock_s=wall_clock_s,
        )
        self.history.append(record)
        return record

    def _superround_eligible(self, start_round: int) -> bool:
        """The engine drives whole cloud intervals with host seams at cloud
        boundaries only — eval/checkpoint cadences must land there. A mesh
        no longer forces the per-round loop: whole cloud intervals run
        client-sharded unless the schedule cannot be lowered
        (``core.hierfavg.sharding_incompatibility``) or the caller pinned an
        explicit per-round ``state_shardings`` pytree."""
        self._mesh_reason = None  # never report a stale reason
        k2 = self.hier_config.kappa2_effective
        if start_round % k2 != 0:
            return False
        for every in (self.cfg.eval_every, self.cfg.checkpoint_every):
            if every and every % k2 != 0:
                return False
        if self.mesh is not None:
            if self._state_shardings is not None:
                self._mesh_reason = (
                    "an explicit state_shardings pytree pins the legacy "
                    "per-round mesh path"
                )
                return False
            if self.grad_accum > 1:
                # the prefetcher's block layout carries no microbatch axis,
                # so the engine's client-dim-2 sharding contract breaks
                self._mesh_reason = "grad_accum > 1 has no sharded block layout yet"
                return False
            self._mesh_reason = self._plan_mesh_placement()
            if self._mesh_reason is not None:
                return False
        return True

    def _plan_mesh_placement(self) -> Optional[str]:
        """Plan (once) and validate the edge-aligned placement for the
        mesh; returns the incompatibility reason, or None with
        ``self._placement`` populated for the engine to reuse."""
        from repro.core.hierfavg import sharding_incompatibility
        from repro.dist.sharding import client_axis_of

        axis = client_axis_of(self.mesh)
        num_shards = int(self.mesh.shape[axis])
        if self._placement is None and self._placement_error is None:
            from repro.core.hierarchy import plan_shard_placement

            try:
                self._placement = plan_shard_placement(
                    as_hierarchy(self.topology), num_shards
                )
            except ValueError as e:
                self._placement_error = str(e)
        if self._placement_error is not None:
            return self._placement_error
        return sharding_incompatibility(
            self.hier_config, self.topology, num_shards, placement=self._placement
        )

    def _check_megakernel(self) -> Optional[str]:
        """None if whole cloud intervals can run through the client-blocked
        megakernel lowering, else why the engine falls back to the scan-fused
        superround. Runner-level seams first (mesh routing, masks, overridden
        detectors), then the schedule-level predicate
        (``core.hierfavg.megakernel_incompatibility``). The reason is cached
        on ``_megakernel_reason`` for reporting — the fallback is named, not
        silent, mirroring the ``_mesh_reason`` idiom."""
        from repro.core.hierfavg import megakernel_incompatibility

        if self.mesh is not None:
            reason = "a device mesh routes to the client-sharded superround"
        elif self.grad_accum > 1:
            reason = "microbatch accumulation keeps the scan-fused path"
        elif self.failures is not None or self.stragglers is not None:
            reason = "failure/straggler masks need the scan-fused survival plumbing"
        elif (
            getattr(self._mask_for_round, "__func__", None)
            is not FederatedRunner._mask_for_round
        ):
            reason = "an overridden failure detector is a live per-round mask seam"
        else:
            reason = megakernel_incompatibility(
                self.hier_config, self.topology, grad_accum=self.grad_accum
            )
        self._megakernel_reason = reason
        return reason

    def _plan_cohort_placement(self) -> Optional[str]:
        """Plan (once) and validate the cohort *slot* placement for the
        mesh; returns the incompatibility reason, or None with
        ``self._cohort_placement`` populated for the engine to reuse.
        Placement-stable packing: the slot layout is a pure function of
        (topology, mesh, cohort_size), so one plan serves every interval."""
        from repro.core.hierfavg import (
            _cohort_quotas,
            sharded_cohort_incompatibility,
        )
        from repro.dist.sharding import client_axis_of

        axis = client_axis_of(self.mesh)
        num_shards = int(self.mesh.shape[axis])
        cohort_size = self.participation.cohort_size
        if self._cohort_placement is None and self._cohort_placement_error is None:
            from repro.core.hierarchy import plan_cohort_placement

            spec = as_hierarchy(self.topology)
            try:
                self._cohort_placement = plan_cohort_placement(
                    spec, _cohort_quotas(spec, cohort_size), num_shards
                )
            except ValueError as e:
                self._cohort_placement_error = str(e)
        if self._cohort_placement_error is not None:
            return self._cohort_placement_error
        return sharded_cohort_incompatibility(
            self.hier_config, self.topology, cohort_size, num_shards,
            placement=self._cohort_placement,
        )

    def _cohort_reason(self, start_round: int) -> Optional[str]:
        """None if the run can go cohort-sampled end-to-end, else why not.
        There is no per-round fallback for sampled participation — the
        full-population state the per-round loop needs never exists — so
        every constraint is a hard error, not a silent downgrade.
        Failure/straggler models compose (the engine masks the sampled
        cohort's weight columns); a mesh composes through the sharded
        cohort lowering when ``sharded_cohort_incompatibility`` clears it."""
        from repro.core.hierfavg import cohort_incompatibility

        k2 = self.hier_config.kappa2_effective
        reason = cohort_incompatibility(
            self.hier_config, self.topology, self.participation.cohort_size
        )
        if reason is not None:
            return reason
        if self.cfg.engine == "per_round":
            return "engine='per_round' has no cohort lowering"
        if self._state_shardings is not None:
            return "an explicit state_shardings pytree pins the legacy per-round mesh path"
        if self.mesh is not None:
            if self.grad_accum > 1:
                return "grad_accum > 1 has no sharded block layout yet"
            reason = self._plan_cohort_placement()
            if reason is not None:
                return reason
        if start_round % k2:
            return f"start_round {start_round} is not a cloud boundary (kappa2_eff={k2})"
        if (self.cfg.num_rounds - start_round) % k2:
            return f"num_rounds {self.cfg.num_rounds} is not a whole number of cloud intervals"
        for name, every in (
            ("eval_every", self.cfg.eval_every),
            ("checkpoint_every", self.cfg.checkpoint_every),
        ):
            if every and every % k2 != 0:
                return f"{name}={every} is finer than a cloud interval (kappa2_eff={k2})"
        return None

    def _deadline_reason(self, start_round: int) -> Optional[str]:
        """None if the run can go through the semi-synchronous deadline
        engine, else why not. Like sampled participation there is no
        per-round fallback — a scheduler was configured, so silently running
        synchronous would change the experiment — every constraint is a hard
        error with a named reason."""
        from repro.core.hierfavg import deadline_incompatibility

        if self.participation is not None:
            return "sampled participation runs through the cohort engine"
        reason = deadline_incompatibility(self.hier_config, self.topology)
        if reason is not None:
            return reason
        if self.cfg.engine == "per_round":
            return "engine='per_round' has no deadline lowering"
        if self.cfg.engine == "megakernel":
            return "the deadline engine and the megakernel lowering do not compose"
        if self.mesh is not None:
            return (
                "the deadline engine is single-device (the gated cloud sync "
                "selects per-edge over the whole client axis); drop the mesh"
            )
        if self._state_shardings is not None:
            return "an explicit state_shardings pytree pins the legacy per-round mesh path"
        k2 = self.hier_config.kappa2_effective
        if start_round % k2:
            return f"start_round {start_round} is not a cloud boundary (kappa2_eff={k2})"
        if (self.cfg.num_rounds - start_round) % k2:
            return f"num_rounds {self.cfg.num_rounds} is not a whole number of cloud intervals"
        for name, every in (
            ("eval_every", self.cfg.eval_every),
            ("checkpoint_every", self.cfg.checkpoint_every),
        ):
            if every and every % k2 != 0:
                return f"{name}={every} is finer than a cloud interval (kappa2_eff={k2})"
        return None

    def _run_deadline(self, state: FedState, start_round: int) -> FedState:
        reason = self._deadline_reason(start_round)
        if reason is not None:
            raise ValueError(f"the deadline engine cannot run: {reason}")
        k2 = self.hier_config.kappa2_effective
        intervals = (self.cfg.num_rounds - start_round) // k2
        if intervals <= 0:
            return state
        if self._engine is None:
            from repro.fed.engine import DeadlineEngine

            self._engine = DeadlineEngine(self)
        state, _ = self._engine.run_intervals(
            state, start_round=start_round, num_intervals=intervals
        )
        return state

    def _run_cohort(self, state: FedState, start_round: int) -> FedState:
        reason = self._cohort_reason(start_round)
        if reason is not None:
            raise ValueError(f"sampled participation cannot run: {reason}")
        k2 = self.hier_config.kappa2_effective
        intervals = (self.cfg.num_rounds - start_round) // k2
        if intervals <= 0:
            return state
        if self._engine is None:
            from repro.fed.engine import CohortEngine

            self._engine = CohortEngine(self)
        state, _ = self._engine.run_intervals(
            state, start_round=start_round, num_intervals=intervals
        )
        return state

    def run(self, state: FedState, *, start_round: int = 0) -> FedState:
        mode = self.cfg.engine  # validated by RunnerConfig.__post_init__
        if self.participation is not None:
            return self._run_cohort(state, start_round)
        if self.deadline is not None:
            return self._run_deadline(state, start_round)
        k2 = self.hier_config.kappa2_effective
        if mode != "per_round":
            eligible = self._superround_eligible(start_round)
            full = (self.cfg.num_rounds - start_round) // k2 if eligible else 0
            if mode in ("superround", "megakernel") and full <= 0:
                mesh_note = (
                    f" (mesh: {self._mesh_reason})" if self._mesh_reason else ""
                )
                raise ValueError(
                    f"engine={mode!r} needs a cloud-aligned start_round, "
                    "eval_every/checkpoint_every multiples of "
                    f"kappa2_effective={k2}, a mesh-shardable schedule, and "
                    f"at least one whole cloud interval of rounds{mesh_note}"
                )
            if full > 0:
                if self._engine is None:
                    from repro.fed.engine import SuperRoundEngine

                    self._engine = SuperRoundEngine(self)
                state, stopped = self._engine.run_intervals(
                    state, start_round=start_round, num_intervals=full
                )
                if stopped:
                    return state
                start_round += full * k2
        # per-round path: the remainder (partial trailing interval), or
        # everything when the cadence needs sub-cloud-interval granularity
        k1 = self.hier_config.kappa1
        wire_per_step = self._wire_bytes_per_step(state)
        for r in range(start_round, self.cfg.num_rounds):
            batches = self.batcher.next_batches(k1)
            batches = jax.tree_util.tree_map(jnp.asarray, batches)
            mask = self._mask_for_round()
            mask_dev = None if mask is None else jnp.asarray(mask)
            n_alive = int(mask.sum()) if mask is not None else self.topology.num_clients
            state, metrics = self._round(state, batches, jnp.int32(r), mask_dev)
            step = int(state.step)

            acc = None
            if self.eval_fn is not None and self.cfg.eval_every and (r + 1) % self.cfg.eval_every == 0:
                acc = float(self.eval_fn(self.eval_model(state.params, mask_dev)))

            self._record_round(
                r, step, float(metrics["loss"]), float(metrics["grad_norm"]),
                n_alive, wire_per_step, accuracy=acc,
            )

            if self.checkpointer is not None and self.cfg.checkpoint_every and (
                r + 1
            ) % self.cfg.checkpoint_every == 0:
                meta = {"round": r + 1, "batcher": self.batcher.state_dict()}
                if self.failures is not None:
                    meta["failures"] = self.failures.state_dict()
                if self.stragglers is not None:
                    meta["stragglers"] = self.stragglers.state_dict()
                self.checkpointer.save(int(state.step), state, meta)

            if acc is not None and self.cfg.target_accuracy and acc >= self.cfg.target_accuracy:
                break
        return state

    # ------------------------------------------------------------------
    def records_to_dict(self) -> Dict[str, list]:
        """Column-major history, one key per ``RoundRecord`` field — derived
        from the dataclass so new record fields can't silently drop out."""
        return {
            f.name: [getattr(h, f.name) for h in self.history]
            for f in dataclasses.fields(RoundRecord)
        }
