"""Declarative experiment construction: the ``ExperimentSpec`` tree.

The paper's value proposition is a *design space* — κ-vector schedules,
topologies, data distributions, aggregation statistics, and link budgets
traded against time-to-accuracy — but assembling one point of that space
by hand takes 8+ ``FederatedRunner`` constructor arguments. This module
makes an experiment a *value*: a serializable dataclass tree that

* round-trips through plain dicts/JSON (``to_dict`` / ``from_dict`` /
  ``to_json`` / ``from_json`` — sweepable, loggable, diffable),
* accepts dotted-path CLI overrides
  (``--set schedule.kappas=4,2 --set transport.levels=identity/int8_ef:128``)
  with errors that name the bad path,
* assembles the full runner (``build() -> FederatedRunner``) or runs the
  experiment end to end (``run_experiment() -> (runner, final_state)``).

Sections (all optional; defaults are the paper's 50-client / 5-edge
benchmark stand-in):

    topology     FedTopology or ragged tree (``fanouts`` grammar)
    schedule     the κ-vector + sync/delta/async flags
    data         synthetic dataset + partition protocol + batching
    model        architecture + optimizer + LR schedule
    precision    client compute/state dtype + remat (``core.hierfavg.PrecisionSpec``)
    transport    per-level link codecs (``fed.transport`` grammar)
    aggregators  per-level aggregation statistic (``core.aggregation``)
    failures     failure / straggler injection
    deadline     semi-synchronous cloud rounds (quorum/deadline/staleness)
    cost         the paper's T/E cost model workload
    network      per-entity cost distributions for the replay simulator
                 (``repro.sim``; inert for training)
    run          rounds, cadences, engine, seeds

Named paper configurations live in ``repro.fed.scenarios``; anything the
spec cannot express (custom meshes, custom models/losses, grad
accumulation) drops down to the explicit ``FederatedRunner(...)``
constructor, which is unchanged. ``topology.mesh_axes`` covers the common
mesh case declaratively: ``--set topology.mesh_axes=clients:4`` runs the
superround engine client-sharded over 4 devices.
"""
from __future__ import annotations

import dataclasses
import json
from typing import Any, Dict, Optional, Sequence, Tuple

import numpy as np

from repro.core.hierfavg import PrecisionSpec
from repro.fed.participation import ParticipationSpec
from repro.sim.distributions import NetworkSpec

PyTree = Any

_MISSING = dataclasses.MISSING


# ---------------------------------------------------------------------------
# Spec sections
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class TopologySpec:
    """The aggregation tree. ``fanouts`` (the ``core.hierarchy.parse_fanouts``
    grammar, e.g. ``"16,12,10,7,5/5"`` or ``"10,10/3,2/2"``) wins when set;
    otherwise the uniform two-level ``num_edges`` x ``clients_per_edge``.

    ``mesh_axes`` maps the tree onto hardware: ``""`` (default) runs
    single-device; ``"clients"`` shards the stacked client axis over every
    visible device; ``"clients:4"`` over the first 4. The superround engine
    then executes client-sharded — edge syncs device-local, one grouped
    psum per cloud interval (on CPU simulate devices with
    ``XLA_FLAGS=--xla_force_host_platform_device_count=K``)."""

    fanouts: str = ""
    num_edges: int = 5
    clients_per_edge: int = 10
    mesh_axes: str = ""

    def build(self):
        from repro.core.hierarchy import parse_fanouts
        from repro.core.hierfavg import FedTopology

        if self.fanouts:
            return parse_fanouts(self.fanouts)
        return FedTopology(num_edges=self.num_edges, clients_per_edge=self.clients_per_edge)

    def build_mesh(self):
        """The device mesh ``mesh_axes`` names (None when unset)."""
        if not self.mesh_axes:
            return None
        from repro.dist.sharding import client_mesh

        name, _, size = self.mesh_axes.partition(":")
        try:
            num = int(size) if size.strip() else 0
        except ValueError:
            raise ValueError(
                f"topology.mesh_axes={self.mesh_axes!r} must look like "
                f"'clients' or 'clients:4' (axis name + optional device count)"
            ) from None
        return client_mesh(num, axis=name.strip() or "clients")

    @property
    def depth(self) -> int:
        from repro.core.hierarchy import as_hierarchy

        return as_hierarchy(self.build()).depth

    @property
    def num_clients(self) -> int:
        from repro.core.hierarchy import as_hierarchy

        return as_hierarchy(self.build()).num_clients


@dataclasses.dataclass(frozen=True)
class ScheduleSpec:
    """The κ-vector: ``kappas[0]`` local steps per edge aggregation,
    ``kappas[l]`` level-l intervals per level-(l+1) aggregation. Length must
    match the topology depth.

    ``async_cloud`` is deprecated: the staleness-1 asynchronous lowering it
    named was retired in favour of the semi-synchronous deadline engine.
    Setting it maps onto a ``DeadlineSpec`` (half-quorum, poly:1 staleness
    decay) with a ``DeprecationWarning`` — configure ``deadline.*``
    directly instead."""

    kappas: Tuple[int, ...] = (6, 10)
    sync_opt_state: bool = False
    delta_cloud: bool = False
    async_cloud: bool = False  # deprecated: use the deadline section


@dataclasses.dataclass(frozen=True)
class DataSpec:
    """Synthetic dataset + partition protocol (Section IV-A).

    ``dataset="gaussians"`` is the paper-bench classification stand-in;
    ``dataset="tokens"`` is the Markov-teacher LM corpus (``num_samples``
    then counts sequences of ``seq_len`` over ``vocab`` tokens).
    ``partition_topology`` (fanouts grammar) partitions for a *different*
    tree than the training topology, keeping the first N client shards —
    the paper's edge-only data-access restriction."""

    dataset: str = "gaussians"  # gaussians | tokens
    partition: str = "edge_iid"  # iid | simple_niid | edge_iid | edge_niid
    num_samples: int = 3000
    dim: int = 16
    num_classes: int = 10
    class_sep: float = 3.5
    batch_size: int = 8
    seed: int = 0
    classes_per_edge: int = 0  # edge_niid skew override (0 = the C/2 rule)
    partition_topology: str = ""  # partition as if this tree (fanouts grammar)
    seq_len: int = 64  # tokens only
    vocab: int = 512  # tokens only
    concentration: float = 0.2  # tokens only
    # virtual-population mode (gaussians only): N lazy bootstrap shards of
    # samples_per_client draws over the shared pool instead of a materialized
    # partition — per-client data is realized only for sampled cohorts
    virtual_clients: int = 0  # 0 = materialized partition (the default)
    samples_per_client: int = 64  # virtual shard size (>= batch_size)


@dataclasses.dataclass(frozen=True)
class ModelSpec:
    """Architecture + optimizer. ``arch="mlp"`` is the benchmark classifier
    (``dim -> hidden -> num_classes``); ``arch="lm-10m" | "lm-100m"`` are the
    decoder-only LM presets (vocab follows ``data.vocab``)."""

    arch: str = "mlp"  # mlp | lm-10m | lm-100m
    hidden: int = 48
    optimizer: str = "sgd"  # sgd | adam
    lr: float = 0.15
    lr_schedule: str = "constant"  # constant | exponential | warmup_cosine
    decay_rate: float = 0.995
    decay_steps: int = 50
    warmup_steps: int = 20


def _parse_levels(text: str, depth: int, parse_one, field: str, default: str) -> tuple:
    """'/'-separated per-level grammar shared by transport/aggregators: a
    single entry (no '/') replicates to every level; otherwise the count
    must match the schedule depth. Errors name the spec field."""
    parts = [p for p in (text.strip() or default).split("/") if p]
    if len(parts) == 1:
        parts = parts * depth
    if len(parts) != depth:
        raise ValueError(
            f"{field}={text!r} names {len(parts)} levels but the schedule has "
            f"{depth}; give one entry per level ('/'-separated) or one entry "
            f"for all levels"
        )
    try:
        return tuple(parse_one(p) for p in parts)
    except ValueError as e:
        raise ValueError(f"{field}: {e}") from None


@dataclasses.dataclass(frozen=True)
class TransportSpec:
    """Per-level link codecs, bottom-up, in the ``fed.transport`` grammar:
    ``"identity/int8_ef:128"`` is an fp32 edge hop and an error-feedback
    int8 cloud hop. A single codec (no ``/``) applies to every level."""

    levels: str = "identity"

    def build(self, depth: int):
        from repro.fed import transport as transport_lib

        codecs = _parse_levels(
            self.levels, depth, transport_lib.parse_codec, "transport.levels", "identity"
        )
        spec = transport_lib.TransportSpec(codecs=codecs)
        return None if spec.is_trivial else spec


@dataclasses.dataclass(frozen=True)
class AggregatorSpec:
    """Per-level aggregation statistic, bottom-up, in the
    ``core.aggregation`` grammar: ``"trimmed_mean:0.1/weighted_mean"`` trims
    at the edge sync and keeps the paper's weighted mean at the cloud. A
    single name applies to every level."""

    levels: str = "weighted_mean"

    def build(self, depth: int):
        from repro.core import aggregation

        aggs = _parse_levels(
            self.levels, depth, aggregation.parse_aggregator,
            "aggregators.levels", "weighted_mean",
        )
        spec = aggregation.AggregatorSpec(aggregators=aggs)
        return None if spec.is_trivial else spec


@dataclasses.dataclass(frozen=True)
class FailureSpec:
    """Host-side failure / straggler injection (``fed.failures``)."""

    p_fail: float = 0.0  # per-boundary P(alive -> dead); 0 = no failures
    p_recover: float = 0.5
    straggler_sigma: float = 0.0  # lognormal step-latency sigma; 0 = off
    straggler_mean_s: float = 1.0
    seed: int = 1

    def build(self, num_clients: int):
        from repro.fed.failures import FailureSimulator, StragglerModel

        failures = stragglers = None
        if self.p_fail > 0:
            failures = FailureSimulator(
                num_clients, p_fail=self.p_fail, p_recover=self.p_recover, seed=self.seed
            )
        if self.straggler_sigma > 0:
            stragglers = StragglerModel(
                num_clients,
                mean_step_s=self.straggler_mean_s,
                sigma=self.straggler_sigma,
                seed=self.seed,
            )
        return failures, stragglers


@dataclasses.dataclass(frozen=True)
class DeadlineSpec:
    """Semi-synchronous cloud rounds (``fed.deadline``): edges run their
    cloud intervals at their own cadence; the cloud closes a round at a
    deadline/quorum and folds whatever arrived, staleness-decayed. Late
    edges carry their upload into the next round instead of being dropped.

    ``quorum=1.0`` with ``timeout_s=0`` is the full barrier — under uniform
    cadences that reproduces the synchronous engine bit-exactly (the parity
    contract). ``buffer_size=K`` (FedBuff-style) overrides the fractional
    quorum with an absolute arrival count. ``staleness`` is the
    ``fed.deadline.parse_staleness`` grammar: ``constant | poly:A | exp:A``.

    Edge cadences: ``mean_interval_s`` pins the base edge-interval seconds
    directly; when 0 they derive from the straggler model (per-edge max
    client slowness x κ₁ x mean step time) if one is configured, else from
    the cost model's ``κ₁·t_comp + t_comm_edge``, else 1s x κ₁.
    ``edge_speed``/``edge_jitter`` are ``sim.distributions`` grammars for
    the per-edge slowness draw and the per-round multiplicative jitter."""

    enabled: bool = False
    timeout_s: float = 0.0  # 0 = no deadline (pure quorum/barrier)
    quorum: float = 1.0  # fraction of live edges that closes the round
    buffer_size: int = 0  # absolute arrival count (FedBuff K); 0 = use quorum
    max_staleness: int = 2  # force-wait bound on an edge's missed rounds
    staleness: str = "constant"  # constant | poly:A | exp:A
    edge_drop_rate: float = 0.0  # P(mid-round dropout of an arrived upload)
    retry_limit: int = 1  # bounded re-upload attempts for dropped edges
    edge_speed: str = "det"  # per-edge slowness distribution (drawn once)
    edge_jitter: str = "det"  # per-round interval jitter distribution
    mean_interval_s: float = 0.0  # 0 = derive from stragglers/costs
    seed: int = 0

    def build_scheduler(self, *, topology, kappa1: int, kappa2: int,
                        stragglers=None, costs=None):
        """The configured ``SemiSyncScheduler`` over this spec's cadence
        model (None when disabled)."""
        if not self.enabled:
            return None
        from repro.core.hierarchy import as_hierarchy
        from repro.fed.deadline import EdgeCadenceModel, SemiSyncScheduler

        spec = as_hierarchy(topology)
        num_edges = spec.num_nodes(spec.depth - 1) if spec.depth >= 2 else 1
        if stragglers is not None and self.mean_interval_s <= 0:
            segments = (
                np.asarray(spec.segments(spec.depth - 1))
                if spec.depth >= 2
                else np.zeros(spec.num_clients, np.int64)
            )
            cadence = EdgeCadenceModel.from_stragglers(
                stragglers, segments, num_edges, kappa1,
                jitter=self.edge_jitter, seed=self.seed,
            )
        else:
            if self.mean_interval_s > 0:
                base = self.mean_interval_s
            elif costs is not None:
                base = kappa1 * costs.t_comp + costs.t_comm_edge
            else:
                base = float(kappa1)
            cadence = EdgeCadenceModel(
                num_edges, base, speed=self.edge_speed,
                jitter=self.edge_jitter, seed=self.seed,
            )
        return SemiSyncScheduler(
            cadence,
            intervals_per_round=kappa2,
            quorum=self.quorum,
            timeout_s=self.timeout_s,
            buffer_size=self.buffer_size,
            max_staleness=self.max_staleness,
            staleness=self.staleness,
            edge_drop_rate=self.edge_drop_rate,
            retry_limit=self.retry_limit,
            seed=self.seed,
        )


@dataclasses.dataclass(frozen=True)
class CostSpec:
    """The paper's T/E accounting (``core.cost_model``). ``workload="none"``
    disables it; ``cloud_latency_mult`` overrides the Table I 10x cloud hop
    when positive (1.0 = edge-only deployments)."""

    workload: str = "mnist"  # mnist | cifar10 | none
    cloud_latency_mult: float = 0.0  # 0 = workload default

    def build(self):
        from repro.core import cost_model as cm

        if self.workload == "none":
            return None
        costs = cm.paper_workload(self.workload)
        if self.cloud_latency_mult > 0:
            costs = dataclasses.replace(costs, cloud_latency_mult=self.cloud_latency_mult)
        return costs


@dataclasses.dataclass(frozen=True)
class RunSpec:
    """Loop shape: rounds, cadences, execution engine, checkpointing, and
    the experiment seed (``PRNGKey(seed)`` drives training noise,
    ``PRNGKey(seed + 1)`` the model init)."""

    num_rounds: int = 40
    eval_every: int = 1
    checkpoint_every: int = 0
    checkpoint_dir: str = ""
    target_accuracy: float = 0.0
    engine: str = "auto"  # auto | superround | megakernel | per_round
    seed: int = 0


# ---------------------------------------------------------------------------
# The experiment spec
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class ExperimentSpec:
    """One point of the paper's design space as a serializable value."""

    name: str = "experiment"
    topology: TopologySpec = dataclasses.field(default_factory=TopologySpec)
    schedule: ScheduleSpec = dataclasses.field(default_factory=ScheduleSpec)
    data: DataSpec = dataclasses.field(default_factory=DataSpec)
    model: ModelSpec = dataclasses.field(default_factory=ModelSpec)
    precision: PrecisionSpec = dataclasses.field(default_factory=PrecisionSpec)
    transport: TransportSpec = dataclasses.field(default_factory=TransportSpec)
    aggregators: AggregatorSpec = dataclasses.field(default_factory=AggregatorSpec)
    participation: ParticipationSpec = dataclasses.field(default_factory=ParticipationSpec)
    failures: FailureSpec = dataclasses.field(default_factory=FailureSpec)
    deadline: DeadlineSpec = dataclasses.field(default_factory=DeadlineSpec)
    cost: CostSpec = dataclasses.field(default_factory=CostSpec)
    network: NetworkSpec = dataclasses.field(default_factory=NetworkSpec)
    run: RunSpec = dataclasses.field(default_factory=RunSpec)

    def __post_init__(self):
        # catch the same-name trap early: fed.transport.TransportSpec /
        # core.aggregation.AggregatorSpec are the *built* forms — the spec
        # tree holds the serializable fed.api wrappers (string fields)
        for f in dataclasses.fields(self):
            default = _field_default(f)
            if dataclasses.is_dataclass(default) and not isinstance(
                getattr(self, f.name), type(default)
            ):
                raise TypeError(
                    f"ExperimentSpec.{f.name} must be a fed.api.{type(default).__name__} "
                    f"(the serializable spec form), got "
                    f"{type(getattr(self, f.name)).__name__}; built forms like "
                    f"fed.transport.TransportSpec / core.aggregation.AggregatorSpec "
                    f"belong in HierFAVGConfig, not the spec tree"
                )

    # -- serialization ------------------------------------------------------

    def to_dict(self) -> Dict[str, Any]:
        return _jsonable(dataclasses.asdict(self))

    @classmethod
    def from_dict(cls, d: Dict[str, Any]) -> "ExperimentSpec":
        return _from_dict(cls, d, prefix="")

    def to_json(self, *, indent: int = 2) -> str:
        return json.dumps(self.to_dict(), indent=indent)

    @classmethod
    def from_json(cls, text: str) -> "ExperimentSpec":
        return cls.from_dict(json.loads(text))

    # -- dotted-path overrides ----------------------------------------------

    def with_overrides(self, assignments: Sequence[str]) -> "ExperimentSpec":
        """Apply ``"dotted.path=value"`` assignments, e.g.
        ``spec.with_overrides(["schedule.kappas=4,2", "run.num_rounds=12"])``.
        Unknown paths and malformed values raise ``ValueError`` naming the
        offending path and listing the valid fields at that point."""
        spec = self
        for a in assignments:
            path, eq, text = a.partition("=")
            if not eq:
                raise ValueError(
                    f"override {a!r} must look like 'dotted.path=value' "
                    f"(e.g. schedule.kappas=4,2)"
                )
            spec = _apply_override(spec, path.strip().split("."), text.strip(), path.strip())
        return spec

    @classmethod
    def parse(
        cls,
        overrides: Sequence[str] = (),
        *,
        base: Optional["ExperimentSpec"] = None,
    ) -> "ExperimentSpec":
        """Build a spec from dotted-path overrides on ``base`` (default: the
        default spec). This is the CLI entry point: pass the values of
        repeated ``--set`` flags."""
        return (base if base is not None else cls()).with_overrides(overrides)

    # -- assembly -----------------------------------------------------------

    def hier_config(self, *, _depth: Optional[int] = None):
        """The ``HierFAVGConfig`` this spec describes (transport +
        aggregators threaded through)."""
        from repro.core.hierfavg import HierFAVGConfig

        depth = self.topology.depth if _depth is None else _depth
        if len(self.schedule.kappas) != depth:
            raise ValueError(
                f"schedule.kappas={self.schedule.kappas} has {len(self.schedule.kappas)} "
                f"levels but the topology tree has depth {depth} "
                f"({self.topology.fanouts or f'{self.topology.num_edges}x{self.topology.clients_per_edge}'}); "
                f"set schedule.kappas to one interval per level"
            )
        return HierFAVGConfig.multi_level(
            self.schedule.kappas,
            sync_opt_state=self.schedule.sync_opt_state,
            delta_cloud=self.schedule.delta_cloud,
            transport=self.transport.build(depth),
            aggregators=self.aggregators.build(depth),
            participation=self.participation if self.participation.is_active else None,
            precision=self.precision if self.precision.is_active else None,
        )

    def init_params(self, rng) -> PyTree:
        """Initial (unstacked) model parameters for this spec's model."""
        return _model_bundle(self)["init"](rng)

    def build(self):
        """Assemble the full ``FederatedRunner`` (data, batcher, model,
        optimizer, transport, aggregators, failures, costs, cadences)."""
        from repro.core.hierarchy import as_hierarchy
        from repro.fed.runner import FederatedRunner, RunnerConfig

        topo = self.topology.build()
        tree = as_hierarchy(topo)
        hier = self.hier_config(_depth=tree.depth)
        bundle = _model_bundle(self)
        batcher, eval_fn = _build_data(self, topo, bundle)
        failures, stragglers = self.failures.build(tree.num_clients)
        costs = self.cost.build()
        deadline_spec = self.deadline
        if self.schedule.async_cloud and not deadline_spec.enabled:
            import warnings

            warnings.warn(
                "schedule.async_cloud is deprecated: the staleness-1 async "
                "lowering was retired. Routing to the semi-synchronous "
                "deadline engine (quorum=0.5, poly:1 staleness decay) — the "
                "cloud folds whatever arrived and late edges carry their "
                "upload forward, matching the old semantics in kind, not "
                "bit-for-bit. Under uniform edge cadences every edge arrives "
                "together, so this reduces to the synchronous engine "
                "exactly. Configure the deadline.* section directly instead.",
                DeprecationWarning,
                stacklevel=2,
            )
            deadline_spec = dataclasses.replace(
                deadline_spec, enabled=True, quorum=0.5, staleness="poly:1.0"
            )
        deadline = deadline_spec.build_scheduler(
            topology=topo,
            kappa1=hier.kappa1,
            kappa2=hier.kappa2_effective,
            stragglers=stragglers,
            costs=costs,
        )
        checkpointer = None
        if self.run.checkpoint_dir:
            from repro.checkpoint import CheckpointManager

            checkpointer = CheckpointManager(self.run.checkpoint_dir, keep=2)
        runner = FederatedRunner(
            loss_fn=bundle["loss"],
            optimizer=_build_optimizer(self.model, self.run.num_rounds * hier.kappa1),
            topology=topo,
            hier_config=hier,
            data_sizes=batcher.data_sizes,
            batcher=batcher,
            runner_config=RunnerConfig(
                num_rounds=self.run.num_rounds,
                eval_every=self.run.eval_every,
                checkpoint_every=self.run.checkpoint_every,
                target_accuracy=self.run.target_accuracy,
                engine=self.run.engine,
            ),
            eval_fn=eval_fn,
            costs=costs,
            failures=failures,
            stragglers=stragglers,
            deadline=deadline,
            checkpointer=checkpointer,
            mesh=self.topology.build_mesh(),
        )
        runner.spec = self  # provenance: the runner knows its declarative form
        return runner

    def run_experiment(self, *, resume: bool = False):
        """Build, initialize, and train: returns ``(runner, final_state)``.
        ``resume=True`` restores the latest checkpoint when one exists."""
        import jax

        runner = self.build()
        params = self.init_params(jax.random.PRNGKey(self.run.seed + 1))
        if resume:
            if runner.checkpointer is None:
                raise ValueError(
                    "run_experiment(resume=True) needs run.checkpoint_dir set on "
                    "the spec — without a checkpointer there is nothing to resume from"
                )
            state, start = runner.restore_or_init(jax.random.PRNGKey(self.run.seed), params)
        else:
            state, start = runner.init(jax.random.PRNGKey(self.run.seed), params), 0
        state = runner.run(state, start_round=start)
        return runner, state

    def describe(self) -> str:
        topo = (
            self.topology.fanouts
            or f"{self.topology.num_edges}x{self.topology.clients_per_edge}"
        )
        extras = []
        if self.topology.mesh_axes:
            extras.append(f"mesh={self.topology.mesh_axes}")
        if self.transport.levels != "identity":
            extras.append(f"transport={self.transport.levels}")
        if self.aggregators.levels != "weighted_mean":
            extras.append(f"agg={self.aggregators.levels}")
        if self.participation.is_active:
            extras.append(
                f"cohort={self.participation.cohort_size}/{self.participation.sampler}"
            )
        if self.precision.is_active:
            tag = self.precision.param_dtype + ("+remat" if self.precision.remat else "")
            extras.append(f"precision={tag}")
        if self.failures.p_fail > 0:
            extras.append(f"p_fail={self.failures.p_fail:g}")
        if self.deadline.enabled:
            gate = (
                f"buffer={self.deadline.buffer_size}"
                if self.deadline.buffer_size
                else f"quorum={self.deadline.quorum:g}"
            )
            extras.append(f"deadline[{gate},{self.deadline.staleness}]")
        tail = (" " + " ".join(extras)) if extras else ""
        return (
            f"{self.name}: {topo} kappas={','.join(map(str, self.schedule.kappas))} "
            f"{self.data.partition} {self.model.arch} rounds={self.run.num_rounds}{tail}"
        )


# ---------------------------------------------------------------------------
# Serialization helpers
# ---------------------------------------------------------------------------


def _jsonable(v):
    if isinstance(v, dict):
        return {k: _jsonable(x) for k, x in v.items()}
    if isinstance(v, (list, tuple)):
        return [_jsonable(x) for x in v]
    return v


def _field_default(f: dataclasses.Field):
    if f.default is not _MISSING:
        return f.default
    return f.default_factory()  # every section field has a factory


def _from_dict(cls, d, prefix: str):
    if not isinstance(d, dict):
        raise ValueError(
            f"spec section {prefix[:-1] or 'root'!r} must be a dict, got {type(d).__name__}"
        )
    fields = {f.name: f for f in dataclasses.fields(cls)}
    unknown = sorted(set(d) - set(fields))
    if unknown:
        raise ValueError(
            f"unknown spec key {prefix + unknown[0]!r}; valid keys under "
            f"{prefix[:-1] or 'the spec root'!r}: {sorted(fields)}"
        )
    kwargs = {}
    for name, f in fields.items():
        if name not in d:
            continue
        default = _field_default(f)
        v = d[name]
        if dataclasses.is_dataclass(default):
            kwargs[name] = _from_dict(type(default), v, prefix=f"{prefix}{name}.")
        elif isinstance(default, tuple):
            if not isinstance(v, (list, tuple)):
                # a string would be digit-split silently ('42' -> (4, 2))
                raise ValueError(
                    f"spec key {prefix + name!r} expects a list of integers, "
                    f"got {type(v).__name__} {v!r}"
                )
            kwargs[name] = tuple(int(x) for x in v)
        else:
            kwargs[name] = v
    return cls(**kwargs)


def _coerce(text: str, current, path: str):
    """Parse an override value by the type of the field's current value."""
    if isinstance(current, bool):
        low = text.lower()
        if low in ("1", "true", "yes", "on"):
            return True
        if low in ("0", "false", "no", "off"):
            return False
        raise ValueError(f"{path!r} expects a boolean (true/false), got {text!r}")
    if isinstance(current, tuple):
        try:
            return tuple(int(x) for x in text.replace("/", ",").split(",") if x)
        except ValueError:
            raise ValueError(
                f"{path!r} expects comma-separated integers (e.g. 4,2), got {text!r}"
            ) from None
    if isinstance(current, int):
        try:
            return int(text)
        except ValueError:
            raise ValueError(f"{path!r} expects an integer, got {text!r}") from None
    if isinstance(current, float):
        try:
            return float(text)
        except ValueError:
            raise ValueError(f"{path!r} expects a number, got {text!r}") from None
    return text


def _apply_override(obj, parts, text: str, full_path: str):
    fields = {f.name: f for f in dataclasses.fields(obj)}
    name = parts[0]
    if name not in fields:
        raise ValueError(
            f"unknown spec path {full_path!r}: {type(obj).__name__} has no field "
            f"{name!r}; valid fields: {sorted(fields)}"
        )
    current = getattr(obj, name)
    if len(parts) == 1:
        if dataclasses.is_dataclass(current):
            raise ValueError(
                f"{full_path!r} is a spec section ({type(current).__name__}), not a "
                f"value; set one of its fields: "
                f"{sorted(f.name for f in dataclasses.fields(current))}"
            )
        return dataclasses.replace(obj, **{name: _coerce(text, current, full_path)})
    if not dataclasses.is_dataclass(current):
        raise ValueError(
            f"cannot descend into {full_path!r}: {'.'.join(full_path.split('.')[:1])} "
            f"field {name!r} is a plain value, not a section"
        )
    return dataclasses.replace(obj, **{name: _apply_override(current, parts[1:], text, full_path)})


# ---------------------------------------------------------------------------
# Build helpers (the one shared assembly path — examples, benchmarks, and
# the scenario registry all construct runners through these)
# ---------------------------------------------------------------------------


_LM_PRESETS = ("lm-10m", "lm-100m")


def _lm_config(spec: ExperimentSpec):
    from repro.configs.paper import LM_100M

    if spec.model.arch == "lm-10m":
        cfg = dataclasses.replace(
            LM_100M, name="lm-10m", num_layers=4, d_model=256, num_heads=8,
            num_kv_heads=4, d_ff=768,
        )
    else:
        cfg = LM_100M
    return dataclasses.replace(cfg, vocab_size=spec.data.vocab)


def _model_bundle(spec: ExperimentSpec) -> Dict[str, Any]:
    """{"init", "loss", "apply"(mlp only)} for the spec's architecture."""
    arch = spec.model.arch
    if arch == "mlp":
        import jax
        import jax.numpy as jnp

        from repro.models import cnn

        dim, hidden, classes = spec.data.dim, spec.model.hidden, spec.data.num_classes

        def init(key):
            k1, k2 = jax.random.split(key)
            return {
                "w1": jax.random.normal(k1, (dim, hidden)) * 0.25,
                "b1": jnp.zeros((hidden,)),
                "w2": jax.random.normal(k2, (hidden, classes)) * 0.25,
                "b2": jnp.zeros((classes,)),
            }

        def apply_fn(p, x):
            h = jax.nn.relu(x @ p["w1"] + p["b1"])
            return h @ p["w2"] + p["b2"]

        return {"init": init, "apply": apply_fn, "loss": cnn.make_cnn_loss_fn(apply_fn)}
    if arch in _LM_PRESETS:
        from repro.models import transformer

        cfg = _lm_config(spec)
        return {
            "init": lambda key: transformer.init_params(key, cfg),
            "apply": None,
            "loss": transformer.make_loss_fn(cfg),
        }
    raise ValueError(
        f"model.arch must be one of ('mlp',) + {_LM_PRESETS}, got {arch!r}"
    )


def _build_optimizer(model: ModelSpec, total_steps: int):
    from repro.optim import adam, exponential_decay, sgd, warmup_cosine

    if model.lr_schedule == "constant":
        lr = model.lr
    elif model.lr_schedule == "exponential":
        lr = exponential_decay(model.lr, model.decay_rate, model.decay_steps)
    elif model.lr_schedule == "warmup_cosine":
        lr = warmup_cosine(model.lr, model.warmup_steps, total_steps)
    else:
        raise ValueError(
            f"model.lr_schedule must be constant|exponential|warmup_cosine, "
            f"got {model.lr_schedule!r}"
        )
    if model.optimizer == "sgd":
        return sgd(lr)
    if model.optimizer == "adam":
        return adam(lr)
    raise ValueError(f"model.optimizer must be sgd|adam, got {model.optimizer!r}")


def _build_data(spec: ExperimentSpec, topo, bundle):
    """(batcher, eval_fn) — the single data-assembly path. RNG order matches
    the historical hand-assembly exactly (dataset draw, then partition, both
    from ``default_rng(data.seed)``), so spec-built runs are bit-identical
    to the constructors they replaced."""
    import jax.numpy as jnp

    from repro.core.hierarchy import as_hierarchy, parse_fanouts
    from repro.data import FederatedBatcher, partition_hierarchy
    from repro.data.synthetic import clustered_gaussians, token_corpus

    d = spec.data
    rng = np.random.default_rng(d.seed)
    pspec = parse_fanouts(d.partition_topology) if d.partition_topology else as_hierarchy(topo)
    n = as_hierarchy(topo).num_clients
    if pspec.num_clients < n:
        raise ValueError(
            f"data.partition_topology={d.partition_topology!r} has "
            f"{pspec.num_clients} clients but the training topology needs {n}"
        )
    kw = {}
    if d.partition == "edge_niid" and d.classes_per_edge:
        kw["classes_per_edge"] = d.classes_per_edge

    if d.dataset == "gaussians":
        from repro.models import cnn

        if bundle["apply"] is None:
            raise ValueError(
                f"model.arch={spec.model.arch!r} is a language model and needs "
                f"data.dataset=tokens (got {d.dataset!r})"
            )

        data = clustered_gaussians(
            rng, num_samples=d.num_samples, num_classes=d.num_classes,
            dim=(d.dim,), class_sep=d.class_sep,
        )
        if d.virtual_clients:
            # population mode: no materialized partition — each client's
            # shard is a lazy function of (seed, client_id), realized only
            # when that client is sampled into a cohort
            from repro.data import VirtualClientBatcher

            if d.virtual_clients != n:
                raise ValueError(
                    f"data.virtual_clients={d.virtual_clients} must equal the "
                    f"topology's {n} clients (the population IS the client set)"
                )
            batcher = VirtualClientBatcher(
                {"inputs": data.x, "targets": data.y},
                num_clients=n,
                samples_per_client=d.samples_per_client,
                batch_size=d.batch_size,
                seed=d.seed,
            )
            apply_fn = bundle["apply"]
            x_all, y_all = jnp.asarray(data.x), jnp.asarray(data.y)

            def eval_fn(p):
                return float(cnn.accuracy(apply_fn(p, x_all), y_all))

            return batcher, eval_fn
        parts = partition_hierarchy(d.partition, data.y, pspec, rng, **kw)[:n]
        batcher = FederatedBatcher(
            {"inputs": data.x, "targets": data.y}, parts, batch_size=d.batch_size, seed=d.seed
        )
        apply_fn = bundle["apply"]
        x_all, y_all = jnp.asarray(data.x), jnp.asarray(data.y)

        def eval_fn(p):
            return float(cnn.accuracy(apply_fn(p, x_all), y_all))

        return batcher, eval_fn

    if d.dataset == "tokens":
        if spec.model.arch not in _LM_PRESETS:
            raise ValueError(
                f"data.dataset=tokens needs a language model, got "
                f"model.arch={spec.model.arch!r}; choose one of {_LM_PRESETS}"
            )
        corp = token_corpus(
            rng, num_sequences=d.num_samples, seq_len=d.seq_len, vocab=d.vocab,
            num_classes=d.num_classes, concentration=d.concentration,
        )
        parts = partition_hierarchy(d.partition, corp.labels, pspec, rng, **kw)[:n]
        batcher = FederatedBatcher(
            {"tokens": corp.tokens}, parts, batch_size=d.batch_size, seed=d.seed,
            batch_fn=lambda b: {"inputs": b["tokens"][..., :-1], "targets": b["tokens"][..., 1:]},
        )
        return batcher, None

    raise ValueError(f"data.dataset must be gaussians|tokens, got {d.dataset!r}")


__all__ = [
    "AggregatorSpec",
    "CostSpec",
    "DataSpec",
    "DeadlineSpec",
    "ExperimentSpec",
    "FailureSpec",
    "ModelSpec",
    "ParticipationSpec",
    "PrecisionSpec",
    "RunSpec",
    "ScheduleSpec",
    "TopologySpec",
    "TransportSpec",
]
