"""Host-side store for the persistent per-client slice of federated state.

The cohort engine keeps only C sampled clients device-resident; everything
*sticky* per client — optimizer-state rows (momentum trace, Adam moments)
and error-feedback residuals — lives here, in host RAM, indexed by original
client id. Model parameters and transport anchors are deliberately NOT
stored: the cohort engine only hands control back after a cloud sync, at
which point every stacked params/anchor row equals the broadcast global
model, so those rows carry no per-client information.

Memory: backing arrays are ``np.zeros((N,) + row_shape)``. numpy's calloc
gives copy-on-write zero pages, so physical memory grows with the set of
clients actually *written*, not with N — a 1M-client population with a 4096
cohort commits pages roughly in proportion to cumulative unique
participants. Zero rows are exactly what ``optimizer.init`` produces for
every in-repo transform (trace/mu/nu start at zeros, EF residuals at zeros),
so "never sampled" and "freshly initialized" are indistinguishable by
construction — no touched-mask branch is needed on the gather path.

``state()`` / ``load()`` expose the store as a checkpointable pytree so a
run can be resumed with all momentum/residual history intact.
"""
from __future__ import annotations

from typing import Any, Dict, List, Sequence

import numpy as np
import jax

PyTree = Any

__all__ = ["ClientStateStore", "sticky_rows", "replace_sticky_rows"]


def sticky_rows(state, cohort_size: int) -> Dict[str, Any]:
    """Extract the per-client transient rows of a stacked ``FedState``.

    Returns ``{"opt": [stacked opt leaves...]}`` plus ``"res"`` when the
    state carries an EF residual. A leaf is per-client iff it has a leading
    axis of length ``cohort_size`` — the same convention as
    ``map_stacked_fed_state`` (scalar counts/schedules are shared, not
    per-client).
    """
    opt_leaves = jax.tree_util.tree_leaves(state.opt_state)
    rows: Dict[str, Any] = {
        "opt": [x for x in opt_leaves if getattr(x, "ndim", 0) >= 1 and x.shape[0] == cohort_size]
    }
    if state.residual is not None:
        rows["res"] = state.residual
    return rows


def replace_sticky_rows(state, rows: Dict[str, Any], cohort_size: int):
    """Inverse of :func:`sticky_rows`: swap fresh rows into a ``FedState``."""
    opt_leaves, opt_def = jax.tree_util.tree_flatten(state.opt_state)
    fresh = iter(rows["opt"])
    new_leaves = [
        next(fresh) if getattr(x, "ndim", 0) >= 1 and x.shape[0] == cohort_size else x
        for x in opt_leaves
    ]
    out = state._replace(opt_state=jax.tree_util.tree_unflatten(opt_def, new_leaves))
    if "res" in rows:
        out = out._replace(residual=rows["res"])
    return out


class ClientStateStore:
    """(N, …) host arrays with gather/scatter by original client id."""

    def __init__(self, num_clients: int, row_template: PyTree):
        """``row_template`` leaves give per-client row shape/dtype (no client axis)."""
        self.num_clients = int(num_clients)
        leaves, self._treedef = jax.tree_util.tree_flatten(row_template)
        self._arrays: List[np.ndarray] = [
            np.zeros((self.num_clients,) + tuple(np.shape(leaf)), dtype=np.asarray(leaf).dtype)
            for leaf in leaves
        ]
        self._touched = np.zeros(self.num_clients, np.bool_)

    @classmethod
    def from_rows(cls, num_clients: int, rows: PyTree) -> "ClientStateStore":
        """Build from a cohort-stacked rows pytree (leaves have a leading cohort axis)."""
        template = jax.tree_util.tree_map(lambda x: np.zeros(x.shape[1:], np.asarray(x).dtype)
                                          if getattr(x, "ndim", 0) >= 1
                                          else np.zeros((), np.asarray(x).dtype), rows)
        return cls(num_clients, template)

    # -- shape/introspection -------------------------------------------------

    @property
    def is_empty(self) -> bool:
        """True when there is no sticky per-client state (e.g. plain SGD, no EF)."""
        return not self._arrays

    @property
    def num_touched(self) -> int:
        """Clients that have participated at least once (rows ever written)."""
        return int(self._touched.sum())

    @property
    def nbytes(self) -> int:
        """Logical size; physical residency is page-lazy (see module docstring)."""
        return sum(a.nbytes for a in self._arrays) + self._touched.nbytes

    # -- the cohort swap -----------------------------------------------------

    def gather(self, ids: Sequence[int]) -> PyTree:
        """Rows for a sampled cohort, zero (= fresh-init) where never written."""
        idx = np.asarray(ids, np.int64)
        return jax.tree_util.tree_unflatten(self._treedef, [a[idx] for a in self._arrays])

    def scatter(self, ids: Sequence[int], rows: PyTree) -> None:
        """Write a cohort's rows back after its cloud interval."""
        idx = np.asarray(ids, np.int64)
        leaves = jax.tree_util.tree_leaves(rows)
        if len(leaves) != len(self._arrays):
            raise ValueError(f"expected {len(self._arrays)} row leaves, got {len(leaves)}")
        for arr, leaf in zip(self._arrays, leaves):
            host = np.asarray(leaf)
            if host.shape != (idx.shape[0],) + arr.shape[1:]:
                raise ValueError(
                    f"row shape {host.shape} incompatible with store leaf {arr.shape}"
                )
            arr[idx] = host.astype(arr.dtype, copy=False)
        self._touched[idx] = True

    # -- the sharded cohort swap ---------------------------------------------

    def gather_placed(self, ids: Sequence[int], placement) -> PyTree:
        """Rows for a sampled cohort in *slot placement order*: gather by
        original client id, then permute/pad by ``placement.gather_index()``
        so leaf leading axes are ``placement.padded_clients``. Phantom slots
        read slot 0's client — their weight is zero, so the values are inert
        and scatter_placed drops them on the way back."""
        idx = np.asarray(ids, np.int64)[placement.gather_index()]
        return jax.tree_util.tree_unflatten(self._treedef, [a[idx] for a in self._arrays])

    def scatter_placed(self, ids: Sequence[int], placement, rows: PyTree) -> None:
        """Inverse of :func:`gather_placed`: un-permute padded rows back to
        sampled-id order (``placement.positions()`` drops phantoms), then
        scatter by original client id."""
        pos = placement.positions()
        rows = jax.tree_util.tree_map(lambda x: np.asarray(x)[pos], rows)
        self.scatter(ids, rows)

    # -- checkpointing -------------------------------------------------------

    def state(self) -> Dict[str, Any]:
        """Checkpointable pytree view (shares buffers; do not mutate)."""
        return {"leaves": list(self._arrays), "touched": self._touched}

    def load(self, state: Dict[str, Any]) -> None:
        leaves = list(state["leaves"])
        if len(leaves) != len(self._arrays):
            raise ValueError(f"expected {len(self._arrays)} store leaves, got {len(leaves)}")
        for i, (arr, leaf) in enumerate(zip(self._arrays, leaves)):
            host = np.asarray(leaf)
            if host.shape != arr.shape:
                raise ValueError(f"store leaf {i}: shape {host.shape} != {arr.shape}")
            self._arrays[i] = host.astype(arr.dtype, copy=False)
        self._touched = np.asarray(state["touched"], np.bool_).copy()
