"""Topology bookkeeping: N clients / L edges / cloud <-> device mesh.

Maps the paper's client-edge-cloud tree onto the production meshes:

  single-pod (16,16) ("data","model"):
      edge l  = a contiguous block of the data axis
      client  = one data-axis row inside the block (TP over "model")
  multi-pod (2,16,16) ("pod","data","model"):
      cloud   = cross-pod (DCN)
      edge    = a block of the data axis inside one pod (ICI)
      client  = one ("pod","data") row

The *federated axes* therefore are ("pod","data") flattened: clients are
sharded over them; edges are contiguous groups of clients; pods are
contiguous groups of edges. ``client_axis_sharding`` returns the
PartitionSpec members for the leading client axis, and ``replica_groups``
exposes the expected grouped-collective structure for HLO verification.

Ragged / deeper trees: ``plan_for_hierarchy`` maps any
``core.hierarchy.HierarchySpec`` onto the same meshes — segment
boundaries need not align with device boundaries, and ``replica_groups``
reports the per-level grouped-collective structure for any tier.
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional, Sequence, Tuple

import numpy as np

from repro.core.hierarchy import HierarchySpec, as_hierarchy
from repro.core.hierfavg import FedTopology


@dataclasses.dataclass(frozen=True)
class MeshFedPlan:
    """Concrete client->device assignment for a given mesh."""

    topology: FedTopology
    fed_axes: Tuple[str, ...]  # mesh axes the client dim is sharded over
    num_pods: int
    edges_per_pod: int
    hierarchy: Optional[HierarchySpec] = None  # ragged tree (None -> uniform)

    @property
    def num_clients(self) -> int:
        # the spec is authoritative for ragged trees (the uniform FedTopology
        # view is only exact for equal fan-out)
        return self.spec.num_clients

    @property
    def num_edges(self) -> int:
        return self.spec.num_nodes(1)

    @property
    def spec(self) -> HierarchySpec:
        return self.hierarchy if self.hierarchy is not None else self.topology.hierarchy()


def plan_for_mesh(
    mesh,
    *,
    edges_per_pod: int,
    clients_per_edge: int,
) -> MeshFedPlan:
    """Build the topology for a mesh with ("pod",)? ("data","model") axes.

    Total clients N = num_pods * edges_per_pod * clients_per_edge. The
    client axis is sharded over ("pod","data") (or ("data",) single-pod);
    N must be a multiple of the product of those axis sizes OR divide it
    evenly (both directions shard cleanly under GSPMD).
    """
    axis_names = mesh.axis_names
    num_pods = mesh.shape["pod"] if "pod" in axis_names else 1
    fed_axes = tuple(a for a in ("pod", "data") if a in axis_names)
    topo = FedTopology(num_edges=num_pods * edges_per_pod, clients_per_edge=clients_per_edge)
    return MeshFedPlan(
        topology=topo, fed_axes=fed_axes, num_pods=num_pods, edges_per_pod=edges_per_pod
    )


def plan_for_hierarchy(mesh, spec: HierarchySpec) -> MeshFedPlan:
    """Build a plan for an arbitrary ragged tree on a ("pod",)? ("data","model")
    mesh. The client axis is sharded over the federated axes exactly as in
    the uniform case — segment boundaries need not align with device
    boundaries (segment_sum lowers to grouped collectives over whichever
    devices hold the segment's rows)."""
    axis_names = mesh.axis_names
    num_pods = mesh.shape["pod"] if "pod" in axis_names else 1
    fed_axes = tuple(a for a in ("pod", "data") if a in axis_names)
    num_edges = spec.num_nodes(1)
    sizes = spec.group_sizes(1)
    # the uniform FedTopology view (used by two-level consumers) is exact
    # only for equal fan-out; ragged plans expose the spec directly
    cpe = int(sizes[0]) if spec.is_uniform(1) else int(round(spec.num_clients / num_edges))
    topo = FedTopology(num_edges=num_edges, clients_per_edge=max(cpe, 1))
    return MeshFedPlan(
        topology=topo,
        fed_axes=fed_axes,
        num_pods=num_pods,
        edges_per_pod=max(num_edges // num_pods, 1),
        hierarchy=spec,
    )


def replica_groups(plan_or_spec, level: int = 1) -> List[List[int]]:
    """Client-index groups for the level-``level`` grouped collective —
    the expected replica_groups of the lowered HLO at that hop."""
    if isinstance(plan_or_spec, MeshFedPlan):
        spec = plan_or_spec.spec
    else:
        spec = as_hierarchy(plan_or_spec)
    return spec.replica_groups(level)


def edge_replica_groups(plan: MeshFedPlan) -> List[List[int]]:
    """Client-index groups for edge aggregation (contiguous blocks)."""
    return replica_groups(plan, 1)


def pod_of_edge(plan: MeshFedPlan, edge: int) -> int:
    return edge // plan.edges_per_pod


def client_weights(data_sizes: Sequence[float]) -> np.ndarray:
    return np.asarray(data_sizes, np.float64)
