"""Topology bookkeeping: N clients / L edges / cloud <-> device mesh.

Maps the paper's client-edge-cloud tree onto the production meshes:

  single-pod (16,16) ("data","model"):
      edge l  = a contiguous block of the data axis
      client  = one data-axis row inside the block (TP over "model")
  multi-pod (2,16,16) ("pod","data","model"):
      cloud   = cross-pod (DCN)
      edge    = a block of the data axis inside one pod (ICI)
      client  = one ("pod","data") row

The *federated axes* therefore are ("pod","data") flattened: clients are
sharded over them; edges are contiguous groups of clients; pods are
contiguous groups of edges. ``client_axis_sharding`` returns the
PartitionSpec members for the leading client axis, and ``replica_groups``
exposes the expected grouped-collective structure for HLO verification.
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional, Sequence, Tuple

import numpy as np

from repro.core.hierfavg import FedTopology


@dataclasses.dataclass(frozen=True)
class MeshFedPlan:
    """Concrete client->device assignment for a given mesh."""

    topology: FedTopology
    fed_axes: Tuple[str, ...]  # mesh axes the client dim is sharded over
    num_pods: int
    edges_per_pod: int

    @property
    def num_clients(self) -> int:
        return self.topology.num_clients

    @property
    def num_edges(self) -> int:
        return self.topology.num_edges


def plan_for_mesh(
    mesh,
    *,
    edges_per_pod: int,
    clients_per_edge: int,
) -> MeshFedPlan:
    """Build the topology for a mesh with ("pod",)? ("data","model") axes.

    Total clients N = num_pods * edges_per_pod * clients_per_edge. The
    client axis is sharded over ("pod","data") (or ("data",) single-pod);
    N must be a multiple of the product of those axis sizes OR divide it
    evenly (both directions shard cleanly under GSPMD).
    """
    axis_names = mesh.axis_names
    num_pods = mesh.shape["pod"] if "pod" in axis_names else 1
    fed_axes = tuple(a for a in ("pod", "data") if a in axis_names)
    topo = FedTopology(num_edges=num_pods * edges_per_pod, clients_per_edge=clients_per_edge)
    return MeshFedPlan(
        topology=topo, fed_axes=fed_axes, num_pods=num_pods, edges_per_pod=edges_per_pod
    )


def edge_replica_groups(plan: MeshFedPlan) -> List[List[int]]:
    """Client-index groups for edge aggregation (contiguous blocks)."""
    c = plan.topology.clients_per_edge
    return [list(range(l * c, (l + 1) * c)) for l in range(plan.num_edges)]


def pod_of_edge(plan: MeshFedPlan, edge: int) -> int:
    return edge // plan.edges_per_pod


def client_weights(data_sizes: Sequence[float]) -> np.ndarray:
    return np.asarray(data_sizes, np.float64)
