"""Per-level compressed transport: pluggable link codecs for HierFAVG.

The paper's only lever on the expensive edge→cloud hop is aggregation
frequency (κ₂). Its follow-up (*Hierarchical FL with Quantization*,
arXiv:2103.14272) shows quantizing uploads at **both** levels compounds
that saving with provable convergence. This module is the plumbing: a
``LinkCodec`` models what one uplink does to a client's model delta
(w − w_anchor), and a ``TransportSpec`` assigns one codec per tree level
of a ``HierarchySpec``, plugging into ``HierFAVGConfig`` alongside the
κ-vector. ``core.hierfavg.build_level_sync`` routes every aggregation
boundary through the level's codec.

Semantics
---------
Codecs are *simulated* transport: ``roundtrip`` applies encode∘decode so
the aggregator sees exactly what a real receiver would reconstruct, while
the payload stays a normal f32 pytree for the rest of the jitted step.
The wire size is accounted analytically via ``bits_per_param`` (threaded
into ``dist.collectives`` and ``core.cost_model``).

Quantization blocks NEVER cross client boundaries: every stacked leaf
(N, ...) is flattened to (N, D) and quantized row-wise in blocks of
``block`` along D — the exact payload layout of ``kernels.quantize`` /
the fused dequantize-aggregate kernel in ``kernels.hier_aggregate``
(cross-checked by test).

Error feedback (``int8_ef``): the residual e = (delta + r) − decode(
encode(delta + r)) is carried per client in ``FedState.residual`` and
added to the next upload, turning the biased rounding error into a
telescoping sum (EF-SGD). Caveats in ``docs/compression.md``.

Mesh execution: because blocks never cross client boundaries, every codec
round-trip (and the EF residual it carries) is a pure per-client-row
computation — under the client-sharded superround the whole transport
stays shard-local, bit-identical per client, with no collective traffic.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Optional, Tuple

import jax
import jax.numpy as jnp

PyTree = Any


# ---------------------------------------------------------------------------
# Row-wise blockwise int8 quantization (jnp; mirrors kernels/quantize math)
# ---------------------------------------------------------------------------

def quantize_rows(x2d: jnp.ndarray, block: int) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """(N, D) → (q (N, Dp) int8, scales (N, Dp/block) f32), Dp = D padded to
    a block multiple. Blocks are per row: no block crosses a client
    boundary. Same math as ``kernels.ref.quantize_ref`` per block."""
    n, d = x2d.shape
    pad = (-d) % block
    xf = x2d.astype(jnp.float32)
    if pad:
        xf = jnp.pad(xf, ((0, 0), (0, pad)))
    nb = (d + pad) // block
    blocks = xf.reshape(n, nb, block)
    scale = jnp.max(jnp.abs(blocks), axis=-1, keepdims=True) / 127.0  # (N, nb, 1)
    safe = jnp.where(scale > 0, scale, 1.0)
    q = jnp.clip(jnp.round(blocks / safe), -127.0, 127.0).astype(jnp.int8)
    return q.reshape(n, nb * block), scale[..., 0]


def dequantize_rows(
    q: jnp.ndarray, scales: jnp.ndarray, d: int, block: int
) -> jnp.ndarray:
    """Inverse of ``quantize_rows``: (N, Dp) int8 + (N, Dp/block) scales →
    (N, d) f32."""
    n, dp = q.shape
    nb = dp // block
    x = q.astype(jnp.float32).reshape(n, nb, block) * scales[..., None]
    return x.reshape(n, dp)[:, :d]


def _roundtrip_leaf(x: jnp.ndarray, block: int) -> jnp.ndarray:
    """encode∘decode one stacked (N, ...) leaf; returns f32, same shape."""
    n = x.shape[0]
    flat = x.astype(jnp.float32).reshape(n, -1)
    q, s = quantize_rows(flat, block)
    back = dequantize_rows(q, s, flat.shape[1], block)
    return back.reshape(x.shape)


# ---------------------------------------------------------------------------
# Codecs
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class IdentityCodec:
    """Uncompressed fp32 link — the paper's transport."""

    name: str = "identity"
    error_feedback: bool = False

    @property
    def is_identity(self) -> bool:
        return True

    @property
    def bits_per_param(self) -> float:
        return 32.0

    def roundtrip(self, tree: PyTree, residual: Optional[PyTree]):
        return tree, residual


@dataclasses.dataclass(frozen=True)
class Int8BlockCodec:
    """Blockwise-absmax int8: 8 bits/value + one f32 scale per ``block``
    values → 8 + 32/block bits per parameter (~8.125 at block=256)."""

    block: int = 256
    error_feedback: bool = False

    def __post_init__(self):
        if self.block < 1:
            raise ValueError(f"block must be >= 1, got {self.block}")

    @property
    def name(self) -> str:
        suffix = "_ef" if self.error_feedback else ""
        return f"int8{suffix}:{self.block}"

    @property
    def is_identity(self) -> bool:
        return False

    @property
    def bits_per_param(self) -> float:
        return 8.0 + 32.0 / self.block

    def roundtrip(self, tree: PyTree, residual: Optional[PyTree]):
        """tree: f32 delta pytree with stacked (N, ...) leaves. Returns
        (decoded delta, new residual). Without error feedback the residual
        passes through untouched; with it, the pre-encode deltas absorb the
        carried residual and the new residual is the fresh rounding error."""
        if self.error_feedback:
            if residual is None:
                raise ValueError("error-feedback codec needs a residual tree in FedState")
            e = jax.tree_util.tree_map(
                lambda d, r: d.astype(jnp.float32) + r.astype(jnp.float32), tree, residual
            )
            decoded = jax.tree_util.tree_map(lambda x: _roundtrip_leaf(x, self.block), e)
            new_residual = jax.tree_util.tree_map(lambda a, b: a - b, e, decoded)
            return decoded, new_residual
        decoded = jax.tree_util.tree_map(lambda x: _roundtrip_leaf(x, self.block), tree)
        return decoded, residual


def int8_ef(block: int = 256) -> Int8BlockCodec:
    """int8 + error-feedback residual (EF-SGD on the link)."""
    return Int8BlockCodec(block=block, error_feedback=True)


_CODEC_FACTORIES = {
    "identity": lambda block: IdentityCodec(),
    "fp32": lambda block: IdentityCodec(),
    "int8": lambda block: Int8BlockCodec(block=block),
    "int8_ef": lambda block: int8_ef(block),
}


def parse_codec(text: str):
    """'identity' | 'int8' | 'int8_ef' with an optional ':block' suffix,
    e.g. 'int8:128'."""
    name, _, block = text.strip().partition(":")
    if name not in _CODEC_FACTORIES:
        raise ValueError(
            f"unknown codec {name!r}; choose from {sorted(_CODEC_FACTORIES)}"
        )
    return _CODEC_FACTORIES[name](int(block) if block else 256)


# ---------------------------------------------------------------------------
# Per-level spec
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class TransportSpec:
    """One codec per aggregation level, bottom-up: ``codecs[0]`` is the
    client→edge uplink (level 1), ``codecs[-1]`` the top (cloud) hop —
    aligned with ``HierFAVGConfig.kappa_vector``."""

    codecs: Tuple[Any, ...]

    def __post_init__(self):
        object.__setattr__(self, "codecs", tuple(self.codecs))
        if not self.codecs:
            raise ValueError("TransportSpec needs at least one level")

    # -- constructors -------------------------------------------------------

    @classmethod
    def identity(cls, depth: int) -> "TransportSpec":
        return cls(codecs=tuple(IdentityCodec() for _ in range(depth)))

    @classmethod
    def uniform(cls, codec, depth: int) -> "TransportSpec":
        return cls(codecs=tuple(codec for _ in range(depth)))

    @classmethod
    def cloud_int8(cls, depth: int, *, block: int = 256, error_feedback: bool = False) -> "TransportSpec":
        """The common deployment: fp32 on cheap lower hops, int8 on the
        expensive top (DCN) hop."""
        top = Int8BlockCodec(block=block, error_feedback=error_feedback)
        return cls(codecs=tuple(IdentityCodec() for _ in range(depth - 1)) + (top,))

    @classmethod
    def parse(cls, text: str) -> "TransportSpec":
        """'/'-separated codec per level, bottom-up: 'identity/int8' is an
        fp32 edge hop and an int8 cloud hop; 'int8:128/int8_ef' quantizes
        both with a 128 block and error feedback at the top."""
        parts = [p for p in text.split("/") if p]
        if not parts:
            raise ValueError(f"empty transport spec: {text!r}")
        return cls(codecs=tuple(parse_codec(p) for p in parts))

    # -- queries ------------------------------------------------------------

    @property
    def depth(self) -> int:
        return len(self.codecs)

    def codec(self, level: int):
        if not 1 <= level <= self.depth:
            raise ValueError(f"level must be in 1..{self.depth}, got {level}")
        return self.codecs[level - 1]

    @property
    def is_trivial(self) -> bool:
        """True iff every level is identity — numerics and accounting are
        then exactly the uncompressed protocol."""
        return all(c.is_identity for c in self.codecs)

    @property
    def needs_residual(self) -> bool:
        return any(c.error_feedback for c in self.codecs)

    def bits_per_param(self, level: int) -> float:
        return float(self.codec(level).bits_per_param)

    def bits_vector(self) -> Tuple[float, ...]:
        """Per-level bits per parameter, bottom-up — what
        ``dist.collectives.hierarchy_traffic_per_step`` consumes."""
        return tuple(float(c.bits_per_param) for c in self.codecs)

    def describe(self) -> str:
        return "/".join(c.name for c in self.codecs)


# ---------------------------------------------------------------------------
# Fused decode+aggregate entry point (Pallas kernel, flat payloads)
# ---------------------------------------------------------------------------

def fused_decode_segment_mean(
    q: jnp.ndarray,
    scales: jnp.ndarray,
    weights: jnp.ndarray,
    segment_ids,
    num_segments: int,
    *,
    block_d: int = 512,
) -> jnp.ndarray:
    """Aggregate int8 payloads without materializing the f32 decode:
    q (N, D) int8 + scales (N, D/qblock) f32 → per-segment weighted mean of
    the dequantized rows, broadcast back to members, (N, D) f32.

    One HBM pass over the int8 payload (~¼ the bytes of decode-then-
    aggregate). Equals ``dequantize_rows`` + ``segment_weighted_mean``
    bit-for-bit (same tiling; see ``kernels.ref.segment_dequant_mean_ref``).
    """
    from repro.kernels import ops

    return ops.segment_dequant_mean(
        q, scales, weights, segment_ids, num_segments, block_d=block_d
    )


def transport_wire_bytes_per_param(spec: Optional[TransportSpec], depth: int) -> Tuple[float, ...]:
    """Per-level wire bytes per fp32 parameter (spec=None → uncompressed)."""
    if spec is None:
        return tuple(4.0 for _ in range(depth))
    return tuple(b / 8.0 for b in spec.bits_vector())
