"""Failure model + straggler mitigation for the federated runner.

At 1000+ node scale, node loss and stragglers are routine. The aggregation
operators (core.aggregation) already accept a survival mask and renormalize
over survivors — this module produces those masks:

* ``FailureSimulator`` — per-client iid failure/recovery Markov chain
  (host-side; deterministic under seed) standing in for a real failure
  detector (heartbeat timeouts).
* ``StragglerModel`` — per-client local-step latency ~ lognormal; a client
  whose κ₁ steps exceed the edge deadline is excluded from that edge
  aggregation (deadline-based partial aggregation) but keeps its local
  model and rejoins at the next boundary — exactly the paper's weighted
  mean restricted to the participating set.
* ``SubtreeOutageSimulator`` — *correlated* failures: an edge server (or a
  whole region, any tier of a ragged ``HierarchySpec``) goes down and
  takes every client beneath it out of the aggregation at once — the
  realistic failure unit of a hierarchical deployment (a client loses its
  uplink when its edge does). The zero-survivor-group rule in
  ``core.aggregation`` then keeps the subtree's parameters frozen until
  the node recovers.
* ``deadline_for`` — the auto-deadline policy: p-th percentile of the
  latency model times a slack factor.

The round runner (fed.runner) threads masks through train_step; masks are
ordinary (N,) float arrays so the jitted step never recompiles.
"""
from __future__ import annotations

import dataclasses
from typing import NamedTuple, Optional, Sequence, Tuple

import numpy as np


@dataclasses.dataclass
class FailureSimulator:
    """Two-state Markov chain per client: alive <-> dead.

    p_fail: P(alive->dead) per aggregation boundary; p_recover: P(dead->alive).
    """

    num_clients: int
    p_fail: float = 0.0
    p_recover: float = 0.5
    seed: int = 0

    def __post_init__(self):
        self._rng = np.random.default_rng(self.seed)
        self.alive = np.ones(self.num_clients, bool)

    def step(self) -> np.ndarray:
        u = self._rng.random(self.num_clients)
        die = self.alive & (u < self.p_fail)
        recover = (~self.alive) & (u < self.p_recover)
        self.alive = (self.alive & ~die) | recover
        return self.alive.astype(np.float32)

    def state_dict(self):
        return {"alive": self.alive.copy(), "rng": self._rng.bit_generator.state}

    def load_state_dict(self, s):
        self.alive = s["alive"].copy()
        self._rng.bit_generator.state = s["rng"]


@dataclasses.dataclass
class StragglerModel:
    """Lognormal per-client step-latency; exceeds-deadline -> masked out."""

    num_clients: int
    mean_step_s: float = 1.0
    sigma: float = 0.3
    seed: int = 0

    def __post_init__(self):
        self._rng = np.random.default_rng(self.seed)
        # persistent per-client slowness factor (heterogeneous hardware)
        self.slowness = np.exp(self._rng.normal(0.0, self.sigma / 2, self.num_clients))

    def interval_latency(self, kappa1: int) -> np.ndarray:
        """Simulated wall time for each client to finish kappa1 local steps."""
        jitter = np.exp(self._rng.normal(0.0, self.sigma, self.num_clients))
        return kappa1 * self.mean_step_s * self.slowness * jitter

    def deadline_for(self, kappa1: int, *, percentile: float = 95.0, slack: float = 1.1) -> float:
        """Deadline = slack * p-th percentile of the latency distribution."""
        # analytic percentile of lognormal(mean*slowness median)
        base = kappa1 * self.mean_step_s * np.median(self.slowness)
        z = {90.0: 1.2816, 95.0: 1.6449, 99.0: 2.3263}.get(percentile, 1.6449)
        return slack * base * float(np.exp(self.sigma * z))

    def survivors(self, kappa1: int, deadline: Optional[float] = None) -> Tuple[np.ndarray, float]:
        lat = self.interval_latency(kappa1)
        d = deadline if deadline is not None else self.deadline_for(kappa1)
        return (lat <= d).astype(np.float32), d

    def state_dict(self):
        # slowness rides along (it is drawn from the same stream at
        # construction, so a resumed model must not redraw it)
        return {"slowness": self.slowness.copy(), "rng": self._rng.bit_generator.state}

    def load_state_dict(self, s):
        self.slowness = s["slowness"].copy()
        self._rng.bit_generator.state = s["rng"]


@dataclasses.dataclass
class SubtreeOutageSimulator:
    """Two-state Markov chain per tier-``tier`` node of a hierarchy: when a
    node is down, every client in its subtree is masked out together.

    spec: a ``core.hierarchy.HierarchySpec`` (or FedTopology via
    ``as_hierarchy``); tier 1 = edge servers, higher tiers = regions.
    """

    spec: object
    tier: int = 1
    p_fail: float = 0.0
    p_recover: float = 0.5
    seed: int = 0

    def __post_init__(self):
        from repro.core.hierarchy import as_hierarchy

        self.spec = as_hierarchy(self.spec)
        if not 1 <= self.tier <= self.spec.depth:
            raise ValueError(f"tier {self.tier} outside 1..{self.spec.depth}")
        self._segments = self.spec.segments(self.tier)
        self._num_nodes = self.spec.num_nodes(self.tier)
        self._rng = np.random.default_rng(self.seed)
        self.alive = np.ones(self._num_nodes, bool)

    def step(self) -> np.ndarray:
        """Advance one boundary; returns the (N,) client survival mask."""
        u = self._rng.random(self._num_nodes)
        die = self.alive & (u < self.p_fail)
        recover = (~self.alive) & (u < self.p_recover)
        self.alive = (self.alive & ~die) | recover
        return self.alive[self._segments].astype(np.float32)

    def state_dict(self):
        return {"alive": self.alive.copy(), "rng": self._rng.bit_generator.state}

    def load_state_dict(self, s):
        self.alive = s["alive"].copy()
        self._rng.bit_generator.state = s["rng"]


def combine_masks(*masks: Optional[np.ndarray]) -> Optional[np.ndarray]:
    out: Optional[np.ndarray] = None
    for m in masks:
        if m is None:
            continue
        out = m if out is None else out * m
    return out


class MaskComposition(NamedTuple):
    """Survival masks split by *why* a client is missing a boundary.

    ``effective`` is the plain product of every mask (what the aggregation
    operators consume — identical to ``combine_masks`` over all inputs).
    ``late`` flags clients whose compute finished but whose upload missed
    the boundary (straggler deadline): their model is fresh and the upload
    can be deferred to the next boundary. ``dead`` flags clients with no
    contribution at all (outage): nothing exists to defer. A client that is
    both dead and slow counts as dead — there is no upload to be late with.
    All three are None when no mask of that kind was supplied.
    """

    effective: Optional[np.ndarray]
    late: Optional[np.ndarray]
    dead: Optional[np.ndarray]

    @property
    def late_count(self) -> int:
        return 0 if self.late is None else int(np.sum(self.late > 0))

    @property
    def dead_count(self) -> int:
        return 0 if self.dead is None else int(np.sum(self.dead > 0))


def compose_masks(
    dead: Sequence[Optional[np.ndarray]] = (),
    late: Sequence[Optional[np.ndarray]] = (),
) -> MaskComposition:
    """Compose outage masks (``dead``: 0 = no contribution) with straggler
    masks (``late``: 0 = compute done, upload deferred) without losing the
    distinction ``combine_masks`` erases.

    Returns a :class:`MaskComposition` whose ``effective`` channel equals
    ``combine_masks(*dead, *late)`` bit for bit — existing aggregation
    semantics are unchanged — plus indicator channels: ``late[i] = 1`` iff
    client i survived every outage mask but was zeroed by a straggler mask,
    ``dead[i] = 1`` iff client i was zeroed by an outage mask.
    """
    dead_m = combine_masks(*dead)
    late_m = combine_masks(*late)
    effective = combine_masks(dead_m, late_m)
    dead_ind = None if dead_m is None else (dead_m == 0).astype(np.float32)
    late_ind = None
    if late_m is not None:
        late_ind = (late_m == 0).astype(np.float32)
        if dead_m is not None:
            late_ind = late_ind * (dead_m != 0)  # dead wins: nothing to defer
    return MaskComposition(effective=effective, late=late_ind, dead=dead_ind)
