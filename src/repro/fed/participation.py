"""Sampled-participation: who trains this cloud interval.

Full-population HierFAVG stacks every client on device; at population scale
(ROADMAP item 1, "millions of users") only a *cohort* can be resident. This
module owns the policy half of that split: a :class:`ParticipationSpec`
config section plus the three cohort samplers it can build —

- ``uniform``      — i.i.d. without replacement over the whole population,
- ``round_robin``  — a rotating contiguous window, so every client is
  guaranteed to participate within ⌈N/C⌉ cloud intervals,
- ``stratified``   — per-edge quotas proportional to edge population (each
  alive edge gets at least one seat), so no edge mean ever collapses to its
  stale broadcast value.

Samplers return **sorted** original client ids. Sorting keeps the cohort's
per-level segment-id vectors non-decreasing (children of a node contiguous),
which is what ``aggregation.segment_weighted_mean`` is specified against and
what the ragged kernels assume.

Every sampler is a tiny host-side state machine with ``state_dict`` /
``load_state_dict`` whose contents survive a JSON round-trip — the cohort
prefetcher packs them into checkpoint metadata so a resumed run replays the
exact same cohort sequence (restart-exactness, same contract as the batcher
cursors).

Pure numpy on purpose: this module is imported by config layers
(``HierFAVGConfig`` carries a spec instance) and must not pull in jax.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional

import numpy as np

__all__ = [
    "ParticipationSpec",
    "CohortSampler",
    "UniformSampler",
    "RoundRobinSampler",
    "StratifiedSampler",
    "stratified_quotas",
    "stratified_slot_edges",
    "build_sampler",
]

SAMPLERS = ("uniform", "round_robin", "stratified")


@dataclasses.dataclass(frozen=True)
class ParticipationSpec:
    """Which clients are device-resident per cloud interval.

    cohort_size=0 (the default) disables sampling: every engine keeps its
    full-population behaviour and this section is inert. A positive cohort
    size routes execution through the cohort engine, which materializes only
    the sampled rows on device.
    """

    cohort_size: int = 0
    sampler: str = "uniform"
    seed: int = 0

    def __post_init__(self):
        if self.cohort_size < 0:
            raise ValueError(f"cohort_size must be >= 0, got {self.cohort_size}")
        if self.sampler not in SAMPLERS:
            raise ValueError(f"sampler must be one of {SAMPLERS}, got {self.sampler!r}")

    @property
    def is_active(self) -> bool:
        return self.cohort_size > 0

    def build_sampler(self, hierarchy) -> "CohortSampler":
        return build_sampler(self, hierarchy)


class CohortSampler:
    """Base: successive ``sample()`` calls yield one cohort per cloud interval."""

    kind = "base"

    def __init__(self, num_clients: int, cohort_size: int):
        num_clients = int(num_clients)
        cohort_size = int(cohort_size)
        if not 1 <= cohort_size <= num_clients:
            raise ValueError(
                f"cohort_size must be in 1..{num_clients} (population), got {cohort_size}"
            )
        self.num_clients = num_clients
        self.cohort_size = cohort_size

    def sample(self) -> np.ndarray:  # pragma: no cover - abstract
        raise NotImplementedError

    def state_dict(self) -> Dict[str, Any]:  # pragma: no cover - abstract
        raise NotImplementedError

    def load_state_dict(self, state: Dict[str, Any]) -> None:  # pragma: no cover
        raise NotImplementedError


class UniformSampler(CohortSampler):
    """I.i.d. cohort without replacement; seed-deterministic and resume-exact."""

    kind = "uniform"

    def __init__(self, num_clients: int, cohort_size: int, seed: int = 0):
        super().__init__(num_clients, cohort_size)
        self._rng = np.random.default_rng((int(seed), 0x5EED))

    def sample(self) -> np.ndarray:
        ids = self._rng.choice(self.num_clients, size=self.cohort_size, replace=False)
        return np.sort(ids).astype(np.int64)

    def state_dict(self) -> Dict[str, Any]:
        # bit_generator.state is a nested dict of strs/ints — JSON-safe
        # (python ints are arbitrary precision, so the 128-bit PCG64 state
        # survives the checkpoint metadata round-trip losslessly).
        return {"kind": self.kind, "rng": self._rng.bit_generator.state}

    def load_state_dict(self, state: Dict[str, Any]) -> None:
        if state.get("kind") != self.kind:
            raise ValueError(f"sampler kind mismatch: {state.get('kind')!r} != {self.kind!r}")
        self._rng.bit_generator.state = state["rng"]


class RoundRobinSampler(CohortSampler):
    """Rotating window: covers every client within ⌈N/C⌉ consecutive cohorts."""

    kind = "round_robin"

    def __init__(self, num_clients: int, cohort_size: int, seed: int = 0):
        super().__init__(num_clients, cohort_size)
        del seed  # deterministic rotation; accepted for interface symmetry
        self._cursor = 0

    def sample(self) -> np.ndarray:
        ids = (self._cursor + np.arange(self.cohort_size, dtype=np.int64)) % self.num_clients
        self._cursor = int((self._cursor + self.cohort_size) % self.num_clients)
        return np.sort(ids)

    def state_dict(self) -> Dict[str, Any]:
        return {"kind": self.kind, "cursor": self._cursor}

    def load_state_dict(self, state: Dict[str, Any]) -> None:
        if state.get("kind") != self.kind:
            raise ValueError(f"sampler kind mismatch: {state.get('kind')!r} != {self.kind!r}")
        self._cursor = int(state["cursor"])


def stratified_quotas(edge_sizes: np.ndarray, cohort_size: int) -> np.ndarray:
    """Per-edge seat counts: proportional to edge population, each edge >= 1.

    Largest-remainder apportionment with a floor of one seat per edge and a
    cap of the edge's population. Deterministic; quotas sum to cohort_size.
    """
    sizes = np.asarray(edge_sizes, np.int64)
    num_edges = sizes.shape[0]
    if np.any(sizes < 1):
        raise ValueError("every edge must have at least one client")
    if cohort_size < num_edges:
        raise ValueError(
            f"stratified sampling needs cohort_size >= num_edges "
            f"({cohort_size} < {num_edges}) so no edge is left cohort-empty"
        )
    if cohort_size > sizes.sum():
        raise ValueError(f"cohort_size {cohort_size} exceeds population {int(sizes.sum())}")
    quota = np.ones(num_edges, np.int64)  # the >=1 floor
    while True:
        remaining = int(cohort_size - quota.sum())
        if remaining == 0:
            return quota
        room = sizes - quota
        open_ix = np.flatnonzero(room > 0)
        share = sizes[open_ix].astype(np.float64)
        ideal = remaining * share / share.sum()
        add = np.minimum(np.floor(ideal).astype(np.int64), room[open_ix])
        if add.sum() == 0:
            # all floors rounded to zero: hand out single seats by largest
            # fractional remainder (stable order breaks exact ties by edge id)
            order = open_ix[np.argsort(-(ideal - np.floor(ideal)), kind="stable")]
            quota[order[:remaining]] += 1
        else:
            quota[open_ix] += add


def stratified_slot_edges(edge_sizes: np.ndarray, cohort_size: int) -> np.ndarray:
    """(cohort_size,) edge id owning each cohort *slot* under stratified
    sampling.

    Because edges are contiguous sorted id ranges and the per-edge quotas
    are fixed, every sorted stratified cohort fills the same slot→edge
    layout: slot j belongs to the edge whose quota block covers j. This is
    the placement-stability contract the sharded cohort lowering builds on —
    the slot layout (and hence the shard placement planned from it) is a
    pure function of (topology, cohort_size), independent of which clients
    the sampler draws each interval.
    """
    quotas = stratified_quotas(edge_sizes, cohort_size)
    return np.repeat(np.arange(quotas.shape[0], dtype=np.int64), quotas)


class StratifiedSampler(CohortSampler):
    """Per-edge proportional quotas; never leaves an alive edge cohort-empty."""

    kind = "stratified"

    def __init__(self, num_clients: int, cohort_size: int, edge_segments: np.ndarray, seed: int = 0):
        super().__init__(num_clients, cohort_size)
        seg = np.asarray(edge_segments, np.int64)
        if seg.shape != (self.num_clients,):
            raise ValueError(f"edge_segments must be ({self.num_clients},), got {seg.shape}")
        sizes = np.bincount(seg)
        self.quotas = stratified_quotas(sizes, self.cohort_size)
        # segments are sorted (children contiguous), so each edge's members
        # are a contiguous id range [offset_e, offset_e + size_e)
        self._offsets = np.concatenate([[0], np.cumsum(sizes)]).astype(np.int64)
        self._rng = np.random.default_rng((int(seed), 0x5EED))

    def sample(self) -> np.ndarray:
        parts = []
        for e, q in enumerate(self.quotas):
            lo, hi = self._offsets[e], self._offsets[e + 1]
            parts.append(lo + self._rng.choice(hi - lo, size=int(q), replace=False))
        return np.sort(np.concatenate(parts)).astype(np.int64)

    def state_dict(self) -> Dict[str, Any]:
        return {"kind": self.kind, "rng": self._rng.bit_generator.state}

    def load_state_dict(self, state: Dict[str, Any]) -> None:
        if state.get("kind") != self.kind:
            raise ValueError(f"sampler kind mismatch: {state.get('kind')!r} != {self.kind!r}")
        self._rng.bit_generator.state = state["rng"]


def build_sampler(spec: ParticipationSpec, hierarchy) -> CohortSampler:
    """Build the sampler a spec describes against a concrete hierarchy.

    ``hierarchy`` is a ``core.hierarchy.HierarchySpec`` (duck-typed here to
    keep this module jax- and core-free): needs ``num_clients`` and, for
    stratified sampling, ``segments(1)``.
    """
    if not spec.is_active:
        raise ValueError("participation is inactive (cohort_size=0); nothing to build")
    n = int(hierarchy.num_clients)
    if spec.sampler == "uniform":
        return UniformSampler(n, spec.cohort_size, spec.seed)
    if spec.sampler == "round_robin":
        return RoundRobinSampler(n, spec.cohort_size, spec.seed)
    if spec.sampler == "stratified":
        return StratifiedSampler(n, spec.cohort_size, hierarchy.segments(1), spec.seed)
    raise ValueError(f"unknown sampler {spec.sampler!r}")  # pragma: no cover
