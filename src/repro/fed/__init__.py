from repro.fed import failures, runner, topology
from repro.fed.failures import FailureSimulator, StragglerModel, combine_masks
from repro.fed.runner import FederatedRunner, RunnerConfig
from repro.fed.topology import MeshFedPlan, edge_replica_groups, plan_for_mesh

__all__ = [
    "failures",
    "runner",
    "topology",
    "FailureSimulator",
    "StragglerModel",
    "combine_masks",
    "FederatedRunner",
    "RunnerConfig",
    "MeshFedPlan",
    "edge_replica_groups",
    "plan_for_mesh",
]
