from repro.fed import failures, runner, topology
from repro.fed.failures import (
    FailureSimulator,
    StragglerModel,
    SubtreeOutageSimulator,
    combine_masks,
)
from repro.fed.runner import FederatedRunner, RunnerConfig
from repro.fed.topology import (
    MeshFedPlan,
    edge_replica_groups,
    plan_for_hierarchy,
    plan_for_mesh,
    replica_groups,
)

__all__ = [
    "failures",
    "runner",
    "topology",
    "FailureSimulator",
    "StragglerModel",
    "SubtreeOutageSimulator",
    "combine_masks",
    "FederatedRunner",
    "RunnerConfig",
    "MeshFedPlan",
    "edge_replica_groups",
    "plan_for_hierarchy",
    "plan_for_mesh",
    "replica_groups",
]
