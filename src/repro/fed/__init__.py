from repro.fed import client_store, deadline, engine, failures, participation, runner, topology, transport
from repro.fed import api, scenarios
from repro.fed.api import ExperimentSpec
from repro.fed.client_store import ClientStateStore
from repro.fed.deadline import (
    EdgeCadenceModel,
    RoundPlan,
    SemiSyncScheduler,
    StalenessPolicy,
    parse_staleness,
)
from repro.fed.engine import CohortEngine, DeadlineEngine, SuperRoundEngine
from repro.fed.participation import ParticipationSpec
from repro.fed.transport import (
    IdentityCodec,
    Int8BlockCodec,
    TransportSpec,
    int8_ef,
    parse_codec,
)
from repro.fed.failures import (
    FailureSimulator,
    MaskComposition,
    StragglerModel,
    SubtreeOutageSimulator,
    combine_masks,
    compose_masks,
)
from repro.fed.runner import FederatedRunner, RunnerConfig
from repro.fed.topology import (
    MeshFedPlan,
    edge_replica_groups,
    plan_for_hierarchy,
    plan_for_mesh,
    replica_groups,
)

__all__ = [
    "api",
    "scenarios",
    "ExperimentSpec",
    "client_store",
    "ClientStateStore",
    "deadline",
    "EdgeCadenceModel",
    "RoundPlan",
    "SemiSyncScheduler",
    "StalenessPolicy",
    "parse_staleness",
    "engine",
    "CohortEngine",
    "DeadlineEngine",
    "SuperRoundEngine",
    "participation",
    "ParticipationSpec",
    "failures",
    "runner",
    "topology",
    "transport",
    "IdentityCodec",
    "Int8BlockCodec",
    "TransportSpec",
    "int8_ef",
    "parse_codec",
    "FailureSimulator",
    "MaskComposition",
    "StragglerModel",
    "SubtreeOutageSimulator",
    "combine_masks",
    "compose_masks",
    "FederatedRunner",
    "RunnerConfig",
    "MeshFedPlan",
    "edge_replica_groups",
    "plan_for_hierarchy",
    "plan_for_mesh",
    "replica_groups",
]
