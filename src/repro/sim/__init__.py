"""repro.sim — trace-driven discrete-event replay of the client-edge-cloud
system.

The analytic cost model prices one round with identical clients; this
package replays the round's dependency DAG under per-client / per-edge
cost *distributions* to answer production questions — p99 round time,
energy CDFs, congested-backhaul what-ifs — and optimizes the client→edge
association on top (HFEL, arXiv 2002.11343). See docs/simulation.md.

    dag            the per-cloud-interval dependency DAG
    distributions  seeded, checkpointable cost distributions + NetworkSpec
    calibrate      node costs from WorkloadCosts / ClusterCosts / roofline
    replay         event-queue replay -> time & energy distributions
    association    greedy + local-search client→edge optimizer

Zero-variance contract: with every distribution ``det`` the replay equals
``cloud_interval_time`` / ``cloud_interval_energy`` to machine precision.
"""
from repro.sim.association import (
    AssociationResult,
    assignment_to_spec,
    optimize_association,
)
from repro.sim.calibrate import (
    SimCosts,
    from_cluster,
    from_roofline,
    from_workload,
    straggler_masks,
    straggler_network,
)
from repro.sim.dag import AGG, HOP, STEP, RoundDag, build_round_dag
from repro.sim.distributions import (
    DeterministicDist,
    Distribution,
    LogNormalDist,
    MixtureDist,
    NetworkModel,
    NetworkSpec,
    parse_distribution,
)
from repro.sim.replay import (
    JitterTables,
    ReplayResult,
    assemble_durations,
    draw_jitter_tables,
    replay_once,
    simulate_round,
    simulate_spec,
    sweep,
)

__all__ = [
    "AGG",
    "HOP",
    "STEP",
    "AssociationResult",
    "DeterministicDist",
    "Distribution",
    "JitterTables",
    "LogNormalDist",
    "MixtureDist",
    "NetworkModel",
    "NetworkSpec",
    "ReplayResult",
    "RoundDag",
    "SimCosts",
    "assignment_to_spec",
    "assemble_durations",
    "build_round_dag",
    "draw_jitter_tables",
    "from_cluster",
    "from_roofline",
    "from_workload",
    "optimize_association",
    "parse_distribution",
    "replay_once",
    "simulate_round",
    "simulate_spec",
    "straggler_masks",
    "straggler_network",
    "sweep",
]
