"""Event-queue replay of the round DAG -> time and energy *distributions*.

The pipeline:

    dag   = build_round_dag(tree, kappas, ...)          # sim.dag
    costs = calibrate.from_workload(paper_workload(..)) # sim.calibrate
    net   = NetworkSpec(...).build(tree)                # sim.distributions
    res   = simulate_round(dag, costs, net, trials=200)
    res.summary()   # p50/p90/p99 round time, per-client energy, ...

Durations are assembled in two stages so that every consumer shares one
random world:

1. ``draw_jitter_tables`` draws per-trial jitter keyed by *canonical*
   ids — (trial, interval, step, client) for compute, (trial, interval,
   client) for uplinks, (trial, interval, node) for higher hops — from
   the ``NetworkModel``'s checkpointable streams. The tables cover the
   full population whether or not a client participates, so a draw never
   depends on cohorts, masks, or the client→edge assignment.
2. ``assemble_durations`` is a pure function (dag, costs, net, tables)
   -> (trials, nodes) float64. Candidate associations re-assemble against
   the *same* tables — common random numbers, so the optimizer compares
   assignments, not noise.

Replay itself comes in two provably identical forms: ``sweep`` (a
vectorized forward pass over the topological order, all trials at once —
the workhorse) and ``replay_once`` (a heap-based event queue for one
trial — the readable reference, used for per-node timelines). Both
consume the same duration matrix, so given a seed the output is
bit-identical run to run (the CI determinism gate).

Zero-variance parity: deterministic distributions never touch an RNG and
multiply by exactly 1.0, so the duration of every node is exactly its
calibrated base cost and the sweep reduces to the analytic schedule
algebra (``tests/test_sim.py`` pins both claims).
"""
from __future__ import annotations

import dataclasses
import heapq
from math import prod
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.sim.calibrate import SimCosts
from repro.sim.dag import AGG, HOP, STEP, RoundDag, build_round_dag
from repro.sim.distributions import NetworkModel, NetworkSpec

__all__ = [
    "JitterTables",
    "draw_jitter_tables",
    "assemble_durations",
    "sweep",
    "replay_once",
    "ReplayResult",
    "simulate_round",
    "simulate_spec",
]


# ---------------------------------------------------------------------------
# Stage 1: canonical jitter tables
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class JitterTables:
    """Per-trial multiplicative jitter, canonically keyed.

    compute   (T, R, k1, N) at step granularity, (T, R, N) at interval
              granularity (one factor shared by the interval's k1 steps —
              the ``StragglerModel.interval_latency`` shape)
    link      (T, R, N)   per client uplink
    backhaul  level -> (T, R, n_nodes(level-1)) for levels >= 2
    """

    trials: int
    granularity: str
    compute: np.ndarray
    link: np.ndarray
    backhaul: Dict[int, np.ndarray]


def _draw(dist, trials: int, num_intervals: int, inner: Tuple[int, ...]) -> np.ndarray:
    """Draw (trials, R, *inner) preserving stream order: trial-major,
    interval-inner — one ``sample`` call per (trial, interval), which for
    the straggler calibration is exactly one ``normal(0, sigma, N)`` per
    interval (the ``interval_latency`` stream)."""
    count = int(prod(inner))
    if dist.is_deterministic:
        return np.full((trials, num_intervals) + inner, dist.sample(1)[0], np.float64)
    out = np.empty((trials, num_intervals) + inner, np.float64)
    for t in range(trials):
        for r in range(num_intervals):
            out[t, r] = dist.sample(count).reshape(inner)
    return out


def draw_jitter_tables(net: NetworkModel, tree, kappas, trials: int) -> JitterTables:
    """Consume the net's jitter streams into canonical tables (advances the
    checkpointable RNG state; deterministic from a fresh ``spec.build``)."""
    kv = tuple(int(k) for k in kappas)
    num_intervals = prod(kv[1:]) if len(kv) > 1 else 1
    n = tree.num_clients
    gran = net.jitter_granularity
    inner = (kv[0], n) if gran == "step" else (n,)
    compute = _draw(net.compute_jitter, trials, num_intervals, inner)
    link = _draw(net.link_jitter, trials, num_intervals, (n,))
    backhaul: Dict[int, np.ndarray] = {}
    for ell in range(2, tree.depth + 1):
        backhaul[ell] = _draw(
            net.backhaul_jitter, trials, num_intervals, (tree.num_nodes(ell - 1),)
        )
    return JitterTables(
        trials=trials, granularity=gran, compute=compute, link=link, backhaul=backhaul
    )


# ---------------------------------------------------------------------------
# Stage 2: pure duration assembly
# ---------------------------------------------------------------------------


def assemble_durations(
    dag: RoundDag,
    costs: SimCosts,
    net: Optional[NetworkModel] = None,
    tables: Optional[JitterTables] = None,
    *,
    client_ids: Optional[np.ndarray] = None,
    capacity: Optional[np.ndarray] = None,
) -> np.ndarray:
    """(trials, nodes) float64 durations. Pure — re-assembling against the
    same tables gives identical rows (the common-random-numbers contract).

    client_ids  canonical id of each of the dag spec's client slots
                (identity unless the tree was re-sorted by the association
                optimizer); nets and tables are keyed by canonical ids
    capacity    per-edge nominal uplink capacity for the contention term
                ``n_e / cap_e`` (default: the current per-edge load, i.e.
                a factor of exactly 1 — the parity-safe reading)
    """
    if costs.depth != dag.spec.depth:
        raise ValueError(
            f"SimCosts has {costs.depth} levels, tree has depth {dag.spec.depth}"
        )
    trials = tables.trials if tables is not None else 1
    n = dag.num_nodes
    dur = np.zeros((trials, n), np.float64)
    if client_ids is None:
        canon = dag.client.astype(np.int64)  # already canonical
    else:
        client_ids = np.asarray(client_ids, np.int64)
        canon = np.where(dag.client >= 0, client_ids[np.maximum(dag.client, 0)], -1)

    steps = np.where(dag.kind == STEP)[0]
    if steps.size:
        c = canon[steps]
        r = dag.interval[steps].astype(np.int64)
        base = costs.t_step * (net.client_speed[c] if net is not None else 1.0)
        if tables is not None:
            if tables.granularity == "step":
                s = dag.step[steps].astype(np.int64)
                base = base * tables.compute[:, r, s, c]
            else:
                base = base * tables.compute[:, r, c]
        dur[:, steps] = base

    seg1 = dag.spec.segments(1)
    up = np.where((dag.kind == HOP) & (dag.level == 1))[0]
    if up.size:
        c = canon[up]
        slot = dag.entity[up].astype(np.int64)
        e = seg1[dag.cohort[slot]]  # edge under the *current* assignment
        base = np.full(up.size, costs.link_t[0], np.float64)
        if net is not None:
            base = base * net.client_link[c] * net.edge_uplink[e]
            if net.contention:
                load = np.bincount(seg1[dag.cohort], minlength=dag.spec.num_nodes(1))
                cap = (
                    load.astype(np.float64)
                    if capacity is None
                    else np.asarray(capacity, np.float64)
                )
                if np.any(cap <= 0):
                    raise ValueError("edge capacities must be positive")
                base = base * (load[e] / cap[e])
        if tables is not None:
            r = dag.interval[up].astype(np.int64)
            base = base * tables.link[:, r, c]
        dur[:, up] = base

    for ell in range(2, dag.spec.depth + 1):
        hops = np.where((dag.kind == HOP) & (dag.level == ell))[0]
        if not hops.size:
            continue
        src = dag.entity[hops].astype(np.int64)  # global tier-(ell-1) id
        base = np.full(hops.size, costs.link_t[ell - 1], np.float64)
        if net is not None and ell == 2:
            base = base * net.edge_backhaul[src]
        if tables is not None:
            r = dag.interval[hops].astype(np.int64)
            base = base * tables.backhaul[ell][:, r, src]
        dur[:, hops] = base

    for ell in range(1, dag.spec.depth + 1):
        aggs = np.where((dag.kind == AGG) & (dag.level == ell))[0]
        if aggs.size:
            dur[:, aggs] = costs.agg_t[ell - 1]
    return dur


# ---------------------------------------------------------------------------
# Replay
# ---------------------------------------------------------------------------


def sweep(dag: RoundDag, durations: np.ndarray) -> np.ndarray:
    """Vectorized forward pass over the topological order: all trials at
    once; ``finish[:, i] = max(finish[:, preds_i]) + dur[:, i]``."""
    trials, n = durations.shape
    fin = np.zeros((trials, n), np.float64)
    for i, ps in enumerate(dag.preds):
        start = fin[:, ps].max(axis=1) if ps.size else np.zeros(trials)
        fin[:, i] = start + durations[:, i]
    return fin


def replay_once(dag: RoundDag, durations_row: np.ndarray) -> np.ndarray:
    """Heap-based discrete-event replay of one trial — the reference
    implementation ``sweep`` must match bit-for-bit (tested). Returns the
    (nodes,) finish times."""
    n = dag.num_nodes
    succs: List[List[int]] = [[] for _ in range(n)]
    indeg = np.zeros(n, np.int64)
    for i, ps in enumerate(dag.preds):
        indeg[i] = ps.size
        for p in ps:
            succs[int(p)].append(i)
    ready = np.zeros(n, np.float64)  # max finish over resolved preds
    fin = np.zeros(n, np.float64)
    heap = [(float(durations_row[i]), i) for i in range(n) if indeg[i] == 0]
    heapq.heapify(heap)
    done = 0
    while heap:
        t, i = heapq.heappop(heap)
        fin[i] = t
        done += 1
        for j in succs[i]:
            ready[j] = max(ready[j], t)
            indeg[j] -= 1
            if indeg[j] == 0:
                heapq.heappush(heap, (float(ready[j] + durations_row[j]), j))
    if done != n:
        raise RuntimeError("cycle in round DAG")  # pragma: no cover
    return fin


def _node_energy(dag: RoundDag, costs: SimCosts, durations: np.ndarray) -> np.ndarray:
    """(trials, nodes) device energy: constant-power scaling, so a node
    that runs ``dur/base`` times longer burns that much more energy — and
    at factor exactly 1 each node costs exactly its calibrated joules
    (the energy half of the parity contract). Only client compute and the
    level-1 radio upload draw device energy (the Table II reading)."""
    e = np.zeros_like(durations)
    steps = np.where(dag.kind == STEP)[0]
    if steps.size and costs.e_step > 0.0:
        if costs.t_step > 0.0:
            e[:, steps] = costs.e_step * (durations[:, steps] / costs.t_step)
        else:
            e[:, steps] = costs.e_step
    up = np.where((dag.kind == HOP) & (dag.level == 1))[0]
    if up.size and costs.e_uplink > 0.0:
        if costs.link_t[0] > 0.0:
            e[:, up] = costs.e_uplink * (durations[:, up] / costs.link_t[0])
        else:
            e[:, up] = costs.e_uplink
    return e


@dataclasses.dataclass
class ReplayResult:
    """One cloud interval replayed over ``trials`` random worlds."""

    dag: RoundDag
    durations: np.ndarray  # (T, n)
    finish: np.ndarray  # (T, n)
    energy: np.ndarray  # (T, n)

    @property
    def trials(self) -> int:
        return self.durations.shape[0]

    @property
    def round_time(self) -> np.ndarray:
        """(T,) cloud-interval wall-clock — the sink's finish time."""
        return self.finish[:, self.dag.sink]

    @property
    def client_energy(self) -> np.ndarray:
        """(T, C) device energy per cohort slot."""
        t, c = self.trials, int(self.dag.cohort.size)
        acc = np.zeros((c, t), np.float64)
        owned = np.where(
            (self.dag.kind == STEP) | ((self.dag.kind == HOP) & (self.dag.level == 1))
        )[0]
        if owned.size:
            np.add.at(acc, self.dag.entity[owned].astype(np.int64), self.energy[:, owned].T)
        return acc.T

    def percentiles(self, qs=(50.0, 90.0, 99.0)) -> Dict[str, float]:
        rt = self.round_time
        out = {f"p{q:g}_s": float(np.percentile(rt, q)) for q in qs}
        out["mean_s"] = float(rt.mean())
        out["max_s"] = float(rt.max())
        return out

    def summary(self) -> Dict[str, object]:
        ce = self.client_energy
        per_client = ce.sum(axis=0) / max(self.trials, 1)  # mean over trials
        return {
            "trials": self.trials,
            "nodes": self.dag.counts(),
            "round_time": self.percentiles(),
            "energy_per_client_j": {
                "mean": float(per_client.mean()),
                "max": float(per_client.max()),
                "p99_pooled": float(np.percentile(ce, 99.0)) if ce.size else 0.0,
            },
        }

    def cdf(self, points: int = 32) -> Dict[str, list]:
        """The round-time CDF at evenly spaced quantiles — plot-ready."""
        qs = np.linspace(0.0, 100.0, points)
        return {
            "quantile": [float(q) / 100.0 for q in qs],
            "round_time_s": [float(v) for v in np.percentile(self.round_time, qs)],
        }

    def timeline(self, trial: int = 0) -> List[Dict[str, object]]:
        """Per-node (start, finish) for one trial — gantt-style debugging."""
        kinds = {STEP: "step", HOP: "hop", AGG: "agg"}
        fin = self.finish[trial]
        dur = self.durations[trial]
        return [
            {
                "node": i,
                "kind": kinds[int(self.dag.kind[i])],
                "level": int(self.dag.level[i]),
                "entity": int(self.dag.entity[i]),
                "client": int(self.dag.client[i]),
                "interval": int(self.dag.interval[i]),
                "start": float(fin[i] - dur[i]),
                "finish": float(fin[i]),
            }
            for i in range(self.dag.num_nodes)
        ]


def simulate_round(
    dag: RoundDag,
    costs: SimCosts,
    net: Optional[NetworkModel] = None,
    *,
    trials: int = 1,
    tables: Optional[JitterTables] = None,
    client_ids: Optional[np.ndarray] = None,
    capacity: Optional[np.ndarray] = None,
) -> ReplayResult:
    """Replay one cloud interval ``trials`` times. Draws fresh jitter
    tables from ``net`` unless given pre-drawn ``tables`` (the
    common-random-numbers path used by the association optimizer)."""
    if tables is None and net is not None:
        tables = draw_jitter_tables(net, dag.spec, dag.kappas, trials)
    dur = assemble_durations(
        dag, costs, net, tables, client_ids=client_ids, capacity=capacity
    )
    fin = sweep(dag, dur)
    return ReplayResult(dag=dag, durations=dur, finish=fin, energy=_node_energy(dag, costs, dur))


def simulate_spec(spec, *, trials: int = 1) -> ReplayResult:
    """Convenience: replay an ``ExperimentSpec`` — tree and κ from its
    topology/schedule, transport bits from its transport section, the cost
    workload from its cost section, network distributions from its
    ``network`` section, and the interval-0 cohort from participation."""
    from repro.core.hierarchy import as_hierarchy
    from repro.sim import calibrate

    tree = as_hierarchy(spec.topology.build())
    kappas = tuple(spec.schedule.kappas)
    costs = spec.cost.build()
    if costs is None:
        raise ValueError("cost.workload='none' — nothing to calibrate the replay from")
    transport = spec.transport.build(tree.depth)  # None when trivial (fp32)
    bits = transport.bits_vector() if transport is not None else None
    sim_costs = calibrate.from_workload(costs, tree.depth, bits_per_param=bits)
    net = spec.network.build(tree) if spec.network.is_active else None
    cohort = None
    if spec.participation.is_active:
        cohort = np.asarray(spec.participation.build_sampler(tree).sample(), np.int64)
    dag = build_round_dag(tree, kappas, cohort=cohort)
    return simulate_round(dag, sim_costs, net, trials=trials)
