"""Seed the replay's node costs from the repo's analytic models.

The parity contract (the reason this module exists): under a zero-variance
``NetworkSpec`` the replay must reduce to the analytic schedule algebra,

    from_workload(costs)  ->  replay == cloud_interval_time / _energy
    from_cluster(costs)   ->  replay == ClusterCosts.interval_time

to float64 machine precision (the only difference left is summation
order: the DAG accumulates ``t_comp`` κ₁κ₂ times where the closed form
multiplies once — a few hundred rounding steps, bounded well below 1e-12
relative; ``tests/test_sim.py`` pins it). To keep that exact, the level-L
(backhaul) base cost is computed with the *same expression* the analytic
model uses, ``(cloud_latency_mult - 1.0) * t_comm_edge`` — the paper
reads the cloud hop as overlapping one edge-period of it.

Calibration sources:

* ``from_workload`` — ``WorkloadCosts`` / ``paper_workload`` (Table I),
  with per-level transport bit-widths applied through
  ``WorkloadCosts.with_bits`` (depth 2) or raw ``bits/32`` wire scaling
  (deeper trees, where no closed form exists).
* ``from_cluster`` — ``ClusterCosts`` (normally filled from
  ``analysis.roofline`` terms): collective times sit on the AGG nodes,
  links are free (the all-reduce *is* the transfer).
* ``from_roofline`` — convenience: ``RooflineTerms`` -> ``ClusterCosts``
  -> ``from_cluster``.
* ``straggler_network`` — satellite: prices the DES's client compute from
  ``fed.failures.StragglerModel``'s *own* distribution (same slowness
  array, same RNG stream), so the deadline-mask path and the replay can
  never drift apart.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Sequence, Tuple

import numpy as np

from repro.core.cost_model import ClusterCosts, WorkloadCosts
from repro.sim.distributions import LogNormalDist, NetworkModel, NetworkSpec

__all__ = [
    "SimCosts",
    "from_workload",
    "from_cluster",
    "from_roofline",
    "straggler_network",
    "straggler_masks",
]


@dataclasses.dataclass(frozen=True)
class SimCosts:
    """Base (pre-distribution) cost of each DAG node kind.

    t_step    one local step (s);  e_step  its device energy (J)
    link_t    per-level hop time, ``link_t[ell-1]`` for a level-ell HOP
              (level 1 = client uplink, level depth = backhaul)
    agg_t     per-level aggregation time (0 for the wireless model —
              server-side math is free next to the radio; the collective
              times for the cluster model)
    e_uplink  client radio energy per level-1 upload (J); higher hops are
              backhaul and cost no device energy (the Table II reading)
    """

    t_step: float
    e_step: float
    link_t: Tuple[float, ...]
    agg_t: Tuple[float, ...]
    e_uplink: float = 0.0

    def __post_init__(self):
        if len(self.link_t) != len(self.agg_t):
            raise ValueError("link_t and agg_t must have one entry per tree level")
        if not self.link_t:
            raise ValueError("need at least one tree level")

    @property
    def depth(self) -> int:
        return len(self.link_t)


def _bits_vector(depth: int, bits_per_param) -> Tuple[float, ...]:
    if bits_per_param is None:
        return (32.0,) * depth
    if isinstance(bits_per_param, (int, float)):
        return (float(bits_per_param),) * depth
    bits = tuple(float(b) for b in bits_per_param)
    if len(bits) != depth:
        raise ValueError(f"bits_per_param has {len(bits)} entries for depth {depth}")
    if any(b <= 0 for b in bits):
        raise ValueError(f"bits per parameter must be positive, got {bits}")
    return bits


def from_workload(
    costs: WorkloadCosts, depth: int = 2, *, bits_per_param=None
) -> SimCosts:
    """Calibrate from a Table I workload (``core.cost_model``).

    ``bits_per_param`` — scalar or one entry per level (the
    ``TransportSpec.bits_vector()`` convention: entry ell-1 is the wire
    width of level-ell uploads). Depth 2 routes through
    ``WorkloadCosts.with_bits`` so parity against the compressed analytic
    model is exact; deeper trees scale each hop by ``bits/32``.
    """
    if depth < 1:
        raise ValueError(f"depth must be >= 1, got {depth}")
    bits = _bits_vector(depth, bits_per_param)
    if depth == 1:
        b = costs.with_bits(bits[0], 32.0)
        link = (b.t_comm_edge,)
        e_up = b.e_comm_edge
    elif depth == 2:
        b = costs.with_bits(bits[0], bits[1])
        # exactly the closed form's terms: kappa2 uplinks at t_comm_edge
        # plus (mult-1) extra edge-periods for the backhaul
        link = (b.t_comm_edge, (b.cloud_latency_mult - 1.0) * b.t_comm_edge)
        e_up = b.e_comm_edge
    else:
        # no closed form above depth 2 — price every hop as a wire
        # transfer at the edge rate, top hop keeping the paper's
        # (mult-1) overlap reading
        scaled = [costs.t_comm_edge * b / 32.0 for b in bits]
        scaled[-1] *= costs.cloud_latency_mult - 1.0
        link = tuple(scaled)
        e_up = costs.e_comm_edge * bits[0] / 32.0
    return SimCosts(
        t_step=costs.t_comp,
        e_step=costs.e_comp,
        link_t=link,
        agg_t=(0.0,) * depth,
        e_uplink=e_up,
    )


def from_cluster(costs: ClusterCosts, depth: int = 2, *, bits_per_param=None) -> SimCosts:
    """Calibrate from TPU-cluster collective times (``analysis.roofline``):
    the all-reduce *is* the transfer, so aggregation nodes carry the time
    and hops are free. Intermediate levels of deeper trees price at the
    edge (ICI) rate. No device-energy notion on the cluster."""
    if depth < 1:
        raise ValueError(f"depth must be >= 1, got {depth}")
    bits = _bits_vector(depth, bits_per_param)
    if depth <= 2:
        b = costs.with_bits(bits[0], bits[-1])
        agg = (b.t_edge_agg,) if depth == 1 else (b.t_edge_agg, b.t_cloud_agg)
    else:
        agg = tuple(
            (costs.t_cloud_agg if ell == depth else costs.t_edge_agg) * bits[ell - 1] / 32.0
            for ell in range(1, depth + 1)
        )
    return SimCosts(
        t_step=costs.t_step,
        e_step=0.0,
        link_t=(0.0,) * depth,
        agg_t=agg,
        e_uplink=0.0,
    )


def from_roofline(step, edge, cloud, depth: int = 2, *, bits_per_param=None) -> SimCosts:
    """``RooflineTerms`` for (local step, edge agg, cloud agg) -> SimCosts."""
    cluster = ClusterCosts(
        t_step=step.bound_s,
        t_edge_agg=edge.collective_s if edge is not None else 0.0,
        t_cloud_agg=cloud.collective_s if cloud is not None else 0.0,
    )
    return from_cluster(cluster, depth, bits_per_param=bits_per_param)


# ---------------------------------------------------------------------------
# Straggler calibration (satellite): one distribution for mask + DES paths
# ---------------------------------------------------------------------------


def straggler_network(model, tree) -> NetworkModel:
    """A :class:`NetworkModel` that prices client compute from a
    ``fed.failures.StragglerModel`` — *sharing* its slowness array and its
    RNG stream, not copying parameters.

    With ``jitter_granularity="interval"`` the replay draws exactly one
    ``(C,)`` lognormal per level-1 interval — the same
    ``normal(0, sigma, N)`` call ``StragglerModel.interval_latency``
    makes — so when ``SimCosts.t_step == model.mean_step_s`` and the
    cohort is the full population, per-client interval compute times in
    the replay are bit-identical to ``interval_latency(kappa1)`` draws
    from the same model state (pinned in ``tests/test_sim.py``). Use a
    dedicated model instance per consumer: masks (``survivors``) and
    timing draws interleave on one shared stream.
    """
    spec = NetworkSpec(
        compute_jitter=f"lognormal:{float(model.sigma)}",
        jitter_granularity="interval",
        seed=int(model.seed),
    )
    net = spec.build(tree)
    if model.num_clients != tree.num_clients:
        raise ValueError(
            f"StragglerModel has {model.num_clients} clients, tree has {tree.num_clients}"
        )
    net.client_speed = np.asarray(model.slowness, np.float64)
    jitter = LogNormalDist(float(model.sigma))
    jitter._rng = model._rng  # share the stream — the no-drift guarantee
    net.compute_jitter = jitter
    return net


def straggler_masks(
    model,
    kappa1: int,
    num_intervals: int,
    *,
    deadline: Optional[float] = None,
    cohort: Optional[Sequence[int]] = None,
) -> np.ndarray:
    """(R, C) deadline masks for ``build_round_dag`` drawn from the same
    ``StragglerModel`` the runner uses (``survivors`` per boundary)."""
    rows = []
    for _ in range(num_intervals):
        mask, _ = model.survivors(kappa1, deadline)
        rows.append(mask if cohort is None else mask[np.asarray(cohort)])
    return np.stack(rows).astype(bool)
