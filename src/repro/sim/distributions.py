"""Seeded, checkpointable cost distributions for the round-replay simulator.

The analytic cost model (``core.cost_model``) prices every client, link,
and backhaul identically — it answers "what does one round cost", never
"what is the p99 round time when 10% of edges sit on a congested
backhaul". This module owns the stochastic half of that gap:

* ``Distribution`` — a seeded multiplicative-factor distribution with
  ``state_dict``/``load_state_dict`` (the PCG64 state survives a JSON
  round-trip, same contract as the cohort samplers), so a checkpointed
  replay resumes bit-exactly.
* ``NetworkSpec`` — the serializable ``ExperimentSpec`` section naming one
  distribution per cost axis (persistent per-client/per-edge factors +
  per-draw jitter), in a small CLI grammar:

      det            deterministic 1.0 (the analytic model)
      det:2.5        deterministic factor 2.5
      lognormal:0.3  exp(N(0, 0.3)), median 1
      mixture:0.9@1,0.1@8
                     10% of entities draw an 8x factor (congested tail)

All factors are *multiplicative* with a deterministic value of exactly
1.0, so a zero-variance ``NetworkSpec()`` leaves every calibrated cost
bit-identical — the replay then reduces to the analytic model (the parity
contract tested in ``tests/test_sim.py``).

Pure numpy on purpose: ``fed.api`` imports ``NetworkSpec`` into the spec
tree, so this module must not pull in jax.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Sequence

import numpy as np

__all__ = [
    "Distribution",
    "DeterministicDist",
    "LogNormalDist",
    "MixtureDist",
    "parse_distribution",
    "NetworkSpec",
    "NetworkModel",
]


# ---------------------------------------------------------------------------
# Distributions
# ---------------------------------------------------------------------------


class Distribution:
    """A seeded multiplicative-factor distribution.

    ``sample(n)`` returns an (n,) float64 array of factors; deterministic
    distributions never touch an RNG, so their draws are exactly their
    value (no float noise — the zero-variance parity contract depends on
    this).
    """

    kind = "base"

    def sample(self, n: int) -> np.ndarray:  # pragma: no cover - abstract
        raise NotImplementedError

    def mean(self) -> float:  # pragma: no cover - abstract
        raise NotImplementedError

    @property
    def is_deterministic(self) -> bool:
        return False

    def state_dict(self) -> Dict[str, Any]:
        return {"kind": self.kind}

    def load_state_dict(self, state: Dict[str, Any]) -> None:
        if state.get("kind") != self.kind:
            raise ValueError(f"state is for {state.get('kind')!r}, not {self.kind!r}")


@dataclasses.dataclass
class DeterministicDist(Distribution):
    """A constant factor — ``det`` (1.0) is the analytic model."""

    value: float = 1.0
    kind = "det"

    def __post_init__(self):
        if self.value <= 0:
            raise ValueError(f"det factor must be positive, got {self.value}")

    def sample(self, n: int) -> np.ndarray:
        return np.full(n, float(self.value), np.float64)

    def mean(self) -> float:
        return float(self.value)

    @property
    def is_deterministic(self) -> bool:
        return True


class LogNormalDist(Distribution):
    """``exp(N(0, sigma)) * median`` — median ``median``, heavy right tail."""

    kind = "lognormal"

    def __init__(self, sigma: float, median: float = 1.0, seed: int = 0):
        if sigma <= 0:
            raise ValueError(f"lognormal sigma must be positive, got {sigma}")
        if median <= 0:
            raise ValueError(f"lognormal median must be positive, got {median}")
        self.sigma = float(sigma)
        self.median = float(median)
        self._rng = np.random.default_rng(seed)

    def sample(self, n: int) -> np.ndarray:
        return self.median * np.exp(self._rng.normal(0.0, self.sigma, n))

    def mean(self) -> float:
        return self.median * float(np.exp(self.sigma**2 / 2.0))

    def state_dict(self) -> Dict[str, Any]:
        return {"kind": self.kind, "sigma": self.sigma, "median": self.median,
                "rng": self._rng.bit_generator.state}

    def load_state_dict(self, state: Dict[str, Any]) -> None:
        super().load_state_dict(state)
        self._rng.bit_generator.state = state["rng"]


class MixtureDist(Distribution):
    """A finite mixture of constant factors: ``mixture:0.9@1,0.1@8`` gives
    10% of draws an 8x factor — the congested-tail model."""

    kind = "mixture"

    def __init__(self, weights: Sequence[float], factors: Sequence[float], seed: int = 0):
        w = np.asarray(weights, np.float64)
        f = np.asarray(factors, np.float64)
        if w.shape != f.shape or w.ndim != 1 or w.size == 0:
            raise ValueError("mixture needs matching 1-d weights and factors")
        if np.any(w < 0) or not np.isclose(w.sum(), 1.0, atol=1e-9):
            raise ValueError(f"mixture weights must be >= 0 and sum to 1, got {w}")
        if np.any(f <= 0):
            raise ValueError(f"mixture factors must be positive, got {f}")
        self.weights = w / w.sum()
        self.factors = f
        self._rng = np.random.default_rng(seed)

    def sample(self, n: int) -> np.ndarray:
        idx = self._rng.choice(self.factors.size, size=n, p=self.weights)
        return self.factors[idx]

    def mean(self) -> float:
        return float(np.dot(self.weights, self.factors))

    def state_dict(self) -> Dict[str, Any]:
        return {"kind": self.kind, "weights": self.weights.tolist(),
                "factors": self.factors.tolist(), "rng": self._rng.bit_generator.state}

    def load_state_dict(self, state: Dict[str, Any]) -> None:
        super().load_state_dict(state)
        self._rng.bit_generator.state = state["rng"]


def parse_distribution(text: str, *, seed: int = 0) -> Distribution:
    """Parse the NetworkSpec grammar: ``det[:V]``, ``lognormal:SIGMA[:MEDIAN]``,
    ``mixture:W@F,W@F,...``."""
    name, _, args = text.strip().partition(":")
    try:
        if name == "det":
            return DeterministicDist(float(args)) if args else DeterministicDist()
        if name == "lognormal":
            parts = args.split(":")
            if not args or len(parts) > 2:
                raise ValueError("lognormal needs SIGMA[:MEDIAN]")
            sigma = float(parts[0])
            median = float(parts[1]) if len(parts) == 2 else 1.0
            return LogNormalDist(sigma, median, seed=seed)
        if name == "mixture":
            weights, factors = [], []
            for comp in args.split(","):
                w, at, f = comp.partition("@")
                if not at:
                    raise ValueError(f"mixture component {comp!r} must be WEIGHT@FACTOR")
                weights.append(float(w))
                factors.append(float(f))
            return MixtureDist(weights, factors, seed=seed)
    except ValueError as e:
        raise ValueError(f"bad distribution {text!r}: {e}") from None
    raise ValueError(
        f"unknown distribution {text!r}; grammar: det[:V] | lognormal:SIGMA[:MEDIAN] "
        f"| mixture:W@F,W@F,..."
    )


# ---------------------------------------------------------------------------
# NetworkSpec: the ExperimentSpec section
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class NetworkSpec:
    """Per-entity cost distributions for the round-replay simulator
    (``repro.sim``). Inert for training — the runner never reads it; the
    sim benches (``benchmarks/round_time_sim.py``) build a
    :class:`NetworkModel` from it.

    Persistent factors (drawn once per entity at build — heterogeneous
    hardware / provisioned links):

        client_speed   per-client compute-time factor
        client_link    per-client uplink factor
        edge_uplink    per-edge factor on every client→edge upload
        edge_backhaul  per-edge factor on the edge→cloud (level-2) hop

    Per-draw jitter (sampled per DAG node during replay — load spikes,
    channel fading):

        compute_jitter   per client-step (or per edge interval, see
                         ``jitter_granularity``) compute-time factor
        link_jitter      per client upload
        backhaul_jitter  per hop at levels >= 2

    ``contention=True`` scales each client's uplink by ``n_e / cap_e``
    (clients sharing edge e's band / its nominal capacity) — under the
    tree's own association every factor is exactly 1, so the parity
    contract is unaffected; the association optimizer trades this load
    term against the persistent link factors (the HFEL knob).
    """

    client_speed: str = "det"
    client_link: str = "det"
    edge_uplink: str = "det"
    edge_backhaul: str = "det"
    compute_jitter: str = "det"
    link_jitter: str = "det"
    backhaul_jitter: str = "det"
    contention: bool = False
    jitter_granularity: str = "step"  # step | interval
    seed: int = 0

    def __post_init__(self):
        if self.jitter_granularity not in ("step", "interval"):
            raise ValueError(
                f"jitter_granularity must be step|interval, got {self.jitter_granularity!r}"
            )
        for f in dataclasses.fields(self):
            if f.type == "str" and f.name != "jitter_granularity":
                parse_distribution(getattr(self, f.name))  # validate eagerly

    @property
    def is_active(self) -> bool:
        """True when any axis deviates from the analytic model."""
        default = NetworkSpec()
        return any(
            getattr(self, f.name) != getattr(default, f.name)
            for f in dataclasses.fields(self)
            if f.name != "seed"
        )

    def build(self, tree) -> "NetworkModel":
        """Draw the persistent factors for ``tree`` (a ``HierarchySpec``)
        and seed the jitter streams. Deterministic under ``seed``."""
        return NetworkModel.build(self, tree)

    def describe(self) -> str:
        default = NetworkSpec()
        tags = [
            f"{f.name}={getattr(self, f.name)}"
            for f in dataclasses.fields(self)
            if f.name != "seed" and getattr(self, f.name) != getattr(default, f.name)
        ]
        return " ".join(tags) if tags else "det"


# stream salts: every axis gets an independent, reproducible PCG64 stream
_STREAMS = {
    "client_speed": 1, "client_link": 2, "edge_uplink": 3, "edge_backhaul": 4,
    "compute_jitter": 5, "link_jitter": 6, "backhaul_jitter": 7,
}


@dataclasses.dataclass
class NetworkModel:
    """The built form of :class:`NetworkSpec`: persistent factor arrays
    (fixed after build) + live jitter distributions (checkpointable)."""

    spec: NetworkSpec
    client_speed: np.ndarray  # (N,)
    client_link: np.ndarray  # (N,)
    edge_uplink: np.ndarray  # (E,)
    edge_backhaul: np.ndarray  # (E,)
    compute_jitter: Distribution
    link_jitter: Distribution
    backhaul_jitter: Distribution

    @classmethod
    def build(cls, spec: NetworkSpec, tree) -> "NetworkModel":
        n = tree.num_clients
        e = tree.num_nodes(1) if tree.depth >= 1 else 1

        def persistent(field: str, count: int) -> np.ndarray:
            d = parse_distribution(getattr(spec, field), seed=(spec.seed, _STREAMS[field]))
            return d.sample(count)

        def jitter(field: str) -> Distribution:
            return parse_distribution(getattr(spec, field), seed=(spec.seed, _STREAMS[field]))

        return cls(
            spec=spec,
            client_speed=persistent("client_speed", n),
            client_link=persistent("client_link", n),
            edge_uplink=persistent("edge_uplink", e),
            edge_backhaul=persistent("edge_backhaul", e),
            compute_jitter=jitter("compute_jitter"),
            link_jitter=jitter("link_jitter"),
            backhaul_jitter=jitter("backhaul_jitter"),
        )

    @property
    def contention(self) -> bool:
        return self.spec.contention

    @property
    def jitter_granularity(self) -> str:
        return self.spec.jitter_granularity

    def state_dict(self) -> Dict[str, Any]:
        """The live RNG state (jitter streams). Persistent factors are a
        pure function of (spec, tree) and rebuild identically."""
        return {
            "compute_jitter": self.compute_jitter.state_dict(),
            "link_jitter": self.link_jitter.state_dict(),
            "backhaul_jitter": self.backhaul_jitter.state_dict(),
        }

    def load_state_dict(self, state: Dict[str, Any]) -> None:
        self.compute_jitter.load_state_dict(state["compute_jitter"])
        self.link_jitter.load_state_dict(state["link_jitter"])
        self.backhaul_jitter.load_state_dict(state["backhaul_jitter"])
