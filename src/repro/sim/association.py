"""HFEL-style client→edge association on top of the replay simulator.

Once per-client and per-edge cost distributions exist (``NetworkSpec``),
which edge a client reports to stops being an accident of the tree and
becomes an optimizable resource-allocation knob — the core observation of
HFEL (arXiv 2002.11343): move clients off slow/congested edges, trade
uplink contention against link quality, and the tail round time drops.

This module searches assignments with the replay itself as the objective
(no surrogate model): greedy initialization by expected chain cost, then
local search over the bottleneck clients. Every candidate is evaluated
under **common random numbers** — one set of canonically-keyed jitter
tables drawn up front (``draw_jitter_tables``), every assignment
re-assembled against it — so the optimizer compares assignments, not
noise, and the reported before/after numbers are paired.

Constraints, per HFEL: a per-edge capacity ``cap_e`` (default: the
incumbent group sizes, so the incumbent is always feasible) and every
edge keeps at least one client (``HierarchySpec`` requires dense parent
ids — an emptied edge would change the tree shape under the schedule).

The result plugs straight back into the hierarchy: ``HierarchySpec``
requires non-decreasing parent ids, so a new assignment implies a client
*permutation* (``client_order``: canonical id per new slot). Data/state
stores keyed by client id must be re-indexed through it — the sim keys
its nets and tables canonically for exactly this reason.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.core.hierarchy import HierarchySpec, as_hierarchy
from repro.sim.calibrate import SimCosts
from repro.sim.dag import build_round_dag
from repro.sim.distributions import NetworkModel
from repro.sim.replay import JitterTables, assemble_durations, draw_jitter_tables, sweep

__all__ = ["AssociationResult", "assignment_to_spec", "optimize_association"]


def assignment_to_spec(
    assignment: np.ndarray, base: HierarchySpec
) -> Tuple[HierarchySpec, np.ndarray]:
    """(assignment[c] = edge id per canonical client) -> a valid sorted
    ``HierarchySpec`` plus ``client_order`` (canonical id per new slot).

    Stable sort by edge keeps within-edge canonical order, so the default
    assignment round-trips to the identity permutation."""
    assignment = np.asarray(assignment, np.int64)
    n_edges = base.num_nodes(1)
    if assignment.shape != (base.num_clients,):
        raise ValueError(f"assignment must be ({base.num_clients},), got {assignment.shape}")
    if assignment.min() < 0 or assignment.max() >= n_edges:
        raise ValueError(f"edge ids must be in 0..{n_edges - 1}")
    if np.unique(assignment).size != n_edges:
        raise ValueError("every edge must keep at least one client")
    order = np.argsort(assignment, kind="stable")
    parents0 = tuple(int(e) for e in assignment[order])
    spec = HierarchySpec(parents=(parents0,) + base.parents[1:])
    return spec, order


@dataclasses.dataclass
class AssociationResult:
    assignment: np.ndarray  # (N,) edge id per canonical client
    spec: HierarchySpec  # the re-sorted tree to run with
    client_order: np.ndarray  # (N,) canonical client id per new slot
    objective: str
    value_before: float
    value_after: float
    moves: List[Tuple[int, int, int]]  # (client, from_edge, to_edge)
    evals: int

    @property
    def improvement(self) -> float:
        """Relative reduction of the objective (0.12 = 12% better)."""
        if self.value_before <= 0:
            return 0.0
        return 1.0 - self.value_after / self.value_before

    def to_dict(self) -> Dict[str, object]:
        return {
            "objective": self.objective,
            "value_before": self.value_before,
            "value_after": self.value_after,
            "improvement": self.improvement,
            "moves": [list(m) for m in self.moves],
            "num_moves": len(self.moves),
            "evals": self.evals,
        }


class _Evaluator:
    """Scores an assignment by replaying it against fixed jitter tables."""

    def __init__(self, base, costs, net, tables, kappas, objective, capacity):
        self.base = base
        self.costs = costs
        self.net = net
        self.tables = tables
        self.kappas = kappas
        self.objective = objective
        self.capacity = capacity
        self.evals = 0
        self._cache: Dict[bytes, float] = {}

    def __call__(self, assignment: np.ndarray) -> float:
        key = assignment.tobytes()
        if key in self._cache:
            return self._cache[key]
        spec, order = assignment_to_spec(assignment, self.base)
        dag = build_round_dag(spec, self.kappas)
        dur = assemble_durations(
            dag, self.costs, self.net, self.tables,
            client_ids=order, capacity=self.capacity,
        )
        fin = sweep(dag, dur)
        if self.objective == "p99_time":
            val = float(np.percentile(fin[:, dag.sink], 99.0))
        else:  # energy: mean per-client device energy
            from repro.sim.replay import ReplayResult, _node_energy

            res = ReplayResult(dag, dur, fin, _node_energy(dag, self.costs, dur))
            val = float(res.client_energy.mean())
        self.evals += 1
        self._cache[key] = val
        return val


def _chain_cost(costs: SimCosts, net: NetworkModel, kappa1: int) -> np.ndarray:
    """Expected per-interval cost of each client's serial chain (compute +
    uplink, persistent factors and jitter means) — the greedy sort key."""
    comp = kappa1 * costs.t_step * net.client_speed * net.compute_jitter.mean()
    up = costs.link_t[0] * net.client_link * net.link_jitter.mean()
    return comp + up


def optimize_association(
    tree,
    costs: SimCosts,
    net: NetworkModel,
    kappas,
    *,
    objective: str = "p99_time",
    trials: int = 32,
    capacity: Optional[np.ndarray] = None,
    top_k: int = 6,
    max_rounds: int = 8,
    greedy_init: bool = True,
) -> AssociationResult:
    """Greedy + local-search client→edge association (depth-2 trees).

    objective   "p99_time" (p99 cloud-interval wall clock) or "energy"
                (mean per-client device energy)
    capacity    per-edge client capacity (default: incumbent group sizes)
    top_k       bottleneck clients probed per local-search round
    max_rounds  local-search rounds (each accepts the best improving move)
    greedy_init start from a cost-aware greedy assignment instead of the
                incumbent (the incumbent is always evaluated as baseline)
    """
    base = as_hierarchy(tree)
    if base.depth != 2:
        raise ValueError(
            f"association optimization is defined for depth-2 trees, got depth {base.depth}"
        )
    if objective not in ("p99_time", "energy"):
        raise ValueError(f"objective must be p99_time|energy, got {objective!r}")
    n = base.num_clients
    n_edges = base.num_nodes(1)
    incumbent = np.asarray(base.segments(1), np.int64).copy()
    group_sizes = np.bincount(incumbent, minlength=n_edges)
    cap = group_sizes.copy() if capacity is None else np.asarray(capacity, np.int64)
    if cap.shape != (n_edges,) or np.any(cap < 1):
        raise ValueError(f"capacity must be ({n_edges},) positive ints")
    if cap.sum() < n:
        raise ValueError(f"total capacity {int(cap.sum())} < {n} clients")

    tables = draw_jitter_tables(net, base, kappas, trials)
    evaluate = _Evaluator(base, costs, net, tables, tuple(kappas), objective, cap)
    value_before = evaluate(incumbent)

    # -- greedy: place expensive clients first, each on the edge that
    # currently adds the least estimated bottleneck cost ------------------
    chain = _chain_cost(costs, net, int(kappas[0]))
    backhaul = costs.link_t[-1] * net.edge_backhaul * net.backhaul_jitter.mean()
    best_assign = incumbent
    best_value = value_before
    if greedy_init:
        greedy = np.full(n, -1, np.int64)
        load = np.zeros(n_edges, np.int64)
        edge_peak = np.zeros(n_edges, np.float64)  # slowest chain on the edge so far
        up_base = costs.link_t[0] * net.client_link * net.link_jitter.mean()
        for c in np.argsort(-chain, kind="stable"):
            # once the still-empty edges need every remaining client,
            # restrict to them (every edge must end with >= 1 client)
            empty = np.where(load == 0)[0]
            remaining = n - int(load.sum())
            feasible = empty if empty.size == remaining else np.where(load < cap)[0]
            up_e = up_base[c] * net.edge_uplink[feasible]
            if net.contention:
                up_e = up_e * (load[feasible] + 1.0) / cap[feasible]
            comp_c = chain[c] - up_base[c]
            cand = np.maximum(edge_peak[feasible], comp_c + up_e) + backhaul[feasible]
            j = int(np.argmin(cand))
            e = int(feasible[j])
            greedy[c] = e
            load[e] += 1
            edge_peak[e] = max(edge_peak[e], comp_c + float(up_e[j]))
        gv = evaluate(greedy)
        if gv < best_value:
            best_assign, best_value = greedy, gv

    # -- local search: move/swap the most expensive clients ----------------
    assign = best_assign.copy()
    value = best_value
    moves: List[Tuple[int, int, int]] = []
    for _ in range(max_rounds):
        load = np.bincount(assign, minlength=n_edges)
        # bottleneck pressure: chain cost scaled by the edge's factors
        pressure = chain * net.edge_uplink[assign]
        if net.contention:
            pressure = pressure * load[assign] / cap[assign]
        candidates = np.argsort(-pressure, kind="stable")[: int(top_k)]
        best_move = None
        for c in candidates:
            src = int(assign[c])
            for dst in range(n_edges):
                if dst == src:
                    continue
                if load[dst] < cap[dst] and load[src] > 1:
                    trial_assign = assign.copy()
                    trial_assign[c] = dst
                    v = evaluate(trial_assign)
                    if best_move is None or v < best_move[0]:
                        best_move = (v, trial_assign, [(int(c), src, dst)])
                # swap with the cheapest client on dst (capacity-neutral)
                on_dst = np.where(assign == dst)[0]
                if on_dst.size:
                    partner = int(on_dst[int(np.argmin(pressure[on_dst]))])
                    trial_assign = assign.copy()
                    trial_assign[c], trial_assign[partner] = dst, src
                    v = evaluate(trial_assign)
                    if best_move is None or v < best_move[0]:
                        best_move = (
                            v, trial_assign,
                            [(int(c), src, dst), (partner, dst, src)],
                        )
        if best_move is None or best_move[0] >= value:
            break
        value, assign = best_move[0], best_move[1]
        moves.extend(best_move[2])

    if value > value_before:  # never return worse than the incumbent
        assign, value, moves = incumbent, value_before, []
    spec, order = assignment_to_spec(assign, base)
    return AssociationResult(
        assignment=assign,
        spec=spec,
        client_order=order,
        objective=objective,
        value_before=value_before,
        value_after=value,
        moves=moves,
        evals=evaluate.evals,
    )
