"""The per-cloud-interval dependency DAG of the client-edge-cloud system.

One cloud interval of the κ-schedule is a DAG of three node kinds:

    STEP   one local SGD step of one client           (κ₁ per level-1 interval)
    HOP    one upload across one tree link: level 1 = client→edge uplink,
           level depth = the edge(…)→cloud backhaul
    AGG    one aggregation at a tier-ℓ node

For a depth-L tree with κ = (κ₁, …, κ_L) there are R = κ₂·…·κ_L level-1
intervals per cloud interval. Interval r ends at *boundary level*
b(r) — the highest ℓ with (r+1) divisible by κ₂·…·κ_ℓ — and the boundary
runs hops+aggs bottom-up through level b(r). A client's next steps are
gated by the *highest* aggregate that fired at the previous boundary
(restricted to its ancestor there): the broadcast back down is free, the
same reading as the analytic model.

Generalities honored here (the analytic model prices none of them):

* **ragged trees** — any ``HierarchySpec``; aggregates wait for exactly
  their own children.
* **sampled cohorts** — pass ``cohort`` (sorted original client ids, e.g.
  from a ``fed.participation`` sampler): only cohort members get chains,
  and only their ancestor nodes aggregate that interval.
* **straggler masks** — ``masks[r, i] == 0`` excludes cohort member i
  from interval r's aggregation (deadline-based partial aggregation, the
  ``fed.failures.StragglerModel`` contract): it keeps computing (its STEP
  nodes exist and burn energy), its upload is skipped, no aggregate waits
  for it, and its chain continues from its own last step (it keeps its
  local model and rejoins at a later boundary).
* **failure masks** — ``alive[r, i] == 0`` is a dead client
  (``FailureSimulator`` / ``SubtreeOutageSimulator``): no nodes at all
  that interval — no compute time, no energy, nothing gated.

Nodes are emitted in topological order (every predecessor has a smaller
id), so replay is a single forward sweep and the last node is always the
cloud aggregate (the sink).
"""
from __future__ import annotations

import dataclasses
from math import prod
from typing import List, Optional, Tuple

import numpy as np

from repro.core.hierarchy import HierarchySpec, as_hierarchy

__all__ = ["STEP", "HOP", "AGG", "RoundDag", "build_round_dag"]

STEP, HOP, AGG = 0, 1, 2


@dataclasses.dataclass
class RoundDag:
    """One cloud interval as a flat, topologically ordered node list.

    kind      (n,) int8   STEP | HOP | AGG
    level     (n,) int8   tree level (STEP: 0; HOP/AGG: 1..depth)
    entity    (n,) int32  STEP / level-1 HOP: cohort slot index;
                          level-ℓ HOP (ℓ>=2): the *global* tier-(ℓ-1)
                          source node id; AGG: the global tier-ℓ node id
    client    (n,) int32  original client id (STEP / level-1 HOP), else -1
    interval  (n,) int16  level-1 interval index r
    step      (n,) int16  step index within the interval (STEP only, else -1)
    preds     tuple of int32 arrays, preds[i] < i (topological order)
    """

    spec: HierarchySpec
    kappas: Tuple[int, ...]
    cohort: np.ndarray  # (C,) original client ids, sorted
    kind: np.ndarray
    level: np.ndarray
    entity: np.ndarray
    client: np.ndarray
    interval: np.ndarray
    step: np.ndarray
    preds: Tuple[np.ndarray, ...]

    @property
    def num_nodes(self) -> int:
        return int(self.kind.size)

    @property
    def num_intervals(self) -> int:
        return prod(self.kappas[1:]) if len(self.kappas) > 1 else 1

    @property
    def sink(self) -> int:
        """The cloud aggregate — always the last node emitted."""
        return self.num_nodes - 1

    def counts(self) -> dict:
        return {
            "nodes": self.num_nodes,
            "steps": int(np.sum(self.kind == STEP)),
            "hops": int(np.sum(self.kind == HOP)),
            "aggs": int(np.sum(self.kind == AGG)),
        }


def _boundary_level(r: int, kappas: Tuple[int, ...]) -> int:
    """Highest level ℓ whose aggregation fires at the end of interval r."""
    level = 1
    period = 1
    for ell in range(2, len(kappas) + 1):
        period *= kappas[ell - 1]
        if (r + 1) % period == 0:
            level = ell
    return level


def _check_mask(name: str, m, num_intervals: int, c_count: int) -> np.ndarray:
    m = np.asarray(m)
    if m.shape != (num_intervals, c_count):
        raise ValueError(
            f"{name} must be ({num_intervals}, {c_count}) "
            f"(level-1 intervals x cohort), got {m.shape}"
        )
    return m > 0


def build_round_dag(
    tree,
    kappas,
    *,
    cohort: Optional[np.ndarray] = None,
    masks: Optional[np.ndarray] = None,
    alive: Optional[np.ndarray] = None,
) -> RoundDag:
    """Construct one cloud interval's DAG.

    tree    a ``HierarchySpec`` (or FedTopology)
    kappas  the κ-vector, one entry per tree level
    cohort  sorted original client ids participating this cloud interval
            (default: the full population)
    masks   (R, C) straggler mask: 0 = computes but misses the deadline
            (excluded from that interval's aggregation)
    alive   (R, C) failure mask: 0 = dead (no compute, no energy)
    """
    spec = as_hierarchy(tree)
    kv = tuple(int(k) for k in kappas)
    if len(kv) != spec.depth:
        raise ValueError(f"kappas {kv} has {len(kv)} levels but the tree has depth {spec.depth}")
    if any(k < 1 for k in kv):
        raise ValueError(f"kappas must be >= 1, got {kv}")

    if cohort is None:
        cohort = np.arange(spec.num_clients, dtype=np.int64)
    else:
        cohort = np.asarray(cohort, np.int64)
        if cohort.size == 0:
            raise ValueError("cohort must be non-empty")
        if np.any(np.diff(cohort) <= 0):
            raise ValueError("cohort ids must be sorted and unique")
        if cohort[0] < 0 or cohort[-1] >= spec.num_clients:
            raise ValueError(
                f"cohort ids must be in 0..{spec.num_clients - 1}, got "
                f"[{cohort[0]}, {cohort[-1]}]"
            )
    c_count = int(cohort.size)
    num_intervals = prod(kv[1:]) if len(kv) > 1 else 1

    masks = (
        np.ones((num_intervals, c_count), bool)
        if masks is None
        else _check_mask("masks", masks, num_intervals, c_count)
    )
    alive = (
        np.ones((num_intervals, c_count), bool)
        if alive is None
        else _check_mask("alive", alive, num_intervals, c_count)
    )
    part = masks & alive  # participates in the interval's aggregation

    # per level: each cohort slot's global ancestor id, and the active
    # (ancestor-of-some-slot) node set with a dense local index
    seg: List[Optional[np.ndarray]] = [None]  # 1-indexed by level
    active: List[Optional[np.ndarray]] = [None]
    local_of: List[Optional[dict]] = [None]
    for ell in range(1, spec.depth + 1):
        s = spec.segments(ell)[cohort]
        seg.append(s)
        act = np.unique(s)
        active.append(act)
        local_of.append({int(g): i for i, g in enumerate(act)})
    # parent map per tier (global ids): tier ℓ-1 node -> tier ℓ node
    parents = [np.asarray(p, np.int64) for p in spec.parents]

    kind: List[int] = []
    level: List[int] = []
    entity: List[int] = []
    client: List[int] = []
    interval: List[int] = []
    stepix: List[int] = []
    preds: List[np.ndarray] = []

    def emit(k, lv, ent, cl, r, s, ps) -> int:
        kind.append(k)
        level.append(lv)
        entity.append(ent)
        client.append(cl)
        interval.append(r)
        stepix.append(s)
        preds.append(np.asarray(ps, np.int32))
        return len(kind) - 1

    # chain[i]: the node slot i's next step must wait on — its own last
    # step (masked/dead), or the broadcast aggregate (participated)
    chain = np.full(c_count, -1, np.int64)
    # prev_agg[ell][local]: the previous aggregate at that node (serial
    # boundary processing on one server keeps its timeline monotone and
    # gives empty aggregations a well-defined time)
    prev_agg: List[Optional[np.ndarray]] = [None] + [
        np.full(active[ell].size, -1, np.int64) for ell in range(1, spec.depth + 1)
    ]

    kappa1 = kv[0]
    for r in range(num_intervals):
        # -- local steps: a serial chain per alive slot --------------------
        last_step = np.full(c_count, -1, np.int64)
        for i in range(c_count):
            if not alive[r, i]:
                continue
            for s in range(kappa1):
                ps = [chain[i]] if chain[i] >= 0 else []
                chain[i] = emit(STEP, 0, i, int(cohort[i]), r, s, ps)
            last_step[i] = chain[i]

        b = _boundary_level(r, kv)
        # -- level-1 boundary: uplinks + edge aggregates -------------------
        up = np.full(c_count, -1, np.int64)
        for i in range(c_count):
            if part[r, i]:
                up[i] = emit(HOP, 1, i, int(cohort[i]), r, -1, [last_step[i]])
        agg_at: List[Optional[np.ndarray]] = [None] * (spec.depth + 1)
        agg_at[1] = np.full(active[1].size, -1, np.int64)
        for li, g in enumerate(active[1]):
            members = np.where((seg[1] == g) & part[r])[0]
            ps = [int(up[i]) for i in members]
            if prev_agg[1][li] >= 0:
                ps.append(int(prev_agg[1][li]))
            agg_at[1][li] = emit(AGG, 1, int(g), -1, r, -1, ps)
        prev_agg[1] = agg_at[1]

        # -- higher boundaries: hop up one level, aggregate, repeat --------
        for ell in range(2, b + 1):
            agg_at[ell] = np.full(active[ell].size, -1, np.int64)
            # hops: one per active tier-(ℓ-1) node, to its tier-ℓ parent
            hop_of = {}
            for li, g in enumerate(active[ell - 1]):
                hop_of[int(g)] = emit(
                    HOP, ell, int(g), -1, r, -1, [int(agg_at[ell - 1][li])]
                )
            for li, g in enumerate(active[ell]):
                children = [
                    int(c) for c in active[ell - 1] if int(parents[ell - 1][c]) == int(g)
                ]
                ps = [hop_of[c] for c in children]
                if prev_agg[ell][li] >= 0:
                    ps.append(int(prev_agg[ell][li]))
                agg_at[ell][li] = emit(AGG, ell, int(g), -1, r, -1, ps)
            prev_agg[ell] = agg_at[ell]

        # -- gates: a participating slot's next step waits on the highest
        # aggregate that fired (its level-b ancestor already transitively
        # waits on the slot's own upload); masked/dead slots keep training
        # from their own local chain --------------------------------------
        for i in range(c_count):
            if part[r, i]:
                chain[i] = int(agg_at[b][local_of[b][int(seg[b][i])]])

    return RoundDag(
        spec=spec,
        kappas=kv,
        cohort=cohort,
        kind=np.asarray(kind, np.int8),
        level=np.asarray(level, np.int8),
        entity=np.asarray(entity, np.int32),
        client=np.asarray(client, np.int32),
        interval=np.asarray(interval, np.int16),
        step=np.asarray(stepix, np.int16),
        preds=tuple(preds),
    )
