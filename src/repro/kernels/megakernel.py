"""Pallas TPU megakernel for one fused edge interval.

``edge_interval_pallas`` executes, per grid step, everything one edge does
between two sync points: its clients' κ₁ local SGD(+momentum) steps AND the
trailing weighted edge mean — one HBM read and one HBM write of the edge's
stacked client rows for the whole interval. The scan-fused superround is
step-major (each of the κ₁ steps streams the full (N, …) state through the
memory hierarchy); here the edge's client block stays VMEM-resident across
every step, so per-interval parameter traffic drops by ~κ₁×.

The kernel is specialized to the repo's canonical flat-row client model —
each client row packs a linear map W ∈ (feat, out), loss = mean squared
error over the local batch — which keeps every step a pair of MXU
contractions and makes the fused interval expressible as a single Pallas
body. General models run the same client-blocked schedule through
``core.hierfavg.build_megakernel_super_round`` (the jnp lowering of this
kernel, XLA-fused); this kernel is the TPU lowering target and the
roofline/bench artifact, validated against ``ref.edge_interval_ref`` at ULP
tolerance in interpret mode (shared step body; only the contraction
lowering differs).

Grid: (num_edges,). VMEM per step (f32): C·(P + κ₁·b·(feat+out)) · 4 bytes
plus the (C, feat, out) gradient/momentum temporaries — e.g. C=8, P=307k,
κ₁=8, b=1: ~12 MB, inside a v5e core's 16 MB budget. The parameter axis
cannot be lane-tiled (each local step needs the client's full W), so the
edge block must fit VMEM whole; the wrapper raises past a documented budget
rather than silently spilling (see docs/performance.md).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Upper bound on the resident edge block (params + batches + temporaries),
# chosen for a TPU v5e core's ~16 MB VMEM with headroom for double buffering.
VMEM_BUDGET_BYTES = 12 << 20


def _interval_steps(params, xin, yin, mu, *, lr: float, momentum: float):
    """The shared fused-interval step body: κ₁ unrolled SGD(+momentum)
    steps for one edge's client block. Called by both the Pallas kernel and
    ``ref.edge_interval_ref`` so interpret-mode parity is bit-exact by
    construction.

    params: (C, feat, out) f32; xin: (C, κ₁, b, feat); yin: (C, κ₁, b, out);
    mu: (C, feat, out) momentum buffer (ignored when momentum == 0).
    Returns (params, mu, losses (C, κ₁) f32).
    """
    k1 = xin.shape[1]
    b, out = yin.shape[2], yin.shape[3]
    losses = []
    for t in range(k1):
        x = xin[:, t]  # (C, b, feat)
        r = jnp.einsum(
            "cbf,cfo->cbo", x, params, preferred_element_type=jnp.float32
        ) - yin[:, t]
        losses.append(jnp.mean(jnp.square(r), axis=(1, 2)))
        grad = (2.0 / (b * out)) * jnp.einsum(
            "cbf,cbo->cfo", x, r, preferred_element_type=jnp.float32
        )
        if momentum != 0.0:
            mu = grad + momentum * mu
            params = params - lr * mu
        else:
            params = params - lr * grad
    return params, mu, jnp.stack(losses, axis=1)


def _edge_interval_kernel(
    x_ref, xin_ref, yin_ref, w_ref, mu_ref, o_ref, loss_ref, mu_out_ref,
    *, feat: int, out: int, lr: float, momentum: float,
):
    """One edge: x (C, P) client rows; xin (C, κ₁, b, feat); yin (C, κ₁, b,
    out); w (C, 1) weights; mu (C, P). Writes the post-interval edge mean
    broadcast to members, per-step per-client losses, and the stepped
    momentum buffer."""
    c = x_ref.shape[0]
    params = x_ref[...].astype(jnp.float32).reshape(c, feat, out)
    mu = mu_ref[...].astype(jnp.float32).reshape(c, feat, out)
    xin = xin_ref[...].astype(jnp.float32)
    yin = yin_ref[...].astype(jnp.float32)
    w = w_ref[...].astype(jnp.float32)  # (C, 1)
    params, mu, losses = _interval_steps(
        params, xin, yin, mu, lr=lr, momentum=momentum
    )
    den = jnp.sum(w)
    mean = jnp.sum(params * w[..., None], axis=0) / den  # (feat, out)
    o_ref[...] = jnp.broadcast_to(mean[None], params.shape).reshape(c, feat * out).astype(o_ref.dtype)
    loss_ref[...] = losses.astype(jnp.float32)
    mu_out_ref[...] = mu.reshape(c, feat * out).astype(mu_out_ref.dtype)


def edge_interval_pallas(
    params: jnp.ndarray,
    inputs: jnp.ndarray,
    targets: jnp.ndarray,
    weights: jnp.ndarray,
    *,
    num_edges: int,
    feat: int,
    lr: float,
    momentum: float = 0.0,
    mu: jnp.ndarray = None,
    interpret: bool = False,
):
    """Fused edge interval over stacked flat client rows.

    params: (N, P) with P = feat·out, each row a client's W flattened;
    inputs: (N, κ₁, b, feat); targets: (N, κ₁, b, out); weights: (N,)
    aggregation weights (client order groups edges contiguously, uniform
    tree). mu: optional (N, P) momentum buffer (required iff momentum != 0).

    Returns (aggregated params (N, P) — each row its edge's post-interval
    weighted mean, losses (N, κ₁) f32, mu (N, P)).
    """
    n, p = params.shape
    if n % num_edges:
        raise ValueError(f"N={n} % num_edges={num_edges} != 0")
    if p % feat:
        raise ValueError(f"P={p} not divisible by feat={feat}")
    out = p // feat
    if inputs.shape[0] != n or targets.shape[0] != n or inputs.shape[1] != targets.shape[1]:
        raise ValueError(
            f"batch shapes {inputs.shape}/{targets.shape} incompatible with params {params.shape}"
        )
    k1, b = inputs.shape[1], inputs.shape[2]
    c = n // num_edges
    if momentum != 0.0 and mu is None:
        raise ValueError("momentum != 0 needs a mu buffer")
    if mu is None:
        mu = jnp.zeros_like(params)
    resident = 4 * c * (2 * p + k1 * b * (feat + out)) + 4 * 3 * c * p
    if resident > VMEM_BUDGET_BYTES:
        raise ValueError(
            f"edge block needs ~{resident >> 20} MiB resident, over the "
            f"{VMEM_BUDGET_BYTES >> 20} MiB VMEM budget — shrink "
            f"clients-per-edge, κ₁·b, or the model (see docs/performance.md)"
        )
    w2 = weights.reshape(n, 1).astype(jnp.float32)

    outs = pl.pallas_call(
        functools.partial(
            _edge_interval_kernel, feat=feat, out=out, lr=lr, momentum=momentum
        ),
        grid=(num_edges,),
        in_specs=[
            pl.BlockSpec((c, p), lambda e: (e, 0)),
            pl.BlockSpec((c, k1, b, feat), lambda e: (e, 0, 0, 0)),
            pl.BlockSpec((c, k1, b, out), lambda e: (e, 0, 0, 0)),
            pl.BlockSpec((c, 1), lambda e: (e, 0)),
            pl.BlockSpec((c, p), lambda e: (e, 0)),
        ],
        out_specs=[
            pl.BlockSpec((c, p), lambda e: (e, 0)),
            pl.BlockSpec((c, k1), lambda e: (e, 0)),
            pl.BlockSpec((c, p), lambda e: (e, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((n, p), params.dtype),
            jax.ShapeDtypeStruct((n, k1), jnp.float32),
            jax.ShapeDtypeStruct((n, p), params.dtype),
        ],
        interpret=interpret,
    )(params, inputs, targets, w2, mu)
    return tuple(outs)
