"""Blockwise int8 quantize / dequantize Pallas kernels.

Used on the HierFAVG *cloud hop* (beyond-paper optimization): client deltas
w − w_anchor are quantized to int8 + per-block f32 scales before crossing
the DCN link, quartering the expensive cross-pod bytes (§Perf). The fused
kernel computes the per-block absmax scale and the rounded payload in one
VMEM pass (the jnp reference reads the tensor twice).

Tile: (block_rows, qblock) where qblock is the quantization block (lane-
aligned, 128·k). absmax is a per-row reduction inside the tile; payload
and scale are written side by side.

Grid: (R / block_rows, D / qblock) over the flattened (R, D) view.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _quant_kernel(x_ref, q_ref, s_ref):
    x = x_ref[...].astype(jnp.float32)  # (br, qb)
    amax = jnp.max(jnp.abs(x), axis=-1, keepdims=True)  # (br, 1)
    scale = amax / 127.0
    safe = jnp.where(scale > 0, scale, 1.0)
    q = jnp.clip(jnp.round(x / safe), -127.0, 127.0)
    q_ref[...] = q.astype(jnp.int8)
    s_ref[...] = scale


def _dequant_kernel(q_ref, s_ref, o_ref):
    o_ref[...] = (q_ref[...].astype(jnp.float32) * s_ref[...]).astype(o_ref.dtype)


def quantize_pallas(
    x: jnp.ndarray, *, qblock: int = 256, block_rows: int = 8, interpret: bool = False
):
    """x: any shape, flattened to (R, qblock) blocks. Returns (q int8 (R,qb), scales f32 (R,1), orig_shape)."""
    shape = x.shape
    flat = x.reshape(-1)
    pad = (-flat.size) % qblock
    if pad:
        flat = jnp.pad(flat, (0, pad))
    rows = flat.size // qblock
    rpad = (-rows) % block_rows
    x2 = flat.reshape(rows, qblock)
    if rpad:
        x2 = jnp.pad(x2, ((0, rpad), (0, 0)))
    rp = rows + rpad

    q, s = pl.pallas_call(
        _quant_kernel,
        grid=(rp // block_rows,),
        in_specs=[pl.BlockSpec((block_rows, qblock), lambda i: (i, 0))],
        out_specs=[
            pl.BlockSpec((block_rows, qblock), lambda i: (i, 0)),
            pl.BlockSpec((block_rows, 1), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((rp, qblock), jnp.int8),
            jax.ShapeDtypeStruct((rp, 1), jnp.float32),
        ],
        interpret=interpret,
    )(x2)
    return q[:rows], s[:rows], shape


def quantize_stacked_pallas(
    x: jnp.ndarray, *, qblock: int = 256, block_rows: int = 8, interpret: bool = False
):
    """Stacked (N, D) client payloads → per-client row-wise blockwise int8.

    Returns (q (N, Dp) int8, scales (N, Dp/qblock) f32) with Dp = D padded
    to a qblock multiple, so no quantization block ever crosses a client
    boundary — the payload layout the fused dequantize-aggregate kernel
    (``hier_aggregate.segment_dequant_mean_pallas``) and the jnp transport
    codecs (``fed.transport.quantize_rows``) share.
    """
    n, d = x.shape
    pad = (-d) % qblock
    xp = jnp.pad(x, ((0, 0), (0, pad))) if pad else x
    dp = d + pad
    # row-major flatten keeps each client's Dp/qblock blocks contiguous
    q, s, _ = quantize_pallas(xp, qblock=qblock, block_rows=block_rows, interpret=interpret)
    return q.reshape(n, dp), s.reshape(n, dp // qblock)


def dequantize_pallas(
    q: jnp.ndarray,
    s: jnp.ndarray,
    shape,
    dtype=jnp.float32,
    *,
    block_rows: int = 8,
    interpret: bool = False,
) -> jnp.ndarray:
    rows, qblock = q.shape
    rpad = (-rows) % block_rows
    if rpad:
        q = jnp.pad(q, ((0, rpad), (0, 0)))
        s = jnp.pad(s, ((0, rpad), (0, 0)))
    rp = rows + rpad
    out = pl.pallas_call(
        _dequant_kernel,
        grid=(rp // block_rows,),
        in_specs=[
            pl.BlockSpec((block_rows, qblock), lambda i: (i, 0)),
            pl.BlockSpec((block_rows, 1), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((block_rows, qblock), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((rp, qblock), dtype),
        interpret=interpret,
    )(q, s)
    flat = out[:rows].reshape(-1)
    n = 1
    for d in shape:
        n *= d
    return flat[:n].reshape(shape)
