"""Flash attention (forward) as a Pallas TPU kernel.

Why it's here: the 32k-prefill and 4k-train cells are *memory-roofline*
bound in the naive form — XLA materializes (B,H,Sq,Sk) f32 score tensors
(32k² × 4B = 4 GiB per head-pair). The flash form never writes scores to
HBM: per (batch·head, q-block), it streams k/v blocks through VMEM with an
online-softmax accumulator, so HBM traffic drops from O(S²) to O(S·d) —
the standard memory-hierarchy adaptation of attention, here tiled for
VMEM/MXU (block sizes multiples of 128 to align with the 128×128 systolic
array and 8×128 vregs).

Supports causal masking and sliding-window (local) attention; the
window/causal structure additionally lets us *skip* fully-masked k-blocks
(block-level early-out via the grid over kv implicitly bounded per q block).

Grid: (B·H, Sq/bq, Sk/bk) with k innermost: accumulators live in VMEM
scratch across the k dimension (rows revisit).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

_NEG_INF = -1e30


def _flash_kernel(
    q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref, l_ref,
    *, scale: float, block_q: int, block_k: int, causal: bool, window: int, seq_k: int,
):
    qi = pl.program_id(1)
    ki = pl.program_id(2)
    nk = pl.num_programs(2)

    @pl.when(ki == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, _NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    q_pos = qi * block_q + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 0)
    k_pos = ki * block_k + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 1)
    mask = k_pos < seq_k  # padding
    if causal:
        mask &= k_pos <= q_pos
    if window > 0:
        mask &= (q_pos - k_pos) < window

    # block-level skip: if every element is masked, leave accumulators alone
    def compute():
        q = q_ref[0].astype(jnp.float32) * scale  # (bq, d)
        k = k_ref[0].astype(jnp.float32)  # (bk, d)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
        )  # (bq, bk)
        s = jnp.where(mask, s, _NEG_INF)
        m_prev = m_ref[...]  # (bq, 1)
        m_cur = jnp.max(s, axis=-1, keepdims=True)
        m_new = jnp.maximum(m_prev, m_cur)
        p = jnp.exp(s - m_new)  # (bq, bk)
        alpha = jnp.exp(m_prev - m_new)  # (bq, 1)
        l_new = alpha * l_ref[...] + jnp.sum(p, axis=-1, keepdims=True)
        v = v_ref[0].astype(jnp.float32)  # (bk, d)
        pv = jax.lax.dot_general(p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
        acc_ref[...] = acc_ref[...] * alpha + pv
        m_ref[...] = m_new
        l_ref[...] = l_new

    if causal or window > 0:
        # block-level early-out: skip k blocks fully outside this q block's
        # causal/window band (the structural win of local attention)
        q_lo = qi * block_q
        q_hi = q_lo + block_q - 1
        k_lo = ki * block_k
        k_hi = k_lo + block_k - 1
        visible = jnp.bool_(True)
        if causal:
            visible &= k_lo <= q_hi
        if window > 0:
            visible &= k_hi >= q_lo - window + 1

        @pl.when(visible)
        def _():
            compute()
    else:
        compute()

    @pl.when(ki == nk - 1)
    def _fin():
        l = l_ref[...]
        safe = jnp.where(l > 0, l, 1.0)
        o_ref[0] = (acc_ref[...] / safe).astype(o_ref.dtype)


def flash_attention_pallas(
    q: jnp.ndarray,
    k: jnp.ndarray,
    v: jnp.ndarray,
    *,
    causal: bool = True,
    window: int = 0,
    block_q: int = 128,
    block_k: int = 128,
    interpret: bool = False,
) -> jnp.ndarray:
    """q,k,v: (BH, S, d) — callers fold batch×heads. Returns (BH, Sq, d).

    Sq/Sk padded to block multiples internally; padding keys are masked,
    padding queries produce zeros (l==0 guard) and are sliced off.
    """
    bh, sq, d = q.shape
    sk = k.shape[1]
    pq = (-sq) % block_q
    pk = (-sk) % block_k
    qp = jnp.pad(q, ((0, 0), (0, pq), (0, 0))) if pq else q
    kp = jnp.pad(k, ((0, 0), (0, pk), (0, 0))) if pk else k
    vp = jnp.pad(v, ((0, 0), (0, pk), (0, 0))) if pk else v
    scale = d ** -0.5

    out = pl.pallas_call(
        functools.partial(
            _flash_kernel,
            scale=scale, block_q=block_q, block_k=block_k,
            causal=causal, window=window, seq_k=sk,
        ),
        grid=(bh, (sq + pq) // block_q, (sk + pk) // block_k),
        in_specs=[
            pl.BlockSpec((1, block_q, d), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, block_k, d), lambda b, i, j: (b, j, 0)),
            pl.BlockSpec((1, block_k, d), lambda b, i, j: (b, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_q, d), lambda b, i, j: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((bh, sq + pq, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, d), jnp.float32),  # acc
            pltpu.VMEM((block_q, 1), jnp.float32),  # running max m
            pltpu.VMEM((block_q, 1), jnp.float32),  # running sum l
        ],
        interpret=interpret,
    )(qp, kp, vp)
    return out[:, :sq]
