"""Pallas TPU kernel for the paper's aggregation operator.

Fuses, per parameter tile, the whole EdgeAggregation/CloudAggregation body:
  masked-weighted sum over each contiguous client group, safe divide,
  broadcast back to the members — one HBM read + one HBM write of the
  stacked parameters (the jnp reference does reshape/sum/where in ~4
  passes). On the aggregation-bound cloud hop, this halves HBM traffic.

TPU adaptation: the client axis N is tiny (16-32) and the parameter axis is
huge, so we tile the *parameter* dim into lane-aligned blocks of 128·k and
keep the whole client column resident in VMEM: block (N, bd). Group
reduction happens in-register via a (G, C, bd) reshape — no cross-block
communication, perfectly parallel grid. The weighted sum runs in f32 on the
VPU regardless of the storage dtype.

Grid: (ceil(D / bd),). VMEM per step: N·bd·(bytes) ≈ 32·512·4 = 64 KiB.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _agg_kernel(x_ref, w_ref, o_ref, *, num_groups: int):
    """x: (N, bd) tile; w: (N, 1) masked weights; o: (N, bd)."""
    x = x_ref[...].astype(jnp.float32)  # (N, bd)
    w = w_ref[...].astype(jnp.float32)  # (N, 1)
    n, bd = x.shape
    c = n // num_groups
    xg = x.reshape(num_groups, c, bd)
    wg = w.reshape(num_groups, c, 1)
    num = jnp.sum(xg * wg, axis=1, keepdims=True)  # (G,1,bd)
    den = jnp.sum(wg, axis=1, keepdims=True)  # (G,1,1)
    safe = jnp.where(den > 0, den, 1.0)
    mean = num / safe
    out = jnp.where(den > 0, jnp.broadcast_to(mean, xg.shape), xg)
    o_ref[...] = out.reshape(n, bd).astype(o_ref.dtype)


def grouped_mean_pallas(
    x: jnp.ndarray,
    weights: jnp.ndarray,
    num_groups: int,
    *,
    block_d: int = 512,
    interpret: bool = False,
) -> jnp.ndarray:
    """x: (N, D) stacked flat params; weights: (N,) already masked.

    Returns the per-group weighted mean broadcast back to members, (N, D).
    D is padded to a block multiple internally.
    """
    n, d = x.shape
    if n % num_groups:
        raise ValueError(f"N={n} % groups={num_groups} != 0")
    pad = (-d) % block_d
    xp = jnp.pad(x, ((0, 0), (0, pad))) if pad else x
    dp = d + pad
    w2 = weights.reshape(n, 1).astype(jnp.float32)

    out = pl.pallas_call(
        functools.partial(_agg_kernel, num_groups=num_groups),
        grid=(dp // block_d,),
        in_specs=[
            pl.BlockSpec((n, block_d), lambda i: (0, i)),
            pl.BlockSpec((n, 1), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((n, block_d), lambda i: (0, i)),
        out_shape=jax.ShapeDtypeStruct((n, dp), x.dtype),
        interpret=interpret,
    )(xp, w2)
    return out[:, :d] if pad else out
