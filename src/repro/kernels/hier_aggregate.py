"""Pallas TPU kernels for the paper's aggregation operator — uniform and
ragged group shapes.

Both kernels fuse, per parameter tile, the whole EdgeAggregation/
CloudAggregation body: masked-weighted sum over each client group, safe
divide, broadcast back to the members — one HBM read + one HBM write of the
stacked parameters (the jnp reference does reshape/sum/where in ~4 passes).
On the aggregation-bound cloud hop, this halves HBM traffic.

TPU adaptation: the client axis N is tiny (16-32) and the parameter axis is
huge, so we tile the *parameter* dim into lane-aligned blocks of 128·k and
keep the whole client column resident in VMEM: block (N, bd). The weighted
sum runs in f32 on the VPU/MXU regardless of the storage dtype.

* ``grouped_mean_pallas`` — equal contiguous groups. Reduction in-register
  via a (G, C, bd) reshape; no cross-block communication, perfectly
  parallel grid.
* ``segment_mean_pallas`` — ragged groups. The sorted per-client segment
  ids ride in as a scalar-prefetch argument (SMEM-resident, shared by all
  grid steps; see ``docs/hierarchy.md``). The kernel builds the (G, N)
  membership one-hot from the ids with a broadcasted iota compare and
  reduces with two small matmuls: ``onehot @ (x*w)`` for the group sums
  and ``onehotᵀ @ mean`` for the broadcast-back — MXU work of size
  G×N×bd per tile, still exactly one HBM read + one HBM write of x.
  Zero-survivor groups keep their members' rows via the alive column.

Grid: (ceil(D / bd),). VMEM per step: N·bd·(bytes) ≈ 32·512·4 = 64 KiB
(uniform) plus the (G,N)+(G,bd) one-hot/means scratch for ragged.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _agg_kernel(x_ref, w_ref, o_ref, *, num_groups: int):
    """x: (N, bd) tile; w: (N, 1) masked weights; o: (N, bd)."""
    x = x_ref[...].astype(jnp.float32)  # (N, bd)
    w = w_ref[...].astype(jnp.float32)  # (N, 1)
    n, bd = x.shape
    c = n // num_groups
    xg = x.reshape(num_groups, c, bd)
    wg = w.reshape(num_groups, c, 1)
    num = jnp.sum(xg * wg, axis=1, keepdims=True)  # (G,1,bd)
    den = jnp.sum(wg, axis=1, keepdims=True)  # (G,1,1)
    safe = jnp.where(den > 0, den, 1.0)
    mean = num / safe
    out = jnp.where(den > 0, jnp.broadcast_to(mean, xg.shape), xg)
    o_ref[...] = out.reshape(n, bd).astype(o_ref.dtype)


def grouped_mean_pallas(
    x: jnp.ndarray,
    weights: jnp.ndarray,
    num_groups: int,
    *,
    block_d: int = 512,
    interpret: bool = False,
) -> jnp.ndarray:
    """x: (N, D) stacked flat params; weights: (N,) already masked.

    Returns the per-group weighted mean broadcast back to members, (N, D).
    D is padded to a block multiple internally.
    """
    n, d = x.shape
    if n % num_groups:
        raise ValueError(f"N={n} % groups={num_groups} != 0")
    pad = (-d) % block_d
    xp = jnp.pad(x, ((0, 0), (0, pad))) if pad else x
    dp = d + pad
    w2 = weights.reshape(n, 1).astype(jnp.float32)

    out = pl.pallas_call(
        functools.partial(_agg_kernel, num_groups=num_groups),
        grid=(dp // block_d,),
        in_specs=[
            pl.BlockSpec((n, block_d), lambda i: (0, i)),
            pl.BlockSpec((n, 1), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((n, block_d), lambda i: (0, i)),
        out_shape=jax.ShapeDtypeStruct((n, dp), x.dtype),
        interpret=interpret,
    )(xp, w2)
    return out[:, :d] if pad else out


def _segment_kernel(seg_ref, x_ref, w_ref, o_ref, *, num_segments: int):
    """seg: (N,) int32 in SMEM; x: (N, bd) tile; w: (N, 1); o: (N, bd)."""
    x = x_ref[...].astype(jnp.float32)
    w = w_ref[...].astype(jnp.float32)
    n, _ = x.shape
    seg = seg_ref[...]
    gids = jax.lax.broadcasted_iota(jnp.int32, (num_segments, n), 0)
    onehot = (seg[None, :] == gids).astype(jnp.float32)  # (G, N)
    num = jnp.dot(onehot, x * w, preferred_element_type=jnp.float32)  # (G, bd)
    den = jnp.dot(onehot, w, preferred_element_type=jnp.float32)  # (G, 1)
    mean = num / jnp.where(den > 0, den, 1.0)
    alive = (den > 0).astype(jnp.float32)  # (G, 1)
    # broadcast-back: members of alive groups get the mean, dead groups
    # keep their input rows (onehotᵀ @ alive is each member's liveness)
    back = jnp.dot(onehot.T, mean * alive, preferred_element_type=jnp.float32)
    keep = 1.0 - jnp.dot(onehot.T, alive, preferred_element_type=jnp.float32)
    o_ref[...] = (back + x * keep).astype(o_ref.dtype)


def _dequant_segment_kernel(seg_ref, q_ref, s_ref, w_ref, o_ref, *, num_segments: int, qblock: int):
    """seg: (N,) int32 in SMEM; q: (N, bd) int8 tile; s: (N, bd/qblock) f32
    scales; w: (N, 1); o: (N, bd) f32. Dequantize + one-hot MXU segment
    reduction in one VMEM residency — the int8 payload is the only HBM
    read of the stacked deltas (~¼ the f32 bytes)."""
    qv = q_ref[...].astype(jnp.float32)  # (N, bd)
    sv = s_ref[...]  # (N, bd/qblock)
    n, bd = qv.shape
    x = (qv.reshape(n, bd // qblock, qblock) * sv[..., None]).reshape(n, bd)
    w = w_ref[...].astype(jnp.float32)
    seg = seg_ref[...]
    gids = jax.lax.broadcasted_iota(jnp.int32, (num_segments, n), 0)
    onehot = (seg[None, :] == gids).astype(jnp.float32)  # (G, N)
    num = jnp.dot(onehot, x * w, preferred_element_type=jnp.float32)  # (G, bd)
    den = jnp.dot(onehot, w, preferred_element_type=jnp.float32)  # (G, 1)
    mean = num / jnp.where(den > 0, den, 1.0)
    alive = (den > 0).astype(jnp.float32)  # (G, 1)
    back = jnp.dot(onehot.T, mean * alive, preferred_element_type=jnp.float32)
    keep = 1.0 - jnp.dot(onehot.T, alive, preferred_element_type=jnp.float32)
    o_ref[...] = (back + x * keep).astype(o_ref.dtype)


def segment_dequant_mean_pallas(
    q: jnp.ndarray,
    scales: jnp.ndarray,
    weights: jnp.ndarray,
    segment_ids,
    num_segments: int,
    *,
    block_d: int = 512,
    interpret: bool = False,
) -> jnp.ndarray:
    """Fused dequantize-and-segment-aggregate: consume the compressed link
    payload directly.

    q: (N, D) int8 — each client's delta, quantized row-wise in blocks of
    qblock = D / scales.shape[1] (``quantize.quantize_stacked_pallas`` /
    ``fed.transport.quantize_rows`` layout). scales: (N, D/qblock) f32.
    weights: (N,) already-masked aggregation weights; segment_ids: (N,)
    sorted ints in [0, num_segments).

    Returns the per-segment weighted mean of the dequantized rows broadcast
    back to members, (N, D) f32; zero-weight segments keep their (dequantized)
    rows. One HBM pass over int8 + scales instead of dequantize-then-
    aggregate's extra f32 round trip. ``block_d`` must be a multiple of
    qblock; D is padded to a block_d multiple internally (zero payload +
    zero scale ⇒ exact zeros in the pad lanes).
    """
    n, d = q.shape
    if scales.shape[0] != n or d % scales.shape[1]:
        raise ValueError(f"scales shape {scales.shape} incompatible with q {q.shape}")
    qblock = d // scales.shape[1]
    if block_d % qblock:
        raise ValueError(f"block_d={block_d} must be a multiple of qblock={qblock}")
    seg = jnp.asarray(segment_ids, jnp.int32)
    if seg.shape != (n,):
        raise ValueError(f"segment_ids shape {seg.shape} != ({n},)")
    pad = (-d) % block_d
    qp = jnp.pad(q, ((0, 0), (0, pad))) if pad else q
    sp = jnp.pad(scales, ((0, 0), (0, pad // qblock))) if pad else scales
    dp = d + pad
    w2 = weights.reshape(n, 1).astype(jnp.float32)
    sblock = block_d // qblock

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(dp // block_d,),
        in_specs=[
            pl.BlockSpec((n, block_d), lambda i, seg_ref: (0, i)),
            pl.BlockSpec((n, sblock), lambda i, seg_ref: (0, i)),
            pl.BlockSpec((n, 1), lambda i, seg_ref: (0, 0)),
        ],
        out_specs=pl.BlockSpec((n, block_d), lambda i, seg_ref: (0, i)),
    )
    out = pl.pallas_call(
        functools.partial(_dequant_segment_kernel, num_segments=num_segments, qblock=qblock),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((n, dp), jnp.float32),
        interpret=interpret,
    )(seg, qp, sp, w2)
    return out[:, :d] if pad else out


def segment_mean_pallas(
    x: jnp.ndarray,
    weights: jnp.ndarray,
    segment_ids,
    num_segments: int,
    *,
    block_d: int = 512,
    interpret: bool = False,
) -> jnp.ndarray:
    """Ragged-group aggregation: x (N, D) stacked flat params; weights (N,)
    already masked; segment_ids (N,) sorted ints in [0, num_segments).

    Returns the per-segment weighted mean broadcast back to members, (N, D);
    zero-weight segments keep their rows. D is padded to a block multiple
    internally. The ids travel via scalar prefetch and are resident in SMEM
    for every grid step.
    """
    n, d = x.shape
    seg = jnp.asarray(segment_ids, jnp.int32)
    if seg.shape != (n,):
        raise ValueError(f"segment_ids shape {seg.shape} != ({n},)")
    pad = (-d) % block_d
    xp = jnp.pad(x, ((0, 0), (0, pad))) if pad else x
    dp = d + pad
    w2 = weights.reshape(n, 1).astype(jnp.float32)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(dp // block_d,),
        in_specs=[
            pl.BlockSpec((n, block_d), lambda i, seg_ref: (0, i)),
            pl.BlockSpec((n, 1), lambda i, seg_ref: (0, 0)),
        ],
        out_specs=pl.BlockSpec((n, block_d), lambda i, seg_ref: (0, i)),
    )
    out = pl.pallas_call(
        functools.partial(_segment_kernel, num_segments=num_segments),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((n, dp), x.dtype),
        interpret=interpret,
    )(seg, xp, w2)
    return out[:, :d] if pad else out
