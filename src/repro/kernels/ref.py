"""Pure-jnp oracles for every kernel — the correctness ground truth.

Tests sweep shapes/dtypes and assert_allclose(kernel(interpret=True), ref).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def grouped_mean_ref(x: jnp.ndarray, weights: jnp.ndarray, num_groups: int) -> jnp.ndarray:
    """(N, D) stacked params, (N,) masked weights -> per-group weighted mean
    broadcast back. Groups with zero total weight keep their inputs."""
    n, d = x.shape
    c = n // num_groups
    xg = x.reshape(num_groups, c, d).astype(jnp.float32)
    wg = weights.reshape(num_groups, c, 1).astype(jnp.float32)
    num = jnp.sum(xg * wg, axis=1, keepdims=True)
    den = jnp.sum(wg, axis=1, keepdims=True)
    mean = num / jnp.where(den > 0, den, 1.0)
    out = jnp.where(den > 0, jnp.broadcast_to(mean, xg.shape), xg)
    return out.reshape(n, d).astype(x.dtype)


def segment_mean_ref(
    x: jnp.ndarray, weights: jnp.ndarray, segment_ids, num_segments: int,
    block_d: int = 512,
) -> jnp.ndarray:
    """(N, D) stacked params, (N,) masked weights, (N,) sorted segment ids
    -> per-segment weighted mean broadcast back; zero-weight segments keep
    their rows.

    Mirrors the Pallas kernel exactly — same one-hot matmul formulation AND
    the same block_d column tiling — so interpret-mode kernel output is
    bit-identical for f32 (XLA's matmul reduction order depends on the
    operand widths, so matching the tiling is part of matching the math).
    """
    seg = jnp.asarray(segment_ids, jnp.int32)
    n, d = x.shape
    w = weights.reshape(-1, 1).astype(jnp.float32)
    gids = jax.lax.broadcasted_iota(jnp.int32, (num_segments, n), 0)
    onehot = (seg[None, :] == gids).astype(jnp.float32)  # (G, N)
    den = jnp.dot(onehot, w, preferred_element_type=jnp.float32)
    safe = jnp.where(den > 0, den, 1.0)
    alive = (den > 0).astype(jnp.float32)
    keep = 1.0 - jnp.dot(onehot.T, alive, preferred_element_type=jnp.float32)

    pad = (-d) % block_d
    xp = jnp.pad(x, ((0, 0), (0, pad))) if pad else x
    outs = []
    for i in range(xp.shape[1] // block_d):
        xt = xp[:, i * block_d : (i + 1) * block_d].astype(jnp.float32)
        num = jnp.dot(onehot, xt * w, preferred_element_type=jnp.float32)
        mean = num / safe
        back = jnp.dot(onehot.T, mean * alive, preferred_element_type=jnp.float32)
        outs.append(back + xt * keep)
    out = jnp.concatenate(outs, axis=1)[:, :d]
    return out.astype(x.dtype)


def segment_dequant_mean_ref(
    q: jnp.ndarray,
    scales: jnp.ndarray,
    weights: jnp.ndarray,
    segment_ids,
    num_segments: int,
    block_d: int = 512,
) -> jnp.ndarray:
    """Oracle for the fused dequantize-and-segment-aggregate kernel.

    q: (N, D) int8 row-wise payload; scales: (N, D/qblock) f32. Dequantizes
    (elementwise — order-independent) then mirrors ``segment_mean_ref``'s
    one-hot matmul formulation and block_d column tiling exactly, so the
    interpret-mode kernel output is bit-identical (f32 out).
    """
    n, d = q.shape
    qblock = d // scales.shape[1]
    x = (q.astype(jnp.float32).reshape(n, d // qblock, qblock) * scales[..., None]).reshape(n, d)
    seg = jnp.asarray(segment_ids, jnp.int32)
    w = weights.reshape(-1, 1).astype(jnp.float32)
    gids = jax.lax.broadcasted_iota(jnp.int32, (num_segments, n), 0)
    onehot = (seg[None, :] == gids).astype(jnp.float32)  # (G, N)
    den = jnp.dot(onehot, w, preferred_element_type=jnp.float32)
    safe = jnp.where(den > 0, den, 1.0)
    alive = (den > 0).astype(jnp.float32)
    keep = 1.0 - jnp.dot(onehot.T, alive, preferred_element_type=jnp.float32)

    pad = (-d) % block_d
    xp = jnp.pad(x, ((0, 0), (0, pad))) if pad else x
    outs = []
    for i in range(xp.shape[1] // block_d):
        xt = xp[:, i * block_d : (i + 1) * block_d]
        num = jnp.dot(onehot, xt * w, preferred_element_type=jnp.float32)
        mean = num / safe
        back = jnp.dot(onehot.T, mean * alive, preferred_element_type=jnp.float32)
        outs.append(back + xt * keep)
    return jnp.concatenate(outs, axis=1)[:, :d]


def edge_interval_ref(
    params: jnp.ndarray,
    inputs: jnp.ndarray,
    targets: jnp.ndarray,
    weights: jnp.ndarray,
    num_edges: int,
    *,
    feat: int,
    lr: float,
    momentum: float = 0.0,
    mu: jnp.ndarray = None,
):
    """Oracle for the fused edge-interval megakernel.

    params: (N, P = feat·out) flat client rows; inputs: (N, κ₁, b, feat);
    targets: (N, κ₁, b, out); weights: (N,). Runs the κ₁ local SGD
    (+momentum) steps then the per-edge weighted mean, edge by edge in
    kernel grid order, through the *same* ``_interval_steps`` body the
    Pallas kernel traces. Interpret-mode parity is ULP-level (~1e-7): the
    step math is shared, only the lowering of the einsum contractions
    differs inside the Pallas interpreter. Returns (aggregated params
    (N, P), losses (N, κ₁) f32, mu (N, P))."""
    from repro.kernels.megakernel import _interval_steps

    n, p = params.shape
    out = p // feat
    c = n // num_edges
    if mu is None:
        mu = jnp.zeros_like(params)
    w = weights.reshape(n, 1).astype(jnp.float32)
    outs, louts, mouts = [], [], []
    for e in range(num_edges):
        sl = slice(e * c, (e + 1) * c)
        pe = params[sl].astype(jnp.float32).reshape(c, feat, out)
        me = mu[sl].astype(jnp.float32).reshape(c, feat, out)
        pe, me, le = _interval_steps(
            pe, inputs[sl].astype(jnp.float32), targets[sl].astype(jnp.float32),
            me, lr=lr, momentum=momentum,
        )
        we = w[sl]
        mean = jnp.sum(pe * we[..., None], axis=0) / jnp.sum(we)
        outs.append(jnp.broadcast_to(mean[None], pe.shape).reshape(c, p))
        louts.append(le)
        mouts.append(me.reshape(c, p))
    return (
        jnp.concatenate(outs).astype(params.dtype),
        jnp.concatenate(louts).astype(jnp.float32),
        jnp.concatenate(mouts).astype(params.dtype),
    )


def attention_ref(
    q: jnp.ndarray,
    k: jnp.ndarray,
    v: jnp.ndarray,
    *,
    causal: bool = True,
    window: int = 0,
) -> jnp.ndarray:
    """Naive softmax attention. q,k,v: (BH, S, d)."""
    scale = q.shape[-1] ** -0.5
    s = jnp.einsum("bqd,bkd->bqk", q.astype(jnp.float32), k.astype(jnp.float32)) * scale
    sq, sk = q.shape[1], k.shape[1]
    qpos = jnp.arange(sq)[:, None]
    kpos = jnp.arange(sk)[None, :]
    mask = jnp.ones((sq, sk), bool)
    if causal:
        mask &= kpos <= qpos
    if window > 0:
        mask &= (qpos - kpos) < window
    s = jnp.where(mask[None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    # rows with no visible keys (can happen for padded q) -> zeros
    any_visible = jnp.any(mask, axis=-1)[None, :, None]
    out = jnp.einsum("bqk,bkd->bqd", p, v.astype(jnp.float32))
    return jnp.where(any_visible, out, 0.0).astype(q.dtype)


def rglru_scan_ref(a: jnp.ndarray, b: jnp.ndarray, h0: jnp.ndarray):
    """Linear recurrence h_t = a_t*h_{t-1} + b_t via associative_scan.

    a,b: (B,S,D); h0: (B,D). Returns (h (B,S,D) f32, hT (B,D) f32)."""
    af = a.astype(jnp.float32)
    bf = b.astype(jnp.float32)
    # fold h0 into the first step: h_1 = a_1*h0 + b_1
    b0 = bf.at[:, 0].add(af[:, 0] * h0.astype(jnp.float32))

    def combine(l, r):
        al, bl = l
        ar, br = r
        return al * ar, ar * bl + br

    _, h = jax.lax.associative_scan(combine, (af, b0), axis=1)
    return h, h[:, -1]


def quantize_ref(x: jnp.ndarray, qblock: int = 256):
    """Blockwise int8 absmax quantization. Returns (q (R,qb) int8, s (R,1) f32, shape)."""
    shape = x.shape
    flat = x.astype(jnp.float32).reshape(-1)
    pad = (-flat.size) % qblock
    if pad:
        flat = jnp.pad(flat, (0, pad))
    blocks = flat.reshape(-1, qblock)
    scale = jnp.max(jnp.abs(blocks), axis=1, keepdims=True) / 127.0
    safe = jnp.where(scale > 0, scale, 1.0)
    q = jnp.clip(jnp.round(blocks / safe), -127, 127).astype(jnp.int8)
    return q, scale, shape


def dequantize_ref(q: jnp.ndarray, s: jnp.ndarray, shape, dtype=jnp.float32) -> jnp.ndarray:
    flat = (q.astype(jnp.float32) * s).reshape(-1)
    n = 1
    for d in shape:
        n *= d
    return flat[:n].reshape(shape).astype(dtype)
