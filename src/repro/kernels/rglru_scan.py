"""RG-LRU linear-recurrence scan as a Pallas TPU kernel.

The recurrence h_t = a_t ⊙ h_{t-1} + b_t is the temporal-mixing core of
RecurrentGemma. GPU implementations lean on warp-level parallel scans; the
TPU-native shape is different: the VPU is a (8,128) vector unit with cheap
per-lane FMA but no cross-lane shuffle-scan, so we go *sequential in time,
wide in channels* — each grid step owns a (block_s, block_d) tile of
(a, b) in VMEM and a (1, block_d) carry in VMEM scratch, and walks
block_s steps with a fori_loop of fused multiply-adds. Channels are
embarrassingly parallel: grid = (B, D/block_d) with the channel axis outer
so each core's carry survives its whole sequence walk.

The sequence axis is NOT gridded (the carry is the loop dependency); a
(8,128)-aligned channel block keeps every FMA fully vectorized. Work is
O(S·D) — same as the jnp associative_scan reference — but one HBM pass
and no log-depth ping-pong buffers.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _rglru_kernel(a_ref, b_ref, h0_ref, o_ref, hT_ref, carry, *, seq_len: int):
    """a,b: (1, S, bd); h0: (1, bd); o: (1, S, bd); hT: (1, bd)."""
    carry[...] = h0_ref[...].astype(jnp.float32)

    def body(t, _):
        a_t = a_ref[0, t].astype(jnp.float32)
        b_t = b_ref[0, t].astype(jnp.float32)
        h = a_t * carry[0] + b_t
        carry[0, :] = h
        o_ref[0, t] = h.astype(o_ref.dtype)
        return 0

    jax.lax.fori_loop(0, seq_len, body, 0)
    hT_ref[...] = carry[...].astype(hT_ref.dtype)


def rglru_scan_pallas(
    a: jnp.ndarray,
    b: jnp.ndarray,
    h0: jnp.ndarray,
    *,
    block_d: int = 128,
    interpret: bool = False,
):
    """a, b: (B, S, D) recurrence coefficients; h0: (B, D) initial state.

    Returns (h: (B, S, D) all states, hT: (B, D) final state). D padded to
    a lane multiple internally.
    """
    B, S, D = a.shape
    pad = (-D) % block_d
    if pad:
        a = jnp.pad(a, ((0, 0), (0, 0), (0, pad)))
        b = jnp.pad(b, ((0, 0), (0, 0), (0, pad)))
        h0 = jnp.pad(h0, ((0, 0), (0, pad)))
    Dp = D + pad

    h, hT = pl.pallas_call(
        functools.partial(_rglru_kernel, seq_len=S),
        grid=(B, Dp // block_d),
        in_specs=[
            pl.BlockSpec((1, S, block_d), lambda i, j: (i, 0, j)),
            pl.BlockSpec((1, S, block_d), lambda i, j: (i, 0, j)),
            pl.BlockSpec((1, block_d), lambda i, j: (i, j)),
        ],
        out_specs=[
            pl.BlockSpec((1, S, block_d), lambda i, j: (i, 0, j)),
            pl.BlockSpec((1, block_d), lambda i, j: (i, j)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, S, Dp), jnp.float32),
            jax.ShapeDtypeStruct((B, Dp), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((1, block_d), jnp.float32)],
        interpret=interpret,
    )(a, b, h0)
    if pad:
        return h[..., :D], hT[..., :D]
    return h, hT
