"""Public jit'd kernel API with a global interpret switch.

On CPU (this container) kernels run with interpret=True — the kernel body
executes in Python and is validated against ref.py. On TPU the same calls
lower to Mosaic. ``use_interpret()`` defaults to True off-TPU.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels import flash_attention as _fa
from repro.kernels import hier_aggregate as _ha
from repro.kernels import quantize as _qz
from repro.kernels import ref
from repro.kernels import rglru_scan as _rg

_FORCE_INTERPRET: Optional[bool] = None


def set_interpret(value: Optional[bool]) -> None:
    global _FORCE_INTERPRET
    _FORCE_INTERPRET = value


def use_interpret() -> bool:
    if _FORCE_INTERPRET is not None:
        return _FORCE_INTERPRET
    return jax.default_backend() != "tpu"


# ---------------------------------------------------------------------------

@functools.partial(jax.jit, static_argnames=("num_groups", "block_d"))
def _grouped_mean_jit(x, w, num_groups, block_d, interpret):
    return _ha.grouped_mean_pallas(x, w, num_groups, block_d=block_d, interpret=interpret)


def grouped_mean(x, weights, num_groups, *, block_d: int = 512):
    return _ha.grouped_mean_pallas(
        x, weights, num_groups, block_d=block_d, interpret=use_interpret()
    )


def segment_mean(x, weights, segment_ids, num_segments, *, block_d: int = 512):
    """Ragged-group aggregation over sorted segment ids (N,). Dispatches to
    the uniform reshape kernel when the ids form equal contiguous blocks
    (same predicate as the jnp path in core.aggregation)."""
    from repro.core.aggregation import _static_uniform_groups

    if _static_uniform_groups(segment_ids, num_segments) is not None:
        return grouped_mean(x, weights, num_segments, block_d=block_d)
    return _ha.segment_mean_pallas(
        x, weights, segment_ids, num_segments, block_d=block_d, interpret=use_interpret()
    )


def flash_attention(q, k, v, *, causal=True, window=0, block_q=128, block_k=128):
    """(BH, S, d) fused attention; falls back to ref for tiny heads."""
    return _fa.flash_attention_pallas(
        q, k, v, causal=causal, window=window, block_q=block_q, block_k=block_k,
        interpret=use_interpret(),
    )


def rglru_scan(a, b, h0, *, block_d=128):
    return _rg.rglru_scan_pallas(a, b, h0, block_d=block_d, interpret=use_interpret())


def segment_dequant_mean(q, scales, weights, segment_ids, num_segments, *, block_d: int = 512):
    """Fused dequantize-and-segment-aggregate: int8 payload (N, D) +
    per-block scales (N, D/qblock) → per-segment weighted mean of the
    dequantized rows broadcast back, (N, D) f32 — one HBM pass over the
    compressed bytes (the transport layer's decode+aggregate in one)."""
    return _ha.segment_dequant_mean_pallas(
        q, scales, weights, segment_ids, num_segments,
        block_d=block_d, interpret=use_interpret(),
    )


def edge_interval(params, inputs, targets, weights, *, num_edges, feat, lr,
                  momentum=0.0, mu=None):
    """Fused edge interval: κ₁ local SGD(+momentum) steps for every client
    plus the trailing per-edge weighted mean, one kernel launch per cloud-
    free sync — the megakernel's TPU lowering (flat-row linear clients; the
    engine's general-model path is ``core.hierfavg.build_megakernel_super_round``).
    Returns (aggregated params (N, P), losses (N, κ₁), mu (N, P))."""
    from repro.kernels import megakernel as _mk

    return _mk.edge_interval_pallas(
        params, inputs, targets, weights, num_edges=num_edges, feat=feat,
        lr=lr, momentum=momentum, mu=mu, interpret=use_interpret(),
    )


def quantize_int8(x, *, qblock=256):
    return _qz.quantize_pallas(x, qblock=qblock, interpret=use_interpret())


def quantize_stacked(x, *, qblock=256):
    """Stacked (N, D) → (q (N, Dp) int8, scales (N, Dp/qblock) f32), blocks
    per client row — the fused aggregate kernel's payload layout."""
    return _qz.quantize_stacked_pallas(x, qblock=qblock, interpret=use_interpret())


def dequantize_int8(q, s, shape, dtype=jnp.float32):
    return _qz.dequantize_pallas(q, s, shape, dtype, interpret=use_interpret())
