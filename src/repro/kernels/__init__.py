"""Pallas TPU kernels for the framework's compute hot-spots.

hier_aggregate — the paper's fused grouped weighted-mean aggregation
  (uniform + ragged segment kernels, plus the fused int8
  dequantize-and-segment-aggregate kernel for the compressed transport)
flash_attention — O(S·d)-HBM attention for the 32k prefill / 4k train cells
rglru_scan — RG-LRU linear recurrence, sequential-in-time / wide-in-channels
quantize — blockwise int8 for the compressed HierFAVG link payloads

Each has a pure-jnp oracle in ref.py; ops.py is the jit'd public API with
interpret=True off-TPU (validated on CPU, lowered on TPU).
"""
from repro.kernels import flash_attention, hier_aggregate, ops, quantize, ref, rglru_scan

__all__ = ["flash_attention", "hier_aggregate", "ops", "quantize", "ref", "rglru_scan"]
