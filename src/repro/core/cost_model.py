"""Latency / energy cost model (Section IV, Eq. 4-5, Tables I-II) plus the
TPU-cluster variant used for the roofline work.

Paper model (wireless clients):
    T_comp = c*D / f                 E_comp = (alpha/2) * c * D * f^2
    T_comm = M / (B * log2(1 + h*p/sigma))     E_comm = p * T_comm
with the cloud hop taking ``cloud_latency_mult`` (=10) x the edge latency.
Client *energy* only covers local compute + the client radio uplink — the
edge->cloud backhaul costs latency, not device energy (this is the only
reading consistent with the paper's own Table II numbers; verified by test).

Per cloud interval (kappa1*kappa2 local steps):
    time   = kappa1*kappa2*T_comp + kappa2*T_comm_edge + (mult-1)*T_comm_edge
    energy = kappa1*kappa2*E_comp + kappa2*E_comm_edge
which for kappa2 = 1 reduces exactly to cloud-based FAVG
(kappa1*T_comp + mult*T_comm_edge).

TPU variant: the same schedule algebra with T_comm replaced by collective
times from the roofline terms (ICI for edge, DCN for cloud).
"""
from __future__ import annotations

import dataclasses
import math
from typing import Callable, Optional, Sequence, Tuple


@dataclasses.dataclass(frozen=True)
class WirelessParams:
    """Table I / Section IV-A constants."""

    bandwidth_hz: float = 1e6
    channel_gain: float = 1e-8
    tx_power_w: float = 0.5
    noise_w: float = 1e-10
    cycles_per_bit: float = 20.0
    cpu_freq_hz: float = 1e9
    capacitance: float = 2e-28
    cloud_latency_mult: float = 10.0

    def t_comp(self, d_bits: float) -> float:
        return self.cycles_per_bit * d_bits / self.cpu_freq_hz

    def e_comp(self, d_bits: float) -> float:
        return 0.5 * self.capacitance * self.cycles_per_bit * d_bits * self.cpu_freq_hz ** 2

    def spectral_rate(self) -> float:
        snr = self.channel_gain * self.tx_power_w / self.noise_w
        return self.bandwidth_hz * math.log2(1.0 + snr)

    def t_comm(self, m_bits: float) -> float:
        return m_bits / self.spectral_rate()

    def e_comm(self, m_bits: float) -> float:
        return self.tx_power_w * self.t_comm(m_bits)


@dataclasses.dataclass(frozen=True)
class WorkloadCosts:
    """Per-local-iteration / per-upload costs for one workload (Table I row)."""

    t_comp: float
    t_comm_edge: float
    e_comp: float
    e_comm_edge: float
    cloud_latency_mult: float = 10.0

    @property
    def t_comm_cloud(self) -> float:
        return self.cloud_latency_mult * self.t_comm_edge

    def with_bits(self, edge_bits_per_param: float = 32.0, cloud_bits_per_param: float = 32.0) -> "WorkloadCosts":
        """Costs under a compressed transport (``fed.transport``): uploads
        carry ``bits/32`` of the fp32 payload per hop. Edge comm time/energy
        scale by the edge ratio; ``cloud_latency_mult`` is rescaled by the
        cloud/edge ratio so ``t_comm_cloud`` lands at exactly
        ``mult * (cloud_bits/32) * t_comm_edge_orig`` — every downstream
        schedule formula then accounts the compressed wire unchanged.
        Compute costs are untouched (quantization is roofline-negligible;
        see ``docs/compression.md``)."""
        if edge_bits_per_param <= 0 or cloud_bits_per_param <= 0:
            raise ValueError("bits per parameter must be positive")
        es = edge_bits_per_param / 32.0
        cs = cloud_bits_per_param / 32.0
        return dataclasses.replace(
            self,
            t_comm_edge=self.t_comm_edge * es,
            e_comm_edge=self.e_comm_edge * es,
            cloud_latency_mult=self.cloud_latency_mult * (cs / es),
        )


# Paper workloads. D (bits touched per local iteration) and M (model bits)
# back-derived from the architecture: M = #params * 32; D chosen by the paper
# such that Table I holds (MNIST: 1.2e6 bits; CIFAR: 2e8 bits).
MNIST_MODEL_BITS = 21840 * 32
CIFAR_MODEL_BITS = 5852170 * 32
MNIST_DATA_BITS_PER_ITER = 1.2e6
CIFAR_DATA_BITS_PER_ITER = 2e8


def paper_workload(name: str, wireless: Optional[WirelessParams] = None) -> WorkloadCosts:
    w = wireless or WirelessParams()
    if name == "mnist":
        d, m = MNIST_DATA_BITS_PER_ITER, MNIST_MODEL_BITS
    elif name == "cifar10":
        d, m = CIFAR_DATA_BITS_PER_ITER, CIFAR_MODEL_BITS
    else:
        raise ValueError(name)
    return WorkloadCosts(
        t_comp=w.t_comp(d),
        t_comm_edge=w.t_comm(m),
        e_comp=w.e_comp(d),
        e_comm_edge=w.e_comm(m),
        cloud_latency_mult=w.cloud_latency_mult,
    )


# ---------------------------------------------------------------------------
# Schedule accounting
# ---------------------------------------------------------------------------

def cloud_interval_time(costs: WorkloadCosts, kappa1: int, kappa2: int) -> float:
    return (
        kappa1 * kappa2 * costs.t_comp
        + kappa2 * costs.t_comm_edge
        + (costs.cloud_latency_mult - 1.0) * costs.t_comm_edge
    )


def cloud_interval_energy(costs: WorkloadCosts, kappa1: int, kappa2: int) -> float:
    return kappa1 * kappa2 * costs.e_comp + kappa2 * costs.e_comm_edge


def time_at_step(costs: WorkloadCosts, kappa1: int, kappa2: int, k: int) -> float:
    """Wall-clock time after k local updates (completed intervals + partials)."""
    ci = kappa1 * kappa2
    full, rem = divmod(k, ci)
    t = full * cloud_interval_time(costs, kappa1, kappa2)
    t += rem * costs.t_comp
    t += (rem // kappa1) * costs.t_comm_edge
    return t


def energy_at_step(costs: WorkloadCosts, kappa1: int, kappa2: int, k: int) -> float:
    ci = kappa1 * kappa2
    full, rem = divmod(k, ci)
    e = full * cloud_interval_energy(costs, kappa1, kappa2)
    e += rem * costs.e_comp
    e += (rem // kappa1) * costs.e_comm_edge
    return e


def time_energy_to_accuracy(
    costs: WorkloadCosts,
    kappa1: int,
    kappa2: int,
    steps_to_accuracy: int,
) -> Tuple[float, float]:
    """(T_alpha, E_alpha): wall-clock and device energy to reach the step at
    which the training run first hit accuracy alpha (measured externally)."""
    return (
        time_at_step(costs, kappa1, kappa2, steps_to_accuracy),
        energy_at_step(costs, kappa1, kappa2, steps_to_accuracy),
    )


# ---------------------------------------------------------------------------
# TPU-cluster cost variant (used with roofline outputs)
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class ClusterCosts:
    """Per-local-step compute time and per-aggregation collective times, in
    seconds, normally filled from analysis.roofline terms."""

    t_step: float  # one local update (compute+memory roofline max)
    t_edge_agg: float  # grouped intra-pod all-reduce (ICI)
    t_cloud_agg: float  # cross-pod all-reduce (DCN)

    def with_bits(self, edge_bits_per_param: float = 32.0, cloud_bits_per_param: float = 32.0) -> "ClusterCosts":
        """Collective times under compressed transport: bandwidth-bound
        all-reduce time scales with the wire bytes."""
        if edge_bits_per_param <= 0 or cloud_bits_per_param <= 0:
            raise ValueError("bits per parameter must be positive")
        return dataclasses.replace(
            self,
            t_edge_agg=self.t_edge_agg * edge_bits_per_param / 32.0,
            t_cloud_agg=self.t_cloud_agg * cloud_bits_per_param / 32.0,
        )

    def interval_time(self, kappa1: int, kappa2: int) -> float:
        return kappa1 * kappa2 * self.t_step + kappa2 * self.t_edge_agg + self.t_cloud_agg

    def per_step_overhead(self, kappa1: int, kappa2: int) -> float:
        """Amortized aggregation cost per local step — the quantity HierFAVG
        drives down (the paper's contribution in roofline terms)."""
        return self.t_edge_agg / kappa1 + self.t_cloud_agg / (kappa1 * kappa2)


def tune_kappas(
    costs,
    steps_to_accuracy_fn: Callable[[int, int], float],
    kappa1s: Sequence[int],
    kappa2s: Sequence[int],
    *,
    objective: str = "time",
) -> Tuple[int, int, float]:
    """Beyond-paper: pick (kappa1, kappa2) minimizing T_alpha or E_alpha.

    steps_to_accuracy_fn(k1, k2) -> expected local steps to target accuracy;
    callers supply either a measured curve or the Theorem-1 bound inverted
    via core.convergence. `costs` is WorkloadCosts or ClusterCosts.
    """
    best = None
    for k1 in kappa1s:
        for k2 in kappa2s:
            steps = steps_to_accuracy_fn(k1, k2)
            if not math.isfinite(steps):
                continue
            if isinstance(costs, ClusterCosts):
                n_int = steps / (k1 * k2)
                t = n_int * costs.interval_time(k1, k2)
                e = t  # no separate device-energy notion on the cluster
            else:
                t = time_at_step(costs, k1, k2, int(round(steps)))
                e = energy_at_step(costs, k1, k2, int(round(steps)))
            val = t if objective == "time" else e
            if best is None or val < best[2]:
                best = (k1, k2, val)
    if best is None:
        raise ValueError("no feasible (kappa1, kappa2)")
    return best
