"""Empirical gradient-divergence probes (Definition 1).

delta_i^l = sup_w ||grad F_i(w) - grad F^l(w)||   (client-edge divergence)
Delta^l   = sup_w ||grad F^l(w) - grad F(w)||     (edge-cloud divergence)

The suprema are estimated by maximizing over a set of probe points (e.g. the
parameter trajectory of a training run, or random perturbations of w0). The
weighted aggregates delta and Delta feed the convergence bounds and the
kappa auto-tuner, and let experiments *quantify* edge-IID vs edge-NIID
partitions rather than eyeballing them.
"""
from __future__ import annotations

from typing import Any, Callable, Sequence

import jax
import jax.numpy as jnp
import numpy as np

PyTree = Any


def _tree_norm(tree: PyTree) -> jnp.ndarray:
    return jnp.sqrt(
        sum(jnp.sum(jnp.square(x.astype(jnp.float32))) for x in jax.tree_util.tree_leaves(tree))
    )


def _tree_sub(a: PyTree, b: PyTree) -> PyTree:
    return jax.tree_util.tree_map(lambda x, y: x.astype(jnp.float32) - y.astype(jnp.float32), a, b)


def _weighted_tree_mean(trees: Sequence[PyTree], weights: np.ndarray) -> PyTree:
    total = float(np.sum(weights))
    out = jax.tree_util.tree_map(lambda x: x.astype(jnp.float32) * (weights[0] / total), trees[0])
    for t, w in zip(trees[1:], weights[1:]):
        out = jax.tree_util.tree_map(lambda a, x: a + x.astype(jnp.float32) * (w / total), out, t)
    return out


def measure_divergence(
    grad_fn: Callable[[PyTree, int], PyTree],
    params_probes: Sequence[PyTree],
    data_sizes: np.ndarray,
    num_edges: int,
):
    """Estimate (delta_i^l, Delta^l, delta, Delta) over probe points.

    grad_fn(w, i) -> client i's full-batch gradient of F_i at w.
    data_sizes: (N,) |D_i|, clients edge-major. Returns a dict with the
    per-client / per-edge bounds (max over probes) and weighted aggregates.
    """
    sizes = np.asarray(data_sizes, dtype=np.float64)
    n = sizes.shape[0]
    c = n // num_edges
    delta_il = np.zeros(n)
    Delta_l = np.zeros(num_edges)

    for w in params_probes:
        grads = [grad_fn(w, i) for i in range(n)]
        edge_grads = []
        for l in range(num_edges):
            idx = list(range(l * c, (l + 1) * c))
            edge_grads.append(_weighted_tree_mean([grads[i] for i in idx], sizes[idx]))
        global_grad = _weighted_tree_mean(
            edge_grads, np.array([sizes[l * c : (l + 1) * c].sum() for l in range(num_edges)])
        )
        for i in range(n):
            l = i // c
            d = float(_tree_norm(_tree_sub(grads[i], edge_grads[l])))
            delta_il[i] = max(delta_il[i], d)
        for l in range(num_edges):
            d = float(_tree_norm(_tree_sub(edge_grads[l], global_grad)))
            Delta_l[l] = max(Delta_l[l], d)

    edge_sizes = sizes.reshape(num_edges, c).sum(axis=1)
    delta = float(np.sum(sizes * delta_il) / sizes.sum())
    Delta = float(np.sum(edge_sizes * Delta_l) / sizes.sum())
    return {
        "delta_client_edge": delta_il,
        "Delta_edge_cloud": Delta_l,
        "delta": delta,
        "Delta": Delta,
    }


def estimate_beta_smoothness(
    grad_fn: Callable[[PyTree], PyTree],
    w0: PyTree,
    rng: jax.Array,
    *,
    num_probes: int = 8,
    radius: float = 1e-2,
) -> float:
    """Crude beta estimate: max ||g(w+e) - g(w)|| / ||e|| over random e."""
    g0 = grad_fn(w0)
    beta = 0.0
    leaves, treedef = jax.tree_util.tree_flatten(w0)
    for k in range(num_probes):
        rng, sub = jax.random.split(rng)
        keys = jax.random.split(sub, len(leaves))
        eps = [radius * jax.random.normal(kk, x.shape, jnp.float32) for kk, x in zip(keys, leaves)]
        eps_tree = jax.tree_util.tree_unflatten(treedef, eps)
        w1 = jax.tree_util.tree_map(lambda x, e: x + e.astype(x.dtype), w0, eps_tree)
        g1 = grad_fn(w1)
        beta = max(beta, float(_tree_norm(_tree_sub(g1, g0)) / _tree_norm(eps_tree)))
    return beta
