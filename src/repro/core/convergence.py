"""Convergence-analysis machinery from Section III (Lemmas 1-3, Theorems 1-2).

These closed forms are used three ways in the framework:
  1. tests assert the paper's qualitative claims (Remarks 1-2) hold for the
     implemented formulas;
  2. the divergence probes (core.divergence) feed *measured* delta / Delta
     into the bounds to predict convergence behaviour;
  3. the kappa auto-tuner (core.cost_model.tune_kappas) minimizes
     time-to-accuracy under the bound — the "future investigation" the paper
     leaves open.

Paper erratum (documented in DESIGN.md / EXPERIMENTS.md): the printed
``h(x, delta, eta) = delta/beta ((eta*beta+1)^x - 1) - eta*beta*x`` has a
typo in the linear term — with delta = 0 it would give h < 0, contradicting
Remark 2's "delta = Delta = 0  =>  G_c = 0" and the source analysis it cites
(Wang et al. 2019, where g(x) = delta/beta((eta*beta+1)^x - 1) - eta*delta*x).
We implement ``- eta*delta*x``, which satisfies h(x, 0, eta) = 0 and
reproduces every property the paper derives from it.
"""
from __future__ import annotations

import math


def h(x: float, delta: float, eta: float, beta: float) -> float:
    """Weight-divergence growth over x local steps under gradient divergence delta."""
    if x <= 0:
        return 0.0
    return (delta / beta) * ((eta * beta + 1.0) ** x - 1.0) - eta * delta * x


def p_of_k(k: int, q: int, kappa1: int, kappa2: int) -> int:
    """Edge-interval index [p] of local step k inside cloud interval {q}."""
    return math.ceil(k / kappa1 - (q - 1) * kappa2)


def G_c(
    k: int,
    kappa1: int,
    kappa2: int,
    delta: float,
    Delta: float,
    eta: float,
    beta: float,
    *,
    q: int = 1,
) -> float:
    """Lemma 2: deviation bound ||w(k) - u_{q}(k)|| for convex losses at step k."""
    p = p_of_k(k, q, kappa1, kappa2)
    t_cloud = k - (q - 1) * kappa1 * kappa2
    t_edge = k - ((q - 1) * kappa2 + p - 1) * kappa1
    return (
        h(t_cloud, Delta, eta, beta)
        + h(t_edge, delta, eta, beta)
        + 0.5 * kappa1 * (p * p + p - 2) * h(kappa1, delta, eta, beta)
    )


def G_c_max(kappa1: int, kappa2: int, delta: float, Delta: float, eta: float, beta: float) -> float:
    """Eq. (2): interval-end upper bound G_c(kappa1*kappa2, eta)."""
    return h(kappa1 * kappa2, Delta, eta, beta) + 0.5 * (
        kappa2 * kappa2 + kappa2 - 1.0
    ) * (kappa1 + 1.0) * h(kappa1, delta, eta, beta)


def G_nc(kappa1: int, kappa2: int, delta: float, Delta: float, eta: float, beta: float) -> float:
    """Lemma 3: deviation bound for non-convex losses."""
    base = (1.0 + eta * beta) ** kappa1 - 1.0
    if base == 0.0:  # eta == 0
        ratio = float(kappa2)
    else:
        ratio = ((1.0 + eta * beta) ** (kappa1 * kappa2) - 1.0) / base
    return (
        h(kappa1 * kappa2, Delta, eta, beta)
        + kappa1 * kappa2 * ratio * h(kappa1, delta, eta, beta)
        + h(kappa1, delta, eta, beta)
    )


def theorem1_bound(
    K: int,
    kappa1: int,
    kappa2: int,
    delta: float,
    Delta: float,
    eta: float,
    beta: float,
    rho: float,
    epsilon: float,
    varphi: float,
) -> float:
    """Theorem 1: F(w(K)) - F(w*) upper bound (convex, fixed step size).

    Returns +inf when the bound's positivity condition fails (the paper's
    condition 2: eta*varphi - rho*G/(kappa1*kappa2*eps^2) > 0).
    """
    B = K / (kappa1 * kappa2)
    g = G_c_max(kappa1, kappa2, delta, Delta, eta, beta)
    denom_term = eta * varphi - rho * g / (kappa1 * kappa2 * epsilon * epsilon)
    if denom_term <= 0 or B <= 0:
        return math.inf
    return 1.0 / (B * denom_term)


def theorem2_bound(
    K: int,
    kappa1: int,
    kappa2: int,
    delta: float,
    Delta: float,
    eta: float,
    beta: float,
    rho: float,
    f0_minus_fstar: float,
) -> float:
    """Theorem 2: bound on the weighted average squared gradient norm
    (non-convex, fixed eta per cloud interval; we take eta constant)."""
    B = K // (kappa1 * kappa2)
    sum_eta = eta * K
    g = G_nc(kappa1, kappa2, delta, Delta, eta, beta)
    return (
        4.0 * f0_minus_fstar / sum_eta
        + 4.0 * rho * B * g / sum_eta
        + 2.0 * beta * beta * B * kappa1 * kappa2 * g * g / sum_eta
    )


# ---------------------------------------------------------------------------
# Qualitative guidelines (Section III-B remarks) as predicates — used by the
# tuner and asserted by tests.
# ---------------------------------------------------------------------------

def guideline_smaller_kappa1(product: int, delta: float, Delta: float, eta: float, beta: float):
    """Remark 2 guideline 1: with kappa1*kappa2 fixed, smaller kappa1 gives a
    smaller deviation bound. Returns the list of (kappa1, kappa2, G) sorted by
    kappa1 so callers/tests can check monotonicity."""
    out = []
    for k1 in range(1, product + 1):
        if product % k1 == 0:
            k2 = product // k1
            out.append((k1, k2, G_c_max(k1, k2, delta, Delta, eta, beta)))
    return out


def guideline_edge_iid_kappa2_free(kappa1: int, delta: float, eta: float, beta: float, kappa2s):
    """Remark 2 guideline 2: with Delta = 0 (edge-IID), G grows only
    quadratically (not exponentially) in kappa2."""
    return [(k2, G_c_max(kappa1, k2, delta, 0.0, eta, beta)) for k2 in kappa2s]
