"""HierFAVG (Algorithm 1) as a composable JAX module.

The production form of the paper's algorithm. Parameters are *stacked* along
a leading client axis (see ``core.aggregation``); a single ``jax.grad`` of
the summed per-client loss yields every client's local gradient at once
(client losses are block-separable in the stacked parameters), so one jitted
``train_step`` advances all N clients one local update and applies the
per-level aggregation schedule. With the paper's κ-vector (κ₁, κ₂):

    k % kappa1 == 0                -> edge aggregation  (grouped, ICI)
    k % (kappa1 * kappa2) == 0     -> cloud aggregation (global, DCN)

and in general, for a depth-L ``HierarchySpec`` with κ = (κ₁, ..., κ_L),
level ℓ aggregates whenever ``k % prod(κ[:ℓ]) == 0`` — the deepest
triggered level wins (its staged mean subsumes all finer levels).

Special cases (paper Remark 1, used as test anchors):
    kappa2 == 1              -> FAVG (two-layer FedAvg)
    kappa1 == kappa2 == 1    -> centralized gradient descent

Two driving modes are exposed:
  * ``build_train_step``  — fused step, aggregation under ``lax.switch``
    (the normal training loop; one compiled executable regardless of k).
  * ``build_local_step`` / ``build_level_sync`` (and the two-level
    ``build_edge_sync`` / ``build_cloud_sync`` wrappers) — the phases as
    separate jittables (used by the dry-run for clean per-phase roofline
    accounting and by the fault-tolerant runner, which injects
    host-detected survival masks at aggregation boundaries).

Topology arguments accept either the seed's two-level ``FedTopology`` or a
ragged ``core.hierarchy.HierarchySpec``; the former is the
``levels=2, uniform`` special case with unchanged numerics.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, Callable, NamedTuple, Optional, Sequence, Tuple, Union

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import aggregation
from repro.core.hierarchy import (
    HierarchySpec,
    ShardPlacement,
    as_hierarchy,
    plan_cohort_placement,
    plan_shard_placement,
)
from repro.optim import GradientTransformation, apply_updates

PyTree = Any
LossFn = Callable[[PyTree, PyTree, jax.Array], jnp.ndarray]  # (params_i, batch_i, rng) -> scalar


@dataclasses.dataclass(frozen=True)
class FedTopology:
    """Client-edge-cloud topology: N = num_edges * clients_per_edge clients.

    The uniform two-level special case; ``hierarchy()`` lifts it into the
    general ragged-tree representation.
    """

    num_edges: int
    clients_per_edge: int

    @property
    def num_clients(self) -> int:
        return self.num_edges * self.clients_per_edge

    def edge_of(self, client: int) -> int:
        return client // self.clients_per_edge

    def hierarchy(self) -> HierarchySpec:
        return HierarchySpec.uniform(self.num_edges, self.clients_per_edge)


Topology = Union[FedTopology, HierarchySpec]


@dataclasses.dataclass(frozen=True)
class PrecisionSpec:
    """Mixed-precision policy for the stacked client state.

    ``param_dtype`` is the storage dtype of the stacked per-client params
    and their stacked optimizer leaves — the N-times-replicated memory that
    dominates device footprint (``"bfloat16"`` halves it). Local-step
    compute runs in the same dtype (batch floating leaves are cast on the
    way into the loss), while every aggregation keeps accumulating in
    float32 (``core.aggregation`` upcasts, reduces, casts back), so the
    per-group / cloud means act as transient fp32 master values re-cast to
    the storage dtype only at the broadcast boundary. Diagnostics (loss /
    grad-norm metrics) are always reduced in float32.

    ``remat`` wraps each per-client loss in ``jax.checkpoint`` so the
    backward pass recomputes activations instead of storing them — the
    knob that trades local-step FLOPs for activation memory when κ₁ steps
    are fused into one executable.
    """

    param_dtype: str = "float32"
    remat: bool = False

    def __post_init__(self):
        dt = jnp.dtype(self.param_dtype)  # raises on unknown names
        if not jnp.issubdtype(dt, jnp.floating):
            raise ValueError(f"param_dtype must be floating, got {self.param_dtype!r}")
        object.__setattr__(self, "param_dtype", dt.name)

    @property
    def dtype(self) -> jnp.dtype:
        return jnp.dtype(self.param_dtype)

    @property
    def is_active(self) -> bool:
        """False for the pure-fp32, no-remat default — every builder then
        takes the exact legacy graph, bitwise unchanged."""
        return self.remat or self.dtype != jnp.dtype(jnp.float32)


@dataclasses.dataclass(frozen=True)
class HierFAVGConfig:
    """Aggregation schedule. kappa1: local steps per edge agg; kappa2: edge
    aggs per cloud agg (paper's κ₁, κ₂). For deeper trees, ``kappas`` holds
    the full per-level vector (κ₁, ..., κ_L): κ_ℓ level-(ℓ-1) intervals per
    level-ℓ aggregation; ``multi_level`` builds a consistent config."""

    kappa1: int
    kappa2: int
    sync_opt_state: bool = False  # also average optimizer state at aggregations
    delta_cloud: bool = False  # cloud agg in delta-vs-anchor form (compressible)
    kappas: Optional[Tuple[int, ...]] = None  # per-level κ vector (None -> (κ₁, κ₂))
    transport: Optional[Any] = None  # fed.transport.TransportSpec: one LinkCodec per level
    aggregators: Optional[Any] = None  # core.aggregation.AggregatorSpec: one per level
    participation: Optional[Any] = None  # fed.participation.ParticipationSpec: sampled cohorts
    precision: Optional[PrecisionSpec] = None  # mixed-precision policy (None == pure fp32)

    def __post_init__(self):
        if self.precision is not None and not isinstance(self.precision, PrecisionSpec):
            raise TypeError(
                f"precision must be a PrecisionSpec, got {type(self.precision).__name__}"
            )
        if self.aggregators is not None:
            if not hasattr(self.aggregators, "aggregator") or not hasattr(
                self.aggregators, "is_trivial"
            ):
                raise TypeError(
                    f"aggregators must be a core.aggregation.AggregatorSpec, got "
                    f"{type(self.aggregators).__name__}"
                )
            n_levels = len(self.kappas) if self.kappas is not None else 2
            if self.aggregators.depth != n_levels:
                raise ValueError(
                    f"aggregators has {self.aggregators.depth} levels but the schedule "
                    f"has {n_levels} (kappas={self.kappas or (self.kappa1, self.kappa2)})"
                )
            if not self.aggregators.is_trivial:
                if self.delta_cloud and not self.aggregators.aggregator(n_levels).is_default:
                    raise ValueError(
                        "delta_cloud requires the default weighted_mean at the top "
                        "level (delta aggregation is a weighted-mean identity)"
                    )
        if self.transport is not None:
            if not hasattr(self.transport, "codec") or not hasattr(self.transport, "is_trivial"):
                raise TypeError(
                    f"transport must be a fed.transport.TransportSpec, got "
                    f"{type(self.transport).__name__}"
                )
            n_levels = len(self.kappas) if self.kappas is not None else 2
            if self.transport.depth != n_levels:
                raise ValueError(
                    f"transport has {self.transport.depth} levels but the schedule has "
                    f"{n_levels} (kappas={self.kappas or (self.kappa1, self.kappa2)})"
                )
            if not self.transport.is_trivial and self.delta_cloud:
                raise ValueError(
                    "a non-identity transport subsumes delta_cloud (both repurpose "
                    "the anchor slot); drop the flag"
                )
        if self.kappas is not None:
            kv = tuple(int(k) for k in self.kappas)
            object.__setattr__(self, "kappas", kv)
            if len(kv) < 1 or any(k < 1 for k in kv):
                raise ValueError(f"kappas must be >= 1 per level, got {kv}")
            if kv[0] != self.kappa1 or (len(kv) > 1 and kv[1] != self.kappa2):
                raise ValueError(
                    f"kappas {kv} inconsistent with kappa1={self.kappa1}, "
                    f"kappa2={self.kappa2}; use HierFAVGConfig.multi_level"
                )
        if self.kappa1 < 1 or self.kappa2 < 1:
            raise ValueError("kappa1/kappa2 must be >= 1")
        if self.participation is not None:
            if not hasattr(self.participation, "cohort_size") or not hasattr(
                self.participation, "is_active"
            ):
                raise TypeError(
                    f"participation must be a fed.participation.ParticipationSpec, got "
                    f"{type(self.participation).__name__}"
                )
            if self.participation.is_active:
                if self.aggregators_active:
                    raise ValueError(
                        "sampled participation requires the default weighted mean at "
                        "every level (a robust statistic over a sampled cohort is not "
                        "the population statistic)"
                    )

    @classmethod
    def multi_level(cls, kappas: Sequence[int], **kwargs) -> "HierFAVGConfig":
        kv = tuple(int(k) for k in kappas)
        if not kv:
            raise ValueError("kappas must have at least one level")
        # a 1-vector is a depth-1 tree (clients -> cloud, classic two-tier
        # FedAvg); kappa2 degrades to 1 for two-level consumers
        return cls(kappa1=kv[0], kappa2=kv[1] if len(kv) > 1 else 1, kappas=kv, **kwargs)

    @property
    def kappa_vector(self) -> Tuple[int, ...]:
        return self.kappas if self.kappas is not None else (self.kappa1, self.kappa2)

    @property
    def num_levels(self) -> int:
        return len(self.kappa_vector)

    def level_interval(self, level: int) -> int:
        """Local steps between level-ℓ aggregations: prod(κ[:ℓ])."""
        return math.prod(self.kappa_vector[:level])

    @property
    def cloud_interval(self) -> int:
        return self.level_interval(self.num_levels)

    @property
    def kappa2_effective(self) -> int:
        """Edge intervals per cloud interval (= κ₂ for two levels) — the
        two-level quantity the paper's cost model consumes."""
        return math.prod(self.kappa_vector[1:])

    def is_level_step(self, level: int, k) -> jnp.ndarray:
        return (k % self.level_interval(level)) == 0

    def is_edge_step(self, k) -> jnp.ndarray:
        return self.is_level_step(1, k)

    def is_cloud_step(self, k) -> jnp.ndarray:
        return self.is_level_step(self.num_levels, k)

    @property
    def transport_active(self) -> bool:
        """True iff some level's uplink actually compresses (an all-identity
        TransportSpec is numerically the uncompressed protocol and allocates
        no anchor/residual state)."""
        return self.transport is not None and not self.transport.is_trivial

    @property
    def aggregators_active(self) -> bool:
        """True iff some level replaces the paper's weighted mean (an
        all-``weighted_mean`` AggregatorSpec is numerically the unchanged
        protocol and takes the exact legacy code path)."""
        return self.aggregators is not None and not self.aggregators.is_trivial

    @property
    def participation_active(self) -> bool:
        """True iff cohort sampling is on (a cohort_size=0 spec is inert and
        every engine keeps its full-population behaviour)."""
        return self.participation is not None and self.participation.is_active

    @property
    def precision_active(self) -> bool:
        """True iff the precision policy changes anything (a pure-fp32,
        no-remat PrecisionSpec keeps the exact legacy graphs)."""
        return self.precision is not None and self.precision.is_active


class FedState(NamedTuple):
    step: jnp.ndarray  # local update counter k
    params: PyTree  # stacked (N, ...) client models
    opt_state: PyTree  # stacked per-client optimizer state
    rng: jax.Array
    anchor: Optional[PyTree] = None  # last broadcast (delta_cloud / compressed transport)
    residual: Optional[PyTree] = None  # per-client error-feedback residual (EF codecs)


def replicate_for_clients(params: PyTree, num_clients: int) -> PyTree:
    """Stack the initial model: every client starts from w0 (Algorithm 1 l.2)."""
    return jax.tree_util.tree_map(
        lambda p: jnp.broadcast_to(p[None], (num_clients,) + p.shape).copy(), params
    )


def init_state(
    rng: jax.Array,
    params: PyTree,
    optimizer: GradientTransformation,
    topology: Topology,
    config: HierFAVGConfig,
    *,
    already_stacked: bool = False,
) -> FedState:
    stacked = params if already_stacked else replicate_for_clients(params, topology.num_clients)
    if config.precision_active:
        # stacked client state is stored (and stepped) in the policy dtype;
        # every aggregation still accumulates in fp32 (core.aggregation)
        dt = config.precision.dtype
        stacked = jax.tree_util.tree_map(
            lambda p: p.astype(dt) if jnp.issubdtype(p.dtype, jnp.floating) else p, stacked
        )
    opt_state = optimizer.init(stacked)
    if config.delta_cloud or config.transport_active:
        # last broadcast each client received: deltas w − anchor are what a
        # compressed uplink carries
        anchor = jax.tree_util.tree_map(jnp.copy, stacked)
    else:
        anchor = None
    residual = None
    if config.transport_active and config.transport.needs_residual:
        residual = jax.tree_util.tree_map(
            lambda p: jnp.zeros(p.shape, jnp.float32), stacked
        )
    return FedState(
        step=jnp.zeros([], jnp.int32), params=stacked, opt_state=opt_state,
        rng=rng, anchor=anchor, residual=residual,
    )


# ---------------------------------------------------------------------------
# Phase builders
# ---------------------------------------------------------------------------

def _apply_precision(loss_fn: LossFn, precision: Optional[PrecisionSpec]) -> LossFn:
    """Wrap a per-client loss with the ``PrecisionSpec`` policy: optional
    ``jax.checkpoint`` (remat) and casting the batch's floating leaves to
    the compute/storage dtype so the forward/backward genuinely run in it.
    The inert policy (or None) returns ``loss_fn`` unchanged — identical
    graph, identical numerics."""
    if precision is None or not precision.is_active:
        return loss_fn
    inner = jax.checkpoint(loss_fn) if precision.remat else loss_fn
    dt = precision.dtype
    if dt == jnp.dtype(jnp.float32):
        return inner

    def cast_loss(params, batch, rng):
        batch = jax.tree_util.tree_map(
            lambda x: x.astype(dt) if jnp.issubdtype(x.dtype, jnp.floating) else x, batch
        )
        return inner(params, batch, rng)

    return cast_loss


def _build_microbatch_grads(loss_fn: LossFn, grad_accum: int):
    """(params, batch, rngs) -> (summed grads, per-client losses) with the
    microbatch accumulation scan — shared by the single-device and the
    client-sharded local steps (identical graphs, identical numerics)."""

    def total_loss(params, batch, rngs):
        losses = jax.vmap(loss_fn)(params, batch, rngs)
        # Sum (not mean): keeps per-client gradients identical to each client
        # running SGD on its own mean loss.
        return jnp.sum(losses), losses

    grad_fn = jax.grad(total_loss, has_aux=True)

    def microbatch_grads(params, batch, rngs):
        if grad_accum == 1:
            return grad_fn(params, batch, rngs)

        def body(carry, micro):
            acc, _ = carry
            g, losses = grad_fn(params, micro, rngs)
            acc = jax.tree_util.tree_map(lambda a, b: a + b, acc, g)
            return (acc, losses), ()

        first = jax.tree_util.tree_map(lambda x: x[0], batch)
        g0, losses0 = grad_fn(params, first, rngs)
        rest = jax.tree_util.tree_map(lambda x: x[1:], batch)
        (acc, losses), _ = jax.lax.scan(body, (g0, losses0), rest)
        acc = jax.tree_util.tree_map(lambda g: g / grad_accum, acc)
        return acc, losses

    return microbatch_grads


def build_local_step(
    loss_fn: LossFn,
    optimizer: GradientTransformation,
    *,
    grad_accum: int = 1,
    precision: Optional[PrecisionSpec] = None,
):
    """One local SGD update for all clients (Algorithm 1 l.5).

    batch leaves:
        grad_accum == 1 : (N, b, ...)
        grad_accum  > 1 : (grad_accum, N, b, ...)   (scanned microbatches)
    ``precision`` applies the mixed-precision policy (batch cast + remat);
    the loss/grad-norm metrics are reduced in fp32 regardless.
    Returns (state, metrics).
    """
    microbatch_grads = _build_microbatch_grads(_apply_precision(loss_fn, precision), grad_accum)

    def local_step(state: FedState, batch: PyTree) -> Tuple[FedState, dict]:
        rng, step_rng = jax.random.split(state.rng)
        n = jax.tree_util.tree_leaves(state.params)[0].shape[0]
        rngs = jax.random.split(step_rng, n)
        grads, losses = microbatch_grads(state.params, batch, rngs)
        updates, opt_state = optimizer.update(grads, state.opt_state, state.params)
        params = apply_updates(state.params, updates)
        gnorm = jnp.sqrt(
            sum(jnp.sum(jnp.square(g.astype(jnp.float32))) for g in jax.tree_util.tree_leaves(grads))
        )
        metrics = {"loss": jnp.mean(losses.astype(jnp.float32)), "grad_norm": gnorm}
        return (
            FedState(
                step=state.step + 1, params=params, opt_state=opt_state, rng=rng,
                anchor=state.anchor, residual=state.residual,
            ),
            metrics,
        )

    return local_step


def _maybe_sync_opt_state(opt_state, agg_fn, sync: bool):
    if not sync:
        return opt_state

    def leaf_ok(x):
        return isinstance(x, jnp.ndarray) and x.ndim >= 1

    return jax.tree_util.tree_map(lambda x: agg_fn(x) if leaf_ok(x) else x, opt_state)


def _shard_row(table, axis: str):
    """Select this shard's row of a host-side (num_shards, ...) table at
    trace time inside ``shard_map`` (via ``lax.axis_index``)."""
    idx = jax.lax.axis_index(axis)
    return jax.lax.dynamic_index_in_dim(jnp.asarray(table), idx, axis=0, keepdims=False)


@dataclasses.dataclass(frozen=True)
class ClientSharding:
    """How each shard of the ``axis``-sharded client dimension sees the tree
    inside a ``shard_map`` body.

    Wraps a ``core.hierarchy.ShardPlacement`` plus the global aggregation
    weights; the ``local_*`` accessors must be called at trace time inside
    the body (they select this shard's row of the host tables with
    ``lax.axis_index``). When every shard has the identical local segment
    layout (uniform packing), ``local_segments`` returns the concrete ids so
    ``segment_weighted_mean`` keeps its static uniform reshape fast path.
    """

    axis: str
    placement: ShardPlacement
    weights_table: Any  # np (num_shards, capacity) f32 permuted+padded weights

    @classmethod
    def build(cls, axis: str, placement: ShardPlacement, weights) -> "ClientSharding":
        table = placement.pad_weights(np.asarray(weights)).reshape(
            placement.num_shards, placement.capacity
        )
        return cls(axis=axis, placement=placement, weights_table=table)

    def local_weights(self):
        return _shard_row(self.weights_table, self.axis)

    def static_segments(self, level: int) -> Optional[np.ndarray]:
        """Concrete (capacity,) local ids when identical across shards."""
        tab = self.placement.local_segments(level)
        return tab[0] if bool((tab == tab[0]).all()) else None

    def local_segments(self, level: int):
        static = self.static_segments(level)
        if static is not None:
            return static
        return _shard_row(self.placement.local_segments(level), self.axis)

    def local_num_segments(self, level: int) -> int:
        return self.placement.local_num_segments(level)

    def client_ids_table(self) -> np.ndarray:
        """(num_shards, capacity) original client ids (phantoms read 0)."""
        return self.placement.gather_index().reshape(
            self.placement.num_shards, self.placement.capacity
        )


def sharding_incompatibility(
    config: HierFAVGConfig,
    topology: Topology,
    num_shards: int,
    placement: Optional[ShardPlacement] = None,
) -> Optional[str]:
    """Why this schedule cannot run client-sharded over ``num_shards``
    devices — None when it can. The runner uses this for engine
    eligibility; ``build_sharded_super_round`` raises on a non-None reason.
    Pass ``placement`` to validate the layout that will actually run
    (otherwise the auto-planned one is checked).
    """
    spec = as_hierarchy(topology)
    if config.delta_cloud and config.sync_opt_state:
        return "delta_cloud + sync_opt_state do not compose (the opt tree has no anchor)"
    if placement is None:
        try:
            placement = plan_shard_placement(spec, num_shards)
        except ValueError as e:
            return str(e)
    elif placement.num_shards != num_shards or placement.spec != spec:
        return (
            f"placement was planned for {placement.num_shards} shard(s) over "
            f"{placement.spec.describe()}, not {num_shards} shard(s) over "
            f"{spec.describe()}"
        )
    if config.aggregators_active:
        if config.aggregators.depth != spec.depth:
            # keep the None-or-reason contract even for configs other
            # entry points would reject (direct predicate callers)
            return (
                f"aggregators cover {config.aggregators.depth} level(s) but "
                f"the tree has depth {spec.depth}"
            )
        if not config.aggregators.aggregator(spec.depth).is_default:
            return (
                "a non-default top-level aggregator needs global order "
                "statistics across shards; only weighted_mean lowers to the "
                "cloud psum"
            )
        for lvl in range(1, spec.depth):
            if not config.aggregators.aggregator(lvl).is_default:
                tab = placement.local_segments(lvl)
                if not bool((tab == tab[0]).all()):
                    return (
                        f"the robust aggregator at level {lvl} needs an "
                        f"identical per-shard segment layout (this packing "
                        f"is ragged across shards)"
                    )
    return None


def build_level_sync(
    topology: Topology,
    config: HierFAVGConfig,
    weights: jnp.ndarray,
    level: int,
    *,
    shard: Optional[ClientSharding] = None,
):
    """Aggregation at one hierarchy level (Algorithm 1 l.25-31 generalized)
    with optional survival mask.

    Level 1 is edge aggregation; level ``spec.depth`` is cloud aggregation.
    Expressed as the staged bottom-up composition (edge means first, then
    region means, then global) so GSPMD emits the ICI-then-DCN reduce
    schedule; numerically equal to the flat level-ℓ segment mean because
    the |D_i| weights compose. The top level honors ``delta_cloud``.

    Robust aggregation: when ``config.aggregators`` assigns this level a
    non-default aggregator (``core.aggregation.AggregatorSpec``, e.g.
    ``trimmed_mean`` or ``coordinate_median``), that statistic replaces the
    weighted mean for this level's sync — applied to whatever the transport
    delivered, so robustness composes with compression and survival masks.
    The default ``weighted_mean`` takes this exact legacy path, bitwise
    unchanged.

    Compressed transport: when ``config.transport`` assigns this level a
    non-identity ``LinkCodec``, each client's upload is its model delta
    w − w_anchor (anchor = last broadcast it received) pushed through the
    codec's encode∘decode before aggregating — the aggregator averages what
    the wire actually delivered: mean_g(anchor + decode(encode(w − anchor)))
    = anchor + mean_g(decode(...)) since the anchor is common within a
    group. Error-feedback codecs carry their residual in
    ``FedState.residual``; the anchor re-syncs to the fresh broadcast after
    *every* level sync (identity levels included) so deltas never straddle
    two broadcasts. Identity-only transports take the exact uncompressed
    path — bitwise unchanged numerics.

    Client-sharded lowering: with ``shard`` (a ``ClientSharding``, for use
    inside a ``shard_map`` body over the client axis) sub-top levels lower
    to device-local segment reductions over the shard-local ids — no
    collective; edge groups never straddle shards by placement — and the
    top level to one grouped ``psum`` (params and, when ``sync_opt_state``,
    the opt leaves ride the same packed reduction). Codec round-trips, EF
    residuals, and robust sub-top aggregators are per-client/per-group and
    stay shard-local.
    """
    spec = as_hierarchy(topology)
    if not 1 <= level <= spec.depth:
        raise ValueError(f"level {level} outside 1..{spec.depth}")
    is_top = level == spec.depth
    codec = None
    if config.transport_active:
        codec = config.transport.codec(level)
        if codec.is_identity:
            codec = None
    # per-level robust aggregator (AggregatorSpec axis); the default
    # weighted mean keeps the exact legacy hierarchical_segment_mean path
    robust = None
    if config.aggregators_active:
        robust = config.aggregators.aggregator(level)
        if robust.is_default:
            robust = None
    if shard is not None:
        return _build_sharded_level_sync(spec, config, level, codec, robust, shard)
    seg_ids = jnp.asarray(spec.segments(level), jnp.int32)
    num_segs = spec.num_nodes(level)

    def level_sync(state: FedState, mask: Optional[jnp.ndarray] = None) -> FedState:
        uploaded = state.params
        residual = state.residual
        if codec is not None:
            delta = jax.tree_util.tree_map(
                lambda x, a: x.astype(jnp.float32) - a.astype(jnp.float32),
                state.params, state.anchor,
            )
            delta_hat, residual = codec.roundtrip(delta, residual)
            uploaded = jax.tree_util.tree_map(
                lambda a, d, x: (a.astype(jnp.float32) + d).astype(x.dtype),
                state.anchor, delta_hat, state.params,
            )
        if is_top and config.delta_cloud and state.anchor is not None:
            agg = lambda t: aggregation.delta_weighted_mean(t, state.anchor, weights, mask)
            params = agg(uploaded)
            anchor = jax.tree_util.tree_map(jnp.copy, params)
        else:
            if robust is not None:
                agg = lambda t: robust(t, weights, spec, level, mask)
            else:
                agg = lambda t: aggregation.hierarchical_segment_mean(t, weights, spec, level, mask)
            params = agg(uploaded)
            if config.transport_active:
                anchor = jax.tree_util.tree_map(jnp.copy, params)
            else:
                anchor = state.anchor
        if codec is not None:
            # A client whose whole level-ℓ group died transmitted nothing
            # and received no broadcast: it must keep its EXACT params and
            # anchor, not the codec roundtrip of them (the aggregation's
            # keep path above saw only `uploaded`). Likewise a masked-out
            # client in a surviving group receives the broadcast but never
            # transmitted, so its EF residual must not be consumed.
            w_eff = weights.astype(jnp.float32)
            if mask is not None:
                w_eff = w_eff * mask.astype(jnp.float32)
            received = jnp.take(
                jax.ops.segment_sum(w_eff, seg_ids, num_segs) > 0, seg_ids
            )  # (N,) group had >= 1 survivor

            def keep_dead(new, old):
                r = received.reshape((-1,) + (1,) * (new.ndim - 1))
                return jnp.where(r, new, old.astype(new.dtype))

            params = jax.tree_util.tree_map(keep_dead, params, state.params)
            anchor = jax.tree_util.tree_map(keep_dead, anchor, state.anchor)
            if residual is not None and state.residual is not None:
                sent = w_eff > 0  # (N,) this client actually uploaded

                def keep_residual(new, old):
                    s = sent.reshape((-1,) + (1,) * (new.ndim - 1))
                    return jnp.where(s, new, old)

                residual = jax.tree_util.tree_map(keep_residual, residual, state.residual)
        opt_state = _maybe_sync_opt_state(state.opt_state, agg, config.sync_opt_state)
        return state._replace(params=params, opt_state=opt_state, anchor=anchor, residual=residual)

    return level_sync


def _build_sharded_level_sync(spec, config, level, codec, robust, shard: ClientSharding):
    """The ``shard``-lowered body of ``build_level_sync`` (see its
    docstring): sub-top levels reduce entirely shard-locally (placement
    guarantees their groups never straddle shards); the top level issues
    exactly one grouped psum. Numerics match the single-device sync up to
    cross-shard summation order at the top level (documented ULP tolerance;
    sub-top syncs add members in the single-device order)."""
    depth = spec.depth
    is_top = level == depth
    if robust is not None:
        if is_top:
            raise ValueError(
                "a non-default top-level aggregator cannot run client-sharded "
                "(global order statistics); see sharding_incompatibility"
            )
        if shard.static_segments(level) is None:
            raise ValueError(
                f"robust aggregator at level {level} needs an identical "
                f"per-shard segment layout; see sharding_incompatibility"
            )
    if is_top and config.delta_cloud and config.sync_opt_state:
        raise ValueError("delta_cloud + sync_opt_state cannot run client-sharded")

    def stage_local(tree, w_local, mask, upto):
        out = tree
        for lvl in range(1, upto + 1):
            out = aggregation.segment_weighted_mean(
                out, w_local, shard.local_segments(lvl), shard.local_num_segments(lvl), mask
            )
        return out

    def level_sync(state: FedState, mask: Optional[jnp.ndarray] = None) -> FedState:
        w_local = shard.local_weights()
        uploaded = state.params
        residual = state.residual
        if codec is not None:
            delta = jax.tree_util.tree_map(
                lambda x, a: x.astype(jnp.float32) - a.astype(jnp.float32),
                state.params, state.anchor,
            )
            delta_hat, residual = codec.roundtrip(delta, residual)
            uploaded = jax.tree_util.tree_map(
                lambda a, d, x: (a.astype(jnp.float32) + d).astype(x.dtype),
                state.anchor, delta_hat, state.params,
            )
        agg = None  # per-tree closure (sub-top opt_state sync)
        synced_opt = None  # opt_state that rode the top-level packed psum
        alive_top = None
        if is_top and config.delta_cloud and state.anchor is not None:
            params, alive_top = aggregation.psum_weighted_mean(
                uploaded, w_local, shard.axis, mask, anchor=state.anchor
            )
            anchor = jax.tree_util.tree_map(jnp.copy, params)
        elif is_top:
            # pack params (+ synced opt leaves) so the cloud boundary issues
            # exactly one cross-device collective
            bundle = {"p": uploaded}
            sync_ix: list = []
            if config.sync_opt_state:
                opt_leaves, opt_def = jax.tree_util.tree_flatten(state.opt_state)
                sync_ix = [
                    i for i, x in enumerate(opt_leaves)
                    if isinstance(x, jnp.ndarray) and x.ndim >= 1
                ]
                bundle["o"] = [opt_leaves[i] for i in sync_ix]
            staged = stage_local(bundle, w_local, mask, depth - 1)
            out, alive_top = aggregation.psum_weighted_mean(staged, w_local, shard.axis, mask)
            params = out["p"]
            if config.sync_opt_state:
                for i, new in zip(sync_ix, out["o"]):
                    opt_leaves[i] = new
                synced_opt = jax.tree_util.tree_unflatten(opt_def, opt_leaves)
            if config.transport_active:
                anchor = jax.tree_util.tree_map(jnp.copy, params)
            else:
                anchor = state.anchor
        else:
            if robust is not None:
                ids = shard.static_segments(level)
                nseg = shard.local_num_segments(level)
                agg = lambda t: robust.segment_call(t, ids, nseg, mask)
            else:
                agg = lambda t: stage_local(t, w_local, mask, level)
            params = agg(uploaded)
            if config.transport_active:
                anchor = jax.tree_util.tree_map(jnp.copy, params)
            else:
                anchor = state.anchor
        if codec is not None:
            # mirror of the single-device keep-dead logic (build_level_sync);
            # at the top level the whole tree is one group, so "my group had
            # a survivor" is the alive bit the packed psum already reduced
            w_eff = w_local.astype(jnp.float32)
            if mask is not None:
                w_eff = w_eff * mask.astype(jnp.float32)
            if is_top:
                received = alive_top
            else:
                ids = jnp.asarray(shard.local_segments(level), jnp.int32)
                nseg = shard.local_num_segments(level)
                received = jnp.take(jax.ops.segment_sum(w_eff, ids, nseg) > 0, ids)

            def keep_dead(new, old):
                r = received
                if r.ndim:
                    r = r.reshape((-1,) + (1,) * (new.ndim - 1))
                return jnp.where(r, new, old.astype(new.dtype))

            params = jax.tree_util.tree_map(keep_dead, params, state.params)
            anchor = jax.tree_util.tree_map(keep_dead, anchor, state.anchor)
            if residual is not None and state.residual is not None:
                sent = w_eff > 0

                def keep_residual(new, old):
                    s = sent.reshape((-1,) + (1,) * (new.ndim - 1))
                    return jnp.where(s, new, old)

                residual = jax.tree_util.tree_map(keep_residual, residual, state.residual)
        if synced_opt is not None:
            opt_state = synced_opt
        else:
            opt_state = _maybe_sync_opt_state(state.opt_state, agg, config.sync_opt_state)
        return state._replace(params=params, opt_state=opt_state, anchor=anchor, residual=residual)

    return level_sync


def build_edge_sync(topology: Topology, config: HierFAVGConfig, weights: jnp.ndarray):
    """Edge aggregation (Algorithm 1 l.8, 25-28): level-1 sync."""
    return build_level_sync(topology, config, weights, 1)


def build_cloud_sync(topology: Topology, config: HierFAVGConfig, weights: jnp.ndarray):
    """Cloud aggregation (Algorithm 1 l.18-21, 29-31): top-level sync."""
    return build_level_sync(topology, config, weights, as_hierarchy(topology).depth)


# ---------------------------------------------------------------------------
# Fused train step
# ---------------------------------------------------------------------------

def _check_levels(spec: HierarchySpec, config: HierFAVGConfig) -> int:
    if config.num_levels != spec.depth:
        raise ValueError(
            f"schedule has {config.num_levels} levels (kappas="
            f"{config.kappa_vector}) but the hierarchy has depth {spec.depth}"
        )
    return spec.depth


def build_train_step(
    loss_fn: LossFn,
    optimizer: GradientTransformation,
    topology: Topology,
    config: HierFAVGConfig,
    weights: jnp.ndarray,
    *,
    grad_accum: int = 1,
):
    """Fused HierFAVG step: local update + conditional per-level aggregation.

    train_step(state, batch, mask=None) -> (state, metrics). ``mask`` is the
    (N,) survival vector from the failure detector (None == all alive).

    The level intervals nest (prod(κ[:ℓ]) divides prod(κ[:ℓ+1])), so the set
    of levels triggered at step k is a prefix 1..m; a single ``lax.switch``
    on m picks the deepest triggered level, whose staged mean subsumes the
    finer ones. m=0 (no boundary) is the identity branch.
    """
    spec = as_hierarchy(topology)
    depth = _check_levels(spec, config)
    local_step = build_local_step(loss_fn, optimizer, grad_accum=grad_accum, precision=config.precision)
    level_syncs = [build_level_sync(spec, config, weights, l) for l in range(1, depth + 1)]

    def train_step(state: FedState, batch: PyTree, mask: Optional[jnp.ndarray] = None):
        state, metrics = local_step(state, batch)
        k = state.step
        deepest = sum(
            config.is_level_step(l, k).astype(jnp.int32) for l in range(1, depth + 1)
        )
        branches = [lambda s: s] + [
            (lambda sync: lambda s: sync(s, mask))(sync) for sync in level_syncs
        ]
        state = jax.lax.switch(deepest, branches, state)
        metrics["step"] = k
        return state, metrics

    return train_step


def build_hier_round(
    loss_fn: LossFn,
    optimizer: GradientTransformation,
    topology: Topology,
    config: HierFAVGConfig,
    weights: jnp.ndarray,
    *,
    grad_accum: int = 1,
):
    """One full *edge interval* as a single jittable: kappa1 local steps
    (scanned) + the deepest due aggregation (edge every round, level ℓ
    every prod(κ₂..κ_ℓ) rounds).

    This is the deployable unit the dry-run lowers: batch leaves carry a
    leading (kappa1,) axis; the aggregation level is selected by the round
    index via one ``lax.switch``.
    """
    spec = as_hierarchy(topology)
    depth = _check_levels(spec, config)
    local_step = build_local_step(loss_fn, optimizer, grad_accum=grad_accum, precision=config.precision)
    level_syncs = [build_level_sync(spec, config, weights, l) for l in range(1, depth + 1)]
    kv = config.kappa_vector
    # rounds between level-ℓ aggregations: prod(κ₂..κ_ℓ)  (level 1 = every round)
    round_intervals = [math.prod(kv[1:l]) for l in range(1, depth + 1)]

    def hier_round(state: FedState, batches: PyTree, round_index: jnp.ndarray, mask=None):
        def body(s, b):
            s, m = local_step(s, b)
            return s, (m["loss"], m["grad_norm"])

        state, (losses, gnorms) = jax.lax.scan(body, state, batches)
        rounds_done = round_index + 1
        deepest = sum(
            ((rounds_done % iv) == 0).astype(jnp.int32) for iv in round_intervals
        )
        # every round ends with at least the edge sync -> branch index deepest-1
        branches = [(lambda sync: lambda s: sync(s, mask))(sync) for sync in level_syncs]
        state = jax.lax.switch(deepest - 1, branches, state)
        return state, {"loss": jnp.mean(losses), "grad_norm": jnp.mean(gnorms)}

    return hier_round


def super_round_schedule(config: HierFAVGConfig) -> Tuple[int, ...]:
    """Deepest aggregation level after each of the κ₂ rounds of one cloud
    interval (1 = edge only, depth = cloud). Static — every level interval
    divides the cloud interval, so the pattern repeats each superround."""
    kv = config.kappa_vector
    depth = len(kv)
    round_intervals = [math.prod(kv[1:l]) for l in range(1, depth + 1)]
    k2_eff = config.kappa2_effective
    return tuple(
        sum(1 for iv in round_intervals if (j + 1) % iv == 0) for j in range(k2_eff)
    )


def build_super_round(
    loss_fn: LossFn,
    optimizer: GradientTransformation,
    topology: Topology,
    config: HierFAVGConfig,
    weights: jnp.ndarray,
    *,
    grad_accum: int = 1,
):
    """One full *cloud interval* as a single jittable: κ₂ effective edge
    intervals (each κ₁ scanned local steps + its due aggregation) fused into
    one ``lax.scan`` over rounds, the per-round level switch folded into the
    scan via the static ``super_round_schedule`` vector.

    This is the zero-copy engine's dispatch unit (``fed.engine``): jitted
    with ``donate_argnums=(0,)`` the multi-copy stacked ``FedState`` (params
    + opt_state + anchor + EF residual) is updated in place instead of
    round-tripped through fresh HBM allocations, and the host regains
    control only at the cloud boundary — exactly the paper's natural
    synchronization point.

        super_round(state, batches, masks=None) -> (state, metrics)

    batch leaves carry a leading (κ₂, κ₁) axis pair; ``masks`` is an
    optional (κ₂, N) stack of per-round survival vectors. Metrics come back
    *stacked* — ``{"loss": (κ₂,), "grad_norm": (κ₂,), "step": (κ₂,)}`` —
    and live on device so the caller can defer the host fetch (async
    metrics; ``RoundRecord`` history is reconstructed later).

    Numerically bit-exact to driving ``build_hier_round`` κ₂ times from a
    cloud-aligned round index: the scan body is the same local-step scan +
    ``lax.switch`` subgraph. Callers must start at a cloud boundary
    (round index ≡ 0 mod κ₂ effective) — the folded schedule assumes it.
    """
    spec = as_hierarchy(topology)
    depth = _check_levels(spec, config)
    local_step = build_local_step(loss_fn, optimizer, grad_accum=grad_accum, precision=config.precision)
    level_syncs = [build_level_sync(spec, config, weights, l) for l in range(1, depth + 1)]
    deepest_per_round = jnp.asarray(super_round_schedule(config), jnp.int32)

    def super_round(state: FedState, batches: PyTree, masks: Optional[jnp.ndarray] = None):
        def round_body(s, xs):
            if masks is None:
                deepest, batch_r = xs
                mask_r = None
            else:
                deepest, batch_r, mask_r = xs

            def step_body(ss, b):
                ss, m = local_step(ss, b)
                return ss, (m["loss"], m["grad_norm"])

            s, (losses, gnorms) = jax.lax.scan(step_body, s, batch_r)
            branches = [(lambda sync: lambda st: sync(st, mask_r))(sync) for sync in level_syncs]
            s = jax.lax.switch(deepest - 1, branches, s)
            metrics = {
                "loss": jnp.mean(losses),
                "grad_norm": jnp.mean(gnorms),
                "step": s.step,
            }
            return s, metrics

        xs = (deepest_per_round, batches)
        if masks is not None:
            xs = xs + (masks,)
        return jax.lax.scan(round_body, state, xs)

    return super_round


def deadline_incompatibility(config: HierFAVGConfig, topology: Topology) -> Optional[str]:
    """Why this schedule cannot run under the semi-synchronous deadline
    engine (``build_deadline_super_round``) — None when it can.

    Mirrors ``sharding_incompatibility``: the single predicate both the
    builder (raises) and the runner's engine dispatch (reports) consult.
    The gated cloud sync needs the plain weighted mean at the top level —
    the staleness gate is a per-client weight multiplier, which is only a
    sound reweighting for a linear aggregator — and a broadcast every edge
    actually receives, which anchor-based transports and averaged optimizer
    state do not yet model for partially-received rounds.
    """
    spec = as_hierarchy(topology)
    if config.transport_active:
        return (
            "compressed transports re-sync every client's anchor at each "
            "boundary; a late edge that missed the broadcast would desync "
            "its delta reference"
        )
    if config.delta_cloud:
        return "delta_cloud's anchor rebroadcast assumes every edge receives each round"
    if config.sync_opt_state:
        return (
            "optimizer-state averaging has no per-edge keep path for late "
            "subtrees yet"
        )
    if config.aggregators_active and not config.aggregators.aggregator(spec.depth).is_default:
        return (
            "the staleness gate reweights client columns, which is only a "
            "sound transformation of the default weighted mean at the top level"
        )
    if config.participation_active:
        return "sampled participation runs through the cohort engine"
    return None


def build_deadline_super_round(
    loss_fn: LossFn,
    optimizer: GradientTransformation,
    topology: Topology,
    config: HierFAVGConfig,
    weights: jnp.ndarray,
    *,
    grad_accum: int = 1,
):
    """One *semi-synchronous* cloud interval: ``build_super_round`` with the
    top-level sync gated by a per-client cloud-arrival weight vector.

        deadline_round(state, batches, gate, masks=None) -> (state, metrics)

    ``gate`` is (N,) float32: each client's edge-level arrival × staleness
    multiplier for THIS interval's cloud aggregation (constant within an
    edge; produced by ``fed.deadline.RoundPlan.client_gate``). Semantics at
    the interval's final round:

    * sub-top stages run exactly as the synchronous staged mean — every
      edge performs its own edge sync with the survival mask, late edges
      included (their clients hold the fresh edge model while the upload
      is in flight);
    * the top stage aggregates with ``mask * gate``: folded edges
      contribute at their staleness-decayed weight, late/dropped edges at
      weight 0;
    * clients whose gate is 0 did not receive the broadcast — they keep
      the edge-synced model instead of the new cloud model (the carry that
      turns "late" into "stale next round" rather than "dropped").

    Sub-top rounds of the interval are byte-identical to
    ``build_super_round``'s (same ``build_level_sync`` branches). With an
    all-ones gate the top stage performs the identical op sequence as the
    synchronous staged mean plus an all-true select; the engine still
    dispatches the stock ``build_super_round`` executable for such trivial
    rounds, so the bit-exact parity contract never rides on XLA emitting
    identical code for two different graphs.
    """
    spec = as_hierarchy(topology)
    depth = _check_levels(spec, config)
    reason = deadline_incompatibility(config, topology)
    if reason is not None:
        raise ValueError(f"schedule cannot run the deadline engine: {reason}")
    local_step = build_local_step(loss_fn, optimizer, grad_accum=grad_accum, precision=config.precision)
    # sub-top syncs are the stock branches; the top branch is rebuilt below
    level_syncs = [build_level_sync(spec, config, weights, l) for l in range(1, depth)]
    deepest_per_round = jnp.asarray(super_round_schedule(config), jnp.int32)

    def gated_top_sync(state: FedState, mask_r, gate) -> FedState:
        # staged composition, mirroring hierarchical_segment_mean(..., depth):
        # sub-top stages with the survival mask alone (every edge syncs),
        # the top stage with mask * gate (only folded edges contribute)
        mid = state.params
        for lvl in range(1, depth):
            mid = aggregation.segment_weighted_mean(
                mid, weights, spec.segments(lvl), spec.num_nodes(lvl), mask_r
            )
        top_mask = gate if mask_r is None else mask_r * gate
        top = aggregation.segment_weighted_mean(
            mid, weights, spec.segments(depth), spec.num_nodes(depth), top_mask
        )
        received = gate > 0  # (N,) this client's edge got the broadcast

        def select(new, old):
            r = received.reshape((-1,) + (1,) * (new.ndim - 1))
            return jnp.where(r, new, old)

        params = jax.tree_util.tree_map(select, top, mid)
        return state._replace(params=params)

    def deadline_round(
        state: FedState,
        batches: PyTree,
        gate: jnp.ndarray,
        masks: Optional[jnp.ndarray] = None,
    ):
        def round_body(s, xs):
            if masks is None:
                deepest, batch_r = xs
                mask_r = None
            else:
                deepest, batch_r, mask_r = xs

            def step_body(ss, b):
                ss, m = local_step(ss, b)
                return ss, (m["loss"], m["grad_norm"])

            s, (losses, gnorms) = jax.lax.scan(step_body, s, batch_r)
            branches = [
                (lambda sync: lambda st: sync(st, mask_r))(sync) for sync in level_syncs
            ] + [lambda st: gated_top_sync(st, mask_r, gate)]
            s = jax.lax.switch(deepest - 1, branches, s)
            metrics = {
                "loss": jnp.mean(losses),
                "grad_norm": jnp.mean(gnorms),
                "step": s.step,
            }
            return s, metrics

        xs = (deepest_per_round, batches)
        if masks is not None:
            xs = xs + (masks,)
        return jax.lax.scan(round_body, state, xs)

    return deadline_round


# ---------------------------------------------------------------------------
# Client-blocked megakernel lowering
# ---------------------------------------------------------------------------

def megakernel_incompatibility(
    config: HierFAVGConfig, topology: Topology, *, grad_accum: int = 1
) -> Optional[str]:
    """Why this schedule cannot run through the client-blocked megakernel
    lowering (``build_megakernel_super_round``) — None when it can.

    Mirrors ``sharding_incompatibility``: the single predicate both the
    builder (raises) and the runner's engine dispatch (reports, then falls
    back to the scan-fused superround) consult. The megakernel restricts to
    the paper topology (two uniform levels) and the plain weighted-mean
    protocol: everything it fuses must be expressible as per-client-block
    local steps plus a trailing segment mean.
    """
    spec = as_hierarchy(topology)
    if not spec.is_paper_topology:
        return (
            f"the megakernel lowering is two-level uniform "
            f"(clients/edges/cloud) only, got {spec.describe()}"
        )
    if config.delta_cloud:
        return "delta_cloud's anchor bookkeeping keeps the scan-fused path"
    if config.transport_active:
        return "compressed transports (codec round-trips, EF residuals) keep the scan-fused path"
    if config.aggregators_active:
        return "non-default aggregators need the full client axis at each sync"
    if config.participation_active:
        return "sampled participation runs through the cohort engine"
    if config.sync_opt_state:
        return "optimizer-state averaging keeps the scan-fused path"
    if grad_accum != 1:
        return "microbatch accumulation keeps the scan-fused path"
    return None


def _rng_step_table(rng: jax.Array, steps: int, num_clients: int):
    """Precompute the per-step per-client key table the sequential
    ``build_local_step`` chain would derive: step t does
    ``rng, step_rng = split(rng); split(step_rng, N)``. A scan of splits
    followed by one vmapped N-way split reproduces the exact same keys
    (bit-exact), returning (final rng, (steps, N, 2) table)."""

    def body(c, _):
        c, s = jax.random.split(c)
        return c, s

    rng, step_keys = jax.lax.scan(body, rng, None, length=steps)
    table = jax.vmap(lambda k: jax.random.split(k, num_clients))(step_keys)
    return rng, table


def _megakernel_block_clients(clients_per_edge: int, bytes_per_client: int) -> int:
    """Client-block size: the largest divisor of ``clients_per_edge`` whose
    block of param+opt rows fits the residency budget (a few MB — VMEM-scale
    on TPU, LLC-scale on CPU). Blocks never straddle an edge, so the
    trailing segment mean stays a per-edge reshape."""
    budget = 4 << 20
    best = 1
    for b in range(1, clients_per_edge + 1):
        if clients_per_edge % b == 0 and b * bytes_per_client <= budget:
            best = b
    return best


def build_megakernel_super_round(
    loss_fn: LossFn,
    optimizer: GradientTransformation,
    topology: Topology,
    config: HierFAVGConfig,
    weights: jnp.ndarray,
    *,
    grad_accum: int = 1,
    block_clients: Optional[int] = None,
):
    """``build_super_round`` lowered client-blocked: the fused edge-interval
    megakernel as one executable per cloud interval.

    The scan-fused superround is step-major — every client advances one
    local step before any client takes its next — so each of the κ₁ steps
    streams the whole stacked (N, …) state through the memory hierarchy.
    This lowering is client-major: per edge interval it maps over blocks of
    ``block_clients`` clients, each block running all κ₁ (unrolled) local
    steps while its params/opt rows stay resident (VMEM on TPU, LLC on
    CPU), then applies the trailing edge/cloud weighted mean. Per-step
    memory traffic drops by ~κ₁× once the stacked state exceeds the cache —
    the regime where this path wins (see docs/performance.md); eligibility
    is ``megakernel_incompatibility``.

        super_round(state, batches, masks=None) -> (state, metrics)

    Same contract as ``build_super_round`` — batch leaves (κ₂, κ₁, N, b,
    …), metrics ``{"loss": (κ₂,), "grad_norm": (κ₂,), "step": (κ₂,)}`` —
    except ``masks`` must be None (the eligibility predicate routes failure
    models to the scan-fused engine). Per-client RNG streams, batches, and
    step math are identical to the baseline; only the summation *order* of
    the segment means and metric reductions differs (documented tolerance,
    ``tests/test_megakernel.py``).
    """
    spec = as_hierarchy(topology)
    _check_levels(spec, config)
    reason = megakernel_incompatibility(config, spec, grad_accum=grad_accum)
    if reason is not None:
        raise ValueError(f"schedule cannot run through the megakernel: {reason}")
    n = spec.num_clients
    num_edges = spec.num_nodes(1)
    cpe = n // num_edges
    k1, k2 = config.kappa1, config.kappa2_effective
    deepest_per_round = super_round_schedule(config)  # static: 1 = edge, 2 = cloud
    w = jnp.asarray(weights, jnp.float32)
    wg = w.reshape(num_edges, cpe)
    den_edge = jnp.sum(wg, axis=1)
    den_cloud = jnp.sum(w)

    loss_p = _apply_precision(loss_fn, config.precision)

    def total_loss(params, batch, rngs):
        losses = jax.vmap(loss_p)(params, batch, rngs)
        return jnp.sum(losses), losses

    grad_fn = jax.grad(total_loss, has_aux=True)

    def edge_mean_leaf(x):
        xf = x.astype(jnp.float32).reshape((num_edges, cpe) + x.shape[1:])
        wexp = wg.reshape((num_edges, cpe) + (1,) * (x.ndim - 1))
        m = jnp.sum(xf * wexp, axis=1) / den_edge.reshape((num_edges,) + (1,) * (x.ndim - 1))
        return jnp.broadcast_to(m[:, None], xf.shape).reshape(x.shape).astype(x.dtype)

    def cloud_mean_leaf(x):
        xf = x.astype(jnp.float32)
        wexp = w.reshape((n,) + (1,) * (x.ndim - 1))
        m = jnp.sum(xf * wexp, axis=0) / den_cloud
        return jnp.broadcast_to(m[None], x.shape).astype(x.dtype)

    tmap, tleaves = jax.tree_util.tree_map, jax.tree_util.tree_leaves

    def block_steps(carry):
        """All κ₁ local steps for one client block, params/opt resident.
        carry leaves: params (Bc, …), opt (stacked (Bc, …) or shared
        scalar), batches (κ₁, Bc, …), rngs (κ₁, Bc, 2)."""
        params, opt, batches, rngs = carry
        losses_t, gsq_t = [], []
        for t in range(k1):
            batch_t = tmap(lambda x: x[t], batches)
            grads, losses = grad_fn(params, batch_t, rngs[t])
            updates, opt = optimizer.update(grads, opt, params)
            params = apply_updates(params, updates)
            losses_t.append(losses.astype(jnp.float32))
            gsq_t.append(
                sum(
                    jnp.sum(jnp.square(g.astype(jnp.float32)), axis=tuple(range(1, g.ndim)))
                    for g in tleaves(grads)
                )
            )
        return params, opt, jnp.stack(losses_t), jnp.stack(gsq_t)

    def super_round(state: FedState, batches: PyTree, masks: Optional[jnp.ndarray] = None):
        if masks is not None:
            raise TypeError(
                "the megakernel superround takes no survival masks; failure "
                "models are routed to the scan-fused engine by eligibility"
            )
        params, opt_state = state.params, state.opt_state
        for leaf in tleaves(opt_state):
            if getattr(leaf, "ndim", 0) >= 1 and leaf.shape[0] != n:
                raise ValueError(
                    f"megakernel needs optimizer state leaves that are either "
                    f"scalar (shared) or stacked (N, ...); got shape {leaf.shape}"
                )
        bytes_per_client = sum(x.nbytes // n for x in tleaves(params)) + sum(
            x.nbytes // n for x in tleaves(opt_state) if getattr(x, "ndim", 0) >= 1
        )
        bc = block_clients if block_clients is not None else _megakernel_block_clients(
            cpe, max(1, bytes_per_client)
        )
        if cpe % bc != 0:
            raise ValueError(f"block_clients={bc} does not divide clients_per_edge={cpe}")
        nb = n // bc

        def reblock(x):
            return x.reshape((nb, bc) + x.shape[1:])

        def reblock_steps(x):
            # (κ₁, N, ...) -> (nb, κ₁, Bc, ...): client-major blocks, each
            # carrying its own κ₁-step slice of batches/keys
            return jnp.moveaxis(x, 1, 0).reshape((nb, bc, k1) + x.shape[2:]).swapaxes(1, 2)

        def block_opt(x):
            if getattr(x, "ndim", 0) >= 1 and x.shape[0] == n:
                return reblock(x)
            return jnp.broadcast_to(x[None], (nb,) + jnp.shape(x))

        def unblock_opt(x, ref):
            if getattr(ref, "ndim", 0) >= 1 and ref.shape[0] == n:
                return x.reshape((n,) + x.shape[2:])
            return x[0]  # shared leaf: every block stepped it identically

        rng, table = _rng_step_table(state.rng, k1 * k2, n)
        step0 = state.step
        loss_r, gnorm_r, step_r = [], [], []
        for j in range(k2):
            pb = tmap(reblock, params)
            ob = tmap(block_opt, opt_state)
            bj = tmap(lambda x: reblock_steps(x[j]), batches)
            tb = reblock_steps(table[j * k1 : (j + 1) * k1])
            pb, ob, losses, gsq = jax.lax.map(block_steps, (pb, ob, bj, tb))
            params = tmap(lambda x: x.reshape((n,) + x.shape[2:]), pb)
            opt_state = tmap(unblock_opt, ob, opt_state)
            # (nb, κ₁, Bc) -> (κ₁, N) in canonical client order
            ls = jnp.moveaxis(losses, 0, 1).reshape(k1, n)
            gs = jnp.moveaxis(gsq, 0, 1).reshape(k1, n)
            loss_r.append(jnp.mean(ls))
            gnorm_r.append(jnp.mean(jnp.sqrt(jnp.sum(gs, axis=1))))
            step_r.append(step0 + (j + 1) * k1)
            mean_leaf = cloud_mean_leaf if deepest_per_round[j] == 2 else edge_mean_leaf
            params = tmap(mean_leaf, params)
        new_state = FedState(
            step=step0 + k1 * k2, params=params, opt_state=opt_state, rng=rng,
            anchor=state.anchor, residual=state.residual,
        )
        metrics = {
            "loss": jnp.stack(loss_r),
            "grad_norm": jnp.stack(gnorm_r),
            "step": jnp.stack(step_r),
        }
        return new_state, metrics

    return super_round


# ---------------------------------------------------------------------------
# Sampled-participation (cohort) lowering
# ---------------------------------------------------------------------------

def cohort_incompatibility(
    config: HierFAVGConfig, topology: Topology, cohort_size: int
) -> Optional[str]:
    """None if the schedule can run cohort-sampled, else a human reason.

    Mirrors ``sharding_incompatibility``: the single predicate both the
    builder (raises) and the runner's dispatch (reports) consult.
    """
    spec = as_hierarchy(topology)
    if config.aggregators_active:
        return "a robust statistic over a sampled cohort is not the population statistic"
    if not 1 <= int(cohort_size) <= spec.num_clients:
        return f"cohort_size {cohort_size} outside 1..{spec.num_clients} (population)"
    part = config.participation
    if part is not None and getattr(part, "sampler", None) == "stratified" and spec.depth >= 2:
        num_edges = spec.num_nodes(1)
        if int(cohort_size) < num_edges:
            # the floor-1-per-alive-edge quota would otherwise over-allocate;
            # reject at eligibility time, naming both numbers, instead of
            # surfacing deep inside sampler construction
            return (
                f"stratified sampling needs cohort_size >= num_edges "
                f"({cohort_size} < {num_edges}): every alive edge gets a "
                f"floor-1 quota, so a smaller cohort cannot cover the edges"
            )
    return None


def init_cohort_state(
    rng: jax.Array,
    params: PyTree,
    optimizer: GradientTransformation,
    config: HierFAVGConfig,
    cohort_size: int,
) -> FedState:
    """Cohort-resident ``FedState``: C stacked rows, not N.

    Zero-init opt_state/residual rows equal what ``ClientStateStore`` hands
    back for never-sampled clients, so a fresh state is exactly "every
    cohort member participates for the first time"."""
    stacked = replicate_for_clients(params, int(cohort_size))
    return init_state(rng, stacked, optimizer, None, config, already_stacked=True)


def _build_cohort_level_sync(spec: HierarchySpec, config: HierFAVGConfig, level: int, cohort_size: int):
    """``build_level_sync`` lowered for a sampled cohort.

    The cohort's per-level segment ids and weights arrive as *traced* inputs
    (``cohort = {"segments": (depth-1, C) int32, "weights": (C,) f32}``), so
    one compiled executable serves every sampled cohort. Segment ids are the
    cohort members' ORIGINAL node ids per level; reductions still run over
    the full node count, and nodes with no sampled member contribute nothing
    (their safe-denominator mean is never taken back). Non-participating
    clients thus carry exactly zero weight in every edge/cloud mean — the
    partial-participation HierFAVG semantics.

    The op-for-op body matches ``build_level_sync``. The top stage is
    cohort-independent (every member maps to the single root), so its ids
    stay static and keep the contiguous-reshape fast path — bit-identical
    to the full-population top stage. Sub-top stages use the traced ids'
    ``segment_sum`` path: bit-identical to the static lowering on ragged
    topologies (same op), within 1 ULP on uniform ones (where the static
    path takes the reshape shortcut instead).

    ``mask`` is an optional (C,) survival vector over the *cohort* columns
    (the failure model's population mask gathered at the sampled ids):
    masked members carry zero weight at every staged level — exactly the
    ``hierarchical_segment_mean`` mask semantics, so at C == N the masked
    cohort sync reproduces the masked full-population sync.
    """
    depth = spec.depth
    is_top = level == depth
    codec = None
    if config.transport_active:
        codec = config.transport.codec(level)
        if codec.is_identity:
            codec = None
    top_ids = np.zeros(int(cohort_size), np.int32)

    def seg(cohort, t):
        return top_ids if t == depth else cohort["segments"][t - 1]

    def stage(tree, cohort, upto, mask):
        out = tree
        for t in range(1, upto + 1):
            out = aggregation.segment_weighted_mean(
                out, cohort["weights"], seg(cohort, t), spec.num_nodes(t), mask
            )
        return out

    def level_sync(state: FedState, cohort, mask: Optional[jnp.ndarray] = None) -> FedState:
        uploaded = state.params
        residual = state.residual
        if codec is not None:
            delta = jax.tree_util.tree_map(
                lambda x, a: x.astype(jnp.float32) - a.astype(jnp.float32),
                state.params, state.anchor,
            )
            delta_hat, residual = codec.roundtrip(delta, residual)
            uploaded = jax.tree_util.tree_map(
                lambda a, d, x: (a.astype(jnp.float32) + d).astype(x.dtype),
                state.anchor, delta_hat, state.params,
            )
        if is_top and config.delta_cloud and state.anchor is not None:
            agg = lambda t: aggregation.delta_weighted_mean(t, state.anchor, cohort["weights"], mask)
            params = agg(uploaded)
            anchor = jax.tree_util.tree_map(jnp.copy, params)
        else:
            agg = lambda t: stage(t, cohort, level, mask)
            params = agg(uploaded)
            if config.transport_active:
                anchor = jax.tree_util.tree_map(jnp.copy, params)
            else:
                anchor = state.anchor
        if codec is not None:
            # every unmasked cohort member uploads and receives (weights are
            # > 0 for sampled clients); the keep-dead plumbing is structurally
            # identical to build_level_sync so the graphs only differ in ids
            w_eff = cohort["weights"].astype(jnp.float32)
            if mask is not None:
                w_eff = w_eff * mask.astype(jnp.float32)
            seg_l = jnp.asarray(seg(cohort, level), jnp.int32)
            received = jnp.take(
                jax.ops.segment_sum(w_eff, seg_l, spec.num_nodes(level)) > 0, seg_l
            )

            def keep_dead(new, old):
                r = received.reshape((-1,) + (1,) * (new.ndim - 1))
                return jnp.where(r, new, old.astype(new.dtype))

            params = jax.tree_util.tree_map(keep_dead, params, state.params)
            anchor = jax.tree_util.tree_map(keep_dead, anchor, state.anchor)
            if residual is not None and state.residual is not None:
                sent = w_eff > 0

                def keep_residual(new, old):
                    s = sent.reshape((-1,) + (1,) * (new.ndim - 1))
                    return jnp.where(s, new, old)

                residual = jax.tree_util.tree_map(keep_residual, residual, state.residual)
        opt_state = _maybe_sync_opt_state(state.opt_state, agg, config.sync_opt_state)
        return state._replace(params=params, opt_state=opt_state, anchor=anchor, residual=residual)

    return level_sync


def build_cohort_super_round(
    loss_fn: LossFn,
    optimizer: GradientTransformation,
    topology: Topology,
    config: HierFAVGConfig,
    *,
    cohort_size: int,
    grad_accum: int = 1,
):
    """``build_super_round`` for a sampled cohort of C clients.

        super_round(state, batches, cohort, masks=None) -> (state, metrics)

    ``state`` stacks C rows (``init_cohort_state``); batch leaves carry a
    leading (κ₂, κ₁) axis pair over cohort-shaped per-step batches;
    ``cohort`` is the traced ``{"segments": (depth-1, C), "weights": (C,)}``
    pytree a ``CohortPrefetcher`` assembles per cloud interval; ``masks`` is
    an optional (κ₂, C) stack of survival vectors over the cohort columns
    (failure/straggler draws gathered at the sampled ids — participation
    and survival compose by masking the cohort's weight columns). Because
    the cohort arrays are inputs rather than constants, resampling never
    recompiles — the executable is reused across every interval.

    With the identity cohort (C == N, weights/segments of the full
    population) this reproduces ``build_super_round`` exactly: bit-exact on
    ragged topologies, within the documented 1-ULP summation-order tolerance
    on uniform ones (see ``_build_cohort_level_sync``).
    """
    spec = as_hierarchy(topology)
    depth = _check_levels(spec, config)
    reason = cohort_incompatibility(config, spec, cohort_size)
    if reason is not None:
        raise ValueError(f"schedule cannot run cohort-sampled: {reason}")
    local_step = build_local_step(loss_fn, optimizer, grad_accum=grad_accum, precision=config.precision)
    level_syncs = [
        _build_cohort_level_sync(spec, config, l, cohort_size) for l in range(1, depth + 1)
    ]
    deepest_per_round = jnp.asarray(super_round_schedule(config), jnp.int32)

    def super_round(state: FedState, batches: PyTree, cohort, masks: Optional[jnp.ndarray] = None):
        def round_body(s, xs):
            if masks is None:
                deepest, batch_r = xs
                mask_r = None
            else:
                deepest, batch_r, mask_r = xs

            def step_body(ss, b):
                ss, m = local_step(ss, b)
                return ss, (m["loss"], m["grad_norm"])

            s, (losses, gnorms) = jax.lax.scan(step_body, s, batch_r)
            branches = [
                (lambda sync: lambda st: sync(st, cohort, mask_r))(sync) for sync in level_syncs
            ]
            s = jax.lax.switch(deepest - 1, branches, s)
            metrics = {
                "loss": jnp.mean(losses),
                "grad_norm": jnp.mean(gnorms),
                "step": s.step,
            }
            return s, metrics

        xs = (deepest_per_round, batches)
        if masks is not None:
            xs = xs + (masks,)
        return jax.lax.scan(round_body, state, xs)

    return super_round


def map_stacked_fed_state(state: FedState, stacked_fn, other_fn, stacked_dim: int) -> FedState:
    """Rebuild a ``FedState`` applying ``stacked_fn`` to every params /
    opt_state / anchor / residual leaf carrying the leading ``stacked_dim``
    client axis and ``other_fn`` to everything else (``step``/``rng`` are
    always "other": their shapes may coincidentally equal ``stacked_dim``).
    The single place that knows which FedState fields carry client rows —
    partition specs and the engine's permute/pad both go through it."""

    def leaf(x):
        if getattr(x, "ndim", 0) >= 1 and x.shape[0] == stacked_dim:
            return stacked_fn(x)
        return other_fn(x)

    sub = lambda t: jax.tree_util.tree_map(leaf, t)
    return FedState(
        step=other_fn(state.step),
        params=sub(state.params),
        opt_state=sub(state.opt_state),
        rng=other_fn(state.rng),
        anchor=None if state.anchor is None else sub(state.anchor),
        residual=None if state.residual is None else sub(state.residual),
    )


def fed_state_partition_specs(state: FedState, axis: str, stacked_dim: int):
    """PartitionSpecs for a (padded) stacked ``FedState``: leaves with a
    leading ``stacked_dim`` client axis shard over ``axis``; ``step`` /
    ``rng`` and scalar opt leaves replicate. Shared by ``shard_map`` specs
    and the engine's ``NamedSharding`` placement."""
    from jax.sharding import PartitionSpec as P

    row, rep = P(axis), P()
    return map_stacked_fed_state(state, lambda _: row, lambda _: rep, stacked_dim)


def build_sharded_super_round(
    loss_fn: LossFn,
    optimizer: GradientTransformation,
    topology: Topology,
    config: HierFAVGConfig,
    weights: jnp.ndarray,
    *,
    mesh,
    axis: str = "clients",
    placement: Optional[ShardPlacement] = None,
    grad_accum: int = 1,
):
    """``build_super_round`` with the stacked client axis sharded over
    ``mesh``'s ``axis`` via ``shard_map`` — the hardware topology mirrors
    the client-edge-cloud topology.

    The state/batches/masks must be in *placement order*: permuted by
    ``placement.gather_index()`` and padded to ``placement.padded_clients``
    (phantom positions carry zero weight; ``fed.engine`` owns the
    conversion). Inside the body every sub-top aggregation is a device-local
    segment reduction and each cloud boundary issues exactly one grouped
    ``psum`` (``core.aggregation.psum_weighted_mean``); per-client RNG
    streams are reproduced exactly by replicating the ``split`` of the
    global key and gathering each shard's original client ids, so local
    steps and sub-top syncs match the single-device superround bit-for-bit
    and only the cloud psum reassociates the weighted sum (documented ULP
    tolerance; see docs/performance.md).

        super_round(state, batches, masks=None) -> (state, metrics)

    batch leaves carry (κ₂, κ₁, padded_N, b, ...); ``masks`` is an optional
    (κ₂, padded_N) stack. Metrics stay per-client so no collective is spent
    on diagnostics: ``{"loss": (κ₂, κ₁, padded_N), "gsq": (κ₂, κ₁,
    padded_N), "step": (κ₂,)}`` — the engine reduces them host-side at
    flush time (phantom columns dropped).
    """
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P

    spec = as_hierarchy(topology)
    depth = _check_levels(spec, config)
    num_shards = int(mesh.shape[axis])
    if placement is None:
        try:
            placement = plan_shard_placement(spec, num_shards)
        except ValueError as e:
            raise ValueError(f"schedule cannot run client-sharded: {e}") from None
    # validate the layout that actually runs, not a freshly planned one
    reason = sharding_incompatibility(config, spec, num_shards, placement=placement)
    if reason is not None:
        raise ValueError(f"schedule cannot run client-sharded: {reason}")
    shard = ClientSharding.build(axis, placement, weights)
    microbatch_grads = _build_microbatch_grads(_apply_precision(loss_fn, config.precision), grad_accum)
    level_syncs = [
        build_level_sync(spec, config, weights, lvl, shard=shard) for lvl in range(1, depth + 1)
    ]
    deepest_per_round = jnp.asarray(super_round_schedule(config), jnp.int32)
    ids_table = shard.client_ids_table()
    n_real = spec.num_clients
    n_padded = placement.padded_clients

    def local_step(s: FedState, batch: PyTree, rngs):
        grads, losses = microbatch_grads(s.params, batch, rngs)
        updates, opt_state = optimizer.update(grads, s.opt_state, s.params)
        params = apply_updates(s.params, updates)
        gsq = sum(
            jnp.sum(jnp.square(g.astype(jnp.float32)), axis=tuple(range(1, g.ndim)))
            for g in jax.tree_util.tree_leaves(grads)
        )
        return (
            FedState(
                step=s.step + 1, params=params, opt_state=opt_state, rng=s.rng,
                anchor=s.anchor, residual=s.residual,
            ),
            losses.astype(jnp.float32),
            gsq,
        )

    def body(state: FedState, batches: PyTree, masks):
        ids = _shard_row(ids_table, axis)
        k1 = config.kappa1
        k2 = len(super_round_schedule(config))
        # Per-step key derivation hoisted out of the step scan: the baseline
        # chain (rng, step_rng = split(rng); split(step_rng, N)) replicated
        # O(N) work inside every sequential scan iteration, which at batch 1
        # dominated the (tiny) per-step math. A scan of bare splits plus one
        # vmapped N-way split + gather of this shard's original client ids
        # reproduces the exact same keys (bit-exact; phantoms reuse client
        # 0's key, their weight is zero) as one batched op per interval.
        def split_body(c, _):
            c, s = jax.random.split(c)
            return c, s

        rng_out, step_keys = jax.lax.scan(split_body, state.rng, None, length=k1 * k2)
        local_keys = jax.vmap(
            lambda k: jnp.take(jax.random.split(k, n_real), ids, axis=0)
        )(step_keys)
        local_keys = local_keys.reshape((k2, k1) + local_keys.shape[1:])
        state = state._replace(rng=rng_out)

        def round_body(s, xs):
            if masks is None:
                deepest, batch_r, keys_r = xs
                mask_r = None
            else:
                deepest, batch_r, keys_r, mask_r = xs

            def step_body(ss, bk):
                b, rngs = bk
                ss, losses, gsq = local_step(ss, b, rngs)
                return ss, (losses, gsq)

            s, (losses, gsqs) = jax.lax.scan(step_body, s, (batch_r, keys_r))
            branches = [(lambda sync: lambda st: sync(st, mask_r))(sync) for sync in level_syncs]
            s = jax.lax.switch(deepest - 1, branches, s)
            return s, {"loss": losses, "gsq": gsqs, "step": s.step}

        xs = (deepest_per_round, batches, local_keys)
        if masks is not None:
            xs = xs + (masks,)
        return jax.lax.scan(round_body, state, xs)

    # batch leaves are (κ₂, κ₁, N, b, ...) — or (κ₂, κ₁, accum, N, b, ...)
    # when microbatch accumulation shifts the client dim right by one
    client_dim = 2 + (1 if grad_accum > 1 else 0)
    batch_spec = P(*([None] * client_dim + [axis]))

    def super_round(state: FedState, batches: PyTree, masks: Optional[jnp.ndarray] = None):
        state_specs = fed_state_partition_specs(state, axis, n_padded)
        batch_specs = jax.tree_util.tree_map(lambda _: batch_spec, batches)
        metric_specs = {"loss": P(None, None, axis), "gsq": P(None, None, axis), "step": P()}
        if masks is None:
            fn = shard_map(
                lambda s, b: body(s, b, None), mesh=mesh,
                in_specs=(state_specs, batch_specs),
                out_specs=(state_specs, metric_specs), check_rep=False,
            )
            return fn(state, batches)
        fn = shard_map(
            body, mesh=mesh,
            in_specs=(state_specs, batch_specs, P(None, axis)),
            out_specs=(state_specs, metric_specs), check_rep=False,
        )
        return fn(state, batches, masks)

    return super_round


# ---------------------------------------------------------------------------
# Sharded cohort lowering (population scale x device scale)
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class _CohortSharding(ClientSharding):
    """``ClientSharding`` over a sampled cohort's *slot* axis.

    Placement-stable packing makes the slot layout — and so every local
    segment table — static (a pure function of topology, cohort size, and
    mesh), but the aggregation weights are the sampled cohort's weight
    columns, traced per interval. ``weights_table`` is repurposed as a
    one-slot mutable cell the ``shard_map`` body fills with this shard's
    traced (capacity,) weight slice before any level sync traces.
    """

    def bind_local_weights(self, w_local) -> None:
        self.weights_table[0] = w_local

    def local_weights(self):
        w = self.weights_table[0]
        if w is None:
            raise RuntimeError(
                "cohort shard weights are bound inside the shard_map body; "
                "call bind_local_weights first"
            )
        return w


def _cohort_quotas(spec: HierarchySpec, cohort_size: int) -> np.ndarray:
    """Per-level-1-node stratified slot quotas — the pure function of
    (topology, cohort_size) that placement-stable packing rests on."""
    if spec.depth == 1:
        return np.asarray([int(cohort_size)], np.int64)
    from repro.fed.participation import stratified_quotas

    return stratified_quotas(spec.group_sizes(1), int(cohort_size))


def sharded_cohort_incompatibility(
    config: HierFAVGConfig,
    topology: Topology,
    cohort_size: int,
    num_shards: int,
    placement: Optional[ShardPlacement] = None,
) -> Optional[str]:
    """Why this schedule cannot run cohort-sampled AND client-sharded over
    ``num_shards`` devices — None when it can.

    Mirrors ``sharding_incompatibility``/``cohort_incompatibility``: the
    single predicate both ``build_sharded_cohort_super_round`` (raises) and
    the runner's eligibility dispatch (reports) consult. Pass ``placement``
    to validate the cohort slot placement that will actually run.
    """
    spec = as_hierarchy(topology)
    reason = cohort_incompatibility(config, spec, cohort_size)
    if reason is not None:
        return reason
    part = config.participation
    if part is not None and getattr(part, "sampler", None) != "stratified" and spec.depth >= 2:
        return (
            f"sharded cohorts need the stratified sampler (placement-stable "
            f"per-edge quotas fix the slot->shard layout); got "
            f"{getattr(part, 'sampler', None)!r}"
        )
    if config.delta_cloud and config.sync_opt_state:
        return "delta_cloud + sync_opt_state do not compose (the opt tree has no anchor)"
    try:
        quotas = _cohort_quotas(spec, cohort_size)
    except ValueError as e:
        return str(e)
    if placement is None:
        try:
            plan_cohort_placement(spec, quotas, num_shards)
        except ValueError as e:
            return str(e)
    else:
        from repro.core.hierarchy import cohort_hierarchy

        slot_spec = cohort_hierarchy(spec, quotas)
        if placement.num_shards != num_shards or placement.spec != slot_spec:
            return (
                f"placement was planned for {placement.num_shards} shard(s) over "
                f"{placement.spec.describe()}, not {num_shards} shard(s) over "
                f"the {slot_spec.describe()} cohort slot tree"
            )
    return None


def build_sharded_cohort_super_round(
    loss_fn: LossFn,
    optimizer: GradientTransformation,
    topology: Topology,
    config: HierFAVGConfig,
    *,
    cohort_size: int,
    mesh,
    axis: str = "clients",
    placement: Optional[ShardPlacement] = None,
    grad_accum: int = 1,
):
    """``build_cohort_super_round`` with the cohort's slot axis sharded over
    ``mesh``'s ``axis`` — population scale and device scale multiply.

    **Placement-stable packing.** Under stratified sampling the per-edge
    cohort quotas are a pure function of (topology, cohort_size)
    (``fed.participation.stratified_quotas``), so the cohort's *slot* tree
    (``core.hierarchy.cohort_hierarchy``) — slot j always reports to the
    same edge — and the edge-aligned shard placement planned from it
    (``plan_cohort_placement``) are computed once and reused for every
    sampled cohort. Per-interval sampling only changes which client fills
    each fixed per-edge slot: segment tables stay static (keeping the
    uniform reshape fast paths), and only the (padded_C,) weight vector is
    traced. Phantom slots (LPT packing pad) carry zero weight.

        super_round(state, batches, weights, masks=None) -> (state, metrics)

    Inputs are in *slot placement order*, permuted by
    ``placement.gather_index()`` and padded to ``placement.padded_clients``
    (``fed.engine.CohortEngine`` owns the conversion): state stacks
    padded_C rows, batch leaves carry (κ₂, κ₁, padded_C, b, ...),
    ``weights`` is the (padded_C,) sampled weight vector (phantoms zero),
    ``masks`` an optional (κ₂, padded_C) survival stack. Sub-top syncs are
    device-local segment means adding members in the single-device cohort
    order (bit-exact); each cloud boundary issues exactly one grouped psum
    (documented rtol=3e-6 reassociation tolerance). Per-slot RNG streams
    reproduce the single-device cohort engine's position-keyed streams
    exactly (hoisted split table gathered at slot ids — at C == N these are
    the original client ids, matching ``build_sharded_super_round``).
    Metrics stay per-client: ``{"loss": (κ₂, κ₁, padded_C), "gsq": (κ₂,
    κ₁, padded_C), "step": (κ₂,)}``, reduced host-side at flush.
    """
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P

    spec = as_hierarchy(topology)
    depth = _check_levels(spec, config)
    num_shards = int(mesh.shape[axis])
    reason = sharded_cohort_incompatibility(
        config, spec, cohort_size, num_shards, placement=placement
    )
    if reason is not None:
        raise ValueError(f"schedule cannot run sharded-cohort: {reason}")
    if placement is None:
        placement = plan_cohort_placement(spec, _cohort_quotas(spec, cohort_size), num_shards)
    shard = _CohortSharding(axis=axis, placement=placement, weights_table=[None])
    microbatch_grads = _build_microbatch_grads(_apply_precision(loss_fn, config.precision), grad_accum)
    level_syncs = []
    for lvl in range(1, depth + 1):
        codec = None
        if config.transport_active:
            codec = config.transport.codec(lvl)
            if codec.is_identity:
                codec = None
        # robust is always None here: cohort_incompatibility rejects
        # non-default aggregators before this point
        level_syncs.append(
            _build_sharded_level_sync(placement.spec, config, lvl, codec, None, shard)
        )
    deepest_per_round = jnp.asarray(super_round_schedule(config), jnp.int32)
    slots_table = shard.client_ids_table()  # (num_shards, capacity) slot ids
    c = int(cohort_size)
    c_padded = placement.padded_clients

    def local_step(s: FedState, batch: PyTree, rngs):
        grads, losses = microbatch_grads(s.params, batch, rngs)
        updates, opt_state = optimizer.update(grads, s.opt_state, s.params)
        params = apply_updates(s.params, updates)
        gsq = sum(
            jnp.sum(jnp.square(g.astype(jnp.float32)), axis=tuple(range(1, g.ndim)))
            for g in jax.tree_util.tree_leaves(grads)
        )
        return (
            FedState(
                step=s.step + 1, params=params, opt_state=opt_state, rng=s.rng,
                anchor=s.anchor, residual=s.residual,
            ),
            losses.astype(jnp.float32),
            gsq,
        )

    def body(state: FedState, batches: PyTree, weights, masks):
        shard.bind_local_weights(weights)
        slots = _shard_row(slots_table, axis)
        k1 = config.kappa1
        k2 = len(super_round_schedule(config))
        # hoisted per-step key table (see build_sharded_super_round): the
        # single-device cohort chain is (rng, step_rng = split(rng);
        # split(step_rng, C)) keyed by cohort POSITION; reproducing it here
        # via a scan of splits + a gather of this shard's slot ids is
        # bit-exact (phantoms reuse slot 0's key, their weight is zero)
        def split_body(cc, _):
            cc, s = jax.random.split(cc)
            return cc, s

        rng_out, step_keys = jax.lax.scan(split_body, state.rng, None, length=k1 * k2)
        local_keys = jax.vmap(
            lambda k: jnp.take(jax.random.split(k, c), slots, axis=0)
        )(step_keys)
        local_keys = local_keys.reshape((k2, k1) + local_keys.shape[1:])
        state = state._replace(rng=rng_out)

        def round_body(s, xs):
            if masks is None:
                deepest, batch_r, keys_r = xs
                mask_r = None
            else:
                deepest, batch_r, keys_r, mask_r = xs

            def step_body(ss, bk):
                b, rngs = bk
                ss, losses, gsq = local_step(ss, b, rngs)
                return ss, (losses, gsq)

            s, (losses, gsqs) = jax.lax.scan(step_body, s, (batch_r, keys_r))
            branches = [(lambda sync: lambda st: sync(st, mask_r))(sync) for sync in level_syncs]
            s = jax.lax.switch(deepest - 1, branches, s)
            return s, {"loss": losses, "gsq": gsqs, "step": s.step}

        xs = (deepest_per_round, batches, local_keys)
        if masks is not None:
            xs = xs + (masks,)
        return jax.lax.scan(round_body, state, xs)

    client_dim = 2 + (1 if grad_accum > 1 else 0)
    batch_spec = P(*([None] * client_dim + [axis]))

    def super_round(state: FedState, batches: PyTree, weights, masks: Optional[jnp.ndarray] = None):
        state_specs = fed_state_partition_specs(state, axis, c_padded)
        batch_specs = jax.tree_util.tree_map(lambda _: batch_spec, batches)
        metric_specs = {"loss": P(None, None, axis), "gsq": P(None, None, axis), "step": P()}
        if masks is None:
            fn = shard_map(
                lambda s, b, w: body(s, b, w, None), mesh=mesh,
                in_specs=(state_specs, batch_specs, P(axis)),
                out_specs=(state_specs, metric_specs), check_rep=False,
            )
            return fn(state, batches, weights)
        fn = shard_map(
            body, mesh=mesh,
            in_specs=(state_specs, batch_specs, P(axis), P(None, axis)),
            out_specs=(state_specs, metric_specs), check_rep=False,
        )
        return fn(state, batches, weights, masks)

    return super_round
