"""The paper's primary contribution: HierFAVG + its analysis + cost model."""
from repro.core.hierarchy import HierarchySpec, as_hierarchy, parse_fanouts
from repro.core.hierfavg import (
    FedState,
    FedTopology,
    HierFAVGConfig,
    build_cloud_sync,
    build_edge_sync,
    build_hier_round,
    build_hier_round_async,
    build_level_sync,
    build_local_step,
    build_super_round,
    build_train_step,
    init_state,
    replicate_for_clients,
    super_round_schedule,
)
from repro.core import aggregation, convergence, cost_model, divergence, reference

__all__ = [
    "FedState",
    "FedTopology",
    "HierarchySpec",
    "HierFAVGConfig",
    "as_hierarchy",
    "parse_fanouts",
    "build_level_sync",
    "build_cloud_sync",
    "build_edge_sync",
    "build_hier_round",
    "build_hier_round_async",
    "build_local_step",
    "build_super_round",
    "build_train_step",
    "init_state",
    "replicate_for_clients",
    "super_round_schedule",
    "aggregation",
    "convergence",
    "cost_model",
    "divergence",
    "reference",
]
