"""Weighted model-aggregation primitives (the paper's EdgeAggregation /
CloudAggregation, Algorithm 1 lines 25-31) as pytree operators.

Representation
--------------
All federated parameters carry a leading **client axis** of size N, laid
out so that clients of the same aggregation group are contiguous:

    leaf.shape == (N, *param_shape)

Two group encodings are supported:

* **uniform** — ``num_groups`` equal contiguous blocks (the paper's
  num_edges × clients_per_edge tree): ``grouped_weighted_mean`` reduces via
  a (G, C, ...) reshape.
* **ragged**  — an explicit sorted ``segment_ids`` vector mapping each
  client to its group (arbitrary fan-out, any level of a
  ``core.hierarchy.HierarchySpec``): ``segment_weighted_mean`` reduces via
  ``jax.ops.segment_sum`` and gathers the group means back. When the
  segment ids describe equal contiguous blocks it dispatches to the
  uniform reshape path, so the paper topology pays nothing for the
  generality.

Under a mesh sharding of `P(("pod","data"), ...)` these lower to *grouped*
all-reduces over exactly the group's devices (intra-pod ICI) and a global
all-reduce (crossing the pod/DCN axis) respectively — the paper's
two-tier communication pattern, verified in the dry-run HLO.

Fault tolerance: every operator takes an optional survival ``mask`` (N,) and
renormalizes over surviving clients, matching the paper's weighted mean
restricted to the participating set. A group with zero survivors keeps its
members' current parameters (they continue local training and rejoin at the
next aggregation).
"""
from __future__ import annotations

from typing import Any, Optional, Sequence, Union

import jax
import jax.numpy as jnp
import numpy as np

PyTree = Any


def _bcast_weights(w: jnp.ndarray, leaf: jnp.ndarray) -> jnp.ndarray:
    """Reshape (…,) weights to broadcast against leaf (…, *param_dims)."""
    return w.reshape(w.shape + (1,) * (leaf.ndim - w.ndim)).astype(jnp.float32)


def weighted_mean(tree: PyTree, weights: jnp.ndarray, mask: Optional[jnp.ndarray] = None) -> PyTree:
    """Cloud aggregation: weighted mean over the full client axis, broadcast back.

    weights: (N,) client dataset sizes |D_i|. mask: optional (N,) in {0,1}.
    """
    w = weights.astype(jnp.float32)
    if mask is not None:
        w = w * mask.astype(jnp.float32)
    denom = jnp.sum(w)

    def leaf_fn(x):
        wb = _bcast_weights(w, x)
        num = jnp.sum(x.astype(jnp.float32) * wb, axis=0, keepdims=True)
        safe = jnp.where(denom > 0, denom, 1.0)
        mean = num / safe
        mean = jnp.broadcast_to(mean, x.shape)
        # zero survivors anywhere -> keep current params
        return jnp.where(denom > 0, mean, x.astype(jnp.float32)).astype(x.dtype)

    return jax.tree_util.tree_map(leaf_fn, tree)


def cloud_model(tree: PyTree, weights: jnp.ndarray, mask: Optional[jnp.ndarray] = None) -> PyTree:
    """The single cloud model (the eval/serving path): the weighted mean over
    the client axis *without* broadcasting back to (N, ...).

    Numerically equal to ``weighted_mean(tree, weights, mask)[0]`` but never
    materializes the N stacked copies of the mean — leaves come back shaped
    (*param_shape,). Zero survivors keeps client 0's current parameters,
    matching the broadcast operator's keep-and-slice behavior.
    """
    w = weights.astype(jnp.float32)
    if mask is not None:
        w = w * mask.astype(jnp.float32)
    denom = jnp.sum(w)

    def leaf_fn(x):
        wb = _bcast_weights(w, x)
        num = jnp.sum(x.astype(jnp.float32) * wb, axis=0)
        safe = jnp.where(denom > 0, denom, 1.0)
        mean = num / safe
        return jnp.where(denom > 0, mean, x[0].astype(jnp.float32)).astype(x.dtype)

    return jax.tree_util.tree_map(leaf_fn, tree)


def grouped_weighted_mean(
    tree: PyTree,
    weights: jnp.ndarray,
    num_groups: int,
    mask: Optional[jnp.ndarray] = None,
) -> PyTree:
    """Edge aggregation: per-edge weighted mean over contiguous client blocks.

    tree leaves: (N, ...); weights/mask: (N,); N must be divisible by num_groups.
    """
    n = weights.shape[0]
    if n % num_groups:
        raise ValueError(f"N={n} not divisible by num_groups={num_groups}")
    group_size = n // num_groups
    w = weights.astype(jnp.float32)
    if mask is not None:
        w = w * mask.astype(jnp.float32)
    wg = w.reshape(num_groups, group_size)
    denom = jnp.sum(wg, axis=1, keepdims=True)  # (G, 1)
    safe = jnp.where(denom > 0, denom, 1.0)

    def leaf_fn(x):
        xg = x.reshape(num_groups, group_size, *x.shape[1:])
        wb = _bcast_weights(wg, xg)
        num = jnp.sum(xg.astype(jnp.float32) * wb, axis=1, keepdims=True)  # (G,1,...)
        mean = num / _bcast_weights(safe, num)
        mean = jnp.broadcast_to(mean, xg.shape)
        alive = _bcast_weights(denom > 0, xg)
        out = jnp.where(alive, mean, xg.astype(jnp.float32))
        return out.reshape(x.shape).astype(x.dtype)

    return jax.tree_util.tree_map(leaf_fn, tree)


def _static_uniform_groups(segment_ids, num_segments: int) -> Optional[int]:
    """If the segment ids are statically known to form equal contiguous
    blocks, return the block count (the uniform fast path); else None."""
    if isinstance(segment_ids, jax.core.Tracer):
        return None
    ids = np.asarray(segment_ids)
    n = ids.shape[0]
    if num_segments <= 0 or n % num_segments:
        return None
    uniform = np.repeat(np.arange(num_segments, dtype=ids.dtype), n // num_segments)
    return num_segments if np.array_equal(ids, uniform) else None


def segment_weighted_mean(
    tree: PyTree,
    weights: jnp.ndarray,
    segment_ids: Union[jnp.ndarray, np.ndarray, Sequence[int]],
    num_segments: int,
    mask: Optional[jnp.ndarray] = None,
) -> PyTree:
    """Ragged edge/region aggregation: per-segment weighted mean, broadcast
    back to the members.

    tree leaves: (N, ...); weights/mask: (N,); segment_ids: (N,) sorted ints
    in [0, num_segments) (a level of ``HierarchySpec.segments``). Equals
    ``grouped_weighted_mean`` exactly when the segments are equal contiguous
    blocks (and dispatches to it, keeping the reshape fast path).
    """
    uniform = _static_uniform_groups(segment_ids, num_segments)
    if uniform is not None:
        return grouped_weighted_mean(tree, weights, uniform, mask)
    seg = jnp.asarray(segment_ids, jnp.int32)
    w = weights.astype(jnp.float32)
    if mask is not None:
        w = w * mask.astype(jnp.float32)
    denom = jax.ops.segment_sum(w, seg, num_segments)  # (G,)
    safe = jnp.where(denom > 0, denom, 1.0)
    alive = denom > 0

    def leaf_fn(x):
        wb = _bcast_weights(w, x)
        sums = jax.ops.segment_sum(x.astype(jnp.float32) * wb, seg, num_segments)  # (G, ...)
        mean = sums / _bcast_weights(safe, sums)
        back = jnp.take(mean, seg, axis=0)  # (N, ...)
        keep = _bcast_weights(jnp.take(alive, seg), back)
        return jnp.where(keep, back, x.astype(jnp.float32)).astype(x.dtype)

    return jax.tree_util.tree_map(leaf_fn, tree)


def segment_weights(
    weights: jnp.ndarray,
    segment_ids: Union[jnp.ndarray, np.ndarray, Sequence[int]],
    num_segments: int,
    mask: Optional[jnp.ndarray] = None,
) -> jnp.ndarray:
    """|D^g| per segment: sum of member dataset sizes (masked)."""
    w = weights.astype(jnp.float32)
    if mask is not None:
        w = w * mask.astype(jnp.float32)
    return jax.ops.segment_sum(w, jnp.asarray(segment_ids, jnp.int32), num_segments)


def hierarchical_segment_mean(
    tree: PyTree,
    weights: jnp.ndarray,
    spec,  # core.hierarchy.HierarchySpec
    level: Optional[int] = None,
    mask: Optional[jnp.ndarray] = None,
) -> PyTree:
    """Level-``level`` aggregation expressed as the staged bottom-up
    composition (edge means, then region means of edge means, ...).

    Numerically equal to the flat ``segment_weighted_mean`` at that level —
    the |D_i| weights compose (each stage's members already hold their
    group's mean, so the next weighted mean over clients equals the mean
    over groups with weights |D^g|) — but kept staged so GSPMD emits the
    hierarchical reduce(ICI) -> reduce(DCN) schedule. ``level=None`` means
    the top (cloud) level.
    """
    lvl = spec.depth if level is None else level
    out = tree
    for t in range(1, lvl + 1):
        out = segment_weighted_mean(out, weights, spec.segments(t), spec.num_nodes(t), mask)
    return out


def group_weights(weights: jnp.ndarray, num_groups: int, mask: Optional[jnp.ndarray] = None) -> jnp.ndarray:
    """|D^l| per edge: sum of member dataset sizes (masked)."""
    w = weights.astype(jnp.float32)
    if mask is not None:
        w = w * mask.astype(jnp.float32)
    return w.reshape(num_groups, -1).sum(axis=1)


def delta_weighted_mean(
    tree: PyTree,
    anchor: PyTree,
    weights: jnp.ndarray,
    mask: Optional[jnp.ndarray] = None,
) -> PyTree:
    """Cloud aggregation in *delta* form: anchor + mean(tree - anchor).

    Mathematically identical to ``weighted_mean`` when every client survives
    (the anchor is the last broadcast model, common to all clients), but the
    payload (w - anchor) is small-magnitude and compresses well — this is the
    entry point for the compressed cloud hop (beyond-paper optimization).
    """
    deltas = jax.tree_util.tree_map(lambda x, a: x - a.astype(x.dtype), tree, anchor)
    mean_delta = weighted_mean(deltas, weights, mask)
    return jax.tree_util.tree_map(lambda a, d: (a.astype(jnp.float32) + d.astype(jnp.float32)).astype(a.dtype), anchor, mean_delta)


def hierarchical_mean(
    tree: PyTree,
    weights: jnp.ndarray,
    num_groups: int,
    mask: Optional[jnp.ndarray] = None,
) -> PyTree:
    """Cloud aggregation expressed as edge-then-cloud composition.

    Equal to ``weighted_mean`` (weights compose: the cloud's weighted mean of
    edge means with weights |D^l| equals the flat weighted mean with |D_i|) —
    kept as the two-stage form so GSPMD emits the hierarchical
    reduce(ICI) -> reduce(DCN) schedule rather than one flat all-reduce.
    """
    edge = grouped_weighted_mean(tree, weights, num_groups, mask)
    # After the edge stage each member of a group holds the group mean, so a
    # flat weighted mean over clients now equals the mean over edges with
    # weights |D^l|.
    return weighted_mean(edge, weights, mask)
