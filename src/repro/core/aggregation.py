"""Weighted model-aggregation primitives (the paper's EdgeAggregation /
CloudAggregation, Algorithm 1 lines 25-31) as pytree operators.

Representation
--------------
All federated parameters carry a leading **client axis** of size N, laid
out so that clients of the same aggregation group are contiguous:

    leaf.shape == (N, *param_shape)

Two group encodings are supported:

* **uniform** — ``num_groups`` equal contiguous blocks (the paper's
  num_edges × clients_per_edge tree): ``grouped_weighted_mean`` reduces via
  a (G, C, ...) reshape.
* **ragged**  — an explicit sorted ``segment_ids`` vector mapping each
  client to its group (arbitrary fan-out, any level of a
  ``core.hierarchy.HierarchySpec``): ``segment_weighted_mean`` reduces via
  ``jax.ops.segment_sum`` and gathers the group means back. When the
  segment ids describe equal contiguous blocks it dispatches to the
  uniform reshape path, so the paper topology pays nothing for the
  generality.

Under a mesh sharding of `P(("pod","data"), ...)` these lower to *grouped*
all-reduces over exactly the group's devices (intra-pod ICI) and a global
all-reduce (crossing the pod/DCN axis) respectively — the paper's
two-tier communication pattern, verified in the dry-run HLO.

Fault tolerance: every operator takes an optional survival ``mask`` (N,) and
renormalizes over surviving clients, matching the paper's weighted mean
restricted to the participating set. A group with zero survivors keeps its
members' current parameters (they continue local training and rejoin at the
next aggregation).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Optional, Sequence, Tuple, Union

import jax
import jax.numpy as jnp
import numpy as np

PyTree = Any


def _bcast_weights(w: jnp.ndarray, leaf: jnp.ndarray) -> jnp.ndarray:
    """Reshape (…,) weights to broadcast against leaf (…, *param_dims)."""
    return w.reshape(w.shape + (1,) * (leaf.ndim - w.ndim)).astype(jnp.float32)


def weighted_mean(tree: PyTree, weights: jnp.ndarray, mask: Optional[jnp.ndarray] = None) -> PyTree:
    """Cloud aggregation: weighted mean over the full client axis, broadcast back.

    weights: (N,) client dataset sizes |D_i|. mask: optional (N,) in {0,1}.
    """
    w = weights.astype(jnp.float32)
    if mask is not None:
        w = w * mask.astype(jnp.float32)
    denom = jnp.sum(w)

    def leaf_fn(x):
        wb = _bcast_weights(w, x)
        num = jnp.sum(x.astype(jnp.float32) * wb, axis=0, keepdims=True)
        safe = jnp.where(denom > 0, denom, 1.0)
        mean = num / safe
        mean = jnp.broadcast_to(mean, x.shape)
        # zero survivors anywhere -> keep current params
        return jnp.where(denom > 0, mean, x.astype(jnp.float32)).astype(x.dtype)

    return jax.tree_util.tree_map(leaf_fn, tree)


def cloud_model(tree: PyTree, weights: jnp.ndarray, mask: Optional[jnp.ndarray] = None) -> PyTree:
    """The single cloud model (the eval/serving path): the weighted mean over
    the client axis *without* broadcasting back to (N, ...).

    Numerically equal to ``weighted_mean(tree, weights, mask)[0]`` but never
    materializes the N stacked copies of the mean — leaves come back shaped
    (*param_shape,). Zero survivors keeps client 0's current parameters,
    matching the broadcast operator's keep-and-slice behavior.
    """
    w = weights.astype(jnp.float32)
    if mask is not None:
        w = w * mask.astype(jnp.float32)
    denom = jnp.sum(w)

    def leaf_fn(x):
        wb = _bcast_weights(w, x)
        num = jnp.sum(x.astype(jnp.float32) * wb, axis=0)
        safe = jnp.where(denom > 0, denom, 1.0)
        mean = num / safe
        return jnp.where(denom > 0, mean, x[0].astype(jnp.float32)).astype(x.dtype)

    return jax.tree_util.tree_map(leaf_fn, tree)


def grouped_weighted_mean(
    tree: PyTree,
    weights: jnp.ndarray,
    num_groups: int,
    mask: Optional[jnp.ndarray] = None,
) -> PyTree:
    """Edge aggregation: per-edge weighted mean over contiguous client blocks.

    tree leaves: (N, ...); weights/mask: (N,); N must be divisible by num_groups.
    """
    n = weights.shape[0]
    if n % num_groups:
        raise ValueError(f"N={n} not divisible by num_groups={num_groups}")
    group_size = n // num_groups
    w = weights.astype(jnp.float32)
    if mask is not None:
        w = w * mask.astype(jnp.float32)
    wg = w.reshape(num_groups, group_size)
    denom = jnp.sum(wg, axis=1, keepdims=True)  # (G, 1)
    safe = jnp.where(denom > 0, denom, 1.0)

    def leaf_fn(x):
        xg = x.reshape(num_groups, group_size, *x.shape[1:])
        wb = _bcast_weights(wg, xg)
        num = jnp.sum(xg.astype(jnp.float32) * wb, axis=1, keepdims=True)  # (G,1,...)
        mean = num / _bcast_weights(safe, num)
        mean = jnp.broadcast_to(mean, xg.shape)
        alive = _bcast_weights(denom > 0, xg)
        out = jnp.where(alive, mean, xg.astype(jnp.float32))
        return out.reshape(x.shape).astype(x.dtype)

    return jax.tree_util.tree_map(leaf_fn, tree)


def _static_uniform_groups(segment_ids, num_segments: int) -> Optional[int]:
    """If the segment ids are statically known to form equal contiguous
    blocks, return the block count (the uniform fast path); else None."""
    if isinstance(segment_ids, jax.core.Tracer):
        return None
    ids = np.asarray(segment_ids)
    n = ids.shape[0]
    if num_segments <= 0 or n % num_segments:
        return None
    uniform = np.repeat(np.arange(num_segments, dtype=ids.dtype), n // num_segments)
    return num_segments if np.array_equal(ids, uniform) else None


def segment_weighted_mean(
    tree: PyTree,
    weights: jnp.ndarray,
    segment_ids: Union[jnp.ndarray, np.ndarray, Sequence[int]],
    num_segments: int,
    mask: Optional[jnp.ndarray] = None,
) -> PyTree:
    """Ragged edge/region aggregation: per-segment weighted mean, broadcast
    back to the members.

    tree leaves: (N, ...); weights/mask: (N,); segment_ids: (N,) sorted ints
    in [0, num_segments) (a level of ``HierarchySpec.segments``). Equals
    ``grouped_weighted_mean`` exactly when the segments are equal contiguous
    blocks (and dispatches to it, keeping the reshape fast path).
    """
    uniform = _static_uniform_groups(segment_ids, num_segments)
    if uniform is not None:
        return grouped_weighted_mean(tree, weights, uniform, mask)
    seg = jnp.asarray(segment_ids, jnp.int32)
    w = weights.astype(jnp.float32)
    if mask is not None:
        w = w * mask.astype(jnp.float32)
    denom = jax.ops.segment_sum(w, seg, num_segments)  # (G,)
    safe = jnp.where(denom > 0, denom, 1.0)
    alive = denom > 0

    def leaf_fn(x):
        wb = _bcast_weights(w, x)
        sums = jax.ops.segment_sum(x.astype(jnp.float32) * wb, seg, num_segments)  # (G, ...)
        mean = sums / _bcast_weights(safe, sums)
        back = jnp.take(mean, seg, axis=0)  # (N, ...)
        keep = _bcast_weights(jnp.take(alive, seg), back)
        return jnp.where(keep, back, x.astype(jnp.float32)).astype(x.dtype)

    return jax.tree_util.tree_map(leaf_fn, tree)


def segment_weights(
    weights: jnp.ndarray,
    segment_ids: Union[jnp.ndarray, np.ndarray, Sequence[int]],
    num_segments: int,
    mask: Optional[jnp.ndarray] = None,
) -> jnp.ndarray:
    """|D^g| per segment: sum of member dataset sizes (masked)."""
    w = weights.astype(jnp.float32)
    if mask is not None:
        w = w * mask.astype(jnp.float32)
    return jax.ops.segment_sum(w, jnp.asarray(segment_ids, jnp.int32), num_segments)


def hierarchical_segment_mean(
    tree: PyTree,
    weights: jnp.ndarray,
    spec,  # core.hierarchy.HierarchySpec
    level: Optional[int] = None,
    mask: Optional[jnp.ndarray] = None,
) -> PyTree:
    """Level-``level`` aggregation expressed as the staged bottom-up
    composition (edge means, then region means of edge means, ...).

    Numerically equal to the flat ``segment_weighted_mean`` at that level —
    the |D_i| weights compose (each stage's members already hold their
    group's mean, so the next weighted mean over clients equals the mean
    over groups with weights |D^g|) — but kept staged so GSPMD emits the
    hierarchical reduce(ICI) -> reduce(DCN) schedule. ``level=None`` means
    the top (cloud) level.
    """
    lvl = spec.depth if level is None else level
    out = tree
    for t in range(1, lvl + 1):
        out = segment_weighted_mean(out, weights, spec.segments(t), spec.num_nodes(t), mask)
    return out


def group_weights(weights: jnp.ndarray, num_groups: int, mask: Optional[jnp.ndarray] = None) -> jnp.ndarray:
    """|D^l| per edge: sum of member dataset sizes (masked)."""
    w = weights.astype(jnp.float32)
    if mask is not None:
        w = w * mask.astype(jnp.float32)
    return w.reshape(num_groups, -1).sum(axis=1)


def delta_weighted_mean(
    tree: PyTree,
    anchor: PyTree,
    weights: jnp.ndarray,
    mask: Optional[jnp.ndarray] = None,
) -> PyTree:
    """Cloud aggregation in *delta* form: anchor + mean(tree - anchor).

    Mathematically identical to ``weighted_mean`` when every client survives
    (the anchor is the last broadcast model, common to all clients), but the
    payload (w - anchor) is small-magnitude and compresses well — this is the
    entry point for the compressed cloud hop (beyond-paper optimization).
    """
    deltas = jax.tree_util.tree_map(lambda x, a: x - a.astype(x.dtype), tree, anchor)
    mean_delta = weighted_mean(deltas, weights, mask)
    return jax.tree_util.tree_map(lambda a, d: (a.astype(jnp.float32) + d.astype(jnp.float32)).astype(a.dtype), anchor, mean_delta)


def psum_weighted_mean(
    tree: PyTree,
    weights: jnp.ndarray,
    axis_name: str,
    mask: Optional[jnp.ndarray] = None,
    *,
    anchor: Optional[PyTree] = None,
) -> Tuple[PyTree, jnp.ndarray]:
    """Global weighted mean over a client axis sharded along ``axis_name``
    with exactly ONE cross-device collective.

    Must be called inside ``shard_map``: every leaf is a shard-local
    (C, ...) slice and ``weights``/``mask`` are the matching (C,) slices.
    Per-leaf partial weighted sums and the masked weight total are raveled
    into a single vector and reduced with one grouped ``lax.psum``; the
    unpacked means broadcast back to every local client. With ``anchor``
    the mean is taken in delta form, anchor + mean(tree − anchor) — the
    ``delta_weighted_mean`` identity. Zero global survivors keeps current
    values, matching ``weighted_mean``.

    Returns ``(tree', alive)`` where ``alive`` is the scalar global
    denominator > 0 predicate — callers reuse it for transport keep-dead
    logic without issuing a second collective.
    """
    w = weights.astype(jnp.float32)
    if mask is not None:
        w = w * mask.astype(jnp.float32)
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    anchors = treedef.flatten_up_to(anchor) if anchor is not None else None
    payload = []
    for i, x in enumerate(leaves):
        xf = x.astype(jnp.float32)
        if anchors is not None:
            xf = xf - anchors[i].astype(jnp.float32)
        payload.append(xf)
    partials = [jnp.sum(p * _bcast_weights(w, p), axis=0).ravel() for p in payload]
    packed = jnp.concatenate(partials + [jnp.sum(w).reshape(1)])
    total = jax.lax.psum(packed, axis_name)
    denom = total[-1]
    alive = denom > 0
    safe = jnp.where(alive, denom, 1.0)
    out = []
    offset = 0
    for i, x in enumerate(leaves):
        param_shape = x.shape[1:]
        size = int(np.prod(param_shape, dtype=np.int64)) if param_shape else 1
        mean = (total[offset : offset + size] / safe).reshape(param_shape)
        offset += size
        full = jnp.broadcast_to(mean, x.shape)
        if anchors is not None:
            full = anchors[i].astype(jnp.float32) + full
        out.append(jnp.where(alive, full, x.astype(jnp.float32)).astype(x.dtype))
    return treedef.unflatten(out), alive


def hierarchical_mean(
    tree: PyTree,
    weights: jnp.ndarray,
    num_groups: int,
    mask: Optional[jnp.ndarray] = None,
) -> PyTree:
    """Cloud aggregation expressed as edge-then-cloud composition.

    Equal to ``weighted_mean`` (weights compose: the cloud's weighted mean of
    edge means with weights |D^l| equals the flat weighted mean with |D_i|) —
    kept as the two-stage form so GSPMD emits the hierarchical
    reduce(ICI) -> reduce(DCN) schedule rather than one flat all-reduce.
    """
    edge = grouped_weighted_mean(tree, weights, num_groups, mask)
    # After the edge stage each member of a group holds the group mean, so a
    # flat weighted mean over clients now equals the mean over edges with
    # weights |D^l|.
    return weighted_mean(edge, weights, mask)


# ---------------------------------------------------------------------------
# Robust per-segment aggregators (the per-level AggregatorSpec axis)
# ---------------------------------------------------------------------------
#
# The paper's protocol aggregates with the |D_i|-weighted mean everywhere.
# Byzantine/outlier-robust FL replaces that statistic per level with a
# coordinate-wise trimmed mean or median (Yin et al., ICML'18) — both are
# *unweighted* order statistics over the surviving members of each segment,
# so they use the survival mask but not the dataset-size weights. A group
# with zero survivors keeps its members' current parameters, matching the
# weighted-mean operators above.


def _segment_members(segment_ids, num_segments: int) -> Tuple[np.ndarray, np.ndarray]:
    """Static (G, Cmax) member-index matrix + validity mask for sorted
    segment ids (host-side; ids come from ``HierarchySpec.segments``)."""
    ids = np.asarray(segment_ids, np.int64)
    sizes = np.bincount(ids, minlength=num_segments)
    cmax = int(sizes.max())
    members = np.zeros((num_segments, cmax), np.int32)
    valid = np.zeros((num_segments, cmax), bool)
    for g in range(num_segments):
        ix = np.where(ids == g)[0]
        members[g, : ix.shape[0]] = ix
        valid[g, : ix.shape[0]] = True
    return members, valid


def _sorted_segment_values(x, members, validb, mask):
    """Gather one (N, ...) leaf into (G, Cmax, ...) f32, masked entries at
    +inf, sorted ascending along the member axis. Returns (sorted, m_g)
    where m_g (G,) counts surviving members per segment."""
    vals = x.astype(jnp.float32)[members]  # (G, Cmax, ...)
    alive = jnp.asarray(validb)
    if mask is not None:
        alive = alive & (mask.astype(jnp.float32)[members] > 0)
    m_g = jnp.sum(alive, axis=1).astype(jnp.int32)  # (G,)
    alive_b = alive.reshape(alive.shape + (1,) * (vals.ndim - 2))
    vals = jnp.where(alive_b, vals, jnp.inf)
    return jnp.sort(vals, axis=1), m_g


def _broadcast_back(per_segment: jnp.ndarray, x: jnp.ndarray, seg, m_g) -> jnp.ndarray:
    """(G, ...) statistic -> (N, ...), zero-survivor groups keep current x."""
    back = jnp.take(per_segment, seg, axis=0)  # (N, ...)
    alive = jnp.take(m_g > 0, seg)
    keep = alive.reshape(alive.shape + (1,) * (back.ndim - 1))
    return jnp.where(keep, back, x.astype(jnp.float32)).astype(x.dtype)


def segment_trimmed_mean(
    tree: PyTree,
    segment_ids: Union[jnp.ndarray, np.ndarray, Sequence[int]],
    num_segments: int,
    mask: Optional[jnp.ndarray] = None,
    *,
    trim: float = 0.1,
) -> PyTree:
    """Coordinate-wise ``trim``-trimmed mean per segment, broadcast back.

    Per segment with m surviving members, each coordinate discards its
    ``floor(trim * m)`` smallest and largest member values and averages the
    rest (unweighted; m small enough that no trimming occurs degrades to the
    plain member mean). ``trim`` must be in [0, 0.5).
    """
    if not 0.0 <= trim < 0.5:
        raise ValueError(f"trim must be in [0, 0.5), got {trim}")
    members, validb = _segment_members(segment_ids, num_segments)
    seg = jnp.asarray(segment_ids, jnp.int32)

    def leaf_fn(x):
        svals, m_g = _sorted_segment_values(x, members, validb, mask)
        k_g = jnp.floor(trim * m_g.astype(jnp.float32)).astype(jnp.int32)  # (G,)
        ranks = jnp.arange(svals.shape[1], dtype=jnp.int32)  # (Cmax,)
        keep = (ranks[None, :] >= k_g[:, None]) & (ranks[None, :] < (m_g - k_g)[:, None])
        count = jnp.maximum(m_g - 2 * k_g, 1).astype(jnp.float32)  # (G,)
        keep_b = keep.reshape(keep.shape + (1,) * (svals.ndim - 2))
        sums = jnp.sum(jnp.where(keep_b, svals, 0.0), axis=1)  # (G, ...)
        mean = sums / count.reshape((-1,) + (1,) * (sums.ndim - 1))
        return _broadcast_back(mean, x, seg, m_g)

    return jax.tree_util.tree_map(leaf_fn, tree)


def segment_coordinate_median(
    tree: PyTree,
    segment_ids: Union[jnp.ndarray, np.ndarray, Sequence[int]],
    num_segments: int,
    mask: Optional[jnp.ndarray] = None,
) -> PyTree:
    """Coordinate-wise median per segment over surviving members, broadcast
    back (the midpoint of the two central order statistics for even m)."""
    members, validb = _segment_members(segment_ids, num_segments)
    seg = jnp.asarray(segment_ids, jnp.int32)

    def leaf_fn(x):
        svals, m_g = _sorted_segment_values(x, members, validb, mask)
        # central order statistics: odd m -> both (m-1)//2; even m -> m//2-1, m//2
        lo = jnp.maximum((m_g - 1) // 2, 0)  # (G,)
        hi = m_g // 2
        idx_shape = (-1, 1) + (1,) * (svals.ndim - 2)
        take = lambda i: jnp.take_along_axis(svals, i.reshape(idx_shape), axis=1)[:, 0]
        med = 0.5 * (take(lo) + take(hi))
        return _broadcast_back(med, x, seg, m_g)

    return jax.tree_util.tree_map(leaf_fn, tree)


# -- aggregator registry ------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class WeightedMeanAggregator:
    """The paper's |D_i|-weighted mean (staged ``hierarchical_segment_mean``).

    The default at every level; ``build_level_sync`` recognizes it and takes
    the exact pre-AggregatorSpec code path, so an all-default spec is
    bitwise-unchanged numerics.
    """

    @property
    def name(self) -> str:
        return "weighted_mean"

    @property
    def is_default(self) -> bool:
        return True

    def __call__(self, tree, weights, spec, level, mask=None):
        return hierarchical_segment_mean(tree, weights, spec, level, mask)


@dataclasses.dataclass(frozen=True)
class TrimmedMeanAggregator:
    """Coordinate-wise trimmed mean over each level-ℓ segment's survivors."""

    trim: float = 0.1

    def __post_init__(self):
        if not 0.0 <= self.trim < 0.5:
            raise ValueError(f"trim must be in [0, 0.5), got {self.trim}")

    @property
    def name(self) -> str:
        return f"trimmed_mean:{self.trim:g}"

    @property
    def is_default(self) -> bool:
        return False

    def __call__(self, tree, weights, spec, level, mask=None):
        return self.segment_call(tree, spec.segments(level), spec.num_nodes(level), mask)

    def segment_call(self, tree, segment_ids, num_segments, mask=None):
        """The statistic over explicit segment ids — the shard-local entry
        point for the mesh-sharded superround (ids must be concrete)."""
        return segment_trimmed_mean(tree, segment_ids, num_segments, mask, trim=self.trim)


@dataclasses.dataclass(frozen=True)
class CoordinateMedianAggregator:
    """Coordinate-wise median over each level-ℓ segment's survivors."""

    @property
    def name(self) -> str:
        return "coordinate_median"

    @property
    def is_default(self) -> bool:
        return False

    def __call__(self, tree, weights, spec, level, mask=None):
        return self.segment_call(tree, spec.segments(level), spec.num_nodes(level), mask)

    def segment_call(self, tree, segment_ids, num_segments, mask=None):
        """The statistic over explicit segment ids — the shard-local entry
        point for the mesh-sharded superround (ids must be concrete)."""
        return segment_coordinate_median(tree, segment_ids, num_segments, mask)


_AGGREGATOR_FACTORIES = {
    "weighted_mean": lambda arg: WeightedMeanAggregator(),
    "mean": lambda arg: WeightedMeanAggregator(),
    "trimmed_mean": lambda arg: TrimmedMeanAggregator(trim=float(arg) if arg else 0.1),
    "coordinate_median": lambda arg: CoordinateMedianAggregator(),
    "median": lambda arg: CoordinateMedianAggregator(),
}


def parse_aggregator(text: str):
    """'weighted_mean' | 'trimmed_mean[:trim]' | 'coordinate_median', e.g.
    'trimmed_mean:0.2'."""
    name, _, arg = text.strip().partition(":")
    if name not in _AGGREGATOR_FACTORIES:
        raise ValueError(
            f"unknown aggregator {name!r}; choose from {sorted(_AGGREGATOR_FACTORIES)}"
        )
    return _AGGREGATOR_FACTORIES[name](arg)


@dataclasses.dataclass(frozen=True)
class AggregatorSpec:
    """One aggregator per tree level, bottom-up — the robustness twin of
    ``fed.transport.TransportSpec``: ``aggregators[0]`` applies at the
    client→edge sync (level 1), ``aggregators[-1]`` at the cloud sync,
    aligned with ``HierFAVGConfig.kappa_vector``."""

    aggregators: Tuple[Any, ...]

    def __post_init__(self):
        object.__setattr__(self, "aggregators", tuple(self.aggregators))
        if not self.aggregators:
            raise ValueError("AggregatorSpec needs at least one level")

    # -- constructors -------------------------------------------------------

    @classmethod
    def default(cls, depth: int) -> "AggregatorSpec":
        return cls(aggregators=tuple(WeightedMeanAggregator() for _ in range(depth)))

    @classmethod
    def uniform(cls, aggregator, depth: int) -> "AggregatorSpec":
        return cls(aggregators=tuple(aggregator for _ in range(depth)))

    @classmethod
    def parse(cls, text: str) -> "AggregatorSpec":
        """'/'-separated aggregator per level, bottom-up:
        'trimmed_mean:0.1/weighted_mean' trims at the edge sync and keeps
        the paper's weighted mean at the cloud."""
        parts = [p for p in text.split("/") if p]
        if not parts:
            raise ValueError(f"empty aggregator spec: {text!r}")
        return cls(aggregators=tuple(parse_aggregator(p) for p in parts))

    # -- queries ------------------------------------------------------------

    @property
    def depth(self) -> int:
        return len(self.aggregators)

    def aggregator(self, level: int):
        if not 1 <= level <= self.depth:
            raise ValueError(f"level must be in 1..{self.depth}, got {level}")
        return self.aggregators[level - 1]

    @property
    def is_trivial(self) -> bool:
        """True iff every level is the default weighted mean — numerics are
        then exactly the pre-AggregatorSpec protocol."""
        return all(a.is_default for a in self.aggregators)

    def describe(self) -> str:
        return "/".join(a.name for a in self.aggregators)
