"""Literal numpy implementation of Algorithm 1 (HierFAVG) — the test oracle.

This mirrors the paper's pseudocode line by line: explicit python loops over
clients and edges, per-client weight vectors, aggregation exactly at
k | kappa1 == 0 and k | kappa1*kappa2 == 0. It is deliberately slow and
simple; tests compare the production JAX implementation against it.
"""
from __future__ import annotations

from typing import Callable, List, Sequence

import numpy as np


def hierfavg_reference(
    w0: np.ndarray,
    grad_fns: Sequence[Callable[[np.ndarray], np.ndarray]],
    data_sizes: Sequence[float],
    num_edges: int,
    kappa1: int,
    kappa2: int,
    num_steps: int,
    lr: Callable[[int], float] | float,
) -> List[np.ndarray]:
    """Run HierFAVG on a quadratic/arbitrary problem with full-batch gradients.

    grad_fns[i](w) -> gradient of client i's local loss F_i at w.
    Returns the per-client weight list after num_steps local updates.
    """
    n = len(grad_fns)
    if n % num_edges:
        raise ValueError("clients must divide evenly across edges")
    c = n // num_edges
    sizes = np.asarray(data_sizes, dtype=np.float64)
    w = [np.array(w0, dtype=np.float64) for _ in range(n)]

    def lr_at(k):
        return lr(k) if callable(lr) else lr

    for k in range(1, num_steps + 1):
        # line 4-5: parallel local gradient steps
        eta = lr_at(k - 1)
        for i in range(n):
            w[i] = w[i] - eta * grad_fns[i](w[i])
        if k % kappa1 == 0:
            # lines 7-9: edge aggregation
            edge_models = []
            for l in range(num_edges):
                idx = range(l * c, (l + 1) * c)
                tot = sizes[list(idx)].sum()
                agg = sum(sizes[i] * w[i] for i in idx) / tot
                edge_models.append(agg)
            if k % (kappa1 * kappa2) != 0:
                # lines 10-13: redistribute edge model to members
                for l in range(num_edges):
                    for i in range(l * c, (l + 1) * c):
                        w[i] = edge_models[l].copy()
            else:
                # lines 17-21: cloud aggregation of edge models, broadcast all
                edge_sizes = np.array([sizes[l * c : (l + 1) * c].sum() for l in range(num_edges)])
                cloud = sum(edge_sizes[l] * edge_models[l] for l in range(num_edges)) / edge_sizes.sum()
                for i in range(n):
                    w[i] = cloud.copy()
    return w


def fedavg_reference(w0, grad_fns, data_sizes, kappa, num_steps, lr):
    """Two-layer FAVG (Section II-B) == HierFAVG with kappa2 = 1, one edge."""
    return hierfavg_reference(w0, grad_fns, data_sizes, 1, kappa, 1, num_steps, lr)


def centralized_gd_reference(w0, grad_fns, data_sizes, num_steps, lr):
    """Centralized gradient descent on the global loss F(w) (Eq. 1)."""
    sizes = np.asarray(data_sizes, dtype=np.float64)
    tot = sizes.sum()
    w = np.array(w0, dtype=np.float64)
    for k in range(num_steps):
        eta = lr(k) if callable(lr) else lr
        g = sum(s * f(w) for s, f in zip(sizes, grad_fns)) / tot
        w = w - eta * g
    return w
