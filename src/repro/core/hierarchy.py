"""Multi-tier ragged hierarchies: the segment-id tree model.

The paper's client-edge-cloud tree is two aggregation levels with equal
fan-out everywhere. Real edge deployments are *ragged*: edges serve
different client counts, regions aggregate different edge counts, and the
tree can be deeper than two levels. ``HierarchySpec`` generalizes
``FedTopology`` to an arbitrary-depth tree described by **parent vectors**:

    parents[t][i] = index of the tier-(t+1) node that tier-t node i reports to

Tier 0 nodes are clients; the last tier is the single cloud root. The
paper's 50-client / 5-edge topology is::

    HierarchySpec.uniform(num_edges=5, clients_per_edge=10)
    # parents = ([0]*10 + [1]*10 + ... + [4]*10, [0]*5)

and a ragged three-level tree (2 regions of 2 and 1 edges, edges with
3/5/2 clients) is::

    HierarchySpec.from_fanouts([[3, 5, 2], [2, 1], [2]])

Aggregation *level* ℓ ∈ {1..depth} averages clients within their tier-ℓ
ancestor: level 1 is edge aggregation, level ``depth`` is cloud
aggregation. ``segments(level)`` yields the (N,) client→ancestor id vector
that ``core.aggregation.segment_weighted_mean`` and the ragged Pallas
kernel consume directly; ids are guaranteed sorted (children of a parent
are contiguous — the canonical order), so grouped collectives and the
kernel's per-block segment encoding stay contiguous.

Validation happens at construction: parent ids must be non-decreasing
(contiguity), dense in [0, num_parents), and every node must have at
least one child. ``is_uniform(level)`` detects the equal-fan-out special
case so callers can keep the contiguous reshape fast path.
"""
from __future__ import annotations

import dataclasses
from typing import List, Sequence, Tuple, Union

import numpy as np


@dataclasses.dataclass(frozen=True)
class HierarchySpec:
    """An arbitrary-depth ragged aggregation tree over N clients.

    parents: tuple of int tuples, bottom-up. ``parents[t]`` maps tier-t
    nodes to tier-(t+1) nodes; tier 0 = clients, top tier = cloud (1 node).
    """

    parents: Tuple[Tuple[int, ...], ...]

    def __post_init__(self):
        if not self.parents:
            raise ValueError("HierarchySpec needs at least one level")
        norm = tuple(tuple(int(p) for p in lvl) for lvl in self.parents)
        object.__setattr__(self, "parents", norm)
        for t, lvl in enumerate(norm):
            arr = np.asarray(lvl, np.int64)
            if arr.size == 0:
                raise ValueError(f"level {t}: empty parent vector")
            if arr.min() < 0:
                raise ValueError(f"level {t}: negative parent id")
            if np.any(np.diff(arr) < 0):
                raise ValueError(
                    f"level {t}: parent ids must be non-decreasing "
                    "(children of a node must be contiguous)"
                )
            if np.any(np.diff(arr) > 1) or arr[0] != 0:
                raise ValueError(f"level {t}: parent ids must be dense 0..P-1 (empty parent)")
            n_parents = int(arr.max()) + 1
            if t + 1 < len(norm) and n_parents != len(norm[t + 1]):
                raise ValueError(
                    f"level {t}: {n_parents} parents but level {t+1} has "
                    f"{len(norm[t + 1])} nodes"
                )
        if int(max(norm[-1])) != 0:
            raise ValueError("top level must map to a single cloud root")

    # -- constructors -------------------------------------------------------

    @classmethod
    def uniform(cls, num_edges: int, clients_per_edge: int) -> "HierarchySpec":
        """The paper's two-level equal-fan-out topology."""
        return cls.from_fanouts([[clients_per_edge] * num_edges, [num_edges]])

    @classmethod
    def from_fanouts(cls, fanouts: Sequence[Sequence[int]]) -> "HierarchySpec":
        """fanouts[t][p] = number of tier-t children of tier-(t+1) node p.

        ``from_fanouts([[3,5,2],[3]])`` = 3 edges with 3/5/2 clients, one
        cloud of 3 edges. The last entry must describe a single root.
        """
        if not fanouts:
            raise ValueError("need at least one fan-out level")
        if len(fanouts[-1]) != 1:
            raise ValueError("last fan-out level must have exactly one (root) node")
        parents: List[Tuple[int, ...]] = []
        for t, level in enumerate(fanouts):
            if any(int(c) < 1 for c in level):
                raise ValueError(f"level {t}: every node needs >= 1 children")
            vec: List[int] = []
            for p, count in enumerate(level):
                vec.extend([p] * int(count))
            parents.append(tuple(vec))
            if t + 1 < len(fanouts) and len(level) != sum(int(c) for c in fanouts[t + 1]):
                raise ValueError(
                    f"level {t} has {len(level)} nodes but level {t+1} fans out "
                    f"to {sum(int(c) for c in fanouts[t + 1])}"
                )
        return cls(parents=tuple(parents))

    # -- shape queries ------------------------------------------------------

    @property
    def depth(self) -> int:
        """Number of aggregation levels (2 for the paper's client-edge-cloud)."""
        return len(self.parents)

    @property
    def num_clients(self) -> int:
        return len(self.parents[0])

    def num_nodes(self, tier: int) -> int:
        """Node count at tier ∈ {0..depth}; tier 0 = clients, depth = root."""
        if tier == 0:
            return self.num_clients
        return int(max(self.parents[tier - 1])) + 1

    def fanouts(self) -> Tuple[Tuple[int, ...], ...]:
        """Inverse of ``from_fanouts``: child counts per node, bottom-up."""
        out = []
        for lvl in self.parents:
            counts = np.bincount(np.asarray(lvl, np.int64))
            out.append(tuple(int(c) for c in counts))
        return tuple(out)

    # -- the aggregation interface ------------------------------------------

    def segments(self, level: int) -> np.ndarray:
        """(N,) int32 vector: each client's tier-``level`` ancestor id.

        This is the segment-id vector segment_weighted_mean / the ragged
        Pallas kernel reduce over. Sorted by construction.
        """
        if not 1 <= level <= self.depth:
            raise ValueError(f"level must be in 1..{self.depth}, got {level}")
        seg = np.asarray(self.parents[0], np.int32)
        for t in range(1, level):
            lift = np.asarray(self.parents[t], np.int32)
            seg = lift[seg]
        return seg

    def group_sizes(self, level: int) -> np.ndarray:
        """Clients per tier-``level`` node."""
        return np.bincount(self.segments(level), minlength=self.num_nodes(level))

    def is_uniform(self, level: int) -> bool:
        """True iff every tier-``level`` node aggregates the same number of
        clients — the contiguous-reshape fast path is then exact."""
        sizes = self.group_sizes(level)
        return bool(np.all(sizes == sizes[0]))

    @property
    def is_paper_topology(self) -> bool:
        """Two levels, equal edges — reduces to the seed's FedTopology."""
        return self.depth == 2 and self.is_uniform(1)

    def replica_groups(self, level: int) -> List[List[int]]:
        """Client-index groups for the level-``level`` grouped collective."""
        seg = self.segments(level)
        return [list(np.where(seg == g)[0]) for g in range(self.num_nodes(level))]

    def describe(self) -> str:
        tiers = [str(self.num_clients)] + [str(self.num_nodes(t)) for t in range(1, self.depth + 1)]
        shape = "ragged" if any(not self.is_uniform(l) for l in range(1, self.depth + 1)) else "uniform"
        return f"{'/'.join(tiers)} ({shape}, depth {self.depth})"

    def fanouts_text(self) -> str:
        """The ``parse_fanouts`` grammar for this tree — the serializable
        form: ``parse_fanouts(spec.fanouts_text()) == spec``."""
        return "/".join(",".join(str(c) for c in lvl) for lvl in self.fanouts())


# ---------------------------------------------------------------------------
# Edge-aligned client -> shard placement (the mesh-sharded superround)
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class ShardPlacement:
    """Edge-aligned client→shard placement for a ``"clients"`` mesh axis.

    Permutes the stacked client axis so that every *alignment group* — the
    clients of one child subtree of the root, i.e. one ``segments(depth-1)``
    group (= one edge for the paper's two-level tree) — lands wholly inside
    one shard. Every aggregation level below the top then reduces entirely
    within a shard (no cross-device collective); only the top (cloud) sync
    crosses shards.

    Non-divisible packings pad: each shard is padded to ``capacity`` clients
    with *phantom* positions (``perm == -1``). Phantoms carry zero
    aggregation weight, reuse client 0's batch rows and RNG stream, and own
    a dedicated trailing local segment per level, so they can never perturb
    a real group's sums — padding is numerically inert (the +0.0 terms they
    contribute to weighted sums leave every bit unchanged; see
    docs/performance.md).

    ``perm[p]`` maps padded position p → original client id (-1 = phantom);
    positions are shard-major: shard s owns ``[s*capacity, (s+1)*capacity)``.
    Within a shard, groups keep ascending group-id order and clients keep
    their original relative order, so shard-local segment reductions add
    members in exactly the single-device order.
    """

    num_shards: int
    capacity: int
    perm: Tuple[int, ...]
    spec: HierarchySpec

    def __post_init__(self):
        object.__setattr__(self, "perm", tuple(int(p) for p in self.perm))
        if len(self.perm) != self.num_shards * self.capacity:
            raise ValueError(
                f"perm has {len(self.perm)} positions, expected "
                f"num_shards*capacity = {self.num_shards * self.capacity}"
            )

    # -- shape queries ------------------------------------------------------

    @property
    def num_clients(self) -> int:
        return self.spec.num_clients

    @property
    def padded_clients(self) -> int:
        return self.num_shards * self.capacity

    @property
    def num_phantoms(self) -> int:
        return sum(1 for p in self.perm if p < 0)

    def valid(self) -> np.ndarray:
        """(padded,) bool: True at real-client positions, False at phantoms."""
        return np.asarray([p >= 0 for p in self.perm], bool)

    # -- layout maps --------------------------------------------------------

    def gather_index(self) -> np.ndarray:
        """(padded,) int32 original→padded gather map. Phantoms read client
        0 — their values are inert (zero weight, dedicated segment)."""
        return np.asarray([max(p, 0) for p in self.perm], np.int32)

    def positions(self) -> np.ndarray:
        """(N,) int32: each original client's position in the padded order
        (the inverse gather for un-sharding)."""
        pos = np.full(self.num_clients, -1, np.int64)
        for where, orig in enumerate(self.perm):
            if orig >= 0:
                pos[orig] = where
        if (pos < 0).any():
            raise ValueError("placement dropped a client (corrupt perm)")
        return pos.astype(np.int32)

    def pad_weights(self, weights) -> np.ndarray:
        """(padded,) f32 permuted aggregation weights, phantoms zeroed."""
        w = np.asarray(weights, np.float32)[self.gather_index()]
        return np.where(self.valid(), w, np.float32(0.0)).astype(np.float32)

    # -- shard-local tree views ---------------------------------------------

    def local_segments(self, level: int) -> np.ndarray:
        """(num_shards, capacity) int32 shard-local segment ids at ``level``
        (1 <= level < depth): global ids relabeled densely per shard in
        order of appearance; phantoms take the dedicated last id."""
        if not 1 <= level <= self.spec.depth - 1:
            raise ValueError(
                f"shard-local segments exist for levels 1..{self.spec.depth - 1} "
                f"(the top level is the cross-shard reduction), got {level}"
            )
        seg = self.spec.segments(level)
        nseg = self.local_num_segments(level)
        out = np.zeros((self.num_shards, self.capacity), np.int32)
        for s in range(self.num_shards):
            row = self.perm[s * self.capacity : (s + 1) * self.capacity]
            local: dict = {}
            for j, orig in enumerate(row):
                if orig < 0:
                    out[s, j] = nseg - 1
                else:
                    out[s, j] = local.setdefault(int(seg[orig]), len(local))
        return out

    def local_num_segments(self, level: int) -> int:
        """Static per-shard segment count at ``level``: the heaviest shard's
        real segment count, plus one trailing phantom segment when padded."""
        seg = self.spec.segments(level)
        most = 0
        for s in range(self.num_shards):
            row = self.perm[s * self.capacity : (s + 1) * self.capacity]
            most = max(most, len({int(seg[p]) for p in row if p >= 0}))
        return most + (1 if self.num_phantoms else 0)

    def describe(self) -> str:
        return (
            f"{self.num_clients} clients -> {self.num_shards} shards x "
            f"{self.capacity} ({self.num_phantoms} phantom pad)"
        )


def plan_shard_placement(spec: HierarchySpec, num_shards: int) -> ShardPlacement:
    """Pack whole root-child subtrees onto shards, balanced by client count.

    Greedy LPT over the ``segments(depth-1)`` alignment groups (largest
    first onto the least-loaded shard, ties by id for determinism);
    ``capacity`` is the heaviest shard's client count and lighter shards pad
    with phantoms. Uniform trees whose group count divides ``num_shards``
    pack exactly (zero padding). Depth-1 trees (classic two-tier FedAvg)
    have no sub-cloud level: clients pack freely as singleton groups.
    """
    if num_shards < 1:
        raise ValueError(f"num_shards must be >= 1, got {num_shards}")
    n = spec.num_clients
    if spec.depth >= 2:
        seg = spec.segments(spec.depth - 1)
    else:
        seg = np.arange(n, dtype=np.int32)
    num_groups = int(seg.max()) + 1
    if num_groups < num_shards:
        raise ValueError(
            f"cannot shard {num_groups} aggregation subtree(s) over {num_shards} "
            f"devices: each shard needs at least one whole level-"
            f"{max(spec.depth - 1, 1)} subtree so sub-cloud syncs stay "
            f"device-local; use a mesh of <= {num_groups} devices or a finer tree"
        )
    members = [np.where(seg == g)[0] for g in range(num_groups)]
    order = sorted(range(num_groups), key=lambda g: (-len(members[g]), g))
    loads = [0] * num_shards
    assigned: List[List[int]] = [[] for _ in range(num_shards)]
    for g in order:
        s = min(range(num_shards), key=lambda k: (loads[k], k))
        assigned[s].append(g)
        loads[s] += len(members[g])
    capacity = max(loads)
    perm: List[int] = []
    for s in range(num_shards):
        row: List[int] = []
        for g in sorted(assigned[s]):
            row.extend(int(c) for c in members[g])
        row.extend([-1] * (capacity - len(row)))
        perm.extend(row)
    return ShardPlacement(num_shards=num_shards, capacity=capacity, perm=tuple(perm), spec=spec)


def cohort_hierarchy(spec: HierarchySpec, quotas) -> HierarchySpec:
    """The *slot* tree of a stratified cohort: ``quotas[e]`` cohort slots
    under level-1 node e, upper tiers unchanged.

    Slots stand in for the sampled clients; because stratified cohorts are
    sorted and edges are contiguous id ranges, slot j of every interval's
    cohort reports to the same edge — the cohort tree (and any placement
    planned from it) is a pure function of (topology, quotas).
    """
    q = np.asarray(quotas, np.int64)
    if spec.depth == 1:
        # depth-1 trees have one "edge" (the root); all slots report to it
        if q.shape != (1,) or int(q.sum()) < 1:
            raise ValueError(f"depth-1 tree needs a single root quota, got {q}")
        return HierarchySpec(parents=(tuple([0] * int(q[0])),))
    num_edges = spec.num_nodes(1)
    if q.shape != (num_edges,):
        raise ValueError(f"quotas must be ({num_edges},) (one per level-1 node), got {q.shape}")
    if np.any(q < 1):
        raise ValueError("every level-1 node needs >= 1 cohort slot (floor-1 quotas)")
    slot_parents = tuple(int(e) for e in np.repeat(np.arange(num_edges), q))
    return HierarchySpec(parents=(slot_parents,) + spec.parents[1:])


def plan_cohort_placement(spec: HierarchySpec, quotas, num_shards: int) -> ShardPlacement:
    """Edge-aligned shard placement for a stratified cohort's *slot* axis.

    ``plan_shard_placement`` over :func:`cohort_hierarchy`: whole root-child
    subtrees of slots pack onto shards, so every sub-top cohort sync stays
    device-local and the placement is reused for every sampled cohort
    (placement-stable packing). The returned placement's ``spec`` is the
    slot tree (``num_clients == sum(quotas)``); at ``cohort == population``
    the quotas equal the edge sizes and this is exactly
    ``plan_shard_placement(spec, num_shards)``.
    """
    return plan_shard_placement(cohort_hierarchy(spec, quotas), num_shards)


def parse_fanouts(text: str) -> HierarchySpec:
    """Parse a CLI fan-out string, bottom-up, levels separated by '/'.

    ``"3,5,2/2,1/2"`` = edges with 3/5/2 clients, regions with 2/1 edges,
    cloud of 2 regions. A trailing root level of 1 may be omitted:
    ``"10,10,10,10,10/5"`` is the paper's 50/5 topology.
    """
    try:
        levels = [[int(x) for x in part.split(",") if x] for part in text.split("/") if part]
    except ValueError as e:
        raise ValueError(
            f"bad fan-out spec {text!r}: expected comma-separated counts with "
            f"'/' between levels, e.g. '3,5,2/2,1/2' ({e})"
        ) from None
    if not levels:
        raise ValueError(f"empty fan-out spec: {text!r}")
    if len(levels[-1]) != 1:
        levels.append([len(levels[-1])])
    return HierarchySpec.from_fanouts(levels)


def as_hierarchy(topology: Union[HierarchySpec, "object"]) -> HierarchySpec:
    """Normalize a FedTopology (two-level uniform) or HierarchySpec."""
    if isinstance(topology, HierarchySpec):
        return topology
    # duck-typed FedTopology (avoids an import cycle with core.hierfavg)
    if hasattr(topology, "num_edges") and hasattr(topology, "clients_per_edge"):
        return HierarchySpec.uniform(topology.num_edges, topology.clients_per_edge)
    raise TypeError(f"cannot interpret {type(topology).__name__} as a hierarchy")
