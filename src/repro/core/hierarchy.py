"""Multi-tier ragged hierarchies: the segment-id tree model.

The paper's client-edge-cloud tree is two aggregation levels with equal
fan-out everywhere. Real edge deployments are *ragged*: edges serve
different client counts, regions aggregate different edge counts, and the
tree can be deeper than two levels. ``HierarchySpec`` generalizes
``FedTopology`` to an arbitrary-depth tree described by **parent vectors**:

    parents[t][i] = index of the tier-(t+1) node that tier-t node i reports to

Tier 0 nodes are clients; the last tier is the single cloud root. The
paper's 50-client / 5-edge topology is::

    HierarchySpec.uniform(num_edges=5, clients_per_edge=10)
    # parents = ([0]*10 + [1]*10 + ... + [4]*10, [0]*5)

and a ragged three-level tree (2 regions of 2 and 1 edges, edges with
3/5/2 clients) is::

    HierarchySpec.from_fanouts([[3, 5, 2], [2, 1], [2]])

Aggregation *level* ℓ ∈ {1..depth} averages clients within their tier-ℓ
ancestor: level 1 is edge aggregation, level ``depth`` is cloud
aggregation. ``segments(level)`` yields the (N,) client→ancestor id vector
that ``core.aggregation.segment_weighted_mean`` and the ragged Pallas
kernel consume directly; ids are guaranteed sorted (children of a parent
are contiguous — the canonical order), so grouped collectives and the
kernel's per-block segment encoding stay contiguous.

Validation happens at construction: parent ids must be non-decreasing
(contiguity), dense in [0, num_parents), and every node must have at
least one child. ``is_uniform(level)`` detects the equal-fan-out special
case so callers can keep the contiguous reshape fast path.
"""
from __future__ import annotations

import dataclasses
from typing import List, Sequence, Tuple, Union

import numpy as np


@dataclasses.dataclass(frozen=True)
class HierarchySpec:
    """An arbitrary-depth ragged aggregation tree over N clients.

    parents: tuple of int tuples, bottom-up. ``parents[t]`` maps tier-t
    nodes to tier-(t+1) nodes; tier 0 = clients, top tier = cloud (1 node).
    """

    parents: Tuple[Tuple[int, ...], ...]

    def __post_init__(self):
        if not self.parents:
            raise ValueError("HierarchySpec needs at least one level")
        norm = tuple(tuple(int(p) for p in lvl) for lvl in self.parents)
        object.__setattr__(self, "parents", norm)
        for t, lvl in enumerate(norm):
            arr = np.asarray(lvl, np.int64)
            if arr.size == 0:
                raise ValueError(f"level {t}: empty parent vector")
            if arr.min() < 0:
                raise ValueError(f"level {t}: negative parent id")
            if np.any(np.diff(arr) < 0):
                raise ValueError(
                    f"level {t}: parent ids must be non-decreasing "
                    "(children of a node must be contiguous)"
                )
            if np.any(np.diff(arr) > 1) or arr[0] != 0:
                raise ValueError(f"level {t}: parent ids must be dense 0..P-1 (empty parent)")
            n_parents = int(arr.max()) + 1
            if t + 1 < len(norm) and n_parents != len(norm[t + 1]):
                raise ValueError(
                    f"level {t}: {n_parents} parents but level {t+1} has "
                    f"{len(norm[t + 1])} nodes"
                )
        if int(max(norm[-1])) != 0:
            raise ValueError("top level must map to a single cloud root")

    # -- constructors -------------------------------------------------------

    @classmethod
    def uniform(cls, num_edges: int, clients_per_edge: int) -> "HierarchySpec":
        """The paper's two-level equal-fan-out topology."""
        return cls.from_fanouts([[clients_per_edge] * num_edges, [num_edges]])

    @classmethod
    def from_fanouts(cls, fanouts: Sequence[Sequence[int]]) -> "HierarchySpec":
        """fanouts[t][p] = number of tier-t children of tier-(t+1) node p.

        ``from_fanouts([[3,5,2],[3]])`` = 3 edges with 3/5/2 clients, one
        cloud of 3 edges. The last entry must describe a single root.
        """
        if not fanouts:
            raise ValueError("need at least one fan-out level")
        if len(fanouts[-1]) != 1:
            raise ValueError("last fan-out level must have exactly one (root) node")
        parents: List[Tuple[int, ...]] = []
        for t, level in enumerate(fanouts):
            if any(int(c) < 1 for c in level):
                raise ValueError(f"level {t}: every node needs >= 1 children")
            vec: List[int] = []
            for p, count in enumerate(level):
                vec.extend([p] * int(count))
            parents.append(tuple(vec))
            if t + 1 < len(fanouts) and len(level) != sum(int(c) for c in fanouts[t + 1]):
                raise ValueError(
                    f"level {t} has {len(level)} nodes but level {t+1} fans out "
                    f"to {sum(int(c) for c in fanouts[t + 1])}"
                )
        return cls(parents=tuple(parents))

    # -- shape queries ------------------------------------------------------

    @property
    def depth(self) -> int:
        """Number of aggregation levels (2 for the paper's client-edge-cloud)."""
        return len(self.parents)

    @property
    def num_clients(self) -> int:
        return len(self.parents[0])

    def num_nodes(self, tier: int) -> int:
        """Node count at tier ∈ {0..depth}; tier 0 = clients, depth = root."""
        if tier == 0:
            return self.num_clients
        return int(max(self.parents[tier - 1])) + 1

    def fanouts(self) -> Tuple[Tuple[int, ...], ...]:
        """Inverse of ``from_fanouts``: child counts per node, bottom-up."""
        out = []
        for lvl in self.parents:
            counts = np.bincount(np.asarray(lvl, np.int64))
            out.append(tuple(int(c) for c in counts))
        return tuple(out)

    # -- the aggregation interface ------------------------------------------

    def segments(self, level: int) -> np.ndarray:
        """(N,) int32 vector: each client's tier-``level`` ancestor id.

        This is the segment-id vector segment_weighted_mean / the ragged
        Pallas kernel reduce over. Sorted by construction.
        """
        if not 1 <= level <= self.depth:
            raise ValueError(f"level must be in 1..{self.depth}, got {level}")
        seg = np.asarray(self.parents[0], np.int32)
        for t in range(1, level):
            lift = np.asarray(self.parents[t], np.int32)
            seg = lift[seg]
        return seg

    def group_sizes(self, level: int) -> np.ndarray:
        """Clients per tier-``level`` node."""
        return np.bincount(self.segments(level), minlength=self.num_nodes(level))

    def is_uniform(self, level: int) -> bool:
        """True iff every tier-``level`` node aggregates the same number of
        clients — the contiguous-reshape fast path is then exact."""
        sizes = self.group_sizes(level)
        return bool(np.all(sizes == sizes[0]))

    @property
    def is_paper_topology(self) -> bool:
        """Two levels, equal edges — reduces to the seed's FedTopology."""
        return self.depth == 2 and self.is_uniform(1)

    def replica_groups(self, level: int) -> List[List[int]]:
        """Client-index groups for the level-``level`` grouped collective."""
        seg = self.segments(level)
        return [list(np.where(seg == g)[0]) for g in range(self.num_nodes(level))]

    def describe(self) -> str:
        tiers = [str(self.num_clients)] + [str(self.num_nodes(t)) for t in range(1, self.depth + 1)]
        shape = "ragged" if any(not self.is_uniform(l) for l in range(1, self.depth + 1)) else "uniform"
        return f"{'/'.join(tiers)} ({shape}, depth {self.depth})"

    def fanouts_text(self) -> str:
        """The ``parse_fanouts`` grammar for this tree — the serializable
        form: ``parse_fanouts(spec.fanouts_text()) == spec``."""
        return "/".join(",".join(str(c) for c in lvl) for lvl in self.fanouts())


def parse_fanouts(text: str) -> HierarchySpec:
    """Parse a CLI fan-out string, bottom-up, levels separated by '/'.

    ``"3,5,2/2,1/2"`` = edges with 3/5/2 clients, regions with 2/1 edges,
    cloud of 2 regions. A trailing root level of 1 may be omitted:
    ``"10,10,10,10,10/5"`` is the paper's 50/5 topology.
    """
    try:
        levels = [[int(x) for x in part.split(",") if x] for part in text.split("/") if part]
    except ValueError as e:
        raise ValueError(
            f"bad fan-out spec {text!r}: expected comma-separated counts with "
            f"'/' between levels, e.g. '3,5,2/2,1/2' ({e})"
        ) from None
    if not levels:
        raise ValueError(f"empty fan-out spec: {text!r}")
    if len(levels[-1]) != 1:
        levels.append([len(levels[-1])])
    return HierarchySpec.from_fanouts(levels)


def as_hierarchy(topology: Union[HierarchySpec, "object"]) -> HierarchySpec:
    """Normalize a FedTopology (two-level uniform) or HierarchySpec."""
    if isinstance(topology, HierarchySpec):
        return topology
    # duck-typed FedTopology (avoids an import cycle with core.hierfavg)
    if hasattr(topology, "num_edges") and hasattr(topology, "clients_per_edge"):
        return HierarchySpec.uniform(topology.num_edges, topology.clients_per_edge)
    raise TypeError(f"cannot interpret {type(topology).__name__} as a hierarchy")
