"""Atomic checkpointing with keep-k retention and full-state restore.

Layout on disk (one directory per step):

    <root>/step_000001200/
        payload.npz       — flattened pytree leaves (np arrays)
        meta.json         — treedef token, leaf dtypes/shapes, user metadata
    <root>/step_000001200.COMMITTED   — marker written LAST (atomicity)

Writes go to a tmp dir + os.replace, and the COMMITTED marker is created
only after a successful rename — a crash mid-write can never produce a
checkpoint that restore will pick up. ``restore_latest`` scans markers in
reverse step order and validates structure against the template pytree
(shape+dtype), skipping corrupt entries.

This is deliberately dependency-free (no orbax offline); the semantics —
atomic commit, keep-k GC, resumable aux state (data cursors, failure-
detector state, round counter) — are the ones that matter at scale.
"""
from __future__ import annotations

import json
import os
import shutil
from typing import Any, Dict, List, Optional, Tuple

import jax
import numpy as np

PyTree = Any


def _flatten_to_arrays(tree: PyTree) -> Tuple[List[np.ndarray], Any]:
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    return [np.asarray(x) for x in leaves], treedef


class CheckpointManager:
    def __init__(self, root: str, *, keep: int = 3):
        self.root = root
        self.keep = keep
        os.makedirs(root, exist_ok=True)

    # ------------------------------------------------------------------
    def _step_dir(self, step: int) -> str:
        return os.path.join(self.root, f"step_{step:012d}")

    def _marker(self, step: int) -> str:
        return self._step_dir(step) + ".COMMITTED"

    def committed_steps(self) -> List[int]:
        out = []
        for name in os.listdir(self.root):
            if name.endswith(".COMMITTED"):
                try:
                    out.append(int(name[len("step_") : -len(".COMMITTED")]))
                except ValueError:
                    continue
        return sorted(out)

    # ------------------------------------------------------------------
    def save(self, step: int, state: PyTree, metadata: Optional[Dict] = None) -> str:
        arrays, treedef = _flatten_to_arrays(state)
        final = self._step_dir(step)
        tmp = final + ".tmp"
        if os.path.exists(tmp):
            shutil.rmtree(tmp)
        os.makedirs(tmp)
        np.savez(os.path.join(tmp, "payload.npz"), **{f"leaf_{i}": a for i, a in enumerate(arrays)})
        meta = {
            "step": step,
            "num_leaves": len(arrays),
            "shapes": [list(a.shape) for a in arrays],
            "dtypes": [str(a.dtype) for a in arrays],
            "user": _jsonable(metadata or {}),
        }
        with open(os.path.join(tmp, "meta.json"), "w") as f:
            json.dump(meta, f)
        if os.path.exists(final):
            shutil.rmtree(final)
        os.replace(tmp, final)
        # commit marker LAST
        with open(self._marker(step), "w") as f:
            f.write("ok")
        self._gc()
        return final

    def _gc(self) -> None:
        steps = self.committed_steps()
        for s in steps[: max(0, len(steps) - self.keep)]:
            try:
                os.remove(self._marker(s))
                shutil.rmtree(self._step_dir(s), ignore_errors=True)
            except OSError:
                pass

    # ------------------------------------------------------------------
    def restore(self, step: int, template: PyTree) -> Tuple[PyTree, Dict]:
        d = self._step_dir(step)
        with open(os.path.join(d, "meta.json")) as f:
            meta = json.load(f)
        payload = np.load(os.path.join(d, "payload.npz"))
        arrays = [payload[f"leaf_{i}"] for i in range(meta["num_leaves"])]
        t_leaves, treedef = jax.tree_util.tree_flatten(template)
        if len(t_leaves) != len(arrays):
            raise ValueError(
                f"checkpoint step {step}: {len(arrays)} leaves, template has {len(t_leaves)}"
            )
        cast = []
        for a, t in zip(arrays, t_leaves):
            if tuple(a.shape) != tuple(np.shape(t)):
                raise ValueError(f"leaf shape mismatch: ckpt {a.shape} vs template {np.shape(t)}")
            cast.append(a.astype(np.asarray(t).dtype) if hasattr(t, "dtype") else a)
        state = jax.tree_util.tree_unflatten(treedef, cast)
        return state, _unjsonable(meta.get("user", {}))

    def restore_latest(self, template: PyTree) -> Optional[Tuple[PyTree, Dict]]:
        for step in reversed(self.committed_steps()):
            try:
                return self.restore(step, template)
            except (ValueError, OSError, KeyError):
                continue  # corrupt / incompatible — try older
        return None


def _jsonable(obj):
    if isinstance(obj, dict):
        return {k: _jsonable(v) for k, v in obj.items()}
    if isinstance(obj, (list, tuple)):
        return [_jsonable(v) for v in obj]
    if isinstance(obj, np.ndarray):
        return {"__ndarray__": obj.tolist(), "dtype": str(obj.dtype)}
    if isinstance(obj, (np.integer,)):
        return int(obj)
    if isinstance(obj, (np.floating,)):
        return float(obj)
    return obj


def _unjsonable(obj):
    """Inverse of :func:`_jsonable` for the ndarray encoding (other values
    round-trip through JSON natively)."""
    if isinstance(obj, dict):
        if "__ndarray__" in obj:
            return np.asarray(obj["__ndarray__"], dtype=obj["dtype"])
        return {k: _unjsonable(v) for k, v in obj.items()}
    if isinstance(obj, list):
        return [_unjsonable(v) for v in obj]
    return obj
