from repro.checkpoint import manager, reshard
from repro.checkpoint.manager import CheckpointManager
from repro.checkpoint.reshard import merge_opt_state, reshard_clients, to_mesh

__all__ = [
    "manager",
    "reshard",
    "CheckpointManager",
    "merge_opt_state",
    "reshard_clients",
    "to_mesh",
]
