"""Elastic re-topology: map a HierFAVG checkpoint onto a different cluster.

Two elastic moves, both defined by the algorithm's own aggregation operator
(so the semantics are principled, not ad hoc):

* ``reshard_clients`` — change (L, C) -> (L', C'). Shrinking merges client
  models by |D_i|-weighted mean (exactly an edge aggregation over the
  merged set); growing replicates the group model to the new members
  (exactly a broadcast). Data sizes re-partition accordingly.
* ``to_mesh`` — re-commit existing arrays to a new mesh/sharding
  (jax.device_put with the target NamedShardings; GSPMD moves the bytes).

Together they cover the elastic-scaling story: lose a pod -> restore the
latest checkpoint with N' < N and keep training; gain capacity -> grow.
"""
from __future__ import annotations

from typing import Any, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

PyTree = Any


def _group_reduce(stack: jnp.ndarray, weights: np.ndarray, groups: int) -> jnp.ndarray:
    """(N, ...) -> (groups, ...) weighted mean over contiguous blocks."""
    n = stack.shape[0]
    size = n // groups
    w = jnp.asarray(weights, jnp.float32).reshape(groups, size)
    xg = stack.reshape(groups, size, *stack.shape[1:]).astype(jnp.float32)
    wb = w.reshape(groups, size, *([1] * (stack.ndim - 1)))
    num = jnp.sum(xg * wb, axis=1)
    den = jnp.sum(wb, axis=1)
    return (num / den).astype(stack.dtype)


def reshard_clients(
    params: PyTree,
    data_sizes: np.ndarray,
    new_num_clients: int,
) -> Tuple[PyTree, np.ndarray]:
    """Map stacked (N, ...) client params onto N' clients.

    N' < N: N must be divisible by N'; contiguous groups of N/N' clients are
    merged by weighted mean (edge-aggregation semantics) and the merged
    client inherits the group's total |D|.
    N' > N: N' must be divisible by N; each client's model is replicated to
    N'/N new clients (broadcast semantics) and its data size is split.
    """
    sizes = np.asarray(data_sizes, np.float64)
    n = sizes.shape[0]
    if new_num_clients == n:
        return params, sizes
    if new_num_clients < n:
        if n % new_num_clients:
            raise ValueError(f"cannot merge {n} clients into {new_num_clients}")
        g = new_num_clients
        merged = jax.tree_util.tree_map(lambda x: _group_reduce(x, sizes, g), params)
        new_sizes = sizes.reshape(g, -1).sum(axis=1)
        return merged, new_sizes
    if new_num_clients % n:
        raise ValueError(f"cannot grow {n} clients into {new_num_clients}")
    rep = new_num_clients // n
    grown = jax.tree_util.tree_map(
        lambda x: jnp.repeat(x, rep, axis=0), params
    )
    new_sizes = np.repeat(sizes / rep, rep)
    return grown, new_sizes


def to_mesh(tree: PyTree, shardings: PyTree) -> PyTree:
    """Re-commit arrays to a new mesh's shardings (cross-mesh restore)."""
    return jax.tree_util.tree_map(jax.device_put, tree, shardings)


def merge_opt_state(opt_state: PyTree, data_sizes: np.ndarray, new_num_clients: int) -> PyTree:
    """Reshard stacked per-client optimizer state the same way as params.

    Scalar leaves (step counters) pass through unchanged; stacked leaves
    (first dim == N) are merged/grown like parameters.
    """
    n = len(np.asarray(data_sizes))

    def leaf(x):
        if hasattr(x, "ndim") and x.ndim >= 1 and x.shape[0] == n:
            out, _ = reshard_clients(x, data_sizes, new_num_clients)
            return out
        return x

    return jax.tree_util.tree_map(leaf, opt_state)
