from repro.data import partition, pipeline, synthetic
from repro.data.partition import partition as make_partition, partition_hierarchy, partition_stats
from repro.data.pipeline import (
    CohortPrefetcher,
    FederatedBatcher,
    SuperBatchPrefetcher,
    VirtualClientBatcher,
    global_batch_iterator,
)
from repro.data.synthetic import ClassificationData, TokenCorpus, clustered_gaussians, embedding_corpus, token_corpus

__all__ = [
    "partition",
    "pipeline",
    "synthetic",
    "make_partition",
    "partition_hierarchy",
    "partition_stats",
    "CohortPrefetcher",
    "FederatedBatcher",
    "SuperBatchPrefetcher",
    "VirtualClientBatcher",
    "global_batch_iterator",
    "ClassificationData",
    "TokenCorpus",
    "clustered_gaussians",
    "embedding_corpus",
    "token_corpus",
]
