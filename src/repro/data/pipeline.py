"""Federated batching: per-client iterators -> stacked (N, b, ...) batches.

The production train step consumes one batch per client per local update,
stacked on the leading client axis (matching the stacked-parameter layout in
``core.hierfavg``). The pipeline:

  1. holds each client's index set (from ``data.partition``),
  2. reshuffles each client's samples every local epoch (client-seeded,
     reproducible, restart-safe: state = (epoch, cursor) per client),
  3. emits pytree batches with leaves shaped (N, b, ...) — or
     (kappa1, N, b, ...) for the scanned ``hier_round`` driver.

Also provides ``global_batch_iterator`` for the plain (non-federated)
LM training path used by the serving/dry-run drivers.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Iterator, List, Optional, Sequence

import numpy as np

PyTree = Any


@dataclasses.dataclass
class ClientCursor:
    epoch: int = 0
    pos: int = 0


class FederatedBatcher:
    """Stateful, restart-safe federated batcher.

    arrays: dict of data arrays (first axis = sample). batch_fn maps a dict
    of per-sample slices to the model's batch pytree (default: identity).
    """

    def __init__(
        self,
        arrays: Dict[str, np.ndarray],
        client_indices: Sequence[np.ndarray],
        batch_size: int,
        *,
        seed: int = 0,
        batch_fn: Optional[Callable[[Dict[str, np.ndarray]], PyTree]] = None,
    ):
        self.arrays = arrays
        self.client_indices = [np.asarray(ix) for ix in client_indices]
        self.batch_size = batch_size
        self.seed = seed
        self.batch_fn = batch_fn or (lambda d: d)
        self.cursors = [ClientCursor() for _ in client_indices]
        self._orders: List[np.ndarray] = [self._order(i) for i in range(len(client_indices))]

    @property
    def num_clients(self) -> int:
        return len(self.client_indices)

    @property
    def data_sizes(self) -> np.ndarray:
        return np.array([ix.shape[0] for ix in self.client_indices], np.float64)

    def _order(self, client: int) -> np.ndarray:
        rng = np.random.default_rng((self.seed, client, self.cursors[client].epoch))
        return rng.permutation(self.client_indices[client])

    def _next_for(self, client: int) -> np.ndarray:
        cur = self.cursors[client]
        order = self._orders[client]
        b = self.batch_size
        if cur.pos + b > order.shape[0]:
            cur.epoch += 1
            cur.pos = 0
            self._orders[client] = order = self._order(client)
        take = order[cur.pos : cur.pos + b]
        cur.pos += b
        return take

    def next_batch(self) -> PyTree:
        """One stacked batch: leaves (N, b, ...)."""
        rows = [self._next_for(i) for i in range(self.num_clients)]
        idx = np.stack(rows)  # (N, b)
        return self.batch_fn({k: v[idx] for k, v in self.arrays.items()})

    def next_batches(self, count: int) -> PyTree:
        """`count` stacked batches with a leading scan axis: (count, N, b, ...)."""
        outs = [self.next_batch() for _ in range(count)]
        import jax

        return jax.tree_util.tree_map(lambda *xs: np.stack(xs), *outs)

    # -- restart safety ------------------------------------------------------
    def state_dict(self) -> Dict[str, Any]:
        return {
            "seed": self.seed,
            "cursors": [(c.epoch, c.pos) for c in self.cursors],
        }

    def load_state_dict(self, state: Dict[str, Any]) -> None:
        self.seed = state["seed"]
        for c, (e, p) in zip(self.cursors, state["cursors"]):
            c.epoch, c.pos = e, p
        self._orders = [self._order(i) for i in range(self.num_clients)]


def global_batch_iterator(
    arrays: Dict[str, np.ndarray], batch_size: int, *, seed: int = 0
) -> Iterator[Dict[str, np.ndarray]]:
    """Simple epoch-shuffled global iterator (non-federated paths)."""
    n = next(iter(arrays.values())).shape[0]
    epoch = 0
    while True:
        rng = np.random.default_rng((seed, epoch))
        order = rng.permutation(n)
        for s in range(0, n - batch_size + 1, batch_size):
            take = order[s : s + batch_size]
            yield {k: v[take] for k, v in arrays.items()}
        epoch += 1
