"""Federated batching: per-client iterators -> stacked (N, b, ...) batches.

The production train step consumes one batch per client per local update,
stacked on the leading client axis (matching the stacked-parameter layout in
``core.hierfavg``). The pipeline:

  1. holds each client's index set (from ``data.partition``),
  2. reshuffles each client's samples every local epoch (client-seeded,
     reproducible, restart-safe: state = (epoch, cursor) per client),
  3. emits pytree batches with leaves shaped (N, b, ...) — or
     (kappa1, N, b, ...) for the scanned ``hier_round`` driver.

Also provides ``global_batch_iterator`` for the plain (non-federated)
LM training path used by the serving/dry-run drivers.
"""
from __future__ import annotations

import dataclasses
import queue
import threading
from typing import Any, Callable, Dict, Iterator, List, Optional, Sequence, Tuple

import numpy as np

PyTree = Any


@dataclasses.dataclass
class ClientCursor:
    epoch: int = 0
    pos: int = 0


class FederatedBatcher:
    """Stateful, restart-safe federated batcher.

    arrays: dict of data arrays (first axis = sample). batch_fn maps a dict
    of per-sample slices to the model's batch pytree (default: identity).
    """

    def __init__(
        self,
        arrays: Dict[str, np.ndarray],
        client_indices: Sequence[np.ndarray],
        batch_size: int,
        *,
        seed: int = 0,
        batch_fn: Optional[Callable[[Dict[str, np.ndarray]], PyTree]] = None,
    ):
        self.arrays = arrays
        self.client_indices = [np.asarray(ix) for ix in client_indices]
        self.batch_size = batch_size
        self.seed = seed
        self.batch_fn = batch_fn or (lambda d: d)
        self.cursors = [ClientCursor() for _ in client_indices]
        self._orders: List[np.ndarray] = [self._order(i) for i in range(len(client_indices))]

    @property
    def num_clients(self) -> int:
        return len(self.client_indices)

    @property
    def data_sizes(self) -> np.ndarray:
        return np.array([ix.shape[0] for ix in self.client_indices], np.float64)

    def _order(self, client: int) -> np.ndarray:
        rng = np.random.default_rng((self.seed, client, self.cursors[client].epoch))
        return rng.permutation(self.client_indices[client])

    def _next_for(self, client: int) -> np.ndarray:
        cur = self.cursors[client]
        order = self._orders[client]
        b = self.batch_size
        if cur.pos + b > order.shape[0]:
            cur.epoch += 1
            cur.pos = 0
            self._orders[client] = order = self._order(client)
        take = order[cur.pos : cur.pos + b]
        cur.pos += b
        return take

    def next_batch(self) -> PyTree:
        """One stacked batch: leaves (N, b, ...)."""
        rows = [self._next_for(i) for i in range(self.num_clients)]
        idx = np.stack(rows)  # (N, b)
        return self.batch_fn({k: v[idx] for k, v in self.arrays.items()})

    def next_batches(self, count: int) -> PyTree:
        """`count` stacked batches with a leading scan axis: (count, N, b, ...)."""
        outs = [self.next_batch() for _ in range(count)]
        import jax

        return jax.tree_util.tree_map(lambda *xs: np.stack(xs), *outs)

    # -- sampled-participation view ------------------------------------------
    def next_batch_for(self, ids: Sequence[int]) -> PyTree:
        """One cohort batch: leaves (C, b, ...); advances only the sampled
        clients' cursors. With ids == range(N) this is ``next_batch`` exactly
        (same per-client draw order), which is what full-participation
        parity rests on."""
        rows = [self._next_for(int(i)) for i in ids]
        idx = np.stack(rows)  # (C, b)
        return self.batch_fn({k: v[idx] for k, v in self.arrays.items()})

    def next_batches_for(self, ids: Sequence[int], count: int) -> PyTree:
        """`count` cohort batches with a leading scan axis: (count, C, b, ...)."""
        outs = [self.next_batch_for(ids) for _ in range(count)]
        import jax

        return jax.tree_util.tree_map(lambda *xs: np.stack(xs), *outs)

    # -- restart safety ------------------------------------------------------
    def state_dict(self) -> Dict[str, Any]:
        return {
            "seed": self.seed,
            "cursors": [(c.epoch, c.pos) for c in self.cursors],
        }

    def load_state_dict(self, state: Dict[str, Any]) -> None:
        self.seed = state["seed"]
        for c, (e, p) in zip(self.cursors, state["cursors"]):
            c.epoch, c.pos = e, p
        self._orders = [self._order(i) for i in range(self.num_clients)]


class VirtualClientBatcher:
    """A population of N *virtual* clients over a shared sample pool.

    At population scale (ROADMAP's "millions of users") materializing N
    per-client index sets up front is O(N) host memory and startup time.
    Here a client's shard is a pure function of ``(seed, client_id)`` —
    ``samples_per_client`` bootstrap draws from the pool, realized lazily
    only when that client is actually sampled into a cohort. Per-epoch
    shuffle order is likewise derived from ``(seed, client_id, epoch)``.
    Cursor state is a dict holding only the clients that ever participated,
    so batcher memory is ∝ cumulative unique participants, not N.

    Interface-compatible with the cohort slice of ``FederatedBatcher``
    (``next_batch_for`` / ``next_batches_for`` / ``state_dict``); the
    full-population ``next_batch`` works too but is intended only for small
    N (tests).
    """

    _SHARD_NS = 0x5A4D  # namespaces the shard draw away from the order draw

    def __init__(
        self,
        arrays: Dict[str, np.ndarray],
        *,
        num_clients: int,
        samples_per_client: int,
        batch_size: int,
        seed: int = 0,
        batch_fn: Optional[Callable[[Dict[str, np.ndarray]], PyTree]] = None,
    ):
        self.arrays = arrays
        self.num_clients = int(num_clients)
        self.samples_per_client = int(samples_per_client)
        self.batch_size = int(batch_size)
        self.seed = int(seed)
        self.batch_fn = batch_fn or (lambda d: d)
        self.num_samples = int(next(iter(arrays.values())).shape[0])
        if self.samples_per_client < self.batch_size:
            raise ValueError(
                f"samples_per_client {self.samples_per_client} < batch_size {self.batch_size}"
            )
        self.cursors: Dict[int, ClientCursor] = {}

    @property
    def data_sizes(self) -> np.ndarray:
        return np.full(self.num_clients, self.samples_per_client, np.float64)

    def _shard(self, client: int) -> np.ndarray:
        """(samples_per_client,) pool indices — the client's virtual dataset."""
        rng = np.random.default_rng((self.seed, self._SHARD_NS, client))
        return rng.integers(0, self.num_samples, self.samples_per_client)

    def _order(self, client: int, epoch: int) -> np.ndarray:
        rng = np.random.default_rng((self.seed, client, epoch))
        return rng.permutation(self.samples_per_client)

    def _take_rows(self, client: int, nbatches: int) -> np.ndarray:
        """(nbatches, b) pool indices; advances the client's cursor. Epoch
        semantics mirror ``FederatedBatcher._next_for`` (partial trailing
        batches are never emitted; the epoch reshuffles instead)."""
        cur = self.cursors.setdefault(client, ClientCursor())
        shard = self._shard(client)
        b = self.batch_size
        order = None
        out = np.empty((nbatches, b), np.int64)
        for j in range(nbatches):
            if cur.pos + b > self.samples_per_client:
                cur.epoch += 1
                cur.pos = 0
                order = None
            if order is None:
                order = self._order(client, cur.epoch)
            out[j] = shard[order[cur.pos : cur.pos + b]]
            cur.pos += b
        return out

    def next_batch_for(self, ids: Sequence[int]) -> PyTree:
        """One cohort batch: leaves (C, b, ...)."""
        rows = np.stack([self._take_rows(int(c), 1)[0] for c in ids])  # (C, b)
        return self.batch_fn({k: v[rows] for k, v in self.arrays.items()})

    def next_batches_for(self, ids: Sequence[int], count: int) -> PyTree:
        """`count` cohort batches with a leading scan axis: (count, C, b, ...)."""
        rows = np.stack([self._take_rows(int(c), count) for c in ids], axis=1)
        return self.batch_fn({k: v[rows] for k, v in self.arrays.items()})

    def next_batch(self) -> PyTree:
        """Full-population batch (small-N testing only at scale N)."""
        return self.next_batch_for(range(self.num_clients))

    def next_batches(self, count: int) -> PyTree:
        outs = [self.next_batch() for _ in range(count)]
        import jax

        return jax.tree_util.tree_map(lambda *xs: np.stack(xs), *outs)

    # -- restart safety ------------------------------------------------------
    def state_dict(self) -> Dict[str, Any]:
        # string keys: this dict rides checkpoint metadata through JSON,
        # which stringifies int keys — normalize here so save/load is stable
        return {
            "seed": self.seed,
            "cursors": {str(c): (cur.epoch, cur.pos) for c, cur in self.cursors.items()},
        }

    def load_state_dict(self, state: Dict[str, Any]) -> None:
        self.seed = int(state["seed"])
        self.cursors = {
            int(c): ClientCursor(epoch=int(e), pos=int(p))
            for c, (e, p) in state["cursors"].items()
        }


class SuperBatchPrefetcher:
    """Double-buffered host→device prefetch of super-round batch blocks.

    The superround engine (``fed.engine``) consumes one
    (rounds_per_block, steps_per_round, N, b, ...) block per cloud-interval
    dispatch. Assembling that block is host work (numpy gathers) and
    uploading it is a host→device copy — both off the critical path once
    the device is busy with interval r: a background worker builds and
    ``jax.device_put``s interval r+1's block while interval r computes, so
    the dispatch loop never waits on batch assembly (double buffering; the
    bounded queue holds at most ``prefetch`` ready blocks).

    Restart safety: each block is paired with the batcher's ``state_dict``
    snapshot taken right after producing it — i.e. the cursor state a
    checkpoint at that block's cloud boundary must record. The live batcher
    runs ahead of the computation, so checkpoints must use the snapshot,
    never ``batcher.state_dict()`` directly.

    ``num_blocks`` bounds total production so the batcher is left positioned
    exactly after the engine's rounds (a per-round fallback can continue
    from it). ``use_thread=False`` degrades to synchronous production (no
    overlap — deterministic single-threaded mode for tests/debugging).
    The worker is the sole batcher consumer while the prefetcher is active.

    Mesh execution: ``device`` may be a ``jax.sharding.Sharding`` (e.g. the
    engine's ``NamedSharding`` over the ``"clients"`` axis), in which case
    ``device_put`` uploads each device's block slice directly instead of a
    single-device copy; ``transform`` is an optional host-side (numpy) hook
    applied to the assembled block before upload — the engine uses it to
    permute + pad the client axis into shard placement order.
    """

    _SENTINEL_OK = "ok"
    _SENTINEL_ERR = "err"

    def __init__(
        self,
        batcher: FederatedBatcher,
        *,
        rounds_per_block: int,
        steps_per_round: int,
        num_blocks: Optional[int] = None,
        device=None,
        prefetch: int = 1,
        use_thread: bool = True,
        transform: Optional[Callable[[PyTree], PyTree]] = None,
    ):
        self.batcher = batcher
        self.rounds_per_block = int(rounds_per_block)
        self.steps_per_round = int(steps_per_round)
        self.num_blocks = num_blocks
        self.device = device
        self.transform = transform
        self._produced = 0
        self._consumed = 0
        self._use_thread = use_thread
        if use_thread:
            self._queue: queue.Queue = queue.Queue(maxsize=max(1, int(prefetch)))
            self._stop = threading.Event()
            self._thread = threading.Thread(
                target=self._worker, name="super-batch-prefetch", daemon=True
            )
            self._thread.start()

    # -- block production ----------------------------------------------------
    def _make_block(self) -> Tuple[PyTree, Dict[str, Any]]:
        import jax

        flat = self.batcher.next_batches(self.rounds_per_block * self.steps_per_round)
        block = jax.tree_util.tree_map(
            lambda x: np.reshape(
                x, (self.rounds_per_block, self.steps_per_round) + x.shape[1:]
            ),
            flat,
        )
        if self.transform is not None:
            block = self.transform(block)
        block = jax.device_put(block, self.device)  # async upload
        snapshot = self.batcher.state_dict()
        return block, snapshot

    def _worker(self) -> None:
        try:
            while not self._stop.is_set() and (
                self.num_blocks is None or self._produced < self.num_blocks
            ):
                item = (self._SENTINEL_OK,) + self._make_block()
                self._produced += 1
                while not self._stop.is_set():
                    try:
                        self._queue.put(item, timeout=0.1)
                        break
                    except queue.Full:
                        continue
        except Exception as e:  # surface worker failures at the next get()
            self._queue.put((self._SENTINEL_ERR, e, None))

    # -- consumption ---------------------------------------------------------
    def get(self) -> Tuple[PyTree, Dict[str, Any]]:
        """Next (device_block, batcher_state_snapshot). Blocks until ready."""
        if self.num_blocks is not None and self._consumed >= self.num_blocks:
            raise RuntimeError(
                f"prefetcher exhausted: all {self.num_blocks} blocks consumed"
            )
        if self._use_thread:
            kind, block, snapshot = self._queue.get()
            if kind == self._SENTINEL_ERR:
                raise RuntimeError("super-batch prefetch worker failed") from block
        else:
            block, snapshot = self._make_block()
            self._produced += 1
        self._consumed += 1
        return block, snapshot

    def stop(self) -> None:
        """Stop the worker (idempotent). Call when abandoning blocks early."""
        if not self._use_thread:
            return
        self._stop.set()
        try:
            while True:
                self._queue.get_nowait()
        except queue.Empty:
            pass
        self._thread.join(timeout=5.0)

    def __enter__(self) -> "SuperBatchPrefetcher":
        return self

    def __exit__(self, *exc) -> None:
        self.stop()


class CohortPrefetcher(SuperBatchPrefetcher):
    """``SuperBatchPrefetcher`` for sampled participation.

    The worker additionally draws the next cloud interval's cohort from a
    ``fed.participation`` sampler and assembles + uploads everything that is
    a pure function of the cohort ids — the (κ₂, κ₁, C, b, ...) batch block
    and the traced ``{"segments": (depth-1, C), "weights": (C,)}`` cohort
    pytree the cohort superround consumes — so sampling, batch gathers, and
    the host→device copies all overlap the previous interval's compute.
    The *client-state* rows are deliberately NOT prefetched: consecutive
    cohorts may overlap, and a row gathered before the previous interval's
    writeback would be stale; the engine swaps store rows synchronously
    (a C-row host gather — cheap next to the batch upload this class hides).

    Restart-exactness: each block's snapshot carries the *sampler* state
    alongside the batcher cursors, both captured right after producing the
    block. The live sampler runs ahead of the computation (prefetch), so a
    checkpoint that recorded the live state would replay *different* cohorts
    on resume — checkpoints must store the snapshot, mirroring the batcher
    contract above.

    ``get()`` returns ``((ids, cohort, block), snapshot)``: host-side int64
    ids for store gather/scatter, device-resident cohort arrays + block, and
    ``snapshot = {"batcher": ..., "sampler": ...}``.
    """

    def __init__(
        self,
        batcher,
        sampler,
        *,
        segments: np.ndarray,
        weights: np.ndarray,
        rounds_per_block: int,
        steps_per_round: int,
        num_blocks: Optional[int] = None,
        device=None,
        prefetch: int = 1,
        use_thread: bool = True,
        placement=None,
        weights_device=None,
    ):
        # fields first: the base __init__ starts the worker thread, which
        # calls our _make_block immediately
        self.sampler = sampler
        self._segments = np.ascontiguousarray(np.asarray(segments, np.int32))
        self._weights = np.asarray(weights, np.float32)
        # sharded-cohort mode: with a `placement` (cohort ShardPlacement) the
        # worker permutes the block's client axis into slot placement order,
        # pads, and uploads per-device slices — `device` is then the block's
        # NamedSharding and `weights_device` the (padded_C,) row sharding.
        # Segments are not uploaded: placement-stable packing makes every
        # segment table static in the sharded lowering.
        self._placement = placement
        self._weights_device = weights_device
        super().__init__(
            batcher,
            rounds_per_block=rounds_per_block,
            steps_per_round=steps_per_round,
            num_blocks=num_blocks,
            device=device,
            prefetch=prefetch,
            use_thread=use_thread,
        )

    def _make_block(self):
        import jax

        ids = np.asarray(self.sampler.sample(), np.int64)
        flat = self.batcher.next_batches_for(ids, self.rounds_per_block * self.steps_per_round)
        block = jax.tree_util.tree_map(
            lambda x: np.reshape(
                x, (self.rounds_per_block, self.steps_per_round) + x.shape[1:]
            ),
            flat,
        )
        if self._placement is not None:
            # slot placement order: phantom slots replicate slot 0's batch
            # (their weight is zero), matching the sharded superround's pad
            gather = self._placement.gather_index()
            block = jax.tree_util.tree_map(lambda x: x[:, :, gather], block)
            cohort = {"weights": self._placement.pad_weights(self._weights[ids])}
            block = jax.device_put(block, self.device)  # async per-device upload
            cohort = jax.device_put(cohort, self._weights_device)
        else:
            cohort = {
                "segments": self._segments[:, ids],
                "weights": self._weights[ids],
            }
            cohort, block = jax.device_put((cohort, block), self.device)  # async upload
        snapshot = {
            "batcher": self.batcher.state_dict(),
            "sampler": self.sampler.state_dict(),
        }
        return (ids, cohort, block), snapshot


def global_batch_iterator(
    arrays: Dict[str, np.ndarray], batch_size: int, *, seed: int = 0
) -> Iterator[Dict[str, np.ndarray]]:
    """Simple epoch-shuffled global iterator (non-federated paths)."""
    n = next(iter(arrays.values())).shape[0]
    epoch = 0
    while True:
        rng = np.random.default_rng((seed, epoch))
        order = rng.permutation(n)
        for s in range(0, n - batch_size + 1, batch_size):
            take = order[s : s + batch_size]
            yield {k: v[take] for k, v in arrays.items()}
        epoch += 1
