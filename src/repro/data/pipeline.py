"""Federated batching: per-client iterators -> stacked (N, b, ...) batches.

The production train step consumes one batch per client per local update,
stacked on the leading client axis (matching the stacked-parameter layout in
``core.hierfavg``). The pipeline:

  1. holds each client's index set (from ``data.partition``),
  2. reshuffles each client's samples every local epoch (client-seeded,
     reproducible, restart-safe: state = (epoch, cursor) per client),
  3. emits pytree batches with leaves shaped (N, b, ...) — or
     (kappa1, N, b, ...) for the scanned ``hier_round`` driver.

Also provides ``global_batch_iterator`` for the plain (non-federated)
LM training path used by the serving/dry-run drivers.
"""
from __future__ import annotations

import dataclasses
import queue
import threading
from typing import Any, Callable, Dict, Iterator, List, Optional, Sequence, Tuple

import numpy as np

PyTree = Any


@dataclasses.dataclass
class ClientCursor:
    epoch: int = 0
    pos: int = 0


class FederatedBatcher:
    """Stateful, restart-safe federated batcher.

    arrays: dict of data arrays (first axis = sample). batch_fn maps a dict
    of per-sample slices to the model's batch pytree (default: identity).
    """

    def __init__(
        self,
        arrays: Dict[str, np.ndarray],
        client_indices: Sequence[np.ndarray],
        batch_size: int,
        *,
        seed: int = 0,
        batch_fn: Optional[Callable[[Dict[str, np.ndarray]], PyTree]] = None,
    ):
        self.arrays = arrays
        self.client_indices = [np.asarray(ix) for ix in client_indices]
        self.batch_size = batch_size
        self.seed = seed
        self.batch_fn = batch_fn or (lambda d: d)
        self.cursors = [ClientCursor() for _ in client_indices]
        self._orders: List[np.ndarray] = [self._order(i) for i in range(len(client_indices))]

    @property
    def num_clients(self) -> int:
        return len(self.client_indices)

    @property
    def data_sizes(self) -> np.ndarray:
        return np.array([ix.shape[0] for ix in self.client_indices], np.float64)

    def _order(self, client: int) -> np.ndarray:
        rng = np.random.default_rng((self.seed, client, self.cursors[client].epoch))
        return rng.permutation(self.client_indices[client])

    def _next_for(self, client: int) -> np.ndarray:
        cur = self.cursors[client]
        order = self._orders[client]
        b = self.batch_size
        if cur.pos + b > order.shape[0]:
            cur.epoch += 1
            cur.pos = 0
            self._orders[client] = order = self._order(client)
        take = order[cur.pos : cur.pos + b]
        cur.pos += b
        return take

    def next_batch(self) -> PyTree:
        """One stacked batch: leaves (N, b, ...)."""
        rows = [self._next_for(i) for i in range(self.num_clients)]
        idx = np.stack(rows)  # (N, b)
        return self.batch_fn({k: v[idx] for k, v in self.arrays.items()})

    def next_batches(self, count: int) -> PyTree:
        """`count` stacked batches with a leading scan axis: (count, N, b, ...)."""
        outs = [self.next_batch() for _ in range(count)]
        import jax

        return jax.tree_util.tree_map(lambda *xs: np.stack(xs), *outs)

    # -- restart safety ------------------------------------------------------
    def state_dict(self) -> Dict[str, Any]:
        return {
            "seed": self.seed,
            "cursors": [(c.epoch, c.pos) for c in self.cursors],
        }

    def load_state_dict(self, state: Dict[str, Any]) -> None:
        self.seed = state["seed"]
        for c, (e, p) in zip(self.cursors, state["cursors"]):
            c.epoch, c.pos = e, p
        self._orders = [self._order(i) for i in range(self.num_clients)]


class SuperBatchPrefetcher:
    """Double-buffered host→device prefetch of super-round batch blocks.

    The superround engine (``fed.engine``) consumes one
    (rounds_per_block, steps_per_round, N, b, ...) block per cloud-interval
    dispatch. Assembling that block is host work (numpy gathers) and
    uploading it is a host→device copy — both off the critical path once
    the device is busy with interval r: a background worker builds and
    ``jax.device_put``s interval r+1's block while interval r computes, so
    the dispatch loop never waits on batch assembly (double buffering; the
    bounded queue holds at most ``prefetch`` ready blocks).

    Restart safety: each block is paired with the batcher's ``state_dict``
    snapshot taken right after producing it — i.e. the cursor state a
    checkpoint at that block's cloud boundary must record. The live batcher
    runs ahead of the computation, so checkpoints must use the snapshot,
    never ``batcher.state_dict()`` directly.

    ``num_blocks`` bounds total production so the batcher is left positioned
    exactly after the engine's rounds (a per-round fallback can continue
    from it). ``use_thread=False`` degrades to synchronous production (no
    overlap — deterministic single-threaded mode for tests/debugging).
    The worker is the sole batcher consumer while the prefetcher is active.

    Mesh execution: ``device`` may be a ``jax.sharding.Sharding`` (e.g. the
    engine's ``NamedSharding`` over the ``"clients"`` axis), in which case
    ``device_put`` uploads each device's block slice directly instead of a
    single-device copy; ``transform`` is an optional host-side (numpy) hook
    applied to the assembled block before upload — the engine uses it to
    permute + pad the client axis into shard placement order.
    """

    _SENTINEL_OK = "ok"
    _SENTINEL_ERR = "err"

    def __init__(
        self,
        batcher: FederatedBatcher,
        *,
        rounds_per_block: int,
        steps_per_round: int,
        num_blocks: Optional[int] = None,
        device=None,
        prefetch: int = 1,
        use_thread: bool = True,
        transform: Optional[Callable[[PyTree], PyTree]] = None,
    ):
        self.batcher = batcher
        self.rounds_per_block = int(rounds_per_block)
        self.steps_per_round = int(steps_per_round)
        self.num_blocks = num_blocks
        self.device = device
        self.transform = transform
        self._produced = 0
        self._consumed = 0
        self._use_thread = use_thread
        if use_thread:
            self._queue: queue.Queue = queue.Queue(maxsize=max(1, int(prefetch)))
            self._stop = threading.Event()
            self._thread = threading.Thread(
                target=self._worker, name="super-batch-prefetch", daemon=True
            )
            self._thread.start()

    # -- block production ----------------------------------------------------
    def _make_block(self) -> Tuple[PyTree, Dict[str, Any]]:
        import jax

        flat = self.batcher.next_batches(self.rounds_per_block * self.steps_per_round)
        block = jax.tree_util.tree_map(
            lambda x: np.reshape(
                x, (self.rounds_per_block, self.steps_per_round) + x.shape[1:]
            ),
            flat,
        )
        if self.transform is not None:
            block = self.transform(block)
        block = jax.device_put(block, self.device)  # async upload
        snapshot = self.batcher.state_dict()
        return block, snapshot

    def _worker(self) -> None:
        try:
            while not self._stop.is_set() and (
                self.num_blocks is None or self._produced < self.num_blocks
            ):
                item = (self._SENTINEL_OK,) + self._make_block()
                self._produced += 1
                while not self._stop.is_set():
                    try:
                        self._queue.put(item, timeout=0.1)
                        break
                    except queue.Full:
                        continue
        except Exception as e:  # surface worker failures at the next get()
            self._queue.put((self._SENTINEL_ERR, e, None))

    # -- consumption ---------------------------------------------------------
    def get(self) -> Tuple[PyTree, Dict[str, Any]]:
        """Next (device_block, batcher_state_snapshot). Blocks until ready."""
        if self.num_blocks is not None and self._consumed >= self.num_blocks:
            raise RuntimeError(
                f"prefetcher exhausted: all {self.num_blocks} blocks consumed"
            )
        if self._use_thread:
            kind, block, snapshot = self._queue.get()
            if kind == self._SENTINEL_ERR:
                raise RuntimeError("super-batch prefetch worker failed") from block
        else:
            block, snapshot = self._make_block()
            self._produced += 1
        self._consumed += 1
        return block, snapshot

    def stop(self) -> None:
        """Stop the worker (idempotent). Call when abandoning blocks early."""
        if not self._use_thread:
            return
        self._stop.set()
        try:
            while True:
                self._queue.get_nowait()
        except queue.Empty:
            pass
        self._thread.join(timeout=5.0)

    def __enter__(self) -> "SuperBatchPrefetcher":
        return self

    def __exit__(self, *exc) -> None:
        self.stop()


def global_batch_iterator(
    arrays: Dict[str, np.ndarray], batch_size: int, *, seed: int = 0
) -> Iterator[Dict[str, np.ndarray]]:
    """Simple epoch-shuffled global iterator (non-federated paths)."""
    n = next(iter(arrays.values())).shape[0]
    epoch = 0
    while True:
        rng = np.random.default_rng((seed, epoch))
        order = rng.permutation(n)
        for s in range(0, n - batch_size + 1, batch_size):
            take = order[s : s + batch_size]
            yield {k: v[take] for k, v in arrays.items()}
        epoch += 1
