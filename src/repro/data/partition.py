"""Client data partitioning (Section IV-A's three non-IID protocols).

Given labels (n,) and a topology (L edges × C clients each), produce a list
of per-client index arrays under one of:

* ``iid``          — uniform random split.
* ``simple_niid``  — each client holds samples of `classes_per_client` (=2)
  classes; clients are randomly assigned to edges. (The paper's "most
  commonly used non-IID data partition [2]".)
* ``edge_iid``     — each client holds ONE class; each edge's C clients
  cover C distinct classes ⇒ edge datasets are IID replicas. (Paper: "assign
  each client samples of one class, and assign each edge 10 clients with
  different classes".)
* ``edge_niid``    — each client holds ONE class; each edge covers only
  `classes_per_edge` (=C/2 in the paper: 5 classes across 10 clients)
  ⇒ edge datasets are non-IID.

All protocols balance sample counts across clients (the paper assumes
"the same amount of training data" per client).
"""
from __future__ import annotations

from typing import List

import numpy as np


def _shards_by_class(labels: np.ndarray, rng: np.random.Generator) -> List[np.ndarray]:
    return [rng.permutation(np.where(labels == c)[0]) for c in range(int(labels.max()) + 1)]


def _balanced_take(pool: np.ndarray, count: int, cursor: int) -> (np.ndarray, int):
    """Take `count` indices from pool starting at cursor, wrapping."""
    n = pool.shape[0]
    idx = np.arange(cursor, cursor + count) % n
    return pool[idx], (cursor + count) % n


def partition_iid(
    labels: np.ndarray, num_clients: int, rng: np.random.Generator
) -> List[np.ndarray]:
    perm = rng.permutation(labels.shape[0])
    return [np.sort(s) for s in np.array_split(perm, num_clients)]


def partition_simple_niid(
    labels: np.ndarray,
    num_clients: int,
    rng: np.random.Generator,
    *,
    classes_per_client: int = 2,
) -> List[np.ndarray]:
    """McMahan-style shard assignment: sort by label, slice into
    num_clients * classes_per_client shards, deal each client k shards."""
    order = np.argsort(labels, kind="stable")
    shards = np.array_split(order, num_clients * classes_per_client)
    shard_ids = rng.permutation(len(shards))
    out = []
    for i in range(num_clients):
        take = shard_ids[i * classes_per_client : (i + 1) * classes_per_client]
        out.append(np.sort(np.concatenate([shards[s] for s in take])))
    return out


def partition_edge_iid(
    labels: np.ndarray,
    num_edges: int,
    clients_per_edge: int,
    rng: np.random.Generator,
) -> List[np.ndarray]:
    """One class per client; each edge's clients cover distinct classes.

    Requires clients_per_edge <= num_classes. Client j of edge l gets class
    (j + l) mod num_classes — distinct within each edge, and class coverage
    rotates across edges so every edge sees a same-shaped class mix (IID
    across edges, maximally non-IID within clients).
    """
    num_classes = int(labels.max()) + 1
    if clients_per_edge > num_classes:
        raise ValueError("edge_iid needs clients_per_edge <= num_classes")
    pools = _shards_by_class(labels, rng)
    cursors = [0] * num_classes
    per_client = labels.shape[0] // (num_edges * clients_per_edge)
    out = []
    for l in range(num_edges):
        for j in range(clients_per_edge):
            c = (j + l) % num_classes
            take, cursors[c] = _balanced_take(pools[c], per_client, cursors[c])
            out.append(np.sort(take))
    return out


def partition_edge_niid(
    labels: np.ndarray,
    num_edges: int,
    clients_per_edge: int,
    rng: np.random.Generator,
    *,
    classes_per_edge: int = 0,
) -> List[np.ndarray]:
    """One class per client; edge l covers only classes_per_edge classes
    (default C/2, the paper's 5-of-10), so edges are non-IID."""
    num_classes = int(labels.max()) + 1
    cpe = classes_per_edge or max(clients_per_edge // 2, 1)
    pools = _shards_by_class(labels, rng)
    cursors = [0] * num_classes
    per_client = labels.shape[0] // (num_edges * clients_per_edge)
    out = []
    for l in range(num_edges):
        base = (l * cpe) % num_classes
        for j in range(clients_per_edge):
            c = (base + (j % cpe)) % num_classes
            take, cursors[c] = _balanced_take(pools[c], per_client, cursors[c])
            out.append(np.sort(take))
    return out


def partition(
    kind: str,
    labels: np.ndarray,
    num_edges: int,
    clients_per_edge: int,
    rng: np.random.Generator,
    **kw,
) -> List[np.ndarray]:
    n = num_edges * clients_per_edge
    if kind == "iid":
        return partition_iid(labels, n, rng)
    if kind == "simple_niid":
        return partition_simple_niid(labels, n, rng, **kw)
    if kind == "edge_iid":
        return partition_edge_iid(labels, num_edges, clients_per_edge, rng)
    if kind == "edge_niid":
        return partition_edge_niid(labels, num_edges, clients_per_edge, rng, **kw)
    raise ValueError(f"unknown partition kind: {kind}")


def partition_hierarchy(
    kind: str,
    labels: np.ndarray,
    spec,  # core.hierarchy.HierarchySpec
    rng: np.random.Generator,
    **kw,
) -> List[np.ndarray]:
    """Partition for a (possibly ragged) ``HierarchySpec``: same protocols,
    but each edge deals to however many clients it actually has.

    ``iid``/``simple_niid`` ignore the tree shape (client-level protocols);
    ``edge_iid``/``edge_niid`` walk the level-1 fan-out so an edge with 7
    clients covers 7 classes (edge_iid) or 7//2 = 3 classes (edge_niid,
    the paper's C/2 rule).
    """
    n = spec.num_clients
    if kind == "iid":
        return partition_iid(labels, n, rng)
    if kind == "simple_niid":
        return partition_simple_niid(labels, n, rng, **kw)
    if kind not in ("edge_iid", "edge_niid"):
        raise ValueError(f"unknown partition kind: {kind}")

    num_classes = int(labels.max()) + 1
    sizes = spec.group_sizes(1)
    if kind == "edge_iid" and int(sizes.max()) > num_classes:
        raise ValueError("edge_iid needs clients_per_edge <= num_classes at every edge")
    pools = _shards_by_class(labels, rng)
    cursors = [0] * num_classes
    per_client = labels.shape[0] // n
    out: List[np.ndarray] = []
    for l, c_l in enumerate(sizes):
        cpe = kw.get("classes_per_edge", 0) or max(int(c_l) // 2, 1)
        base = (l * cpe) % num_classes
        for j in range(int(c_l)):
            if kind == "edge_iid":
                c = (j + l) % num_classes
            else:
                c = (base + (j % cpe)) % num_classes
            take, cursors[c] = _balanced_take(pools[c], per_client, cursors[c])
            out.append(np.sort(take))
    return out


def partition_stats(parts: List[np.ndarray], labels: np.ndarray) -> np.ndarray:
    """(num_clients, num_classes) label histogram — used by tests and the
    divergence probes to verify the protocol produced the intended skew."""
    num_classes = int(labels.max()) + 1
    out = np.zeros((len(parts), num_classes), np.int64)
    for i, idx in enumerate(parts):
        binc = np.bincount(labels[idx], minlength=num_classes)
        out[i] = binc
    return out
