"""Synthetic datasets with *controllable non-IIDness*.

The paper's experiments hinge on label-skewed partitions (simple-NIID,
edge-IID, edge-NIID). Offline we cannot load MNIST/CIFAR, so we generate
datasets whose class structure supports exactly the same partition
protocols and whose difficulty is tunable:

* ``clustered_gaussians`` — a C-class Gaussian-mixture classification
  problem (stands in for MNIST/CIFAR in the paper-reproduction benches:
  same 10-class structure, same partition semantics, learnable by the same
  CNN/MLP family in a few hundred steps).
* ``token_corpus`` — a Markov-teacher LM corpus over `vocab` tokens with
  per-class transition kernels, so label-skew partitions induce genuinely
  divergent client gradients (δ, Δ > 0) for the LM architectures.

Everything is generated with numpy RNG (seeded, reproducible) and returned
as plain numpy arrays; the pipeline layer shards/batches them.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import numpy as np


@dataclasses.dataclass(frozen=True)
class ClassificationData:
    x: np.ndarray  # (n, ...) float32
    y: np.ndarray  # (n,) int32

    @property
    def num_samples(self) -> int:
        return int(self.y.shape[0])

    @property
    def num_classes(self) -> int:
        return int(self.y.max()) + 1


def clustered_gaussians(
    rng: np.random.Generator,
    *,
    num_samples: int = 10_000,
    num_classes: int = 10,
    dim: Tuple[int, ...] = (28, 28, 1),
    class_sep: float = 3.0,
    noise: float = 1.0,
) -> ClassificationData:
    """C well-separated Gaussian clusters in a flattened image space.

    class_sep/noise tune difficulty; with the defaults a small CNN reaches
    >95% in a few dozen steps, giving the paper's T_alpha/E_alpha benches a
    fast, deterministic accuracy curve.
    """
    d = int(np.prod(dim))
    centers = rng.normal(0.0, class_sep, size=(num_classes, d))
    y = rng.integers(0, num_classes, size=num_samples).astype(np.int32)
    x = centers[y] + rng.normal(0.0, noise, size=(num_samples, d))
    return ClassificationData(x=x.reshape((num_samples, *dim)).astype(np.float32), y=y)


@dataclasses.dataclass(frozen=True)
class TokenCorpus:
    tokens: np.ndarray  # (n, seq_len+1) int32 — inputs[t], targets shifted
    labels: np.ndarray  # (n,) int32 "topic" class of each sequence

    @property
    def num_samples(self) -> int:
        return int(self.tokens.shape[0])

    @property
    def num_classes(self) -> int:
        return int(self.labels.max()) + 1


def token_corpus(
    rng: np.random.Generator,
    *,
    num_sequences: int = 2048,
    seq_len: int = 128,
    vocab: int = 256,
    num_classes: int = 10,
    concentration: float = 0.3,
) -> TokenCorpus:
    """Markov-teacher corpus: each class has its own sparse transition kernel.

    Lower `concentration` -> sparser kernels -> more divergent per-class
    gradients (higher δ/Δ under label-skewed partitions).
    """
    # Per-class transition matrices, Dirichlet rows (sparse-ish).
    kernels = rng.dirichlet(np.full(vocab, concentration), size=(num_classes, vocab))
    labels = rng.integers(0, num_classes, size=num_sequences).astype(np.int32)
    toks = np.empty((num_sequences, seq_len + 1), np.int32)
    toks[:, 0] = rng.integers(0, vocab, size=num_sequences)
    for t in range(seq_len):
        # vectorized per-class sampling
        p = kernels[labels, toks[:, t]]  # (n, vocab)
        cdf = np.cumsum(p, axis=1)
        u = rng.random((num_sequences, 1))
        toks[:, t + 1] = (u < cdf).argmax(axis=1)
    return TokenCorpus(tokens=toks, labels=labels)


def embedding_corpus(
    rng: np.random.Generator,
    *,
    num_sequences: int = 512,
    seq_len: int = 64,
    d_model: int = 64,
    num_classes: int = 10,
) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Stub-frontend corpus for [vlm]/[audio] archs: precomputed frame/patch
    embeddings (float) + integer targets. Returns (embeds, targets, labels)."""
    centers = rng.normal(0, 1, size=(num_classes, d_model))
    labels = rng.integers(0, num_classes, size=num_sequences).astype(np.int32)
    embeds = centers[labels][:, None, :] + 0.3 * rng.normal(
        0, 1, size=(num_sequences, seq_len, d_model)
    )
    targets = rng.integers(0, num_classes * 8, size=(num_sequences, seq_len)).astype(np.int32)
    return embeds.astype(np.float32), targets, labels
