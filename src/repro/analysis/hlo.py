"""HLO-text analyzer: FLOPs, HBM traffic and collective bytes.

Why not ``compiled.cost_analysis()``: on the CPU backend it counts a
``while`` (scan) body ONCE — for a 61-layer scanned model it undercounts
FLOPs by ~num_layers×. This parser walks the HLO computations, resolves
the call graph (calls / to_apply / body / condition / fusion), multiplies
everything inside a while body by its statically-parsed trip count, and
accumulates:

  * dot/convolution FLOPs (2 × output_numel × contracted size),
  * per-op HBM traffic (operand+result bytes of top-level non-bookkeeping
    ops — a fusion counts once at its boundary),
  * collective traffic per op kind, with replica-group reconstruction from
    the iota format ``[G,S]<=[dims]T(perm)`` so each collective can be
    attributed to mesh axes (model/data ICI vs pod DCN).

Trip counts come from the while condition's ``compare(..., constant(K)),
direction=LT`` pattern (what lax.scan emits); a failed parse records the
while in ``unresolved_whiles`` and multiplies by 1 — tests assert the
dry-run cells parse with zero unresolved whiles.
"""
from __future__ import annotations

import dataclasses
import re
from collections import defaultdict
from typing import Dict, List, Optional, Tuple

import numpy as np

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2,
    "s32": 4, "u32": 4, "s64": 8, "u64": 8,
    "f8e4m3fn": 1, "f8e5m2": 1, "bf16": 2, "f16": 2, "f32": 4, "f64": 8,
    "c64": 8, "c128": 16, "token": 0, "opaque": 0,
}

_COLLECTIVES = (
    "all-reduce-start", "all-gather-start", "reduce-scatter", "all-to-all",
    "collective-permute-start", "all-reduce", "all-gather", "collective-permute",
)

_BOOKKEEPING = {
    "parameter", "constant", "tuple", "get-tuple-element", "bitcast",
    "copy-start", "copy-done", "after-all", "partition-id",
    "replica-id", "iota", "while", "conditional", "call", "custom-call",
    "opt-barrier",
}

# ops that only *touch* part of their operands: traffic = bytes moved, not
# the full operand (a dynamic-slice of a 13 GB stacked-param array inside a
# scan body reads one layer's slice, not the whole array)
_SLICING = {"dynamic-slice", "slice", "gather"}
_UPDATING = {"dynamic-update-slice", "scatter"}
_OUTPUT_ONLY = {"broadcast", "pad", "reverse", "rng", "rng-bit-generator"}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")


def _shape_bytes(type_str: str) -> float:
    """Total bytes of a (possibly tuple) HLO type string."""
    total = 0.0
    for dtype, dims in _SHAPE_RE.findall(type_str):
        if dtype not in _DTYPE_BYTES:
            continue
        numel = 1
        if dims:
            for d in dims.split(","):
                numel *= int(d)
        total += numel * _DTYPE_BYTES[dtype]
    return total


def _first_shape(type_str: str) -> Tuple[str, List[int]]:
    m = _SHAPE_RE.search(type_str)
    if not m:
        return "", []
    dtype, dims = m.groups()
    return dtype, [int(d) for d in dims.split(",")] if dims else []


@dataclasses.dataclass
class OpInfo:
    name: str
    opcode: str
    type_str: str
    operands: List[str]
    attrs: str
    line: str
    is_root: bool = False


@dataclasses.dataclass
class Computation:
    name: str
    ops: Dict[str, OpInfo] = dataclasses.field(default_factory=dict)
    order: List[str] = dataclasses.field(default_factory=list)
    is_entry: bool = False


_COMP_HEADER = re.compile(r"^(ENTRY\s+)?%?([\w\.\-_]+)\s*\(.*\)\s*->\s*.*\{\s*$")
_OP_LINE = re.compile(
    r"^\s*(?:ROOT\s+)?%([\w\.\-_]+)\s*=\s*((?:\([^()]*\)|[\w\[\]\{\},\d\s:]+?))\s+([\w\-]+)\((.*)$"
)
_OPERAND_RE = re.compile(r"%([\w\.\-_]+)")


def parse_hlo(text: str) -> Dict[str, Computation]:
    comps: Dict[str, Computation] = {}
    cur: Optional[Computation] = None
    for raw in text.splitlines():
        line = raw.rstrip()
        if not line:
            continue
        m = _COMP_HEADER.match(line.strip())
        if m and not line.startswith(" "):
            cur = Computation(name=m.group(2), is_entry=bool(m.group(1)))
            comps[cur.name] = cur
            continue
        if cur is None:
            continue
        if line.strip() == "}":
            cur = None
            continue
        om = _OP_LINE.match(line)
        if om:
            name, type_str, opcode, rest = om.groups()
            # operands = %refs before any attribute keyword in rest's first paren group
            depth = 1
            end = 0
            for i, ch in enumerate(rest):
                if ch == "(":
                    depth += 1
                elif ch == ")":
                    depth -= 1
                    if depth == 0:
                        end = i
                        break
            operand_str = rest[:end]
            attrs = rest[end + 1 :]
            ops = _OPERAND_RE.findall(operand_str)
            info = OpInfo(
                name=name, opcode=opcode, type_str=type_str.strip(), operands=ops,
                attrs=attrs, line=line, is_root=line.lstrip().startswith("ROOT "),
            )
            cur.ops[name] = info
            cur.order.append(name)
    return comps


# ---------------------------------------------------------------------------
# Trip counts
# ---------------------------------------------------------------------------

_CONST_RE = re.compile(r"constant\((-?\d+)\)")
_DIRECTION_RE = re.compile(r"direction=(\w+)")


def while_trip_count(cond: Computation, comps: Optional[Dict[str, "Computation"]] = None) -> Optional[int]:
    """Parse scan-style conditions: counter < constant (LT) or LE.

    Handles the compare being wrapped in a kLoop fusion (the CPU backend's
    ``wrapped_compare`` pattern): the direction comes from the fused
    computation, the bound from the condition computation's constant.
    """
    consts: Dict[str, int] = {}
    for op in cond.ops.values():
        if op.opcode == "constant":
            m = _CONST_RE.search(op.line)
            if m:
                consts[op.name] = int(m.group(1))

    def direction_of(comp: Computation) -> Optional[str]:
        for op in comp.ops.values():
            if op.opcode == "compare":
                d = _DIRECTION_RE.search(op.attrs or op.line)
                if d:
                    return d.group(1)
        return None

    def finish(direction: str, bound: int) -> Optional[int]:
        if direction == "LT":
            return max(bound, 0)
        if direction == "LE":
            return max(bound + 1, 0)
        if direction in ("GT", "GE"):  # reverse counters
            return max(bound, 0)
        return None

    # direct compare in the condition body
    for op in cond.ops.values():
        if op.opcode == "compare":
            d = _DIRECTION_RE.search(op.attrs or op.line)
            direction = d.group(1) if d else ""
            for o in op.operands:
                if o in consts:
                    got = finish(direction, consts[o])
                    if got is not None:
                        return got
    # compare wrapped in a fusion: bound = fusion operand constant
    if comps is not None:
        for op in cond.ops.values():
            if op.opcode == "fusion":
                m = re.search(r"calls=%([\w\.\-_]+)", op.attrs)
                if not m or m.group(1) not in comps:
                    continue
                direction = direction_of(comps[m.group(1)])
                if direction is None:
                    continue
                for o in op.operands:
                    if o in consts:
                        got = finish(direction, consts[o])
                        if got is not None:
                            return got
    # last resort: single s32 constant in a tiny condition ⇒ scan bound (LT)
    if len(consts) == 1 and len(cond.ops) <= 8:
        return max(next(iter(consts.values())), 0)
    return None


# ---------------------------------------------------------------------------
# FLOPs / bytes per op
# ---------------------------------------------------------------------------

_CONTRACT_RE = re.compile(r"lhs_contracting_dims=\{([\d,]*)\}")


def _dot_flops(op: OpInfo, shapes: Dict[str, str]) -> float:
    _, out_dims = _first_shape(op.type_str)
    out_numel = float(np.prod(out_dims)) if out_dims else 1.0
    lhs = op.operands[0] if op.operands else None
    contract = 1.0
    m = _CONTRACT_RE.search(op.attrs)
    if m and lhs and lhs in shapes:
        _, lhs_dims = _first_shape(shapes[lhs])
        idxs = [int(i) for i in m.group(1).split(",") if i]
        for i in idxs:
            if i < len(lhs_dims):
                contract *= lhs_dims[i]
    return 2.0 * out_numel * contract


_WINDOW_SIZE_RE = re.compile(r"size=([\dx]+)")


def _conv_flops(op: OpInfo, shapes: Dict[str, str]) -> float:
    _, out_dims = _first_shape(op.type_str)
    out_numel = float(np.prod(out_dims)) if out_dims else 1.0
    # kernel operand: spatial dims × input channels
    if len(op.operands) >= 2 and op.operands[1] in shapes:
        _, k_dims = _first_shape(shapes[op.operands[1]])
        k_numel = float(np.prod(k_dims)) if k_dims else 1.0
        # kernel numel = kh*kw*cin*cout; flops = 2*out_numel*kh*kw*cin
        _, o_dims = _first_shape(op.type_str)
        cout = o_dims[-1] if o_dims else 1
        # try to divide out cout (layout-dependent; conservative fallback)
        per_out = k_numel / max(cout, 1)
        return 2.0 * out_numel * per_out
    return 2.0 * out_numel


_PARAM_IDX_RE = re.compile(r"parameter\((\d+)\)")
_CALLS_RE = re.compile(r"calls=%([\w\.\-_]+)")


def _fusion_traffic(op: OpInfo, shapes: Dict[str, str], comps: Dict[str, "Computation"]) -> float:
    """HBM traffic of a fusion at its boundary, accounting for partial reads.

    A fused computation that only *slices* an operand (dynamic-slice /
    gather of one layer from the stacked-parameter array inside a scan
    body) reads the slice, not the operand; a fused in-place
    dynamic-update-slice writes the update region, not the whole array.
    """
    m = _CALLS_RE.search(op.attrs)
    callee = comps.get(m.group(1)) if m else None
    out_bytes = _shape_bytes(op.type_str)
    if callee is None:
        return out_bytes + sum(_shape_bytes(shapes.get(o, "")) for o in op.operands)

    # parameter index -> op name in the callee
    param_of_idx: Dict[int, str] = {}
    for p in callee.ops.values():
        if p.opcode == "parameter":
            pm = _PARAM_IDX_RE.search(p.line)
            if pm:
                param_of_idx[int(pm.group(1))] = p.name

    callee_shapes = {o.name: o.type_str for o in callee.ops.values()}
    root = next((o for o in callee.ops.values() if o.is_root), None)

    # in-place DUS pattern: a single DUS in the callee whose full-array
    # operand is a parameter and whose result reaches the root (possibly
    # through converts/bitcasts) — common as "dynamic-update-slice_convert"
    # fusions in scan bodies. Traffic = the update region, not the buffer.
    def _numel(ts: str) -> float:
        n = 0
        for _, dims in _SHAPE_RE.findall(ts):
            k = 1
            for d in dims.split(","):
                if d:
                    k *= int(d)
            n += k
        return n

    dus_ops = [o for o in callee.ops.values() if o.opcode == "dynamic-update-slice"]
    dus_inplace = None
    if (
        len(dus_ops) == 1
        and root is not None
        # numel (not bytes): "...convert" fusions change dtype after the DUS
        and _numel(root.type_str) == _numel(dus_ops[0].type_str)
    ):
        dus_inplace = dus_ops[0]

    total = 0.0
    passthrough: set = set()
    if dus_inplace is not None and len(dus_inplace.operands) > 1:
        total += 2.0 * _shape_bytes(callee_shapes.get(dus_inplace.operands[1], ""))
        # follow the buffer operand back through dtype/layout no-ops
        frontier = [dus_inplace.operands[0]]
        while frontier:
            nm = frontier.pop()
            if nm in passthrough:
                continue
            passthrough.add(nm)
            src = callee.ops.get(nm)
            if src is not None and src.opcode in ("convert", "bitcast", "copy", "reshape"):
                frontier.extend(src.operands)
    else:
        total += out_bytes

    for i, operand in enumerate(op.operands):
        pname = param_of_idx.get(i)
        full = _shape_bytes(shapes.get(operand, ""))
        if pname is None:
            total += full
            continue
        consumers = [o for o in callee.ops.values() if pname in o.operands]
        if not consumers:
            continue  # unused operand
        if pname in passthrough:
            continue  # in-place array pass-through
        if all(c.opcode in _SLICING for c in consumers):
            total += sum(
                min(_shape_bytes(c.type_str), full) for c in consumers
            )
        else:
            total += full
    return total


# ---------------------------------------------------------------------------
# Replica-group reconstruction + axis attribution
# ---------------------------------------------------------------------------

_RG_IOTA = re.compile(r"replica_groups=\[(\d+),(\d+)\]<=\[([\d,]+)\](?:T\(([\d,]+)\))?")
_RG_EXPLICIT = re.compile(r"replica_groups=\{(\{[\d,\{\}\s]*\})\}")


def parse_replica_groups(attrs: str) -> Optional[np.ndarray]:
    """Returns (G, S) array of device ids, or None."""
    m = _RG_IOTA.search(attrs)
    if m:
        g, s = int(m.group(1)), int(m.group(2))
        dims = [int(d) for d in m.group(3).split(",")]
        arr = np.arange(int(np.prod(dims))).reshape(dims)
        if m.group(4):
            perm = [int(p) for p in m.group(4).split(",")]
            arr = arr.transpose(perm)
        return arr.reshape(g, s)
    m = _RG_EXPLICIT.search(attrs)
    if m:
        groups = []
        for grp in re.findall(r"\{([\d,\s]+)\}", m.group(1)):
            groups.append([int(x) for x in grp.replace(" ", "").split(",") if x])
        if groups and all(len(g) == len(groups[0]) for g in groups):
            return np.asarray(groups)
    return None


def classify_groups(groups: Optional[np.ndarray], mesh_axes: Dict[str, np.ndarray]) -> str:
    """Which mesh axes vary within a group: 'model' / 'data' / 'pod' /
    comma-joined for multi-axis / 'unknown'."""
    if groups is None:
        return "unknown"
    varying = []
    for axis, coords in mesh_axes.items():
        per_dev = coords[groups]  # (G, S)
        if np.any(per_dev != per_dev[:, :1]):
            varying.append(axis)
    return ",".join(varying) if varying else "self"


def mesh_axis_coords(mesh) -> Dict[str, np.ndarray]:
    """device_id -> coordinate per axis, for the classify step."""
    devs = mesh.devices
    ids = np.vectorize(lambda d: d.id)(devs)
    out = {}
    n = ids.max() + 1
    for i, axis in enumerate(mesh.axis_names):
        coord = np.zeros(n, np.int64)
        idx = np.indices(devs.shape)[i]
        coord[ids.reshape(-1)] = idx.reshape(-1)
        out[axis] = coord
    return out


# ---------------------------------------------------------------------------
# Whole-module analysis
# ---------------------------------------------------------------------------

def _feeds_bf16_convert(op: OpInfo, comp: Computation) -> bool:
    """True if the collective's f32 result is immediately converted to bf16
    (directly or through get-tuple-element) — the CPU backend's
    convert-dot-convert legalization of bf16 matmuls. The TPU target would
    run this collective with a bf16 payload."""
    frontier = {op.name}
    for _ in range(2):  # collective -> (gte) -> convert
        next_frontier = set()
        for o in comp.ops.values():
            if not any(f in o.operands for f in frontier):
                continue
            if o.opcode == "get-tuple-element":
                next_frontier.add(o.name)
            elif o.opcode == "convert" and o.type_str.startswith("bf16"):
                return True
            elif o.opcode == "fusion" and "convert" in o.name and "bf16" in o.type_str:
                return True
        if not next_frontier:
            return False
        frontier = next_frontier
    return False


_CALL_ATTR = re.compile(r"(?:calls|to_apply|body|condition)=%([\w\.\-_]+)")
_BRANCH_ATTR = re.compile(
    r"(?:true_computation|false_computation)=%([\w\.\-_]+)|branch_computations=\{([^}]*)\}"
)


def _branch_callees(attrs: str) -> List[str]:
    out: List[str] = []
    for m in _BRANCH_ATTR.finditer(attrs):
        if m.group(1):
            out.append(m.group(1))
        elif m.group(2):
            out.extend(re.findall(r"%([\w\.\-_]+)", m.group(2)))
    return out


@dataclasses.dataclass
class CollectiveRecord:
    opcode: str
    bytes: float  # payload bytes of the (tuple) result, ONE execution
    group_size: int
    axes: str  # mesh-axis classification
    count: float  # executions incl. while multipliers
    # The CPU backend legalizes bf16 dots to f32 (convert-dot-convert), so
    # TP all-reduces of bf16 matmul partials appear with f32 payloads. When
    # the result is immediately converted (back) to bf16 we count half the
    # bytes — what the TPU target would move. Documented in EXPERIMENTS.md.
    bf16_promoted: bool = False

    @property
    def effective_bytes(self) -> float:
        return self.bytes * (0.5 if self.bf16_promoted else 1.0)

    @property
    def traffic_per_device(self) -> float:
        """Link traffic per participating device per execution (ring model)."""
        s = max(self.group_size, 1)
        if self.opcode.startswith("all-reduce"):
            return 2.0 * (s - 1) / s * self.effective_bytes
        if self.opcode.startswith("all-gather"):
            return (s - 1) / s * self.effective_bytes
        if self.opcode.startswith("reduce-scatter"):
            return (s - 1) / s * self.effective_bytes
        if self.opcode.startswith("all-to-all"):
            return (s - 1) / s * self.effective_bytes
        if self.opcode.startswith("collective-permute"):
            return self.effective_bytes
        return self.effective_bytes


@dataclasses.dataclass
class HloSummary:
    flops: float
    hbm_bytes: float
    collectives: List[CollectiveRecord]
    unresolved_whiles: int
    per_comp_flops: Dict[str, float]

    def collective_bytes_per_device(self, axes_filter: Optional[Tuple[str, ...]] = None) -> float:
        total = 0.0
        for c in self.collectives:
            if axes_filter is not None and not any(a in c.axes for a in axes_filter):
                continue
            total += c.traffic_per_device * c.count
        return total

    def collective_breakdown(self) -> Dict[str, float]:
        out: Dict[str, float] = defaultdict(float)
        for c in self.collectives:
            out[c.axes] += c.traffic_per_device * c.count
        return dict(out)


def analyze(text: str, mesh=None, *, conditional_weight: float = 1.0) -> HloSummary:
    """conditional_weight: multiplier for work inside `conditional` branches
    (lax.cond). 1.0 counts every branch fully (upper bound); 0.0 excludes
    them — used by the roofline to isolate the local-step cost of the fused
    HierFAVG train step from its aggregation branches, which are accounted
    separately (amortized by κ₁ / κ₁κ₂) via the phase cells."""
    comps = parse_hlo(text)
    mesh_axes = mesh_axis_coords(mesh) if mesh is not None else {}

    # shapes per computation (operand lookup is computation-local)
    entry = None
    for c in comps.values():
        if c.is_entry:
            entry = c
    if entry is None:  # fall back: computation named like main
        entry = max(comps.values(), key=lambda c: len(c.ops))

    # Pass 1: local (single-execution) stats per computation
    local_flops: Dict[str, float] = {}
    local_bytes: Dict[str, float] = {}
    local_colls: Dict[str, List[CollectiveRecord]] = {}
    callees: Dict[str, List[Tuple[str, str]]] = {}  # comp -> [(callee, via_opcode)]
    unresolved = 0

    for cname, comp in comps.items():
        shapes = {op.name: op.type_str for op in comp.ops.values()}
        fl = 0.0
        by = 0.0
        colls: List[CollectiveRecord] = []
        calls: List[Tuple[str, str]] = []
        for op in comp.ops.values():
            if op.opcode == "dot":
                fl += _dot_flops(op, shapes)
            elif op.opcode == "convolution":
                fl += _conv_flops(op, shapes)
            if op.opcode not in _BOOKKEEPING:
                out_bytes = _shape_bytes(op.type_str)
                if op.opcode == "fusion":
                    by += _fusion_traffic(op, shapes, comps)
                elif op.opcode in _SLICING or op.opcode in _OUTPUT_ONLY:
                    by += 2.0 * out_bytes  # read the region + write the result
                elif op.opcode in _UPDATING:
                    upd = (
                        _shape_bytes(shapes.get(op.operands[1], ""))
                        if len(op.operands) > 1
                        else out_bytes
                    )
                    by += 2.0 * upd  # in-place: write region + read update
                else:
                    opnd_bytes = sum(_shape_bytes(shapes.get(o, "")) for o in op.operands)
                    by += opnd_bytes + out_bytes
            if op.opcode in _COLLECTIVES and not op.opcode.endswith("-done"):
                groups = parse_replica_groups(op.attrs)
                gsize = int(groups.shape[1]) if groups is not None else 1
                axes = classify_groups(groups, mesh_axes) if mesh_axes else "unknown"
                payload = _shape_bytes(op.type_str)
                promoted = "f32" in op.type_str and _feeds_bf16_convert(op, comp)
                colls.append(
                    CollectiveRecord(op.opcode, payload, gsize, axes, 1.0, bf16_promoted=promoted)
                )
            for callee in _CALL_ATTR.findall(op.attrs):
                calls.append((callee, op.opcode))
        local_flops[cname] = fl
        local_bytes[cname] = by
        local_colls[cname] = colls
        callees[cname] = calls

    # Pass 2: roll up with while multipliers (memoized DFS)
    total_flops: Dict[str, float] = {}
    total_bytes: Dict[str, float] = {}
    total_colls: Dict[str, List[CollectiveRecord]] = {}
    visiting = set()

    def resolve(cname: str) -> Tuple[float, float, List[CollectiveRecord]]:
        nonlocal unresolved
        if cname in total_flops:
            return total_flops[cname], total_bytes[cname], total_colls[cname]
        if cname in visiting or cname not in comps:
            return 0.0, 0.0, []
        visiting.add(cname)
        fl = local_flops[cname]
        by = local_bytes[cname]
        cl = list(local_colls[cname])
        comp = comps[cname]
        for op in comp.ops.values():
            if op.opcode == "while":
                bm = re.search(r"body=%([\w\.\-_]+)", op.attrs)
                cm = re.search(r"condition=%([\w\.\-_]+)", op.attrs)
                body = bm.group(1) if bm else None
                cond = cm.group(1) if cm else None
                trips = None
                if cond and cond in comps:
                    trips = while_trip_count(comps[cond], comps)
                if trips is None:
                    trips = 1
                    unresolved += 1
                if body:
                    bfl, bby, bcl = resolve(body)
                    fl += trips * bfl
                    by += trips * bby
                    for c in bcl:
                        cl.append(dataclasses.replace(c, count=c.count * trips))
            elif op.opcode == "conditional":
                for callee in _branch_callees(op.attrs):
                    cfl, cby, ccl = resolve(callee)
                    fl += conditional_weight * cfl
                    by += conditional_weight * cby
                    if conditional_weight > 0:
                        for c in ccl:
                            cl.append(dataclasses.replace(c, count=c.count * conditional_weight))
            else:
                for m in _CALL_ATTR.finditer(op.attrs):
                    kind = m.group(0).split("=")[0]
                    if kind in ("body", "condition"):
                        continue
                    cfl, cby, ccl = resolve(m.group(1))
                    fl += cfl
                    # fusion boundary traffic already counted at the fusion
                    # op itself; inner ops of a fusion don't touch HBM
                    if op.opcode != "fusion":
                        by += cby
                        cl.extend(ccl)
                    else:
                        cl.extend(ccl)  # collectives can't fuse; keep safe
        visiting.discard(cname)
        total_flops[cname] = fl
        total_bytes[cname] = by
        total_colls[cname] = cl
        return fl, by, cl

    fl, by, cl = resolve(entry.name)
    return HloSummary(
        flops=fl,
        hbm_bytes=by,
        collectives=cl,
        unresolved_whiles=unresolved,
        per_comp_flops=total_flops,
    )
