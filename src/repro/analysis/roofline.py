"""Three-term roofline model (TPU v5e constants, per instructions).

    compute term    = FLOPs / (chips × 197 TFLOP/s)
    memory term     = HBM bytes / (chips × 819 GB/s)
    collective term = collective bytes / (chips × 50 GB/s per link)

All inputs come from the *partitioned* HLO module (compiled.as_text()), so
parsed quantities are already per-device; terms divide by per-chip peaks
directly and global numbers are reported as per_device × chips.

HierFAVG-specific accounting: the paper's contribution is *amortization* of
the two aggregation hops. ``hierfavg_step_terms`` combines the local-step
cell with the edge/cloud phase cells as

    per-step collective = local + edge/κ₁ + cloud/(κ₁·κ₂)

with the cloud hop's bytes optionally scaled by the DCN slowdown (the
paper's 10× edge→cloud latency assumption, Section IV-A) to express DCN
seconds in ICI-equivalent terms.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional

from repro.analysis.hlo import HloSummary

PEAK_FLOPS = 197e12  # bf16 / chip
HBM_BW = 819e9  # bytes/s / chip
ICI_BW = 50e9  # bytes/s / link
DCN_SLOWDOWN = 10.0  # paper's cloud:edge latency ratio, reused for pod axis


@dataclasses.dataclass
class RooflineTerms:
    name: str
    chips: int
    flops_per_device: float
    hbm_bytes_per_device: float
    coll_bytes_per_device: float
    coll_breakdown: Dict[str, float]  # mesh-axis class -> bytes/device
    model_flops_global: float = 0.0

    @property
    def compute_s(self) -> float:
        return self.flops_per_device / PEAK_FLOPS

    @property
    def memory_s(self) -> float:
        return self.hbm_bytes_per_device / HBM_BW

    @property
    def collective_s(self) -> float:
        """ICI bytes at ICI speed + pod-axis (DCN) bytes at DCN speed."""
        dcn = sum(v for k, v in self.coll_breakdown.items() if "pod" in k)
        ici = self.coll_bytes_per_device - dcn
        return ici / ICI_BW + dcn * DCN_SLOWDOWN / ICI_BW

    @property
    def dominant(self) -> str:
        terms = {
            "compute": self.compute_s,
            "memory": self.memory_s,
            "collective": self.collective_s,
        }
        return max(terms, key=terms.get)

    @property
    def bound_s(self) -> float:
        return max(self.compute_s, self.memory_s, self.collective_s)

    @property
    def useful_flops_ratio(self) -> float:
        total = self.flops_per_device * self.chips
        return self.model_flops_global / total if total > 0 else 0.0

    @property
    def roofline_fraction(self) -> float:
        """Fraction of the compute roofline achieved if the step runs at the
        max-term speed: (useful compute time) / (bound time)."""
        if self.bound_s <= 0:
            return 0.0
        useful_s = self.model_flops_global / (self.chips * PEAK_FLOPS)
        return useful_s / self.bound_s

    def to_dict(self) -> Dict:
        return {
            "name": self.name,
            "chips": self.chips,
            "flops_per_device": self.flops_per_device,
            "hbm_bytes_per_device": self.hbm_bytes_per_device,
            "coll_bytes_per_device": self.coll_bytes_per_device,
            "coll_breakdown": self.coll_breakdown,
            "model_flops_global": self.model_flops_global,
            "compute_s": self.compute_s,
            "memory_s": self.memory_s,
            "collective_s": self.collective_s,
            "dominant": self.dominant,
            "useful_flops_ratio": self.useful_flops_ratio,
            "roofline_fraction": self.roofline_fraction,
        }


def from_summary(
    name: str, summary: HloSummary, chips: int, *, model_flops_global: float = 0.0
) -> RooflineTerms:
    return RooflineTerms(
        name=name,
        chips=chips,
        flops_per_device=summary.flops,
        hbm_bytes_per_device=summary.hbm_bytes,
        coll_bytes_per_device=summary.collective_bytes_per_device(),
        coll_breakdown=summary.collective_breakdown(),
        model_flops_global=model_flops_global,
    )


def hierfavg_step_terms(
    name: str,
    local: RooflineTerms,
    edge: Optional[RooflineTerms],
    cloud: Optional[RooflineTerms],
    kappa1: int,
    kappa2: int,
) -> RooflineTerms:
    """Amortized per-local-step terms — the paper's protocol in roofline form."""
    def scaled(t: Optional[RooflineTerms], f: float):
        if t is None:
            return 0.0, 0.0, 0.0, {}
        bd = {k: v * f for k, v in t.coll_breakdown.items()}
        return t.flops_per_device * f, t.hbm_bytes_per_device * f, t.coll_bytes_per_device * f, bd

    ef, eb, ec, ebd = scaled(edge, 1.0 / kappa1)
    cf, cb, cc, cbd = scaled(cloud, 1.0 / (kappa1 * kappa2))
    breakdown = dict(local.coll_breakdown)
    for d in (ebd, cbd):
        for k, v in d.items():
            breakdown[k] = breakdown.get(k, 0.0) + v
    return RooflineTerms(
        name=name,
        chips=local.chips,
        flops_per_device=local.flops_per_device + ef + cf,
        hbm_bytes_per_device=local.hbm_bytes_per_device + eb + cb,
        coll_bytes_per_device=local.coll_bytes_per_device + ec + cc,
        coll_breakdown=breakdown,
        model_flops_global=local.model_flops_global,
    )


# ---------------------------------------------------------------------------
# Empirical calibration against the edge-interval megakernel
# ---------------------------------------------------------------------------
#
# The analytic model above prices steps from HLO text with *datasheet* peaks.
# ``calibrate_megakernel`` closes the loop on a live host: it times the
# megakernel's math at one bench shape, measures the host's own peaks with
# micro-probes (a timed matmul and a timed streaming copy), and reports
# achieved-vs-peak fractions. On CPU hosts the compiled jnp oracle
# (``kernels.ref.edge_interval_ref``) carries the timing — interpret-mode
# Pallas is an emulator, not an executor — while ``path="pallas"`` exists for
# real accelerator runs.


@dataclasses.dataclass
class CalibrationResult:
    name: str
    elapsed_s: float
    flops: float  # analytic work of one fused edge interval
    bytes_moved: float  # analytic minimal HBM traffic of the fused design
    peak_flops: float  # measured host peak (FLOP/s)
    peak_bw: float  # measured host peak (B/s)

    @property
    def achieved_flops(self) -> float:
        return self.flops / self.elapsed_s

    @property
    def achieved_bw(self) -> float:
        return self.bytes_moved / self.elapsed_s

    @property
    def flops_fraction(self) -> float:
        return self.achieved_flops / self.peak_flops

    @property
    def bw_fraction(self) -> float:
        return self.achieved_bw / self.peak_bw

    def to_dict(self) -> Dict:
        return {
            "name": self.name,
            "elapsed_s": self.elapsed_s,
            "flops": self.flops,
            "bytes_moved": self.bytes_moved,
            "peak_flops": self.peak_flops,
            "peak_bw": self.peak_bw,
            "achieved_flops": self.achieved_flops,
            "achieved_bw": self.achieved_bw,
            "flops_fraction": self.flops_fraction,
            "bw_fraction": self.bw_fraction,
        }


def measure_host_peaks(*, n: int = 1024, reps: int = 5) -> Dict[str, float]:
    """Micro-probe the host: best-of-reps f32 matmul (FLOP/s) and streaming
    add (read+write B/s) on the default backend."""
    import time

    import jax
    import jax.numpy as jnp

    a = jnp.ones((n, n), jnp.float32)
    mm = jax.jit(lambda x: x @ x)
    mm(a).block_until_ready()
    best = float("inf")
    for _ in range(reps):
        t0 = time.perf_counter()
        mm(a).block_until_ready()
        best = min(best, time.perf_counter() - t0)
    peak_flops = 2.0 * n**3 / best

    big = jnp.ones((n * n * 8,), jnp.float32)
    add = jax.jit(lambda x: x + 1.0)
    add(big).block_until_ready()
    best_c = float("inf")
    for _ in range(reps):
        t0 = time.perf_counter()
        add(big).block_until_ready()
        best_c = min(best_c, time.perf_counter() - t0)
    peak_bw = 2.0 * big.nbytes / best_c
    return {"flops": peak_flops, "bw": peak_bw}


def megakernel_interval_cost(
    *, num_clients: int, kappa1: int, batch: int, feat: int, out: int, dtype_bytes: int = 4
) -> Dict[str, float]:
    """Analytic work/traffic of one fused edge interval (all edges).

    FLOPs per client per step: forward + backward matmuls (2·2·b·f·o) plus
    the momentum/param elementwise updates (~4·P with P = f·o); the trailing
    edge mean adds ~2·P per client. Minimal traffic is the megakernel's
    design point: params and momentum cross HBM once in, once out, per
    client per *interval* (not per step), batches stream in once.
    """
    p = feat * out
    per_step = 4.0 * batch * feat * out + 4.0 * p
    flops = num_clients * (kappa1 * per_step + 2.0 * p)
    bytes_moved = float(dtype_bytes) * num_clients * (
        4.0 * p + kappa1 * batch * (feat + out)
    )
    return {"flops": flops, "bytes": bytes_moved}


def calibrate_megakernel(
    *,
    num_edges: int = 2,
    clients_per_edge: int = 4,
    kappa1: int = 4,
    batch: int = 2,
    feat: int = 64,
    out: int = 128,
    reps: int = 5,
    path: str = "ref",
    peaks: Optional[Dict[str, float]] = None,
) -> CalibrationResult:
    """Time one fused edge interval and report achieved-vs-peak fractions.

    ``path="ref"`` times the compiled jnp oracle (kernel-equivalent math;
    the honest figure on CPU hosts); ``path="pallas"`` times the Pallas
    kernel itself (use on real accelerators — under interpret mode its
    wall-time measures the emulator, not the kernel).
    """
    import functools
    import time

    import jax
    import jax.numpy as jnp
    import numpy as np

    from repro.kernels import ops as _ops
    from repro.kernels import ref as _ref

    rng = np.random.default_rng(0)
    n = num_edges * clients_per_edge
    p = feat * out
    params = jnp.asarray(rng.normal(size=(n, p)) * 0.05, jnp.float32)
    xs = jnp.asarray(rng.normal(size=(n, kappa1, batch, feat)), jnp.float32)
    ys = jnp.asarray(rng.normal(size=(n, kappa1, batch, out)), jnp.float32)
    ws = jnp.asarray(rng.uniform(1, 2, size=(n,)), jnp.float32)

    if path == "ref":
        fn = jax.jit(functools.partial(
            _ref.edge_interval_ref, num_edges=num_edges, feat=feat, lr=0.05))
        run = lambda: fn(params, xs, ys, ws)
    elif path == "pallas":
        run = lambda: _ops.edge_interval(
            params, xs, ys, ws, num_edges=num_edges, feat=feat, lr=0.05)
    else:
        raise ValueError(f"path must be ref|pallas, got {path!r}")

    jax.block_until_ready(run())  # compile / warm
    best = float("inf")
    for _ in range(reps):
        t0 = time.perf_counter()
        jax.block_until_ready(run())
        best = min(best, time.perf_counter() - t0)

    cost = megakernel_interval_cost(
        num_clients=n, kappa1=kappa1, batch=batch, feat=feat, out=out)
    pk = peaks if peaks is not None else measure_host_peaks()
    return CalibrationResult(
        name=f"edge_interval[{path}] E={num_edges} C={clients_per_edge} "
        f"k1={kappa1} b={batch} {feat}x{out}",
        elapsed_s=best,
        flops=cost["flops"],
        bytes_moved=cost["bytes"],
        peak_flops=pk["flops"],
        peak_bw=pk["bw"],
    )


def model_flops(cfg, shape, *, active: bool = True) -> float:
    """6·N·D (train) / 2·N·D (forward-only), N = (active) params, D = tokens."""
    from repro.configs.base import active_param_count, param_count

    n = active_param_count(cfg) if active else param_count(cfg)
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * n * tokens
    if shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * n * tokens
    # decode: one token per request
    return 2.0 * n * shape.global_batch
