"""Three-term roofline model (TPU v5e constants, per instructions).

    compute term    = FLOPs / (chips × 197 TFLOP/s)
    memory term     = HBM bytes / (chips × 819 GB/s)
    collective term = collective bytes / (chips × 50 GB/s per link)

All inputs come from the *partitioned* HLO module (compiled.as_text()), so
parsed quantities are already per-device; terms divide by per-chip peaks
directly and global numbers are reported as per_device × chips.

HierFAVG-specific accounting: the paper's contribution is *amortization* of
the two aggregation hops. ``hierfavg_step_terms`` combines the local-step
cell with the edge/cloud phase cells as

    per-step collective = local + edge/κ₁ + cloud/(κ₁·κ₂)

with the cloud hop's bytes optionally scaled by the DCN slowdown (the
paper's 10× edge→cloud latency assumption, Section IV-A) to express DCN
seconds in ICI-equivalent terms.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional

from repro.analysis.hlo import HloSummary

PEAK_FLOPS = 197e12  # bf16 / chip
HBM_BW = 819e9  # bytes/s / chip
ICI_BW = 50e9  # bytes/s / link
DCN_SLOWDOWN = 10.0  # paper's cloud:edge latency ratio, reused for pod axis


@dataclasses.dataclass
class RooflineTerms:
    name: str
    chips: int
    flops_per_device: float
    hbm_bytes_per_device: float
    coll_bytes_per_device: float
    coll_breakdown: Dict[str, float]  # mesh-axis class -> bytes/device
    model_flops_global: float = 0.0

    @property
    def compute_s(self) -> float:
        return self.flops_per_device / PEAK_FLOPS

    @property
    def memory_s(self) -> float:
        return self.hbm_bytes_per_device / HBM_BW

    @property
    def collective_s(self) -> float:
        """ICI bytes at ICI speed + pod-axis (DCN) bytes at DCN speed."""
        dcn = sum(v for k, v in self.coll_breakdown.items() if "pod" in k)
        ici = self.coll_bytes_per_device - dcn
        return ici / ICI_BW + dcn * DCN_SLOWDOWN / ICI_BW

    @property
    def dominant(self) -> str:
        terms = {
            "compute": self.compute_s,
            "memory": self.memory_s,
            "collective": self.collective_s,
        }
        return max(terms, key=terms.get)

    @property
    def bound_s(self) -> float:
        return max(self.compute_s, self.memory_s, self.collective_s)

    @property
    def useful_flops_ratio(self) -> float:
        total = self.flops_per_device * self.chips
        return self.model_flops_global / total if total > 0 else 0.0

    @property
    def roofline_fraction(self) -> float:
        """Fraction of the compute roofline achieved if the step runs at the
        max-term speed: (useful compute time) / (bound time)."""
        if self.bound_s <= 0:
            return 0.0
        useful_s = self.model_flops_global / (self.chips * PEAK_FLOPS)
        return useful_s / self.bound_s

    def to_dict(self) -> Dict:
        return {
            "name": self.name,
            "chips": self.chips,
            "flops_per_device": self.flops_per_device,
            "hbm_bytes_per_device": self.hbm_bytes_per_device,
            "coll_bytes_per_device": self.coll_bytes_per_device,
            "coll_breakdown": self.coll_breakdown,
            "model_flops_global": self.model_flops_global,
            "compute_s": self.compute_s,
            "memory_s": self.memory_s,
            "collective_s": self.collective_s,
            "dominant": self.dominant,
            "useful_flops_ratio": self.useful_flops_ratio,
            "roofline_fraction": self.roofline_fraction,
        }


def from_summary(
    name: str, summary: HloSummary, chips: int, *, model_flops_global: float = 0.0
) -> RooflineTerms:
    return RooflineTerms(
        name=name,
        chips=chips,
        flops_per_device=summary.flops,
        hbm_bytes_per_device=summary.hbm_bytes,
        coll_bytes_per_device=summary.collective_bytes_per_device(),
        coll_breakdown=summary.collective_breakdown(),
        model_flops_global=model_flops_global,
    )


def hierfavg_step_terms(
    name: str,
    local: RooflineTerms,
    edge: Optional[RooflineTerms],
    cloud: Optional[RooflineTerms],
    kappa1: int,
    kappa2: int,
) -> RooflineTerms:
    """Amortized per-local-step terms — the paper's protocol in roofline form."""
    def scaled(t: Optional[RooflineTerms], f: float):
        if t is None:
            return 0.0, 0.0, 0.0, {}
        bd = {k: v * f for k, v in t.coll_breakdown.items()}
        return t.flops_per_device * f, t.hbm_bytes_per_device * f, t.coll_bytes_per_device * f, bd

    ef, eb, ec, ebd = scaled(edge, 1.0 / kappa1)
    cf, cb, cc, cbd = scaled(cloud, 1.0 / (kappa1 * kappa2))
    breakdown = dict(local.coll_breakdown)
    for d in (ebd, cbd):
        for k, v in d.items():
            breakdown[k] = breakdown.get(k, 0.0) + v
    return RooflineTerms(
        name=name,
        chips=local.chips,
        flops_per_device=local.flops_per_device + ef + cf,
        hbm_bytes_per_device=local.hbm_bytes_per_device + eb + cb,
        coll_bytes_per_device=local.coll_bytes_per_device + ec + cc,
        coll_breakdown=breakdown,
        model_flops_global=local.model_flops_global,
    )


def model_flops(cfg, shape, *, active: bool = True) -> float:
    """6·N·D (train) / 2·N·D (forward-only), N = (active) params, D = tokens."""
    from repro.configs.base import active_param_count, param_count

    n = active_param_count(cfg) if active else param_count(cfg)
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * n * tokens
    if shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * n * tokens
    # decode: one token per request
    return 2.0 * n * shape.global_batch
