"""Cloud-based vs edge-based vs client-edge-cloud FL (the paper's Fig. 1/2
story) on one synthetic problem — prints the accuracy-vs-simulated-time
frontier of each topology, plus any ragged / deeper hierarchies you ask
for.

    PYTHONPATH=src python examples/compare_topologies.py
    PYTHONPATH=src python examples/compare_topologies.py --levels 3
    PYTHONPATH=src python examples/compare_topologies.py \
        --fanout 16,12,10,7,5/3,2/2 --kappas 6,5,2

``--fanout`` is the bottom-up child-count nest of the tree (levels
separated by '/'): ``16,12,10,7,5/3,2/2`` = five edges serving 16/12/10/7/5
clients, two regions of 3 and 2 edges, one cloud. ``--kappas`` is the
matching per-level schedule (local steps per edge agg, edge aggs per
region agg, ...).
"""
import argparse
import sys

sys.path.insert(0, ".")  # allow running from repo root

from benchmarks.fig2_topologies import run_edge_only
from benchmarks.common import first_reach, run_hierarchy_schedule, run_schedule
from repro.core import parse_fanouts

# 50 clients under progressively less uniform trees (paper topology first)
DEFAULT_SWEEP = {
    2: (
        ("hierarchical (uniform 5 edges)", "10,10,10,10,10/5", (6, 10)),
        ("hierarchical (ragged 5 edges)", "16,12,10,7,5/5", (6, 10)),
    ),
    3: (
        ("3-level (uniform 2 regions)", "10,10,10,10,10/3,2/2", (6, 5, 2)),
        ("3-level (ragged 2 regions)", "16,12,10,7,5/3,2/2", (6, 5, 2)),
    ),
}


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--levels", type=int, default=0,
                    help="also sweep trees of this depth (0 = both 2 and 3)")
    ap.add_argument("--fanout", type=str, default=None,
                    help="one explicit tree instead of the sweep, e.g. 16,12,10,7,5/3,2/2")
    ap.add_argument("--kappas", type=str, default=None,
                    help="per-level schedule for --fanout, e.g. 6,5,2")
    ap.add_argument("--rounds", type=int, default=100)
    args = ap.parse_args(argv)

    sep = 2.0
    runs = {}
    print("training baseline topologies (50 clients, simple-NIID)...")
    runs["cloud-based (kappa=60, 10x latency)"] = run_schedule(
        60, 1, partition="simple_niid", rounds=10, class_sep=sep
    )
    runs["edge-based (1 edge, 10 clients)"] = run_edge_only(rounds=60)

    if args.fanout:
        spec = parse_fanouts(args.fanout)
        if args.kappas:
            kappas = tuple(int(k) for k in args.kappas.split(","))
        else:
            kappas = (6,) + (2,) * (spec.depth - 1)
        entries = [(f"custom {spec.describe()}", spec, kappas)]
    else:
        if args.kappas:
            ap.error("--kappas needs --fanout (the default sweep fixes its own schedules)")
        entries = []
        for depth, rows in DEFAULT_SWEEP.items():
            if args.levels and depth != args.levels:
                continue
            for name, fanout, kappas in rows:
                entries.append((name, parse_fanouts(fanout), kappas))

    for name, spec, kappas in entries:
        print(f"training {name}: tree {spec.describe()}, kappas {kappas}...")
        runs[name] = run_hierarchy_schedule(
            spec, kappas, partition="simple_niid", rounds=args.rounds, class_sep=sep
        )

    print(f"\n{'topology':42s} {'best acc':>8s} {'T_0.9':>9s}")
    for name, r in runs.items():
        hs = [h for h in r.history if h.accuracy is not None]
        hit = first_reach(r, 0.9)
        t = f"{hit[1]:8.1f}s" if hit else "   never"
        print(f"{name:42s} {max(h.accuracy for h in hs):8.3f} {t}")
    print("\nexpected (paper): hierarchical ~ cloud accuracy (same data reach), at a")
    print("fraction of the wall-clock; edge-based is fast but caps below (less data).")
    print("ragged/deeper trees track the uniform frontier — the schedule, not the")
    print("tree shape, sets the T/E tradeoff.")


if __name__ == "__main__":
    main()
