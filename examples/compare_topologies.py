"""Cloud-based vs edge-based vs client-edge-cloud FL (the paper's Fig. 1/2
story) on one synthetic problem — prints the accuracy-vs-simulated-time
frontier of each topology.

    PYTHONPATH=src python examples/compare_topologies.py
"""
import sys

sys.path.insert(0, ".")  # allow running from repo root

from benchmarks.fig2_topologies import run_edge_only
from benchmarks.common import run_schedule


def main():
    print("training three topologies (50 clients / 5 edges, simple-NIID)...")
    runs = {
        "cloud-based (kappa=60, 10x latency)": run_schedule(60, 1, partition="simple_niid", rounds=10, class_sep=2.0),
        "hierarchical (kappa1=6, kappa2=10)": run_schedule(6, 10, partition="simple_niid", rounds=100, class_sep=2.0),
        "edge-based (1 edge, 10 clients)": run_edge_only(rounds=60),
    }
    print(f"\n{'topology':42s} {'best acc':>8s} {'T_0.9':>9s}")
    from benchmarks.common import first_reach
    for name, r in runs.items():
        hs = [h for h in r.history if h.accuracy is not None]
        hit = first_reach(r, 0.9)
        t = f"{hit[1]:8.1f}s" if hit else "   never"
        print(f"{name:42s} {max(h.accuracy for h in hs):8.3f} {t}")
    print("\nexpected (paper): hierarchical ~ cloud accuracy (same data reach), at a")
    print("fraction of the wall-clock; edge-based is fast but caps below (less data).")


if __name__ == "__main__":
    main()
