"""Quickstart: hierarchical FL in ~40 lines.

    PYTHONPATH=src python examples/quickstart.py

Trains a small classifier across 20 clients / 4 edge servers with HierFAVG
(kappa1=4 local steps per edge aggregation, kappa2=2 edge rounds per cloud
round) and prints the accuracy + simulated wall-clock/energy per round.
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import FedTopology, HierFAVGConfig, cost_model as cm
from repro.data import FederatedBatcher, clustered_gaussians, make_partition
from repro.fed import FederatedRunner, RunnerConfig
from repro.models import cnn
from repro.optim import sgd


def main():
    rng = np.random.default_rng(0)
    data = clustered_gaussians(rng, num_samples=2000, num_classes=10, dim=(16,), class_sep=3.5)
    parts = make_partition("edge_niid", data.y, num_edges=4, clients_per_edge=5, rng=rng)
    batcher = FederatedBatcher({"inputs": data.x, "targets": data.y}, parts, batch_size=8)

    def init(key):
        k1, k2 = jax.random.split(key)
        return {"w1": jax.random.normal(k1, (16, 48)) * 0.25, "b1": jnp.zeros(48),
                "w2": jax.random.normal(k2, (48, 10)) * 0.25, "b2": jnp.zeros(10)}

    def apply_fn(p, x):
        return jax.nn.relu(x @ p["w1"] + p["b1"]) @ p["w2"] + p["b2"]

    runner = FederatedRunner(
        loss_fn=cnn.make_cnn_loss_fn(apply_fn),
        optimizer=sgd(0.15),
        topology=FedTopology(num_edges=4, clients_per_edge=5),
        hier_config=HierFAVGConfig(kappa1=4, kappa2=2),
        data_sizes=batcher.data_sizes,
        batcher=batcher,
        runner_config=RunnerConfig(num_rounds=24, eval_every=4),
        eval_fn=lambda p: float(cnn.accuracy(apply_fn(p, jnp.asarray(data.x)), jnp.asarray(data.y))),
        costs=cm.paper_workload("mnist"),
    )
    state = runner.init(jax.random.PRNGKey(0), init(jax.random.PRNGKey(1)))
    runner.run(state)
    for h in runner.history:
        if h.accuracy is not None:
            print(f"round {h.round:3d}  step {h.step:4d}  loss {h.loss:.3f}  "
                  f"acc {h.accuracy:.3f}  T={h.sim_time_s:6.1f}s  E={h.sim_energy_j:5.2f}J")
    final = [h.accuracy for h in runner.history if h.accuracy is not None][-1]
    print(f"\nfinal accuracy: {final:.3f} (HierFAVG, 20 clients / 4 edges, edge-NIID)")


if __name__ == "__main__":
    main()
