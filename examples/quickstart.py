"""Quickstart: hierarchical FL from a declarative spec, in ~15 lines.

    PYTHONPATH=src python examples/quickstart.py

Trains a small classifier across 20 clients / 4 edge servers with HierFAVG
(kappa1=4 local steps per edge aggregation, kappa2=2 edge rounds per cloud
round) and prints the accuracy + simulated wall-clock/energy per round.
The whole experiment is the ``quickstart`` registry entry — tweak any axis
with a dotted-path override, e.g.
``scenarios.get("quickstart", overrides=["schedule.kappas=6,2"])``.
"""
from repro.fed import scenarios


def main():
    spec = scenarios.get("quickstart")
    print(spec.describe())
    runner, _ = spec.run_experiment()
    for h in runner.history:
        if h.accuracy is not None:
            print(f"round {h.round:3d}  step {h.step:4d}  loss {h.loss:.3f}  "
                  f"acc {h.accuracy:.3f}  T={h.sim_time_s:6.1f}s  E={h.sim_energy_j:5.2f}J")
    final = [h.accuracy for h in runner.history if h.accuracy is not None][-1]
    print(f"\nfinal accuracy: {final:.3f} (HierFAVG, 20 clients / 4 edges, edge-NIID)")


if __name__ == "__main__":
    main()
