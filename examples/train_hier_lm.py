"""End-to-end federated LM training (deliverable b).

    PYTHONPATH=src python examples/train_hier_lm.py              # ~10M model, fast
    PYTHONPATH=src python examples/train_hier_lm.py --preset 100m --rounds 40

Trains a decoder-only LM with HierFAVG across 8 clients / 2 edges on a
Markov-teacher token corpus with label-skewed (edge-NIID) client splits,
with checkpointing + failure injection — the full production loop on CPU.
"""
import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import CheckpointManager
from repro.configs.paper import LM_100M
from repro.core import FedTopology, HierFAVGConfig
from repro.data import FederatedBatcher, make_partition, token_corpus
from repro.fed import FailureSimulator, FederatedRunner, RunnerConfig
from repro.models import transformer
from repro.optim import adam, warmup_cosine

PRESETS = {
    "10m": dataclasses.replace(
        LM_100M, name="lm-10m", num_layers=4, d_model=256, num_heads=8,
        num_kv_heads=4, d_ff=768, vocab_size=512,
    ),
    "100m": dataclasses.replace(LM_100M, vocab_size=512),
}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--preset", default="10m", choices=list(PRESETS))
    ap.add_argument("--rounds", type=int, default=24)
    ap.add_argument("--seq-len", type=int, default=64)
    ap.add_argument("--ckpt-dir", default="/tmp/hier_lm_ckpt")
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--inject-failures", action="store_true")
    args = ap.parse_args()

    cfg = PRESETS[args.preset]
    rng = np.random.default_rng(0)
    corp = token_corpus(rng, num_sequences=512, seq_len=args.seq_len, vocab=cfg.vocab_size,
                        num_classes=8, concentration=0.2)
    parts = make_partition("edge_niid", corp.labels, 2, 4, rng, classes_per_edge=4)
    batcher = FederatedBatcher(
        {"tokens": corp.tokens}, parts, batch_size=8, seed=0,
        batch_fn=lambda d: {"inputs": d["tokens"][..., :-1], "targets": d["tokens"][..., 1:]},
    )

    topo = FedTopology(num_edges=2, clients_per_edge=4)
    hier = HierFAVGConfig(kappa1=4, kappa2=2)
    params = transformer.init_params(jax.random.PRNGKey(0), cfg)
    n_params = sum(x.size for x in jax.tree_util.tree_leaves(params))
    print(f"model: {cfg.name} ({n_params/1e6:.1f}M params), topology 8 clients / 2 edges, "
          f"kappa1={hier.kappa1} kappa2={hier.kappa2}")

    runner = FederatedRunner(
        loss_fn=transformer.make_loss_fn(cfg),
        optimizer=adam(warmup_cosine(3e-4, 20, args.rounds * hier.kappa1)),
        topology=topo,
        hier_config=hier,
        data_sizes=batcher.data_sizes,
        batcher=batcher,
        runner_config=RunnerConfig(num_rounds=args.rounds, checkpoint_every=8),
        checkpointer=CheckpointManager(args.ckpt_dir, keep=2),
        failures=FailureSimulator(8, p_fail=0.1, seed=1) if args.inject_failures else None,
    )
    if args.resume:
        state, start = runner.restore_or_init(jax.random.PRNGKey(1), params)
        print(f"resumed at round {start}")
    else:
        state, start = runner.init(jax.random.PRNGKey(1), params), 0

    t0 = time.time()
    state = runner.run(state, start_round=start)
    for h in runner.history:
        if h.round % 4 == 0 or h.round == args.rounds - 1:
            print(f"round {h.round:3d}  step {h.step:4d}  loss {h.loss:.4f}  alive {h.mask_alive}")
    print(f"\ntrained {int(state.step)} local steps in {time.time()-t0:.0f}s; "
          f"loss {runner.history[0].loss:.3f} -> {runner.history[-1].loss:.3f}")


if __name__ == "__main__":
    main()
