"""End-to-end federated LM training (deliverable b), spec-driven.

    PYTHONPATH=src python examples/train_hier_lm.py              # ~10M model, fast
    PYTHONPATH=src python examples/train_hier_lm.py --preset 100m --rounds 40

Trains a decoder-only LM with HierFAVG across 8 clients / 2 edges on a
Markov-teacher token corpus with label-skewed (edge-NIID) client splits,
with checkpointing + failure injection — the full production loop on CPU,
assembled from the ``lm_edge_niid`` registry scenario. Every CLI flag is a
dotted-path override on that spec.
"""
import argparse
import time

from repro.fed import scenarios


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--preset", default="10m", choices=["10m", "100m"])
    ap.add_argument("--rounds", type=int, default=24)
    ap.add_argument("--seq-len", type=int, default=64)
    ap.add_argument("--ckpt-dir", default="/tmp/hier_lm_ckpt")
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--inject-failures", action="store_true")
    ap.add_argument("--set", dest="overrides", action="append", default=[],
                    metavar="PATH=VALUE", help="extra spec overrides, repeatable")
    args = ap.parse_args()

    overrides = [
        f"model.arch=lm-{args.preset}",
        f"run.num_rounds={args.rounds}",
        f"data.seq_len={args.seq_len}",
        f"run.checkpoint_dir={args.ckpt_dir}",
        "run.checkpoint_every=8",
    ]
    if args.inject_failures:
        overrides += ["failures.p_fail=0.1"]
    spec = scenarios.get("lm_edge_niid", overrides=overrides + args.overrides)
    print(spec.describe())

    t0 = time.time()
    runner, state = spec.run_experiment(resume=args.resume)
    for h in runner.history:
        if h.round % 4 == 0 or h.round == spec.run.num_rounds - 1:
            print(f"round {h.round:3d}  step {h.step:4d}  loss {h.loss:.4f}  alive {h.mask_alive}")
    print(f"\ntrained {int(state.step)} local steps in {time.time()-t0:.0f}s; "
          f"loss {runner.history[0].loss:.3f} -> {runner.history[-1].loss:.3f}")


if __name__ == "__main__":
    main()
