"""Serve the (cloud) model: prefill a batch of prompts, then decode with a
KV cache — the serving path the decode/prefill dry-run cells lower.

    PYTHONPATH=src python examples/serve_lm.py --batch 4 --gen 24
"""
import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.paper import LM_100M
from repro.models import transformer


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=24)
    ap.add_argument("--temperature", type=float, default=0.8)
    args = ap.parse_args()

    cfg = dataclasses.replace(
        LM_100M, name="lm-serve", num_layers=4, d_model=256, num_heads=8,
        num_kv_heads=4, d_ff=768, vocab_size=512,
    )
    params = transformer.init_params(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(0)
    B, P = args.batch, args.prompt_len
    prompts = jnp.asarray(rng.integers(0, cfg.vocab_size, size=(B, P)), jnp.int32)
    max_len = P + args.gen

    prefill = jax.jit(lambda p, x: transformer.prefill(p, cfg, x, max_len))
    decode = jax.jit(lambda p, c, t, pos: transformer.decode_step(p, cfg, c, t, pos))

    t0 = time.time()
    logits, caches = prefill(params, prompts)
    jax.block_until_ready(logits)
    t_prefill = time.time() - t0

    key = jax.random.PRNGKey(1)
    tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
    out = [tok]
    t0 = time.time()
    for t in range(args.gen - 1):
        pos = jnp.full((B,), P + t, jnp.int32)
        logits, caches = decode(params, caches, tok, pos)
        key, sub = jax.random.split(key)
        tok = jax.random.categorical(sub, logits / args.temperature, axis=-1).astype(jnp.int32)
        out.append(tok)
    jax.block_until_ready(tok)
    t_decode = time.time() - t0

    gen = jnp.stack(out, axis=1)
    print(f"prefill: {B}×{P} tokens in {t_prefill*1e3:.0f} ms "
          f"({B*P/t_prefill:.0f} tok/s)")
    print(f"decode : {B}×{args.gen-1} tokens in {t_decode*1e3:.0f} ms "
          f"({B*(args.gen-1)/max(t_decode,1e-9):.0f} tok/s)")
    for b in range(min(B, 2)):
        print(f"request {b}: prompt={np.asarray(prompts[b])[:8]}... -> "
              f"generated={np.asarray(gen[b])[:12]}...")


if __name__ == "__main__":
    main()
