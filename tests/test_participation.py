"""Cohort samplers: the laws the participation engine relies on —
round-robin coverage, seed determinism, resume-exactness (state survives a
JSON round-trip, like checkpoint metadata), stratified quota apportionment —
plus the population-scale smoke proving device state scales with the cohort,
not the population."""
import json

import numpy as np
import pytest

from repro.core.hierarchy import parse_fanouts
from repro.fed.participation import (
    ParticipationSpec,
    RoundRobinSampler,
    StratifiedSampler,
    UniformSampler,
    build_sampler,
    stratified_quotas,
)
from repro.testing import given, settings, st


def _assert_valid_cohort(ids, n, c):
    assert ids.shape == (c,)
    assert np.all(np.diff(ids) > 0)  # sorted, no duplicates
    assert 0 <= ids[0] and ids[-1] < n


# ---------------------------------------------------------------------------
# sampler laws
# ---------------------------------------------------------------------------

@given(n=st.integers(1, 64), c=st.integers(1, 64))
@settings(max_examples=30)
def test_round_robin_covers_population(n, c):
    """Every client participates within ceil(N/C) consecutive cohorts."""
    c = min(c, n)
    sampler = RoundRobinSampler(n, c)
    seen = np.zeros(n, bool)
    for _ in range(-(-n // c)):
        ids = sampler.sample()
        _assert_valid_cohort(ids, n, c)
        seen[ids] = True
    assert seen.all()


@given(n=st.integers(2, 128), c=st.integers(1, 128), seed=st.integers(0, 7))
@settings(max_examples=20)
def test_uniform_seed_deterministic_and_resume_exact(n, c, seed):
    """Same seed -> same cohort stream; a JSON-round-tripped state_dict
    resumes the stream exactly, even loaded into a differently-seeded
    sampler (the restored RNG state fully overrides the seed)."""
    c = min(c, n)
    a = UniformSampler(n, c, seed)
    b = UniformSampler(n, c, seed)
    for _ in range(3):
        np.testing.assert_array_equal(a.sample(), b.sample())
    snap = json.loads(json.dumps(a.state_dict()))
    resumed = UniformSampler(n, c, seed + 1)
    resumed.load_state_dict(snap)
    for _ in range(3):
        ids = a.sample()
        _assert_valid_cohort(ids, n, c)
        np.testing.assert_array_equal(ids, resumed.sample())


def test_round_robin_resume_exact():
    a = RoundRobinSampler(10, 4)
    a.sample()
    a.sample()
    b = RoundRobinSampler(10, 4)
    b.load_state_dict(json.loads(json.dumps(a.state_dict())))
    for _ in range(5):
        np.testing.assert_array_equal(a.sample(), b.sample())


@given(num_edges=st.integers(2, 6), extra=st.integers(0, 10), seed=st.integers(0, 9))
@settings(max_examples=25)
def test_stratified_never_leaves_an_edge_empty(num_edges, extra, seed):
    """Each cohort hits every edge exactly per its quota (>= 1 seat), with
    members drawn from that edge's own id range."""
    sizes = np.random.default_rng(seed).integers(1, 9, size=num_edges)
    seg = np.repeat(np.arange(len(sizes)), sizes)
    n = int(seg.shape[0])
    c = min(n, len(sizes) + extra)
    sampler = StratifiedSampler(n, c, seg)
    quotas = sampler.quotas
    assert quotas.sum() == c
    assert (quotas >= 1).all() and (quotas <= np.asarray(sizes)).all()
    for _ in range(2):
        ids = sampler.sample()
        _assert_valid_cohort(ids, n, c)
        np.testing.assert_array_equal(
            np.bincount(seg[ids], minlength=len(sizes)), quotas
        )


def test_stratified_resume_exact():
    seg = np.repeat(np.arange(3), [5, 4, 3])
    a = StratifiedSampler(12, 6, seg, seed=2)
    a.sample()
    b = StratifiedSampler(12, 6, seg, seed=2)
    b.load_state_dict(json.loads(json.dumps(a.state_dict())))
    for _ in range(4):
        np.testing.assert_array_equal(a.sample(), b.sample())


@given(num_edges=st.integers(1, 10), seed=st.integers(0, 9))
@settings(max_examples=25)
def test_stratified_quota_laws(num_edges, seed):
    sizes = np.random.default_rng(seed).integers(1, 101, size=num_edges)
    sizes = np.asarray(sizes, np.int64)
    total, floor = int(sizes.sum()), len(sizes)
    for c in sorted({floor, total, (floor + total) // 2}):
        q = stratified_quotas(sizes, c)
        assert int(q.sum()) == c
        assert (q >= 1).all() and (q <= sizes).all()


def test_stratified_quota_errors():
    with pytest.raises(ValueError, match="cohort_size >= num_edges"):
        stratified_quotas(np.array([3, 3, 3]), 2)
    with pytest.raises(ValueError, match="exceeds population"):
        stratified_quotas(np.array([2, 2]), 5)
    with pytest.raises(ValueError, match="at least one client"):
        stratified_quotas(np.array([0, 3]), 2)


# ---------------------------------------------------------------------------
# spec plumbing
# ---------------------------------------------------------------------------

def test_participation_spec_validation():
    assert not ParticipationSpec().is_active  # cohort_size=0: inert default
    assert ParticipationSpec(cohort_size=4).is_active
    with pytest.raises(ValueError, match="cohort_size"):
        ParticipationSpec(cohort_size=-1)
    with pytest.raises(ValueError, match="sampler"):
        ParticipationSpec(cohort_size=4, sampler="lottery")


def test_build_sampler_dispatch_and_bounds():
    tree = parse_fanouts("2,3/2")  # N=5
    built = ParticipationSpec(cohort_size=3, sampler="stratified").build_sampler(tree)
    assert isinstance(built, StratifiedSampler)
    assert isinstance(
        build_sampler(ParticipationSpec(cohort_size=2, sampler="round_robin"), tree),
        RoundRobinSampler,
    )
    with pytest.raises(ValueError, match="inactive"):
        build_sampler(ParticipationSpec(), tree)
    with pytest.raises(ValueError, match="cohort_size"):
        build_sampler(ParticipationSpec(cohort_size=9), tree)  # 9 > N=5


def test_sampler_kind_mismatch_rejected():
    u = UniformSampler(10, 3)
    with pytest.raises(ValueError, match="kind"):
        u.load_state_dict(RoundRobinSampler(10, 3).state_dict())


# ---------------------------------------------------------------------------
# population-scale smoke (excluded from tier-1 by the marker; the CI
# population job runs it with `-m population`)
# ---------------------------------------------------------------------------

@pytest.mark.population
def test_population_smoke_device_state_is_cohort_sized():
    """100k virtual clients, 256-client stratified cohorts, CPU: every
    device-resident stacked leaf is (256, ...) while the (100k, ...)
    population exists only as host numpy (store + cursors + sampler)."""
    import jax

    from repro.fed.api import (
        CostSpec,
        DataSpec,
        ExperimentSpec,
        ModelSpec,
        RunSpec,
        ScheduleSpec,
        TopologySpec,
    )

    spec = ExperimentSpec(
        name="pop_smoke",
        topology=TopologySpec(num_edges=200, clients_per_edge=500),
        schedule=ScheduleSpec(kappas=(2, 2)),
        data=DataSpec(
            partition="iid", num_samples=4000, batch_size=4,
            virtual_clients=100_000, samples_per_client=8,
        ),
        model=ModelSpec(lr=0.01, optimizer="adam"),
        participation=ParticipationSpec(cohort_size=256, sampler="stratified"),
        cost=CostSpec(workload="none"),
        run=RunSpec(num_rounds=4, eval_every=0),
    )
    runner, state = spec.run_experiment()  # 2 cloud intervals

    for leaf in jax.tree_util.tree_leaves(state.params):
        assert leaf.shape[0] == 256, leaf.shape
    store = runner.client_store
    assert not store.is_empty  # adam mu/nu rows are sticky
    for arr in store.state()["leaves"]:
        assert isinstance(arr, np.ndarray) and arr.shape[0] == 100_000
    # peak live client state ∝ cohort: at most intervals * C distinct
    # participants have ever been materialized/written
    assert 256 <= store.num_touched <= 2 * 256
    assert [r.round for r in runner.history] == [0, 1, 2, 3]
    assert all(np.isfinite(r.loss) for r in runner.history)
