"""Checkpoint atomicity / retention + elastic resharding semantics."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import CheckpointManager, merge_opt_state, reshard_clients


def make_state(rng, n=4):
    return {
        "w": jnp.asarray(rng.normal(size=(n, 3, 5)), jnp.float32),
        "b": jnp.asarray(rng.normal(size=(n, 7)), jnp.float32),
        "step": jnp.asarray(12, jnp.int32),
    }


def test_save_restore_roundtrip(tmp_path, rng):
    mgr = CheckpointManager(str(tmp_path), keep=2)
    state = make_state(rng)
    mgr.save(10, state, {"round": 3, "note": "x"})
    got, meta = mgr.restore(10, state)
    for a, b in zip(jax.tree_util.tree_leaves(state), jax.tree_util.tree_leaves(got)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    assert meta["round"] == 3


def test_keep_k_gc(tmp_path, rng):
    mgr = CheckpointManager(str(tmp_path), keep=2)
    state = make_state(rng)
    for s in (1, 2, 3, 4):
        mgr.save(s, state)
    assert mgr.committed_steps() == [3, 4]


def test_restore_latest_skips_corrupt(tmp_path, rng):
    mgr = CheckpointManager(str(tmp_path), keep=3)
    state = make_state(rng)
    mgr.save(1, state, {"round": 1})
    mgr.save(2, state, {"round": 2})
    # corrupt the newest payload but leave its COMMITTED marker
    os.remove(os.path.join(mgr._step_dir(2), "payload.npz"))
    got = mgr.restore_latest(state)
    assert got is not None
    _, meta = got
    assert meta["round"] == 1


def test_uncommitted_checkpoint_ignored(tmp_path, rng):
    mgr = CheckpointManager(str(tmp_path), keep=3)
    state = make_state(rng)
    mgr.save(5, state, {"round": 5})
    os.remove(mgr._marker(5))  # simulate crash before commit marker
    assert mgr.restore_latest(state) is None


def test_reshard_shrink_is_weighted_merge(rng):
    params = {"w": jnp.asarray(rng.normal(size=(4, 3)), jnp.float32)}
    sizes = np.array([1.0, 3.0, 2.0, 2.0])
    merged, new_sizes = reshard_clients(params, sizes, 2)
    want0 = (1 * params["w"][0] + 3 * params["w"][1]) / 4
    np.testing.assert_allclose(np.asarray(merged["w"][0]), np.asarray(want0), rtol=1e-6)
    np.testing.assert_array_equal(new_sizes, [4.0, 4.0])


def test_reshard_grow_replicates(rng):
    params = {"w": jnp.asarray(rng.normal(size=(2, 3)), jnp.float32)}
    grown, sizes = reshard_clients(params, np.array([2.0, 4.0]), 4)
    np.testing.assert_array_equal(np.asarray(grown["w"][0]), np.asarray(grown["w"][1]))
    np.testing.assert_array_equal(sizes, [1.0, 1.0, 2.0, 2.0])


def test_reshard_roundtrip_identity(rng):
    """grow then shrink recovers the originals (uniform weights)."""
    params = {"w": jnp.asarray(rng.normal(size=(2, 5)), jnp.float32)}
    sizes = np.array([1.0, 1.0])
    grown, gs = reshard_clients(params, sizes, 6)
    back, bs = reshard_clients(grown, gs, 2)
    np.testing.assert_allclose(np.asarray(back["w"]), np.asarray(params["w"]), rtol=1e-6)


def test_merge_opt_state_passthrough_scalars(rng):
    opt_state = ({"mu": jnp.ones((4, 3))}, jnp.asarray(7, jnp.int32))
    merged = merge_opt_state(opt_state, np.ones(4), 2)
    assert merged[0]["mu"].shape == (2, 3)
    assert int(merged[1]) == 7
