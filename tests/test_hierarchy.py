"""Ragged hierarchies: HierarchySpec validation, segment aggregation laws
(property-based), the multi-level schedule, and the ragged Pallas kernel."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (
    HierarchySpec, HierFAVGConfig, as_hierarchy, build_train_step, init_state,
    parse_fanouts,
)
from repro.core import aggregation
from repro.core.hierfavg import FedTopology
from repro.kernels import ops, ref
from repro.optim import sgd
from repro.testing import given, settings, st

ops.set_interpret(True)


def random_spec(seed: int, depth: int, max_fan: int = 5) -> HierarchySpec:
    """A random ragged tree: bottom-up fan-outs with 1..max_fan children."""
    r = np.random.default_rng(seed)
    fanouts = []
    nodes = 1
    top_down = []
    for _ in range(depth):
        top_down.append([int(r.integers(1, max_fan + 1)) for _ in range(nodes)])
        nodes = sum(top_down[-1])
    for level in reversed(top_down):
        fanouts.append(level)
    return HierarchySpec.from_fanouts(fanouts)


def numpy_segment_mean(x, w, seg):
    """Literal per-group weighted mean oracle; dead groups keep rows."""
    out = x.astype(np.float64).copy()
    for g in np.unique(seg):
        m = seg == g
        tot = w[m].sum()
        if tot > 0:
            out[m] = (x[m] * w[m, None]).sum(axis=0) / tot
    return out


# ---------------------------------------------------------------------------
# HierarchySpec structure
# ---------------------------------------------------------------------------

def test_uniform_reduces_to_fed_topology():
    spec = HierarchySpec.uniform(5, 10)
    topo = FedTopology(num_edges=5, clients_per_edge=10)
    assert spec == as_hierarchy(topo)
    assert spec.is_paper_topology and spec.depth == 2 and spec.num_clients == 50
    np.testing.assert_array_equal(spec.segments(1), np.repeat(np.arange(5), 10))
    np.testing.assert_array_equal(spec.segments(2), np.zeros(50, np.int32))


def test_from_fanouts_ragged_three_level():
    spec = HierarchySpec.from_fanouts([[3, 5, 2], [2, 1], [2]])
    assert spec.num_clients == 10 and spec.depth == 3
    assert spec.num_nodes(1) == 3 and spec.num_nodes(2) == 2 and spec.num_nodes(3) == 1
    assert not spec.is_uniform(1) and not spec.is_paper_topology
    np.testing.assert_array_equal(spec.group_sizes(1), [3, 5, 2])
    np.testing.assert_array_equal(spec.segments(2), [0] * 8 + [1] * 2)
    assert spec.fanouts() == ((3, 5, 2), (2, 1), (2,))


def test_parse_fanouts_cli_forms():
    assert parse_fanouts("3,5,2/2,1/2") == HierarchySpec.from_fanouts([[3, 5, 2], [2, 1], [2]])
    # trailing singleton root may be omitted
    assert parse_fanouts("10,10,10,10,10/5") == HierarchySpec.uniform(5, 10)


@pytest.mark.parametrize(
    "bad",
    [
        [[2, 0], [2]],  # empty node
        [[2, 2], [3]],  # fan-out/node-count mismatch
        [[2, 2], [1, 1]],  # two roots
    ],
)
def test_invalid_fanouts_rejected(bad):
    with pytest.raises(ValueError):
        HierarchySpec.from_fanouts(bad)


def test_unsorted_parent_ids_rejected():
    with pytest.raises(ValueError):
        HierarchySpec(parents=((0, 1, 0, 1), (0, 0)))


def test_replica_groups_cover_disjointly():
    spec = random_spec(3, depth=3)
    for level in range(1, spec.depth + 1):
        groups = spec.replica_groups(level)
        flat = sorted(i for g in groups for i in g)
        assert flat == list(range(spec.num_clients))


# ---------------------------------------------------------------------------
# segment_weighted_mean laws (property-based over random ragged trees)
# ---------------------------------------------------------------------------

@given(seed=st.integers(0, 50), depth=st.integers(1, 4))
@settings(max_examples=25, deadline=None)
def test_segment_mean_equals_flat_mean_per_group(seed, depth):
    """On any random ragged tree, segment_weighted_mean at any level equals
    the per-group flat weighted mean."""
    spec = random_spec(seed, depth)
    r = np.random.default_rng(seed)
    n = spec.num_clients
    x = r.normal(size=(n, 7)).astype(np.float32)
    w = r.uniform(0.5, 3.0, size=n).astype(np.float32)
    for level in range(1, depth + 1):
        seg = spec.segments(level)
        got = aggregation.segment_weighted_mean(
            jnp.asarray(x), jnp.asarray(w), seg, spec.num_nodes(level)
        )
        np.testing.assert_allclose(
            np.asarray(got), numpy_segment_mean(x, w, seg), atol=1e-5
        )


@given(seed=st.integers(0, 30))
@settings(max_examples=15, deadline=None)
def test_segment_mean_masked_renormalizes(seed):
    """Masked survivors only: the mean renormalizes over the participating
    set; zero-survivor groups keep their members' parameters."""
    spec = random_spec(seed, depth=2)
    r = np.random.default_rng(seed + 1)
    n = spec.num_clients
    seg = spec.segments(1)
    x = r.normal(size=(n, 5)).astype(np.float32)
    w = r.uniform(1.0, 2.0, size=n).astype(np.float32)
    mask = (r.random(n) > 0.4).astype(np.float32)
    got = aggregation.segment_weighted_mean(
        jnp.asarray(x), jnp.asarray(w), seg, spec.num_nodes(1), jnp.asarray(mask)
    )
    want = numpy_segment_mean(x, w * mask, seg)
    np.testing.assert_allclose(np.asarray(got), want, atol=1e-5)
    # explicitly: any group with zero survivors kept its rows bit-for-bit
    for g in range(spec.num_nodes(1)):
        m = seg == g
        if (w * mask)[m].sum() == 0:
            np.testing.assert_array_equal(np.asarray(got)[m], x[m])


def test_zero_survivor_group_keeps_params():
    seg = np.asarray([0, 0, 1, 1, 1], np.int32)
    x = jnp.arange(25, dtype=jnp.float32).reshape(5, 5)
    w = jnp.ones(5)
    mask = jnp.asarray([0.0, 0.0, 1.0, 1.0, 0.0])
    got = aggregation.segment_weighted_mean(x, w, seg, 2, mask)
    np.testing.assert_array_equal(np.asarray(got[:2]), np.asarray(x[:2]))
    want_g1 = np.asarray(x[2:4]).mean(axis=0)
    np.testing.assert_allclose(np.asarray(got[2:]), np.tile(want_g1, (3, 1)), atol=1e-6)


def test_segment_mean_uniform_matches_grouped_exactly():
    """Acceptance anchor: on uniform trees the segment path IS the grouped
    path (static dispatch), so equality is bitwise."""
    r = np.random.default_rng(0)
    x = jnp.asarray(r.normal(size=(12, 33)), jnp.float32)
    w = jnp.asarray(r.uniform(0.5, 2.0, size=12), jnp.float32)
    seg = np.repeat(np.arange(3, dtype=np.int32), 4)
    got = aggregation.segment_weighted_mean(x, w, seg, 3)
    want = aggregation.grouped_weighted_mean(x, w, 3)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_segment_mean_traced_ids_inside_jit():
    """The jnp segment path also works with traced ids (no static dispatch)."""
    r = np.random.default_rng(2)
    x = jnp.asarray(r.normal(size=(6, 4)), jnp.float32)
    w = jnp.ones(6)
    seg = jnp.asarray([0, 0, 0, 1, 1, 1], jnp.int32)

    @jax.jit
    def f(x, w, seg):
        return aggregation.segment_weighted_mean(x, w, seg, 2)

    np.testing.assert_allclose(
        np.asarray(f(x, w, seg)),
        numpy_segment_mean(np.asarray(x), np.asarray(w), np.asarray(seg)),
        atol=1e-6,
    )


def test_hierarchical_segment_mean_equals_flat_top_level():
    """Staged bottom-up composition == flat weighted mean at the root
    (the |D_i| weights compose) on a ragged 3-level tree."""
    spec = HierarchySpec.from_fanouts([[3, 5, 2], [2, 1], [2]])
    r = np.random.default_rng(1)
    x = jnp.asarray(r.normal(size=(10, 6)), jnp.float32)
    w = jnp.asarray(r.uniform(0.5, 3.0, size=10), jnp.float32)
    staged = aggregation.hierarchical_segment_mean(x, w, spec)
    flat = aggregation.weighted_mean(x, w)
    np.testing.assert_allclose(np.asarray(staged), np.asarray(flat), atol=1e-5)


# ---------------------------------------------------------------------------
# Multi-level schedule semantics
# ---------------------------------------------------------------------------

def test_kappa_vector_schedule_intervals():
    cfg = HierFAVGConfig.multi_level([4, 2, 3])
    assert cfg.kappa_vector == (4, 2, 3)
    assert [cfg.level_interval(l) for l in (1, 2, 3)] == [4, 8, 24]
    assert cfg.cloud_interval == 24 and cfg.kappa2_effective == 6
    assert bool(cfg.is_level_step(2, 8)) and not bool(cfg.is_level_step(3, 8))


def test_config_level_mismatch_rejected():
    spec = HierarchySpec.from_fanouts([[2, 2], [2]])
    with pytest.raises(ValueError):
        build_train_step(
            lambda p, b, r: 0.0, sgd(0.1), spec, HierFAVGConfig.multi_level([2, 2, 2]),
            jnp.ones(4),
        )


def test_three_level_train_step_matches_numpy_schedule():
    """Quadratic clients on a ragged 3-level tree: the fused train step
    reproduces the literal per-level numpy schedule."""
    spec = HierarchySpec.from_fanouts([[3, 5, 2], [2, 1], [2]])
    cfg = HierFAVGConfig.multi_level([2, 2, 2])
    r = np.random.default_rng(0)
    centers = r.normal(size=(10, 4))
    sizes = r.integers(1, 5, size=10).astype(np.float64)

    def loss_fn(p, b, _):
        return 0.5 * jnp.sum((p["w"] - b["c"]) ** 2)

    opt = sgd(0.1)
    state = init_state(jax.random.PRNGKey(0), {"w": jnp.zeros(4)}, opt, spec, cfg)
    step = jax.jit(build_train_step(
        loss_fn, opt, spec, cfg, jnp.asarray(sizes, jnp.float32)
    ))
    batch = {"c": jnp.asarray(centers, jnp.float32)}

    w = np.zeros((10, 4))
    for k in range(1, 17):
        w = w - 0.1 * (w - centers)
        for level in (3, 2, 1):
            if k % cfg.level_interval(level) == 0:
                for t in range(1, level + 1):
                    w = numpy_segment_mean(w, sizes, spec.segments(t))
                break
    for _ in range(16):
        state, _ = step(state, batch)
    np.testing.assert_allclose(np.asarray(state.params["w"]), w, atol=1e-5)


def test_two_level_vector_matches_scalar_config():
    """multi_level([k1, k2]) is the seed schedule bit-for-bit."""
    topo = FedTopology(num_edges=2, clients_per_edge=3)
    r = np.random.default_rng(0)
    centers = r.normal(size=(6, 3))
    sizes = r.integers(1, 4, size=6).astype(np.float64)

    def loss_fn(p, b, _):
        return 0.5 * jnp.sum((p["w"] - b["c"]) ** 2)

    batch = {"c": jnp.asarray(centers, jnp.float32)}
    outs = []
    for cfg in (HierFAVGConfig(kappa1=2, kappa2=3), HierFAVGConfig.multi_level([2, 3])):
        opt = sgd(0.1)
        s = init_state(jax.random.PRNGKey(0), {"w": jnp.zeros(3)}, opt, topo, cfg)
        step = jax.jit(build_train_step(loss_fn, opt, topo, cfg, jnp.asarray(sizes, jnp.float32)))
        for _ in range(13):
            s, _ = step(s, batch)
        outs.append(np.asarray(s.params["w"]))
    np.testing.assert_array_equal(outs[0], outs[1])


# ---------------------------------------------------------------------------
# Ragged Pallas kernel (interpret mode) vs jnp reference
# ---------------------------------------------------------------------------

# Bit-exactness is a compiled-vs-compiled claim: the interpret-mode kernel
# runs under jit, so the reference must too (XLA fuses eager-mode
# intermediates differently, which perturbs the last ulp).
_ref_segment_mean = jax.jit(
    ref.segment_mean_ref, static_argnames=("num_segments", "block_d")
)


@pytest.mark.parametrize("d", [64, 300, 513])
@pytest.mark.parametrize("seed", [0, 1, 2])
def test_segment_kernel_bitexact_f32(rng, d, seed):
    """Acceptance: the ragged kernel matches the jnp reference bit-for-bit
    in f32 (same one-hot matmul formulation and tiling)."""
    spec = random_spec(seed, depth=2)
    n = spec.num_clients
    seg = spec.segments(1)
    g = spec.num_nodes(1)
    x = jnp.asarray(rng.normal(size=(n, d)), jnp.float32)
    w = jnp.asarray(rng.uniform(0.5, 4.0, size=n), jnp.float32)
    got = ops.segment_mean(x, w, seg, g, block_d=128)
    want = _ref_segment_mean(x, w, seg, num_segments=g, block_d=128)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_segment_kernel_matches_numpy_on_three_level_tree(rng):
    """Ragged 3-level tree, every level, vs the literal numpy oracle."""
    spec = HierarchySpec.from_fanouts([[6, 4, 5, 3, 2], [3, 2], [2]])
    n = spec.num_clients
    x = jnp.asarray(rng.normal(size=(n, 200)), jnp.float32)
    w = jnp.asarray(rng.uniform(0.5, 2.0, size=n), jnp.float32)
    for level in range(1, spec.depth + 1):
        seg = spec.segments(level)
        got = ops.segment_mean(x, w, seg, spec.num_nodes(level), block_d=128)
        np.testing.assert_allclose(
            np.asarray(got),
            numpy_segment_mean(np.asarray(x), np.asarray(w), seg),
            atol=1e-5,
        )


def test_segment_kernel_masked_dead_group(rng):
    spec = HierarchySpec.from_fanouts([[3, 4, 2], [3]])
    n = spec.num_clients
    seg = spec.segments(1)
    x = jnp.asarray(rng.normal(size=(n, 256)), jnp.float32)
    w = jnp.asarray(rng.uniform(1, 2, size=n), jnp.float32).at[:3].set(0.0)
    got = ops.segment_mean(x, w, seg, 3, block_d=128)
    want = _ref_segment_mean(x, w, seg, num_segments=3, block_d=128)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))
    np.testing.assert_array_equal(np.asarray(got[:3]), np.asarray(x[:3]))  # dead edge


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_segment_kernel_dtypes(rng, dtype):
    spec = random_spec(7, depth=2)
    n = spec.num_clients
    seg = spec.segments(1)
    g = spec.num_nodes(1)
    x = jnp.asarray(rng.normal(size=(n, 384)), dtype)
    w = jnp.asarray(rng.uniform(0.5, 4.0, size=n), jnp.float32)
    got = ops.segment_mean(x, w, seg, g, block_d=128)
    want = _ref_segment_mean(x, w, seg, num_segments=g, block_d=128)
    tol = 0 if dtype == jnp.float32 else 5e-2
    np.testing.assert_allclose(
        np.asarray(got, np.float32), np.asarray(want, np.float32), atol=tol
    )


# ---------------------------------------------------------------------------
# Correlated subtree outages
# ---------------------------------------------------------------------------

def test_subtree_outage_masks_whole_edges():
    from repro.fed import SubtreeOutageSimulator

    spec = HierarchySpec.from_fanouts([[3, 5, 2], [2, 1], [2]])
    sim = SubtreeOutageSimulator(spec, tier=1, p_fail=0.6, p_recover=0.3, seed=0)
    seg = spec.segments(1)
    saw_outage = False
    for _ in range(20):
        mask = sim.step()
        assert mask.shape == (spec.num_clients,)
        # a mask is constant within every edge (correlated failure unit)
        for g in range(spec.num_nodes(1)):
            assert len(np.unique(mask[seg == g])) == 1
        saw_outage = saw_outage or mask.min() == 0.0
    assert saw_outage
