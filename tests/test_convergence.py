"""Section III closed forms: Lemmas 2-3, Theorems 1-2, Remarks 1-2.

Property-based (hypothesis) where the paper states monotonicity/limits.
"""
import math

import pytest
from repro.testing import given, settings, st

from repro.core import convergence as cv

ETA = st.floats(1e-4, 0.5)
BETA = st.floats(0.1, 10.0)
DELTA = st.floats(0.0, 5.0)
KAPPA = st.integers(1, 16)


@given(x=st.integers(0, 64), delta=DELTA, eta=ETA, beta=BETA)
def test_h_nonnegative_and_zero_at_zero_divergence(x, delta, eta, beta):
    assert cv.h(x, 0.0, eta, beta) == pytest.approx(0.0)
    assert cv.h(x, delta, eta, beta) >= -1e-9


@given(delta=DELTA, eta=ETA, beta=BETA, k1=KAPPA, k2=KAPPA)
def test_G_zero_iff_iid(delta, eta, beta, k1, k2):
    """Remark 2: delta = Delta = 0 (IID) => G_c = 0. Conversely G > 0 needs
    non-IID data AND an actual aggregation interval: kappa1*kappa2 = 1 is
    centralized GD where the deviation vanishes regardless (Remark 1)."""
    assert cv.G_c_max(k1, k2, 0.0, 0.0, eta, beta) == pytest.approx(0.0)
    assert cv.G_nc(k1, k2, 0.0, 0.0, eta, beta) == pytest.approx(0.0)
    if delta > 1e-6 and k1 * k2 > 1:
        assert cv.G_c_max(k1, k2, delta, delta, eta, beta) > 0


@given(eta=ETA, beta=BETA, delta=st.floats(0.01, 5.0), Delta=st.floats(0.01, 5.0), k1=KAPPA, k2=KAPPA)
@settings(max_examples=60)
def test_G_monotone_in_kappas(eta, beta, delta, Delta, k1, k2):
    """Remark 2: the bound increases with either aggregation interval."""
    g = cv.G_c_max(k1, k2, delta, Delta, eta, beta)
    assert cv.G_c_max(k1 + 1, k2, delta, Delta, eta, beta) >= g - 1e-9
    assert cv.G_c_max(k1, k2 + 1, delta, Delta, eta, beta) >= g - 1e-9


def test_kappa2_1_consistency():
    """Remark 1: with kappa2 = 1 the bound collapses to the two-layer form
    h(k, Delta + delta) (up to the h-subadditivity gap)."""
    eta, beta, d, D = 0.01, 1.0, 0.5, 0.7
    k1 = 6
    # G_c at interval end with kappa2=1: h(k1, Delta) + h(k1, delta)·(small)
    g = cv.G_c(k1, k1, 1, d, D, eta, beta)
    two_layer = cv.h(k1, D + d, eta, beta)
    # exact equality isn't claimed; both vanish together and stay same order
    assert g <= two_layer * 2 + 1e-9
    assert (g == 0) == (two_layer == 0)


def test_guideline_smaller_kappa1():
    """Guideline 1: fixed product, smaller kappa1 => smaller deviation."""
    out = cv.guideline_smaller_kappa1(16, delta=0.5, Delta=0.5, eta=0.01, beta=1.0)
    gs = [g for _, _, g in out]  # sorted by kappa1 ascending
    assert all(gs[i] <= gs[i + 1] + 1e-12 for i in range(len(gs) - 1))


def test_guideline_edge_iid_kappa2_cheap():
    """Guideline 2: Delta = 0 => raising kappa2 grows G only polynomially;
    with Delta > 0 the growth is exponential (dominates for large kappa2)."""
    eta, beta, delta, k1 = 0.01, 1.0, 0.5, 4
    iid = cv.guideline_edge_iid_kappa2_free(k1, delta, eta, beta, range(1, 30))
    ratio_iid = iid[-1][1] / iid[10][1]
    niid = [cv.G_c_max(k1, k2, delta, 0.5, eta, beta) for k2 in range(1, 30)]
    ratio_niid = niid[-1] / niid[10]
    assert ratio_niid > ratio_iid  # exponential beats polynomial growth


def test_theorem1_bound_positive_and_tightens_with_K():
    args = dict(kappa1=4, kappa2=2, delta=0.05, Delta=0.05, eta=0.01, beta=1.0,
                rho=1.0, epsilon=1.0, varphi=0.5)
    b1 = cv.theorem1_bound(K=64, **args)
    b2 = cv.theorem1_bound(K=128, **args)
    assert 0 < b2 < b1 < math.inf


def test_theorem1_infeasible_returns_inf():
    assert cv.theorem1_bound(
        K=64, kappa1=16, kappa2=16, delta=50.0, Delta=50.0, eta=0.4, beta=5.0,
        rho=1.0, epsilon=0.01, varphi=0.01,
    ) == math.inf


def test_theorem2_decreases_with_K():
    args = dict(kappa1=4, kappa2=2, delta=0.1, Delta=0.1, eta=0.01, beta=1.0,
                rho=1.0, f0_minus_fstar=10.0)
    assert cv.theorem2_bound(K=256, **args) < cv.theorem2_bound(K=64, **args)
