"""Client-sharded superround execution: edge-aligned shard placement,
shard_map parity against the single-device engine, the one-collective-per-
cloud-interval guarantee, donation, and the mesh-aware runner/API plumbing.

Placement and compatibility logic is pure host code and always runs. The
shard_map cases need a device mesh: the 1-shard cases run everywhere (the
full sharded code path over a 1-device mesh), the >=4-shard cases skip
unless the session exposes 4 devices — CI runs them in a dedicated job
under ``XLA_FLAGS=--xla_force_host_platform_device_count=4``.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.experimental.shard_map import shard_map
from jax.sharding import PartitionSpec as P

from repro.core import (
    ClientSharding,
    FedTopology,
    HierFAVGConfig,
    build_level_sync,
    build_sharded_super_round,
    build_super_round,
    fed_state_partition_specs,
    init_state,
    plan_shard_placement,
    sharding_incompatibility,
)
from repro.core.aggregation import AggregatorSpec, parse_aggregator
from repro.core.hierarchy import as_hierarchy, parse_fanouts
from repro.dist.sharding import (
    ShardingRules,
    batch_block_sharding,
    client_mesh,
    fed_state_shardings,
    mask_stack_sharding,
)
from repro.fed import TransportSpec
from repro.fed.api import ExperimentSpec
from repro.optim import momentum, sgd

needs4 = pytest.mark.skipif(
    jax.device_count() < 4,
    reason="needs 4 devices (XLA_FLAGS=--xla_force_host_platform_device_count=4)",
)

DIM = 3


# ---------------------------------------------------------------------------
# placement planning (host-side, always runs)
# ---------------------------------------------------------------------------

def test_placement_uniform_packs_exactly():
    spec = as_hierarchy(FedTopology(num_edges=8, clients_per_edge=2))
    p = plan_shard_placement(spec, 4)
    assert p.capacity == 4 and p.num_phantoms == 0
    assert sorted(p.perm) == list(range(16))
    # every edge's clients land inside one shard, in original order
    seg = spec.segments(1)
    for s in range(4):
        row = p.perm[s * p.capacity : (s + 1) * p.capacity]
        for a, b in zip(row, row[1:]):
            assert not (seg[a] == seg[b] and a > b)  # intra-group order kept
        assert len({seg[c] for c in row}) == 2  # whole edges only
    # identical local layout across shards -> static ids, uniform fast path
    tab = p.local_segments(1)
    assert (tab == tab[0]).all()
    np.testing.assert_array_equal(tab[0], [0, 0, 1, 1])


def test_placement_ragged_pads_with_phantoms():
    spec = parse_fanouts("4,2,1/3")
    p = plan_shard_placement(spec, 2)
    assert p.capacity == 4  # LPT: [4] vs [2, 1] + 1 phantom
    assert p.num_phantoms == 1
    assert p.padded_clients == 8
    valid = p.valid()
    assert valid.sum() == 7
    # inverse maps every real client back to its padded position
    pos = p.positions()
    gather = p.gather_index()
    for c in range(7):
        assert gather[pos[c]] == c
    # phantoms get the dedicated trailing local segment
    tab = p.local_segments(1)
    nseg = p.local_num_segments(1)
    phantom_cols = ~valid.reshape(2, p.capacity)
    assert (tab[phantom_cols] == nseg - 1).all()
    # weights: phantoms carry exactly zero
    w = p.pad_weights(np.arange(1, 8, dtype=np.float64))
    assert (w[~valid] == 0).all() and w[valid].sum() == sum(range(1, 8))


def test_placement_rejects_more_shards_than_subtrees():
    spec = as_hierarchy(FedTopology(num_edges=2, clients_per_edge=5))
    with pytest.raises(ValueError, match="subtree"):
        plan_shard_placement(spec, 4)


def test_placement_depth3_aligns_regions():
    # depth-3: alignment groups are level-2 regions, so BOTH edge and
    # region syncs stay shard-local
    spec = parse_fanouts("3,2,3,2/2,2/2")
    p = plan_shard_placement(spec, 2)
    seg2 = spec.segments(2)
    for s in range(2):
        row = [c for c in p.perm[s * p.capacity : (s + 1) * p.capacity] if c >= 0]
        assert len({seg2[c] for c in row}) == 1  # one whole region per shard


def test_sharding_incompatibility_reasons():
    topo = FedTopology(num_edges=4, clients_per_edge=2)
    ok = HierFAVGConfig(kappa1=2, kappa2=2)
    assert sharding_incompatibility(ok, topo, 4) is None
    robust_top = HierFAVGConfig(
        kappa1=2, kappa2=2,
        aggregators=AggregatorSpec(
            aggregators=(parse_aggregator("weighted_mean"), parse_aggregator("median"))
        ),
    )
    assert "top-level" in sharding_incompatibility(robust_top, topo, 4)
    # robust edge sync over a packing that is ragged across shards
    ragged = parse_fanouts("4,2,1/3")
    robust_edge = HierFAVGConfig(
        kappa1=2, kappa2=2,
        aggregators=AggregatorSpec(
            aggregators=(parse_aggregator("trimmed_mean:0.25"), parse_aggregator("weighted_mean"))
        ),
    )
    assert sharding_incompatibility(robust_edge, topo, 4) is None
    assert "segment layout" in sharding_incompatibility(robust_edge, ragged, 2)
    # too many shards surfaces the placement error as the reason
    assert "subtree" in sharding_incompatibility(ok, FedTopology(2, 4), 4)


def test_client_member_rejects_indivisible_counts():
    class _FakeMesh:
        axis_names = ("clients",)
        shape = {"clients": 4}

    rules = ShardingRules(mesh=_FakeMesh(), client_axes=("clients",))
    assert rules._client_member(8) == "clients"
    with pytest.raises(ValueError, match="not divisible"):
        rules._client_member(6)
    # no client axes configured is not an error (serving rules)
    assert ShardingRules(mesh=_FakeMesh(), client_axes=())._client_member(6) is None


# ---------------------------------------------------------------------------
# shard_map parity vs the single-device superround
# ---------------------------------------------------------------------------

def _quad(rng, n):
    centers = rng.normal(size=(n, DIM))
    sizes = rng.integers(1, 4, size=n).astype(np.float64)

    def loss_fn(params, batch, _rng):
        return 0.5 * jnp.sum((params["w"] - batch["c"]) ** 2)

    batch = {"c": jnp.asarray(centers, jnp.float32)}
    return sizes, loss_fn, batch


def _pad_state(state, placement, n):
    gather = jnp.asarray(placement.gather_index())

    def pad_tree(t):
        return jax.tree_util.tree_map(
            lambda x: jnp.take(x, gather, axis=0)
            if getattr(x, "ndim", 0) >= 1 and x.shape[0] == n
            else x,
            t,
        )

    return state._replace(
        params=pad_tree(state.params),
        opt_state=pad_tree(state.opt_state),
        anchor=None if state.anchor is None else pad_tree(state.anchor),
        residual=None if state.residual is None else pad_tree(state.residual),
    )


def _drive_pair(topo, cfg, num_shards, *, opt=None, masks=None, intervals=2, seed=0):
    """Run `intervals` cloud intervals through (a) the single-device
    superround and (b) the client-sharded superround over `num_shards`
    devices; return both final states (sharded one un-permuted back to
    canonical order) plus both metric views."""
    opt = opt or sgd(0.1)
    spec = as_hierarchy(topo)
    n = spec.num_clients
    sizes, loss_fn, batch = _quad(np.random.default_rng(seed), n)
    w = jnp.asarray(sizes, jnp.float32)
    k1, k2 = cfg.kappa1, cfg.kappa2_effective
    mesh = client_mesh(num_shards)
    placement = plan_shard_placement(spec, num_shards)

    s1 = init_state(jax.random.PRNGKey(0), {"w": jnp.zeros(DIM)}, opt, topo, cfg)
    s2 = init_state(jax.random.PRNGKey(0), {"w": jnp.zeros(DIM)}, opt, topo, cfg)
    sup = jax.jit(build_super_round(loss_fn, opt, topo, cfg, w), donate_argnums=(0,))
    shsup = jax.jit(
        build_sharded_super_round(loss_fn, opt, topo, cfg, w, mesh=mesh, placement=placement),
        donate_argnums=(0,),
    )
    block = jax.tree_util.tree_map(
        lambda x: jnp.stack([x] * (k2 * k1)).reshape((k2, k1) + x.shape), batch
    )
    gather = placement.gather_index()
    s2 = _pad_state(s2, placement, n)
    s2 = jax.device_put(
        s2, fed_state_shardings(mesh, "clients", s2, placement.padded_clients)
    )
    block_sh = jax.tree_util.tree_map(
        lambda x: jax.device_put(
            jnp.take(x, jnp.asarray(gather), axis=2), batch_block_sharding(mesh, "clients")
        ),
        block,
    )
    valid = placement.valid()
    m1_all, m2_all = [], []
    for q in range(intervals):
        if masks is None:
            m1 = m2 = None
        else:
            st = np.stack(masks[q * k2 : (q + 1) * k2]).astype(np.float32)
            m1 = jnp.asarray(st)
            m2 = jax.device_put(
                jnp.asarray(st[:, gather] * valid[None, :]),
                mask_stack_sharding(mesh, "clients"),
            )
        s1, mt1 = sup(s1, block, m1)
        s2, mt2 = shsup(s2, block_sh, m2)
        m1_all.append(jax.device_get(mt1))
        m2_all.append(jax.device_get(mt2))
    pos = jnp.asarray(placement.positions())
    unpad = lambda t: jax.tree_util.tree_map(
        lambda x: jnp.take(x, pos, axis=0)
        if getattr(x, "ndim", 0) >= 1 and x.shape[0] == placement.padded_clients
        else x,
        t,
    )
    s2 = s2._replace(
        params=unpad(s2.params),
        opt_state=unpad(s2.opt_state),
        anchor=None if s2.anchor is None else unpad(s2.anchor),
        residual=None if s2.residual is None else unpad(s2.residual),
    )
    return s1, s2, m1_all, m2_all, placement


def _assert_states_close(s1, s2):
    """The documented mesh tolerance: every sub-top reduction and local step
    is order-identical, only the cloud psum reassociates the weighted sum,
    so states agree to ~1 ULP per cloud boundary (rtol 3e-6)."""
    for t1, t2, what in [
        (s1.params, s2.params, "params"),
        (s1.opt_state, s2.opt_state, "opt_state"),
        (s1.anchor, s2.anchor, "anchor"),
        (s1.residual, s2.residual, "residual"),
    ]:
        l1 = jax.tree_util.tree_leaves(t1)
        l2 = jax.tree_util.tree_leaves(t2)
        assert len(l1) == len(l2), what
        for a, b in zip(l1, l2):
            np.testing.assert_allclose(
                np.asarray(a), np.asarray(b), rtol=3e-6, atol=2e-7, err_msg=what
            )
    assert int(s1.step) == int(s2.step)


def _assert_metrics_close(m1_all, m2_all, placement):
    valid = placement.valid()
    for mt1, mt2 in zip(m1_all, m2_all):
        loss1 = np.asarray(mt1["loss"])  # (κ₂,)
        loss2 = np.asarray(mt2["loss"])[:, :, valid].mean(axis=(1, 2))
        np.testing.assert_allclose(loss1, loss2, rtol=1e-5, atol=1e-7)
        np.testing.assert_array_equal(np.asarray(mt1["step"]), np.asarray(mt2["step"]))
        gn1 = np.asarray(mt1["grad_norm"])
        gsq = np.asarray(mt2["gsq"])[:, :, valid]
        gn2 = np.sqrt(gsq.sum(axis=2)).mean(axis=1)
        np.testing.assert_allclose(gn1, gn2, rtol=1e-5, atol=1e-7)


def test_sharded_superround_single_shard_everywhere():
    """The full sharded path over a 1-device mesh — runs in every
    environment, so tier-1 always exercises shard_map + psum lowering."""
    topo = FedTopology(num_edges=2, clients_per_edge=3)
    cfg = HierFAVGConfig(kappa1=2, kappa2=3)
    s1, s2, m1, m2, placement = _drive_pair(topo, cfg, 1)
    _assert_states_close(s1, s2)
    _assert_metrics_close(m1, m2, placement)


@needs4
def test_sharded_superround_uniform():
    topo = FedTopology(num_edges=4, clients_per_edge=2)
    cfg = HierFAVGConfig(kappa1=2, kappa2=3)
    s1, s2, m1, m2, placement = _drive_pair(topo, cfg, 4)
    assert placement.num_phantoms == 0
    _assert_states_close(s1, s2)
    _assert_metrics_close(m1, m2, placement)


@needs4
def test_sharded_superround_ragged_padded():
    """Ragged edges force phantom padding; padding must be numerically
    inert (zero weight, dedicated segment)."""
    spec = parse_fanouts("3,2,3,2/4")
    cfg = HierFAVGConfig(kappa1=2, kappa2=2)
    s1, s2, m1, m2, placement = _drive_pair(spec, cfg, 4)
    assert placement.num_phantoms > 0
    _assert_states_close(s1, s2)
    _assert_metrics_close(m1, m2, placement)


@needs4
def test_sharded_superround_masks_with_dead_edge():
    topo = FedTopology(num_edges=4, clients_per_edge=2)
    cfg = HierFAVGConfig(kappa1=2, kappa2=3)
    masks = [np.ones(8, np.float32) for _ in range(6)]
    masks[1][3] = 0.0
    masks[2][:2] = 0.0  # edge 0 entirely dead at a boundary
    masks[5][0] = 0.0  # masked client at the cloud boundary
    s1, s2, m1, m2, placement = _drive_pair(topo, cfg, 4, masks=masks)
    _assert_states_close(s1, s2)
    _assert_metrics_close(m1, m2, placement)


@needs4
def test_sharded_superround_int8_ef_transport():
    """Compressed uplinks: anchor re-sync, EF residual carry, and the
    keep-dead logic all stay shard-local (plus a masked round)."""
    topo = FedTopology(num_edges=4, clients_per_edge=2)
    cfg = HierFAVGConfig(
        kappa1=2, kappa2=2, transport=TransportSpec.parse("int8_ef:64/int8_ef:64")
    )
    masks = [np.ones(8, np.float32) for _ in range(4)]
    masks[1][2] = 0.0
    s1, s2, m1, m2, placement = _drive_pair(topo, cfg, 4, masks=masks)
    assert s2.residual is not None
    _assert_states_close(s1, s2)


@needs4
def test_sharded_superround_trimmed_edge_aggregator():
    topo = FedTopology(num_edges=4, clients_per_edge=3)
    cfg = HierFAVGConfig(
        kappa1=2, kappa2=2,
        aggregators=AggregatorSpec(
            aggregators=(parse_aggregator("trimmed_mean:0.25"), parse_aggregator("weighted_mean"))
        ),
    )
    masks = [np.ones(12, np.float32) for _ in range(4)]
    masks[0][5] = 0.0
    s1, s2, m1, m2, placement = _drive_pair(topo, cfg, 4, masks=masks)
    _assert_states_close(s1, s2)


@needs4
def test_sharded_superround_sync_opt_state():
    """Momentum state rides the same packed cloud psum as the params."""
    topo = FedTopology(num_edges=4, clients_per_edge=2)
    cfg = HierFAVGConfig(kappa1=2, kappa2=2, sync_opt_state=True)
    s1, s2, _, _, _ = _drive_pair(topo, cfg, 4, opt=momentum(0.1, 0.9))
    _assert_states_close(s1, s2)


@needs4
def test_sharded_edge_sync_bitexact():
    """Edge aggregation is collective-free AND bit-exact under sharding:
    placement keeps each edge whole and preserves member order, so the
    shard-local reduction adds the same values in the same order."""
    topo = FedTopology(num_edges=4, clients_per_edge=3)
    cfg = HierFAVGConfig(kappa1=1, kappa2=2)
    spec = as_hierarchy(topo)
    rng = np.random.default_rng(3)
    sizes = rng.integers(1, 4, size=12).astype(np.float64)
    w = jnp.asarray(sizes, jnp.float32)
    opt = sgd(0.1)
    state = init_state(jax.random.PRNGKey(0), {"w": jnp.zeros(DIM)}, opt, topo, cfg)
    state = state._replace(
        params={"w": jnp.asarray(rng.normal(size=(12, DIM)), jnp.float32)}
    )
    ref = build_level_sync(topo, cfg, w, 1)(state).params["w"]

    mesh = client_mesh(4)
    placement = plan_shard_placement(spec, 4)
    shard = ClientSharding.build("clients", placement, w)
    sync = build_level_sync(topo, cfg, w, 1, shard=shard)
    padded = _pad_state(state, placement, 12)
    specs = fed_state_partition_specs(padded, "clients", placement.padded_clients)
    with mesh:
        out = shard_map(
            lambda s: sync(s), mesh=mesh, in_specs=(specs,), out_specs=specs,
            check_rep=False,
        )(padded)
    got = np.asarray(out.params["w"])[placement.positions()]
    np.testing.assert_array_equal(np.asarray(ref), got)


def test_sharded_superround_one_collective_per_interval():
    """The acceptance check: exactly one cross-device collective (psum) in
    the whole cloud-interval program, for a 2-level topology — with and
    without sync_opt_state (opt leaves ride the same packed psum)."""
    topo = FedTopology(num_edges=4, clients_per_edge=2)
    n = 8
    sizes, loss_fn, batch = _quad(np.random.default_rng(0), n)
    w = jnp.asarray(sizes, jnp.float32)
    opt = sgd(0.1)
    shards = min(4, jax.device_count())
    mesh = client_mesh(shards)
    placement = plan_shard_placement(as_hierarchy(topo), shards)
    for cfg in (
        HierFAVGConfig(kappa1=2, kappa2=3),
        HierFAVGConfig(kappa1=2, kappa2=3, sync_opt_state=True),
    ):
        state = init_state(jax.random.PRNGKey(0), {"w": jnp.zeros(DIM)}, opt, topo, cfg)
        state = _pad_state(state, placement, n)
        block = jax.tree_util.tree_map(
            lambda x: jnp.take(
                jnp.stack([x] * 6).reshape((3, 2) + x.shape),
                jnp.asarray(placement.gather_index()), axis=2,
            ),
            batch,
        )
        fn = build_sharded_super_round(
            loss_fn, opt, topo, cfg, w, mesh=mesh, placement=placement
        )
        jaxpr = str(jax.make_jaxpr(fn)(state, block, None))
        assert jaxpr.count(" psum") == 1, "expected exactly one psum per cloud interval"


def test_sharded_superround_donation():
    """donate_argnums must release the sharded input FedState's buffers —
    the zero-copy claim survives shard_map."""
    topo = FedTopology(num_edges=2, clients_per_edge=3)
    cfg = HierFAVGConfig(kappa1=2, kappa2=2)
    n = 6
    sizes, loss_fn, batch = _quad(np.random.default_rng(0), n)
    w = jnp.asarray(sizes, jnp.float32)
    opt = sgd(0.1)
    shards = min(2, jax.device_count())
    mesh = client_mesh(shards)
    placement = plan_shard_placement(as_hierarchy(topo), shards)
    state = init_state(jax.random.PRNGKey(0), {"w": jnp.zeros(DIM)}, opt, topo, cfg)
    state = _pad_state(state, placement, n)
    state = jax.device_put(
        state, fed_state_shardings(mesh, "clients", state, placement.padded_clients)
    )
    donated_leaf = state.params["w"]
    block = jax.tree_util.tree_map(
        lambda x: jax.device_put(
            jnp.take(
                jnp.stack([x] * 4).reshape((2, 2) + x.shape),
                jnp.asarray(placement.gather_index()), axis=2,
            ),
            batch_block_sharding(mesh, "clients"),
        ),
        batch,
    )
    fn = jax.jit(
        build_sharded_super_round(loss_fn, opt, topo, cfg, w, mesh=mesh, placement=placement),
        donate_argnums=(0,),
    )
    out, _ = fn(state, block, None)
    jax.block_until_ready(out.params)
    assert donated_leaf.is_deleted(), "donated sharded input buffer was not released"
    assert not jax.tree_util.tree_leaves(out.params)[0].is_deleted()


# ---------------------------------------------------------------------------
# runner + ExperimentSpec integration
# ---------------------------------------------------------------------------

def _mesh_spec(extra=()):
    return ExperimentSpec.parse(
        [
            "topology.num_edges=4", "topology.clients_per_edge=4",
            "schedule.kappas=2,3", "run.num_rounds=6", "run.eval_every=3",
            "data.num_samples=320", "failures.p_fail=0.2",
        ]
        + list(extra)
    )


@needs4
def test_runner_mesh_parity_end_to_end():
    """A mesh-configured spec runs whole cloud intervals through the
    sharded engine (no per-round fallback) and reproduces the single-device
    history: steps, masks, losses, eval accuracy."""
    out = {}
    for tag, extra in [("single", []), ("mesh", ["topology.mesh_axes=clients:4"])]:
        runner, state = _mesh_spec(extra).run_experiment()
        out[tag] = (runner, runner.records_to_dict(), np.asarray(state.params["w1"]))
    runner_m, rec_m, p_m = out["mesh"]
    _, rec_s, p_s = out["single"]
    assert runner_m.mesh is not None
    assert runner_m._engine is not None and runner_m._engine.mesh is not None
    np.testing.assert_allclose(p_s, p_m, rtol=3e-6, atol=2e-7)
    np.testing.assert_allclose(rec_s["loss"], rec_m["loss"], rtol=1e-5)
    assert rec_s["step"] == rec_m["step"]
    assert rec_s["mask_alive"] == rec_m["mask_alive"]
    for a, b in zip(rec_s["accuracy"], rec_m["accuracy"]):
        assert (a is None) == (b is None)
        if a is not None:
            assert abs(a - b) < 0.02


@needs4
def test_runner_mesh_unshardable_falls_back_per_round():
    """engine='auto' + a schedule the sharded path cannot lower (robust
    cloud aggregator) must still train — via the per-round loop."""
    spec = _mesh_spec(
        ["topology.mesh_axes=clients:4", "aggregators.levels=weighted_mean/median"]
    )
    runner, state = spec.run_experiment()
    assert runner._engine is None  # fell back: no superround engine built
    assert runner._mesh_reason and "top-level" in runner._mesh_reason
    assert [r.round for r in runner.history] == list(range(6))


def test_topology_spec_mesh_axes_roundtrip_and_errors():
    spec = ExperimentSpec.parse(["topology.mesh_axes=clients:2"])
    assert spec.topology.mesh_axes == "clients:2"
    again = ExperimentSpec.from_dict(spec.to_dict())
    assert again == spec
    assert "mesh=clients:2" in spec.describe()
    # oversubscribing visible devices names the XLA_FLAGS recipe
    with pytest.raises(ValueError, match="xla_force_host_platform_device_count"):
        ExperimentSpec.parse(["topology.mesh_axes=clients:4096"]).build()
    with pytest.raises(ValueError, match="mesh_axes"):
        ExperimentSpec.parse(["topology.mesh_axes=clients:two"]).build()
