"""Semi-synchronous tiered engine: deadline/quorum scheduling, staleness
decay, fault injection, and the bit-exact parity contract with the
synchronous superround engine (fed.deadline + fed.engine.DeadlineEngine +
core.hierfavg.build_deadline_super_round)."""
import json

import jax
import numpy as np
import pytest

from repro.fed.api import ExperimentSpec
from repro.fed.deadline import (
    EdgeCadenceModel,
    SemiSyncScheduler,
    StalenessPolicy,
    parse_staleness,
)
from repro.fed.failures import StragglerModel


# ---------------------------------------------------------------------------
# Staleness policies
# ---------------------------------------------------------------------------


def test_staleness_grammar_and_math():
    s = np.arange(5)
    np.testing.assert_array_equal(parse_staleness("constant").weights(s), np.ones(5))
    poly = parse_staleness("poly:2")
    np.testing.assert_allclose(poly.weights(s), (1.0 + s) ** -2.0)
    exp = parse_staleness("exp:0.5")
    np.testing.assert_allclose(exp.weights(s), np.exp(-0.5 * s))
    assert parse_staleness("constant").is_trivial
    assert parse_staleness("poly:0").is_trivial
    assert not exp.is_trivial
    assert exp.describe() == "exp:0.5"


def test_staleness_weight_is_exactly_one_at_zero():
    """The parity contract rides on this: an on-time update is weighted at
    exactly 1.0 under every policy, so a trivial plan's gate is all-ones."""
    for text in ("constant", "poly:1.7", "exp:0.3"):
        w = parse_staleness(text).weights(np.zeros(3))
        assert (w == 1.0).all(), text


def test_staleness_parse_errors():
    for bad in ("poly", "poly:x", "exp:", "poly:-1", "linear:2", "constant:3"):
        with pytest.raises(ValueError):
            parse_staleness(bad)


# ---------------------------------------------------------------------------
# Edge cadence
# ---------------------------------------------------------------------------


def test_cadence_deterministic_and_resumable():
    a = EdgeCadenceModel(4, 2.0, speed="lognormal:0.5", jitter="lognormal:0.2", seed=7)
    b = EdgeCadenceModel(4, 2.0, speed="lognormal:0.5", jitter="lognormal:0.2", seed=7)
    np.testing.assert_array_equal(a.slowness, b.slowness)
    np.testing.assert_array_equal(a.interval_durations(), b.interval_durations())
    snap = a.state_dict()
    ahead = [a.interval_durations() for _ in range(3)]
    b.load_state_dict(snap)
    for d in ahead:
        np.testing.assert_array_equal(d, b.interval_durations())


def test_cadence_det_is_uniform():
    c = EdgeCadenceModel(3, 1.5)
    np.testing.assert_array_equal(c.slowness, np.ones(3))
    np.testing.assert_array_equal(c.interval_durations(), np.full(3, 1.5))


def test_cadence_from_stragglers_per_edge_max_and_no_rng_draw():
    """An edge finishes when its slowest client does; deriving the cadence
    must not consume the straggler model's RNG stream (which drives the
    training-visible survival masks)."""
    model = StragglerModel(6, mean_step_s=2.0, sigma=0.6, seed=3)
    twin = StragglerModel(6, mean_step_s=2.0, sigma=0.6, seed=3)
    segments = np.array([0, 0, 0, 1, 1, 1])
    cad = EdgeCadenceModel.from_stragglers(model, segments, 2, kappa1=4, seed=0)
    np.testing.assert_array_equal(
        cad.slowness, [model.slowness[:3].max(), model.slowness[3:].max()]
    )
    assert cad.base_interval_s == 4 * 2.0
    # the twin never produced a cadence: masks must still match draw-for-draw
    np.testing.assert_array_equal(
        model.survivors(4, None)[0], twin.survivors(4, None)[0]
    )


def test_cadence_from_stragglers_clientless_edge_nominal():
    model = StragglerModel(2, sigma=0.5, seed=1)
    cad = EdgeCadenceModel.from_stragglers(model, np.array([0, 0]), 3, kappa1=2)
    assert cad.slowness[1] == 1.0 and cad.slowness[2] == 1.0


# ---------------------------------------------------------------------------
# Scheduler semantics
# ---------------------------------------------------------------------------


def _uniform_sched(**kw):
    return SemiSyncScheduler(EdgeCadenceModel(4, 1.0), **kw)


def _slow_edge_sched(slow=6.0, **kw):
    cad = EdgeCadenceModel(4, 1.0, slowness=np.array([1.0, 1.0, 1.0, slow]))
    return SemiSyncScheduler(cad, **kw)


def test_barrier_plans_are_trivial():
    sched = _uniform_sched(quorum=1.0)
    assert sched.is_barrier
    for r in range(5):
        plan = sched.next_round()
        assert plan.is_trivial
        assert plan.folded.all() and (plan.weights == 1.0).all()
        assert plan.close == pytest.approx(r + 1.0)  # lockstep clock


def test_quorum_leaves_slow_edge_behind_then_folds_it_stale():
    sched = _slow_edge_sched(quorum=0.75, staleness="poly:1", max_staleness=5)
    p0 = sched.next_round()
    np.testing.assert_array_equal(p0.folded, [True, True, True, False])
    assert p0.close == pytest.approx(1.0)  # 3rd of the fast arrivals
    assert not p0.is_trivial
    # fast edges restart, slow edge stays in flight with its original finish
    p1 = sched.next_round()
    np.testing.assert_array_equal(p1.arrivals[3], 6.0)
    # ... until its upload lands; it then folds at poly-decayed weight
    stale_fold = None
    for _ in range(8):
        p = sched.next_round()
        if p.folded[3]:
            stale_fold = p
            break
    assert stale_fold is not None
    s = stale_fold.staleness[3]
    assert s > 0
    assert stale_fold.weights[3] == pytest.approx((1.0 + s) ** -1.0)
    assert (stale_fold.weights[:3] == 1.0).all()  # on-time edges undecayed


def test_fedbuff_buffer_size_overrides_quorum():
    cad = EdgeCadenceModel(4, 1.0, slowness=np.array([1.0, 2.0, 3.0, 4.0]))
    sched = SemiSyncScheduler(cad, buffer_size=2, quorum=1.0, max_staleness=10)
    plan = sched.next_round()
    assert plan.close == pytest.approx(2.0)  # K=2nd arrival, quorum ignored
    np.testing.assert_array_equal(plan.folded, [True, True, False, False])


def test_timeout_caps_close_but_never_before_first_arrival():
    cad = EdgeCadenceModel(3, 1.0, slowness=np.array([1.0, 5.0, 9.0]))
    sched = SemiSyncScheduler(cad, quorum=1.0, timeout_s=3.0, max_staleness=10)
    plan = sched.next_round()
    assert plan.close == pytest.approx(3.0)  # capped below the barrier's 9.0
    np.testing.assert_array_equal(plan.folded, [True, False, False])
    # timeout shorter than every arrival: wait for the first upload anyway
    tight = SemiSyncScheduler(
        EdgeCadenceModel(2, 1.0, slowness=np.array([2.0, 4.0])),
        quorum=1.0, timeout_s=0.5, max_staleness=10,
    )
    p = tight.next_round()
    assert p.close == pytest.approx(2.0) and p.folded[0]


def test_max_staleness_is_a_hard_bound():
    sched = _slow_edge_sched(slow=10.0, quorum=0.5, max_staleness=2)
    seen = []
    for _ in range(12):
        p = sched.next_round()
        seen.append(int(p.staleness.max()))
        # a live edge at the bound forces the round to wait for it
        assert (sched.staleness <= 2).all()
    assert max(seen) == 2  # the bound is reached, never exceeded


def test_dropout_retries_then_abandons():
    cad = EdgeCadenceModel(1, 1.0)
    sched = SemiSyncScheduler(
        cad, quorum=1.0, edge_drop_rate=0.6, retry_limit=1, seed=12,
        max_staleness=50,
    )
    saw_drop = saw_retry_fold = saw_exhaust = False
    prev = None
    for _ in range(40):
        plan = sched.next_round()
        if plan.dropped[0]:
            assert plan.weights[0] == 0.0 and not plan.folded[0]
            saw_drop = True
        if prev is not None and prev.dropped[0]:
            # a retried upload is ready immediately at the new round's start
            if plan.arrivals[0] == plan.start:
                saw_retry_fold = saw_retry_fold or bool(plan.folded[0])
            else:
                # retry exhausted: the edge recomputed a fresh interval
                assert plan.arrivals[0] > plan.start
                saw_exhaust = True
        prev = plan
    assert saw_drop and saw_retry_fold and saw_exhaust


def test_dead_edges_excluded_from_quorum_and_fold():
    cad = EdgeCadenceModel(2, 1.0, slowness=np.array([1.0, 3.0]))
    sched = SemiSyncScheduler(cad, quorum=1.0, max_staleness=10)
    plan = sched.next_round(dead=np.array([False, True]))
    np.testing.assert_array_equal(plan.dead, [False, True])
    np.testing.assert_array_equal(plan.folded, [True, False])
    assert plan.close == pytest.approx(1.0)  # did not wait for the dead edge
    assert not plan.is_trivial  # the dead edge must not receive the broadcast


def test_total_outage_closes_immediately():
    sched = _uniform_sched()
    plan = sched.next_round(dead=np.ones(4, bool))
    assert plan.close == plan.start and not plan.folded.any()


def test_scheduler_state_roundtrip_mid_stream():
    def plans_equal(a, b):
        for x, y in zip(a, b):
            for fa, fb in zip(x, y):
                np.testing.assert_array_equal(np.asarray(fa), np.asarray(fb))

    def make():
        cad = EdgeCadenceModel(
            4, 1.0, speed="lognormal:0.5", jitter="lognormal:0.2", seed=5
        )
        return SemiSyncScheduler(
            cad, quorum=0.5, staleness="exp:0.4", edge_drop_rate=0.3,
            retry_limit=2, max_staleness=3, seed=5,
        )

    a = make()
    for _ in range(3):
        a.next_round()
    snap = a.state_dict()
    ahead = [a.next_round() for _ in range(5)]
    b = make()
    for _ in range(1):  # different position: load must fully overwrite
        b.next_round()
    b.load_state_dict(snap)
    plans_equal(ahead, [b.next_round() for _ in range(5)])


def test_scheduler_state_survives_json():
    """The state rides checkpoint metadata, which is JSON on disk — the
    manager's ndarray encoding must round-trip it exactly."""
    from repro.checkpoint.manager import _jsonable, _unjsonable

    a = _uniform_sched(quorum=0.5, edge_drop_rate=0.2, seed=9)
    for _ in range(3):
        a.next_round()
    wire = json.loads(json.dumps(_jsonable(a.state_dict())))
    b = _uniform_sched(quorum=0.5, edge_drop_rate=0.2, seed=9)
    b.load_state_dict(_unjsonable(wire))
    pa, pb = a.next_round(), b.next_round()
    for fa, fb in zip(pa, pb):
        np.testing.assert_array_equal(np.asarray(fa), np.asarray(fb))


def test_scheduler_validation_errors():
    cad = EdgeCadenceModel(2, 1.0)
    for kw in (
        {"quorum": 0.0},
        {"quorum": 1.5},
        {"timeout_s": -1.0},
        {"buffer_size": 3},
        {"max_staleness": -1},
        {"edge_drop_rate": 1.0},
        {"retry_limit": -1},
        {"intervals_per_round": 0},
    ):
        with pytest.raises(ValueError):
            SemiSyncScheduler(cad, **kw)


# ---------------------------------------------------------------------------
# Engine integration: parity contract, wall clock, resume
# ---------------------------------------------------------------------------


def _small_spec(*overrides):
    return ExperimentSpec.parse(
        [
            "topology.num_edges=3",
            "topology.clients_per_edge=4",
            "schedule.kappas=2,4",
            "data.num_samples=400",
            "run.num_rounds=8",
            "run.eval_every=4",
            *overrides,
        ]
    )


def _history_rows(runner, skip=()):
    import dataclasses as dc

    return [
        tuple(getattr(h, f.name) for f in dc.fields(h) if f.name not in skip)
        for h in runner.history
    ]


def _assert_params_equal(a, b):
    for x, y in zip(jax.tree_util.tree_leaves(a), jax.tree_util.tree_leaves(b)):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


def test_parity_contract_barrier_is_bit_exact():
    """Tier-1 gate: uniform cadences + full quorum + trivial staleness
    reproduce the synchronous superround engine bit-exactly — params and
    history (the event clock is the one additive new column)."""
    r_sync, s_sync = _small_spec().run_experiment()
    r_dl, s_dl = _small_spec(
        "deadline.enabled=true", "deadline.quorum=1.0"
    ).run_experiment()
    from repro.fed.engine import DeadlineEngine, SuperRoundEngine

    assert type(r_sync._engine) is SuperRoundEngine
    assert type(r_dl._engine) is DeadlineEngine
    _assert_params_equal(s_sync.params, s_dl.params)
    _assert_params_equal(s_sync.opt_state, s_dl.opt_state)
    np.testing.assert_array_equal(np.asarray(s_sync.rng), np.asarray(s_dl.rng))
    assert _history_rows(r_sync, skip=("wall_clock_s",)) == _history_rows(
        r_dl, skip=("wall_clock_s",)
    )
    # the synchronous engine has no event clock; the deadline engine's is
    # strictly increasing
    assert all(h.wall_clock_s == 0.0 for h in r_sync.history)
    walls = [h.wall_clock_s for h in r_dl.history]
    assert all(b > a for a, b in zip(walls, walls[1:])) and walls[0] > 0


def test_parity_contract_with_stragglers():
    """Client-level straggler masks keep the stock executable as long as no
    whole edge dies: the deadline barrier must stay bit-exact under them."""
    ov = ("failures.straggler_sigma=0.3", "failures.straggler_mean_s=1.0")
    r_sync, s_sync = _small_spec(*ov).run_experiment()
    r_dl, s_dl = _small_spec(
        *ov, "deadline.enabled=true", "deadline.quorum=1.0",
        "deadline.edge_jitter=det",
    ).run_experiment()
    _assert_params_equal(s_sync.params, s_dl.params)
    assert _history_rows(r_sync, skip=("wall_clock_s",)) == _history_rows(
        r_dl, skip=("wall_clock_s",)
    )
    # with stragglers the cadence derives from the model's slowness tail
    assert r_dl.deadline.cadence.base_interval_s == pytest.approx(2.0)
    assert r_dl.deadline.cadence.slowness.max() > 1.0


def test_deadline_run_quorum_heterogeneous():
    spec = _small_spec(
        "deadline.enabled=true", "deadline.quorum=0.67",
        "deadline.edge_speed=lognormal:0.6", "deadline.staleness=poly:0.5",
        "deadline.max_staleness=3",
    )
    runner, state = spec.run_experiment()
    assert len(runner.history) == 8
    walls = [h.wall_clock_s for h in runner.history]
    assert all(b > a for a, b in zip(walls, walls[1:]))
    assert runner.history[-1].accuracy is not None
    for leaf in jax.tree_util.tree_leaves(state.params):
        assert np.isfinite(np.asarray(leaf)).all()


def test_deadline_resume_parity(tmp_path):
    """Interrupted + resumed == straight run, bit for bit: the checkpoint
    carries the scheduler's event queue + staleness state (mirroring the
    cohort resume-parity contract)."""
    def overrides(ckpt_dir):
        return (
            "deadline.enabled=true", "deadline.quorum=0.67",
            "deadline.edge_speed=lognormal:0.6", "deadline.staleness=poly:1",
            "deadline.edge_drop_rate=0.2", "deadline.seed=3",
            "run.checkpoint_every=4", f"run.checkpoint_dir={ckpt_dir}",
        )

    straight, s_straight = _small_spec(*overrides(tmp_path / "a")).run_experiment()

    _small_spec(*overrides(tmp_path / "b"), "run.num_rounds=4").run_experiment()
    resumed_spec = _small_spec(*overrides(tmp_path / "b"))
    resumed, s_resumed = resumed_spec.run_experiment(resume=True)

    _assert_params_equal(s_straight.params, s_resumed.params)
    _assert_params_equal(s_straight.opt_state, s_resumed.opt_state)
    np.testing.assert_array_equal(np.asarray(s_straight.rng), np.asarray(s_resumed.rng))
    # the resumed history covers rounds 4..7; rows must match the straight
    # run's tail field-for-field, wall clock included
    assert _history_rows(resumed) == _history_rows(straight)[4:]


def test_deadline_engine_rejects_bad_cadences():
    spec = _small_spec("deadline.enabled=true", "run.eval_every=3")
    with pytest.raises(ValueError, match="eval_every"):
        spec.run_experiment()
    spec = _small_spec("deadline.enabled=true", "run.engine=per_round")
    with pytest.raises(ValueError, match="per_round"):
        spec.run_experiment()
    spec = _small_spec("deadline.enabled=true", "run.engine=megakernel")
    with pytest.raises(ValueError, match="megakernel"):
        spec.run_experiment()


def test_deadline_rejects_transport_and_delta():
    from repro.core.hierfavg import deadline_incompatibility

    spec = _small_spec("deadline.enabled=true", "transport.levels=identity/int8:128")
    with pytest.raises(ValueError, match="transport|delta|desync"):
        spec.run_experiment()
    spec2 = _small_spec("deadline.enabled=true", "schedule.delta_cloud=true")
    with pytest.raises(ValueError):
        spec2.run_experiment()
    cfg = _small_spec().hier_config()
    topo = _small_spec().topology.build()
    assert deadline_incompatibility(cfg, topo) is None


# ---------------------------------------------------------------------------
# Spec plumbing: serialization, deprecation, scenarios
# ---------------------------------------------------------------------------


def test_deadline_spec_roundtrips():
    spec = _small_spec(
        "deadline.enabled=true", "deadline.buffer_size=2",
        "deadline.staleness=exp:0.7", "deadline.timeout_s=5.5",
    )
    again = ExperimentSpec.from_json(spec.to_json())
    assert again == spec
    assert again.deadline.buffer_size == 2
    assert "deadline[buffer=2,exp:0.7]" in spec.describe()


def test_async_cloud_deprecation_maps_to_deadline():
    spec = _small_spec("schedule.async_cloud=true")
    with pytest.warns(DeprecationWarning, match="deadline"):
        runner = spec.build()
    assert runner.deadline is not None
    assert runner.deadline.quorum == pytest.approx(0.5)
    assert runner.deadline.policy.describe() == "poly:1"
    # an explicit deadline section wins silently over the deprecated flag
    import warnings

    spec2 = _small_spec("schedule.async_cloud=true", "deadline.enabled=true")
    with warnings.catch_warnings():
        warnings.simplefilter("error")
        runner2 = spec2.build()
    assert runner2.deadline.quorum == pytest.approx(1.0)


def test_deadline_scenarios_registered_and_overridable():
    from repro.fed import scenarios

    for name in ("deadline_straggler", "fedbuff_k4", "stale_decay"):
        assert name in scenarios.names()
        spec = scenarios.get(name, overrides=["run.num_rounds=8", "deadline.quorum=0.9"])
        assert spec.deadline.enabled and spec.run.num_rounds == 8
        if not spec.deadline.buffer_size:
            assert spec.deadline.quorum == pytest.approx(0.9)
        # --set round-trip: dict form rebuilds the identical spec
        assert ExperimentSpec.from_dict(spec.to_dict()) == spec
        runner = spec.build()
        assert runner.deadline is not None
