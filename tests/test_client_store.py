"""ClientStateStore: gather/scatter round-trips, zero-init ≡ fresh optimizer
state, sticky-row extraction/replacement on real FedStates, and checkpoint
survival of the composite store pytree."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import CheckpointManager
from repro.core import HierFAVGConfig, init_cohort_state
from repro.fed.client_store import ClientStateStore, replace_sticky_rows, sticky_rows
from repro.optim import adam, momentum, sgd

N, C = 20, 4


def _template():
    return {"mu": np.zeros((3, 2), np.float32), "nu": np.zeros((3,), np.float32)}


def _rows(rng, count):
    return {
        "mu": rng.normal(size=(count, 3, 2)).astype(np.float32),
        "nu": rng.normal(size=(count, 3)).astype(np.float32),
    }


def test_scatter_gather_roundtrip_bitexact(rng):
    store = ClientStateStore(N, _template())
    ids = np.array([2, 7, 11, 19])
    rows = _rows(rng, C)
    store.scatter(ids, rows)
    got = store.gather(ids)
    for key in ("mu", "nu"):
        np.testing.assert_array_equal(got[key], rows[key])
    assert store.num_touched == C


def test_never_sampled_rows_are_zero(rng):
    """Zero rows == optimizer.init output, so first-time participants need
    no special casing on the gather path."""
    store = ClientStateStore(N, _template())
    store.scatter(np.array([0, 1, 2, 3]), _rows(rng, C))
    fresh = store.gather(np.array([10, 15]))
    for key in ("mu", "nu"):
        np.testing.assert_array_equal(fresh[key], np.zeros_like(fresh[key]))
    assert store.num_touched == C  # reads don't mark


def test_scatter_overwrites(rng):
    store = ClientStateStore(N, _template())
    ids = np.array([1, 3, 5, 7])
    store.scatter(ids, _rows(rng, C))
    second = _rows(rng, C)
    store.scatter(ids, second)
    np.testing.assert_array_equal(store.gather(ids)["mu"], second["mu"])
    assert store.num_touched == C


def test_scatter_validates_shapes(rng):
    store = ClientStateStore(N, _template())
    with pytest.raises(ValueError, match="row leaves"):
        store.scatter(np.array([0]), {"mu": np.zeros((1, 3, 2), np.float32)})
    with pytest.raises(ValueError, match="incompatible"):
        store.scatter(
            np.array([0]),
            {"mu": np.zeros((1, 3, 3), np.float32), "nu": np.zeros((1, 3), np.float32)},
        )


def test_from_rows_strips_cohort_axis(rng):
    rows = _rows(rng, C)
    store = ClientStateStore.from_rows(N, rows)
    assert store.gather(np.arange(N))["mu"].shape == (N, 3, 2)
    store.scatter(np.arange(C), rows)
    np.testing.assert_array_equal(store.gather(np.arange(C))["nu"], rows["nu"])


def test_empty_store_for_stateless_optimizer():
    """Plain SGD keeps no per-client rows: the store is empty and the cohort
    swap is a no-op (the engine skips gather/scatter entirely)."""
    cfg = HierFAVGConfig(kappa1=2, kappa2=2)
    state = init_cohort_state(jax.random.PRNGKey(0), {"w": jnp.zeros(3)}, sgd(0.1), cfg, C)
    rows = sticky_rows(state, C)
    assert rows["opt"] == [] and "res" not in rows
    store = ClientStateStore.from_rows(N, jax.device_get(rows))
    assert store.is_empty
    assert store.gather(np.arange(C))["opt"] == []


@pytest.mark.parametrize("opt_fn", [lambda: momentum(0.1, 0.9), lambda: adam(1e-3)])
def test_sticky_rows_roundtrip_on_fed_state(opt_fn):
    """sticky_rows ∘ replace_sticky_rows is the identity on the stacked
    leaves, and leaves shared (scalar) opt leaves untouched."""
    cfg = HierFAVGConfig(kappa1=2, kappa2=2)
    state = init_cohort_state(
        jax.random.PRNGKey(0), {"w": jnp.zeros((3, 2))}, opt_fn(), cfg, C
    )
    rows = sticky_rows(state, C)
    assert rows["opt"], "stateful optimizer must expose stacked rows"
    perturbed = {"opt": [x + 1.0 for x in rows["opt"]]}
    swapped = replace_sticky_rows(state, perturbed, C)
    back = sticky_rows(swapped, C)
    for a, b in zip(back["opt"], perturbed["opt"]):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    assert int(swapped.step) == int(state.step)  # shared leaves untouched


def test_store_survives_checkpoint_roundtrip(rng, tmp_path):
    """state()/load(): a store checkpointed through CheckpointManager comes
    back bit-exact, touched mask included."""
    store = ClientStateStore(N, _template())
    ids = np.array([2, 5, 13, 17])
    rows = _rows(rng, C)
    store.scatter(ids, rows)
    manager = CheckpointManager(str(tmp_path), keep=2)
    manager.save(1, {"store": store.state()}, {"round": 2})

    restored_store = ClientStateStore(N, _template())
    payload, meta = manager.restore_latest({"store": restored_store.state()})
    restored_store.load(payload["store"])
    assert meta["round"] == 2
    assert restored_store.num_touched == C
    for key in ("mu", "nu"):
        np.testing.assert_array_equal(restored_store.gather(ids)[key], rows[key])
    np.testing.assert_array_equal(
        restored_store.gather(np.array([0]))["mu"], np.zeros((1, 3, 2), np.float32)
    )


def test_load_validates_shapes():
    store = ClientStateStore(N, _template())
    bad = store.state()
    with pytest.raises(ValueError, match="store leaves"):
        store.load({"leaves": bad["leaves"][:1], "touched": bad["touched"]})
    with pytest.raises(ValueError, match="shape"):
        store.load(
            {"leaves": [np.zeros((N + 1, 3, 2), np.float32), np.zeros((N, 3), np.float32)],
             "touched": bad["touched"]}
        )


def test_nbytes_scales_with_population():
    small = ClientStateStore(10, _template())
    big = ClientStateStore(1000, _template())
    assert big.nbytes > 90 * small.nbytes  # logical size ∝ N (physical is page-lazy)
