"""Mixed-precision client state (``PrecisionSpec``): bf16 compute/state
with f32 aggregation arithmetic, the remat hook, spec serialization, and
per-engine leaf-dtype guarantees."""
import dataclasses
import json

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import FedTopology, HierFAVGConfig, PrecisionSpec, init_state
from repro.fed.api import ExperimentSpec
from repro.optim import momentum, sgd


# ---------------------------------------------------------------------------
# The spec itself
# ---------------------------------------------------------------------------


def test_precision_spec_validation():
    assert not PrecisionSpec().is_active
    assert PrecisionSpec(param_dtype="bfloat16").is_active
    assert PrecisionSpec(remat=True).is_active
    assert PrecisionSpec(param_dtype="bfloat16").dtype == jnp.dtype(jnp.bfloat16)
    # names normalize through jnp.dtype
    assert PrecisionSpec(param_dtype="float16").param_dtype == "float16"
    with pytest.raises(ValueError):
        PrecisionSpec(param_dtype="int8")
    with pytest.raises((ValueError, TypeError)):
        PrecisionSpec(param_dtype="not_a_dtype")


def test_hier_config_precision_field():
    cfg = HierFAVGConfig(kappa1=2, kappa2=2)
    assert not cfg.precision_active
    cfg = HierFAVGConfig(kappa1=2, kappa2=2, precision=PrecisionSpec(param_dtype="bfloat16"))
    assert cfg.precision_active
    with pytest.raises(TypeError):
        HierFAVGConfig(kappa1=2, kappa2=2, precision="bfloat16")


def test_experiment_spec_roundtrip_and_overrides():
    spec = ExperimentSpec().with_overrides(
        ["precision.param_dtype=bfloat16", "precision.remat=true"]
    )
    assert spec.precision == PrecisionSpec(param_dtype="bfloat16", remat=True)
    blob = spec.to_json()
    spec2 = ExperimentSpec.from_json(blob)
    assert spec2.precision == spec.precision
    assert json.loads(blob)["precision"]["param_dtype"] == "bfloat16"
    # default stays inactive and out of the built config
    assert not ExperimentSpec().precision.is_active
    assert ExperimentSpec().hier_config().precision is None
    assert spec.hier_config().precision == spec.precision
    assert "precision=bfloat16+remat" in spec.describe()


# ---------------------------------------------------------------------------
# State dtypes + memory footprint
# ---------------------------------------------------------------------------


def _nbytes(tree):
    return sum(x.nbytes for x in jax.tree_util.tree_leaves(tree))


def test_init_state_casts_and_halves_client_memory():
    topo = FedTopology(num_edges=2, clients_per_edge=4)
    p0 = {"w": jnp.zeros((16, 8), jnp.float32), "b": jnp.zeros((8,), jnp.float32)}
    opt = momentum(0.1, 0.9)
    cfg32 = HierFAVGConfig(kappa1=2, kappa2=2)
    cfg16 = dataclasses.replace(cfg32, precision=PrecisionSpec(param_dtype="bfloat16"))
    s32 = init_state(jax.random.PRNGKey(0), p0, opt, topo, cfg32)
    s16 = init_state(jax.random.PRNGKey(0), p0, opt, topo, cfg16)
    for leaf in jax.tree_util.tree_leaves(s16.params):
        assert leaf.dtype == jnp.bfloat16
    # momentum's trace rows follow the (bf16) param dtype -> the stacked
    # per-client state (params + trace) is exactly half the f32 bytes
    assert _nbytes(s16.params) * 2 == _nbytes(s32.params)
    stacked16 = [
        x for x in jax.tree_util.tree_leaves(s16.opt_state) if getattr(x, "ndim", 0) >= 1
    ]
    stacked32 = [
        x for x in jax.tree_util.tree_leaves(s32.opt_state) if getattr(x, "ndim", 0) >= 1
    ]
    assert sum(x.nbytes for x in stacked16) * 2 == sum(x.nbytes for x in stacked32)
    for leaf in stacked16:
        assert leaf.dtype == jnp.bfloat16


def _spec(*overrides):
    return ExperimentSpec().with_overrides([
        "topology.num_edges=2", "topology.clients_per_edge=4",
        "schedule.kappas=2,2", "data.num_samples=320", "data.batch_size=4",
        "run.num_rounds=4", "run.eval_every=0", "cost.workload=none",
        *overrides,
    ])


@pytest.mark.parametrize("engine", ["superround", "megakernel", "per_round"])
def test_fed_state_leaf_dtypes_per_engine(engine):
    runner, state = _spec(
        f"run.engine={engine}", "precision.param_dtype=bfloat16"
    ).run_experiment()
    for leaf in jax.tree_util.tree_leaves(state.params):
        assert leaf.dtype == jnp.bfloat16, f"{engine}: param leaf {leaf.dtype}"
    n = runner.topology.num_clients
    for leaf in jax.tree_util.tree_leaves(state.opt_state):
        if getattr(leaf, "ndim", 0) >= 1 and leaf.shape[0] == n:
            assert leaf.dtype == jnp.bfloat16, f"{engine}: opt leaf {leaf.dtype}"
    if engine == "megakernel":
        assert runner._engine.uses_megakernel


# ---------------------------------------------------------------------------
# Trajectory + convergence parity
# ---------------------------------------------------------------------------


def test_bf16_trajectory_tracks_fp32():
    """bf16 client state follows the f32 trajectory within bf16's ~3
    significant digits: losses stay within a few percent over a short run
    (documented tolerance — bf16 has an 8-bit mantissa, so per-step
    rounding is ~1e-2 relative; the f32 aggregation accumulate keeps it
    from compounding across sync boundaries). The atol floor covers the
    late-run regime where the loss itself is ~1e-2."""
    final = {}
    for tag, extra in (("fp32", ()), ("bf16", ("precision.param_dtype=bfloat16",))):
        runner, _ = _spec("run.num_rounds=8", *extra).run_experiment()
        final[tag] = np.asarray([h.loss for h in runner.history])
    np.testing.assert_allclose(final["bf16"], final["fp32"], rtol=0.05, atol=0.01)
    # both actually trained
    assert final["bf16"][-1] < final["bf16"][0]


def test_bf16_convergence_parity_one_scenario():
    """Accuracy at the end of a short edge_iid run: bf16 within a few
    points of f32 (the ISSUE's convergence-parity gate)."""
    accs = {}
    for tag, extra in (("fp32", ()), ("bf16", ("precision.param_dtype=bfloat16",))):
        runner, state = _spec(
            "run.num_rounds=8", "run.eval_every=4", *extra
        ).run_experiment()
        accs[tag] = [h.accuracy for h in runner.history if h.accuracy is not None][-1]
    assert abs(accs["bf16"] - accs["fp32"]) < 0.05, accs


def test_remat_policy_is_numerically_transparent():
    """remat=True reruns the forward pass under ``jax.checkpoint`` — same
    math, same results, bit-for-bit at f32."""
    base = _spec()
    r1, s1 = base.run_experiment()
    r2, s2 = _spec("precision.remat=true").run_experiment()
    for a, b in zip(
        jax.tree_util.tree_leaves(s1.params), jax.tree_util.tree_leaves(s2.params)
    ):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    # remat alone activates the precision hook but keeps f32 state
    for leaf in jax.tree_util.tree_leaves(s2.params):
        assert leaf.dtype == jnp.float32
