"""Fault tolerance: failure masks, stragglers, cost model (Table I/II)."""
import numpy as np
import pytest

from repro.core import cost_model as cm
from repro.fed.failures import FailureSimulator, StragglerModel, combine_masks


def test_failure_simulator_deterministic():
    a = FailureSimulator(8, p_fail=0.3, seed=1)
    b = FailureSimulator(8, p_fail=0.3, seed=1)
    for _ in range(5):
        np.testing.assert_array_equal(a.step(), b.step())


def test_failure_state_roundtrip():
    a = FailureSimulator(8, p_fail=0.3, p_recover=0.4, seed=1)
    for _ in range(3):
        a.step()
    s = a.state_dict()
    want = [a.step() for _ in range(3)]
    b = FailureSimulator(8, p_fail=0.3, p_recover=0.4, seed=99)
    b.load_state_dict(s)
    got = [b.step() for _ in range(3)]
    np.testing.assert_array_equal(np.stack(want), np.stack(got))


def test_straggler_deadline_excludes_slow_tail():
    m = StragglerModel(64, mean_step_s=1.0, sigma=0.4, seed=0)
    surv, deadline = m.survivors(kappa1=8)
    assert 0.5 < surv.mean() <= 1.0  # most clients make the deadline
    assert deadline > 8.0  # above the nominal 8 steps


def test_straggler_state_roundtrip():
    a = StragglerModel(16, mean_step_s=1.0, sigma=0.3, seed=2)
    a.interval_latency(4)
    s = a.state_dict()
    want = [a.interval_latency(4) for _ in range(3)]
    # a different seed draws different slowness — load must restore both
    # the persistent slowness array and the live RNG stream
    b = StragglerModel(16, mean_step_s=1.0, sigma=0.3, seed=77)
    b.load_state_dict(s)
    np.testing.assert_array_equal(a.slowness, b.slowness)
    got = [b.interval_latency(4) for _ in range(3)]
    np.testing.assert_array_equal(np.stack(want), np.stack(got))
    np.testing.assert_array_equal(a.survivors(8)[0], b.survivors(8)[0])


def test_combine_masks():
    assert combine_masks(None, None) is None
    a = np.array([1.0, 0.0, 1.0])
    b = np.array([1.0, 1.0, 0.0])
    np.testing.assert_array_equal(combine_masks(a, None, b), [1.0, 0.0, 0.0])


# ---------------------------------------------------------------------------
# Cost model: paper Table I values + Table II monotonicity
# ---------------------------------------------------------------------------

def test_table1_mnist_constants():
    w = cm.paper_workload("mnist")
    assert w.t_comp == pytest.approx(0.024, rel=1e-6)  # Table I
    assert w.e_comp == pytest.approx(0.0024, rel=1e-6)
    assert w.t_comm_edge == pytest.approx(0.1233, rel=5e-3)
    assert w.e_comm_edge == pytest.approx(0.0616, rel=5e-3)


def test_table1_cifar_constants():
    w = cm.paper_workload("cifar10")
    assert w.t_comp == pytest.approx(4.0, rel=1e-6)
    assert w.e_comp == pytest.approx(0.4, rel=1e-6)
    assert w.t_comm_edge == pytest.approx(33.0, rel=6e-3)
    assert w.e_comm_edge == pytest.approx(16.5, rel=6e-3)


def test_kappa2_1_reduces_to_cloud_favg():
    """Schedule algebra: kappa2=1 interval == cloud-based FAVG interval."""
    w = cm.paper_workload("mnist")
    t = cm.cloud_interval_time(w, kappa1=60, kappa2=1)
    expect = 60 * w.t_comp + w.cloud_latency_mult * w.t_comm_edge
    assert t == pytest.approx(expect, rel=1e-9)


def test_time_to_accuracy_decreases_with_kappa2():
    """Table II trend: frequent edge averaging means FEWER local steps to
    the target accuracy (guideline 1), and since edge comms are 10× cheaper
    than cloud comms, T_alpha falls monotonically with kappa2. At FIXED
    step count, more aggregations cost more time — the win is entirely in
    the steps-to-accuracy reduction, exactly as the paper argues."""
    w = cm.paper_workload("mnist")
    # steps-to-accuracy decreasing in kappa2 (paper Fig. 4a/4b behaviour;
    # Table II's T ratios imply a ~2.5× step reduction at (6,10) vs (60,1))
    steps = {(60, 1): 600, (30, 2): 480, (15, 4): 360, (6, 10): 240}
    times = [cm.time_at_step(w, k1, k2, s) for (k1, k2), s in steps.items()]
    assert all(times[i] > times[i + 1] for i in range(len(times) - 1))
    # and at FIXED steps, time grows with aggregation frequency
    fixed = [cm.time_at_step(w, k1, k2, 600) for (k1, k2) in steps]
    assert all(fixed[i] <= fixed[i + 1] for i in range(len(fixed) - 1))


def test_energy_u_shape_possible():
    """Energy = compute part (flat in kappa2) + comm part (grows with kappa2):
    with steps-to-accuracy DECREASING in kappa2 (the empirical behaviour),
    E_alpha first falls then rises — reproduce the paper's U-shape."""
    w = cm.paper_workload("mnist")
    steps = {1: 600, 2: 420, 4: 360, 10: 340}  # fewer steps when averaging more
    E = {k2: cm.energy_at_step(w, 60 // k2 if k2 != 10 else 6, k2, s) for k2, s in steps.items()}
    assert E[2] < E[1]  # moderate kappa2 saves energy
    assert E[10] > E[4]  # too-frequent comms cost energy again


def test_tune_kappas_picks_finite_best():
    w = cm.paper_workload("mnist")
    k1, k2, val = cm.tune_kappas(
        w, lambda a, b: 600.0 * (1.0 + 0.1 * (a / (a * b))), [6, 15, 30, 60], [1, 2, 4, 10]
    )
    assert val > 0 and k1 in (6, 15, 30, 60)


# ---------------------------------------------------------------------------
# compose_masks: the dead-vs-late channel split (fed.deadline consumers)
# ---------------------------------------------------------------------------


def test_compose_masks_effective_matches_combine():
    """The combined channel is bit-identical to the historical
    ``combine_masks`` of every model — the runner's survival mask does not
    change when the composition is taken apart."""
    from repro.fed.failures import compose_masks

    rng = np.random.default_rng(0)
    dead = (rng.random(16) > 0.3).astype(np.float32)
    late = (rng.random(16) > 0.4).astype(np.float32)
    parts = compose_masks(dead=[dead], late=[late])
    np.testing.assert_array_equal(parts.effective, combine_masks(dead, late))


def test_compose_masks_channels_disjoint_dead_wins():
    """A client that is both dead and past the deadline counts as dead —
    there is no deferred upload to carry when the compute never happened."""
    from repro.fed.failures import compose_masks

    dead = np.array([1, 0, 1, 0], np.float32)  # clients 1, 3 dead
    late = np.array([1, 0, 0, 1], np.float32)  # clients 1, 2 late
    parts = compose_masks(dead=[dead], late=[late])
    np.testing.assert_array_equal(parts.dead, [0, 1, 0, 1])
    # client 1 is dead AND late -> reported only on the dead channel
    np.testing.assert_array_equal(parts.late, [0, 0, 1, 0])
    assert parts.dead_count == 2 and parts.late_count == 1
    np.testing.assert_array_equal(parts.effective, [1, 0, 0, 0])


def test_compose_masks_none_channels():
    from repro.fed.failures import compose_masks

    empty = compose_masks()
    assert empty.effective is None and empty.dead is None and empty.late is None
    assert empty.dead_count == 0 and empty.late_count == 0

    late_only = compose_masks(late=[np.array([1, 0], np.float32)])
    assert late_only.dead is None
    np.testing.assert_array_equal(late_only.late, [0, 1])
    np.testing.assert_array_equal(late_only.effective, [1, 0])


def test_compose_masks_from_live_models():
    """FailureSimulator feeds the dead channel, StragglerModel the late
    channel; the simulators' RNG streams are untouched by the split."""
    from repro.fed.failures import compose_masks

    fail_a = FailureSimulator(8, p_fail=0.4, seed=3)
    fail_b = FailureSimulator(8, p_fail=0.4, seed=3)
    strag_a = StragglerModel(8, sigma=0.5, seed=4)
    strag_b = StragglerModel(8, sigma=0.5, seed=4)
    for _ in range(4):
        dead_m = fail_a.step()
        late_m, _ = strag_a.survivors(2, None)
        parts = compose_masks(dead=[dead_m], late=[late_m])
        ref = combine_masks(fail_b.step(), strag_b.survivors(2, None)[0])
        np.testing.assert_array_equal(parts.effective, ref)
        # every client is on exactly one channel or alive
        marked = parts.dead + parts.late
        assert marked.max() <= 1
        np.testing.assert_array_equal(parts.effective, 1.0 - marked)
