"""Data pipeline: partition protocols (Section IV-A) + restart-safe batching."""
import numpy as np
import pytest
from repro.testing import given, settings, st

from repro.data import partition as pt
from repro.data.pipeline import FederatedBatcher
from repro.data.synthetic import clustered_gaussians, token_corpus


def labels_10(rng, n=2000):
    return rng.integers(0, 10, size=n).astype(np.int64)


def test_iid_balanced(rng):
    labels = labels_10(rng)
    parts = pt.partition("iid", labels, 5, 10, rng)
    sizes = [len(p) for p in parts]
    assert max(sizes) - min(sizes) <= 1
    assert sum(sizes) == len(labels)


def test_simple_niid_two_classes(rng):
    labels = np.sort(labels_10(rng))
    parts = pt.partition("simple_niid", labels, 5, 10, rng)
    stats = pt.partition_stats(parts, labels)
    classes_per_client = (stats > 0).sum(axis=1)
    # shard edges may split a class boundary: 2 (occasionally 3) classes
    assert classes_per_client.max() <= 3
    assert np.median(classes_per_client) <= 2


def test_edge_iid_structure(rng):
    labels = labels_10(rng)
    parts = pt.partition("edge_iid", labels, 5, 10, rng)
    stats = pt.partition_stats(parts, labels)
    # each client: exactly one class
    assert ((stats > 0).sum(axis=1) == 1).all()
    # each edge: all 10 classes covered (paper: "10 clients with different classes")
    for e in range(5):
        edge = stats[e * 10 : (e + 1) * 10].sum(axis=0)
        assert (edge > 0).all()


def test_edge_niid_structure(rng):
    labels = labels_10(rng)
    parts = pt.partition("edge_niid", labels, 5, 10, rng)
    stats = pt.partition_stats(parts, labels)
    assert ((stats > 0).sum(axis=1) == 1).all()
    for e in range(5):
        edge = stats[e * 10 : (e + 1) * 10].sum(axis=0)
        assert (edge > 0).sum() == 5  # paper: 5 classes per edge


@given(num_edges=st.integers(2, 5), cpe=st.integers(2, 8), seed=st.integers(0, 100))
@settings(max_examples=20, deadline=None)
def test_partition_property_disjoint_cover(num_edges, cpe, seed):
    """Any protocol: client index sets are disjoint (IID/simple split the
    full dataset; class-per-client protocols may subsample evenly)."""
    rng = np.random.default_rng(seed)
    labels = rng.integers(0, 10, size=1200)
    for kind in ("iid", "simple_niid"):
        parts = pt.partition(kind, labels, num_edges, cpe, rng)
        flat = np.concatenate(parts)
        assert len(np.unique(flat)) == len(flat)
        assert len(flat) == len(labels)


def test_synthetic_learnable_structure(rng):
    data = clustered_gaussians(rng, num_samples=500, num_classes=4, dim=(8,), class_sep=4.0)
    # nearest-centroid on the generating structure is >90% accurate
    cents = np.stack([data.x[data.y == c].mean(axis=0) for c in range(4)])
    pred = np.argmin(((data.x[:, None] - cents[None]) ** 2).sum(-1), axis=1)
    assert (pred == data.y).mean() > 0.9


def test_token_corpus_class_structure(rng):
    corp = token_corpus(rng, num_sequences=64, seq_len=32, vocab=50, num_classes=3)
    assert corp.tokens.shape == (64, 33)
    assert corp.tokens.max() < 50 and corp.tokens.min() >= 0


def test_batcher_restart_safety(rng):
    x = rng.normal(size=(200, 3)).astype(np.float32)
    y = rng.integers(0, 10, size=200).astype(np.int32)
    parts = pt.partition("iid", y, 2, 2, rng)
    mk = lambda: FederatedBatcher({"x": x, "y": y}, parts, batch_size=8, seed=7)

    b1 = mk()
    for _ in range(10):
        b1.next_batch()
    saved = b1.state_dict()
    want = [b1.next_batch() for _ in range(5)]

    b2 = mk()
    b2.load_state_dict(saved)
    got = [b2.next_batch() for _ in range(5)]
    for wb, gb in zip(want, got):
        np.testing.assert_array_equal(wb["x"], gb["x"])


def test_batcher_stacked_shapes(rng):
    x = rng.normal(size=(100, 4)).astype(np.float32)
    y = rng.integers(0, 10, size=100).astype(np.int32)
    parts = pt.partition("iid", y, 2, 3, rng)
    b = FederatedBatcher({"x": x, "y": y}, parts, batch_size=4)
    batch = b.next_batch()
    assert batch["x"].shape == (6, 4, 4)
    multi = b.next_batches(3)
    assert multi["x"].shape == (3, 6, 4, 4)
