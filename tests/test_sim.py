"""repro.sim: round DAG, zero-variance parity, replay determinism,
distributions, straggler calibration, and the association optimizer."""
import json

import numpy as np
import pytest

from repro.core import cost_model as cm
from repro.core.hierarchy import HierarchySpec
from repro.fed.failures import StragglerModel
from repro.sim import (
    AGG,
    HOP,
    STEP,
    DeterministicDist,
    LogNormalDist,
    MixtureDist,
    NetworkSpec,
    SimCosts,
    assemble_durations,
    assignment_to_spec,
    build_round_dag,
    draw_jitter_tables,
    from_cluster,
    from_roofline,
    from_workload,
    optimize_association,
    parse_distribution,
    replay_once,
    simulate_round,
    simulate_spec,
    straggler_masks,
    straggler_network,
    sweep,
)
from repro.sim.dag import _boundary_level

UNIFORM = HierarchySpec.uniform(5, 10)
RAGGED = HierarchySpec.from_fanouts([[16, 12, 10, 7, 5], [5]])


# ---------------------------------------------------------------------------
# DAG construction
# ---------------------------------------------------------------------------

def test_boundary_levels():
    # kappas (k1, 3, 2): level-2 boundary every 3rd interval, level-3 every 6th
    assert [_boundary_level(r, (4, 3, 2)) for r in range(6)] == [1, 1, 2, 1, 1, 3]
    assert [_boundary_level(r, (6, 10)) for r in range(10)] == [1] * 9 + [2]


def test_dag_topology_and_counts():
    dag = build_round_dag(UNIFORM, (6, 10))
    assert dag.num_intervals == 10
    # 50 clients x 6 steps x 10 intervals; uplink per client-interval; one
    # edge agg per edge-interval; one backhaul hop per edge + the cloud agg
    assert dag.counts() == {
        "nodes": 3000 + 500 + 50 + 5 + 1, "steps": 3000, "hops": 505, "aggs": 51,
    }
    for i, ps in enumerate(dag.preds):
        assert np.all(ps < i)  # topological order
    assert dag.kind[dag.sink] == AGG and dag.level[dag.sink] == dag.spec.depth


def test_dag_ragged_agg_fanin():
    dag = build_round_dag(RAGGED, (2, 3))
    assert dag.counts()["steps"] == 50 * 2 * 3
    # interval-0 edge aggregates wait for exactly their own children
    for edge, fanout in enumerate([16, 12, 10, 7, 5]):
        (node,) = np.where(
            (dag.kind == AGG) & (dag.level == 1)
            & (dag.entity == edge) & (dag.interval == 0)
        )[0]
        assert dag.preds[node].size == fanout


def test_dag_validation():
    with pytest.raises(ValueError, match="depth"):
        build_round_dag(UNIFORM, (6, 10, 2))
    with pytest.raises(ValueError, match=">= 1"):
        build_round_dag(UNIFORM, (0, 10))
    with pytest.raises(ValueError, match="sorted"):
        build_round_dag(UNIFORM, (2, 2), cohort=np.array([3, 1]))
    with pytest.raises(ValueError, match="in 0"):
        build_round_dag(UNIFORM, (2, 2), cohort=np.array([0, 50]))
    with pytest.raises(ValueError, match="non-empty"):
        build_round_dag(UNIFORM, (2, 2), cohort=np.array([], np.int64))
    with pytest.raises(ValueError, match="masks"):
        build_round_dag(UNIFORM, (2, 2), masks=np.ones((3, 50)))


def test_dag_cohort_restricts_tree():
    cohort = np.array([0, 1, 2, 10, 11, 47])  # edges {0, 1, 4} active
    dag = build_round_dag(UNIFORM, (3, 2), cohort=cohort)
    assert dag.counts()["steps"] == 6 * 3 * 2
    edge_aggs = (dag.kind == AGG) & (dag.level == 1)
    assert set(dag.entity[edge_aggs].tolist()) == {0, 1, 4}
    # the cloud agg waits on one backhaul hop per *active* edge
    assert dag.preds[dag.sink].size == 3


# ---------------------------------------------------------------------------
# Zero-variance parity vs the analytic schedule algebra
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("workload", ["mnist", "cifar10"])
@pytest.mark.parametrize("kappas", [(1, 1), (6, 10), (15, 4), (60, 1)])
@pytest.mark.parametrize("tree", [UNIFORM, RAGGED], ids=["uniform", "ragged"])
def test_parity_workload(workload, kappas, tree):
    costs = cm.paper_workload(workload)
    res = simulate_round(build_round_dag(tree, kappas), from_workload(costs, 2))
    k1, k2 = kappas
    want_t = cm.cloud_interval_time(costs, k1, k2)
    want_e = cm.cloud_interval_energy(costs, k1, k2)
    np.testing.assert_allclose(res.round_time[0], want_t, rtol=1e-12)
    np.testing.assert_allclose(res.client_energy[0], want_e, rtol=1e-12)


def test_parity_compressed_transport():
    costs = cm.paper_workload("mnist")
    bits = (32.0, 8.125)  # identity edge hop, int8:256 cloud hop
    res = simulate_round(
        build_round_dag(UNIFORM, (6, 10)),
        from_workload(costs, 2, bits_per_param=bits),
    )
    eff = costs.with_bits(*bits)
    np.testing.assert_allclose(
        res.round_time[0], cm.cloud_interval_time(eff, 6, 10), rtol=1e-12
    )
    np.testing.assert_allclose(
        res.client_energy[0], cm.cloud_interval_energy(eff, 6, 10), rtol=1e-12
    )


def test_parity_cluster():
    cc = cm.ClusterCosts(t_step=1e-3, t_edge_agg=2e-4, t_cloud_agg=2e-3)
    res = simulate_round(build_round_dag(UNIFORM, (6, 10)), from_cluster(cc, 2))
    np.testing.assert_allclose(res.round_time[0], cc.interval_time(6, 10), rtol=1e-12)
    assert res.client_energy.max() == 0.0  # no device-energy notion on the cluster


def test_parity_depth3_closed_form():
    """Depth-3 critical path: kappa1*R steps + R level-1 hops + kappa3
    level-2 hops + one level-3 hop (all clients identical)."""
    tree = HierarchySpec.from_fanouts([[4, 4, 4, 4], [2, 2], [2]])
    costs = cm.paper_workload("mnist")
    sim_costs = from_workload(costs, 3)
    k1, k2, k3 = 2, 3, 2
    res = simulate_round(build_round_dag(tree, (k1, k2, k3)), sim_costs)
    R = k2 * k3
    want = (
        k1 * R * costs.t_comp
        + R * sim_costs.link_t[0]
        + k3 * sim_costs.link_t[1]
        + sim_costs.link_t[2]
    )
    np.testing.assert_allclose(res.round_time[0], want, rtol=1e-12)
    np.testing.assert_allclose(
        res.client_energy[0], k1 * R * costs.e_comp + R * sim_costs.e_uplink, rtol=1e-12
    )


def test_parity_simulate_spec_transport():
    """The spec path threads the transport's bit widths into calibration."""
    from repro.fed.api import CostSpec, ExperimentSpec, ScheduleSpec, TopologySpec, TransportSpec

    spec = ExperimentSpec(
        name="parity_int8",
        topology=TopologySpec(num_edges=5, clients_per_edge=10),
        schedule=ScheduleSpec(kappas=(6, 10)),
        transport=TransportSpec(levels="identity/int8:256"),
        cost=CostSpec(workload="mnist"),
    )
    bits = spec.transport.build(2).bits_vector()
    eff = cm.paper_workload("mnist").with_bits(*bits)
    res = simulate_spec(spec)
    np.testing.assert_allclose(
        res.round_time[0], cm.cloud_interval_time(eff, 6, 10), rtol=1e-12
    )


# ---------------------------------------------------------------------------
# Masks: stragglers keep computing, dead clients vanish
# ---------------------------------------------------------------------------

def _small_masked(masks=None, alive=None):
    tree = HierarchySpec.uniform(2, 3)
    costs = cm.paper_workload("mnist")
    dag = build_round_dag(tree, (2, 2), masks=masks, alive=alive)
    return costs, dag, simulate_round(dag, from_workload(costs, 2))


def test_straggler_mask_semantics():
    masks = np.ones((2, 6))
    masks[0, 0] = 0  # slot 0 misses interval 0's deadline
    costs, dag, res = _small_masked(masks=masks)
    # it still computes (and pays energy for) its interval-0 steps, but
    # skips the upload: one e_comm less than a full participant
    full = 4 * costs.e_comp + 2 * costs.e_comm_edge
    np.testing.assert_allclose(res.client_energy[0, 1:], full, rtol=1e-12)
    np.testing.assert_allclose(
        res.client_energy[0, 0], full - costs.e_comm_edge, rtol=1e-12
    )
    assert not np.any((dag.kind == HOP) & (dag.level == 1)
                      & (dag.entity == 0) & (dag.interval == 0))
    # its interval-1 chain continues from its own last step, not the agg
    steps0 = np.where((dag.kind == STEP) & (dag.entity == 0))[0]
    (pred,) = dag.preds[steps0[2]]
    assert dag.kind[pred] == STEP and pred == steps0[1]
    # a participant's interval-1 chain is gated by the broadcast aggregate
    steps1 = np.where((dag.kind == STEP) & (dag.entity == 1))[0]
    (pred,) = dag.preds[steps1[2]]
    assert dag.kind[pred] == AGG
    # the edge-0 aggregate waits only for the two on-time members
    (agg0,) = np.where((dag.kind == AGG) & (dag.level == 1)
                       & (dag.entity == 0) & (dag.interval == 0))[0]
    assert dag.preds[agg0].size == 2
    # and the masked slot never delays the round
    np.testing.assert_allclose(
        res.round_time[0], cm.cloud_interval_time(costs, 2, 2), rtol=1e-12
    )


def test_failure_mask_semantics():
    alive = np.ones((2, 6))
    alive[0, 0] = 0  # slot 0 dead for interval 0
    costs, dag, res = _small_masked(alive=alive)
    steps0 = np.where((dag.kind == STEP) & (dag.entity == 0))[0]
    assert steps0.size == 2  # interval 1 only — no compute while dead
    assert dag.preds[steps0[0]].size == 0  # rejoins from a fresh chain
    np.testing.assert_allclose(
        res.client_energy[0, 0], 2 * costs.e_comp + costs.e_comm_edge, rtol=1e-12
    )


# ---------------------------------------------------------------------------
# Replay: sweep == event queue, bit-identical determinism
# ---------------------------------------------------------------------------

def _jittery_net(tree, seed=7):
    return NetworkSpec(
        client_speed="lognormal:0.4",
        edge_backhaul="mixture:0.5@1,0.5@4",
        compute_jitter="lognormal:0.2",
        link_jitter="lognormal:0.3",
        backhaul_jitter="lognormal:0.25",
        seed=seed,
    ).build(tree)


def test_replay_once_matches_sweep():
    tree = HierarchySpec.uniform(3, 4)
    dag = build_round_dag(tree, (2, 3))
    res = simulate_round(
        dag, from_workload(cm.paper_workload("mnist"), 2), _jittery_net(tree), trials=5
    )
    for t in range(5):
        np.testing.assert_array_equal(replay_once(dag, res.durations[t]), res.finish[t])


def test_replay_bit_identical_across_builds():
    def run():
        tree = HierarchySpec.uniform(3, 4)
        dag = build_round_dag(tree, (2, 3))
        return simulate_round(
            dag, from_workload(cm.paper_workload("mnist"), 2),
            _jittery_net(tree), trials=8,
        )

    a, b = run(), run()
    np.testing.assert_array_equal(a.finish, b.finish)
    np.testing.assert_array_equal(a.energy, b.energy)


def test_jitter_widens_the_tail():
    tree = HierarchySpec.uniform(3, 4)
    dag = build_round_dag(tree, (2, 3))
    res = simulate_round(
        dag, from_workload(cm.paper_workload("mnist"), 2), _jittery_net(tree), trials=64
    )
    p = res.percentiles()
    analytic = cm.cloud_interval_time(cm.paper_workload("mnist"), 2, 3)
    assert p["p99_s"] > p["p50_s"] > 0
    assert p["p99_s"] > analytic  # max over jittered clients beats the mean point
    cdf = res.cdf(9)
    assert cdf["round_time_s"] == sorted(cdf["round_time_s"])
    tl = res.timeline(0)
    assert len(tl) == dag.num_nodes and tl[-1]["kind"] == "agg"


# ---------------------------------------------------------------------------
# Distributions + NetworkSpec
# ---------------------------------------------------------------------------

def test_parse_distribution_grammar():
    assert isinstance(parse_distribution("det"), DeterministicDist)
    assert parse_distribution("det:2.5").sample(3).tolist() == [2.5] * 3
    d = parse_distribution("lognormal:0.3:2.0")
    assert isinstance(d, LogNormalDist) and d.median == 2.0
    m = parse_distribution("mixture:0.9@1,0.1@8")
    assert isinstance(m, MixtureDist)
    np.testing.assert_allclose(m.mean(), 0.9 * 1 + 0.1 * 8)
    for bad in ("gamma:1", "lognormal", "lognormal:-0.5", "mixture:0.9@1,0.4@8",
                "mixture:1.0", "det:-1"):
        with pytest.raises(ValueError):
            parse_distribution(bad)


def test_distribution_state_roundtrip_json():
    for make in (lambda: LogNormalDist(0.4, seed=3),
                 lambda: MixtureDist([0.7, 0.3], [1.0, 5.0], seed=3)):
        a = make()
        a.sample(17)
        state = json.loads(json.dumps(a.state_dict()))  # JSON-safe by contract
        want = [a.sample(5) for _ in range(3)]
        b = make()
        b.load_state_dict(state)
        got = [b.sample(5) for _ in range(3)]
        np.testing.assert_array_equal(np.stack(want), np.stack(got))
    with pytest.raises(ValueError):
        LogNormalDist(0.3).load_state_dict({"kind": "mixture"})


def test_network_model_state_roundtrip():
    tree = HierarchySpec.uniform(3, 4)
    net = _jittery_net(tree)
    draw_jitter_tables(net, tree, (2, 3), trials=2)  # advance the streams
    state = net.state_dict()
    want = draw_jitter_tables(net, tree, (2, 3), trials=2)
    net2 = _jittery_net(tree)
    net2.load_state_dict(state)
    got = draw_jitter_tables(net2, tree, (2, 3), trials=2)
    np.testing.assert_array_equal(want.compute, got.compute)
    np.testing.assert_array_equal(want.backhaul[2], got.backhaul[2])


def test_network_spec_flags_and_api_roundtrip():
    from repro.fed.api import ExperimentSpec

    assert not NetworkSpec().is_active
    assert NetworkSpec(link_jitter="lognormal:0.2").is_active
    assert NetworkSpec(seed=9) == NetworkSpec(seed=9)
    with pytest.raises(ValueError):
        NetworkSpec(jitter_granularity="hourly")
    with pytest.raises(ValueError):
        NetworkSpec(client_speed="gamma:2")
    spec = ExperimentSpec(
        name="rt", network=NetworkSpec(edge_backhaul="mixture:0.9@1,0.1@8", seed=4)
    )
    again = ExperimentSpec.from_dict(spec.to_dict())
    assert again.network == spec.network
    over = spec.with_overrides(
        ["network.contention=true", "network.client_speed=lognormal:0.5"]
    )
    assert over.network.contention and over.network.client_speed == "lognormal:0.5"


def test_calibrate_validation_and_roofline():
    import types

    costs = cm.paper_workload("mnist")
    with pytest.raises(ValueError):
        from_workload(costs, 0)
    with pytest.raises(ValueError):
        from_workload(costs, 2, bits_per_param=(8.0,))
    with pytest.raises(ValueError):
        from_workload(costs, 2, bits_per_param=(8.0, -1.0))
    with pytest.raises(ValueError):
        SimCosts(t_step=1.0, e_step=0.0, link_t=(1.0,), agg_t=(0.0, 0.0))
    term = lambda s: types.SimpleNamespace(bound_s=s, collective_s=s)
    sc = from_roofline(term(1e-3), term(2e-4), term(2e-3), 2)
    assert sc.t_step == 1e-3 and sc.agg_t == (2e-4, 2e-3) and sc.link_t == (0.0, 0.0)


def test_simulate_spec_scenarios():
    from repro.fed import scenarios

    for name in ("congested_backhaul", "hetero_clients_assoc", "straggler_tail"):
        res = simulate_spec(scenarios.get(name), trials=3)
        assert res.round_time.shape == (3,)
        assert np.all(np.isfinite(res.round_time)) and np.all(res.round_time > 0)
        assert res.summary()["round_time"]["p99_s"] > 0


# ---------------------------------------------------------------------------
# Straggler calibration: one distribution for masks and replay
# ---------------------------------------------------------------------------

def test_straggler_network_exact_stream():
    """Replayed per-interval compute equals interval_latency draws from an
    identically seeded model — same slowness, same RNG stream."""
    tree = HierarchySpec.uniform(2, 8)
    k1, k2 = 4, 3
    model = StragglerModel(16, mean_step_s=0.5, sigma=0.4, seed=7)
    twin = StragglerModel(16, mean_step_s=0.5, sigma=0.4, seed=7)
    net = straggler_network(model, tree)
    costs = SimCosts(t_step=0.5, e_step=0.0, link_t=(0.0, 0.0), agg_t=(0.0, 0.0))
    dag = build_round_dag(tree, (k1, k2))
    res = simulate_round(dag, costs, net, trials=1)
    steps = np.where(dag.kind == STEP)[0]
    got = np.zeros((k2, 16))
    np.add.at(
        got,
        (dag.interval[steps].astype(int), dag.entity[steps].astype(int)),
        res.durations[0, steps],
    )
    want = np.stack([twin.interval_latency(k1) for _ in range(k2)])
    np.testing.assert_allclose(got, want, rtol=1e-12)


def test_straggler_network_validates_population():
    with pytest.raises(ValueError, match="clients"):
        straggler_network(StragglerModel(8, seed=0), HierarchySpec.uniform(2, 8))


def test_straggler_masks_shapes():
    model = StragglerModel(50, mean_step_s=1.0, sigma=0.6, seed=1)
    m = straggler_masks(model, kappa1=4, num_intervals=3)
    assert m.shape == (3, 50) and m.dtype == bool
    cohort = np.array([0, 5, 9, 31])
    mc = straggler_masks(model, 4, 2, cohort=cohort)
    assert mc.shape == (2, 4)
    # masks plug straight into the DAG builder
    build_round_dag(UNIFORM, (4, 3), masks=straggler_masks(model, 4, 3))


# ---------------------------------------------------------------------------
# Common random numbers + association optimization
# ---------------------------------------------------------------------------

def test_common_random_numbers_across_assignments():
    """A client's compute durations are identical whichever edge it sits
    on — tables are canonically keyed, so candidates differ only where the
    assignment matters."""
    tree = HierarchySpec.uniform(2, 3)
    costs = from_workload(cm.paper_workload("mnist"), 2)
    net = NetworkSpec(client_speed="lognormal:0.4", compute_jitter="lognormal:0.2",
                      seed=3).build(tree)
    tables = draw_jitter_tables(net, tree, (2, 2), trials=4)
    dag0 = build_round_dag(tree, (2, 2))
    d0 = assemble_durations(dag0, costs, net, tables)
    # swap clients 0 and 3 across the two edges
    spec2, order = assignment_to_spec(np.array([1, 0, 0, 0, 1, 1]), tree)
    dag2 = build_round_dag(spec2, (2, 2))
    d2 = assemble_durations(dag2, costs, net, tables, client_ids=order)
    for c in range(6):
        idx0 = np.where((dag0.kind == STEP) & (dag0.entity == c))[0]
        slots = order[dag2.entity[np.where(dag2.kind == STEP)[0]]]
        idx2 = np.where(dag2.kind == STEP)[0][slots == c]
        np.testing.assert_array_equal(d0[:, idx0], d2[:, idx2])
    # purity: re-assembly against the same tables is bit-identical
    np.testing.assert_array_equal(d0, assemble_durations(dag0, costs, net, tables))


def test_assignment_to_spec_roundtrip():
    incumbent = np.asarray(UNIFORM.segments(1))
    spec, order = assignment_to_spec(incumbent, UNIFORM)
    np.testing.assert_array_equal(order, np.arange(50))
    assert spec.parents == UNIFORM.parents
    with pytest.raises(ValueError, match="at least one"):
        assignment_to_spec(np.zeros(50, np.int64), UNIFORM)
    with pytest.raises(ValueError, match="edge ids"):
        assignment_to_spec(np.full(50, 7), UNIFORM)


def test_association_improves_heterogeneous_tail():
    tree = HierarchySpec.uniform(4, 6)
    costs = from_workload(cm.paper_workload("mnist"), 2)
    net = NetworkSpec(
        client_speed="lognormal:0.5",
        edge_uplink="mixture:0.5@1,0.5@5",
        link_jitter="lognormal:0.1",
        contention=True,
        seed=1,
    ).build(tree)
    res = optimize_association(
        tree, costs, net, (6, 2), trials=16, top_k=4, max_rounds=4
    )
    assert res.value_after <= res.value_before  # never worse than incumbent
    assert res.improvement > 0  # and strictly better on this skewed setup
    # a valid re-sorted tree: same shape, every edge kept >= 1 client
    load = np.bincount(res.assignment, minlength=4)
    assert load.sum() == 24 and load.min() >= 1 and load.max() <= 6
    # the permutation is consistent with the returned spec
    np.testing.assert_array_equal(
        np.asarray(res.spec.segments(1)), res.assignment[res.client_order]
    )
    d = res.to_dict()
    assert d["evals"] == res.evals and d["num_moves"] == len(res.moves)


def test_association_energy_objective_and_validation():
    tree = HierarchySpec.uniform(2, 3)
    costs = from_workload(cm.paper_workload("mnist"), 2)
    net = NetworkSpec(client_speed="lognormal:0.3", seed=2).build(tree)
    res = optimize_association(tree, costs, net, (2, 2), objective="energy",
                               trials=4, top_k=2, max_rounds=2)
    assert np.isfinite(res.value_after) and res.value_after <= res.value_before
    with pytest.raises(ValueError, match="objective"):
        optimize_association(tree, costs, net, (2, 2), objective="latency")
    with pytest.raises(ValueError, match="depth-2"):
        optimize_association(
            HierarchySpec.from_fanouts([[2, 2], [1, 1], [2]]),
            from_workload(cm.paper_workload("mnist"), 3), net, (2, 2, 1),
        )
    with pytest.raises(ValueError, match="capacity"):
        optimize_association(tree, costs, net, (2, 2), capacity=np.array([2, 2]))
