"""Fused edge-interval megakernel: Pallas kernel vs oracle, the client-
blocked superround lowering vs the scan-fused baseline, and the engine's
opt-in fast path with named-reason fallback."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (
    FedTopology,
    HierFAVGConfig,
    build_megakernel_super_round,
    build_super_round,
    init_state,
    megakernel_incompatibility,
)
from repro.core.hierarchy import parse_fanouts
from repro.data import FederatedBatcher, clustered_gaussians, make_partition
from repro.fed import FailureSimulator, FederatedRunner, RunnerConfig
from repro.fed.api import ExperimentSpec
from repro.kernels import ops, ref
from repro.models import cnn
from repro.optim import adam, momentum, sgd


@pytest.fixture(autouse=True)
def _interpret():
    ops.set_interpret(True)
    yield
    ops.set_interpret(None)


# ---------------------------------------------------------------------------
# Pallas kernel vs oracle
# ---------------------------------------------------------------------------


@pytest.mark.parametrize(
    "n,e,k1,b,feat,out,mom",
    [
        (8, 2, 3, 2, 4, 3, 0.0),
        (16, 4, 2, 1, 8, 5, 0.9),
        (8, 2, 4, 2, 6, 2, 0.9),
        (8, 1, 2, 3, 4, 4, 0.0),  # single edge = cloud mean
    ],
)
def test_edge_interval_kernel_matches_ref(rng, n, e, k1, b, feat, out, mom):
    p = feat * out
    params = jnp.asarray(rng.normal(size=(n, p)) * 0.1, jnp.float32)
    x = jnp.asarray(rng.normal(size=(n, k1, b, feat)), jnp.float32)
    y = jnp.asarray(rng.normal(size=(n, k1, b, out)), jnp.float32)
    w = jnp.asarray(rng.uniform(1, 3, n), jnp.float32)
    mu = jnp.asarray(rng.normal(size=(n, p)) * 0.01, jnp.float32) if mom else None
    got = ops.edge_interval(
        params, x, y, w, num_edges=e, feat=feat, lr=0.1, momentum=mom, mu=mu
    )
    want = ref.edge_interval_ref(
        params, x, y, w, e, feat=feat, lr=0.1, momentum=mom, mu=mu
    )
    # shared step body; only the contraction lowering differs -> ULP parity
    for a, b_, name in zip(got, want, ("params", "losses", "mu")):
        np.testing.assert_allclose(
            np.asarray(a), np.asarray(b_), rtol=3e-6, atol=5e-7, err_msg=name
        )


def test_edge_interval_kernel_matches_super_round(rng):
    """One fused edge interval == κ₁ local steps + aggregation through the
    reference protocol lowering (E=1, κ₂=1: the cloud mean IS the edge
    mean), documented-ULP tolerance."""
    n, k1, b, feat, out = 8, 3, 2, 4, 3
    topo = FedTopology(num_edges=1, clients_per_edge=n)
    config = HierFAVGConfig(kappa1=k1, kappa2=1)
    w = jnp.asarray(rng.uniform(1, 3, n), jnp.float32)

    def loss_fn(p, batch, _rng):
        return jnp.mean(jnp.square(batch["x"] @ p["w"] - batch["y"]))

    p0 = {"w": jnp.asarray(rng.normal(size=(feat, out)) * 0.1, jnp.float32)}
    st = init_state(jax.random.PRNGKey(0), p0, sgd(0.1), topo, config)
    x = jnp.asarray(rng.normal(size=(1, k1, n, b, feat)), jnp.float32)
    y = jnp.asarray(rng.normal(size=(1, k1, n, b, out)), jnp.float32)
    sb, m = jax.jit(build_super_round(loss_fn, sgd(0.1), topo, config, w))(
        st, {"x": x, "y": y}, None
    )
    gp, gl, _ = ops.edge_interval(
        st.params["w"].reshape(n, feat * out),
        jnp.moveaxis(x[0], 1, 0), jnp.moveaxis(y[0], 1, 0),
        w, num_edges=1, feat=feat, lr=0.1,
    )
    np.testing.assert_allclose(
        np.asarray(sb.params["w"].reshape(n, feat * out)), np.asarray(gp),
        rtol=3e-6, atol=1e-6,
    )
    np.testing.assert_allclose(float(jnp.mean(gl)), float(m["loss"][0]), rtol=1e-6)


def test_edge_interval_kernel_vmem_budget():
    from repro.kernels.megakernel import edge_interval_pallas

    n, feat, out = 8, 512, 1024  # 8 clients x 2 MiB rows, one edge
    params = jnp.zeros((n, feat * out), jnp.float32)
    x = jnp.zeros((n, 4, 1, feat), jnp.float32)
    y = jnp.zeros((n, 4, 1, out), jnp.float32)
    w = jnp.ones((n,), jnp.float32)
    with pytest.raises(ValueError, match="VMEM budget"):
        edge_interval_pallas(
            params, x, y, w, num_edges=1, feat=feat, lr=0.1, interpret=True
        )


# ---------------------------------------------------------------------------
# Client-blocked superround lowering vs the scan-fused baseline
# ---------------------------------------------------------------------------


def _mk_problem(rng, n, feat=5, out=3):
    def loss_fn(p, batch, _rng):
        return jnp.mean(jnp.square(batch["x"] @ p["w"] + p["b"] - batch["y"]))

    p0 = {
        "w": jnp.asarray(rng.normal(size=(feat, out)) * 0.1, jnp.float32),
        "b": jnp.zeros((out,), jnp.float32),
    }
    def batches(k2, k1, b=2):
        return {
            "x": jnp.asarray(rng.normal(size=(k2, k1, n, b, feat)), jnp.float32),
            "y": jnp.asarray(rng.normal(size=(k2, k1, n, b, out)), jnp.float32),
        }
    return loss_fn, p0, batches


@pytest.mark.parametrize("opt_name", ["sgd", "momentum"])
@pytest.mark.parametrize("block_clients", [None, 1, 2, 4])
def test_blocked_super_round_matches_baseline(rng, opt_name, block_clients):
    n, e, k1, k2 = 8, 2, 3, 4
    opt = sgd(0.1) if opt_name == "sgd" else momentum(0.1, 0.9)
    topo = FedTopology(num_edges=e, clients_per_edge=n // e)
    config = HierFAVGConfig(kappa1=k1, kappa2=k2)
    w = jnp.asarray(rng.uniform(1, 3, n), jnp.float32)
    loss_fn, p0, batches = _mk_problem(rng, n)
    blk = batches(k2, k1)
    st = init_state(jax.random.PRNGKey(0), p0, opt, topo, config)
    base = jax.jit(build_super_round(loss_fn, opt, topo, config, w))
    mega = jax.jit(
        build_megakernel_super_round(
            loss_fn, opt, topo, config, w, block_clients=block_clients
        )
    )
    sb, mb = base(jax.tree_util.tree_map(jnp.copy, st), blk, None)
    sm, mm = mega(jax.tree_util.tree_map(jnp.copy, st), blk)
    # same steps, same RNG chain; only the mean/metric summation order
    # differs -> documented reassociation tolerance
    for a, b in zip(
        jax.tree_util.tree_leaves(sb.params), jax.tree_util.tree_leaves(sm.params)
    ):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-5, atol=1e-6)
    for a, b in zip(
        jax.tree_util.tree_leaves(sb.opt_state), jax.tree_util.tree_leaves(sm.opt_state)
    ):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(np.asarray(mb["loss"]), np.asarray(mm["loss"]), rtol=1e-5)
    np.testing.assert_allclose(
        np.asarray(mb["grad_norm"]), np.asarray(mm["grad_norm"]), rtol=1e-5
    )
    np.testing.assert_array_equal(np.asarray(mb["step"]), np.asarray(mm["step"]))
    assert int(sb.step) == int(sm.step)
    # the per-client RNG chain is reproduced exactly, not approximately
    np.testing.assert_array_equal(np.asarray(sb.rng), np.asarray(sm.rng))


def test_blocked_super_round_rejects_masks(rng):
    n, e = 4, 2
    topo = FedTopology(num_edges=e, clients_per_edge=n // e)
    config = HierFAVGConfig(kappa1=2, kappa2=2)
    loss_fn, p0, batches = _mk_problem(rng, n)
    fn = build_megakernel_super_round(
        loss_fn, sgd(0.1), topo, config, jnp.ones((n,), jnp.float32)
    )
    st = init_state(jax.random.PRNGKey(0), p0, sgd(0.1), topo, config)
    with pytest.raises(TypeError, match="survival masks"):
        fn(st, batches(2, 2), jnp.ones((2, n), jnp.float32))


def test_blocked_super_round_rejects_unstackable_opt_state(rng):
    """adam forces f32 (N, ...) mu/nu rows — those stack fine; a synthetic
    optimizer with a wrong-leading-dim leaf must be rejected, not silently
    misblocked."""
    n, e = 4, 2
    topo = FedTopology(num_edges=e, clients_per_edge=n // e)
    config = HierFAVGConfig(kappa1=2, kappa2=2)
    loss_fn, p0, batches = _mk_problem(rng, n)
    fn = build_megakernel_super_round(
        loss_fn, adam(0.01), topo, config, jnp.ones((n,), jnp.float32)
    )
    st = init_state(jax.random.PRNGKey(0), p0, adam(0.01), topo, config)
    # adam's stacked state is fine
    fn(jax.tree_util.tree_map(jnp.copy, st), batches(2, 2))
    bad = st._replace(
        opt_state=jax.tree_util.tree_map(
            lambda x: x[: n - 1] if getattr(x, "ndim", 0) >= 1 and x.shape[0] == n else x,
            st.opt_state,
        )
    )
    with pytest.raises(ValueError, match="optimizer state leaves"):
        fn(bad, batches(2, 2))


# ---------------------------------------------------------------------------
# Eligibility predicate
# ---------------------------------------------------------------------------


def test_megakernel_incompatibility_reasons():
    topo = FedTopology(num_edges=2, clients_per_edge=4)
    ok = HierFAVGConfig(kappa1=2, kappa2=2)
    assert megakernel_incompatibility(ok, topo) is None
    cases = [
        (HierFAVGConfig(kappa1=2, kappa2=2, delta_cloud=True), "delta_cloud"),
        (HierFAVGConfig(kappa1=2, kappa2=2, sync_opt_state=True), "optimizer-state"),
    ]
    for cfg, frag in cases:
        reason = megakernel_incompatibility(cfg, topo)
        assert reason is not None and frag in reason, (cfg, reason)
    assert "microbatch" in megakernel_incompatibility(ok, topo, grad_accum=2)
    # ragged trees stay on the scan-fused path
    ragged = parse_fanouts("5,3/2")
    assert "uniform" in megakernel_incompatibility(ok, ragged)
    # deeper uniform trees too (the lowering is two-level only for now)
    deep = parse_fanouts("2,2,2,2/2,2/2")
    cfg3 = HierFAVGConfig.multi_level((2, 2, 2))
    assert megakernel_incompatibility(cfg3, deep) is not None


def test_megakernel_builder_raises_on_incompatible(rng):
    topo = FedTopology(num_edges=2, clients_per_edge=2)
    cfg = HierFAVGConfig(kappa1=2, kappa2=2, delta_cloud=True)
    loss_fn, _, _ = _mk_problem(rng, 4)
    with pytest.raises(ValueError, match="megakernel"):
        build_megakernel_super_round(
            loss_fn, sgd(0.1), topo, cfg, jnp.ones((4,), jnp.float32)
        )


# ---------------------------------------------------------------------------
# Engine fast path + named-reason fallback
# ---------------------------------------------------------------------------


def _spec(*overrides):
    return ExperimentSpec().with_overrides([
        "topology.num_edges=2", "topology.clients_per_edge=4",
        "schedule.kappas=2,2", "data.num_samples=320", "data.batch_size=4",
        "run.num_rounds=4", "run.eval_every=0", "cost.workload=none",
        *overrides,
    ])


def test_engine_megakernel_matches_superround_trajectory():
    runs = {}
    for eng in ("superround", "megakernel"):
        runner, state = _spec(f"run.engine={eng}").run_experiment()
        runs[eng] = (runner, state)
    rs, ss = runs["superround"]
    rm, sm = runs["megakernel"]
    assert rm._engine.uses_megakernel and rm._engine.megakernel_reason is None
    assert not getattr(rs._engine, "uses_megakernel", False)
    for a, b in zip(
        jax.tree_util.tree_leaves(ss.params), jax.tree_util.tree_leaves(sm.params)
    ):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-5, atol=1e-6)
    np.testing.assert_array_equal(np.asarray(ss.rng), np.asarray(sm.rng))
    assert len(rs.history) == len(rm.history)
    for h1, h2 in zip(rs.history, rm.history):
        assert h1.step == h2.step
        np.testing.assert_allclose(h1.loss, h2.loss, rtol=1e-5)
        np.testing.assert_allclose(h1.grad_norm, h2.grad_norm, rtol=1e-5)


def test_engine_megakernel_fallback_reasons():
    # schedule-level: delta_cloud keeps the scan-fused path
    runner, _ = _spec("run.engine=megakernel", "schedule.delta_cloud=true").run_experiment()
    eng = runner._engine
    assert not eng.uses_megakernel and "delta_cloud" in eng.megakernel_reason
    assert runner._megakernel_reason == eng.megakernel_reason
    # runner-level: failure models keep the scan-fused survival plumbing
    runner, _ = _spec("run.engine=megakernel", "failures.p_fail=0.3").run_experiment()
    assert not runner._engine.uses_megakernel
    assert "failure" in runner._engine.megakernel_reason


def test_engine_megakernel_fallback_still_correct():
    """A fallen-back megakernel run is exactly a superround run."""
    runs = {}
    for eng in ("superround", "megakernel"):
        runner, state = _spec(
            f"run.engine={eng}", "schedule.delta_cloud=true"
        ).run_experiment()
        runs[eng] = state
    for a, b in zip(
        jax.tree_util.tree_leaves(runs["superround"].params),
        jax.tree_util.tree_leaves(runs["megakernel"].params),
    ):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_engine_megakernel_mesh_routes_to_sharded(rng):
    """With a mesh, engine='megakernel' reports the mesh reason and runs
    the client-sharded superround (single-device mesh keeps it cheap)."""
    mesh = pytest.importorskip("jax.sharding").Mesh(
        np.array(jax.devices()[:1]), ("clients",)
    )
    n, e, k1, k2 = 8, 2, 2, 2
    data = clustered_gaussians(rng, num_samples=160, num_classes=4, dim=(6,), class_sep=2.0)
    parts = make_partition("iid", data.y, e, n // e, rng)
    batcher = FederatedBatcher(
        {"inputs": data.x, "targets": data.y}, parts, batch_size=4, seed=0
    )

    def apply_fn(p, x):
        return x @ p["w"]

    loss_fn = cnn.make_cnn_loss_fn(apply_fn)
    runner = FederatedRunner(
        loss_fn=loss_fn,
        optimizer=sgd(0.1),
        topology=FedTopology(num_edges=e, clients_per_edge=n // e),
        hier_config=HierFAVGConfig(kappa1=k1, kappa2=k2),
        data_sizes=batcher.data_sizes,
        batcher=batcher,
        runner_config=RunnerConfig(num_rounds=k2, engine="megakernel"),
        mesh=mesh,
    )
    p0 = {"w": jnp.asarray(rng.normal(size=(6, 4)) * 0.1, jnp.float32)}
    state = runner.init(jax.random.PRNGKey(0), p0)
    runner.run(state)
    assert not runner._engine.uses_megakernel
    assert "mesh" in runner._engine.megakernel_reason


def test_runner_config_engine_validation():
    RunnerConfig(num_rounds=1, engine="megakernel")
    with pytest.raises(ValueError, match="megakernel"):
        RunnerConfig(num_rounds=1, engine="hyperkernel")


def test_engine_megakernel_raises_without_whole_interval():
    spec = _spec("run.engine=megakernel", "run.num_rounds=1")  # < kappa2
    with pytest.raises(ValueError, match="megakernel"):
        spec.run_experiment()
