"""Sharded cohort superrounds: placement-stable packing laws, parity of the
composed lowering against both the single-device cohort engine and the
full-population sharded superround, and the mesh-composed cohort runner.

1-shard cases run everywhere (the full shard_map path over a 1-device
mesh); >=4-shard cases skip unless XLA_FLAGS=--xla_force_host_platform_device_count=4.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import FedTopology, HierFAVGConfig, init_state
from repro.core.hierarchy import (
    HierarchySpec,
    as_hierarchy,
    cohort_hierarchy,
    parse_fanouts,
    plan_cohort_placement,
    plan_shard_placement,
)
from repro.core.hierfavg import (
    _cohort_quotas,
    build_cohort_super_round,
    build_sharded_cohort_super_round,
    build_sharded_super_round,
    build_super_round,
    init_cohort_state,
    map_stacked_fed_state,
    sharded_cohort_incompatibility,
)
from repro.dist.sharding import (
    batch_block_sharding,
    client_mesh,
    fed_state_shardings,
    mask_stack_sharding,
)
from repro.fed import ParticipationSpec, TransportSpec
from repro.fed.participation import (
    StratifiedSampler,
    stratified_quotas,
    stratified_slot_edges,
)
from repro.optim import momentum, sgd
from repro.testing import given, settings, st

needs4 = pytest.mark.skipif(
    jax.device_count() < 4,
    reason="needs XLA_FLAGS=--xla_force_host_platform_device_count=4",
)
DIM = 3


# ---------------------------------------------------------------------------
# placement stability laws (the contract the sharded lowering rests on)
# ---------------------------------------------------------------------------

def _ragged_spec(sizes):
    """A 2-level ragged tree with the given per-edge client counts."""
    e = len(sizes)
    parents0 = tuple(int(x) for x in np.repeat(np.arange(e), sizes))
    return HierarchySpec(parents=(parents0, (0,) * e))


@given(
    sizes=st.lists(st.integers(1, 8), min_size=2, max_size=6),
    extra=st.integers(0, 10),
    shards=st.integers(1, 4),
)
@settings(max_examples=25)
def test_placement_stable_across_intervals_and_resume(sizes, extra, shards):
    """Stratified quotas, the slot->edge layout, and the planned cohort
    ShardPlacement are pure functions of (topology, mesh, cohort_size):
    identical across sampled intervals and across a sampler state_dict
    round-trip — and every shard's quota sum equals its valid slot count."""
    spec = _ragged_spec(sizes)
    n = spec.num_clients
    sizes = np.asarray(sizes, np.int64)
    c = int(min(n, len(sizes) + extra))
    shards = int(min(shards, len(sizes)))

    quotas = stratified_quotas(sizes, c)
    slot_edges = stratified_slot_edges(sizes, c)
    assert int(quotas.sum()) == c
    np.testing.assert_array_equal(
        slot_edges, np.repeat(np.arange(len(sizes)), quotas)
    )

    sampler = StratifiedSampler(n, c, spec.segments(1), seed=7)
    np.testing.assert_array_equal(sampler.quotas, quotas)
    seg1 = np.asarray(spec.segments(1))
    for _ in range(3):
        ids = sampler.sample()
        # every sorted stratified cohort fills the same slot->edge layout
        np.testing.assert_array_equal(seg1[ids], slot_edges)

    # resume: a state_dict round-trip replays the identical cohort stream
    snap = sampler.state_dict()
    twin = StratifiedSampler(n, c, spec.segments(1), seed=0)
    twin.load_state_dict(snap)
    np.testing.assert_array_equal(sampler.sample(), twin.sample())

    # the plan is deterministic: replanning yields the identical placement
    p1 = plan_cohort_placement(spec, quotas, shards)
    p2 = plan_cohort_placement(spec, quotas, shards)
    np.testing.assert_array_equal(p1.perm, p2.perm)
    assert p1.spec == cohort_hierarchy(spec, quotas)

    # per-shard slot accounting: edges never straddle shards, and each
    # shard's valid slot count is exactly the sum of its edges' quotas
    rows = np.asarray(p1.perm).reshape(shards, p1.capacity)
    seen_edges = {}
    for s in range(shards):
        slots = rows[s][rows[s] >= 0]
        edges_here = np.unique(slot_edges[slots])
        for e in edges_here:
            assert e not in seen_edges, "edge straddles shards"
            seen_edges[int(e)] = s
        assert slots.shape[0] == int(quotas[edges_here].sum())
    assert len(seen_edges) == len(sizes)


def test_stratified_rejects_cohort_smaller_than_edges():
    """The floor-1-per-edge quota needs cohort_size >= num_edges; the error
    names both numbers, at the sampler and at cohort eligibility."""
    with pytest.raises(ValueError, match=r"2 < 3"):
        stratified_quotas(np.asarray([4, 4, 4]), 2)
    from repro.core.hierfavg import cohort_incompatibility

    cfg = HierFAVGConfig(
        kappa1=2, kappa2=2,
        participation=ParticipationSpec(cohort_size=2, sampler="stratified"),
    )
    reason = cohort_incompatibility(cfg, parse_fanouts("4,4,4/3"), 2)
    assert reason is not None and "2 < 3" in reason


def test_sharded_cohort_incompatibility_reasons():
    spec = parse_fanouts("5,4,3/3")
    good = HierFAVGConfig(kappa1=2, kappa2=2)
    assert sharded_cohort_incompatibility(good, spec, 8, 2) is None
    # placement-stable packing needs the stratified sampler
    cfg = HierFAVGConfig(
        kappa1=2, kappa2=2,
        participation=ParticipationSpec(cohort_size=8, sampler="uniform"),
    )
    reason = sharded_cohort_incompatibility(cfg, spec, 8, 2)
    assert reason is not None and "stratified" in reason
    # delta_cloud + sync_opt_state has no sharded lowering (no opt anchor)
    cfg = HierFAVGConfig(kappa1=2, kappa2=2, delta_cloud=True, sync_opt_state=True)
    reason = sharded_cohort_incompatibility(cfg, spec, 8, 2)
    assert reason is not None and "sync_opt_state" in reason
    # a placement planned for a different shard count is rejected
    placement = plan_cohort_placement(spec, _cohort_quotas(spec, 8), 1)
    reason = sharded_cohort_incompatibility(good, spec, 8, 2, placement=placement)
    assert reason is not None and "shard" in reason


# ---------------------------------------------------------------------------
# builder parity
# ---------------------------------------------------------------------------

def _quad(rng, n):
    centers = rng.normal(size=(n, DIM))
    sizes = rng.integers(1, 4, size=n).astype(np.float64)

    def loss_fn(params, batch, _rng):
        return 0.5 * jnp.sum((params["w"] - batch["c"]) ** 2)

    batch = {"c": jnp.asarray(centers, jnp.float32)}
    return sizes, loss_fn, batch


def _stratified_ids(spec, c, rng):
    """A sorted stratified-shaped cohort (quota-block slot layout)."""
    edge_sizes = np.bincount(np.asarray(spec.segments(1)))
    quotas = stratified_quotas(edge_sizes, c)
    offsets = np.concatenate([[0], np.cumsum(edge_sizes)])
    return np.sort(
        np.concatenate(
            [
                offsets[e] + rng.choice(int(edge_sizes[e]), size=int(q), replace=False)
                for e, q in enumerate(quotas)
            ]
        )
    ).astype(np.int64)


def _identity_cohort(spec, sizes):
    if spec.depth > 1:
        table = np.stack(
            [np.asarray(spec.segments(l), np.int32) for l in range(1, spec.depth)]
        )
    else:
        table = np.zeros((0, spec.num_clients), np.int32)
    return {"segments": jnp.asarray(table), "weights": jnp.asarray(sizes, jnp.float32)}


def _assert_close(t1, t2, what):
    l1, l2 = jax.tree_util.tree_leaves(t1), jax.tree_util.tree_leaves(t2)
    assert len(l1) == len(l2), what
    for a, b in zip(l1, l2):
        np.testing.assert_allclose(
            np.asarray(a), np.asarray(b), rtol=3e-6, atol=2e-7, err_msg=what
        )


def _drive_sharded_cohort(topo, cfg, num_shards, *, c, opt=None, with_masks=False,
                          intervals=2, seed=0):
    """Run `intervals` cloud intervals through (a) the single-device cohort
    superround and (b) the sharded cohort superround over `num_shards`
    devices, with the same stratified-shaped cohort; return both final
    states (sharded one un-permuted to cohort order) and metric views."""
    opt = opt or sgd(0.1)
    spec = as_hierarchy(topo)
    n = spec.num_clients
    rng = np.random.default_rng(seed)
    sizes, loss_fn, batch = _quad(rng, n)
    k1, k2 = cfg.kappa1, cfg.kappa2_effective
    ids = _stratified_ids(spec, c, rng)
    cohort = {
        "segments": jnp.asarray(
            np.stack([np.asarray(spec.segments(l), np.int32)[ids]
                      for l in range(1, spec.depth)])
            if spec.depth > 1 else np.zeros((0, c), np.int32)
        ),
        "weights": jnp.asarray(sizes[ids], jnp.float32),
    }
    batch_c = {"c": batch["c"][ids]}
    block = jax.tree_util.tree_map(
        lambda x: jnp.stack([x] * (k2 * k1)).reshape((k2, k1) + x.shape), batch_c
    )
    masks = (
        None if not with_masks
        else (rng.random((intervals, k2, c)) > 0.3).astype(np.float32)
    )

    s1 = init_cohort_state(jax.random.PRNGKey(0), {"w": jnp.zeros(DIM)}, opt, cfg, c)
    coh = jax.jit(
        build_cohort_super_round(loss_fn, opt, topo, cfg, cohort_size=c),
        donate_argnums=(0,),
    )

    mesh = client_mesh(num_shards)
    placement = plan_cohort_placement(spec, _cohort_quotas(spec, c), num_shards)
    gather, pos = placement.gather_index(), placement.positions()
    valid = placement.valid()
    shc = jax.jit(
        build_sharded_cohort_super_round(
            loss_fn, opt, topo, cfg, cohort_size=c, mesh=mesh, placement=placement
        ),
        donate_argnums=(0,),
    )
    s2 = init_cohort_state(jax.random.PRNGKey(0), {"w": jnp.zeros(DIM)}, opt, cfg, c)
    s2 = map_stacked_fed_state(
        s2, lambda x: jnp.take(x, jnp.asarray(gather), axis=0), lambda x: x, c
    )
    s2 = jax.device_put(
        s2, fed_state_shardings(mesh, "clients", s2, placement.padded_clients)
    )
    block_sh = jax.tree_util.tree_map(
        lambda x: jax.device_put(
            jnp.take(x, jnp.asarray(gather), axis=2),
            batch_block_sharding(mesh, "clients"),
        ),
        block,
    )
    w_pad = jnp.asarray(placement.pad_weights(sizes[ids]))
    m1_all, m2_all = [], []
    for q in range(intervals):
        if masks is None:
            m1 = m2 = None
        else:
            m1 = jnp.asarray(masks[q])
            m2 = jax.device_put(
                jnp.asarray(masks[q][:, gather] * valid[None, :]),
                mask_stack_sharding(mesh, "clients"),
            )
        s1, mt1 = coh(s1, block, cohort, m1)
        s2, mt2 = shc(s2, block_sh, w_pad, m2)
        m1_all.append(jax.device_get(mt1))
        m2_all.append(jax.device_get(mt2))
    s2 = map_stacked_fed_state(
        s2, lambda x: jnp.take(x, jnp.asarray(pos), axis=0), lambda x: x,
        placement.padded_clients,
    )
    return s1, s2, m1_all, m2_all, placement


@pytest.mark.parametrize(
    "opt_name,cfg_kw,with_masks",
    [
        ("sgd", {}, False),
        ("sgd", {}, True),
        ("momentum", {"sync_opt_state": True}, False),
        ("sgd", {"transport": TransportSpec.parse("int8_ef:64/int8_ef:64")}, False),
    ],
    ids=["sgd", "sgd_masked", "momentum_sync_opt", "int8_ef_both"],
)
def test_sharded_cohort_single_shard_everywhere(opt_name, cfg_kw, with_masks):
    """The full sharded-cohort path over a 1-device mesh (C < N, ragged
    tree) — tier-1 always exercises the composed shard_map lowering."""
    topo = parse_fanouts("5,4,3/3")
    cfg = HierFAVGConfig(kappa1=2, kappa2=3, **cfg_kw)
    opt = momentum(0.1, 0.9) if opt_name == "momentum" else sgd(0.1)
    s1, s2, m1, m2, placement = _drive_sharded_cohort(
        topo, cfg, 1, c=8, opt=opt, with_masks=with_masks
    )
    _assert_close(s1.params, s2.params, "params")
    _assert_close(s1.opt_state, s2.opt_state, "opt_state")
    if s1.anchor is not None:
        _assert_close(s1.anchor, s2.anchor, "anchor")
    if s1.residual is not None:
        _assert_close(s1.residual, s2.residual, "residual")
    np.testing.assert_array_equal(np.asarray(s1.rng), np.asarray(s2.rng))
    valid = placement.valid()
    for a, b in zip(m1, m2):
        loss_b = np.asarray(b["loss"])[:, :, valid].mean(axis=(1, 2))
        np.testing.assert_allclose(np.asarray(a["loss"]), loss_b, rtol=1e-5, atol=1e-7)
        np.testing.assert_array_equal(np.asarray(a["step"]), np.asarray(b["step"]))


@needs4
@pytest.mark.parametrize(
    "opt_name,cfg_kw",
    [
        ("sgd", {}),
        ("momentum", {"sync_opt_state": True}),
        ("sgd", {"transport": TransportSpec.parse("int8_ef:64/int8_ef:64")}),
    ],
    ids=["sgd", "momentum_sync_opt", "int8_ef_both"],
)
def test_sharded_cohort_full_population_parity_4shards(opt_name, cfg_kw):
    """C == N over 4 shards: the sharded cohort superround reproduces
    ``build_sharded_super_round`` at the documented cloud-psum tolerance —
    the exit-proof parity anchor (incl. sync_opt_state and int8_ef)."""
    topo = FedTopology(num_edges=4, clients_per_edge=3)
    spec = as_hierarchy(topo)
    n = spec.num_clients
    cfg = HierFAVGConfig(kappa1=2, kappa2=3, **cfg_kw)
    opt = momentum(0.1, 0.9) if opt_name == "momentum" else sgd(0.1)
    rng = np.random.default_rng(0)
    sizes, loss_fn, batch = _quad(rng, n)
    w = jnp.asarray(sizes, jnp.float32)
    k1, k2 = cfg.kappa1, cfg.kappa2_effective
    mesh = client_mesh(4)

    # population path: edge-aligned client placement
    pop_placement = plan_shard_placement(spec, 4)
    # cohort path at C == N: quotas are exactly the edge sizes, so the slot
    # tree equals the client tree and both placements coincide
    coh_placement = plan_cohort_placement(spec, _cohort_quotas(spec, n), 4)
    np.testing.assert_array_equal(pop_placement.perm, coh_placement.perm)
    gather = pop_placement.gather_index()
    pos = pop_placement.positions()

    def shard_in(state, placement):
        out = map_stacked_fed_state(
            state, lambda x: jnp.take(x, jnp.asarray(gather), axis=0), lambda x: x, n
        )
        return jax.device_put(
            out, fed_state_shardings(mesh, "clients", out, placement.padded_clients)
        )

    block = jax.tree_util.tree_map(
        lambda x: jnp.stack([x] * (k2 * k1)).reshape((k2, k1) + x.shape), batch
    )
    block_sh = jax.tree_util.tree_map(
        lambda x: jax.device_put(
            jnp.take(x, jnp.asarray(gather), axis=2),
            batch_block_sharding(mesh, "clients"),
        ),
        block,
    )
    s1 = init_state(jax.random.PRNGKey(0), {"w": jnp.zeros(DIM)}, opt, topo, cfg)
    s1 = shard_in(s1, pop_placement)
    s2 = init_cohort_state(jax.random.PRNGKey(0), {"w": jnp.zeros(DIM)}, opt, cfg, n)
    s2 = shard_in(s2, coh_placement)
    sup = jax.jit(
        build_sharded_super_round(
            loss_fn, opt, topo, cfg, w, mesh=mesh, placement=pop_placement
        ),
        donate_argnums=(0,),
    )
    shc = jax.jit(
        build_sharded_cohort_super_round(
            loss_fn, opt, topo, cfg, cohort_size=n, mesh=mesh, placement=coh_placement
        ),
        donate_argnums=(0,),
    )
    w_pad = jnp.asarray(pop_placement.pad_weights(sizes))
    for _ in range(2):
        s1, mt1 = sup(s1, block_sh, None)
        s2, mt2 = shc(s2, block_sh, w_pad, None)
    unpad = lambda s: map_stacked_fed_state(
        s, lambda x: jnp.take(x, jnp.asarray(pos), axis=0), lambda x: x,
        pop_placement.padded_clients,
    )
    s1, s2 = unpad(s1), unpad(s2)
    _assert_close(s1.params, s2.params, "params")
    _assert_close(s1.opt_state, s2.opt_state, "opt_state")
    if s1.anchor is not None:
        _assert_close(s1.anchor, s2.anchor, "anchor")
    if s1.residual is not None:
        _assert_close(s1.residual, s2.residual, "residual")
    np.testing.assert_array_equal(np.asarray(s1.rng), np.asarray(s2.rng))
    np.testing.assert_allclose(
        np.asarray(mt1["loss"]), np.asarray(mt2["loss"]), rtol=1e-5, atol=1e-7
    )


def test_sharded_cohort_one_collective_per_interval():
    """Exactly one cross-device collective (the grouped cloud psum) in the
    whole sharded-cohort cloud-interval program."""
    topo = FedTopology(num_edges=4, clients_per_edge=4)
    spec = as_hierarchy(topo)
    c = 8
    cfg = HierFAVGConfig(kappa1=2, kappa2=3, sync_opt_state=True)
    rng = np.random.default_rng(0)
    sizes, loss_fn, _ = _quad(rng, spec.num_clients)
    opt = sgd(0.1)
    shards = min(4, jax.device_count())
    mesh = client_mesh(shards)
    placement = plan_cohort_placement(spec, _cohort_quotas(spec, c), shards)
    ids = _stratified_ids(spec, c, rng)
    state = init_cohort_state(jax.random.PRNGKey(0), {"w": jnp.zeros(DIM)}, opt, cfg, c)
    state = map_stacked_fed_state(
        state, lambda x: jnp.take(x, jnp.asarray(placement.gather_index()), axis=0),
        lambda x: x, c,
    )
    block = {
        "c": jnp.zeros((cfg.kappa2_effective, cfg.kappa1, placement.padded_clients, DIM))
    }
    w_pad = jnp.asarray(placement.pad_weights(sizes[ids]))
    fn = build_sharded_cohort_super_round(
        loss_fn, opt, topo, cfg, cohort_size=c, mesh=mesh, placement=placement
    )
    jaxpr = str(jax.make_jaxpr(fn)(state, block, w_pad, None))
    assert jaxpr.count(" psum") == 1, "expected exactly one psum per cloud interval"


# ---------------------------------------------------------------------------
# satellite: masked cohort == masked superround at C == N (same draw)
# ---------------------------------------------------------------------------

def test_cohort_masks_match_superround_full_population():
    """Survival masks compose with participation: at C == N the masked
    cohort superround reproduces the masked full-population superround
    bit-for-bit on a ragged tree (same mask draw, weight-column masking)."""
    spec = parse_fanouts("1,2,3/3")
    n = spec.num_clients
    rng = np.random.default_rng(3)
    sizes, loss_fn, batch = _quad(rng, n)
    cfg = HierFAVGConfig(kappa1=2, kappa2=3)
    opt = sgd(0.1)
    w = jnp.asarray(sizes, jnp.float32)
    k1, k2 = cfg.kappa1, cfg.kappa2_effective
    s1 = init_state(jax.random.PRNGKey(0), {"w": jnp.zeros(DIM)}, opt, spec, cfg)
    s2 = init_cohort_state(jax.random.PRNGKey(0), {"w": jnp.zeros(DIM)}, opt, cfg, n)
    sup = jax.jit(build_super_round(loss_fn, opt, spec, cfg, w), donate_argnums=(0,))
    coh = jax.jit(
        build_cohort_super_round(loss_fn, opt, spec, cfg, cohort_size=n),
        donate_argnums=(0,),
    )
    cohort = _identity_cohort(spec, sizes)
    block = jax.tree_util.tree_map(
        lambda x: jnp.stack([x] * (k2 * k1)).reshape((k2, k1) + x.shape), batch
    )
    for _ in range(2):
        masks = jnp.asarray((rng.random((k2, n)) > 0.3).astype(np.float32))
        s1, mt1 = sup(s1, block, masks)
        s2, mt2 = coh(s2, block, cohort, masks)
        np.testing.assert_array_equal(
            np.asarray(mt1["loss"]), np.asarray(mt2["loss"])
        )
    for t1, t2, what in [(s1.params, s2.params, "params"),
                         (s1.opt_state, s2.opt_state, "opt_state")]:
        for a, b in zip(jax.tree_util.tree_leaves(t1), jax.tree_util.tree_leaves(t2)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b), err_msg=what)
    np.testing.assert_array_equal(np.asarray(s1.rng), np.asarray(s2.rng))


# ---------------------------------------------------------------------------
# runner integration
# ---------------------------------------------------------------------------

def _cohort_spec(extra=()):
    from repro.fed.api import ExperimentSpec

    return ExperimentSpec.parse(
        [
            "topology.num_edges=4", "topology.clients_per_edge=4",
            "schedule.kappas=2,3", "run.num_rounds=12", "run.eval_every=6",
            "data.num_samples=320", "failures.p_fail=0.2",
            "participation.cohort_size=8", "participation.sampler=stratified",
        ]
        + list(extra)
    )


def test_cohort_runner_mesh_requires_stratified():
    """A mesh + a non-stratified sampler is a named hard error (no silent
    downgrade — sampled participation has no per-round fallback)."""
    spec = _cohort_spec(["participation.sampler=uniform", "topology.mesh_axes=clients:1"])
    with pytest.raises(ValueError, match="stratified"):
        spec.run_experiment()


def test_cohort_runner_with_failures_single_device():
    """Failure/straggler models compose with sampled participation (the old
    hard error is gone): the run completes, records cohort-column alive
    counts, and touches only sampled clients."""
    spec = _cohort_spec()
    runner, state = spec.run_experiment()
    assert runner.mesh is None and runner._engine is not None
    recs = runner.records_to_dict()
    assert recs["round"] == list(range(12))
    assert all(0 <= a <= 8 for a in recs["mask_alive"])
    assert any(a < 8 for a in recs["mask_alive"])  # p_fail=0.2 actually bit
    assert all(np.isfinite(l) for l in recs["loss"])


@needs4
def test_cohort_runner_mesh_parity_end_to_end():
    """The composed path: a mesh-configured cohort spec (stratified, with a
    failure model) runs through the sharded cohort engine and reproduces the
    single-device cohort run — history, masks, store, final params."""
    out = {}
    for tag, extra in [("single", []), ("mesh", ["topology.mesh_axes=clients:4"])]:
        runner, state = _cohort_spec(extra).run_experiment()
        out[tag] = (runner, runner.records_to_dict(), np.asarray(state.params["w1"]))
    runner_m, rec_m, p_m = out["mesh"]
    runner_s, rec_s, p_s = out["single"]
    assert runner_m.mesh is not None
    assert runner_m._engine is not None and runner_m._engine.mesh is not None
    assert runner_m._cohort_placement is not None
    np.testing.assert_allclose(p_s, p_m, rtol=3e-6, atol=2e-7)
    np.testing.assert_allclose(rec_s["loss"], rec_m["loss"], rtol=1e-5)
    assert rec_s["step"] == rec_m["step"]
    assert rec_s["mask_alive"] == rec_m["mask_alive"]
    # sticky rows land in the store by ORIGINAL client id on both paths
    st_s, st_m = runner_s.client_store, runner_m.client_store
    assert st_s.num_touched == st_m.num_touched
    for a, b in zip(st_s.state()["leaves"], st_m.state()["leaves"]):
        np.testing.assert_allclose(a, b, rtol=3e-6, atol=2e-7)
    for a, b in zip(rec_s["accuracy"], rec_m["accuracy"]):
        assert (a is None) == (b is None)
        if a is not None:
            assert abs(a - b) < 0.02


@needs4
def test_cohort_runner_mesh_resume_parity(tmp_path):
    """Interrupted + resumed sharded-cohort run == straight run: the slot
    placement is re-planned identically (placement stability) and the
    checkpoint carries canonical cohort-order state + sampler snapshots."""
    from repro.checkpoint import CheckpointManager

    def run_spec(ckdir, num_rounds):
        spec = _cohort_spec(
            ["topology.mesh_axes=clients:4", f"run.num_rounds={num_rounds}",
             "run.checkpoint_every=6"]
        )
        runner = spec.build()
        runner.checkpointer = CheckpointManager(str(ckdir), keep=4)
        params = spec.init_params(jax.random.PRNGKey(1))
        state, start = runner.restore_or_init(jax.random.PRNGKey(0), params)
        state = runner.run(state, start_round=start)
        return runner, state, start

    ra, sa, _ = run_spec(tmp_path / "straight", 12)
    rb, sb, start_b = run_spec(tmp_path / "resumed", 6)
    assert start_b == 0
    rc, sc, start_c = run_spec(tmp_path / "resumed", 12)  # resumes at round 6
    assert start_c == 6
    np.testing.assert_allclose(
        np.asarray(sa.params["w1"]), np.asarray(sc.params["w1"]),
        rtol=3e-6, atol=2e-7,
    )
    st_a, st_c = ra.client_store.state(), rc.client_store.state()
    for a, b in zip(st_a["leaves"], st_c["leaves"]):
        np.testing.assert_allclose(a, b, rtol=3e-6, atol=2e-7)
    np.testing.assert_array_equal(st_a["touched"], st_c["touched"])
