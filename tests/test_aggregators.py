"""Per-level pluggable aggregators: robust statistics vs numpy oracles,
survival-mask and ragged-tree handling, AggregatorSpec plumbing through
HierFAVGConfig, and bit-exactness of the default weighted_mean spec versus
the pre-redesign aggregation path on both execution engines."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (
    AggregatorSpec,
    FedTopology,
    HierFAVGConfig,
    TrimmedMeanAggregator,
    aggregation,
    build_hier_round,
    init_state,
    parse_fanouts,
)
from repro.core.aggregation import (
    parse_aggregator,
    segment_coordinate_median,
    segment_trimmed_mean,
)
from repro.data import FederatedBatcher, clustered_gaussians, make_partition
from repro.fed import FederatedRunner, RunnerConfig, scenarios
from repro.models import cnn
from repro.optim import sgd


# ---------------------------------------------------------------------------
# Statistic oracles
# ---------------------------------------------------------------------------

def _np_trimmed(x, trim):
    m = x.shape[0]
    k = int(np.floor(trim * m))
    s = np.sort(x, axis=0)
    return s[k : m - k].mean(axis=0)


@pytest.mark.parametrize("seg,mask", [
    (np.array([0, 0, 0, 1, 1, 1]), None),  # uniform
    (np.array([0, 0, 0, 0, 1, 1]), None),  # ragged
    (np.array([0, 0, 0, 0, 1, 1]), np.array([1, 0, 1, 1, 1, 1], np.float32)),
])
def test_segment_trimmed_mean_matches_numpy(rng, seg, mask):
    x = rng.normal(size=(6, 5)).astype(np.float32)
    out = np.asarray(segment_trimmed_mean(
        {"w": jnp.asarray(x)}, seg, 2, None if mask is None else jnp.asarray(mask),
        trim=0.3,
    )["w"])
    for g in range(2):
        in_g = (seg == g) if mask is None else ((seg == g) & (mask > 0))
        ref = _np_trimmed(x[np.where(in_g)[0]], 0.3)
        got = out[seg == g]
        np.testing.assert_allclose(got, np.broadcast_to(ref, got.shape), atol=1e-6)


@pytest.mark.parametrize("sizes", [(3, 3), (4, 2), (5, 4)])  # odd + even groups
def test_segment_coordinate_median_matches_numpy(rng, sizes):
    seg = np.concatenate([np.full(c, g) for g, c in enumerate(sizes)])
    x = rng.normal(size=(seg.shape[0], 7)).astype(np.float32)
    out = np.asarray(segment_coordinate_median({"w": jnp.asarray(x)}, seg, len(sizes), None)["w"])
    for g in range(len(sizes)):
        ref = np.median(x[seg == g], axis=0)
        got = out[seg == g]
        np.testing.assert_allclose(got, np.broadcast_to(ref, got.shape), atol=1e-6)


def test_zero_survivor_group_keeps_params(rng):
    x = rng.normal(size=(6, 4)).astype(np.float32)
    seg = np.array([0, 0, 0, 1, 1, 1])
    mask = jnp.asarray(np.array([0, 0, 0, 1, 1, 1], np.float32))
    for fn in (segment_trimmed_mean, segment_coordinate_median):
        out = np.asarray(fn({"w": jnp.asarray(x)}, seg, 2, mask)["w"])
        np.testing.assert_array_equal(out[:3], x[:3])  # dead group frozen
        assert not np.array_equal(out[3:], x[3:])  # alive group aggregated


def test_trimmed_mean_discards_outlier_median_too(rng):
    x = rng.normal(size=(8, 3)).astype(np.float32)
    clean_mean = x[:7].mean(axis=0)
    x[7] = 1e6  # one Byzantine client
    seg = np.zeros(8, np.int64)
    t = np.asarray(segment_trimmed_mean({"w": jnp.asarray(x)}, seg, 1, None, trim=0.2)["w"])[0]
    m = np.asarray(segment_coordinate_median({"w": jnp.asarray(x)}, seg, 1, None)["w"])[0]
    assert np.max(np.abs(t - clean_mean)) < 1.0
    assert np.max(np.abs(m - clean_mean)) < 1.0
    # the weighted mean is destroyed by the outlier
    wm = np.asarray(aggregation.weighted_mean(
        {"w": jnp.asarray(x)}, jnp.ones(8))["w"])[0]
    assert np.max(np.abs(wm - clean_mean)) > 1e4


# ---------------------------------------------------------------------------
# Spec parsing / config plumbing
# ---------------------------------------------------------------------------

def test_parse_aggregator_grammar():
    assert parse_aggregator("weighted_mean").is_default
    assert parse_aggregator("trimmed_mean:0.2") == TrimmedMeanAggregator(trim=0.2)
    assert parse_aggregator("median").name == "coordinate_median"
    with pytest.raises(ValueError, match="unknown aggregator"):
        parse_aggregator("krum")
    with pytest.raises(ValueError, match="trim"):
        parse_aggregator("trimmed_mean:0.6")


def test_aggregator_spec_describe_roundtrip():
    s = AggregatorSpec.parse("trimmed_mean:0.1/weighted_mean")
    assert AggregatorSpec.parse(s.describe()) == s
    assert not s.is_trivial and s.depth == 2
    assert AggregatorSpec.default(3).is_trivial


def test_config_validates_aggregator_depth_and_flags():
    with pytest.raises(ValueError, match="levels"):
        HierFAVGConfig(kappa1=2, kappa2=2, aggregators=AggregatorSpec.parse("median/median/median"))
    with pytest.raises(TypeError, match="AggregatorSpec"):
        HierFAVGConfig(kappa1=2, kappa2=2, aggregators="median/median")
    with pytest.raises(ValueError, match="delta_cloud"):
        HierFAVGConfig(kappa1=2, kappa2=2, delta_cloud=True,
                       aggregators=AggregatorSpec.parse("weighted_mean/median"))
    # robust edge + delta top is fine; trivial spec composes with anything
    HierFAVGConfig(kappa1=2, kappa2=2, delta_cloud=True,
                   aggregators=AggregatorSpec.parse("median/weighted_mean"))
    HierFAVGConfig(kappa1=2, kappa2=2, delta_cloud=True, aggregators=AggregatorSpec.default(2))


# ---------------------------------------------------------------------------
# End-to-end: bit-exactness of the default, robust runs on both engines
# ---------------------------------------------------------------------------

def _runner(engine, aggregators, *, num_rounds=6, seed=0):
    rng = np.random.default_rng(seed)
    data = clustered_gaussians(rng, num_samples=360, num_classes=10, dim=(8,), class_sep=3.0)
    parts = make_partition("edge_iid", data.y, 2, 3, rng)
    batcher = FederatedBatcher(
        {"inputs": data.x, "targets": data.y}, parts, batch_size=4, seed=seed
    )

    def apply_fn(p, x):
        return jax.nn.relu(x @ p["w1"]) @ p["w2"]

    runner = FederatedRunner(
        loss_fn=cnn.make_cnn_loss_fn(apply_fn),
        optimizer=sgd(0.1),
        topology=FedTopology(num_edges=2, clients_per_edge=3),
        hier_config=HierFAVGConfig(kappa1=2, kappa2=3, aggregators=aggregators),
        data_sizes=batcher.data_sizes,
        batcher=batcher,
        runner_config=RunnerConfig(num_rounds=num_rounds, engine=engine),
    )
    k1, k2 = jax.random.split(jax.random.PRNGKey(seed))
    params = {
        "w1": jax.random.normal(k1, (8, 16)) * 0.3,
        "w2": jax.random.normal(k2, (16, 10)) * 0.3,
    }
    state = runner.init(jax.random.PRNGKey(seed), params)
    state = runner.run(state)
    return runner, state


@pytest.mark.parametrize("engine", ["per_round", "superround"])
def test_default_aggregator_spec_is_bitwise_noop(engine):
    """An all-weighted_mean AggregatorSpec must take the exact legacy path:
    identical params and history on the per-round AND superround engines."""
    r_none, s_none = _runner(engine, None)
    r_spec, s_spec = _runner(engine, AggregatorSpec.default(2))
    np.testing.assert_array_equal(np.asarray(s_none.params["w1"]), np.asarray(s_spec.params["w1"]))
    np.testing.assert_array_equal(np.asarray(s_none.params["w2"]), np.asarray(s_spec.params["w2"]))
    assert r_none.records_to_dict() == r_spec.records_to_dict()


def test_robust_aggregators_engine_parity():
    """trimmed edge / median cloud runs agree across the two engines (same
    lax.switch subgraph, scan-fused or not) — up to the documented 1-ULP
    XLA:CPU codegen drift (docs/performance.md) that the sort/gather
    statistics amplify past exact equality."""
    agg = AggregatorSpec.parse("trimmed_mean:0.2/coordinate_median")
    _, s_per = _runner("per_round", agg)
    _, s_super = _runner("superround", agg)
    np.testing.assert_allclose(
        np.asarray(s_per.params["w1"]), np.asarray(s_super.params["w1"]),
        rtol=2e-6, atol=1e-6,
    )


def test_robust_cloud_sync_collapses_clients():
    """After a median cloud boundary every client holds the same model."""
    agg = AggregatorSpec.parse("weighted_mean/coordinate_median")
    _, state = _runner("per_round", agg, num_rounds=3)  # round 3 = cloud boundary
    w1 = np.asarray(state.params["w1"])
    np.testing.assert_array_equal(w1, np.broadcast_to(w1[0], w1.shape))


def test_robust_aggregation_on_ragged_tree(rng):
    """Trimmed edge sync runs on a ragged HierarchySpec via build_hier_round."""
    spec = parse_fanouts("3,5,2/3")
    n = spec.num_clients
    cfg = HierFAVGConfig.multi_level(
        (2, 2), aggregators=AggregatorSpec.parse("trimmed_mean:0.2/weighted_mean")
    )
    weights = jnp.asarray(rng.integers(1, 4, size=n), jnp.float32)

    def loss_fn(params, batch, _rng):
        return 0.5 * jnp.sum((params["w"] - batch["c"]) ** 2)

    opt = sgd(0.1)
    state = init_state(jax.random.PRNGKey(0), {"w": jnp.zeros(4)}, opt, spec, cfg)
    round_fn = jax.jit(build_hier_round(loss_fn, opt, spec, cfg, weights))
    batches = {"c": jnp.asarray(rng.normal(size=(2, n, 4)), jnp.float32)}
    mask = jnp.asarray((rng.random(n) > 0.2).astype(np.float32))
    state, metrics = round_fn(state, batches, jnp.int32(0), mask)
    assert np.isfinite(float(metrics["loss"]))
    assert np.all(np.isfinite(np.asarray(state.params["w"])))


def test_eval_model_uses_robust_top_aggregator():
    """The eval/early-stop path scores the model the cloud would actually
    publish: the robust top-level statistic, not the weighted mean."""
    r_med, s = _runner("per_round", AggregatorSpec.parse("weighted_mean/coordinate_median"),
                       num_rounds=1)
    params = jax.tree_util.tree_map(lambda x: jnp.asarray(np.asarray(x)), s.params)
    # poison one client: the weighted mean moves, the median must not
    poisoned = jax.tree_util.tree_map(lambda x: x.at[0].set(1e6), params)
    med = np.asarray(r_med.eval_model(poisoned, None)["w1"])
    ref = np.median(np.asarray(poisoned["w1"]), axis=0)
    np.testing.assert_allclose(med, ref, atol=1e-6)

    r_def, _ = _runner("per_round", None, num_rounds=1)
    wm = np.asarray(r_def.eval_model(poisoned, None)["w1"])
    assert np.max(np.abs(wm)) > 1e4  # default path is the (poisoned) mean


def test_trimmed_edge_scenario_from_registry():
    """Acceptance: a trimmed_mean edge-level scenario runs end-to-end from a
    registry name with no hand-assembled runner."""
    runner, state = scenarios.get(
        "trimmed_edge", overrides=["run.num_rounds=4", "run.eval_every=4"]
    ).run_experiment()
    assert runner.hier_config.aggregators_active
    assert runner.hier_config.aggregators.aggregator(1).name == "trimmed_mean:0.1"
    assert len(runner.history) == 4
    acc = runner.history[-1].accuracy
    assert acc is not None and acc > 0.3
    assert np.all(np.isfinite(np.asarray(state.params["w1"])))
