"""HLO parser unit tests on hand-written modules + roofline algebra."""
import numpy as np
import pytest

from repro.analysis import hlo
from repro.analysis.roofline import RooflineTerms, hierfavg_step_terms

SAMPLE = """\
HloModule test

%add (a: f32[], b: f32[]) -> f32[] {
  %a = f32[] parameter(0)
  %b = f32[] parameter(1)
  ROOT %s = f32[] add(%a, %b)
}

%body (p: (s32[], f32[8,16])) -> (s32[], f32[8,16]) {
  %p = (s32[], f32[8,16]) parameter(0)
  %i = s32[] get-tuple-element(%p), index=0
  %x = f32[8,16]{1,0} get-tuple-element(%p), index=1
  %w = f32[16,16]{1,0} constant({...})
  %d = f32[8,16]{1,0} dot(%x, %w), lhs_contracting_dims={1}, rhs_contracting_dims={0}
  %ar = f32[8,16]{1,0} all-reduce(%d), channel_id=1, replica_groups=[4,2]<=[8], use_global_device_ids=true, to_apply=%add
  %one = s32[] constant(1)
  %ni = s32[] add(%i, %one)
  ROOT %t = (s32[], f32[8,16]) tuple(%ni, %ar)
}

%cond (p: (s32[], f32[8,16])) -> pred[] {
  %p = (s32[], f32[8,16]) parameter(0)
  %i = s32[] get-tuple-element(%p), index=0
  %k = s32[] constant(5)
  ROOT %c = pred[] compare(%i, %k), direction=LT
}

ENTRY %main (x: f32[8,16]) -> (s32[], f32[8,16]) {
  %x = f32[8,16]{1,0} parameter(0)
  %z = s32[] constant(0)
  %init = (s32[], f32[8,16]) tuple(%z, %x)
  ROOT %w = (s32[], f32[8,16]) while(%init), condition=%cond, body=%body
}
"""


def test_parse_and_trip_count():
    comps = hlo.parse_hlo(SAMPLE)
    assert set(comps) == {"add", "body", "cond", "main"}
    assert hlo.while_trip_count(comps["cond"], comps) == 5


def test_flops_with_while_multiplier():
    s = hlo.analyze(SAMPLE)
    # dot: 2*8*16*16 = 4096 flops, ×5 trips
    assert s.flops == pytest.approx(5 * 4096)
    assert s.unresolved_whiles == 0


def test_collective_counting_and_ring_model():
    s = hlo.analyze(SAMPLE)
    assert len(s.collectives) == 1
    c = s.collectives[0]
    assert c.count == 5 and c.group_size == 2
    # ring all-reduce: 2*(2-1)/2 * 512B = 512B per execution
    assert s.collective_bytes_per_device() == pytest.approx(5 * 512)


def test_tuple_type_with_index_comment():
    txt = """\
ENTRY %m (p: f32[4]) -> f32[4] {
  %p = f32[4]{0} parameter(0)
  ROOT %ar = (f32[4]{0}, f32[8]{0}, /*index=2*/f32[16]{0}) all-reduce(%p, %p, %p), replica_groups={{0,1},{2,3}}, to_apply=%a
}
"""
    comps = hlo.parse_hlo(txt)
    op = comps["m"].ops["ar"]
    assert op.opcode == "all-reduce"
    assert hlo._shape_bytes(op.type_str) == (4 + 8 + 16) * 4


def test_replica_group_reconstruction_iota_with_transpose():
    g = hlo.parse_replica_groups("replica_groups=[8,2]<=[2,4,2]T(1,0,2)")
    assert g.shape == (8, 2)
    arr = np.arange(16).reshape(2, 4, 2).transpose(1, 0, 2).reshape(8, 2)
    np.testing.assert_array_equal(g, arr)


def test_replica_group_explicit():
    g = hlo.parse_replica_groups("replica_groups={{0,2},{1,3}}")
    np.testing.assert_array_equal(g, [[0, 2], [1, 3]])


def test_roofline_dominant_and_fraction():
    t = RooflineTerms(
        name="x", chips=256,
        flops_per_device=197e12 * 0.5,  # 0.5 s compute
        hbm_bytes_per_device=819e9 * 0.25,  # 0.25 s memory
        coll_bytes_per_device=50e9 * 0.1,  # 0.1 s collective
        coll_breakdown={"model": 50e9 * 0.1},
        model_flops_global=197e12 * 256 * 0.4,
    )
    assert t.dominant == "compute"
    assert t.roofline_fraction == pytest.approx(0.4 / 0.5)


def test_hierfavg_amortization():
    """Edge bytes /kappa1, cloud bytes /kappa1*kappa2 — the paper's knob."""
    local = RooflineTerms("l", 256, 1e12, 1e9, 1e9, {"model": 1e9})
    edge = RooflineTerms("e", 256, 0, 0, 8e9, {"data": 8e9})
    cloud = RooflineTerms("c", 256, 0, 0, 16e9, {"pod,data": 16e9})
    amort = hierfavg_step_terms("a", local, edge, cloud, kappa1=4, kappa2=2)
    assert amort.coll_bytes_per_device == pytest.approx(1e9 + 8e9 / 4 + 16e9 / 8)
    # DCN-slowdown applies to the pod-axis share
    assert amort.collective_s > (1e9 + 2e9) / 50e9


def test_bf16_promotion_halves_effective_bytes():
    txt = """\
ENTRY %m (p: bf16[64]) -> bf16[64] {
  %p = bf16[64]{0} parameter(0)
  %c = f32[64]{0} convert(%p)
  %ar = f32[64]{0} all-reduce(%c), replica_groups=[1,2]<=[2], to_apply=%a
  ROOT %o = bf16[64]{0} convert(%ar)
}
"""
    s = hlo.analyze(txt)
    c = s.collectives[0]
    assert c.bf16_promoted
    assert c.effective_bytes == pytest.approx(64 * 4 / 2)
