"""Roofline calibration against the edge-interval megakernel (marker:
``calibration`` — timing-sensitive, host-dependent, not tier-1; run with
``pytest -m calibration``)."""
import pytest

from repro.analysis.roofline import (
    calibrate_megakernel,
    measure_host_peaks,
    megakernel_interval_cost,
)

pytestmark = pytest.mark.calibration

# achieved throughput can legitimately sit far below peak (tiny shape, jit
# overhead) but must never *beat* the host's measured peak by more than the
# micro-probes' own noise
LOOSE_FACTOR = 2.0


def test_interval_cost_model_scales():
    c1 = megakernel_interval_cost(num_clients=8, kappa1=4, batch=2, feat=64, out=128)
    c2 = megakernel_interval_cost(num_clients=16, kappa1=4, batch=2, feat=64, out=128)
    assert c2["flops"] == 2 * c1["flops"]
    assert c2["bytes"] == 2 * c1["bytes"]
    # doubling kappa1 doubles step work but NOT the params/momentum traffic
    c3 = megakernel_interval_cost(num_clients=8, kappa1=8, batch=2, feat=64, out=128)
    assert c3["flops"] < 2 * c1["flops"]
    assert c3["bytes"] < 2 * c1["bytes"]


def test_calibration_achieved_within_peak_envelope():
    peaks = measure_host_peaks(n=512, reps=3)
    assert peaks["flops"] > 0 and peaks["bw"] > 0
    res = calibrate_megakernel(reps=3, peaks=peaks)
    assert res.elapsed_s > 0
    # the loose-factor envelope: achieved in (0, LOOSE_FACTOR x peak]
    assert 0 < res.flops_fraction <= LOOSE_FACTOR, res.to_dict()
    assert 0 < res.bw_fraction <= LOOSE_FACTOR, res.to_dict()
    d = res.to_dict()
    for key in ("achieved_flops", "achieved_bw", "peak_flops", "peak_bw"):
        assert d[key] > 0
