"""optim.compression coverage: self-describing QuantizedTree, roundtrip
error bounds, zero-block safety, composition with aggregation, and the
cross-check that the jnp quantizers and the Pallas/ref kernel quantizer
produce identical payloads on lane-aligned shapes."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import aggregation
from repro.fed import transport as tp
from repro.kernels import ops, ref
from repro.optim import compression

ops.set_interpret(True)


def make_tree(rng, scale=2.0):
    return {
        "w1": jnp.asarray(rng.normal(size=(37, 129)) * scale, jnp.float32),
        "b": jnp.asarray(rng.normal(size=(513,)) * scale, jnp.float32),
        "nested": {"w2": jnp.asarray(rng.normal(size=(8, 64)) * scale, jnp.float32)},
    }


# ---------------------------------------------------------------------------
# Self-describing QuantizedTree (no `like` tree needed)
# ---------------------------------------------------------------------------

def test_dequantize_self_describing(rng):
    tree = make_tree(rng)
    q = compression.quantize_int8(tree, block=128)
    assert q.shapes is not None and q.dtypes is not None
    back = compression.dequantize_int8(q)  # no `like`
    assert jax.tree_util.tree_structure(back) == jax.tree_util.tree_structure(tree)
    for a, b in zip(jax.tree_util.tree_leaves(back), jax.tree_util.tree_leaves(tree)):
        assert a.shape == b.shape and a.dtype == b.dtype


def test_dequantize_like_still_supported_and_equal(rng):
    tree = make_tree(rng)
    q = compression.quantize_int8(tree, block=256)
    via_meta = compression.dequantize_int8(q)
    via_like = compression.dequantize_int8(q, tree)
    for a, b in zip(jax.tree_util.tree_leaves(via_meta), jax.tree_util.tree_leaves(via_like)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_dequantize_without_metadata_requires_like(rng):
    tree = make_tree(rng)
    q = compression.quantize_int8(tree, block=256)
    legacy = compression.QuantizedTree(payload=q.payload, scales=q.scales, block=q.block)
    with pytest.raises(ValueError):
        compression.dequantize_int8(legacy)
    back = compression.dequantize_int8(legacy, tree)  # old call form
    for a, b in zip(jax.tree_util.tree_leaves(back), jax.tree_util.tree_leaves(tree)):
        assert a.shape == b.shape


def test_dequantize_preserves_dtype(rng):
    tree = {"w": jnp.asarray(rng.normal(size=(16, 128)), jnp.bfloat16)}
    q = compression.quantize_int8(tree, block=128)
    back = compression.dequantize_int8(q)
    assert back["w"].dtype == jnp.bfloat16


# ---------------------------------------------------------------------------
# Roundtrip error bound / zero-block safety / wire size
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("block", [64, 128, 256])
def test_roundtrip_error_within_half_scale(rng, block):
    tree = make_tree(rng)
    q = compression.quantize_int8(tree, block=block)
    back = compression.dequantize_int8(q)
    for x, b, s in zip(
        jax.tree_util.tree_leaves(tree),
        jax.tree_util.tree_leaves(back),
        jax.tree_util.tree_leaves(q.scales),
    ):
        # per-element error bounded by its block's scale/2 (absmax grid)
        err = np.abs(np.asarray(b, np.float32) - np.asarray(x, np.float32))
        bound = np.repeat(np.asarray(s), block)[: x.size].reshape(x.shape)
        assert np.all(err <= bound * 0.5 + 1e-7)


def test_zero_block_safety():
    tree = {"w": jnp.zeros((4, 300), jnp.float32)}
    q = compression.quantize_int8(tree, block=128)
    back = compression.dequantize_int8(q)
    assert float(jnp.max(jnp.abs(back["w"]))) == 0.0
    # mixed zero/nonzero blocks: zero blocks stay exactly zero
    x = jnp.zeros((512,), jnp.float32).at[:128].set(3.0)
    q2 = compression.quantize_int8({"w": x}, block=128)
    back2 = compression.dequantize_int8(q2)["w"]
    assert float(jnp.max(jnp.abs(back2[128:]))) == 0.0
    np.testing.assert_allclose(np.asarray(back2[:128]), 3.0, rtol=1e-6)


def test_compressed_bytes_quarter_of_fp32(rng):
    tree = make_tree(rng)
    q = compression.quantize_int8(tree, block=256)
    n_params = sum(x.size for x in jax.tree_util.tree_leaves(tree))
    wire = compression.compressed_bytes(q)
    # int8 payload (padded to block) + fp32 scale per block ≈ n/4 of fp32
    assert wire < 4 * n_params * 0.3
    assert wire >= n_params  # at least 1 byte per param


# ---------------------------------------------------------------------------
# Composition with aggregation (compress → aggregate ≈ aggregate)
# ---------------------------------------------------------------------------

def test_compress_aggregate_commutes_within_bound(rng):
    n, d = 9, 400
    x = jnp.asarray(rng.normal(size=(n, d)), jnp.float32)
    w = jnp.asarray(rng.uniform(0.5, 3.0, size=n), jnp.float32)
    seg = jnp.asarray([0, 0, 0, 0, 1, 1, 2, 2, 2], jnp.int32)
    # compress each client's row (per-client blocks, transport layout)
    q, s = tp.quantize_rows(x, 128)
    decoded = tp.dequantize_rows(q, s, d, 128)
    agg_compressed = aggregation.segment_weighted_mean(decoded, w, seg, 3)
    agg_plain = aggregation.segment_weighted_mean(x, w, seg, 3)
    # aggregation is a convex combination -> error stays within the
    # per-element roundtrip bound max(scale)/2
    bound = float(jnp.max(s)) * 0.5 + 1e-6
    assert float(jnp.max(jnp.abs(agg_compressed - agg_plain))) <= bound


# ---------------------------------------------------------------------------
# Cross-check: optim.compression (jnp) vs kernels.quantize (Pallas + ref)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("shape,block", [((8, 1024), 256), ((4, 512), 128), ((2048,), 256)])
def test_jnp_and_pallas_quantizers_identical(rng, shape, block):
    """On lane-aligned shapes the three quantizers are the same wire format:
    identical int8 payloads AND identical scales (compared under jit, where
    the interpret-mode Pallas kernel also runs)."""
    x = jnp.asarray(rng.normal(size=shape) * 3.0, jnp.float32)

    qk, sk, _ = ops.quantize_int8(x, qblock=block)  # Pallas (interpret, jitted)
    qr, sr = jax.jit(lambda v: ref.quantize_ref(v, qblock=block)[:2])(x)  # kernel oracle
    qo_tree = jax.jit(lambda v: compression.quantize_int8(v, block=block)[:2])({"x": x})
    qo, so = qo_tree[0]["x"], qo_tree[1]["x"]  # optim jnp quantizer

    np.testing.assert_array_equal(np.asarray(qk), np.asarray(qr))
    np.testing.assert_array_equal(np.asarray(qk).reshape(-1), np.asarray(qo).reshape(-1))
    np.testing.assert_array_equal(np.asarray(sk).reshape(-1), np.asarray(sr).reshape(-1))
    np.testing.assert_array_equal(np.asarray(sk).reshape(-1), np.asarray(so).reshape(-1))


def test_transport_rows_match_pallas_stacked(rng):
    """fed.transport.quantize_rows == kernels quantize_stacked payload
    layout, bit for bit (under jit)."""
    x = jnp.asarray(rng.normal(size=(8, 1024)), jnp.float32)
    qt, st = jax.jit(lambda v: tp.quantize_rows(v, 256))(x)
    qk, sk = ops.quantize_stacked(x, qblock=256)
    np.testing.assert_array_equal(np.asarray(qt), np.asarray(qk))
    np.testing.assert_array_equal(np.asarray(st), np.asarray(sk))
