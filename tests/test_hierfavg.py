"""HierFAVG (Algorithm 1) semantics vs the literal numpy oracle."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (
    FedTopology, HierFAVGConfig, build_hier_round, build_train_step,
    init_state, reference,
)
from repro.core import aggregation
from repro.optim import sgd


def quadratic_setup(rng, n=6, dim=4, edges=2):
    centers = rng.normal(size=(n, dim))
    sizes = rng.integers(1, 5, size=n).astype(np.float64)
    grad_fns = [lambda w, c=centers[i]: (w - c) for i in range(n)]

    def loss_fn(params, batch, _rng):
        return 0.5 * jnp.sum((params["w"] - batch["c"]) ** 2)

    batch = {"c": jnp.asarray(centers, jnp.float32)}
    return centers, sizes, grad_fns, loss_fn, batch


@pytest.mark.parametrize("kappa1,kappa2", [(2, 3), (1, 1), (3, 1), (1, 4), (4, 2)])
def test_matches_reference(rng, kappa1, kappa2):
    n, dim, edges = 6, 4, 2
    centers, sizes, grad_fns, loss_fn, batch = quadratic_setup(rng, n, dim, edges)
    topo = FedTopology(num_edges=edges, clients_per_edge=n // edges)
    cfg = HierFAVGConfig(kappa1=kappa1, kappa2=kappa2)
    opt = sgd(0.1)
    state = init_state(jax.random.PRNGKey(0), {"w": jnp.zeros(dim)}, opt, topo, cfg)
    step = jax.jit(build_train_step(loss_fn, opt, topo, cfg, jnp.asarray(sizes, jnp.float32)))
    K = 2 * kappa1 * kappa2 + kappa1  # includes a partial interval
    for _ in range(K):
        state, _ = step(state, batch)
    ref = reference.hierfavg_reference(np.zeros(dim), grad_fns, sizes, edges, kappa1, kappa2, K, 0.1)
    np.testing.assert_allclose(np.asarray(state.params["w"]), np.stack(ref), atol=1e-5)


def test_kappa2_1_equals_fedavg(rng):
    """Remark 1: kappa2 = 1 retrogrades to two-layer FAVG."""
    n, dim = 6, 3
    centers, sizes, grad_fns, loss_fn, batch = quadratic_setup(rng, n, dim)
    favg = reference.fedavg_reference(np.zeros(dim), grad_fns, sizes, 4, 12, 0.05)
    hier = reference.hierfavg_reference(np.zeros(dim), grad_fns, sizes, 2, 4, 1, 12, 0.05)
    # with kappa2=1 every edge agg is followed by a cloud agg: same traj
    np.testing.assert_allclose(np.stack(favg), np.stack(hier), atol=1e-12)


def test_kappa_1_1_equals_centralized(rng):
    """Remark 1: kappa1 = kappa2 = 1 is centralized gradient descent."""
    n, dim = 6, 3
    centers, sizes, grad_fns, loss_fn, batch = quadratic_setup(rng, n, dim)
    cent = reference.centralized_gd_reference(np.zeros(dim), grad_fns, sizes, 10, 0.05)
    hier = reference.hierfavg_reference(np.zeros(dim), grad_fns, sizes, 2, 1, 1, 10, 0.05)
    np.testing.assert_allclose(hier[0], cent, atol=1e-12)


def test_hier_round_equals_train_steps(rng):
    """The scanned hier_round driver == kappa1 individual train steps."""
    n, dim, edges = 4, 3, 2
    centers, sizes, grad_fns, loss_fn, batch = quadratic_setup(rng, n, dim, edges)
    topo = FedTopology(num_edges=edges, clients_per_edge=n // edges)
    cfg = HierFAVGConfig(kappa1=3, kappa2=2)
    opt = sgd(0.1)
    w = jnp.asarray(sizes[:n], jnp.float32)
    batch = {"c": jnp.asarray(centers[:n], jnp.float32)}

    s1 = init_state(jax.random.PRNGKey(0), {"w": jnp.zeros(dim)}, opt, topo, cfg)
    s2 = init_state(jax.random.PRNGKey(0), {"w": jnp.zeros(dim)}, opt, topo, cfg)
    step = jax.jit(build_train_step(loss_fn, opt, topo, cfg, w))
    rnd = jax.jit(build_hier_round(loss_fn, opt, topo, cfg, w))

    stacked = jax.tree_util.tree_map(lambda x: jnp.stack([x] * cfg.kappa1), batch)
    for r in range(4):  # spans a cloud boundary (kappa2=2)
        for _ in range(cfg.kappa1):
            s1, _ = step(s1, batch)
        s2, _ = rnd(s2, stacked, jnp.int32(r))
    np.testing.assert_allclose(np.asarray(s1.params["w"]), np.asarray(s2.params["w"]), atol=1e-6)


def test_masked_aggregation_renormalizes(rng):
    """Failure mask: weighted mean over survivors only (paper's weighted
    mean restricted to the participating set)."""
    x = jnp.asarray(rng.normal(size=(4, 5)), jnp.float32)
    w = jnp.asarray([1.0, 2.0, 3.0, 4.0])
    mask = jnp.asarray([1.0, 0.0, 1.0, 1.0])
    got = aggregation.weighted_mean(x, w, mask)
    expect = (1 * x[0] + 3 * x[2] + 4 * x[3]) / 8.0
    np.testing.assert_allclose(np.asarray(got[0]), np.asarray(expect), rtol=1e-6)
    # all-dead group keeps its parameters
    got2 = aggregation.grouped_weighted_mean(x, w, 2, jnp.asarray([0.0, 0.0, 1.0, 1.0]))
    np.testing.assert_allclose(np.asarray(got2[:2]), np.asarray(x[:2]))


def test_delta_cloud_mode_matches_plain(rng):
    """delta_cloud (anchor + mean delta) == plain weighted mean when all
    clients survive."""
    n, dim, edges = 4, 3, 2
    centers, sizes, grad_fns, loss_fn, batch = quadratic_setup(rng, n, dim, edges)
    topo = FedTopology(num_edges=edges, clients_per_edge=2)
    w = jnp.asarray(sizes[:n], jnp.float32)
    batch = {"c": jnp.asarray(centers[:n], jnp.float32)}
    opt = sgd(0.1)
    outs = []
    for delta in (False, True):
        cfg = HierFAVGConfig(kappa1=2, kappa2=2, delta_cloud=delta)
        s = init_state(jax.random.PRNGKey(0), {"w": jnp.zeros(dim)}, opt, topo, cfg)
        step = jax.jit(build_train_step(loss_fn, opt, topo, cfg, w))
        for _ in range(8):
            s, _ = step(s, batch)
        outs.append(np.asarray(s.params["w"]))
    np.testing.assert_allclose(outs[0], outs[1], atol=1e-5)


def test_hierarchical_mean_equals_flat(rng):
    """DESIGN §aggregation: edge-then-cloud composition == flat weighted mean."""
    x = jnp.asarray(rng.normal(size=(6, 7)), jnp.float32)
    w = jnp.asarray(rng.uniform(0.5, 3.0, size=6), jnp.float32)
    flat = aggregation.weighted_mean(x, w)
    hier = aggregation.hierarchical_mean(x, w, 2)
    np.testing.assert_allclose(np.asarray(flat), np.asarray(hier), rtol=1e-5)


def test_async_cloud_field_retired():
    """``async_cloud`` was retired: the semi-synchronous deadline engine
    (``fed.deadline`` + ``build_deadline_super_round``) subsumes the old
    staleness-1 lowering; the spec-level flag maps there with a warning."""
    from repro.core import hierfavg

    with pytest.raises(TypeError):
        HierFAVGConfig(kappa1=2, kappa2=2, async_cloud=True)
    assert not hasattr(hierfavg, "build_hier_round_async")


def test_deadline_super_round_gate_semantics(rng):
    """The gated cloud sync [beyond paper]: a full gate reproduces the
    synchronous superround; a partial gate folds only gated edges into the
    published model while the late edge keeps its own edge-synced params
    (the carry that rides into the next round)."""
    from repro.core.hierfavg import build_deadline_super_round, build_super_round

    n, dim, edges = 4, 3, 2
    centers = rng.normal(size=(edges, dim))
    div_c = np.concatenate([np.tile(centers[0], (2, 1)), np.tile(centers[1], (2, 1))])

    def loss_fn(params, batch, _rng):
        return 0.5 * jnp.sum((params["w"] - batch["c"]) ** 2)

    topo = FedTopology(num_edges=edges, clients_per_edge=2)
    w = jnp.ones((n,), jnp.float32)
    opt = sgd(0.1)
    cfg = HierFAVGConfig(kappa1=2, kappa2=2)
    batch = {"c": jnp.asarray(div_c, jnp.float32)}
    block = jax.tree_util.tree_map(
        lambda x: jnp.stack([jnp.stack([x] * cfg.kappa1)] * cfg.kappa2), batch
    )

    sync_round = jax.jit(build_super_round(loss_fn, opt, topo, cfg, w))
    gated_round = jax.jit(build_deadline_super_round(loss_fn, opt, topo, cfg, w))

    def fresh():
        return init_state(jax.random.PRNGKey(0), {"w": jnp.zeros(dim)}, opt, topo, cfg)

    s_sync, _ = sync_round(fresh(), block, None)
    s_full, _ = gated_round(fresh(), block, jnp.ones((n,), jnp.float32), None)
    np.testing.assert_array_equal(np.asarray(s_sync.params["w"]), np.asarray(s_full.params["w"]))

    # gate out edge 1: clients 0-1 fold and receive the cloud model (built
    # from edge 0 alone); clients 2-3 keep their own edge-synced model
    gate = jnp.asarray([1.0, 1.0, 0.0, 0.0], jnp.float32)
    s_part, _ = gated_round(fresh(), block, gate, None)
    part = np.asarray(s_part.params["w"])
    np.testing.assert_array_equal(part[0], part[1])
    np.testing.assert_array_equal(part[2], part[3])
    assert np.abs(part[0] - part[2]).max() > 1e-6  # late edge NOT broadcast to
    # folded clients' model is edge 0's sync (the only gated contribution),
    # which tracked centers[0] — nearer to it than the late edge's model is
    assert np.linalg.norm(part[0] - centers[0]) < np.linalg.norm(part[2] - centers[0])
