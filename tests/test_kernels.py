"""Per-kernel shape/dtype sweeps: pallas(interpret=True) vs ref.py oracle."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref

ops.set_interpret(True)


@pytest.mark.parametrize("n,groups", [(4, 2), (8, 4), (16, 2), (32, 8)])
@pytest.mark.parametrize("d", [64, 300, 513])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_grouped_mean_sweep(rng, n, groups, d, dtype):
    x = jnp.asarray(rng.normal(size=(n, d)), dtype)
    w = jnp.asarray(rng.uniform(0.5, 4.0, size=n), jnp.float32)
    got = ops.grouped_mean(x, w, groups, block_d=128)
    want = ref.grouped_mean_ref(x, w, groups)
    tol = 1e-5 if dtype == jnp.float32 else 5e-2
    np.testing.assert_allclose(
        np.asarray(got, np.float32), np.asarray(want, np.float32), atol=tol
    )


def test_grouped_mean_masked_and_dead_group(rng):
    x = jnp.asarray(rng.normal(size=(8, 256)), jnp.float32)
    w = jnp.asarray(rng.uniform(1, 2, size=8), jnp.float32).at[:4].set(0.0)
    got = ops.grouped_mean(x, w, 2)
    want = ref.grouped_mean_ref(x, w, 2)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=1e-5)
    np.testing.assert_allclose(np.asarray(got[:4]), np.asarray(x[:4]))  # dead group


@pytest.mark.parametrize("s,window", [(128, 0), (200, 0), (256, 33), (120, 64)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_sweep(rng, s, window, dtype):
    bh, d = 3, 64
    q = jnp.asarray(rng.normal(size=(bh, s, d)), dtype)
    k = jnp.asarray(rng.normal(size=(bh, s, d)), dtype)
    v = jnp.asarray(rng.normal(size=(bh, s, d)), dtype)
    got = ops.flash_attention(q, k, v, causal=True, window=window, block_q=64, block_k=64)
    want = ref.attention_ref(q, k, v, causal=True, window=window)
    tol = 3e-4 if dtype == jnp.float32 else 4e-2
    np.testing.assert_allclose(
        np.asarray(got, np.float32), np.asarray(want, np.float32), atol=tol
    )


@pytest.mark.parametrize("s,d", [(33, 96), (128, 128), (64, 200)])
def test_rglru_scan_sweep(rng, s, d):
    B = 2
    a = jnp.asarray(rng.uniform(0.7, 0.999, size=(B, s, d)), jnp.float32)
    b = jnp.asarray(rng.normal(size=(B, s, d)) * 0.1, jnp.float32)
    h0 = jnp.asarray(rng.normal(size=(B, d)), jnp.float32)
    h, hT = ops.rglru_scan(a, b, h0)
    hr, hTr = ref.rglru_scan_ref(a, b, h0)
    np.testing.assert_allclose(np.asarray(h), np.asarray(hr), atol=1e-5)
    np.testing.assert_allclose(np.asarray(hT), np.asarray(hTr), atol=1e-5)


@pytest.mark.parametrize("shape", [(37, 129), (8, 2048), (1000,), (3, 5, 7)])
@pytest.mark.parametrize("qblock", [128, 256])
def test_quantize_roundtrip_sweep(rng, shape, qblock):
    x = jnp.asarray(rng.normal(size=shape) * 3.0, jnp.float32)
    q, s, shp = ops.quantize_int8(x, qblock=qblock)
    qr, sr, _ = ref.quantize_ref(x, qblock=qblock)
    np.testing.assert_array_equal(np.asarray(q), np.asarray(qr))
    np.testing.assert_allclose(np.asarray(s), np.asarray(sr), rtol=1e-6)
    back = ops.dequantize_int8(q, s, shp)
    # int8 absmax quantization: error bounded by scale/2 per element
    scale_max = float(jnp.max(s))
    assert float(jnp.max(jnp.abs(back - x))) <= scale_max * 0.5 + 1e-6


def test_quantize_zero_block(rng):
    x = jnp.zeros((4, 256), jnp.float32)
    q, s, shp = ops.quantize_int8(x)
    assert float(jnp.max(jnp.abs(ops.dequantize_int8(q, s, shp)))) == 0.0
