"""Per-kernel shape/dtype sweeps: pallas(interpret=True) vs ref.py oracle."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref

ops.set_interpret(True)


@pytest.mark.parametrize("n,groups", [(4, 2), (8, 4), (16, 2), (32, 8)])
@pytest.mark.parametrize("d", [64, 300, 513])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_grouped_mean_sweep(rng, n, groups, d, dtype):
    x = jnp.asarray(rng.normal(size=(n, d)), dtype)
    w = jnp.asarray(rng.uniform(0.5, 4.0, size=n), jnp.float32)
    got = ops.grouped_mean(x, w, groups, block_d=128)
    want = ref.grouped_mean_ref(x, w, groups)
    tol = 1e-5 if dtype == jnp.float32 else 5e-2
    np.testing.assert_allclose(
        np.asarray(got, np.float32), np.asarray(want, np.float32), atol=tol
    )


def test_grouped_mean_masked_and_dead_group(rng):
    x = jnp.asarray(rng.normal(size=(8, 256)), jnp.float32)
    w = jnp.asarray(rng.uniform(1, 2, size=8), jnp.float32).at[:4].set(0.0)
    got = ops.grouped_mean(x, w, 2)
    want = ref.grouped_mean_ref(x, w, 2)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=1e-5)
    np.testing.assert_allclose(np.asarray(got[:4]), np.asarray(x[:4]))  # dead group


@pytest.mark.parametrize("s,window", [(128, 0), (200, 0), (256, 33), (120, 64)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_sweep(rng, s, window, dtype):
    bh, d = 3, 64
    q = jnp.asarray(rng.normal(size=(bh, s, d)), dtype)
    k = jnp.asarray(rng.normal(size=(bh, s, d)), dtype)
    v = jnp.asarray(rng.normal(size=(bh, s, d)), dtype)
    got = ops.flash_attention(q, k, v, causal=True, window=window, block_q=64, block_k=64)
    want = ref.attention_ref(q, k, v, causal=True, window=window)
    tol = 3e-4 if dtype == jnp.float32 else 4e-2
    np.testing.assert_allclose(
        np.asarray(got, np.float32), np.asarray(want, np.float32), atol=tol
    )


@pytest.mark.parametrize("s,d", [(33, 96), (128, 128), (64, 200)])
def test_rglru_scan_sweep(rng, s, d):
    B = 2
    a = jnp.asarray(rng.uniform(0.7, 0.999, size=(B, s, d)), jnp.float32)
    b = jnp.asarray(rng.normal(size=(B, s, d)) * 0.1, jnp.float32)
    h0 = jnp.asarray(rng.normal(size=(B, d)), jnp.float32)
    h, hT = ops.rglru_scan(a, b, h0)
    hr, hTr = ref.rglru_scan_ref(a, b, h0)
    np.testing.assert_allclose(np.asarray(h), np.asarray(hr), atol=1e-5)
    np.testing.assert_allclose(np.asarray(hT), np.asarray(hTr), atol=1e-5)


@pytest.mark.parametrize("shape", [(37, 129), (8, 2048), (1000,), (3, 5, 7)])
@pytest.mark.parametrize("qblock", [128, 256])
def test_quantize_roundtrip_sweep(rng, shape, qblock):
    x = jnp.asarray(rng.normal(size=shape) * 3.0, jnp.float32)
    q, s, shp = ops.quantize_int8(x, qblock=qblock)
    qr, sr, _ = ref.quantize_ref(x, qblock=qblock)
    np.testing.assert_array_equal(np.asarray(q), np.asarray(qr))
    np.testing.assert_allclose(np.asarray(s), np.asarray(sr), rtol=1e-6)
    back = ops.dequantize_int8(q, s, shp)
    # int8 absmax quantization: error bounded by scale/2 per element
    scale_max = float(jnp.max(s))
    assert float(jnp.max(jnp.abs(back - x))) <= scale_max * 0.5 + 1e-6


def test_quantize_zero_block(rng):
    x = jnp.zeros((4, 256), jnp.float32)
    q, s, shp = ops.quantize_int8(x)
    assert float(jnp.max(jnp.abs(ops.dequantize_int8(q, s, shp)))) == 0.0


SEGMENTS = {
    8: [0, 0, 0, 1, 1, 2, 2, 3],
    12: [0, 0, 0, 0, 1, 1, 2, 2, 2, 2, 3, 3],
}


@pytest.mark.parametrize("n", [8, 12])
@pytest.mark.parametrize("d,qblock,block_d", [(1024, 256, 512), (768, 128, 256), (512, 128, 512)])
def test_segment_dequant_mean_bitexact_vs_ref(rng, n, d, qblock, block_d):
    """The fused dequantize-aggregate kernel is BIT-exact against the jnp
    oracle (both jitted; the oracle mirrors the kernel's tiling)."""
    import functools

    x = jnp.asarray(rng.normal(size=(n, d)) * 0.1, jnp.float32)
    w = jnp.asarray(rng.uniform(0.5, 3.0, size=n), jnp.float32)
    seg = jnp.asarray(SEGMENTS[n], jnp.int32)
    q, s = ops.quantize_stacked(x, qblock=qblock)
    got = ops.segment_dequant_mean(q, s, w, seg, 4, block_d=block_d)
    want = jax.jit(
        functools.partial(ref.segment_dequant_mean_ref, num_segments=4, block_d=block_d)
    )(q, s, w, seg)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_segment_dequant_mean_equals_decode_then_aggregate(rng):
    """Fusion changes bytes moved, not math: fused == dequantize (kernel)
    then segment_mean (kernel) on the f32 intermediate."""
    n, d, qblock = 8, 1024, 256
    x = jnp.asarray(rng.normal(size=(n, d)) * 0.2, jnp.float32)
    w = jnp.asarray(rng.uniform(0.5, 2.0, size=n), jnp.float32)
    seg = jnp.asarray(SEGMENTS[n], jnp.int32)
    q, s = ops.quantize_stacked(x, qblock=qblock)
    fused = ops.segment_dequant_mean(q, s, w, seg, 4, block_d=512)
    rows = q.shape[1] // qblock * n
    decoded = ops.dequantize_int8(
        q.reshape(rows, qblock), s.reshape(rows, 1), (n, d)
    )
    staged = ops.segment_mean(decoded, w, seg, 4, block_d=512)
    np.testing.assert_allclose(np.asarray(fused), np.asarray(staged), atol=1e-6)


def test_segment_dequant_mean_dead_segment_keeps_rows(rng):
    n, d = 8, 512
    x = jnp.asarray(rng.normal(size=(n, d)), jnp.float32)
    w = jnp.asarray(rng.uniform(1.0, 2.0, size=n), jnp.float32).at[3:5].set(0.0)
    seg = jnp.asarray(SEGMENTS[n], jnp.int32)  # segment 1 = rows 3..4, now dead
    q, s = ops.quantize_stacked(x, qblock=128)
    got = ops.segment_dequant_mean(q, s, w, seg, 4, block_d=512)
    decoded = np.asarray(
        q.astype(jnp.float32).reshape(n, d // 128, 128) * s.reshape(n, d // 128)[..., None]
    ).reshape(n, d)
    np.testing.assert_allclose(np.asarray(got)[3:5], decoded[3:5], atol=1e-7)


def test_segment_dequant_mean_validates_shapes(rng):
    q = jnp.zeros((4, 512), jnp.int8)
    s = jnp.zeros((4, 2), jnp.float32)
    w = jnp.ones((4,), jnp.float32)
    with pytest.raises(ValueError):  # block_d not a multiple of qblock
        ops.segment_dequant_mean(q, s, w, [0, 0, 1, 1], 2, block_d=384)
    with pytest.raises(ValueError):  # bad segment vector
        ops.segment_dequant_mean(q, s, w, [0, 0, 1], 2)
