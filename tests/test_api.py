"""Declarative ExperimentSpec API: serialization round-trips, dotted-path
overrides with actionable errors, scenario-registry builds, and equivalence
of spec-built runners with the explicit FederatedRunner assembly."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import FedTopology, HierFAVGConfig, cost_model as cm
from repro.data import FederatedBatcher, clustered_gaussians, make_partition
from repro.fed import FederatedRunner, RunnerConfig, scenarios
from repro.fed.api import (
    DataSpec,
    ExperimentSpec,
    ModelSpec,
    RunSpec,
    ScheduleSpec,
    TopologySpec,
    TransportSpec,
)
from repro.fed.runner import RoundRecord
from repro.models import cnn
from repro.optim import sgd


# ---------------------------------------------------------------------------
# Serialization round-trips
# ---------------------------------------------------------------------------

def test_default_spec_roundtrip():
    spec = ExperimentSpec()
    assert ExperimentSpec.from_dict(spec.to_dict()) == spec
    assert ExperimentSpec.from_json(spec.to_json()) == spec


def test_nondefault_spec_roundtrip():
    spec = ExperimentSpec(
        name="x",
        topology=TopologySpec(fanouts="3,5,2/3"),
        schedule=ScheduleSpec(kappas=(2, 3), sync_opt_state=True),
        data=DataSpec(partition="edge_niid", classes_per_edge=3, seed=7),
        model=ModelSpec(lr=0.01, lr_schedule="exponential"),
        transport=TransportSpec(levels="identity/int8_ef:128"),
        run=RunSpec(num_rounds=6, engine="per_round"),
    )
    rt = ExperimentSpec.from_dict(spec.to_dict())
    assert rt == spec
    assert rt.schedule.kappas == (2, 3)  # list -> tuple restored


def test_every_scenario_roundtrips_and_builds():
    for name in scenarios.names():
        spec = scenarios.get(name)
        assert ExperimentSpec.from_dict(spec.to_dict()) == spec, name
        runner = spec.build()
        assert isinstance(runner, FederatedRunner), name
        assert runner.spec == spec, name


def test_from_dict_unknown_key_names_dotted_path():
    d = ExperimentSpec().to_dict()
    d["schedule"]["kapas"] = [4, 2]
    with pytest.raises(ValueError, match=r"schedule\.kapas"):
        ExperimentSpec.from_dict(d)
    with pytest.raises(ValueError, match="bogus"):
        ExperimentSpec.from_dict({"bogus": {}})


# ---------------------------------------------------------------------------
# Dotted-path overrides
# ---------------------------------------------------------------------------

def test_override_grammar():
    spec = ExperimentSpec.parse([
        "schedule.kappas=4,2",
        "transport.levels=identity/int8_ef:128",
        "run.num_rounds=12",
        "schedule.sync_opt_state=true",
        "data.class_sep=2.5",
        "name=custom",
    ])
    assert spec.schedule.kappas == (4, 2)
    assert spec.transport.levels == "identity/int8_ef:128"
    assert spec.run.num_rounds == 12
    assert spec.schedule.sync_opt_state is True
    assert spec.data.class_sep == 2.5
    assert spec.name == "custom"


@pytest.mark.parametrize("bad,fragment", [
    ("schedule.kapas=4", "kapas"),  # unknown leaf names the path
    ("bogus.x=1", "bogus"),  # unknown section
    ("schedule.kappas=abc", "comma-separated"),  # bad tuple value
    ("run.num_rounds=ten", "integer"),  # bad int
    ("schedule.sync_opt_state=maybe", "boolean"),  # bad bool
    ("run=3", "section"),  # assigning to a section
    ("norounds", "dotted.path=value"),  # missing '='
])
def test_override_errors_are_actionable(bad, fragment):
    with pytest.raises(ValueError, match=fragment):
        ExperimentSpec.parse([bad])


def test_override_leaves_base_untouched():
    base = scenarios.get("quickstart")
    tweaked = base.with_overrides(["run.num_rounds=2"])
    assert base.run.num_rounds == 24 and tweaked.run.num_rounds == 2


# ---------------------------------------------------------------------------
# Build-time validation
# ---------------------------------------------------------------------------

def test_kappas_depth_mismatch_is_actionable():
    spec = ExperimentSpec.parse(["schedule.kappas=4,2,2"])  # 2-level topo
    with pytest.raises(ValueError, match="depth"):
        spec.build()


def test_transport_depth_mismatch_is_actionable():
    spec = ExperimentSpec.parse(["transport.levels=identity/int8/int8"])
    with pytest.raises(ValueError, match=r"transport\.levels"):
        spec.build()


def test_unknown_codec_and_aggregator_name_the_field():
    with pytest.raises(ValueError, match=r"transport\.levels"):
        ExperimentSpec.parse(["transport.levels=int7"]).build()
    with pytest.raises(ValueError, match=r"aggregators\.levels"):
        ExperimentSpec.parse(["aggregators.levels=krum"]).build()


def test_spec_rejects_built_forms_in_sections():
    """The spec tree holds the serializable fed.api wrappers; passing the
    same-named built forms (fed.transport.TransportSpec /
    core.aggregation.AggregatorSpec) fails fast with a pointed message."""
    from repro.core.aggregation import AggregatorSpec as BuiltAggregatorSpec
    from repro.fed.transport import TransportSpec as BuiltTransportSpec

    with pytest.raises(TypeError, match="serializable spec form"):
        ExperimentSpec(transport=BuiltTransportSpec.identity(2))
    with pytest.raises(TypeError, match="serializable spec form"):
        ExperimentSpec(aggregators=BuiltAggregatorSpec.default(2))


def test_runner_config_engine_validated_at_construction():
    with pytest.raises(ValueError, match="engine"):
        RunnerConfig(num_rounds=3, engine="warp")
    with pytest.raises(ValueError, match="engine"):
        ExperimentSpec.parse(["run.engine=warp"]).build()


# ---------------------------------------------------------------------------
# Spec-built runner == explicit constructor (the quickstart equivalence)
# ---------------------------------------------------------------------------

def _legacy_quickstart_runner(num_rounds):
    rng = np.random.default_rng(0)
    data = clustered_gaussians(rng, num_samples=2000, num_classes=10, dim=(16,), class_sep=3.5)
    parts = make_partition("edge_niid", data.y, num_edges=4, clients_per_edge=5, rng=rng)
    batcher = FederatedBatcher({"inputs": data.x, "targets": data.y}, parts, batch_size=8)

    def init(key):
        k1, k2 = jax.random.split(key)
        return {"w1": jax.random.normal(k1, (16, 48)) * 0.25, "b1": jnp.zeros(48),
                "w2": jax.random.normal(k2, (48, 10)) * 0.25, "b2": jnp.zeros(10)}

    def apply_fn(p, x):
        return jax.nn.relu(x @ p["w1"] + p["b1"]) @ p["w2"] + p["b2"]

    runner = FederatedRunner(
        loss_fn=cnn.make_cnn_loss_fn(apply_fn),
        optimizer=sgd(0.15),
        topology=FedTopology(num_edges=4, clients_per_edge=5),
        hier_config=HierFAVGConfig(kappa1=4, kappa2=2),
        data_sizes=batcher.data_sizes,
        batcher=batcher,
        runner_config=RunnerConfig(num_rounds=num_rounds, eval_every=4),
        eval_fn=lambda p: float(cnn.accuracy(apply_fn(p, jnp.asarray(data.x)), jnp.asarray(data.y))),
        costs=cm.paper_workload("mnist"),
    )
    state = runner.init(jax.random.PRNGKey(0), init(jax.random.PRNGKey(1)))
    runner.run(state)
    return runner


def test_quickstart_spec_matches_explicit_assembly():
    """The rebuilt examples/quickstart.py (registry 'quickstart') must
    reproduce the pre-redesign hand-assembled runner's history exactly."""
    rounds = 8
    legacy = _legacy_quickstart_runner(rounds)
    runner, _ = scenarios.get(
        "quickstart", overrides=[f"run.num_rounds={rounds}"]
    ).run_experiment()
    a = [dataclasses.astuple(h) for h in legacy.history]
    b = [dataclasses.astuple(h) for h in runner.history]
    assert a == b


def test_from_dict_rejects_string_for_tuple_field():
    d = ExperimentSpec().to_dict()
    d["schedule"]["kappas"] = "42"  # would digit-split to (4, 2)
    with pytest.raises(ValueError, match=r"schedule\.kappas"):
        ExperimentSpec.from_dict(d)


def test_arch_dataset_mismatch_is_actionable():
    with pytest.raises(ValueError, match="dataset=tokens"):
        ExperimentSpec.parse(["model.arch=lm-10m"]).build()
    with pytest.raises(ValueError, match="language model"):
        ExperimentSpec.parse(["data.dataset=tokens"]).build()


def test_resume_without_checkpoint_dir_raises():
    with pytest.raises(ValueError, match="checkpoint_dir"):
        scenarios.get("quickstart", overrides=["run.num_rounds=2"]).run_experiment(resume=True)


def test_run_experiment_resume_roundtrip(tmp_path):
    over = [
        f"run.checkpoint_dir={tmp_path}", "run.checkpoint_every=4",
        "run.num_rounds=4", "run.eval_every=4",
    ]
    spec = scenarios.get("quickstart", overrides=over)
    spec.run_experiment()
    # straight-through 8 rounds vs 4 + resume 4: identical final state
    spec8 = spec.with_overrides(["run.num_rounds=8"])
    _, s_direct = scenarios.get(
        "quickstart", overrides=["run.num_rounds=8", "run.eval_every=4"]
    ).run_experiment()
    _, s2 = spec8.run_experiment(resume=True)
    np.testing.assert_array_equal(np.asarray(s2.params["w1"]), np.asarray(s_direct.params["w1"]))
    assert int(s2.step) == int(s_direct.step)


# ---------------------------------------------------------------------------
# records_to_dict derivation (satellite)
# ---------------------------------------------------------------------------

def test_records_to_dict_tracks_roundrecord_fields():
    runner, _ = scenarios.get(
        "quickstart", overrides=["run.num_rounds=2", "run.eval_every=2"]
    ).run_experiment()
    rec = runner.records_to_dict()
    assert set(rec) == {f.name for f in dataclasses.fields(RoundRecord)}
    assert rec["round"] == [0, 1]
    assert rec["loss"] == [h.loss for h in runner.history]
    assert rec["accuracy"][-1] is not None
