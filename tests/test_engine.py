"""Superround engine: bit-exactness vs the per-round driver, buffer
donation, device-side prefetch, and runner integration."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (
    FedTopology,
    HierFAVGConfig,
    aggregation,
    build_cohort_super_round,
    build_hier_round,
    build_super_round,
    init_cohort_state,
    init_state,
    super_round_schedule,
)
from repro.core.hierarchy import as_hierarchy, parse_fanouts
from repro.data import FederatedBatcher, SuperBatchPrefetcher, clustered_gaussians, make_partition
from repro.fed import (
    FailureSimulator,
    FederatedRunner,
    ParticipationSpec,
    RunnerConfig,
    TransportSpec,
)
from repro.models import cnn
from repro.optim import momentum, sgd

DIM = 3


def _quad(rng, n):
    centers = rng.normal(size=(n, DIM))
    sizes = rng.integers(1, 4, size=n).astype(np.float64)

    def loss_fn(params, batch, _rng):
        return 0.5 * jnp.sum((params["w"] - batch["c"]) ** 2)

    batch = {"c": jnp.asarray(centers, jnp.float32)}
    return sizes, loss_fn, batch


def _assert_trees_equal(t1, t2, what, ulp_tol=False):
    leaves1 = jax.tree_util.tree_leaves(t1)
    leaves2 = jax.tree_util.tree_leaves(t2)
    assert len(leaves1) == len(leaves2), what
    for a, b in zip(leaves1, leaves2):
        if ulp_tol:
            np.testing.assert_allclose(
                np.asarray(a), np.asarray(b), rtol=3e-6, atol=2e-7, err_msg=what
            )
        else:
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b), err_msg=what)


def _drive_both(topo, cfg, sizes, loss_fn, batch, opt, *, intervals=2, masks=None):
    """Run `intervals` cloud intervals through (a) the per-round hier_round
    loop and (b) the fused superround, from identical initial state; return
    both final states plus both metric streams."""
    k1, k2 = cfg.kappa1, cfg.kappa2_effective
    w = jnp.asarray(sizes, jnp.float32)
    s1 = init_state(jax.random.PRNGKey(0), {"w": jnp.zeros(DIM)}, opt, topo, cfg)
    s2 = init_state(jax.random.PRNGKey(0), {"w": jnp.zeros(DIM)}, opt, topo, cfg)
    rnd = jax.jit(build_hier_round(loss_fn, opt, topo, cfg, w))
    sup = jax.jit(build_super_round(loss_fn, opt, topo, cfg, w), donate_argnums=(0,))
    per = jax.tree_util.tree_map(lambda x: jnp.stack([x] * k1), batch)
    block = jax.tree_util.tree_map(
        lambda x: jnp.stack([x] * (k2 * k1)).reshape((k2, k1) + x.shape), batch
    )
    metrics1 = {"loss": [], "grad_norm": []}
    for q in range(intervals):
        for j in range(k2):
            m = None if masks is None else jnp.asarray(masks[q * k2 + j])
            s1, mt = rnd(s1, per, jnp.int32(q * k2 + j), m)
            metrics1["loss"].append(float(mt["loss"]))
            metrics1["grad_norm"].append(float(mt["grad_norm"]))
    metrics2 = {"loss": [], "grad_norm": []}
    for q in range(intervals):
        mstack = (
            None
            if masks is None
            else jnp.asarray(np.stack(masks[q * k2 : (q + 1) * k2]))
        )
        s2, mt = sup(s2, block, mstack)
        metrics2["loss"].extend(np.asarray(mt["loss"]).tolist())
        metrics2["grad_norm"].extend(np.asarray(mt["grad_norm"]).tolist())
    return s1, s2, metrics1, metrics2


def _assert_states_equal(s1, s2, ulp_tol=False):
    """ulp_tol=False is the bit-exact claim. Configs whose aggregation graph
    XLA:CPU compiles with different FMA/reassociation choices inside the
    fused scan than in the standalone per-round executable (momentum
    sync_opt_state, depth-3 ragged) are compared at a ~1-ULP tolerance
    instead — the graphs are op-for-op identical; only codegen contraction
    differs between the two executables."""
    _assert_trees_equal(s1.params, s2.params, "params", ulp_tol)
    _assert_trees_equal(s1.opt_state, s2.opt_state, "opt_state", ulp_tol)
    assert int(s1.step) == int(s2.step)
    if s1.anchor is not None or s2.anchor is not None:
        _assert_trees_equal(s1.anchor, s2.anchor, "anchor", ulp_tol)
    if s1.residual is not None or s2.residual is not None:
        _assert_trees_equal(s1.residual, s2.residual, "residual", ulp_tol)


# ---------------------------------------------------------------------------
# bit-exactness vs the per-round loop
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("kappa1,kappa2", [(2, 3), (1, 1), (3, 1), (1, 4)])
def test_superround_bitexact_two_level(rng, kappa1, kappa2):
    sizes, loss_fn, batch = _quad(rng, 6)
    topo = FedTopology(num_edges=2, clients_per_edge=3)
    cfg = HierFAVGConfig(kappa1=kappa1, kappa2=kappa2)
    s1, s2, m1, m2 = _drive_both(topo, cfg, sizes, loss_fn, batch, sgd(0.1))
    _assert_states_equal(s1, s2)
    np.testing.assert_array_equal(m1["loss"], m2["loss"])
    # grad_norm is a diagnostic side-output: XLA may reassociate its
    # sum-of-squares reduction differently inside the fused scan (state and
    # loss stay bit-exact), so allow ULP-level drift here only
    np.testing.assert_allclose(m1["grad_norm"], m2["grad_norm"], rtol=1e-6)


def test_superround_bitexact_masks(rng):
    """Per-round survival masks == the (κ₂, N) stacked mask scan, including
    a round where a whole edge dies."""
    sizes, loss_fn, batch = _quad(rng, 6)
    topo = FedTopology(num_edges=2, clients_per_edge=3)
    cfg = HierFAVGConfig(kappa1=2, kappa2=3)
    masks = [np.ones(6, np.float32) for _ in range(6)]
    masks[1][4] = 0.0
    masks[2][:3] = 0.0  # edge 0 entirely dead at a boundary
    masks[5][0] = 0.0  # masked client at the cloud boundary
    s1, s2, m1, m2 = _drive_both(
        topo, cfg, sizes, loss_fn, batch, sgd(0.1), masks=masks
    )
    _assert_states_equal(s1, s2)
    np.testing.assert_array_equal(m1["loss"], m2["loss"])


def test_superround_bitexact_sync_opt_state(rng):
    """Momentum state averaged at boundaries (sync_opt_state) survives the
    fusion bit-exactly."""
    sizes, loss_fn, batch = _quad(rng, 6)
    topo = FedTopology(num_edges=2, clients_per_edge=3)
    cfg = HierFAVGConfig(kappa1=2, kappa2=2, sync_opt_state=True)
    s1, s2, _, _ = _drive_both(topo, cfg, sizes, loss_fn, batch, momentum(0.1, 0.9))
    _assert_states_equal(s1, s2, ulp_tol=True)


def test_superround_bitexact_ragged_multilevel(rng):
    """Depth-3 ragged tree with κ=(2,2,2): the folded level switch must
    reproduce the deepest-wins schedule across both mid and top boundaries."""
    spec = parse_fanouts("3,2,3/2,1/2")
    sizes, loss_fn, batch = _quad(rng, spec.num_clients)
    cfg = HierFAVGConfig.multi_level([2, 2, 2])
    s1, s2, m1, m2 = _drive_both(spec, cfg, sizes, loss_fn, batch, sgd(0.1))
    _assert_states_equal(s1, s2, ulp_tol=True)
    np.testing.assert_allclose(m1["loss"], m2["loss"], rtol=1e-6)
    np.testing.assert_allclose(m1["grad_norm"], m2["grad_norm"], rtol=1e-6)


@pytest.mark.parametrize("transport", ["identity/int8:64", "int8_ef:64/int8_ef:64"])
def test_superround_bitexact_transport(rng, transport):
    """Compressed uplinks (anchor re-sync, EF residual carry) are identical
    under the fused scan — including with a survival mask."""
    sizes, loss_fn, batch = _quad(rng, 6)
    topo = FedTopology(num_edges=2, clients_per_edge=3)
    cfg = HierFAVGConfig(kappa1=2, kappa2=2, transport=TransportSpec.parse(transport))
    masks = [np.ones(6, np.float32) for _ in range(4)]
    masks[1][2] = 0.0
    s1, s2, _, _ = _drive_both(
        topo, cfg, sizes, loss_fn, batch, sgd(0.1), masks=masks
    )
    _assert_states_equal(s1, s2)


def test_super_round_schedule():
    assert super_round_schedule(HierFAVGConfig(kappa1=4, kappa2=4)) == (1, 1, 1, 2)
    assert super_round_schedule(HierFAVGConfig(kappa1=2, kappa2=1)) == (2,)
    assert super_round_schedule(HierFAVGConfig.multi_level([2, 2, 2])) == (1, 2, 1, 3)


def test_superround_donation(rng):
    """donate_argnums must actually release the input FedState's buffers
    (the zero-copy claim): donated leaves are deleted after dispatch."""
    sizes, loss_fn, batch = _quad(rng, 6)
    topo = FedTopology(num_edges=2, clients_per_edge=3)
    cfg = HierFAVGConfig(kappa1=2, kappa2=2)
    opt = sgd(0.1)
    w = jnp.asarray(sizes, jnp.float32)
    sup = jax.jit(build_super_round(loss_fn, opt, topo, cfg, w), donate_argnums=(0,))
    state = init_state(jax.random.PRNGKey(0), {"w": jnp.zeros(DIM)}, opt, topo, cfg)
    donated_leaf = state.params["w"]
    block = jax.tree_util.tree_map(
        lambda x: jnp.stack([x] * 4).reshape((2, 2) + x.shape), batch
    )
    out, _ = sup(state, block, None)
    jax.block_until_ready(out.params)
    if jax.default_backend() in ("cpu", "gpu", "tpu"):
        assert donated_leaf.is_deleted(), "donated input buffer was not released"
    assert not jax.tree_util.tree_leaves(out.params)[0].is_deleted()


# ---------------------------------------------------------------------------
# prefetcher
# ---------------------------------------------------------------------------

def _make_batcher(seed=0, n=6, batch=4):
    rng = np.random.default_rng(seed)
    data = clustered_gaussians(rng, num_samples=240, num_classes=10, dim=(5,), class_sep=2.0)
    parts = make_partition("edge_iid", data.y, 2, n // 2, rng)
    return FederatedBatcher(
        {"inputs": data.x, "targets": data.y}, parts, batch_size=batch, seed=seed
    )


@pytest.mark.parametrize("use_thread", [True, False])
def test_prefetcher_matches_batcher(use_thread):
    """Prefetched blocks reproduce the exact batch sequence (reshaped to a
    (rounds, steps) leading pair) and the snapshots are restart-exact."""
    ref = _make_batcher()
    expect = [ref.next_batches(6) for _ in range(3)]

    pf = SuperBatchPrefetcher(
        _make_batcher(), rounds_per_block=2, steps_per_round=3,
        num_blocks=3, use_thread=use_thread,
    )
    snapshots = []
    with pf:
        for q in range(3):
            block, snap = pf.get()
            snapshots.append(snap)
            for key in ("inputs", "targets"):
                got = np.asarray(block[key]).reshape((-1,) + block[key].shape[2:])
                np.testing.assert_array_equal(got, expect[q][key])
        with pytest.raises(RuntimeError):
            pf.get()  # num_blocks exhausted

    # snapshot q restores a batcher positioned after block q
    resumed = _make_batcher()
    resumed.load_state_dict(snapshots[0])
    np.testing.assert_array_equal(
        resumed.next_batches(6)["inputs"], expect[1]["inputs"]
    )


def test_prefetcher_stop_is_idempotent():
    pf = SuperBatchPrefetcher(
        _make_batcher(), rounds_per_block=2, steps_per_round=2, num_blocks=8
    )
    pf.get()
    pf.stop()
    pf.stop()


# ---------------------------------------------------------------------------
# runner integration
# ---------------------------------------------------------------------------

def _mlp_runner(engine, *, num_rounds, eval_every=0, seed=0, failures=None):
    rng = np.random.default_rng(seed)
    data = clustered_gaussians(rng, num_samples=360, num_classes=10, dim=(8,), class_sep=3.0)
    parts = make_partition("edge_iid", data.y, 2, 3, rng)
    batcher = FederatedBatcher(
        {"inputs": data.x, "targets": data.y}, parts, batch_size=4, seed=seed
    )

    def apply_fn(p, x):
        return jax.nn.relu(x @ p["w1"]) @ p["w2"]

    def eval_fn(p):
        return float(cnn.accuracy(apply_fn(p, jnp.asarray(data.x)), jnp.asarray(data.y)))

    runner = FederatedRunner(
        loss_fn=cnn.make_cnn_loss_fn(apply_fn),
        optimizer=sgd(0.1),
        topology=FedTopology(num_edges=2, clients_per_edge=3),
        hier_config=HierFAVGConfig(kappa1=2, kappa2=3),
        data_sizes=batcher.data_sizes,
        batcher=batcher,
        runner_config=RunnerConfig(num_rounds=num_rounds, eval_every=eval_every, engine=engine),
        eval_fn=eval_fn if eval_every else None,
        failures=failures,
    )
    k1, k2 = jax.random.split(jax.random.PRNGKey(seed))
    params = {
        "w1": jax.random.normal(k1, (8, 16)) * 0.3,
        "w2": jax.random.normal(k2, (16, 10)) * 0.3,
    }
    state = runner.init(jax.random.PRNGKey(seed), params)
    return runner, state


def test_runner_engine_parity(rng):
    """engine='auto' (2 superround intervals + 1 per-round leftover) must
    reproduce the full per-round history: loss, grad_norm, steps, masks,
    eval accuracy, wire bytes."""
    out = {}
    for mode in ("auto", "per_round"):
        runner, state = _mlp_runner(
            mode, num_rounds=7, eval_every=3,
            failures=FailureSimulator(6, p_fail=0.2, p_recover=0.5, seed=3),
        )
        state = runner.run(state)
        out[mode] = (runner.records_to_dict(), np.asarray(state.params["w1"]))
    rec_a, p_a = out["auto"]
    rec_p, p_p = out["per_round"]
    np.testing.assert_array_equal(p_a, p_p)
    gn_a = rec_a.pop("grad_norm")
    gn_p = rec_p.pop("grad_norm")
    np.testing.assert_allclose(gn_a, gn_p, rtol=1e-6)  # diagnostic: ULP drift ok
    assert rec_a == rec_p
    assert rec_a["round"] == list(range(7))  # engine intervals + fallback round


def test_engine_hoists_masks_without_failure_model(monkeypatch):
    """No failure/straggler model -> the all-alive mask triple is built
    once, not by κ₂ detector polls per cloud interval. (Patched on the
    class: the stock implementation is what gets hoisted.)"""
    calls = {"n": 0}

    def counting_mask(self):
        calls["n"] += 1
        return None

    monkeypatch.setattr(FederatedRunner, "_mask_for_round", counting_mask)
    runner, state = _mlp_runner("superround", num_rounds=6)
    runner.run(state)
    assert calls["n"] == 0
    assert [r.round for r in runner.history] == list(range(6))


def test_engine_honors_overridden_mask_seam():
    """An instance-level _mask_for_round override (no failure model set)
    must still be polled per round — the hoist only covers the stock
    implementation, keeping engine/per-round parity for injected masks."""
    calls = {"n": 0}

    def injecting_mask():
        calls["n"] += 1
        m = np.ones(6, np.float32)
        m[5] = 0.0
        return m

    runner, state = _mlp_runner("superround", num_rounds=6)
    runner._mask_for_round = injecting_mask
    runner.run(state)
    assert calls["n"] == 6  # κ₂ polls per interval, 2 intervals
    assert all(r.mask_alive == 5 for r in runner.history)


def test_runner_forced_superround_requires_cloud_granularity():
    runner, state = _mlp_runner("superround", num_rounds=6, eval_every=1)
    with pytest.raises(ValueError, match="superround"):
        runner.run(state)


def test_runner_rejects_unknown_engine():
    # validated at construction (RunnerConfig.__post_init__), not first run()
    with pytest.raises(ValueError, match="engine"):
        RunnerConfig(num_rounds=3, engine="warp")


# ---------------------------------------------------------------------------
# satellites: eval reduction + wire accounting
# ---------------------------------------------------------------------------

def test_cloud_model_matches_weighted_mean(rng):
    x = {"w": jnp.asarray(rng.normal(size=(5, 4, 3)), jnp.float32)}
    w = jnp.asarray([1.0, 2.0, 0.5, 3.0, 1.5])
    mask = jnp.asarray([1.0, 0.0, 1.0, 1.0, 0.0])
    full = aggregation.weighted_mean(x, w, mask)
    single = aggregation.cloud_model(x, w, mask)
    assert single["w"].shape == (4, 3)  # no (N, ...) broadcast
    np.testing.assert_array_equal(np.asarray(full["w"][0]), np.asarray(single["w"]))
    # zero survivors: keeps client 0's params, like weighted_mean[0]
    dead = jnp.zeros(5)
    np.testing.assert_array_equal(
        np.asarray(aggregation.weighted_mean(x, w, dead)["w"][0]),
        np.asarray(aggregation.cloud_model(x, w, dead)["w"]),
    )


# ---------------------------------------------------------------------------
# sampled participation: identity-cohort parity (C == N)
# ---------------------------------------------------------------------------
# The cohort lowering must be the *same algorithm* as the fused superround
# when every client participates. Ragged trees exercise the segment-sum
# aggregation path in both builders, so the comparison there is bit-exact;
# uniform trees are the one place the graphs legitimately differ (the static
# builder takes the contiguous-reshape shortcut, traced cohort ids cannot),
# leaving ~1-ULP contraction differences — same situation as
# `_assert_states_equal`'s documented ulp_tol cases.

def _identity_cohort(spec, sizes):
    """The cohort dict for 'everyone participates': per-level segment ids
    columned from the full tree, weights in original client order."""
    if spec.depth > 1:
        table = np.stack(
            [np.asarray(spec.segments(l), np.int32) for l in range(1, spec.depth)]
        )
    else:
        table = np.zeros((0, spec.num_clients), np.int32)
    return {
        "segments": jnp.asarray(table),
        "weights": jnp.asarray(sizes, jnp.float32),
    }


def _drive_cohort_vs_super(topo, cfg, sizes, loss_fn, batch, opt, *, intervals=2):
    spec = as_hierarchy(topo)
    n = spec.num_clients
    k1, k2 = cfg.kappa1, cfg.kappa2_effective
    w = jnp.asarray(sizes, jnp.float32)
    s1 = init_state(jax.random.PRNGKey(0), {"w": jnp.zeros(DIM)}, opt, topo, cfg)
    s2 = init_cohort_state(jax.random.PRNGKey(0), {"w": jnp.zeros(DIM)}, opt, cfg, n)
    sup = jax.jit(build_super_round(loss_fn, opt, topo, cfg, w), donate_argnums=(0,))
    coh = jax.jit(
        build_cohort_super_round(loss_fn, opt, topo, cfg, cohort_size=n),
        donate_argnums=(0,),
    )
    cohort = _identity_cohort(spec, sizes)
    block = jax.tree_util.tree_map(
        lambda x: jnp.stack([x] * (k2 * k1)).reshape((k2, k1) + x.shape), batch
    )
    m1, m2 = [], []
    for _ in range(intervals):
        s1, mt1 = sup(s1, block, None)
        s2, mt2 = coh(s2, block, cohort)
        m1.append(jax.device_get(mt1))
        m2.append(jax.device_get(mt2))
    return s1, s2, m1, m2


@pytest.mark.parametrize(
    "opt_name,cfg_kw",
    [
        ("sgd", {}),
        ("momentum", {"sync_opt_state": True}),
        ("sgd", {"transport": TransportSpec.parse("int8_ef:64/int8_ef:64")}),
    ],
    ids=["sgd", "momentum_sync_opt", "int8_ef_both"],
)
def test_cohort_identity_bitexact_ragged(rng, opt_name, cfg_kw):
    """Identity cohort == fused superround, bit for bit, on a ragged tree —
    including synced momentum traces and EF residual/anchor carry."""
    spec = parse_fanouts("1,2,3/3")
    sizes, loss_fn, batch = _quad(rng, spec.num_clients)
    cfg = HierFAVGConfig(kappa1=2, kappa2=3, **cfg_kw)
    opt = momentum(0.1, 0.9) if opt_name == "momentum" else sgd(0.1)
    s1, s2, m1, m2 = _drive_cohort_vs_super(spec, cfg, sizes, loss_fn, batch, opt)
    _assert_states_equal(s1, s2)
    _assert_trees_equal(s1.rng, s2.rng, "rng")
    for a, b in zip(m1, m2):
        np.testing.assert_array_equal(a["loss"], b["loss"])
        np.testing.assert_array_equal(a["grad_norm"], b["grad_norm"])
        np.testing.assert_array_equal(a["step"], b["step"])


def test_cohort_identity_uniform_ulp(rng):
    """Uniform trees: the static builder's contiguous-reshape mean vs the
    cohort's segment-sum over traced ids — op-for-op the same reduction, so
    agreement is at the documented ~1-ULP codegen tolerance."""
    sizes, loss_fn, batch = _quad(rng, 6)
    topo = FedTopology(num_edges=2, clients_per_edge=3)
    cfg = HierFAVGConfig(kappa1=2, kappa2=2)
    s1, s2, m1, m2 = _drive_cohort_vs_super(topo, cfg, sizes, loss_fn, batch, sgd(0.1))
    _assert_states_equal(s1, s2, ulp_tol=True)
    for a, b in zip(m1, m2):
        np.testing.assert_allclose(a["loss"], b["loss"], rtol=1e-6)


# ---------------------------------------------------------------------------
# sampled participation: runner-level parity and the cohort engine
# ---------------------------------------------------------------------------

def _ragged_batcher(n, seed=0, batch=4):
    rng = np.random.default_rng(seed)
    data = clustered_gaussians(
        rng, num_samples=40 * n, num_classes=10, dim=(8,), class_sep=3.0
    )
    parts = [np.arange(i, 40 * n, n) for i in range(n)]  # round-robin shards
    batcher = FederatedBatcher(
        {"inputs": data.x, "targets": data.y}, parts, batch_size=batch, seed=seed
    )
    return batcher, data


def _ragged_runner(engine, *, participation=None, opt=None, num_rounds=8,
                   eval_every=4, checkpoint_every=0, seed=0, checkpointer=None,
                   **cfg_kw):
    """A runner on the ragged 5,4,3/3 tree (N=12); `participation` routes it
    through the cohort engine."""
    topo = parse_fanouts("5,4,3/3")
    batcher, data = _ragged_batcher(topo.num_clients, seed)

    def apply_fn(p, x):
        return jax.nn.relu(x @ p["w1"]) @ p["w2"]

    def eval_fn(p):
        return float(cnn.accuracy(apply_fn(p, jnp.asarray(data.x)), jnp.asarray(data.y)))

    runner = FederatedRunner(
        loss_fn=cnn.make_cnn_loss_fn(apply_fn),
        optimizer=opt or sgd(0.1),
        topology=topo,
        hier_config=HierFAVGConfig(
            kappa1=2, kappa2=2, participation=participation, **cfg_kw
        ),
        data_sizes=batcher.data_sizes,
        batcher=batcher,
        runner_config=RunnerConfig(
            num_rounds=num_rounds, eval_every=eval_every,
            checkpoint_every=checkpoint_every, engine=engine,
        ),
        eval_fn=eval_fn if eval_every else None,
        checkpointer=checkpointer,
    )
    k1, k2 = jax.random.split(jax.random.PRNGKey(seed))
    params = {
        "w1": jax.random.normal(k1, (8, 16)) * 0.3,
        "w2": jax.random.normal(k2, (16, 10)) * 0.3,
    }
    state = runner.init(jax.random.PRNGKey(seed), params)
    return runner, state


@pytest.mark.parametrize(
    "opt_name,cfg_kw",
    [
        ("sgd", {"sync_opt_state": True}),
        ("momentum", {}),
        ("sgd", {"transport": TransportSpec.parse("int8_ef:64/int8_ef:64")}),
    ],
    ids=["sgd_sync_opt", "momentum", "int8_ef_both"],
)
def test_cohort_runner_parity_full_population(opt_name, cfg_kw):
    """With C == N (round_robin: every cohort is the whole population, in
    order) the cohort engine — store swap, prefetched cohort arrays, cohort
    eval reduction included — reproduces the superround runner's history and
    final state bit-exactly on the ragged tree."""
    def build(engine, part):
        opt = momentum(0.1, 0.9) if opt_name == "momentum" else sgd(0.1)
        return _ragged_runner(engine, participation=part, opt=opt, **cfg_kw)

    base, bstate = build("superround", None)
    bstate = base.run(bstate)
    part = ParticipationSpec(cohort_size=12, sampler="round_robin")
    coh, cstate = build("auto", part)
    cstate = coh.run(cstate)

    rec_b, rec_c = base.records_to_dict(), coh.records_to_dict()
    gn_b, gn_c = rec_b.pop("grad_norm"), rec_c.pop("grad_norm")
    np.testing.assert_allclose(gn_b, gn_c, rtol=1e-6)  # diagnostic: ULP drift ok
    assert rec_b == rec_c
    _assert_states_equal(bstate, cstate)
    _assert_trees_equal(bstate.rng, cstate.rng, "rng")
    # momentum/EF leave sticky rows behind; the store must have seen them all
    if not coh.client_store.is_empty:
        assert coh.client_store.num_touched == 12


def test_cohort_runner_rejects_incompatible_setups():
    part = ParticipationSpec(cohort_size=6, sampler="uniform")
    runner, state = _ragged_runner("per_round", participation=part)
    with pytest.raises(ValueError, match="per_round"):
        runner.run(state)
    runner, state = _ragged_runner("auto", participation=part, eval_every=3)
    with pytest.raises(ValueError, match="eval_every"):
        runner.run(state)


def test_cohort_config_rejects_aggregators():
    part = ParticipationSpec(cohort_size=4)
    with pytest.raises(ValueError, match="weighted mean"):
        HierFAVGConfig(
            kappa1=2, kappa2=2, participation=part,
            aggregators=aggregation.AggregatorSpec.parse("median/weighted_mean"),
        )


def test_cohort_resume_parity(tmp_path):
    """Interrupted + resumed == straight run, bit for bit. The checkpoint
    carries paired sampler+batcher snapshots (the sampler RNG state IS the
    cohort sequence) and the full store, so the resumed run replays the
    exact same cohorts, batches, and sticky rows."""
    from repro.checkpoint import CheckpointManager

    part = ParticipationSpec(cohort_size=6, sampler="uniform", seed=1)

    def build(ckdir, num_rounds):
        return _ragged_runner(
            "auto", participation=part, opt=momentum(0.1, 0.9),
            num_rounds=num_rounds, eval_every=4, checkpoint_every=4,
            checkpointer=CheckpointManager(str(ckdir), keep=4),
        )

    ra, sa = build(tmp_path / "straight", 8)
    sa = ra.run(sa)

    rb, sb = build(tmp_path / "resumed", 4)
    rb.run(sb)  # stops (and checkpoints) at round 4

    rc, _ = build(tmp_path / "resumed", 8)
    k1, k2 = jax.random.split(jax.random.PRNGKey(0))
    params = {
        "w1": jax.random.normal(k1, (8, 16)) * 0.3,
        "w2": jax.random.normal(k2, (16, 10)) * 0.3,
    }
    sc, start = rc.restore_or_init(jax.random.PRNGKey(0), params)
    assert start == 4
    sc = rc.run(sc, start_round=start)

    _assert_states_equal(sa, sc)
    _assert_trees_equal(sa.rng, sc.rng, "rng")
    # host store contents (momentum traces by original client id) match
    st_a, st_c = ra.client_store.state(), rc.client_store.state()
    _assert_trees_equal(st_a["leaves"], st_c["leaves"], "store leaves")
    np.testing.assert_array_equal(st_a["touched"], st_c["touched"])
    # the resumed history is the straight run's tail
    tail = ra.history[4:]
    assert len(rc.history) == len(tail)
    for x, y in zip(tail, rc.history):
        assert (x.round, x.step, x.loss, x.accuracy) == (y.round, y.step, y.loss, y.accuracy)
    # and both samplers continue on the identical cohort stream
    np.testing.assert_array_equal(
        ra._cohort_sampler().sample(), rc._cohort_sampler().sample()
    )


def test_wire_bytes_respects_dtype(rng):
    """bf16 models must report half the uplink bytes of fp32 (the hardcoded
    4-byte leaf assumption is gone)."""
    runner, state32 = _mlp_runner("per_round", num_rounds=1)
    params16 = jax.tree_util.tree_map(
        lambda x: x.astype(jnp.bfloat16), {"w1": np.zeros((8, 16)), "w2": np.zeros((16, 10))}
    )
    state16 = runner.init(jax.random.PRNGKey(0), params16)
    b32 = runner._wire_bytes_per_step(state32)
    b16 = runner._wire_bytes_per_step(state16)
    assert b32 > 0
    np.testing.assert_allclose(b16, b32 / 2, rtol=1e-6)
