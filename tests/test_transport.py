"""Per-level compressed transport: codecs, TransportSpec plumbing, the
hierfavg aggregation-boundary routing, and the bits-per-param accounting."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (
    FedTopology, HierFAVGConfig, build_hier_round, build_train_step,
    cost_model as cm, init_state, parse_fanouts,
)
from repro.dist import collectives
from repro.fed import transport as tp
from repro.optim import sgd


def quadratic_setup(rng, n=6, dim=4):
    centers = rng.normal(size=(n, dim))
    sizes = rng.integers(1, 5, size=n).astype(np.float64)

    def loss_fn(params, batch, _rng):
        return 0.5 * jnp.sum((params["w"] - batch["c"]) ** 2)

    batch = {"c": jnp.asarray(centers, jnp.float32)}
    return sizes, loss_fn, batch


def run_steps(rng, transport, steps=9, kappa1=2, kappa2=2, n=6, dim=4):
    sizes, loss_fn, batch = quadratic_setup(rng, n, dim)
    topo = FedTopology(num_edges=2, clients_per_edge=n // 2)
    cfg = HierFAVGConfig(kappa1=kappa1, kappa2=kappa2, transport=transport)
    opt = sgd(0.1)
    state = init_state(jax.random.PRNGKey(0), {"w": jnp.zeros(dim)}, opt, topo, cfg)
    step = jax.jit(build_train_step(loss_fn, opt, topo, cfg, jnp.asarray(sizes, jnp.float32)))
    for _ in range(steps):
        state, _ = step(state, batch)
    return np.asarray(state.params["w"])


# ---------------------------------------------------------------------------
# Codec / spec units
# ---------------------------------------------------------------------------

def test_quantize_rows_roundtrip_bound(rng):
    x = jnp.asarray(rng.normal(size=(4, 700)) * 2.0, jnp.float32)
    q, s = tp.quantize_rows(x, 256)
    assert q.shape == (4, 768) and s.shape == (4, 3)
    back = tp.dequantize_rows(q, s, 700, 256)
    assert back.shape == (4, 700)
    assert float(jnp.max(jnp.abs(back - x))) <= float(jnp.max(s)) * 0.5 + 1e-6


def test_quantize_rows_blocks_stay_per_client(rng):
    """Changing one client's row must not change any other row's payload."""
    x = np.asarray(rng.normal(size=(3, 512)), np.float32)
    q1, s1 = tp.quantize_rows(jnp.asarray(x), 256)
    x2 = x.copy()
    x2[1] *= 100.0
    q2, s2 = tp.quantize_rows(jnp.asarray(x2), 256)
    np.testing.assert_array_equal(np.asarray(q1[0]), np.asarray(q2[0]))
    np.testing.assert_array_equal(np.asarray(q1[2]), np.asarray(q2[2]))
    np.testing.assert_array_equal(np.asarray(s1)[[0, 2]], np.asarray(s2)[[0, 2]])


def test_codec_bits_per_param():
    assert tp.IdentityCodec().bits_per_param == 32.0
    assert tp.Int8BlockCodec(block=256).bits_per_param == pytest.approx(8.125)
    assert tp.Int8BlockCodec(block=128).bits_per_param == pytest.approx(8.25)
    assert tp.int8_ef(256).error_feedback and not tp.Int8BlockCodec().error_feedback


def test_parse_and_describe():
    spec = tp.TransportSpec.parse("identity/int8:128/int8_ef")
    assert spec.depth == 3
    assert spec.codec(1).is_identity
    assert spec.codec(2).block == 128 and not spec.codec(2).error_feedback
    assert spec.codec(3).error_feedback
    assert spec.needs_residual and not spec.is_trivial
    assert spec.describe() == "identity/int8:128/int8_ef:256"
    assert tp.TransportSpec.identity(2).is_trivial
    cloud = tp.TransportSpec.cloud_int8(3)
    assert [c.is_identity for c in cloud.codecs] == [True, True, False]
    with pytest.raises(ValueError):
        tp.parse_codec("int4")
    with pytest.raises(ValueError):
        tp.TransportSpec.parse("")


def test_error_feedback_residual_identity(rng):
    """EF codec: new residual == pre-encode input minus what the wire
    delivered, and the carried residual is added to the next upload."""
    codec = tp.int8_ef(128)
    delta = {"w": jnp.asarray(rng.normal(size=(3, 200)), jnp.float32)}
    zero = jax.tree_util.tree_map(jnp.zeros_like, delta)
    out1, r1 = codec.roundtrip(delta, zero)
    np.testing.assert_allclose(
        np.asarray(r1["w"]), np.asarray(delta["w"] - out1["w"]), atol=1e-7
    )
    # second boundary with the same delta: input absorbs the residual
    out2, r2 = codec.roundtrip(delta, r1)
    np.testing.assert_allclose(
        np.asarray(out2["w"] + r2["w"]), np.asarray(delta["w"] + r1["w"]), atol=1e-6
    )
    # EF telescopes: two decoded uploads track 2*delta better than unbiased-less plain
    tot = np.asarray(out1["w"] + out2["w"])
    np.testing.assert_allclose(tot, 2 * np.asarray(delta["w"]), atol=float(jnp.max(jnp.abs(delta["w"]))) / 127 + 1e-5)


def test_plain_codec_leaves_residual_untouched(rng):
    codec = tp.Int8BlockCodec(block=128)
    delta = {"w": jnp.asarray(rng.normal(size=(2, 128)), jnp.float32)}
    out, res = codec.roundtrip(delta, None)
    assert res is None
    marker = {"w": jnp.full((2, 128), 7.0)}
    _, res2 = codec.roundtrip(delta, marker)
    assert res2 is marker


# ---------------------------------------------------------------------------
# hierfavg integration
# ---------------------------------------------------------------------------

def test_identity_transport_bitwise_unchanged(rng):
    # two fresh generators with the same seed -> identical problems
    r1, r2 = np.random.default_rng(123), np.random.default_rng(123)
    plain = run_steps(r1, None)
    ident = run_steps(r2, tp.TransportSpec.identity(2))
    np.testing.assert_array_equal(plain, ident)


def test_int8_transport_tracks_plain(rng):
    r1, r2 = np.random.default_rng(7), np.random.default_rng(7)
    plain = run_steps(r1, None, steps=12)
    int8 = run_steps(r2, tp.TransportSpec.parse("identity/int8"), steps=12)
    assert not np.array_equal(plain, int8)  # compression actually happened
    np.testing.assert_allclose(int8, plain, atol=5e-3)


def test_ef_transport_tracks_plain_both_levels(rng):
    r1, r2 = np.random.default_rng(11), np.random.default_rng(11)
    plain = run_steps(r1, None, steps=12)
    ef = run_steps(r2, tp.TransportSpec.parse("int8_ef:128/int8_ef:128"), steps=12)
    np.testing.assert_allclose(ef, plain, atol=2e-2)


def test_transport_state_allocation(rng):
    sizes, loss_fn, batch = quadratic_setup(rng)
    topo = FedTopology(num_edges=2, clients_per_edge=3)
    opt = sgd(0.1)
    cfg = HierFAVGConfig(kappa1=2, kappa2=2, transport=tp.TransportSpec.identity(2))
    s = init_state(jax.random.PRNGKey(0), {"w": jnp.zeros(4)}, opt, topo, cfg)
    assert s.anchor is None and s.residual is None  # trivial spec: no extra state
    cfg = HierFAVGConfig(kappa1=2, kappa2=2, transport=tp.TransportSpec.parse("identity/int8"))
    s = init_state(jax.random.PRNGKey(0), {"w": jnp.zeros(4)}, opt, topo, cfg)
    assert s.anchor is not None and s.residual is None  # no EF codec: no residual
    cfg = HierFAVGConfig(kappa1=2, kappa2=2, transport=tp.TransportSpec.parse("identity/int8_ef"))
    s = init_state(jax.random.PRNGKey(0), {"w": jnp.zeros(4)}, opt, topo, cfg)
    assert s.anchor is not None and s.residual is not None


def test_config_validation():
    with pytest.raises(ValueError):  # depth mismatch
        HierFAVGConfig(kappa1=2, kappa2=2, transport=tp.TransportSpec.parse("int8"))
    with pytest.raises(ValueError):  # active transport subsumes delta_cloud
        HierFAVGConfig(
            kappa1=2, kappa2=2, delta_cloud=True,
            transport=tp.TransportSpec.parse("identity/int8"),
        )
    with pytest.raises(TypeError):
        HierFAVGConfig(kappa1=2, kappa2=2, transport="identity/int8")
    # trivial transport composes with delta_cloud unchanged
    HierFAVGConfig(kappa1=2, kappa2=2, delta_cloud=True, transport=tp.TransportSpec.identity(2))


def test_multilevel_ragged_transport_runs(rng):
    """3-level ragged tree, int8 on the top two hops, via build_hier_round."""
    spec = parse_fanouts("3,2,3/2,1/2")
    n = spec.num_clients
    sizes = rng.integers(1, 4, size=n).astype(np.float64)
    centers = rng.normal(size=(n, 3))

    def loss_fn(params, batch, _rng):
        return 0.5 * jnp.sum((params["w"] - batch["c"]) ** 2)

    cfg = HierFAVGConfig.multi_level(
        [2, 2, 2], transport=tp.TransportSpec.parse("identity/int8/int8_ef")
    )
    opt = sgd(0.1)
    w = jnp.asarray(sizes, jnp.float32)
    state = init_state(jax.random.PRNGKey(0), {"w": jnp.zeros(3)}, opt, spec, cfg)
    rnd = jax.jit(build_hier_round(loss_fn, opt, spec, cfg, w))
    batch = {"c": jnp.asarray(centers, jnp.float32)}
    stacked = jax.tree_util.tree_map(lambda x: jnp.stack([x] * cfg.kappa1), batch)
    for r in range(8):  # spans the level-2 and level-3 boundaries
        state, m = rnd(state, stacked, jnp.int32(r))
    got = np.asarray(state.params["w"])
    assert np.isfinite(got).all()
    # after enough rounds every client contracts toward the weighted center
    target = np.average(centers, axis=0, weights=sizes)
    assert np.abs(got - target[None]).max() < 0.5


def test_dead_group_keeps_exact_params_under_codec(rng):
    """A client whose whole edge died transmitted nothing and received no
    broadcast: its params/anchor must be BIT-exact across the boundary even
    with a non-identity codec (no quantization noise injected), and a
    masked-out client in a surviving group must not have its EF residual
    consumed."""
    sizes, loss_fn, batch = quadratic_setup(rng)
    topo = FedTopology(num_edges=2, clients_per_edge=3)
    cfg = HierFAVGConfig(
        kappa1=1, kappa2=2, transport=tp.TransportSpec.parse("int8_ef:128/int8_ef:128")
    )
    opt = sgd(0.1)
    w = jnp.asarray(sizes, jnp.float32)
    state = init_state(jax.random.PRNGKey(0), {"w": jnp.zeros(4)}, opt, topo, cfg)
    step = jax.jit(build_train_step(loss_fn, opt, topo, cfg, w))
    # warm up two steps all-alive so params/anchor/residual are non-trivial
    for _ in range(2):
        state, _ = step(state, batch)
    mask = jnp.asarray([0.0, 0.0, 0.0, 1.0, 0.0, 1.0])  # edge 0 fully dead
    before = state
    state, _ = step(state, batch, mask)
    # dead edge's clients: exactly one masked local SGD step happened, then
    # the boundary must leave params == post-local-step values untouched.
    # Recompute the local step alone to get the expected value:
    from repro.core.hierfavg import build_local_step

    local = jax.jit(build_local_step(loss_fn, opt))
    expect, _ = local(before, batch)
    # atol guards only against cross-program 1-ulp compile differences;
    # codec noise would be ~scale/2 ≈ 1e-4, orders of magnitude above it
    np.testing.assert_allclose(
        np.asarray(state.params["w"])[:3], np.asarray(expect.params["w"])[:3], atol=1e-7
    )
    # anchor of dead clients untouched (they received no broadcast)
    np.testing.assert_array_equal(
        np.asarray(state.anchor["w"])[:3], np.asarray(before.anchor["w"])[:3]
    )
    # residual: dead clients (0-2) and the masked-out client 4 kept theirs
    for i in (0, 1, 2, 4):
        np.testing.assert_array_equal(
            np.asarray(state.residual["w"])[i], np.asarray(before.residual["w"])[i]
        )
    # surviving clients aggregated: 3 and 5 hold the same (new) model
    np.testing.assert_array_equal(
        np.asarray(state.params["w"])[3], np.asarray(state.params["w"])[5]
    )
    assert not np.array_equal(np.asarray(state.params["w"])[3], np.asarray(expect.params["w"])[3])


# ---------------------------------------------------------------------------
# bits accounting: collectives + cost model + runner threading
# ---------------------------------------------------------------------------

def test_collectives_bits_scaling():
    spec = parse_fanouts("10,10,10,10,10/5")
    base = collectives.hierarchy_traffic_per_step(1e6, spec, (6, 10))
    tr = tp.TransportSpec.parse("identity/int8")
    comp = collectives.hierarchy_traffic_per_step(
        1e6, spec, (6, 10), bits_per_param=tr.bits_vector()
    )
    assert comp[0] == base[0]  # edge hop untouched
    np.testing.assert_allclose(comp[1], base[1] * 8.125 / 32.0)
    with pytest.raises(ValueError):
        collectives.hierarchy_traffic_per_step(1e6, spec, (6, 10), bits_per_param=(8.0,))
    edge, cloud = collectives.hierfavg_traffic_per_step(
        1e6, 10, 5, 6, 10, cloud_bits_per_param=8.0
    )
    edge0, cloud0 = collectives.hierfavg_traffic_per_step(1e6, 10, 5, 6, 10)
    assert edge == edge0 and cloud == cloud0 * 0.25


def test_collectives_mixed_bits_ragged():
    """Mixed per-level bit widths on a ragged tree: each hop scales by its
    own bits/32 and the bottleneck is the largest group at that level."""
    spec = parse_fanouts("16,12,10,7,5/5")
    base = collectives.hierarchy_traffic_per_step(1e6, spec, (6, 10))
    assert base[0] == pytest.approx(collectives.ring_allreduce_bytes(1e6, 16) / 6)
    assert base[1] == pytest.approx(collectives.ring_allreduce_bytes(1e6, 5) / 60)
    mixed = collectives.hierarchy_traffic_per_step(
        1e6, spec, (6, 10), bits_per_param=(16.0, 8.0)
    )
    np.testing.assert_allclose(mixed[0], base[0] * 0.5)
    np.testing.assert_allclose(mixed[1], base[1] * 0.25)


def test_collectives_mixed_bits_depth3_ragged():
    spec = parse_fanouts("4,3,2,5/2,2/2")
    kv = (2, 3, 4)
    base = collectives.hierarchy_traffic_per_step(1e6, spec, kv)
    # bottleneck groups: a 5-client edge, 2 edges per region, 2 regions
    assert base[0] == pytest.approx(collectives.ring_allreduce_bytes(1e6, 5) / 2)
    assert base[1] == pytest.approx(collectives.ring_allreduce_bytes(1e6, 2) / 6)
    assert base[2] == pytest.approx(collectives.ring_allreduce_bytes(1e6, 2) / 24)
    mixed = collectives.hierarchy_traffic_per_step(
        1e6, spec, kv, bits_per_param=(32.0, 16.0, 8.0)
    )
    np.testing.assert_allclose(mixed[0], base[0])
    np.testing.assert_allclose(mixed[1], base[1] * 0.5)
    np.testing.assert_allclose(mixed[2], base[2] * 0.25)
    with pytest.raises(ValueError):  # one entry per level, strictly
        collectives.hierarchy_traffic_per_step(1e6, spec, kv, bits_per_param=(16.0, 8.0))
    with pytest.raises(ValueError):  # positive widths only
        collectives.hierarchy_traffic_per_step(
            1e6, spec, kv, bits_per_param=(32.0, 0.0, 8.0)
        )


def test_workload_costs_with_bits():
    costs = cm.paper_workload("mnist")
    comp = costs.with_bits(32.0, 8.0)
    # edge leg unchanged, cloud leg quartered
    assert comp.t_comm_edge == costs.t_comm_edge
    np.testing.assert_allclose(comp.t_comm_cloud, costs.t_comm_cloud * 0.25)
    # compute terms untouched -> interval time strictly between
    t_base = cm.cloud_interval_time(costs, 6, 10)
    t_comp_only = 60 * costs.t_comp
    t_q = cm.cloud_interval_time(comp, 6, 10)
    assert t_comp_only < t_q < t_base
    # energy: uplink term scales with edge bits
    e8 = costs.with_bits(8.0, 8.0)
    np.testing.assert_allclose(
        cm.cloud_interval_energy(e8, 6, 10),
        60 * costs.e_comp + 10 * costs.e_comm_edge * 0.25,
    )
    with pytest.raises(ValueError):
        costs.with_bits(0.0, 8.0)


def test_cluster_costs_with_bits():
    c = cm.ClusterCosts(t_step=1.0, t_edge_agg=0.5, t_cloud_agg=2.0)
    q = c.with_bits(8.0, 8.0)
    np.testing.assert_allclose(q.t_edge_agg, 0.125)
    np.testing.assert_allclose(q.t_cloud_agg, 0.5)
    assert q.t_step == 1.0


def test_transport_wire_bytes_helper():
    tr = tp.TransportSpec.parse("identity/int8")
    assert tp.transport_wire_bytes_per_param(None, 2) == (4.0, 4.0)
    b = tp.transport_wire_bytes_per_param(tr, 2)
    assert b[0] == 4.0 and b[1] == pytest.approx(8.125 / 8.0)


def test_fused_decode_segment_mean_matches_composition(rng):
    n, d = 8, 512
    x = jnp.asarray(rng.normal(size=(n, d)) * 0.1, jnp.float32)
    w = jnp.asarray(rng.uniform(0.5, 2.0, size=n), jnp.float32)
    seg = jnp.asarray([0, 0, 0, 1, 1, 2, 2, 2], jnp.int32)
    q, s = tp.quantize_rows(x, 128)
    fused = tp.fused_decode_segment_mean(q, s, w, seg, 3, block_d=256)
    from repro.core import aggregation

    composed = aggregation.segment_weighted_mean(
        tp.dequantize_rows(q, s, d, 128), w, seg, 3
    )
    np.testing.assert_allclose(np.asarray(fused), np.asarray(composed), atol=1e-6)
